"""StatsObjective — the protocol behind every stats-based federated loss.

The paper's core insight (Eq. 3) is that any loss computable from
encoding statistics that are *linear in samples* can be trained
federatedly by aggregating those statistics: large-batch statistics are
exactly the client-size-weighted average of per-client statistics, so the
two-phase aggregate / redistribute / stop-grad-combine protocol (Fig. 2)
— and the Appendix-A centralized-equivalence — apply to the whole family,
not just CCO. Sec. 6 names VICReg as the first extension; this module
makes the family a first-class protocol.

A :class:`StatsObjective` declares

  * its stat spec — which statistics ride the wire (``stat_keys``,
    ``stat_spec``) and whether the within-view second moments are among
    them (``second_moments``, the kernel's moment-set flag);
  * ``stats`` / ``stats_masked`` — accumulation through the ONE shared
    accumulator (:func:`repro.core.cco.moment_stats`), required linear in
    samples so Eq.-3 aggregation, the flattened-cohort
    ``cco_stats_pallas`` path, and the shard_map psum path all stay
    exact;
  * ``loss_from_stats`` — the loss as a pure function of statistics;
  * ``combine`` — the stop-grad combine ``<.>_k + sg(<.>_A - <.>_k)``
    (paper Fig. 2; shared default).

Everything downstream — ``fed_sim.stats_round``, the engine bodies,
``stats_round_sharded``, the comm Channels, the train CLI, and the
benchmarks — is parametric in the objective: the channels transport the
objective's stats dict unchanged (payload shapes differ per objective;
quantization / DP / dropout and wire-bytes accounting compose per leaf),
and the gradient-equivalence tests run per registered objective.

Precision contract: statistics ACCUMULATE in float32 regardless of the
encoder's compute dtype. ``cco.moment_stats`` casts its inputs to f32
before any reduction, so a bf16 encoder forward
(``EngineConfig.compute_dtype='bfloat16'``) feeds f32 sums — this is
what keeps Eq.-3 exact under mixed precision: the aggregation is a sum
over the whole cohort (N up to tens of thousands of samples), and bf16's
8-bit mantissa would lose low-order per-sample contributions long before
the cohort is fully accumulated, silently biasing ``sq_*``/``cross``
(and thus every loss in the family) toward the large-magnitude samples.
Tests pin both halves: accumulator dtype is f32 for bf16 inputs, and
bf16-input stats stay within bf16-rounding tolerance of f32 stats.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import cco

F32 = jnp.float32
Stats = Dict[str, jnp.ndarray]


class StatsObjective:
    """A dual-encoding loss computable from linear-in-samples statistics.

    Subclasses set ``name``, ``stat_keys``, ``second_moments``, and
    implement ``loss_from_stats``; the accumulation, combine, spec, and
    collapse-probe plumbing is shared.
    """

    name: str = "stats"
    stat_keys: Tuple[str, ...] = cco.STAT_KEYS
    second_moments: bool = False

    # ------------------------------------------------------- accumulation
    def stats(self, zf, zg) -> Stats:
        """Batch statistics of encodings zf, zg: (N, d) -> Stats."""
        return cco.moment_stats(zf, zg, second_moments=self.second_moments)

    def stats_masked(self, zf, zg, mask) -> Stats:
        """Statistics over valid samples only (mask: (N,) in {0,1})."""
        return cco.moment_stats(zf, zg, mask,
                                second_moments=self.second_moments)

    def stat_spec(self, d: int) -> Dict[str, Tuple[int, ...]]:
        """Wire payload spec: stat key -> shape, for encoding dim ``d``.

        Derived from ``stats`` itself via ``jax.eval_shape`` (no FLOPs, no
        memory), so custom registered objectives with their own stat keys
        get a correct spec with no override."""
        z = jax.ShapeDtypeStruct((1, d), F32)
        return {k: tuple(v.shape)
                for k, v in jax.eval_shape(self.stats, z, z).items()}

    def stat_template(self, d: int) -> Stats:
        """Zero payload pytree matching ``stat_spec`` (bytes accounting)."""
        return {k: jnp.zeros(s, F32) for k, s in self.stat_spec(d).items()}

    # ------------------------------------------------------ loss + combine
    def loss_from_stats(self, st: Stats) -> jnp.ndarray:
        raise NotImplementedError

    def combine(self, local: Stats, agg: Stats) -> Stats:
        """Stop-grad combine <.>_C = <.>_k + sg(<.>_A - <.>_k) (Fig. 2)."""
        return cco.dcco_combine(local, agg)

    def loss(self, zf, zg) -> jnp.ndarray:
        """Centralized large-batch loss (the paper's upper-bound baseline)."""
        return self.loss_from_stats(self.stats(zf, zg))

    # ------------------------------------------------------------- probes
    def encoding_std(self, agg: Stats) -> jnp.ndarray:
        """Collapse probe on aggregated stats (mean per-dim std of F)."""
        return jnp.sqrt(jnp.maximum(
            agg["sq_f"] - agg["mean_f"] ** 2, 0.0)).mean()

    def __repr__(self) -> str:
        return f"{type(self).__name__}()"


def per_client_loss(objective: StatsObjective, zf, zg,
                    clients: int) -> jnp.ndarray:
    """Faithful per-client federated objective for any StatsObjective.

    L = sum_k (N_k/N) L(<.>_k + sg(<.>_A - <.>_k)) with equal-size
    clients laid out contiguously — the generic form of
    ``dcco.dcco_loss_per_client`` / the old ``dvicreg_loss_per_client``.
    Gradient-equivalent to the centralized ``objective.loss`` by the
    Appendix-A argument (tested per registered objective).
    """
    n, d = zf.shape
    assert n % clients == 0
    st_k = jax.vmap(objective.stats)(zf.reshape(clients, n // clients, d),
                                     zg.reshape(clients, n // clients, d))
    w = jnp.full((clients,), 1.0 / clients, F32)
    agg = cco.weighted_average_stats(st_k, w)

    def client_loss(stats_k):
        return objective.loss_from_stats(objective.combine(stats_k, agg))

    return jnp.sum(w * jax.vmap(client_loss)(st_k))


def make_shard_map_loss(objective: StatsObjective, mesh,
                        data_axes=("data",)):
    """Shard_map loss for any StatsObjective: local stats -> explicit psum
    aggregation over ``data_axes`` (the Fig.-2 wire collective at device
    granularity) -> stop-grad combine -> loss. Generic form of
    ``dcco.make_shard_map_dcco_loss``; gradients match the centralized
    loss exactly (shard_map's transpose psums the per-shard grads)."""
    from jax.sharding import PartitionSpec as P

    from repro.core.dcco import shard_map_compat

    pspec = P(data_axes if len(data_axes) > 1 else data_axes[0], None)

    def local_loss(zf_local, zg_local):
        local = objective.stats(zf_local, zg_local)
        agg = {k: jax.lax.pmean(v, data_axes) for k, v in local.items()}
        loss = objective.loss_from_stats(objective.combine(local, agg))
        return loss[None] if loss.ndim == 0 else loss

    sharded = shard_map_compat(local_loss, mesh,
                               in_specs=(pspec, pspec), out_specs=P())

    def wrapped(zf, zg):
        return sharded(zf, zg).reshape(())

    return wrapped
