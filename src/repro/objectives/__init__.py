# StatsObjective protocol: one sufficient-statistics abstraction powering
# every stats-based federated loss (paper Eq. 3 generalized; Sec. 6).
from repro.objectives.base import (  # noqa: F401
    Stats, StatsObjective, make_shard_map_loss, per_client_loss)
from repro.objectives.standard import (  # noqa: F401
    CCOObjective, VicRegObjective, WMSEObjective)

# CLI-facing registry. Factories take objective-specific hyperparameters
# (CCO's lam, VICReg's weights, ...); register_objective extends it.
_REGISTRY = {
    "dcco": CCOObjective,
    "dvicreg": VicRegObjective,
    "dwmse": WMSEObjective,
}

OBJECTIVES = tuple(_REGISTRY)


def register_objective(name: str, factory) -> None:
    """Register a StatsObjective factory under ``name`` (CLI-visible)."""
    global OBJECTIVES
    _REGISTRY[name] = factory
    OBJECTIVES = tuple(_REGISTRY)


def get_objective(objective, **hyper) -> StatsObjective:
    """Resolve a name (or pass through an instance) to a StatsObjective."""
    if isinstance(objective, StatsObjective):
        if hyper:
            raise ValueError(
                f"hyperparameters {sorted(hyper)} cannot be applied to an "
                f"already-constructed objective {objective!r}")
        return objective
    if objective in _REGISTRY:
        return _REGISTRY[objective](**hyper)
    raise ValueError(f"unknown objective {objective!r}; expected one of "
                     f"{OBJECTIVES} or a StatsObjective instance")
