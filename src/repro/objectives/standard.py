"""The three registered objectives: D-CCO, D-VICReg, D-WMSE.

Each wraps its loss module (`repro.core.{cco,vicreg,wmse}`) behind the
:class:`~repro.objectives.base.StatsObjective` protocol. D-CCO ships the
paper's five statistics; D-VICReg and D-WMSE add the two within-view
second moments (``second_moments=True`` — the kernel's moment-set flag),
so their wire payload is the 7-stat dict and every comm Channel / bytes
accountant sees the larger shapes automatically.
"""
from __future__ import annotations

from repro.core import cco, vicreg, wmse
from repro.objectives.base import StatsObjective


class CCOObjective(StatsObjective):
    """Cross-correlation optimization (paper Eq. 1-3) — the default."""

    name = "dcco"
    stat_keys = cco.STAT_KEYS
    second_moments = False

    def __init__(self, lam: float = 20.0):
        self.lam = float(lam)

    def loss_from_stats(self, st):
        return cco.cco_loss_from_stats(st, self.lam)

    def __repr__(self):
        return f"CCOObjective(lam={self.lam})"


class VicRegObjective(StatsObjective):
    """VICReg (Bardes et al. 2022) from seven statistics — the extension
    the paper names as future work (Sec. 6)."""

    name = "dvicreg"
    stat_keys = vicreg.VICREG_STAT_KEYS
    second_moments = True

    def __init__(self, inv_weight: float = 25.0, var_weight: float = 25.0,
                 cov_weight: float = 1.0, gamma: float = 1.0,
                 eps: float = 1e-4):
        self.inv_weight = float(inv_weight)
        self.var_weight = float(var_weight)
        self.cov_weight = float(cov_weight)
        self.gamma = float(gamma)
        self.eps = float(eps)

    def loss_from_stats(self, st):
        return vicreg.vicreg_loss_from_stats(
            st, inv_weight=self.inv_weight, var_weight=self.var_weight,
            cov_weight=self.cov_weight, gamma=self.gamma, eps=self.eps)

    def __repr__(self):
        return (f"VicRegObjective(inv={self.inv_weight}, "
                f"var={self.var_weight}, cov={self.cov_weight})")


class WMSEObjective(StatsObjective):
    """Whitening-penalty W-MSE from the same seven statistics — the third
    registered objective, proving the protocol is not a two-case special."""

    name = "dwmse"
    stat_keys = wmse.WMSE_STAT_KEYS
    second_moments = True

    def __init__(self, inv_weight: float = 1.0, whiten_weight: float = 1.0):
        self.inv_weight = float(inv_weight)
        self.whiten_weight = float(whiten_weight)

    def loss_from_stats(self, st):
        return wmse.wmse_loss_from_stats(
            st, inv_weight=self.inv_weight,
            whiten_weight=self.whiten_weight)

    def __repr__(self):
        return (f"WMSEObjective(inv={self.inv_weight}, "
                f"whiten={self.whiten_weight})")
