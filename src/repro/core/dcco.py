"""DCCO loss paths for the pod-scale (single-program) train step.

Three implementations, all gradient-equivalent (tested):

  fused      — centralized-equivalent: CCO on the differentiable global batch
               statistics. By the paper's Appendix-A theorem this equals one
               DCCO round with one local step, at the cost of ZERO extra
               collectives beyond the stats all-reduce XLA already inserts
               for the batch-mean. This is the optimized production path.

  per_client — faithful per-client formulation: per-client stats, weighted
               aggregate, stop-grad combine per client, weighted per-client
               losses. Mirrors the protocol math exactly (gradients provably
               identical; see tests/test_equivalence.py).

  shard_map  — protocol-faithful at the *device* level: each (pod,data) shard
               plays a client cohort; local stats -> explicit psum over the
               data axes (the wire aggregation of Fig. 2) -> stop-grad
               combine -> loss. Used to demonstrate/measure the protocol's
               collective on the mesh.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import cco

F32 = jnp.float32


def dcco_loss_fused(zf, zg, lam: float) -> jnp.ndarray:
    return cco.cco_loss(zf, zg, lam)


def dcco_loss_per_client(zf, zg, lam: float, clients: int) -> jnp.ndarray:
    """Faithful per-client DCCO objective (equal-size clients).

    L = sum_k (N_k/N) L_CCO(<.>_k + sg(<.>_A - <.>_k))
    """
    st_k = cco.per_client_stats(zf, zg, clients)             # stacked (K, ...)
    w = jnp.full((clients,), 1.0 / clients, F32)
    agg = cco.weighted_average_stats(st_k, w)

    def client_loss(stats_k):
        return cco.cco_loss_from_stats(cco.dcco_combine(stats_k, agg), lam)

    losses = jax.vmap(client_loss)(st_k)
    return jnp.sum(w * losses)


def dcco_loss_shard_map_local(zf_local, zg_local, lam: float, axis_names) -> jnp.ndarray:
    """Body to be run under shard_map: zf/zg are the LOCAL shard's encodings.

    Computes local stats, aggregates across `axis_names` with an explicit
    psum (the DCCO wire protocol), applies the stop-grad combine, and
    returns the local loss (identical value on all shards).
    """
    local = cco.encoding_stats(zf_local, zg_local)
    # equal shard sizes -> weighted average = pmean
    agg = {k: jax.lax.pmean(v, axis_names) for k, v in local.items()}
    combined = cco.dcco_combine(local, agg)
    return cco.cco_loss_from_stats(combined, lam)


def shard_map_compat(f, mesh, in_specs, out_specs):
    """``shard_map`` across jax versions: new releases expose ``jax.shard_map``
    with ``check_vma``; older ones ``jax.experimental.shard_map`` with
    ``check_rep``. Replication checking is disabled either way (the DCCO
    bodies make outputs replicated via explicit psums)."""
    import inspect
    try:
        from jax import shard_map as sm
    except ImportError:
        from jax.experimental.shard_map import shard_map as sm
    params = inspect.signature(sm).parameters
    check_kw = "check_vma" if "check_vma" in params else "check_rep"
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **{check_kw: False})


def make_shard_map_dcco_loss(mesh, lam: float, data_axes=("data",)):
    """Returns loss_fn(zf, zg) where zf/zg are batch-sharded global arrays.

    Note the gradient: each shard backprops through its local stats only;
    psum of the per-shard grads (inserted by shard_map's transpose) yields
    exactly the centralized gradient — Appendix A at device granularity.
    """
    pspec = P(data_axes if len(data_axes) > 1 else data_axes[0], None)

    @functools.partial(
        shard_map_compat, mesh=mesh,
        in_specs=(pspec, pspec), out_specs=P())
    def loss_fn(zf, zg):
        loss = dcco_loss_shard_map_local(zf, zg, lam, data_axes)
        return loss[None] if loss.ndim == 0 else loss

    def wrapped(zf, zg):
        out = loss_fn(zf, zg)
        return out.reshape(())

    return wrapped


def dcco_loss(zf, zg, lam: float, impl: str = "fused", clients: int = 0,
              mesh=None, data_axes=("data",)):
    if impl == "fused":
        return dcco_loss_fused(zf, zg, lam)
    if impl == "per_client":
        assert clients > 0
        return dcco_loss_per_client(zf, zg, lam, clients)
    if impl == "shard_map":
        assert mesh is not None
        return make_shard_map_dcco_loss(mesh, lam, data_axes)(zf, zg)
    raise ValueError(f"unknown dcco impl {impl}")
