"""Scan-compiled federated round engine.

The paper's runtime is hundreds-to-thousands of rounds over sampled cohorts
of tiny clients (Fig. 2). Driving :mod:`repro.core.fed_sim` one round at a
time from Python pays per-round dispatch, host-side cohort sampling, and a
re-trace whenever shapes wobble. The engine compiles the whole multi-round
loop into ONE XLA program:

  * ``lax.scan`` over rounds with a donated ``(params, opt_state, rng)``
    carry — no host round-trips, buffers reused in place;
  * in-scan client sampling via ``jax.random.fold_in(rng, round_index)``
    followed by on-device gather + augmentation (the sampler is a traced
    function, so cohort selection lives inside the scan body);
  * a pluggable round body: ``dcco`` | ``fedavg_cco`` | ``fedavg_contrastive``
    | ``fedavg_byol`` | ``centralized`` — all reuse the reference semantics in
    :mod:`repro.core.fed_sim`, so scan-of-N-rounds == N Python-driven rounds
    (tested in tests/test_round_engine.py); the stats bodies (dcco /
    fedavg_cco / centralized) are parametric in a
    :class:`repro.objectives.StatsObjective` (``EngineConfig.objective``:
    dcco / dvicreg / dwmse), whose stats dict rides every wire unchanged;
  * a sharded-cohort DCCO path: the (K, n, ...) client axis is laid across
    the mesh's data axis with ``shard_map``; the phase-1 stats aggregation
    and the phase-2 delta average become explicit psums — the wire protocol
    of Fig. 2 at device granularity (same pattern as core/dcco.py);
  * optional routing of the phase-1 aggregate statistics through the fused
    one-pass ``cco_stats_pallas`` kernel (exact by Eq. 3 — statistics are
    linear in samples, so the flattened-cohort stats equal the weighted
    average of per-client stats);
  * chunked scan segments: rounds run in segments of ``chunk_rounds`` so
    per-round metrics (loss, encoding-std collapse probe, wire bytes)
    stream back to the host between segments, where periodic checkpointing
    via ``repro.checkpoint`` hooks in;
  * a pluggable server-update strategy and client-drift correction
    (``EngineConfig.server_update`` / ``prox_mu`` / ``scaffold``,
    :mod:`repro.server`): the server step is any FedOpt-family
    ``ServerUpdate`` (plain delegate, FedAvgM, FedAdagrad/FedAdam/FedYogi),
    FedProx adds a proximal term to every local step, and SCAFFOLD control
    variates ride the scan carry as an extra pytree (server variate +
    per-cohort-slot client variates) whose uplink flows through the same
    channel as every other payload;
  * a pluggable communication channel (``EngineConfig.channel``,
    :mod:`repro.comm`): every client->server payload — phase-1 statistics
    and phase-2 deltas — is routed through the channel's encode/decode and
    participation-weighted aggregation INSIDE the scan body (dispatch is
    trace-time, so lossy wires cost no extra Python per round), with
    per-round bytes-on-the-wire in ``EngineMetrics.wire_bytes``;
  * hierarchical aggregation and streaming mega-cohorts
    (:mod:`repro.hierarchy`): a ``HierarchicalChannel`` fans the cohort in
    through edge aggregators (clients -> edges -> server, one comm channel
    per hop, both hops' bytes accounted — on the sharded path each device
    folds its local edges with the ``kernels/segment_sum.py`` one-pass
    kernel), and ``EngineConfig.cohort_chunk`` streams the cohort through
    the round in fixed-size chunks via an inner ``lax.scan`` whose carry
    holds only the running stat/delta sums — peak memory O(chunk) instead
    of O(cohort), which is what makes thousands-of-clients rounds fit.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import fed_sim
from repro.core.dcco import shard_map_compat
from repro.server import drift as drift_lib
from repro.server import update as server_update_lib

F32 = jnp.float32

ALGORITHMS = ("dcco", "fedavg_cco", "fedavg_contrastive", "fedavg_byol",
              "centralized")

# EngineConfig.compute_dtype spellings -> canonical jnp dtype. Only the
# encoder forward/backward runs in the compute dtype; every Eq.-3 statistic
# accumulation, loss, optimizer state, and master parameter stays f32
# (see cast_encoder_apply).
COMPUTE_DTYPES = {
    "float32": jnp.float32, "f32": jnp.float32, "fp32": jnp.float32,
    "bfloat16": jnp.bfloat16, "bf16": jnp.bfloat16,
}


def resolve_compute_dtype(compute_dtype):
    """Canonicalize an EngineConfig.compute_dtype spelling to a jnp dtype."""
    if compute_dtype in COMPUTE_DTYPES:
        return COMPUTE_DTYPES[compute_dtype]
    raise ValueError(f"unknown compute_dtype {compute_dtype!r}; expected one "
                     f"of {sorted(COMPUTE_DTYPES)}")


def cast_encoder_apply(encoder_apply: Callable, compute_dtype) -> Callable:
    """Mixed-precision wrapper: run the encoder forward/backward in
    ``compute_dtype`` while the Eq.-3 statistics stay f32.

    The paper's losses are computed from *sums of per-sample encoding
    statistics* (Eq. 3), and those sums divide near-cancelling quantities
    (correlation denominators), so the accumulation is the precision-
    critical path — the encoder forward is not. This wrapper casts float
    params and float batch leaves to ``compute_dtype`` at the encoder
    boundary and returns the (low-precision) encodings unchanged;
    ``cco.moment_stats`` — the ONE accumulator every stats objective
    shares — upcasts its inputs to f32 before any reduction, so every
    statistic, loss, delta, and optimizer buffer downstream of this
    wrapper is f32 regardless of the compute dtype (property-tested in
    tests/test_mixed_precision.py).

    The cast is linear, so ``grad`` through it yields f32 master-parameter
    gradients (the classic master-weights recipe); ``float32`` returns
    ``encoder_apply`` unchanged — statically zero-cost, bit-identical.
    Integer leaves (token ids, labels) pass through untouched.
    """
    dtype = resolve_compute_dtype(compute_dtype)
    if dtype == jnp.float32:
        return encoder_apply

    def cast_tree(tree):
        return jax.tree.map(
            lambda x: x.astype(dtype)
            if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating) else x,
            tree)

    def apply(params, batch):
        return encoder_apply(cast_tree(params), cast_tree(batch))

    return apply

_CHANNEL_SALT = 0xC0                 # fold_in salt for the per-round comm key


class EngineConfig(NamedTuple):
    """Static configuration of the compiled round loop."""
    algorithm: str = "dcco"
    objective: Any = None           # StatsObjective instance or registered
                                    # name (repro.objectives) driving the
                                    # dcco/fedavg_cco/centralized bodies;
                                    # None = CCO with ``lam`` (pre-protocol
                                    # behavior, bit-identical)
    lam: float = 20.0
    temperature: float = 0.1
    client_lr: float = 1.0
    local_steps: int = 1
    chunk_rounds: int = 20          # rounds per jitted scan segment
    cohort_chunk: int = 0           # >0: stream the cohort through the
                                    # round in chunks of this many clients
                                    # (repro.hierarchy.streaming) — peak
                                    # memory O(cohort_chunk) instead of
                                    # O(cohort); requires a chunkable
                                    # sampler (make_streaming_sampler)
    scan_unroll: int = 0            # 0 = auto: 8 on CPU (XLA:CPU loses
                                    # inter-op parallelism inside while
                                    # bodies), 1 on accelerators
    donate: bool = True             # donate the (params, opt, rng) carry
    compute_dtype: str = "float32"  # encoder forward/backward dtype
                                    # ("float32" | "bfloat16"; aliases
                                    # f32/fp32/bf16). Statistics, losses,
                                    # deltas, optimizer state, and master
                                    # params stay f32 regardless — Eq.-3
                                    # accumulation is the precision-critical
                                    # path (see cast_encoder_apply)
    cohort_axis: Any = None         # mesh axis (or tuple of axes — the
                                    # multi-host data x client mesh) to
                                    # shard the K client axis over
    stats_kernel: str = "off"       # "off" | "pallas" | "interpret"
    channel: Any = None             # repro.comm Channel; None = ideal wire
    # --- server-optimization & client-drift subsystem (repro.server) ---
    server_update: Any = None       # repro.server ServerUpdate strategy;
                                    # None = wrap the engine's server_opt
                                    # argument as the bit-identical
                                    # fedavg_sgd delegate
    prox_mu: float = 0.0            # FedProx proximal coefficient (0 = off)
    scaffold: bool = False          # SCAFFOLD control variates: adds a
                                    # (c, c_slots) pytree to the scan carry
    # --- semi-synchronous buffered engine (repro.core.buffer) ---
    async_k: int = 0                # >0: FedBuff-style semi-synchronous
                                    # rounds — apply the server update when
                                    # this many client contributions have
                                    # ARRIVED (staleness-weighted buffer);
                                    # 0 = strictly synchronous rounds
    staleness_fn: Any = "unit"      # repro.core.buffer.STALENESS_FNS name
                                    # or callable tau -> weight
    latency: Any = None             # repro.data.latency model (None/"zero"/
                                    # "uniform"/"heavytail"/LatencyModel);
                                    # must match the async sampler's
    async_collapse: bool = True     # collapse the provably-synchronous
                                    # config (K = cohort, zero latency, unit
                                    # staleness) to the sync round body —
                                    # bit-identical baselines, same idiom as
                                    # HierarchicalChannel.collapse_ideal;
                                    # False forces the real buffered path
    # --- cluster-aware aggregation (repro.cluster) ---
    num_clusters: int = 0           # >1: cosine k-means on the per-client
                                    # Eq.-3 stats assigns each cohort
                                    # client a cluster inside the scan;
                                    # per-cluster correlation targets +
                                    # server-update slots (ClusterState
                                    # rides the carry). 0/1 = the global
                                    # path, bit-identical (structural
                                    # collapse, the async_collapse idiom)
    cluster_iters: int = 2          # Lloyd iterations per round (warm-
                                    # started from the carried centroids)
    cluster_fold: str = "jnp"       # per-cluster segment-sum fold impl
                                    # (hierarchy.FOLD_IMPLS; a
                                    # HierarchicalChannel's fold_impl wins)
    # --- periodic retrieval eval (repro.retrieval) ---
    retrieval_eval: Any = None      # traceable params -> {metric: scalar}
                                    # (repro.retrieval.make_retrieval_eval:
                                    # recall@k / MRR on a held-out corpus);
                                    # runs INSIDE the scan body so the
                                    # whole experiment stays one program.
                                    # A STATEFUL eval (make_refreshing_
                                    # retrieval_eval: .stateful, called as
                                    # (params, state) -> (metrics, state))
                                    # threads its index state through the
                                    # scan carry — drift-gated refresh
                                    # instead of a full re-encode per eval
    retrieval_every: int = 1        # evaluate on rounds where
                                    # round % retrieval_every == 0; skipped
                                    # rounds emit NaN (lax.cond, so the
                                    # encoder FLOPs are actually skipped)


class EngineCarry(NamedTuple):
    params: Any
    opt_state: Any
    rng: jnp.ndarray
    drift: Any = ()                 # drift-correction state (ScaffoldState
                                    # when EngineConfig.scaffold, else empty)
    buffer: Any = ()                # semi-synchronous buffer + in-flight
                                    # ring (buffer_lib.AsyncState when the
                                    # real buffered path runs, else empty)
    reval: Any = ()                 # stateful retrieval-eval state (the
                                    # refreshing eval's encoded corpus,
                                    # else empty) — threaded through the
                                    # scan so each periodic eval refreshes
                                    # rather than rebuilds the index
    cluster: Any = ()               # cluster-aware aggregation state
                                    # (repro.cluster.ClusterState when
                                    # EngineConfig.num_clusters > 1:
                                    # per-cluster params/opt slots +
                                    # warm-start centroids, else empty)


class EngineMetrics(NamedTuple):
    """Stacked per-round metrics, leading axis = rounds (= scheduler ticks
    on the buffered engine)."""
    loss: jnp.ndarray
    encoding_std: jnp.ndarray
    wire_bytes: jnp.ndarray = 0.0   # uplink bytes/round (0: ideal wire)
    applied: jnp.ndarray = 1.0      # server updates applied this tick
                                    # (sync rounds apply every round; the
                                    # buffered engine applies on K-triggers)
    staleness: jnp.ndarray = 0.0    # mean staleness (ticks) of the applied
                                    # aggregate, 0 when no update applied
    retrieval: Any = ()             # {"recall_at_k": (rounds,), "mrr":
                                    # (rounds,)} when EngineConfig.
                                    # retrieval_eval is set (NaN on rounds
                                    # the periodic eval skipped), else {}


# ---------------------------------------------------------------------------
# phase-1 aggregate statistics through the fused Pallas kernel
# ---------------------------------------------------------------------------

def make_kernel_agg_stats(interpret: bool = False,
                          second_moments: bool = False) -> Callable:
    """Aggregate cohort stats in one pass of the fused cco_stats kernel.

    ``second_moments`` selects the kernel's moment set (the objective's
    ``second_moments`` flag): "full" additionally emits the within-view
    moments VICReg-family objectives need, still in one pass.

    Rows are pre-masked (zeroed) and the normalizer is the true valid-sample
    count, which is exact for binary masks: (m*f)^2 = m*f^2 and
    (m*f)(m*g) = m*f*g.
    """
    from repro.kernels.cco_stats import cco_stats_pallas

    moments = "full" if second_moments else "cross"

    def agg_stats(zf, zg, mask):
        m = mask.astype(F32)[:, None]
        return cco_stats_pallas(zf.astype(F32) * m, zg.astype(F32) * m,
                                jnp.sum(mask.astype(F32)),
                                interpret=interpret, moments=moments)

    return agg_stats


def _resolve_agg_stats_fn(cfg: EngineConfig, objective) -> Optional[Callable]:
    if cfg.stats_kernel == "off":
        return None
    second = objective.second_moments
    if cfg.stats_kernel == "pallas":
        # pallas only compiles on accelerator backends; CPU falls back to
        # the (slow but exact) interpreter so the flag works everywhere
        return make_kernel_agg_stats(
            interpret=jax.default_backend() == "cpu", second_moments=second)
    if cfg.stats_kernel == "interpret":
        return make_kernel_agg_stats(interpret=True, second_moments=second)
    raise ValueError(f"unknown stats_kernel {cfg.stats_kernel!r}")


# ---------------------------------------------------------------------------
# sharded-cohort stats round (client axis on the mesh's data axis — or, on
# a multi-host mesh, on a (data, client) tuple of axes)
# ---------------------------------------------------------------------------

def _axis_names(axis):
    """Normalize a shard_map axis argument to a tuple of mesh-axis names."""
    return tuple(axis) if isinstance(axis, (tuple, list)) else (axis,)


def _axis_pspec(axis):
    """PartitionSpec sharding dim 0 over one axis or a tuple of axes."""
    names = _axis_names(axis)
    return P(names[0] if len(names) == 1 else names)


def _axis_size(mesh, axis) -> int:
    size = 1
    for name in _axis_names(axis):
        size *= mesh.shape[name]
    return size


def _linear_axis_index(mesh, axis):
    """The shard's linear index over one axis or a row-major tuple of axes
    (``jax.lax.axis_index`` takes a single name on the supported jax
    range, so the multi-axis index is composed explicitly)."""
    names = _axis_names(axis)
    idx = jax.lax.axis_index(names[0])
    for name in names[1:]:
        idx = idx * mesh.shape[name] + jax.lax.axis_index(name)
    return idx


def stats_round_sharded(encoder_apply: Callable, params, opt_state,
                        server_opt, client_data, client_sizes, mesh, *,
                        objective,
                        client_lr: float = 1.0, local_steps: int = 1,
                        axis="data", channel=None, channel_key=None,
                        prox_mu: float = 0.0, scaffold_state=None):
    """One two-phase stats round (any StatsObjective) with the (K, n, ...)
    client axis sharded over ``axis``. ``dcco_round_sharded`` is the
    CCO-bound back-compat alias.

    Each shard hosts K/ndev clients; phase-1 aggregation and the phase-2
    delta average are explicit psums over ``axis`` — exactly the wire
    collectives of Fig. 2, reusing the psum pattern of core/dcco.py. The
    psum aggregation is exact for any registered objective because the
    protocol requires stats linear in samples (Eq. 3). Output equals the
    single-device ``fed_sim.stats_round`` (weights N_k/N are normalized
    by the psummed global sample count).

    With a ``channel`` (repro.comm) the collectives model a real wire:
    participation and the mask-renormalized weights come from
    ``channel.begin_round`` on the full cohort (sharded alongside sizes, so
    no psum renormalization is needed — the weights already sum to 1
    globally); each shard runs the per-client encode/decode locally with a
    shard-folded key; server-side post-processing (DP noise) uses the
    replicated round key, so every shard adds the *same* noise and the
    aggregate stays replicated.

    Drift correction mirrors ``fed_sim.dcco_round``: ``prox_mu`` is
    client-local (no collective); SCAFFOLD slot variates shard with the
    client axis (each device refreshes its own slots) while the server
    variate stays replicated — the variate-delta average is one more psum,
    channel-routed under the ``"variate"`` phase. With a ``scaffold_state``
    the round returns ``(params, opt_state, new_state, metrics)``.
    """
    server_update = server_update_lib.as_server_update(server_opt)
    if scaffold_state is not None and channel is not None:
        fed_sim.check_variate_noise(channel)
    n_pad = jax.tree.leaves(client_data)[0].shape[1]
    # a tuple of axes (the multi-host data x client mesh) shards the K
    # client axis over their product; psum over the tuple is the combined
    # in-host + cross-host wire aggregation (exact by Eq.-3 linearity —
    # any summation tree)
    axis = axis if isinstance(axis, str) else tuple(axis)
    p_axis = _axis_pspec(axis)
    nshards = _axis_size(mesh, axis)
    if channel is not None:
        if channel_key is None:
            raise ValueError("channel requires channel_key")
        ctx = channel.begin_round(channel_key, client_sizes)
    else:
        ctx = None

    def local_body(p, batch_l, sizes_l, *extra):
        extra = list(extra)
        masks = fed_sim._client_masks(sizes_l, n_pad)
        if channel is None:
            n_tot = jax.lax.psum(jnp.sum(sizes_l.astype(F32)), axis)
            w_l = sizes_l.astype(F32) / n_tot
            ctx_l, ckey = None, None
        else:
            from repro.comm.channel import ChannelContext
            # local view of the round context: payload randomness differs
            # per shard (fold in the shard index), server-side randomness
            # (post_aggregate) uses the replicated round key
            w_l, mask_l, ckey, num_part = extra[:4]
            del extra[:4]
            shard_key = jax.random.fold_in(ckey,
                                           _linear_axis_index(mesh, axis))
            ctx_l = ChannelContext(shard_key, mask_l, w_l, num_part)
        if scaffold_state is not None:
            # replicated server variate + this shard's slice of the slots
            state_l = drift_lib.ScaffoldState(*extra)

        def client_stats(batch, mask):
            zf, zg = encoder_apply(p, batch)
            return objective.stats_masked(zf, zg, mask)

        st_k = jax.vmap(client_stats)(batch_l, masks)
        if ctx_l is None:
            agg = {k: jax.lax.psum(jnp.tensordot(w_l, v, axes=1), axis)
                   for k, v in st_k.items()}
        else:
            # channel.local_fold is this shard's partial aggregate of the
            # decoded payloads (the base fold is the same tensordot as
            # above; a hierarchical channel folds its shard-local edges
            # here, kernels/segment_sum.py); the psum is the server hop
            dec = channel.encode_decode(ctx_l, st_k, "stats")
            part = channel.local_fold(ctx_l, dec, "stats",
                                      num_shards=nshards)
            agg = {k: jax.lax.psum(v, axis) for k, v in part.items()}
            agg = channel.post_aggregate(
                ctx_l._replace(key=ckey), agg, "stats")

        def client_update(batch, mask, corr=None):
            def loss_fn(pp):
                zf, zg = encoder_apply(pp, batch)
                local = objective.stats_masked(zf, zg, mask)
                return objective.loss_from_stats(objective.combine(local, agg))

            return fed_sim.client_local_steps(loss_fn, p, client_lr,
                                              local_steps, prox_mu=prox_mu,
                                              correction=corr)

        if scaffold_state is None:
            deltas, losses_k = jax.vmap(client_update)(batch_l, masks)
        else:
            deltas, losses_k = jax.vmap(client_update)(
                batch_l, masks, drift_lib.scaffold_corrections(state_l))
        raw_deltas = deltas
        if ctx_l is None:
            avg_delta = jax.tree.map(
                lambda d: jax.lax.psum(jnp.tensordot(w_l, d, axes=1), axis),
                deltas)
        else:
            dec_d = channel.encode_decode(ctx_l, deltas, "update")
            part_d = channel.local_fold(ctx_l, dec_d, "update",
                                        num_shards=nshards)
            avg_delta = jax.tree.map(lambda d: jax.lax.psum(d, axis), part_d)
            avg_delta = channel.post_aggregate(
                ctx_l._replace(key=ckey), avg_delta, "update")
        loss = jax.lax.psum(jnp.sum(w_l * losses_k), axis)
        outs = (avg_delta, loss[None], agg)
        if scaffold_state is not None:
            # option-II refresh on this shard's slots from its raw deltas;
            # the variate-delta average is one more channel-routed psum
            ck_new = drift_lib.scaffold_new_slot_variates(
                state_l, raw_deltas, client_lr, local_steps)
            dc = jax.tree.map(lambda new, old: new - old,
                              ck_new, state_l.c_slots)
            if ctx_l is None:
                agg_dc = jax.tree.map(
                    lambda d: jax.lax.psum(jnp.tensordot(w_l, d, axes=1),
                                           axis), dc)
            else:
                dec_c = channel.encode_decode(ctx_l, dc, "variate")
                part_c = channel.local_fold(ctx_l, dec_c, "variate",
                                            num_shards=nshards)
                agg_dc = jax.tree.map(lambda d: jax.lax.psum(d, axis),
                                      part_c)
                agg_dc = channel.post_aggregate(
                    ctx_l._replace(key=ckey), agg_dc, "variate")
            # ck_new leaves the shard unmasked; the dropped-slot blend and
            # the server-variate fold happen once, outside the shard_map,
            # via drift_lib.scaffold_apply_round on the gathered outputs
            outs = outs + (ck_new, agg_dc)
        return outs

    extra_args, extra_specs = (), ()
    out_specs = (P(), P(), P())
    if channel is not None:
        # weights/mask shard with the client axis; the round key and the
        # participant count are replicated
        extra_args += (ctx.weights, ctx.mask, ctx.key, ctx.num_participants)
        extra_specs += (p_axis, p_axis, P(), P())
    if scaffold_state is not None:
        extra_args += (scaffold_state.c, scaffold_state.c_slots)
        extra_specs += (P(), p_axis)
        out_specs += (p_axis, P())        # slot variates sharded, agg_dc
                                          # replicated like any aggregate
    sharded = shard_map_compat(
        local_body, mesh,
        in_specs=(P(), p_axis, p_axis) + extra_specs,
        out_specs=out_specs)
    outs = sharded(params, client_data, client_sizes, *extra_args)
    avg_delta, loss, agg = outs[:3]

    params, opt_state = server_update.step(params, opt_state, avg_delta)
    enc_std = objective.encoding_std(agg)
    wire = 0.0
    if channel is not None:
        wire = channel.round_bytes(ctx, agg) + \
            channel.round_bytes(ctx, avg_delta)
    if scaffold_state is not None:
        ck_new, agg_dc = outs[3:]
        if channel is not None:
            wire = wire + channel.round_bytes(ctx, agg_dc)
        new_state = drift_lib.scaffold_apply_round(
            scaffold_state, ck_new, agg_dc,
            None if ctx is None else ctx.mask)
        return params, opt_state, new_state, fed_sim.RoundMetrics(
            loss.reshape(()), enc_std, jnp.asarray(wire, F32))
    return params, opt_state, fed_sim.RoundMetrics(loss.reshape(()), enc_std,
                                                   jnp.asarray(wire, F32))


def dcco_round_sharded(encoder_apply: Callable, params, opt_state, server_opt,
                       client_data, client_sizes, mesh, *, lam: float = 20.0,
                       objective=None, **round_kw):
    """Back-compat alias: sharded DCCO == ``stats_round_sharded`` with the
    CCO objective (``lam``); ``objective=`` selects another registered
    stats objective (then ``lam`` is ignored)."""
    return stats_round_sharded(
        encoder_apply, params, opt_state, server_opt, client_data,
        client_sizes, mesh,
        objective=fed_sim.resolve_objective(objective, lam), **round_kw)


# ---------------------------------------------------------------------------
# round bodies
# ---------------------------------------------------------------------------

def make_round_body(encoder_apply: Callable, server_opt, cfg: EngineConfig,
                    mesh=None) -> Callable:
    """Build round_fn(params, opt_state, drift, batch, sizes, key) for
    cfg.algorithm, returning (params, opt_state, drift, metrics). ``key``
    is the per-round channel key (ignored by bodies without a communication
    channel); ``drift`` is the drift-correction carry (a ScaffoldState when
    cfg.scaffold, otherwise passed through untouched)."""
    if cfg.algorithm not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {cfg.algorithm!r}; "
                         f"expected one of {ALGORITHMS}")
    if cfg.cohort_axis is not None and cfg.algorithm != "dcco":
        raise NotImplementedError(
            "sharded cohorts are implemented for the dcco body only")
    # the stats objective driving the dcco / fedavg_cco / centralized
    # bodies; None -> CCO with cfg.lam (bit-identical to the pre-protocol
    # engine). Resolution happens once, at build time — as does the
    # mixed-precision encoder wrap (float32 is the identity).
    encoder_apply = cast_encoder_apply(encoder_apply, cfg.compute_dtype)
    objective = fed_sim.resolve_objective(cfg.objective, cfg.lam)
    if cfg.objective is not None and cfg.algorithm in (
            "fedavg_contrastive", "fedavg_byol"):
        raise ValueError(
            f"algorithm {cfg.algorithm!r} trains a non-stats loss; "
            f"objective={objective!r} would be silently ignored")
    if cfg.algorithm == "centralized" and (cfg.scaffold or cfg.prox_mu):
        raise ValueError(
            "the centralized body has no local client training, so "
            "drift correction (scaffold / prox_mu) does not apply")
    server_update = server_update_lib.as_server_update(
        cfg.server_update if cfg.server_update is not None else server_opt)
    channel = cfg.channel
    if channel is not None:
        if cfg.scaffold:
            # build-time twin of the trace-time check in the round bodies
            fed_sim.check_variate_noise(channel)
        if cfg.algorithm == "centralized":
            raise ValueError(
                "the centralized body has no client->server wire; "
                "channel is not applicable")
        if cfg.stats_kernel != "off" and not channel.supports_flat_stats:
            raise ValueError(
                f"stats_kernel={cfg.stats_kernel!r} aggregates phase-1 "
                f"stats from the flattened cohort, which is incompatible "
                f"with {channel!r} (needs per-client payloads)")
        noise_phases = getattr(channel, "noise_phases", None)
        if (noise_phases is not None
                and cfg.algorithm.startswith("fedavg_")
                and "update" not in noise_phases):
            # fedavg has no stats uplink: a stats-only DP channel would add
            # zero noise while the accountant still reports a finite epsilon
            raise ValueError(
                f"{channel!r} noises only {noise_phases}, but "
                f"{cfg.algorithm!r} ships client updates only — construct "
                f"it with noise_phases=('update',) to noise the aggregate "
                f"it actually releases")

    def _with_drift(inner):
        """Adapt a fed_sim-style round call to the uniform
        (params, opt_state, drift, batch, sizes, key) body signature:
        with cfg.scaffold the inner round already returns the 4-tuple;
        otherwise the drift carry passes through untouched."""
        def round_fn(params, opt_state, drift, batch, sizes, key):
            if cfg.scaffold:
                return inner(params, opt_state, batch, sizes, key,
                             scaffold_state=drift)
            p, o, m = inner(params, opt_state, batch, sizes, key)
            return p, o, drift, m
        return round_fn

    if cfg.algorithm == "dcco":
        if cfg.cohort_axis is not None:
            if mesh is None:
                raise ValueError("cohort_axis requires a mesh")

            def inner(params, opt_state, batch, sizes, key, **drift_kw):
                return stats_round_sharded(
                    encoder_apply, params, opt_state, server_update, batch,
                    sizes, mesh, objective=objective, client_lr=cfg.client_lr,
                    local_steps=cfg.local_steps, axis=cfg.cohort_axis,
                    channel=channel, channel_key=key, prox_mu=cfg.prox_mu,
                    **drift_kw)
        else:
            agg_stats_fn = _resolve_agg_stats_fn(cfg, objective)

            def inner(params, opt_state, batch, sizes, key, **drift_kw):
                return fed_sim.stats_round(
                    encoder_apply, params, opt_state, server_update, batch,
                    sizes, objective=objective, client_lr=cfg.client_lr,
                    local_steps=cfg.local_steps, agg_stats_fn=agg_stats_fn,
                    channel=channel, channel_key=key, prox_mu=cfg.prox_mu,
                    **drift_kw)
        round_fn = _with_drift(inner)
    elif cfg.algorithm.startswith("fedavg_"):
        kind = {"fedavg_cco": "stats", "fedavg_contrastive": "contrastive",
                "fedavg_byol": "byol"}[cfg.algorithm]

        def inner(params, opt_state, batch, sizes, key, **drift_kw):
            return fed_sim.fedavg_round(
                encoder_apply, params, opt_state, server_update, batch, sizes,
                loss_kind=kind, objective=objective,
                temperature=cfg.temperature,
                client_lr=cfg.client_lr, local_steps=cfg.local_steps,
                channel=channel, channel_key=key, prox_mu=cfg.prox_mu,
                **drift_kw)
        round_fn = _with_drift(inner)
    else:  # centralized: union of the cohort, one large-batch stats step
        def round_fn(params, opt_state, drift, batch, sizes, key):
            n_pad = jax.tree.leaves(batch)[0].shape[1]
            union = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), batch)
            mask = fed_sim._client_masks(sizes, n_pad).reshape(-1)
            p, o, m = fed_sim.centralized_step(
                encoder_apply, params, opt_state, server_update, union,
                mask=mask, objective=objective)
            return p, o, drift, m

    return round_fn


# ---------------------------------------------------------------------------
# streaming round body (repro.hierarchy.streaming)
# ---------------------------------------------------------------------------

def make_streaming_round_body(encoder_apply: Callable, server_opt,
                              cfg: EngineConfig, sampler) -> Callable:
    """Build the streaming round body: round_fn(params, opt_state, drift,
    k_sel, k_aug, key) -> (params, opt_state, drift, metrics). Unlike the
    materialized bodies it samples INSIDE the round, one cohort chunk at a
    time, so the engine never holds more than ``cfg.cohort_chunk`` clients
    of batch data — the `sampler` must be chunkable
    (``FederatedDataset.make_streaming_sampler`` /
    ``repro.hierarchy.StreamingSampler``)."""
    from repro.hierarchy import streaming as streaming_lib

    if cfg.algorithm != "dcco":
        raise ValueError(
            f"cohort_chunk streams the two-phase stats round only "
            f"(algorithm 'dcco'), got {cfg.algorithm!r}")
    if cfg.cohort_axis is not None:
        raise ValueError(
            "cohort_chunk and cohort_axis are two layouts for the same "
            "client axis — stream it or shard it, not both")
    if cfg.scaffold:
        raise ValueError(
            "SCAFFOLD keeps per-cohort-slot variates resident, which is "
            "exactly the O(cohort) state cohort_chunk removes — disable "
            "scaffold for streaming rounds")
    if cfg.stats_kernel != "off":
        raise ValueError(
            "stats_kernel aggregates phase-1 stats from the flattened "
            "materialized cohort; with cohort_chunk the cohort never "
            "materializes — use the default per-chunk accumulation")
    if not hasattr(sampler, "sample_chunk"):
        raise ValueError(
            "cohort_chunk needs a chunkable sampler "
            "(FederatedDataset.make_streaming_sampler or a "
            "repro.hierarchy.StreamingSampler), got a plain round sampler")
    if sampler.cohort_chunk != cfg.cohort_chunk:
        raise ValueError(
            f"sampler chunks {sampler.cohort_chunk} clients but "
            f"EngineConfig.cohort_chunk={cfg.cohort_chunk}")
    num_chunks = sampler.clients_per_round // cfg.cohort_chunk
    encoder_apply = cast_encoder_apply(encoder_apply, cfg.compute_dtype)
    objective = fed_sim.resolve_objective(cfg.objective, cfg.lam)
    # resolution to a ServerUpdate happens once, inside the round (the
    # same single coercion point as the materialized bodies)
    server_opt = (cfg.server_update if cfg.server_update is not None
                  else server_opt)
    channel = cfg.channel

    def round_fn(params, opt_state, drift, k_sel, k_aug, key):
        sizes = sampler.cohort_sizes(k_sel)
        # per-round O(K)-scalar sampling state, hoisted out of both
        # phase scans
        state = sampler.prepare(k_sel, k_aug)
        p, o, m = streaming_lib.streaming_stats_round(
            encoder_apply, params, opt_state, server_opt,
            lambda c: sampler.sample_chunk(state, c),
            num_chunks, sizes, objective=objective,
            client_lr=cfg.client_lr, local_steps=cfg.local_steps,
            channel=channel, channel_key=key, prox_mu=cfg.prox_mu)
        return p, o, drift, m

    return round_fn


# ---------------------------------------------------------------------------
# semi-synchronous buffered round body (repro.core.buffer)
# ---------------------------------------------------------------------------

def make_async_round_body(encoder_apply: Callable, server_opt,
                          cfg: EngineConfig, k_cohort: int) -> Callable:
    """Build the buffered round body: round_fn(params, opt_state, drift,
    astate, batch, sizes, delays, key) -> (params, opt_state, drift,
    astate, metrics).

    Each scheduler tick dispatches a full cohort through the ordinary
    two-phase stats round math (phase-1 stats, dispatch-cohort aggregate,
    phase-2 deltas — the same folds as ``fed_sim.stats_round``), but the
    server update is DEFERRED: per-client contributions are scattered into
    the in-flight ring at their arrival delay with a staleness weight
    ``s(delay)`` riding the weighted segment-sum fold, this tick's
    arrivals fold into the server buffer, and the update applies only when
    ``cfg.async_k`` contributions have accumulated (then the buffer
    resets). Exact by Eq.-3 linearity — the buffer merely re-associates
    the weighted sum; see :mod:`repro.core.buffer`.
    """
    from repro.core import buffer as buffer_lib
    from repro.core import cco

    if cfg.algorithm != "dcco":
        raise ValueError(
            f"async_k buffers the two-phase stats round only "
            f"(algorithm 'dcco'), got {cfg.algorithm!r}")
    if cfg.cohort_axis is not None:
        raise ValueError(
            "async_k and cohort_axis are not composed: the buffered "
            "scheduler folds per-client contributions on one host — "
            "shard the cohort or buffer it, not both")
    if cfg.stats_kernel != "off":
        raise ValueError(
            "stats_kernel aggregates phase-1 stats from the flattened "
            "cohort; the async buffer scatters per-client contributions "
            "by arrival delay — needs per-client payloads")
    encoder_apply = cast_encoder_apply(encoder_apply, cfg.compute_dtype)
    objective = fed_sim.resolve_objective(cfg.objective, cfg.lam)
    staleness_fn = buffer_lib.resolve_staleness(cfg.staleness_fn)
    server_update = server_update_lib.as_server_update(
        cfg.server_update if cfg.server_update is not None else server_opt)
    channel = cfg.channel
    if channel is not None:
        if getattr(channel, "noise_phases", None) is not None:
            raise ValueError(
                f"{channel!r} with async_k: DP noise calibration across "
                f"staleness-weighted multi-tick aggregates is undefined "
                f"(the per-contribution weights change the sensitivity) — "
                f"run DP on the synchronous engine")
        if hasattr(channel, "hop_bytes") and not channel.collapses:
            raise ValueError(
                f"{channel!r} with async_k: a lossy edge hop folds "
                f"per-EDGE aggregates, but the buffer scatters per-CLIENT "
                f"contributions — use a collapsing (ideal-hop) tree or a "
                f"flat channel")
    k_trigger = float(cfg.async_k)

    def round_fn(params, opt_state, drift, astate, batch, sizes, delays,
                 key):
        n_pad = jax.tree.leaves(batch)[0].shape[1]
        masks = fed_sim._client_masks(sizes, n_pad)
        if channel is None:
            ctx = None
            w = sizes.astype(F32) / jnp.sum(sizes.astype(F32))
            pmask = jnp.ones((k_cohort,), F32)
        else:
            ctx = channel.begin_round(key, sizes)
            w, pmask = ctx.weights, ctx.mask
        wire = 0.0

        # ---- phase 1 (dispatch-synchronous): cohort stats -> aggregate.
        # The dispatch cohort's OWN aggregate drives phase 2 — the
        # stop-grad combine needs the round's population estimate at
        # dispatch time, before any of these contributions arrive.
        def client_stats(b, m):
            zf, zg = encoder_apply(params, b)
            return objective.stats_masked(zf, zg, m)

        st_k = jax.vmap(client_stats)(batch, masks)
        if ctx is None:
            st_wire = st_k
            agg = cco.weighted_average_stats(st_k, sizes.astype(F32))
        else:
            # same math as channel.aggregate, with the decoded per-client
            # payloads kept — they are what the ring scatters
            st_wire = channel.encode_decode(ctx, st_k, "stats")
            agg = jax.tree.map(lambda v: jnp.tensordot(w, v, axes=1),
                               st_wire)
            agg = channel.post_aggregate(ctx, agg, "stats")
            wire = wire + channel.round_bytes(ctx, agg)

        # ---- phase 2: local steps against the dispatch aggregate
        def client_update(b, m, corr=None):
            def loss_fn(p):
                zf, zg = encoder_apply(p, b)
                local = objective.stats_masked(zf, zg, m)
                return objective.loss_from_stats(
                    objective.combine(local, agg))

            return fed_sim.client_local_steps(
                loss_fn, params, cfg.client_lr, cfg.local_steps,
                prox_mu=cfg.prox_mu, correction=corr)

        if cfg.scaffold:
            deltas, losses_k = jax.vmap(client_update)(
                batch, masks, drift_lib.scaffold_corrections(drift))
        else:
            deltas, losses_k = jax.vmap(client_update)(batch, masks)
        if ctx is None:
            d_wire = deltas
        else:
            d_wire = channel.encode_decode(ctx, deltas, "update")
            wire = wire + channel.round_bytes(
                ctx, jax.tree.map(lambda x: x[0], deltas))
        if cfg.scaffold:
            # variate refresh stays dispatch-synchronous (client-side
            # state, never buffered); its uplink rides this tick's wire
            drift, extra = fed_sim._scaffold_round_tail(
                drift, deltas, cfg.client_lr, cfg.local_steps, w, ctx,
                channel)
            wire = wire + extra

        # ---- staleness-weighted scatter into the in-flight ring
        s_w = staleness_fn(delays.astype(F32))
        w_eff = w * s_w * pmask
        pending = buffer_lib.dispatch_fold(
            astate.pending, st_wire, d_wire, losses_k, w_eff, pmask,
            delays)
        arrived, pending = buffer_lib.ring_pop(pending)
        buf = buffer_lib.buffer_add(astate.buffer, arrived)

        # ---- apply the server update once K contributions accumulated
        do_apply = buf.count >= k_trigger
        _, avg_delta, mean_tau = buffer_lib.buffer_aggregate(buf)
        p_new, o_new = server_update.step(params, opt_state, avg_delta)
        sel = lambda new, old: jax.tree.map(            # noqa: E731
            lambda a, b: jnp.where(do_apply, a, b), new, old)
        params2, opt2 = sel(p_new, params), sel(o_new, opt_state)
        buf = buffer_lib.buffer_reset_where(buf, do_apply)
        astate2 = buffer_lib.AsyncState(
            buf, pending,
            astate.applied_total + do_apply.astype(jnp.int32))

        metrics = EngineMetrics(
            loss=jnp.sum(w * losses_k),
            encoding_std=objective.encoding_std(agg),
            wire_bytes=jnp.asarray(wire, F32),
            applied=do_apply.astype(F32),
            staleness=jnp.where(do_apply, mean_tau, 0.0))
        return params2, opt2, drift, astate2, metrics

    return round_fn


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class RoundEngine:
    """jit + lax.scan federated training driver.

    ``sampler(k_sel, k_aug) -> (batch, sizes)`` must be jax-traceable: it
    runs INSIDE the scan body (see FederatedDataset.make_round_sampler).
    A full experiment is ceil(R / chunk_rounds) XLA program invocations
    instead of R Python dispatches.
    """

    def __init__(self, encoder_apply: Callable, server_opt,
                 sampler: Callable, config: EngineConfig = EngineConfig(),
                 mesh=None):
        if config.chunk_rounds < 1:
            raise ValueError(
                f"chunk_rounds must be >= 1, got {config.chunk_rounds}")
        if config.retrieval_every < 1:
            raise ValueError(
                f"retrieval_every must be >= 1, got {config.retrieval_every}")
        if config.retrieval_eval is not None and \
                not callable(config.retrieval_eval):
            raise ValueError(
                "retrieval_eval must be a traceable params -> {metric: "
                "scalar} callable (repro.retrieval.make_retrieval_eval) or "
                "a stateful (params, state) -> (metrics, state) eval "
                "(repro.retrieval.make_refreshing_retrieval_eval)")
        self._retrieval_stateful = bool(
            getattr(config.retrieval_eval, "stateful", False))
        if self._retrieval_stateful and \
                not callable(getattr(config.retrieval_eval, "init_state",
                                     None)):
            raise ValueError(
                "a stateful retrieval_eval must expose init_state(params) "
                "seeding its index state "
                "(repro.retrieval.make_refreshing_retrieval_eval does)")
        self._retrieval_template = None  # eval_shape of retrieval_eval,
                                         # resolved lazily on first run()
        self.config = config
        self.sampler = sampler
        self.drift_state = None      # final drift carry of the last run()
        self.buffer_state = None     # final AsyncState of the last run()
        self.cluster_state = None    # final ClusterState of the last run()
        self._streaming = config.cohort_chunk > 0
        self._async = config.async_k > 0
        self._async_real = False     # True when the buffered path runs
        if config.num_clusters < 0:
            raise ValueError(
                f"num_clusters must be >= 0, got {config.num_clusters}")
        # num_clusters <= 1: ONE cluster is the global aggregate by
        # definition, so route to the global path — structurally
        # bit-identical (the async_collapse / collapse_ideal idiom)
        self._clustered = config.num_clusters > 1
        if self._clustered:
            if self._async:
                raise ValueError(
                    "num_clusters and async_k are not composed: the "
                    "buffered scheduler re-associates contributions "
                    "across ticks, but cluster targets and slots are "
                    "per-dispatch — cluster the synchronous engine")
            if self._streaming:
                raise ValueError(
                    "num_clusters assigns clusters from the materialized "
                    "cohort's per-client stats; cohort_chunk never "
                    "materializes the cohort — drop one")
            if config.cohort_axis is not None:
                raise ValueError(
                    "num_clusters and cohort_axis are not composed: the "
                    "k-means assignment and per-cluster slots fold on one "
                    "host — shard the cohort or cluster it, not both")
        if self._async:
            from repro.core import buffer as buffer_lib
            from repro.data import latency as latency_lib
            if self._streaming:
                raise ValueError(
                    "async_k and cohort_chunk are two schedulers for the "
                    "same round (buffered arrivals vs streamed chunks) "
                    "and are not composed — drop one")
            if not hasattr(sampler, "latency"):
                raise ValueError(
                    "async_k needs a latency-aware sampler emitting "
                    "(batch, sizes, delays) — use "
                    "FederatedDataset.make_async_round_sampler or "
                    "repro.data.latency.make_async_sampler, got a plain "
                    "round sampler")
            lat = latency_lib.resolve_latency(config.latency)
            if sampler.latency != lat:
                raise ValueError(
                    f"sampler draws delays from {sampler.latency} but "
                    f"EngineConfig.latency resolves to {lat} — the ring "
                    f"horizon and the delay stream must agree")
            k_cohort = sampler.clients_per_round
            if not 1 <= config.async_k <= k_cohort:
                raise ValueError(
                    f"async_k={config.async_k} must be in [1, "
                    f"clients_per_round={k_cohort}]: fewer than one "
                    f"contribution never triggers, more than one cohort "
                    f"can never accumulate before the first apply")
            # resolve once so an unknown name fails at build, not in trace
            buffer_lib.resolve_staleness(config.staleness_fn)
            self._async_collapsed = (
                config.async_collapse and lat.kind == "zero"
                and config.staleness_fn in (None, "unit")
                and config.async_k == k_cohort)
            if self._async_collapsed:
                # K = cohort, zero latency, unit staleness: every dispatch
                # arrives immediately and triggers exactly one apply — the
                # buffered round IS the synchronous round, so compute it
                # as one (bit-identical, the collapse_ideal idiom)
                self.round_fn = make_round_body(encoder_apply, server_opt,
                                                config, mesh)
            else:
                self.round_fn = make_async_round_body(
                    encoder_apply, server_opt, config, k_cohort)
                self._async_real = True
                self._async_horizon = lat.horizon
                self._objective = fed_sim.resolve_objective(
                    config.objective, config.lam)
                self._encoder_apply = encoder_apply
        elif self._streaming:
            self.round_fn = make_streaming_round_body(
                encoder_apply, server_opt, config, sampler)
        elif self._clustered:
            from repro.cluster import make_cluster_round_body
            self.round_fn = make_cluster_round_body(encoder_apply,
                                                    server_opt, config)
            # kept for sizing the fresh ClusterState (stats row width via
            # jax.eval_shape — no FLOPs), same idiom as the async buffer
            self._objective = fed_sim.resolve_objective(
                config.objective, config.lam)
            self._encoder_apply = encoder_apply
        else:
            self.round_fn = make_round_body(encoder_apply, server_opt,
                                            config, mesh)
        donate = (0,) if config.donate else ()
        self._segment = jax.jit(
            functools.partial(self._run_segment, config.chunk_rounds),
            donate_argnums=donate)
        self._tail_segments = {}   # tail length -> jitted segment
        self._donate = donate

    # -- one scan segment ---------------------------------------------------
    def _run_segment(self, num_rounds: int, carry: EngineCarry, start):
        def body(c, r):
            rkey = jax.random.fold_in(c.rng, r)
            k_sel, k_aug = jax.random.split(rkey)
            # channel randomness comes from a fold_in (not a 3-way split)
            # so the selection/augmentation streams are unchanged vs the
            # channel-less engine — resume and regression baselines hold
            k_ch = jax.random.fold_in(rkey, _CHANNEL_SALT)
            buffer, cluster = c.buffer, c.cluster
            if self._async_real:
                batch, sizes, delays = self.sampler(k_sel, k_aug)
                params, opt_state, drift, buffer, m = self.round_fn(
                    c.params, c.opt_state, c.drift, c.buffer, batch, sizes,
                    delays, k_ch)
                applied, stale = m.applied, m.staleness
            elif self._streaming:
                # the streaming body samples inside the round, one cohort
                # chunk at a time — the full batch never materializes here
                params, opt_state, drift, m = self.round_fn(
                    c.params, c.opt_state, c.drift, k_sel, k_aug, k_ch)
                applied, stale = jnp.ones((), F32), jnp.zeros((), F32)
            elif self._clustered:
                batch, sizes = self.sampler(k_sel, k_aug)
                params, opt_state, cluster, m = self.round_fn(
                    c.params, c.opt_state, c.cluster, batch, sizes, k_ch)
                drift = c.drift
                applied, stale = jnp.ones((), F32), jnp.zeros((), F32)
            else:
                if self._async:
                    # collapsed async config: same cohorts (delays are a
                    # fold_in side stream off k_sel), sync round body
                    batch, sizes, _delays = self.sampler(k_sel, k_aug)
                else:
                    batch, sizes = self.sampler(k_sel, k_aug)
                params, opt_state, drift, m = self.round_fn(
                    c.params, c.opt_state, c.drift, batch, sizes, k_ch)
                applied, stale = jnp.ones((), F32), jnp.zeros((), F32)
            rmet, reval = self._retrieval_metrics(params, r, c.reval)
            return (EngineCarry(params, opt_state, c.rng, drift, buffer,
                                reval, cluster),
                    EngineMetrics(m.loss, m.encoding_std,
                                  jnp.asarray(m.wire_bytes, F32),
                                  applied, stale, rmet))

        unroll = self.config.scan_unroll or (
            8 if jax.default_backend() == "cpu" else 1)
        xs = start + jnp.arange(num_rounds)
        return jax.lax.scan(body, carry, xs,
                            unroll=min(unroll, num_rounds))

    def _retrieval_metrics(self, params, r, state):
        """The periodic in-scan retrieval eval on round ``r``'s params:
        (metrics, state) — the configured eval on rounds hitting the
        cadence, a NaN-filled template otherwise (lax.cond — the skipped
        branch costs nothing at runtime). A stateful eval's index state
        threads through (unchanged on skipped rounds); ((), state) when no
        retrieval eval is configured."""
        eval_fn = self.config.retrieval_eval
        if eval_fn is None:
            return (), state
        on_cadence = (r % self.config.retrieval_every) == 0

        def nan_template():
            return jax.tree.map(lambda s: jnp.full(s.shape, jnp.nan, F32),
                                self._retrieval_template)

        if self._retrieval_stateful:
            def run_eval(p, s):
                m, s2 = eval_fn(p, s)
                return jax.tree.map(lambda x: jnp.asarray(x, F32), m), s2

            def skip_eval(_p, s):
                return nan_template(), s

            return jax.lax.cond(on_cadence, run_eval, skip_eval,
                                params, state)

        def run_eval(p):
            return jax.tree.map(lambda x: jnp.asarray(x, F32), eval_fn(p))

        def skip_eval(_p):
            return nan_template()

        return jax.lax.cond(on_cadence, run_eval, skip_eval, params), state

    def _segment_fn(self, num_rounds: int):
        if num_rounds == self.config.chunk_rounds:
            return self._segment
        if num_rounds not in self._tail_segments:
            self._tail_segments[num_rounds] = jax.jit(
                functools.partial(self._run_segment, num_rounds),
                donate_argnums=self._donate)
        return self._tail_segments[num_rounds]

    def _init_async_state(self, params):
        """Zero AsyncState sized from the sampler/encoder shapes (no FLOPs:
        the encoding dim comes from ``jax.eval_shape``)."""
        from repro.core import buffer as buffer_lib
        k0 = jax.random.PRNGKey(0)
        batch_s, _, _ = jax.eval_shape(self.sampler, k0, k0)
        client0 = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), batch_s)
        zf_s, _ = jax.eval_shape(self._encoder_apply, params, client0)
        return buffer_lib.init_state(
            self._objective.stat_spec(zf_s.shape[-1]), params,
            self._async_horizon)

    def _init_cluster_state(self, params, opt_state):
        """Fresh per-cluster slots; the centroid row width comes from the
        objective's stat_spec via ``jax.eval_shape`` (no FLOPs)."""
        from repro import cluster as cluster_lib
        k0 = jax.random.PRNGKey(0)
        batch_s, _ = jax.eval_shape(self.sampler, k0, k0)
        client0 = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype), batch_s)
        zf_s, _ = jax.eval_shape(self._encoder_apply, params, client0)
        dim = cluster_lib.stats_dim(
            self._objective.stat_spec(zf_s.shape[-1]))
        return cluster_lib.init_cluster_state(
            params, opt_state, self.config.num_clusters, dim)

    # -- full run -----------------------------------------------------------
    def run(self, params, opt_state, rng, rounds: int, *, start_round: int = 0,
            on_segment: Optional[Callable] = None, ckpt_dir: Optional[str] = None,
            ckpt_every: int = 0, ckpt_name: str = "engine",
            drift_state=None, buffer_state=None, cluster_state=None):
        """Run ``rounds`` rounds; returns (params, opt_state, EngineMetrics).

        Metrics stream back per segment; ``on_segment(round_end, carry,
        seg_metrics)`` fires after each segment; checkpoints are written at
        the first segment boundary at or past each ``ckpt_every`` multiple.

        With ``EngineConfig.retrieval_eval`` the returned (and per-segment)
        ``EngineMetrics.retrieval`` dict carries per-round recall@k / MRR
        (NaN on rounds the ``retrieval_every`` cadence skipped) — computed
        in-scan on the post-update params, alongside whatever probe the
        ``on_segment`` callback runs.

        With ``EngineConfig.scaffold``, the control variates ride the scan
        carry: pass ``drift_state=`` to resume from saved variates (zeros
        otherwise — the cohort size is inferred from the sampler via
        ``jax.eval_shape``), and read the final state from
        ``self.drift_state`` after the run (it is part of the returned
        carry, so it is safe to keep).

        On the real buffered path (``async_k`` without collapse) the
        staleness buffer and in-flight ring ride the carry the same way:
        pass ``buffer_state=`` to resume mid-flight contributions (zeros
        otherwise), read the final :class:`repro.core.buffer.AsyncState`
        from ``self.buffer_state``, and checkpoints gain a ``"buffer"``
        entry so save -> resume preserves in-flight work.

        With ``donate=True`` (default) the ``carry`` seen by ``on_segment``
        is donated to the NEXT segment: read it synchronously inside the
        callback (evaluate, log, ...) and ``jnp.copy`` anything you keep —
        retained references raise "Array has been deleted" later. The
        segment metrics are not donated and are safe to keep.
        """
        reval = ()
        if self.config.retrieval_eval is not None:
            if self._retrieval_stateful:
                # seed the refreshing eval's index state (the one full
                # chunked encode) from the run's initial params
                reval = self.config.retrieval_eval.init_state(params)
            if self._retrieval_template is None:
                # metric names/shapes of the periodic eval (no FLOPs) — the
                # NaN template the scan emits on skipped rounds
                if self._retrieval_stateful:
                    self._retrieval_template = jax.eval_shape(
                        lambda p, s: jax.tree.map(
                            lambda x: jnp.asarray(x, F32),
                            self.config.retrieval_eval(p, s)[0]),
                        params, reval)
                else:
                    self._retrieval_template = jax.eval_shape(
                        lambda p: jax.tree.map(
                            lambda x: jnp.asarray(x, F32),
                            self.config.retrieval_eval(p)),
                        params)
        drift = () if drift_state is None else drift_state
        if self.config.scaffold and drift_state is None:
            shapes = jax.eval_shape(
                self.sampler, jax.random.PRNGKey(0), jax.random.PRNGKey(0))
            drift = drift_lib.scaffold_init(params, shapes[1].shape[0])
        buffer = () if buffer_state is None else buffer_state
        if self._async_real and buffer_state is None:
            buffer = self._init_async_state(params)
        cluster = () if cluster_state is None else cluster_state
        if self._clustered and cluster_state is None:
            cluster = self._init_cluster_state(params, opt_state)
        carry = EngineCarry(params, opt_state, rng, drift, buffer, reval,
                            cluster)
        if self._donate:
            # segments donate their carry; copy once so the CALLER's buffers
            # survive the run (donation then recycles only engine-internal
            # buffers from segment to segment).
            carry = jax.tree.map(jnp.copy, carry)
        chunk = self.config.chunk_rounds
        cols = [[] for _ in EngineMetrics._fields]
        done, last_ckpt = 0, 0
        while done < rounds:
            seg = min(chunk, rounds - done)
            carry, m = self._segment_fn(seg)(
                carry, jnp.asarray(start_round + done, jnp.int32))
            done += seg
            for col, v in zip(cols, m):
                # the retrieval field is a dict of per-round arrays (or ()
                # when unused); everything else is a plain (seg,) array
                col.append(v if isinstance(v, (dict, tuple))
                           else jnp.asarray(v, F32))
            round_end = start_round + done
            if on_segment is not None:
                on_segment(round_end, carry, m)
            if ckpt_dir and ckpt_every and (done - last_ckpt) >= ckpt_every:
                from repro.checkpoint import save_checkpoint
                path = os.path.join(ckpt_dir, f"{ckpt_name}.msgpack")
                blob = {"params": carry.params, "opt": carry.opt_state}
                if self.config.scaffold:
                    blob["drift"] = carry.drift
                if self._async_real:
                    blob["buffer"] = carry.buffer
                if self._clustered:
                    blob["cluster"] = carry.cluster
                save_checkpoint(path, blob, round_end)
                last_ckpt = done
        self.drift_state = carry.drift if self.config.scaffold else None
        self.buffer_state = carry.buffer if self._async_real else None
        self.cluster_state = carry.cluster if self._clustered else None
        if self.config.channel is not None:
            # host-side bookkeeping (e.g. the DP epsilon accountant)
            self.config.channel.finalize_rounds(done)
        fields = []
        for name, col in zip(EngineMetrics._fields, cols):
            if name == "retrieval":
                if col and isinstance(col[0], dict):
                    fields.append({k: jnp.concatenate([c[k] for c in col])
                                   for k in col[0]})
                else:
                    fields.append({})
            else:
                fields.append(jnp.concatenate(col) if col
                              else jnp.zeros((0,)))
        metrics = EngineMetrics(*fields)
        return carry.params, carry.opt_state, metrics
