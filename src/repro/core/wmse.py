"""D-WMSE: whitening-style decorrelation through the aggregated-statistics
strategy — the third registered objective, proving the StatsObjective
protocol is not a CCO/VICReg two-case special.

W-MSE (Ermolov et al. 2021) aligns the two views with an MSE term and
prevents collapse by whitening the encodings — pushing the within-view
covariance toward (a scaled) identity. The exact whitening transform is
not linear in samples, but its penalty form is: align the views and
penalize the Frobenius distance of the within-view covariance from the
identity. That form needs the same seven linear-in-samples statistics as
VICReg (DCCO's five + the within-view second moments), so paper Eq. 3
aggregation, the flattened-cohort kernel path, the shard_map psum path,
and the Appendix-A stop-grad equivalence all apply verbatim:

  invariance:  <|F - G|^2>            from <F^2>, <G^2>, diag<F G^T>
  whitening:   |Cov(F) - I|_F^2 / d   from <F F^T>, <F>   (and G likewise)
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import cco

F32 = jnp.float32

WMSE_STAT_KEYS = cco.STAT_KEYS + cco.SECOND_MOMENT_KEYS


def wmse_stats(zf, zg):
    """Same seven statistics as VICReg (the within-view moment set)."""
    return cco.moment_stats(zf, zg, second_moments=True)


def wmse_stats_masked(zf, zg, mask):
    return cco.moment_stats(zf, zg, mask, second_moments=True)


def wmse_loss_from_stats(st, *, inv_weight: float = 1.0,
                         whiten_weight: float = 1.0):
    """Whitening-penalty W-MSE computed purely from statistics."""
    d = st["mean_f"].shape[0]
    # invariance: E|F-G|^2 = E F^2 + E G^2 - 2 diag(E F G^T)
    inv = jnp.sum(st["sq_f"] + st["sq_g"] - 2.0 * jnp.diagonal(st["cross"])) / d

    def whiten_term(cov2, mean):
        cov = cov2 - jnp.outer(mean, mean)
        return jnp.sum((cov - jnp.eye(d, dtype=F32)) ** 2) / d

    whiten = whiten_term(st["cov_f"], st["mean_f"]) + \
        whiten_term(st["cov_g"], st["mean_g"])
    return inv_weight * inv + whiten_weight * whiten


def wmse_loss(zf, zg, **kw):
    """Centralized large-batch W-MSE (the upper-bound baseline)."""
    return wmse_loss_from_stats(wmse_stats(zf, zg), **kw)
