"""Comparative losses (paper Sec. 4): NT-Xent contrastive (SimCLR, tau=0.1),
supervised cross-entropy, and the predictive-loss collapse probe (App. C)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def ntxent_loss(zf, zg, temperature: float = 0.1) -> jnp.ndarray:
    """SimCLR NT-Xent over a batch of paired encodings zf, zg: (N, d).

    Each zf[i] is contrasted against zg[i] (positive) and all other
    encodings in the union of {zf, zg} minus itself (negatives); symmetrized.
    """
    n = zf.shape[0]
    zf = zf.astype(F32)
    zg = zg.astype(F32)
    za = jnp.concatenate([zf, zg], axis=0)                  # (2N, d)
    za = za / jnp.maximum(jnp.linalg.norm(za, axis=-1, keepdims=True), 1e-8)
    sim = za @ za.T / temperature                           # (2N, 2N)
    sim = jnp.where(jnp.eye(2 * n, dtype=bool), -1e9, sim)
    # positives: i <-> i+N
    pos_idx = jnp.concatenate([jnp.arange(n) + n, jnp.arange(n)])
    logprob = jax.nn.log_softmax(sim, axis=-1)
    loss = -logprob[jnp.arange(2 * n), pos_idx]
    return loss.mean()


def softmax_cross_entropy(logits, labels, num_classes: int | None = None) -> jnp.ndarray:
    """logits: (..., C); labels int (...)."""
    logp = jax.nn.log_softmax(logits.astype(F32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean()


def byol_predictive_loss(z_online, z_target) -> jnp.ndarray:
    """Normalized MSE predictive loss (BYOL/SimSiam family) — used by the
    App.-C collapse probe: without batch statistics this loss can be driven
    to ~0 by a constant encoder."""
    zo = z_online.astype(F32)
    zt = jax.lax.stop_gradient(z_target.astype(F32))
    zo = zo / jnp.maximum(jnp.linalg.norm(zo, axis=-1, keepdims=True), 1e-8)
    zt = zt / jnp.maximum(jnp.linalg.norm(zt, axis=-1, keepdims=True), 1e-8)
    return (2.0 - 2.0 * (zo * zt).sum(-1)).mean()


def encoding_variance(z) -> jnp.ndarray:
    """Mean per-dimension std of encodings — collapse indicator (VICReg-style)."""
    return jnp.sqrt(jnp.var(z.astype(F32), axis=0) + 1e-8).mean()
