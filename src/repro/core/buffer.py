"""Staleness-weighted server-side stats buffer (FedBuff-style).

The semi-synchronous engine (``EngineConfig.async_k``) decouples client
dispatch from the server update: every scheduler tick dispatches a cohort,
each client's contribution (phase-1 stats + phase-2 delta) "arrives"
``delay`` ticks later (:mod:`repro.data.latency`), and the server applies
its update as soon as ``K`` contributions have accumulated — throughput is
bounded by the server fold rate, not the slowest client.

This module owns the two pieces of state that ride the scan carry
(``EngineCarry.buffer``) and the pure folds over them:

  * an in-flight ring (:class:`StalenessBuffer` with a leading
    ``(horizon,)`` axis): slot ``j`` holds the staleness-weighted partial
    sums of contributions arriving ``j`` ticks from now, plus per-slot
    counters (mass / count / staleness mass). Dispatch scatters a cohort
    into its delay buckets with ONE weighted segment-sum fold
    (:func:`repro.hierarchy.aggregation.fold_to_edges`, the same
    ``kernels/segment_sum.py`` weighted fold the hierarchy uses) — the
    per-contribution staleness weight simply rides the fold's weight
    vector. Memory is O(horizon * (stats + params)), independent of how
    many contributions are in flight;
  * the arrived buffer (:class:`StalenessBuffer`, scalar counters): each
    tick pops ring slot 0 into it; when ``count >= K`` the engine applies
    ``server_update.step`` on the mass-normalized delta and resets it.

Exactness (paper Eq. 3): encoding statistics are linear in samples, so the
buffer is nothing but a re-association of the flat weighted sum
``sum_i w_i s(tau_i) x_i`` — any arrival order, any ring partition, and
any staleness weighting is an exact weighted aggregate (property-tested in
``tests/test_async_engine.py``). With unit staleness weights, zero
latency, and ``K = cohort`` the fold IS the synchronous round's fold, which
is why that configuration collapses to the sync body bit-identically.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32

# staleness-weight registry: tick delay tau -> down-weight s(tau).
# "poly" is the FedBuff choice (Nguyen et al., 2022): s = (1 + tau)^-1/2.
STALENESS_FNS = {
    "unit": lambda tau: jnp.ones_like(tau),
    "poly": lambda tau: (1.0 + tau) ** -0.5,
    "inv": lambda tau: 1.0 / (1.0 + tau),
}


def resolve_staleness(spec):
    """Coerce None / registry name / callable into a staleness weight fn."""
    if spec is None:
        spec = "unit"
    if callable(spec):
        return spec
    if spec not in STALENESS_FNS:
        raise ValueError(f"unknown staleness fn {spec!r}; expected one of "
                         f"{tuple(STALENESS_FNS)} or a callable")
    return STALENESS_FNS[spec]


class StalenessBuffer(NamedTuple):
    """Weighted partial sums of client contributions + counters.

    As the arrived buffer every field is a scalar-counter / unweighted-sum
    pytree; as the in-flight ring every field carries a leading
    ``(horizon,)`` slot axis. ``mass`` is ``sum_i w_i * s(tau_i)`` (the
    normalizer), ``count`` the participating-contribution count (what the
    K-trigger compares), ``tau`` the staleness mass ``sum_i w_i s_i tau_i``
    (mean staleness = tau / mass).
    """
    stats: Any
    delta: Any
    loss: jnp.ndarray
    mass: jnp.ndarray
    count: jnp.ndarray
    tau: jnp.ndarray


class AsyncState(NamedTuple):
    """The ``EngineCarry.buffer`` extension of the buffered engine."""
    buffer: StalenessBuffer      # arrived, awaiting the K-trigger
    pending: StalenessBuffer     # in-flight ring, leading (horizon,) axis
    applied_total: jnp.ndarray   # int32: server updates applied so far


def init_state(stat_spec, params, horizon: int) -> AsyncState:
    """Zero AsyncState for ``stat_spec`` (stat key -> shape, from
    ``StatsObjective.stat_spec``), a params pytree, and ring depth
    ``horizon``."""
    def zeros(lead=()):
        return StalenessBuffer(
            stats={k: jnp.zeros(lead + tuple(s), F32)
                   for k, s in stat_spec.items()},
            delta=jax.tree.map(
                lambda p: jnp.zeros(lead + tuple(p.shape), F32), params),
            loss=jnp.zeros(lead, F32), mass=jnp.zeros(lead, F32),
            count=jnp.zeros(lead, F32), tau=jnp.zeros(lead, F32))

    return AsyncState(zeros(), zeros((horizon,)),
                      jnp.zeros((), jnp.int32))


def dispatch_fold(pending: StalenessBuffer, st_k, deltas, losses_k,
                  w_eff, mask, delays, impl: str = "jnp") -> StalenessBuffer:
    """Scatter one dispatched cohort into its delay buckets.

    ``w_eff`` (K,) is the full per-contribution weight — participation
    weight times staleness weight — riding the segment-sum fold;
    ``mask`` (K,) in {0,1} feeds the K-trigger count (a dropped client
    contributes neither mass nor count); ``delays`` (K,) int32 in
    [0, horizon) are the bucket ids.
    """
    from repro.hierarchy.aggregation import fold_to_edges

    horizon = pending.mass.shape[0]
    ones = jnp.ones_like(w_eff)
    f = fold_to_edges(
        {"stats": st_k, "delta": deltas, "loss": losses_k,
         "mass": ones, "tau": delays.astype(F32)},
        w_eff, delays, horizon, impl=impl)
    cnt = fold_to_edges({"c": ones}, mask, delays, horizon, impl=impl)["c"]
    folded = StalenessBuffer(f["stats"], f["delta"], f["loss"],
                             f["mass"], cnt, f["tau"])
    return jax.tree.map(jnp.add, pending, folded)


def ring_pop(pending: StalenessBuffer):
    """Pop slot 0 (this tick's arrivals) and advance the ring.

    Returns ``(arrived, pending')`` where ``arrived`` is a scalar-counter
    StalenessBuffer and ``pending'`` has every slot shifted one tick
    closer with a zeroed tail slot.
    """
    arrived = jax.tree.map(lambda x: x[0], pending)
    shifted = jax.tree.map(
        lambda x: jnp.roll(x, -1, axis=0).at[-1].set(0.0), pending)
    return arrived, shifted


def buffer_add(buf: StalenessBuffer, arrived: StalenessBuffer):
    """Fold arrived contributions into the server buffer (exact by Eq.-3
    linearity: addition of weighted partial sums)."""
    return jax.tree.map(jnp.add, buf, arrived)


def buffer_aggregate(buf: StalenessBuffer, floor: float = 1e-12):
    """Mass-normalized aggregate (avg_stats, avg_delta, mean_staleness).

    The normalizer is floored so an empty or outage-starved buffer (all
    contributions dropped by a lossy channel) yields zeros, never NaN —
    the same guard discipline as the objective var-floor.
    """
    denom = jnp.maximum(buf.mass, floor)
    avg_stats = jax.tree.map(lambda v: v / denom, buf.stats)
    avg_delta = jax.tree.map(lambda v: v / denom, buf.delta)
    return avg_stats, avg_delta, buf.tau / denom


def buffer_reset_where(buf: StalenessBuffer, cond):
    """Zero the buffer where scalar ``cond`` holds (post-apply reset)."""
    return jax.tree.map(lambda x: jnp.where(cond, jnp.zeros_like(x), x), buf)
