"""D-VICReg: the paper's distributed-statistics strategy applied to VICReg
(Bardes et al. 2022) — the extension the paper names as future work (Sec. 6:
"evaluate the proposed aggregated statistics-based distributed learning
strategy with other statistics-based loss functions such as Bardes et al.").

VICReg needs seven linear-in-samples statistics (DCCO's five plus the two
within-view second-moment matrices), so the same aggregate/redistribute/
stop-grad-combine machinery — and the Appendix-A equivalence — applies
verbatim:

  invariance:  ⟨|F − G|²⟩         from ⟨F²⟩, ⟨G²⟩, diag⟨FG^T⟩
  variance:    hinge(γ − std(F)) from ⟨F⟩, ⟨F²⟩ (and G likewise)
  covariance:  off-diag Cov(F)²  from ⟨FF^T⟩, ⟨F⟩ (and G likewise)
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import cco

F32 = jnp.float32

VICREG_STAT_KEYS = cco.STAT_KEYS + cco.SECOND_MOMENT_KEYS


def vicreg_stats(zf, zg) -> Dict[str, jnp.ndarray]:
    """Seven statistics: DCCO's five + within-view second moments."""
    return cco.moment_stats(zf, zg, second_moments=True)


def vicreg_stats_masked(zf, zg, mask) -> Dict[str, jnp.ndarray]:
    """Masked variant, through the same shared accumulator as CCO's —
    one implementation, zero copy-paste drift (bit-identity with the
    historical per-loss formulas is asserted in tests/test_objectives.py)."""
    return cco.moment_stats(zf, zg, mask, second_moments=True)


def vicreg_loss_from_stats(st, *, inv_weight: float = 25.0,
                           var_weight: float = 25.0, cov_weight: float = 1.0,
                           gamma: float = 1.0, eps: float = 1e-4):
    """VICReg (Bardes et al. 2022 Eq. 6) computed purely from statistics."""
    d = st["mean_f"].shape[0]
    # invariance: E|F-G|^2 = E F^2 + E G^2 - 2 diag(E F G^T)
    inv = jnp.sum(st["sq_f"] + st["sq_g"] - 2.0 * jnp.diagonal(st["cross"])) / d

    def var_term(sq, mean):
        var = jnp.maximum(sq - mean ** 2, 0.0)
        return jnp.mean(jax.nn.relu(gamma - jnp.sqrt(var + eps)))

    var = var_term(st["sq_f"], st["mean_f"]) + var_term(st["sq_g"], st["mean_g"])

    def cov_term(cov2, mean):
        cov = cov2 - jnp.outer(mean, mean)
        off = jnp.sum(cov * cov) - jnp.sum(jnp.diagonal(cov) ** 2)
        return off / d

    covp = cov_term(st["cov_f"], st["mean_f"]) + cov_term(st["cov_g"], st["mean_g"])
    return inv_weight * inv + var_weight * var + cov_weight * covp


def vicreg_loss(zf, zg, **kw):
    """Centralized large-batch VICReg."""
    return vicreg_loss_from_stats(vicreg_stats(zf, zg), **kw)


def dvicreg_loss_per_client(zf, zg, clients: int, **kw):
    """Faithful D-VICReg objective: per-client stats, weighted aggregate,
    stop-grad combine (paper Fig. 2 with VICReg's seven statistics)."""
    n, d = zf.shape
    assert n % clients == 0
    zf_c = zf.reshape(clients, n // clients, d)
    zg_c = zg.reshape(clients, n // clients, d)
    st_k = jax.vmap(vicreg_stats)(zf_c, zg_c)
    w = jnp.full((clients,), 1.0 / clients, F32)
    agg = cco.weighted_average_stats(st_k, w)

    def client_loss(stats_k):
        return vicreg_loss_from_stats(cco.dcco_combine(stats_k, agg), **kw)

    return jnp.sum(w * jax.vmap(client_loss)(st_k))
