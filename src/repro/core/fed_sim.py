"""Client-level federated simulator — the paper's training runtime.

This is the *protocol-faithful* implementation: explicit client sampling,
model broadcast, the two server<->client communication phases of DCCO
(Fig. 2), local training, and the FedOpt-style server update. The pod-scale
fused path (launch/steps.py) is the performance implementation; this module
is the reference semantics, and tests assert they agree (Appendix A).

Client data layout: a pytree whose leaves have leading dims (K, n, ...) —
K clients, n samples each (padded; ``mask`` (K, n) marks real samples, so
variable-size clients like DERM's 1-6 images/case are supported).

``encoder_apply(params, batch) -> (zf, zg)`` abstracts the dual encoding
model: batch is one client's (n, ...) slice holding both views.

The rounds here materialize the whole cohort on the leading K axis. Two
scale-out companions share their exact semantics: the sharded-cohort path
(:func:`repro.core.round_engine.stats_round_sharded`, K laid across the
device mesh) and the streaming path
(:func:`repro.hierarchy.streaming.streaming_stats_round`, K processed in
O(chunk)-memory chunks) — both exact by Eq. 3 because every payload is
linear in samples, and both reusing the comm ``channel`` contract
(a :class:`repro.hierarchy.HierarchicalChannel` additionally fans the
aggregation in through edge aggregators with one channel per hop).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import utils
from repro.core import cco, losses
from repro.optim import optimizers as opt_lib
from repro.server import drift as drift_lib
from repro.server import update as server_update_lib

F32 = jnp.float32


def resolve_objective(objective, lam: float = 20.0):
    """Resolve an objective name/instance; ``None`` -> CCO with ``lam``.

    ``lam`` is CCO's hyperparameter, so it also applies when the CCO
    objective is requested *by name* — ``objective="dcco", lam=5.0`` must
    not silently train with the default lam. Other names/instances carry
    their own hyperparameters and ignore ``lam``.

    Imported lazily: ``repro.objectives`` builds on ``repro.core``, so a
    module-level import here would be circular.
    """
    from repro import objectives as objectives_lib
    if objective is None or objective == "dcco":
        return objectives_lib.CCOObjective(lam=lam)
    return objectives_lib.get_objective(objective)


class RoundMetrics(NamedTuple):
    loss: jnp.ndarray
    encoding_std: jnp.ndarray
    # uplink bytes this round (0 when no comm channel is modeled)
    wire_bytes: Any = 0.0


def sample_clients(key, num_clients: int, clients_per_round: int):
    """Server samples K clients without replacement."""
    return jax.random.choice(key, num_clients, (clients_per_round,), replace=False)


def _client_masks(client_sizes, n_pad: int):
    idx = jnp.arange(n_pad)[None, :]
    return (idx < client_sizes[:, None]).astype(F32)


def client_local_steps(loss_fn, params, client_lr: float, local_steps: int,
                       *, prox_mu: float = 0.0, correction=None):
    """Run a client's local plain-GD steps (paper: lr 1.0, 1 step).

    Returns (delta in f32, first-step loss). Shared by every round body —
    fed_sim and the sharded engine path — so the update rule has one home.

    Drift correction hooks (repro.server.drift):
      ``prox_mu``    — FedProx: the proximal gradient
                       ``mu * (p_local - p_broadcast)`` is added analytically
                       each step. ``prox_mu = 0`` (static) skips the term
                       entirely — bit-identical to the plain step (tested).
      ``correction`` — SCAFFOLD: a params-shaped pytree (``c - c_k``) added
                       to every local gradient; ``None`` skips it.
    """
    p_local = params
    loss0 = jnp.zeros((), F32)
    for step in range(local_steps):
        loss_val, g = jax.value_and_grad(loss_fn)(p_local)
        if step == 0:
            loss0 = loss_val
        if prox_mu:
            g = jax.tree.map(
                lambda g_, p_, p0: g_.astype(F32) + prox_mu * (
                    p_.astype(F32) - p0.astype(F32)), g, p_local, params)
        if correction is not None:
            g = jax.tree.map(lambda g_, c_: g_.astype(F32) + c_,
                             g, correction)
        p_local = jax.tree.map(
            lambda p_, g_: (p_.astype(F32)
                            - client_lr * g_.astype(F32)).astype(p_.dtype),
            p_local, g)
    delta = utils.tree_sub(utils.tree_cast(p_local, F32),
                           utils.tree_cast(params, F32))
    return delta, loss0


def check_variate_noise(channel) -> None:
    """A noising channel (DP) that does not noise the ``"variate"`` phase
    would release the aggregated SCAFFOLD variate delta — a deterministic
    clipped function of every client's raw update — un-noised while the
    accountant still reports a finite epsilon. Refuse the combination
    loudly (same contract as the engine's fedavg+stats-only guard)."""
    noise_phases = getattr(channel, "noise_phases", None)
    if noise_phases is not None and "variate" not in noise_phases:
        raise ValueError(
            f"{channel!r} noises only {noise_phases}, but SCAFFOLD ships "
            f"per-client variate deltas too — construct it with "
            f"noise_phases including 'variate' so the epsilon it reports "
            f"covers everything it releases")


def _scaffold_round_tail(scaffold_state, deltas, client_lr, local_steps,
                         w, ctx, channel):
    """Shared SCAFFOLD round tail: refresh slot variates from the *raw*
    client deltas (the refresh is client-side — it never crosses the wire),
    ship the variate deltas through the channel's ``"variate"`` phase, and
    fold the aggregate into the carried state.

    Returns (new ScaffoldState, extra uplink bytes)."""
    c_slots_new = drift_lib.scaffold_new_slot_variates(
        scaffold_state, deltas, client_lr, local_steps)
    dc = jax.tree.map(lambda new, old: new - old,
                      c_slots_new, scaffold_state.c_slots)
    if ctx is None:
        agg_dc = jax.tree.map(lambda d: jnp.tensordot(w, d, axes=1), dc)
        extra, pmask = 0.0, None
    else:
        agg_dc = channel.aggregate(ctx, dc, "variate")
        extra, pmask = channel.round_bytes(ctx, agg_dc), ctx.mask
    return drift_lib.scaffold_apply_round(
        scaffold_state, c_slots_new, agg_dc, pmask), extra


# ---------------------------------------------------------------------------
# two-phase stats round (paper Sec 3.3, Fig. 2 — generic over StatsObjective)
# ---------------------------------------------------------------------------

def stats_round(encoder_apply: Callable, params, opt_state, server_opt,
                client_data, client_sizes, *, objective,
                client_lr: float = 1.0, local_steps: int = 1,
                agg_stats_fn: Optional[Callable] = None,
                channel=None, channel_key=None,
                prox_mu: float = 0.0, scaffold_state=None):
    """One two-phase aggregated-statistics round for any StatsObjective
    (``repro.objectives``: dcco / dvicreg / dwmse / registered custom).
    Returns (params, opt_state, metrics). ``dcco_round`` is the CCO-bound
    back-compat alias.

    The protocol is objective-agnostic: phase 1 aggregates whatever stats
    dict ``objective.stats_masked`` emits (Eq. 3 applies because the
    protocol requires linearity in samples), phase 2 optimizes
    ``objective.loss_from_stats`` on the stop-grad combine, and any comm
    ``channel`` transports the objective's stats dict unchanged — payload
    shapes differ per objective (5 vs 7 stats) and quantization / DP /
    dropout / wire-bytes accounting compose per leaf.

    ``agg_stats_fn(zf_flat, zg_flat, mask_flat) -> Stats``, if given, computes
    the phase-1 *aggregate* statistics in one pass over the flattened cohort
    encodings. By Eq. 3 (stats are linear in samples) this equals the weighted
    average of per-client stats exactly — it is how the engine routes phase 1
    through the fused ``cco_stats_pallas`` kernel (with the objective's
    moment set). Phase 1 is never differentiated, so a non-differentiable
    kernel is safe here. The flat path requires a lossless
    full-participation channel (``channel.supports_flat_stats``) since
    per-client payloads never materialize.

    ``channel`` (repro.comm) routes both uplinks — phase-1 statistics and
    phase-2 deltas — through an explicit wire: participation mask and
    aggregation weights come from ``channel.begin_round(channel_key, ...)``,
    payloads go through the channel's encode/decode, and
    ``metrics.wire_bytes`` reports the round's uplink bytes. With
    ``channel=None`` (default) the legacy lossless path runs unchanged;
    DenseChannel is bit-identical to it (tested).

    ``server_opt`` may be a :class:`repro.optim.Optimizer` (wrapped as the
    bit-identical ``fedavg_sgd`` delegate) or any
    :class:`repro.server.ServerUpdate` strategy (FedAvgM / FedAdam / ...).

    Drift correction: ``prox_mu`` adds the FedProx proximal term to every
    local step (``0.0`` = statically off, bit-identical). Passing a
    ``scaffold_state`` (:class:`repro.server.ScaffoldState`) enables
    SCAFFOLD control variates; the round then returns a **4-tuple**
    ``(params, opt_state, new_scaffold_state, metrics)`` instead of the
    usual 3-tuple, and the per-slot variate deltas ride the channel's
    ``"variate"`` phase (accounted in ``metrics.wire_bytes``).
    """
    server_update = server_update_lib.as_server_update(server_opt)
    if scaffold_state is not None and channel is not None:
        check_variate_noise(channel)
    n_pad = jax.tree.leaves(client_data)[0].shape[1]
    masks = _client_masks(client_sizes, n_pad)               # (K, n)
    if channel is None:
        ctx = None
        w = client_sizes.astype(F32) / jnp.sum(client_sizes.astype(F32))
    else:
        if channel_key is None:
            raise ValueError("channel requires channel_key")
        ctx = channel.begin_round(channel_key, client_sizes)
        w = ctx.weights
    wire = 0.0

    # ---- phase 1: clients compute local stats; server aggregates (Eq. 3)
    if agg_stats_fn is None:
        def client_stats(batch, mask):
            zf, zg = encoder_apply(params, batch)
            return objective.stats_masked(zf, zg, mask)

        st_k = jax.vmap(client_stats)(client_data, masks)
        if ctx is None:
            agg = cco.weighted_average_stats(st_k, client_sizes.astype(F32))
        else:
            agg = channel.aggregate(ctx, st_k, "stats")
    else:
        if ctx is not None and not channel.supports_flat_stats:
            raise ValueError(
                f"agg_stats_fn needs per-client payloads, incompatible "
                f"with {channel!r}")
        zf_k, zg_k = jax.vmap(lambda b: encoder_apply(params, b))(client_data)
        agg = agg_stats_fn(zf_k.reshape(-1, zf_k.shape[-1]),
                           zg_k.reshape(-1, zg_k.shape[-1]),
                           masks.reshape(-1))
    if ctx is not None:
        wire = wire + channel.round_bytes(ctx, agg)

    # ---- phase 2: server redistributes agg stats; clients run local steps
    def client_update(batch, mask, corr=None):
        def loss_fn(p):
            zf, zg = encoder_apply(p, batch)
            local = objective.stats_masked(zf, zg, mask)
            combined = objective.combine(local, agg)
            return objective.loss_from_stats(combined)

        return client_local_steps(loss_fn, params, client_lr, local_steps,
                                  prox_mu=prox_mu, correction=corr)

    if scaffold_state is None:
        deltas, losses_k = jax.vmap(client_update)(client_data, masks)
    else:
        deltas, losses_k = jax.vmap(client_update)(
            client_data, masks, drift_lib.scaffold_corrections(scaffold_state))

    # ---- server: weighted average of deltas -> FedOpt pseudo-gradient
    if ctx is None:
        avg_delta = jax.tree.map(lambda d: jnp.tensordot(w, d, axes=1), deltas)
    else:
        avg_delta = channel.aggregate(ctx, deltas, "update")
        wire = wire + channel.round_bytes(ctx, avg_delta)
    params, opt_state = server_update.step(params, opt_state, avg_delta)

    # collapse probe on the aggregated stats
    enc_std = objective.encoding_std(agg)
    if scaffold_state is not None:
        new_scaffold, extra = _scaffold_round_tail(
            scaffold_state, deltas, client_lr, local_steps, w, ctx, channel)
        metrics = RoundMetrics(jnp.sum(w * losses_k), enc_std,
                               jnp.asarray(wire + extra, F32))
        return params, opt_state, new_scaffold, metrics
    return params, opt_state, RoundMetrics(jnp.sum(w * losses_k), enc_std,
                                           jnp.asarray(wire, F32))


def dcco_round(encoder_apply: Callable, params, opt_state, server_opt,
               client_data, client_sizes, *, lam: float = 20.0,
               objective=None, **round_kw):
    """Back-compat alias: one DCCO round == ``stats_round`` with the CCO
    objective (``lam`` is CCO's off-diagonal weight). See ``stats_round``
    for the full contract; passing ``objective=`` selects another
    registered stats objective (then ``lam`` is ignored)."""
    return stats_round(encoder_apply, params, opt_state, server_opt,
                       client_data, client_sizes,
                       objective=resolve_objective(objective, lam),
                       **round_kw)


# ---------------------------------------------------------------------------
# FedAvg baselines (within-client loss, no stats exchange)
# ---------------------------------------------------------------------------

def fedavg_round(encoder_apply: Callable, params, opt_state, server_opt,
                 client_data, client_sizes, *, loss_kind: str = "cco",
                 lam: float = 20.0, temperature: float = 0.1,
                 objective=None,
                 client_lr: float = 1.0, local_steps: int = 1,
                 channel=None, channel_key=None,
                 prox_mu: float = 0.0, scaffold_state=None):
    """FedAvg with a within-client loss: 'stats' | 'cco' | 'contrastive'
    | 'byol'.

    The ``'stats'`` kind runs any :class:`repro.objectives.StatsObjective`
    as a *within-client* loss (no stats exchange — the baseline DCCO-style
    training is compared against); ``'cco'`` is its back-compat spelling
    bound to the CCO objective with ``lam``, so the historical path is
    bit-identical.

    ``channel`` routes the single uplink (client deltas) through the wire,
    same contract as in ``stats_round`` — as are ``server_opt`` (Optimizer
    or ServerUpdate), ``prox_mu``, and ``scaffold_state`` (which again
    turns the return into a 4-tuple carrying the new variates).
    """
    server_update = server_update_lib.as_server_update(server_opt)
    if loss_kind in ("cco", "stats"):
        objective = resolve_objective(objective, lam)
    if scaffold_state is not None and channel is not None:
        check_variate_noise(channel)
    n_pad = jax.tree.leaves(client_data)[0].shape[1]
    masks = _client_masks(client_sizes, n_pad)
    if channel is None:
        ctx = None
        w = client_sizes.astype(F32) / jnp.sum(client_sizes.astype(F32))
    else:
        if channel_key is None:
            raise ValueError("channel requires channel_key")
        ctx = channel.begin_round(channel_key, client_sizes)
        w = ctx.weights

    def client_loss(p, batch, mask):
        zf, zg = encoder_apply(p, batch)
        if loss_kind in ("cco", "stats"):
            st = objective.stats_masked(zf, zg, mask)
            return objective.loss_from_stats(st)
        if loss_kind == "contrastive":
            # NOTE: padding samples contribute as (weak) negatives; paper's
            # clients are tiny so we keep the simple masked-mean variant.
            return losses.ntxent_loss(zf, zg, temperature)
        if loss_kind == "byol":
            return losses.byol_predictive_loss(zf, zg)
        raise ValueError(loss_kind)

    def client_update(batch, mask, corr=None):
        return client_local_steps(lambda p: client_loss(p, batch, mask),
                                  params, client_lr, local_steps,
                                  prox_mu=prox_mu, correction=corr)

    if scaffold_state is None:
        deltas, losses_k = jax.vmap(client_update)(client_data, masks)
    else:
        deltas, losses_k = jax.vmap(client_update)(
            client_data, masks, drift_lib.scaffold_corrections(scaffold_state))
    if ctx is None:
        avg_delta = jax.tree.map(lambda d: jnp.tensordot(w, d, axes=1), deltas)
        wire = 0.0
    else:
        avg_delta = channel.aggregate(ctx, deltas, "update")
        wire = channel.round_bytes(ctx, avg_delta)
    params, opt_state = server_update.step(params, opt_state, avg_delta)
    if scaffold_state is not None:
        new_scaffold, extra = _scaffold_round_tail(
            scaffold_state, deltas, client_lr, local_steps, w, ctx, channel)
        metrics = RoundMetrics(jnp.sum(w * losses_k), jnp.zeros((), F32),
                               jnp.asarray(wire + extra, F32))
        return params, opt_state, new_scaffold, metrics
    return params, opt_state, RoundMetrics(jnp.sum(w * losses_k),
                                           jnp.zeros((), F32),
                                           jnp.asarray(wire, F32))


# ---------------------------------------------------------------------------
# Centralized step (the paper's upper bound) — for equivalence checks
# ---------------------------------------------------------------------------

def centralized_step(encoder_apply: Callable, params, opt_state, server_opt,
                     batch, mask=None, *, lam: float = 20.0, objective=None):
    """One centralized large-batch step of a stats objective (default: CCO
    with ``lam`` — the pre-protocol behavior). batch leaves: (N, ...).

    ``server_opt`` may be an Optimizer or a ServerUpdate; the raw gradient
    goes straight to the wrapped optimizer (there is no client delta here,
    so drift corrections do not apply)."""
    server_opt = server_update_lib.as_server_update(server_opt).opt
    objective = resolve_objective(objective, lam)

    def loss_fn(p):
        zf, zg = encoder_apply(p, batch)
        if mask is not None:
            st = objective.stats_masked(zf, zg, mask)
        else:
            st = objective.stats(zf, zg)
        return objective.loss_from_stats(st)

    loss, g = jax.value_and_grad(loss_fn)(params)
    updates, opt_state = server_opt.update(g, opt_state, params)
    params = opt_lib.apply_updates(params, updates)
    return params, opt_state, RoundMetrics(loss, jnp.zeros((), F32))
