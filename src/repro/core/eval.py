"""Evaluation protocols (paper Sec. 4): linear evaluation on frozen
encodings and full-finetuning cross-entropy training.

The linear probe uses a closed-form ridge classifier on one-hot targets —
deterministic and cheap, which is what benchmarks need for *relative*
comparisons between pretraining methods (the paper's tables compare methods
under an identical probe protocol; the probe family matters less than
holding it fixed)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def ridge_linear_probe(train_z, train_y, test_z, test_y, num_classes: int,
                       l2: float = 1e-2):
    """Fit W on (train_z -> one-hot) in closed form; return test accuracy."""
    z = train_z.astype(F32)
    z = jnp.concatenate([z, jnp.ones((z.shape[0], 1), F32)], axis=1)  # bias
    y = jax.nn.one_hot(train_y, num_classes, dtype=F32)
    d = z.shape[1]
    a = z.T @ z + l2 * jnp.eye(d, dtype=F32)
    b = z.T @ y
    w = jnp.linalg.solve(a, b)
    zt = jnp.concatenate([test_z.astype(F32),
                          jnp.ones((test_z.shape[0], 1), F32)], axis=1)
    pred = jnp.argmax(zt @ w, axis=-1)
    return (pred == test_y).mean()


def recall_at_k(retrieved_relevant, ks=(1, 5, 10)):
    """Recall@k over a (Q, K) boolean relevance matrix of ranked retrievals
    (column j = "the rank-j item is relevant to query i"). Returns
    {k: fraction of queries with >= 1 relevant item in the top k} as f32
    scalars. Every k must be <= K — silently truncated recall would read
    as a real score."""
    rel = jnp.asarray(retrieved_relevant)
    for k in ks:
        if k > rel.shape[1]:
            raise ValueError(f"recall@{k} needs >= {k} ranked items, "
                             f"got {rel.shape[1]}")
    return {k: jnp.any(rel[:, :k], axis=1).astype(F32).mean() for k in ks}


def mean_reciprocal_rank(retrieved_relevant):
    """MRR over a (Q, K) boolean relevance matrix of ranked retrievals:
    mean of 1/rank of each query's FIRST relevant item (0 contribution for
    queries with none in the top K)."""
    rel = jnp.asarray(retrieved_relevant)
    first = jnp.argmax(rel, axis=1)                 # first True (0 if none)
    found = jnp.any(rel, axis=1)
    return jnp.where(found, 1.0 / (first.astype(F32) + 1.0), 0.0).mean()


def retrieval_metrics(retrieved_idx, query_labels, corpus_labels,
                      ks=(1, 5, 10)):
    """Label-match retrieval quality of a ranked (Q, K) index matrix.

    An item is relevant to a query when their labels agree — the protocol
    of the paper's deployed use case (class-mate retrieval on the synthetic
    benchmarks). Returns {"recall_at_<k>": ..., "mrr": ...} f32 scalars;
    MRR is computed within the K retrieved ranks."""
    rel = corpus_labels[retrieved_idx] == query_labels[:, None]
    out = {f"recall_at_{k}": v for k, v in recall_at_k(rel, ks).items()}
    out["mrr"] = mean_reciprocal_rank(rel)
    return out


def knn_probe(train_z, train_y, test_z, test_y, k: int = 5,
              num_classes: int = None):
    """Cosine k-NN accuracy — second, parameter-free probe.

    ``num_classes`` must be passed explicitly when calling under ``jit``:
    the default derives it from the concrete label array
    (``int(jnp.max(train_y)) + 1``), which cannot work on tracers since
    the vote-count shape depends on it.
    """
    if num_classes is None:
        num_classes = int(jnp.max(train_y)) + 1

    def norm(z):
        z = z.astype(F32)
        return z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), 1e-8)

    sim = norm(test_z) @ norm(train_z).T                     # (T, N)
    _, idx = jax.lax.top_k(sim, k)
    votes = train_y[idx]                                     # (T, k)
    counts = jax.vmap(lambda v: jnp.bincount(v, length=num_classes))(votes)
    pred = jnp.argmax(counts, axis=-1)
    return (pred == test_y).mean()
