# The paper's primary contribution: DCCO — distributed cross-correlation
# optimization for federated dual-encoder training (see DESIGN.md).
from repro.core import fed_sim  # noqa: F401
from repro.core.cco import (  # noqa: F401
    SECOND_MOMENT_KEYS, STAT_KEYS, cco_loss, cco_loss_from_stats,
    correlation_matrix, dcco_combine, encoding_stats, encoding_stats_masked,
    moment_stats, per_client_stats, weighted_average_stats)
from repro.core.dcco import (  # noqa: F401
    dcco_loss, dcco_loss_fused, dcco_loss_per_client,
    make_shard_map_dcco_loss)
from repro.core.losses import (  # noqa: F401
    byol_predictive_loss, encoding_variance, ntxent_loss,
    softmax_cross_entropy)
from repro.core.round_engine import (  # noqa: F401
    ALGORITHMS, EngineCarry, EngineConfig, EngineMetrics, RoundEngine,
    dcco_round_sharded, make_round_body, stats_round_sharded)
