# The paper's primary contribution: DCCO — distributed cross-correlation
# optimization for federated dual-encoder training (see DESIGN.md).
from repro.core.cco import (  # noqa: F401
    encoding_stats, encoding_stats_masked, weighted_average_stats,
    correlation_matrix, cco_loss, cco_loss_from_stats, dcco_combine,
    per_client_stats, STAT_KEYS)
from repro.core.dcco import (  # noqa: F401
    dcco_loss, dcco_loss_fused, dcco_loss_per_client,
    make_shard_map_dcco_loss)
from repro.core.losses import (  # noqa: F401
    ntxent_loss, softmax_cross_entropy, byol_predictive_loss, encoding_variance)
from repro.core import fed_sim  # noqa: F401
from repro.core.round_engine import (  # noqa: F401
    ALGORITHMS, EngineCarry, EngineConfig, EngineMetrics, RoundEngine,
    dcco_round_sharded, make_round_body)
