"""Cross Correlation Optimization (CCO) loss and encoding statistics.

Paper Eq. 1-3. The five statistics
    <F_i>, <F_i^2>, <G_j>, <G_j^2>, <F_i G_j>
are linear in samples, so large-batch statistics are exactly weighted
averages of per-client statistics (Eq. 3) — the insight DCCO is built on.

All statistics math is f32 regardless of model dtype: correlation
coefficients divide near-cancelling quantities and are ill-conditioned
in bf16.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

F32 = jnp.float32
Stats = Dict[str, jnp.ndarray]   # mean_f/sq_f: (d,), mean_g/sq_g: (d,), cross: (d,d)

STAT_KEYS = ("mean_f", "sq_f", "mean_g", "sq_g", "cross")


def encoding_stats(zf, zg) -> Stats:
    """Five batch statistics of encodings zf, zg: (N, d) -> Stats."""
    zf = zf.astype(F32)
    zg = zg.astype(F32)
    n = zf.shape[0]
    return {
        "mean_f": zf.mean(0),
        "sq_f": (zf * zf).mean(0),
        "mean_g": zg.mean(0),
        "sq_g": (zg * zg).mean(0),
        "cross": zf.T @ zg / n,
    }


def weighted_average_stats(stats: Stats, weights) -> Stats:
    """Aggregate stacked per-client stats (leading axis K) with weights N_k/N.

    Implements paper Eq. 3 exactly.
    """
    w = weights.astype(F32) / jnp.sum(weights.astype(F32))

    def avg(x):
        return jnp.tensordot(w, x, axes=1)

    return {k: avg(v) for k, v in stats.items()}


def correlation_matrix(stats: Stats, eps: float = 1e-8):
    """C_ij per paper Eq. 2, from the five statistics."""
    var_f = stats["sq_f"] - stats["mean_f"] ** 2
    var_g = stats["sq_g"] - stats["mean_g"] ** 2
    cov = stats["cross"] - jnp.outer(stats["mean_f"], stats["mean_g"])
    denom = jnp.sqrt(jnp.maximum(var_f, 0.0) + eps)[:, None] * \
        jnp.sqrt(jnp.maximum(var_g, 0.0) + eps)[None, :]
    return cov / denom


def cco_loss_from_stats(stats: Stats, lam: float = 20.0) -> jnp.ndarray:
    """Paper Eq. 1 with the 1/(d-1) off-diagonal normalization."""
    c = correlation_matrix(stats)
    d = c.shape[0]
    diag = jnp.diagonal(c)
    on = jnp.sum((1.0 - diag) ** 2)
    off = (jnp.sum(c * c) - jnp.sum(diag * diag)) / (d - 1)
    return on + lam * off


def cco_loss(zf, zg, lam: float = 20.0) -> jnp.ndarray:
    """Centralized large-batch CCO loss (the paper's upper-bound baseline)."""
    return cco_loss_from_stats(encoding_stats(zf, zg), lam)


def dcco_combine(local: Stats, agg: Stats) -> Stats:
    """Combined statistics <.>_C = <.>_k + sg(<.>_A - <.>_k)  (paper Fig. 2).

    Value equals the aggregated statistics; gradients flow only through the
    local statistics — each client can backprop only through its own data.
    """
    return {k: local[k] + jax.lax.stop_gradient(agg[k] - local[k]) for k in local}


def encoding_stats_masked(zf, zg, mask) -> Stats:
    """Statistics over valid samples only (mask: (N,) in {0,1}).

    Supports variable-size clients (DERM: 1-6 images/case) via padding."""
    zf = zf.astype(F32)
    zg = zg.astype(F32)
    w = mask.astype(F32)
    n = jnp.maximum(w.sum(), 1.0)
    zf_m = zf * w[:, None]
    zg_m = zg * w[:, None]
    return {
        "mean_f": zf_m.sum(0) / n,
        "sq_f": (zf_m * zf).sum(0) / n,
        "mean_g": zg_m.sum(0) / n,
        "sq_g": (zg_m * zg).sum(0) / n,
        "cross": zf_m.T @ zg / n,
    }


def per_client_stats(zf, zg, clients: int) -> Stats:
    """Reshape a round's encodings (N, d) into per-client stats (K leading).

    Assumes equal-size clients laid out contiguously: N = K * n_k.
    """
    n, d = zf.shape
    assert n % clients == 0
    zf_c = zf.reshape(clients, n // clients, d)
    zg_c = zg.reshape(clients, n // clients, d)
    return jax.vmap(encoding_stats)(zf_c, zg_c)
