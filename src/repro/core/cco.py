"""Cross Correlation Optimization (CCO) loss and encoding statistics.

Paper Eq. 1-3. The five statistics
    <F_i>, <F_i^2>, <G_j>, <G_j^2>, <F_i G_j>
are linear in samples, so large-batch statistics are exactly weighted
averages of per-client statistics (Eq. 3) — the insight DCCO is built on.

All statistics math is f32 regardless of model dtype: correlation
coefficients divide near-cancelling quantities and are ill-conditioned
in bf16.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

F32 = jnp.float32
Stats = Dict[str, jnp.ndarray]   # mean_f/sq_f: (d,), mean_g/sq_g: (d,), cross: (d,d)

STAT_KEYS = ("mean_f", "sq_f", "mean_g", "sq_g", "cross")
# the optional within-view second moments (VICReg / W-MSE moment set)
SECOND_MOMENT_KEYS = ("cov_f", "cov_g")


def moment_stats(zf, zg, mask=None, *, second_moments: bool = False) -> Stats:
    """The one sufficient-statistics accumulator every objective shares.

    Computes the five CCO statistics — and, with ``second_moments``, the two
    within-view second-moment matrices <F F^T>, <G G^T> that VICReg-family
    losses need — in a single place, for both the dense and the masked
    (padded variable-size client) layouts. Every statistic is linear in
    samples, which is the invariant paper Eq. 3, the flattened-cohort
    kernel path, and the shard_map psum path all rely on
    (property-tested per registered objective in tests/test_objectives.py).

    ``mask`` is ``(N,)`` in {0, 1}; rows with 0 contribute nothing and the
    normalizer is the valid-sample count (DERM: 1-6 images/case padding).
    """
    zf = zf.astype(F32)
    zg = zg.astype(F32)
    if mask is None:
        n = zf.shape[0]
        st = {
            "mean_f": zf.mean(0),
            "sq_f": (zf * zf).mean(0),
            "mean_g": zg.mean(0),
            "sq_g": (zg * zg).mean(0),
            "cross": zf.T @ zg / n,
        }
        if second_moments:
            st["cov_f"] = zf.T @ zf / n
            st["cov_g"] = zg.T @ zg / n
        return st
    w = mask.astype(F32)
    n = jnp.maximum(w.sum(), 1.0)
    zf_m = zf * w[:, None]
    zg_m = zg * w[:, None]
    st = {
        "mean_f": zf_m.sum(0) / n,
        "sq_f": (zf_m * zf).sum(0) / n,
        "mean_g": zg_m.sum(0) / n,
        "sq_g": (zg_m * zg).sum(0) / n,
        "cross": zf_m.T @ zg / n,
    }
    if second_moments:
        st["cov_f"] = zf_m.T @ zf / n
        st["cov_g"] = zg_m.T @ zg / n
    return st


def encoding_stats(zf, zg) -> Stats:
    """Five batch statistics of encodings zf, zg: (N, d) -> Stats."""
    return moment_stats(zf, zg)


def weighted_average_stats(stats: Stats, weights) -> Stats:
    """Aggregate stacked per-client stats (leading axis K) with weights N_k/N.

    Implements paper Eq. 3 exactly.
    """
    w = weights.astype(F32) / jnp.sum(weights.astype(F32))

    def avg(x):
        return jnp.tensordot(w, x, axes=1)

    return {k: avg(v) for k, v in stats.items()}


def correlation_matrix(stats: Stats, eps: float = 1e-8,
                       var_floor: float = 1e-6):
    """C_ij per paper Eq. 2, from the five statistics.

    The variance is floored at ``var_floor * (1 + |sq|)`` — a *relative*
    floor. With ``local_steps >= 2`` on tiny (2-sample) clients the stale
    stop-grad combine ``local + sg(agg - local)`` cancels catastrophically
    once the local stats diverge, and the combined variance can come out
    ~0 or even negative while the covariance does not cancel; the old
    absolute ``max(var, 0) + 1e-8`` then produced a ~1e-8 denominator,
    |C| ~ 1e7, and a loss/gradient explosion that overflowed to NaN within
    a round. Tying the floor to the second-moment scale bounds |C| by
    ~1/var_floor regardless of how degenerate the cancellation is. For any
    healthy variance (var > floor) the floor is bit-invisible: the max
    resolves to var and the expression equals the pre-floor formula
    exactly (asserted in tests/test_objectives.py).
    """
    floor_f = var_floor * (1.0 + jnp.abs(stats["sq_f"]))
    floor_g = var_floor * (1.0 + jnp.abs(stats["sq_g"]))
    var_f = jnp.maximum(stats["sq_f"] - stats["mean_f"] ** 2, floor_f)
    var_g = jnp.maximum(stats["sq_g"] - stats["mean_g"] ** 2, floor_g)
    cov = stats["cross"] - jnp.outer(stats["mean_f"], stats["mean_g"])
    denom = jnp.sqrt(var_f + eps)[:, None] * \
        jnp.sqrt(var_g + eps)[None, :]
    return cov / denom


def cco_loss_from_stats(stats: Stats, lam: float = 20.0) -> jnp.ndarray:
    """Paper Eq. 1 with the 1/(d-1) off-diagonal normalization."""
    c = correlation_matrix(stats)
    d = c.shape[0]
    diag = jnp.diagonal(c)
    on = jnp.sum((1.0 - diag) ** 2)
    off = (jnp.sum(c * c) - jnp.sum(diag * diag)) / (d - 1)
    return on + lam * off


def cco_loss(zf, zg, lam: float = 20.0) -> jnp.ndarray:
    """Centralized large-batch CCO loss (the paper's upper-bound baseline)."""
    return cco_loss_from_stats(encoding_stats(zf, zg), lam)


def dcco_combine(local: Stats, agg: Stats) -> Stats:
    """Combined statistics <.>_C = <.>_k + sg(<.>_A - <.>_k)  (paper Fig. 2).

    Value equals the aggregated statistics; gradients flow only through the
    local statistics — each client can backprop only through its own data.
    """
    return {k: local[k] + jax.lax.stop_gradient(agg[k] - local[k]) for k in local}


def encoding_stats_masked(zf, zg, mask) -> Stats:
    """Statistics over valid samples only (mask: (N,) in {0,1}).

    Supports variable-size clients (DERM: 1-6 images/case) via padding."""
    return moment_stats(zf, zg, mask)


def per_client_stats(zf, zg, clients: int) -> Stats:
    """Reshape a round's encodings (N, d) into per-client stats (K leading).

    Assumes equal-size clients laid out contiguously: N = K * n_k.
    """
    n, d = zf.shape
    assert n % clients == 0
    zf_c = zf.reshape(clients, n // clients, d)
    zg_c = zg.reshape(clients, n // clients, d)
    return jax.vmap(encoding_stats)(zf_c, zg_c)
