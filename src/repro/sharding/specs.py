"""Logical-axis sharding rules -> PartitionSpec trees.

Mesh axes: ("pod",)? + ("data", "model"). Batch/client dims shard over
(pod, data); weight feature dims shard over model (tensor parallel);
MoE expert dims shard over model (expert parallel). Every rule is
divisibility-aware: a dim that does not divide by the axis size stays
replicated rather than failing at compile time (e.g. kv_heads=8 on
model=16).

Baseline policy (recorded in DESIGN.md/EXPERIMENTS.md): SSM / xLSTM mixer
weights replicated (their fused in-projections interleave semantic segments,
so naive column sharding causes resharding collectives); attention + FFN +
MoE + embedding sharded. The FSDP mode (see param_pspecs) shards everything
— including the recurrent mixers — by storage, which is how zamba2/xlstm
shed the replication cost in the §Perf FSDP variant.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
import numpy as np

DATA_AXES: Tuple[str, ...] = ("pod", "data")   # present subset used


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def data_axes(mesh: Mesh):
    axes = tuple(a for a in DATA_AXES if a in mesh.axis_names)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _maybe(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0 and dim >= size


def _spec(ndim: int, shard_dim: int | None, axis: str | None) -> P:
    if shard_dim is None or axis is None:
        return P()
    parts = [None] * ndim
    parts[shard_dim] = axis
    return P(*parts)


# param-name rules: (substring, which dim of the *unstacked* weight to shard)
_OUT = ("wq/w", "wk/w", "wv/w", "gate/w", "up/w", "ffn_up/w", "w_uk/w", "w_uv/w")
_IN = ("wo/w", "down/w", "ffn_down/w", "out_proj/w")
_REPLICATE = ("router", "norm", "scale", "bias", "A_log", "dt_bias", "conv_w",
              "conv_b", "r_i", "r_f", "r_z", "r_o", "w_i", "w_f", "w_gates",
              "in_proj", "w_dkv", "kv_norm")


def param_pspecs(params: Any, mesh: Mesh, mode: str = "tp") -> Any:
    """PartitionSpec tree matching `params` (works on arrays or
    ShapeDtypeStructs).

    mode="tp"   — tensor parallel: attention-head/FFN/expert dims shard over
                  `model`; contractions produce per-layer activation
                  all-reduces. Baseline.
    mode="fsdp" — fully-sharded data parallel: every >=2D weight shards its
                  largest divisible dim over `model` purely as STORAGE; the
                  batch is spread over (pod, data, model) so XLA inserts
                  per-layer weight all-gathers instead of activation
                  all-reduces. Wins whenever tokens/device x d_model >>
                  params/layer (true for train_4k; see EXPERIMENTS §Perf).
    """
    if mode == "fsdp":
        return _fsdp_pspecs(params, mesh)
    msize = _axis_size(mesh, "model")

    def rule(path, leaf):
        pstr = _path_str(path)
        shape = leaf.shape
        nd = len(shape)

        if any(s in pstr for s in _REPLICATE):
            return P()
        if "experts/" in pstr:
            # expert weights are 3D (E, d, f)/(E, f, d), 4D when scan-stacked
            # (paths may carry tower/ or optimizer-state prefixes)
            e_dim = nd - 3
            if e_dim >= 0 and _maybe(shape[e_dim], msize):
                return _spec(nd, e_dim, "model")
            return P()
        if "embed/table" in pstr:               # (V, D)
            return _spec(nd, 0, "model") if _maybe(shape[0], msize) else P()
        if "unembed/w" in pstr:                 # (D, V)
            return _spec(nd, 1, "model") if _maybe(shape[1], msize) else P()
        if any(pstr.endswith(s) or f"/{s}" in pstr for s in _OUT):
            return _spec(nd, nd - 1, "model") if _maybe(shape[-1], msize) else P()
        if any(pstr.endswith(s) or f"/{s}" in pstr for s in _IN):
            return _spec(nd, nd - 2, "model") if _maybe(shape[-2], msize) else P()
        if pstr.endswith("up/w"):               # mlstm up proj
            return _spec(nd, nd - 1, "model") if _maybe(shape[-1], msize) else P()
        return P()

    return jax.tree_util.tree_map_with_path(rule, params)


def _fsdp_pspecs(params: Any, mesh: Mesh) -> Any:
    msize = _axis_size(mesh, "model")

    def rule(path, leaf):
        shape = leaf.shape
        nd = len(shape)
        if nd < 2 or msize <= 1:
            return P()
        pstr = _path_str(path)
        # NOTE (measured, see EXPERIMENTS §Perf): keeping experts
        # expert-parallel inside FSDP mode makes things WORSE (31 GiB
        # resident vs 19) — token sharding over all axes conflicts with the
        # expert dispatch axes. A true hybrid needs the MoE block to
        # re-shard tokens to the data axes before dispatch; until then MoE
        # archs should use the TP/EP baseline, not FSDP.
        start = 1 if ("layers/" in pstr and nd >= 3) else 0
        cands = [(shape[i], i) for i in range(start, nd) if _maybe(shape[i], msize)]
        if not cands:
            return P()
        _, dim = max(cands)
        return _spec(nd, dim, "model")

    return jax.tree_util.tree_map_with_path(rule, params)


def opt_state_pspecs(opt_specs: Any, opt_sds: Any, mesh: Mesh) -> Any:
    """ZeRO-1: additionally shard optimizer moments over the data axes.

    Starting from the parameter-aligned specs, the largest still-unsharded
    dim of every >=2D moment leaf is sharded over (pod, data) when
    divisible. Grads arrive via reduce-scatter instead of all-reduce and
    the updated params are all-gathered — wired automatically by SPMD once
    these in/out shardings are pinned.
    """
    ax = data_axes(mesh)
    axes = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
    dsize = int(np.prod([_axis_size(mesh, a) for a in axes])) if axes else 1

    def rule(spec: P, leaf) -> P:
        shape = leaf.shape
        if len(shape) < 2 or dsize <= 1:
            return spec
        parts = list(spec) + [None] * (len(shape) - len(spec))
        cands = [(shape[i], i) for i in range(len(shape))
                 if parts[i] is None and _maybe(shape[i], dsize)]
        if not cands:
            return spec
        _, dim = max(cands)
        parts[dim] = ax
        return P(*parts)

    return jax.tree_util.tree_map(rule, opt_specs, opt_sds,
                                  is_leaf=lambda x: isinstance(x, P))


def batch_pspec(mesh: Mesh, ndim: int = 2, batch: int = 0) -> P:
    """Shard leading (batch/client) dim over (pod, data) when divisible."""
    ax = data_axes(mesh)
    if batch:
        axes = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
        dsize = int(np.prod([_axis_size(mesh, a) for a in axes]))
        if not _maybe(batch, dsize):
            return P(*([None] * ndim))
    return P(ax, *([None] * (ndim - 1)))


def cache_pspecs(cache: Any, mesh: Mesh, *, seq_shard: bool = False) -> Any:
    """Sharding for decode caches.

    Layout conventions (see transformer.init_cache):
      attn k/v:      (n_super, B, W, kvh, dh)
      mla latent:    (n_super, B, S, r); k_rope: (n_super, B, S, dr)
      kv_pos:        (n_super, B, W)
      mamba conv:    (n_super, B, w-1, conv_dim); ssm: (n_super, B, H, N, P)
      xlstm C/n/m:   (n_super, B, ...)
    Batch shards over (pod,data) when divisible; with seq_shard=True (used
    when batch==1, e.g. long_500k) the seq/window dim shards over data
    instead (flash-decode style) and kv heads over model when divisible.
    """
    ax = data_axes(mesh)
    dsize = int(np.prod([_axis_size(mesh, a) for a in (ax if isinstance(ax, tuple)
                                                       else (ax,) if ax else ())]))
    msize = _axis_size(mesh, "model")

    def rule(path, leaf):
        pstr = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        if pstr.endswith("pos") and nd == 0:
            return P()
        has_super = pstr.startswith("layers/")
        b_dim = 1 if has_super else 0
        if nd <= b_dim:
            return P()
        parts = [None] * nd
        if not seq_shard and _maybe(shape[b_dim], dsize):
            parts[b_dim] = ax
            # flash-decode: also shard the seq/window dim over `model` so the
            # cache fits per-chip HBM and attention reduces over seq shards
            # (small per-head softmax-stat collectives instead of cache
            # all-gathers).
            if nd >= b_dim + 2 and _maybe(shape[b_dim + 1], msize) and (
                    "kv_pos" in pstr or "scale" in pstr or
                    pstr.rsplit("/", 1)[-1] in ("k", "v") or
                    "latent" in pstr or "k_rope" in pstr):
                parts[b_dim + 1] = "model"
        elif seq_shard:
            # shard the seq/window dim (dim after batch) over data
            if "kv_pos" in pstr and nd >= b_dim + 2 and _maybe(shape[b_dim + 1], dsize):
                parts[b_dim + 1] = ax
            elif any(k in pstr for k in ("/k", "/v", "latent", "k_rope", "scale")) \
                    and nd >= b_dim + 2 and _maybe(shape[b_dim + 1], dsize):
                parts[b_dim + 1] = ax
            # kv heads over model for attn k/v (dim b+2)
            if nd >= b_dim + 3 and pstr.rsplit("/", 1)[-1] in ("k", "v") \
                    and _maybe(shape[b_dim + 2], msize):
                parts[b_dim + 2] = "model"
        return P(*parts)

    return jax.tree_util.tree_map_with_path(rule, cache)
