from repro.sharding.specs import (  # noqa: F401
    param_pspecs, batch_pspec, cache_pspecs, named, DATA_AXES)
