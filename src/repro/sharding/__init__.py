from repro.sharding.multihost import (  # noqa: F401
    host_local_to_global, make_corpus_mesh, make_multihost_mesh,
    maybe_initialize_distributed)
from repro.sharding.specs import (  # noqa: F401
    param_pspecs, batch_pspec, cache_pspecs, named, DATA_AXES)
