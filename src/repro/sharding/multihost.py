"""Multi-host mesh plumbing for the sharded-cohort path.

The single-process `shard_map` path (``EngineConfig.cohort_axis``) shards
the K client axis over the local devices of one process. This module grows
that to a ``jax.distributed`` mesh: N processes x D local devices become a
2-D ("data", "client") mesh, and the engine's sharded stats round runs
with ``cohort_axis=("data", "client")`` — the psum in
``stats_round_sharded`` accepts the axis tuple, so the cross-host
aggregate is the same Eq.-3 sum, just re-associated (exact by linearity).

Environment contract (set per process by the launcher):

  REPRO_COORDINATOR    host:port of process 0 (e.g. "127.0.0.1:12345")
  REPRO_NUM_PROCESSES  world size
  REPRO_PROCESS_ID     this process's rank in [0, world)

``maybe_initialize_distributed`` is a no-op when REPRO_COORDINATOR is
unset, so single-process runs (the default, and every existing test) never
touch jax.distributed. On the CPU backend the gloo collectives
implementation is selected first — without it XLA:CPU rejects cross-process
computations outright ("Multiprocess computations aren't implemented on
the CPU backend"), which is exactly what the 2-process CI smoke runs on.

Combine with ``XLA_FLAGS=--xla_force_host_platform_device_count=D`` (the
SNIPPETS idiom; see tests/test_multihost.py) to give each CPU process D
local devices, i.e. a (N, D) data x client mesh.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

COORDINATOR_ENV = "REPRO_COORDINATOR"
NUM_PROCESSES_ENV = "REPRO_NUM_PROCESSES"
PROCESS_ID_ENV = "REPRO_PROCESS_ID"


def maybe_initialize_distributed(env: Optional[dict] = None) -> bool:
    """Initialize jax.distributed from the REPRO_* env contract.

    Returns True when a multi-process runtime was initialized, False for
    the single-process no-op. Must run before any other jax call that
    instantiates a backend (jax.devices(), jit, ...).
    """
    env = os.environ if env is None else env
    coordinator = env.get(COORDINATOR_ENV)
    if not coordinator:
        return False
    num_processes = int(env[NUM_PROCESSES_ENV])
    process_id = int(env[PROCESS_ID_ENV])
    # XLA:CPU has no native cross-process collectives; gloo provides them
    # (and is what the 2-process CI smoke exercises). Set unconditionally:
    # probing the backend first (jax.default_backend()) would instantiate
    # it, and initialize() must run before ANY backend exists. The option
    # only takes effect if/when a CPU client is created.
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)
    return True


def make_multihost_mesh(axis_names: Tuple[str, str] = ("data", "client")):
    """Global (process_count, local_device_count) mesh over ALL devices.

    Axis 0 ("data") spans processes, axis 1 ("client") spans each
    process's local devices — `jax.devices()` enumerates globally in
    process order, so the reshape lines hosts up with mesh rows. On one
    process this degenerates to a (1, D) mesh whose "client" axis is
    exactly the single-host cohort_axis layout.
    """
    devices = np.array(jax.devices())
    per_host = jax.local_device_count()
    return Mesh(devices.reshape(jax.process_count(), per_host), axis_names)


def make_corpus_mesh(num_shards: Optional[int] = None,
                     axis: str = "corpus") -> Mesh:
    """1-D retrieval-serving mesh: ``num_shards`` devices (default all)
    along a single ``axis`` ("corpus") — one index shard per device.

    Unlike the training mesh's (process, local-device) grid, corpus
    sharding is layout-flat: ``jax.devices()`` enumerates globally in
    process order, so shard s of the contiguous partition lands on device
    s and each process holds a contiguous run of shards — which is what
    lets ``ShardedCorpusIndex`` feed ``host_local_to_global`` its local
    slice. Works single-process (forced device counts included) and under
    an initialized jax.distributed runtime alike.
    """
    devices = np.array(jax.devices())
    s = len(devices) if num_shards is None else num_shards
    if not 1 <= s <= len(devices):
        raise ValueError(f"num_shards={s} must be in [1, device count "
                         f"{len(devices)}]")
    return Mesh(devices[:s], (axis,))


def host_local_to_global(mesh: Mesh, spec: P, tree):
    """Assemble per-process host-local shards into global arrays.

    Each process passes ITS slice of the leading (sharded) axis; the
    result is the logically-concatenated global array laid out per
    ``spec`` on ``mesh``. Single-process meshes skip the multihost utils
    (they require an initialized distributed runtime).
    """
    if jax.process_count() == 1:
        sharding = NamedSharding(mesh, spec)
        return jax.tree.map(lambda x: jax.device_put(x, sharding), tree)
    from jax.experimental import multihost_utils
    return multihost_utils.host_local_array_to_global_array(tree, mesh, spec)
