from repro.data.partition import dirichlet_partition, iid_partition  # noqa: F401
from repro.data.pipeline import FederatedDataset  # noqa: F401
from repro.data.synthetic import (  # noqa: F401
    synthetic_labeled_images, synthetic_labeled_tokens)
