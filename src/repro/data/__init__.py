from repro.data.partition import (  # noqa: F401
    PARTITIONS, PartitionSpec, build_partition, dirichlet_partition,
    get_partition, iid_partition, label_dominance, register_partition)
from repro.data.pipeline import FederatedDataset  # noqa: F401
from repro.data.synthetic import (  # noqa: F401
    synthetic_labeled_images, synthetic_labeled_tokens)
