"""Synthetic datasets (the container has no real datasets offline).

Both generators produce *class-structured* data so that representation
quality is measurable: examples of the same class share a latent prototype,
and a linear probe on good encodings separates classes. This preserves the
paper's experimental logic (IID vs non-IID clients, probe accuracy) without
CIFAR-100/DERM files.
"""
from __future__ import annotations

import numpy as np


def synthetic_labeled_images(num_samples: int, num_classes: int,
                             image_size: int = 16, channels: int = 3,
                             noise: float = 0.35, seed: int = 0):
    """Class prototypes + per-sample noise. Returns (images (N,H,W,C) f32 in
    [0,1]-ish, labels (N,))."""
    rng = np.random.RandomState(seed)
    protos = rng.randn(num_classes, image_size, image_size, channels).astype(np.float32)
    labels = rng.randint(0, num_classes, num_samples)
    imgs = protos[labels] + noise * rng.randn(num_samples, image_size, image_size,
                                              channels).astype(np.float32)
    imgs = (imgs - imgs.min()) / (imgs.max() - imgs.min() + 1e-6)
    return imgs.astype(np.float32), labels.astype(np.int32)


def synthetic_labeled_tokens(num_samples: int, num_classes: int, seq_len: int,
                             vocab: int, class_vocab_frac: float = 0.25,
                             seed: int = 0):
    """Token sequences whose unigram distribution is class-dependent:
    each class prefers a slice of the vocabulary. Returns (tokens (N,S) i32,
    labels (N,))."""
    rng = np.random.RandomState(seed)
    labels = rng.randint(0, num_classes, num_samples)
    span = max(2, int(vocab * class_vocab_frac))
    toks = np.zeros((num_samples, seq_len), np.int32)
    for i, y in enumerate(labels):
        lo = (y * span // max(num_classes, 1)) % max(vocab - span, 1)
        mix = rng.rand(seq_len) < 0.8
        toks[i] = np.where(mix, rng.randint(lo, lo + span, seq_len),
                           rng.randint(0, vocab, seq_len))
    return toks, labels.astype(np.int32)
