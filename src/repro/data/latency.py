"""Per-client arrival-latency models for the semi-synchronous engine.

The sync ``RoundEngine`` blocks every round on its whole cohort, so one
straggler stalls the fleet. The buffered engine (``EngineConfig.async_k``,
``repro.core.buffer``) instead lets each dispatched client's contribution
"arrive" ``delay`` scheduler ticks after dispatch. This module owns that
delay model:

  * :class:`LatencyModel` — a tiny static spec (kind, ring horizon,
    heavy-tail severity, per-client seed);
  * :func:`sample_delays` — draws integer delays in ``[0, horizon)`` for a
    cohort of client ids. The ``heavytail`` kind gives every client a
    PERSISTENT Pareto-distributed base latency (a slow client is slow every
    round — the cross-device straggler regime of McMahan et al., 2017),
    keyed by ``fold_in`` on the client id so the draw is reproducible and
    independent of the round;
  * :func:`make_async_sampler` — wraps any plain ``(k_sel, k_aug) ->
    (batch, sizes)`` round sampler into the async 3-tuple form
    ``(batch, sizes, delays)``. The delay key is a ``fold_in`` salt off
    ``k_sel`` (no split), so the selection and augmentation streams are
    bit-identical to the synchronous sampler's — zero-latency async runs
    see exactly the cohorts the sync engine would.

``FederatedDataset.make_async_round_sampler`` is the dataset-aware twin:
same contract, but delays are drawn from the TRUE sampled client ids, so
heavy-tail stragglers persist across rounds.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

LATENCY_KINDS = ("zero", "uniform", "heavytail")

_LATENCY_SALT = 0x1A7    # fold_in salt off k_sel -> the per-round delay key


class LatencyModel(NamedTuple):
    """Static arrival-delay spec for the buffered engine.

    kind: "zero" (every contribution arrives the tick it was dispatched),
    "uniform" (iid delays in [0, horizon)), or "heavytail" (persistent
    per-client Pareto base latency, severity ``tail``). ``horizon`` bounds
    the in-flight ring depth: delays are clipped to ``horizon - 1``.
    """
    kind: str = "zero"
    horizon: int = 1
    tail: float = 0.7       # Pareto exponent multiplier (heavytail only)
    seed: int = 0           # per-client base-latency stream (heavytail only)


def resolve_latency(spec) -> LatencyModel:
    """Coerce None / kind-name / LatencyModel into a validated model."""
    if spec is None:
        spec = LatencyModel()
    elif isinstance(spec, str):
        defaults = {"zero": LatencyModel(),
                    "uniform": LatencyModel("uniform", horizon=4),
                    "heavytail": LatencyModel("heavytail", horizon=8)}
        if spec not in defaults:
            raise ValueError(f"unknown latency kind {spec!r}; "
                             f"expected one of {LATENCY_KINDS}")
        spec = defaults[spec]
    if not isinstance(spec, LatencyModel):
        raise ValueError(f"latency spec must be None, a kind name, or a "
                         f"LatencyModel, got {type(spec).__name__}")
    if spec.kind not in LATENCY_KINDS:
        raise ValueError(f"unknown latency kind {spec.kind!r}; "
                         f"expected one of {LATENCY_KINDS}")
    if spec.horizon < 1:
        raise ValueError(f"latency horizon must be >= 1, got {spec.horizon}")
    if spec.kind == "heavytail" and spec.tail <= 0:
        raise ValueError(f"heavytail severity must be > 0, got {spec.tail}")
    return spec


def sample_delays(model: LatencyModel, key, client_ids) -> jnp.ndarray:
    """Integer arrival delays in ``[0, model.horizon)`` for one cohort.

    ``key`` is the per-round delay key (used by round-varying kinds);
    ``client_ids`` (K,) int are the sampled clients — the heavytail kind
    derives each client's PERSISTENT base latency from them via fold_in,
    so the same client is slow in every round it is dispatched.
    """
    k = client_ids.shape[0]
    if model.kind == "zero":
        return jnp.zeros((k,), jnp.int32)
    if model.kind == "uniform":
        return jax.random.randint(key, (k,), 0, model.horizon, jnp.int32)
    base = jax.random.PRNGKey(model.seed)
    u = jax.vmap(
        lambda c: jax.random.uniform(jax.random.fold_in(base, c),
                                     minval=1e-6))(client_ids)
    # Pareto-tail base latency: u^(-tail) - 1 is 0 for most clients and
    # large for a heavy few; floor to ticks, clip to the ring horizon
    d = jnp.floor(u ** (-model.tail) - 1.0)
    return jnp.clip(d, 0, model.horizon - 1).astype(jnp.int32)


def make_async_sampler(base_sampler, model, clients_per_round: int):
    """Wrap a plain round sampler into the async ``(batch, sizes, delays)``
    contract the buffered engine expects. Delays key off the cohort SLOT
    index (0..K-1), not true client ids — use
    ``FederatedDataset.make_async_round_sampler`` for persistent per-client
    stragglers; this wrapper is for fixed-data samplers (tests, toys)."""
    model = resolve_latency(model)
    slots = jnp.arange(clients_per_round, dtype=jnp.int32)

    def sampler(k_sel, k_aug):
        batch, sizes = base_sampler(k_sel, k_aug)
        dk = jax.random.fold_in(k_sel, _LATENCY_SALT)
        return batch, sizes, sample_delays(model, dk, slots)

    sampler.latency = model
    sampler.clients_per_round = clients_per_round
    return sampler
