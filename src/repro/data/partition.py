"""Client dataset partitioning (paper Sec 4.1) — strategies as data.

The paper's premise is many small *non-IID* client datasets, and the
non-IID survey literature (label-distribution skew, quantity skew,
pathological label sharding) treats heterogeneity as an axis to sweep,
not a single knob. This module therefore exposes partition *strategies as
registered data*: a :class:`PartitionSpec` names a strategy plus one
normalized ``severity`` in [0, 1], and each strategy maps severity onto
its own natural parameter:

  ================== =====================================================
  strategy           severity mapping
  ================== =====================================================
  ``iid``            none — shuffled uniform assignment (severity-flat
                     control)
  ``uniform``        none — class-stratified equal split (each client
                     holds every class in equal measure; the *most*
                     homogeneous control, severity-flat)
  ``label``          classes-per-client ``m = round(C - severity*(C-1))``
                     — severity 1 is the pathological single-class shard
                     (McMahan et al. 2017), severity 0 holds all C classes
  ``dirichlet``      ``alpha = 10**(3 - 6*severity)`` — severity 0 is the
                     paper's alpha=1000 "IID", severity 1 is alpha=1e-3
                     (effectively single-class; Hsu et al. 2019)
  ``dirichlet_quantity``  client *sizes* ~ Dirichlet(beta), label
                     distribution IID; ``beta = 10**(3 - 6*severity)`` —
                     severity 0 gives near-equal sizes, severity 1 a
                     heavy-tailed size distribution (floor 1 sample)
  ================== =====================================================

Every strategy conserves samples: each dataset index is assigned to at
most one client, and each assigned (non-padding) slot holds a distinct
index (property-tested). ``register_partition`` extends the registry,
mirroring ``repro.objectives.register_objective``.

Non-IID Dirichlet partitions use the sampling process of Hsu et al. 2019:
for each client, draw a categorical distribution q ~ Dir(alpha * prior)
and sample that client's examples from the class-conditional pools.
alpha -> 0 gives single-class clients (the paper's "non-IID", alpha = 0);
alpha -> inf gives IID clients (paper uses alpha = 1000 as "IID").
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import numpy as np


class PartitionSpec(NamedTuple):
    """A named partition strategy + its normalized severity knob.

    ``severity`` in [0, 1] is the one cross-strategy heterogeneity axis
    (0 = homogeneous, 1 = maximally skewed); each strategy maps it onto
    its own parameter (see the module table). ``alpha`` is the raw
    Dirichlet-concentration override used by the deprecated
    ``FederatedDataset.build(alpha=...)`` back-compat alias — when set,
    the ``dirichlet`` strategy uses it verbatim (bit-identical to the
    historical partition for existing seeds) and ``severity`` is ignored.
    """
    strategy: str = "dirichlet"
    severity: float = 1.0
    alpha: Optional[float] = None


def check_feasible(num_samples: int, num_clients: int,
                   samples_per_client: int) -> None:
    """Raise a clear ValueError when the demanded partition cannot be cut
    from the dataset. (Previously ``dirichlet_partition``'s
    resample-until-non-empty loop would exhaust every class pool and die
    on a cryptic empty-``choice`` error — or spin — when
    ``num_clients * samples_per_client`` approached the dataset size.)"""
    need = num_clients * samples_per_client
    if need > num_samples:
        raise ValueError(
            f"infeasible partition: {num_clients} clients x "
            f"{samples_per_client} samples/client = {need} samples, but the "
            f"dataset has only {num_samples}; at this client size it "
            f"supports at most {num_samples // samples_per_client} clients "
            f"(or {num_samples // num_clients} samples/client for "
            f"{num_clients} clients)")


# --------------------------------------------------------------- severity --

def severity_to_alpha(severity: float) -> float:
    """severity in [0,1] -> Dirichlet concentration, log-interpolated
    between the paper's IID anchor (alpha=1000 at severity 0) and an
    effectively single-class alpha=1e-3 at severity 1."""
    return float(10.0 ** (3.0 - 6.0 * float(severity)))


def severity_to_classes(severity: float, num_classes: int) -> int:
    """severity in [0,1] -> classes held per client for the ``label``
    shard strategy: all C classes at severity 0, single-class at 1."""
    m = int(round(num_classes - float(severity) * (num_classes - 1)))
    return max(1, min(num_classes, m))


# -------------------------------------------------------------- strategies --

def dirichlet_partition(labels: np.ndarray, num_clients: int,
                        samples_per_client: int, alpha: float,
                        seed: int = 0) -> np.ndarray:
    """Returns index array (num_clients, samples_per_client) into the dataset.

    alpha == 0 is handled as the limit: each client draws all its samples
    from one uniformly-chosen class (paper's fully non-IID setting).
    """
    labels = np.asarray(labels)
    check_feasible(len(labels), num_clients, samples_per_client)
    rng = np.random.RandomState(seed)
    classes = np.unique(labels)
    pools = {c: rng.permutation(np.where(labels == c)[0]).tolist() for c in classes}
    out = np.zeros((num_clients, samples_per_client), np.int64)
    for k in range(num_clients):
        if alpha <= 0:
            probs = np.zeros(len(classes))
            probs[rng.randint(len(classes))] = 1.0
        else:
            probs = rng.dirichlet(alpha * np.ones(len(classes)))
        for s in range(samples_per_client):
            # resample class until its pool is non-empty; check_feasible
            # guarantees some pool is, so the redirect below terminates
            for _ in range(100):
                c = classes[rng.choice(len(classes), p=probs)]
                if pools[c]:
                    break
                nonempty = [i for i, cc in enumerate(classes) if pools[cc]]
                probs = np.zeros(len(classes))
                probs[rng.choice(nonempty)] = 1.0
            out[k, s] = pools[c].pop()
    return out


def iid_partition(num_samples: int, num_clients: int, samples_per_client: int,
                  seed: int = 0) -> np.ndarray:
    check_feasible(num_samples, num_clients, samples_per_client)
    rng = np.random.RandomState(seed)
    idx = rng.permutation(num_samples)[: num_clients * samples_per_client]
    return idx.reshape(num_clients, samples_per_client)


def label_partition(labels: np.ndarray, num_clients: int,
                    samples_per_client: int, severity: float,
                    seed: int = 0) -> np.ndarray:
    """Pathological label sharding: client k holds ``m(severity)`` classes
    (rotating shards over the class list), its samples split evenly among
    them. severity 1 -> m = 1 (single-class clients), severity 0 -> m = C
    (every class, near-stratified)."""
    labels = np.asarray(labels)
    check_feasible(len(labels), num_clients, samples_per_client)
    rng = np.random.RandomState(seed)
    classes = np.unique(labels)
    ncls = len(classes)
    m = severity_to_classes(severity, ncls)
    pools = {c: rng.permutation(np.where(labels == c)[0]).tolist()
             for c in classes}
    out = np.zeros((num_clients, samples_per_client), np.int64)
    for k in range(num_clients):
        mine = [classes[(k * m + j) % ncls] for j in range(m)]
        for s in range(samples_per_client):
            c = mine[s % m]
            if not pools[c]:
                # deterministic spill: draw from the fullest remaining pool
                c = max(classes, key=lambda cc: len(pools[cc]))
            out[k, s] = pools[c].pop()
    return out


def uniform_partition(labels: np.ndarray, num_clients: int,
                      samples_per_client: int, severity: float = 0.0,
                      seed: int = 0) -> np.ndarray:
    """Class-stratified equal split — every client cycles through all C
    classes, the most homogeneous control (severity-flat by definition;
    ``severity`` is accepted so the sweep grid is uniform, and ignored)."""
    del severity
    return label_partition(labels, num_clients, samples_per_client, 0.0, seed)


def dirichlet_quantity_partition(labels: np.ndarray, num_clients: int,
                                 samples_per_client: int, severity: float,
                                 seed: int = 0
                                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Quantity skew: label distribution IID, but client *sizes* drawn
    from Dir(beta) over clients (``beta = severity_to_alpha(severity)``),
    floored at 1 sample and capped at ``samples_per_client`` (the padded
    row width). Returns ``(index, sizes)``: rows of ``index`` hold
    ``sizes[k]`` distinct dataset indices, the remaining slots repeat the
    row's first index and are masked out downstream by ``sizes``."""
    labels = np.asarray(labels)
    check_feasible(len(labels), num_clients, samples_per_client)
    rng = np.random.RandomState(seed)
    n = samples_per_client
    beta = severity_to_alpha(severity)
    q = rng.dirichlet(beta * np.ones(num_clients))
    sizes = np.clip(np.round(q * num_clients * n), 1, n).astype(np.int64)
    perm = rng.permutation(len(labels))[: int(sizes.sum())]
    out = np.zeros((num_clients, n), np.int64)
    off = 0
    for k in range(num_clients):
        take = perm[off:off + sizes[k]]
        off += int(sizes[k])
        out[k, :sizes[k]] = take
        out[k, sizes[k]:] = take[0]
    return out, sizes


# ---------------------------------------------------------------- registry --

def _iid_strategy(labels, num_clients, samples_per_client, severity,
                  seed=0):
    del severity
    return iid_partition(len(np.asarray(labels)), num_clients,
                         samples_per_client, seed)


def _dirichlet_strategy(labels, num_clients, samples_per_client, severity,
                        seed=0, alpha=None):
    if alpha is None:
        alpha = severity_to_alpha(severity)
    # alpha >= 1e6 has always meant "IID" at the build() level; keep the
    # exact branch so the deprecated alpha= alias stays bit-identical
    if alpha >= 1e6:
        return iid_partition(len(np.asarray(labels)), num_clients,
                             samples_per_client, seed)
    return dirichlet_partition(labels, num_clients, samples_per_client,
                               alpha, seed)


_REGISTRY: dict = {
    "iid": _iid_strategy,
    "uniform": uniform_partition,
    "label": label_partition,
    "dirichlet": _dirichlet_strategy,
    "dirichlet_quantity": dirichlet_quantity_partition,
}

PARTITIONS = tuple(_REGISTRY)


def register_partition(name: str, fn: Callable) -> None:
    """Register a partition strategy under ``name`` (CLI-visible).

    ``fn(labels, num_clients, samples_per_client, severity, seed)`` must
    return either an ``(num_clients, samples_per_client)`` int index
    array (full-size clients) or an ``(index, sizes)`` pair for
    variable-size clients — ``build_partition`` normalizes both."""
    global PARTITIONS
    _REGISTRY[name] = fn
    PARTITIONS = tuple(_REGISTRY)


def get_partition(name: str) -> Callable:
    """Resolve a registered strategy name to its partition function."""
    if name in _REGISTRY:
        return _REGISTRY[name]
    raise ValueError(f"unknown partition strategy {name!r}; "
                     f"expected one of {PARTITIONS}")


def build_partition(spec: PartitionSpec, labels, *, num_clients: int,
                    samples_per_client: int, seed: int = 0
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Cut the client partition a :class:`PartitionSpec` describes.

    Returns ``(index, sizes)``: ``index`` is (num_clients,
    samples_per_client) int64 into the dataset, ``sizes`` the per-client
    valid-sample counts (== samples_per_client for every strategy except
    ``dirichlet_quantity``; padded slots are masked out by ``sizes``
    downstream, same as the paper's variable-size DERM clients)."""
    if not isinstance(spec, PartitionSpec):
        raise TypeError(f"expected a PartitionSpec, got {type(spec)!r}")
    fn = get_partition(spec.strategy)
    kwargs = {}
    if spec.alpha is not None:
        if spec.strategy != "dirichlet":
            raise ValueError(
                f"PartitionSpec.alpha overrides the Dirichlet concentration "
                f"and applies to the 'dirichlet' strategy only, not "
                f"{spec.strategy!r} — use severity instead")
        kwargs["alpha"] = float(spec.alpha)
    elif not 0.0 <= float(spec.severity) <= 1.0:
        raise ValueError(
            f"PartitionSpec.severity must be in [0, 1], got {spec.severity}")
    out = fn(labels, num_clients, samples_per_client, float(spec.severity),
             seed, **kwargs)
    if isinstance(out, tuple):
        idx, sizes = out
    else:
        idx, sizes = out, np.full((num_clients,), samples_per_client,
                                  np.int64)
    return np.asarray(idx, np.int64), np.asarray(sizes, np.int64)


# ------------------------------------------------------------ skew metric --

def label_dominance(labels, index, sizes=None) -> float:
    """Mean over clients of the fraction its most-common label holds —
    the monotone-in-severity label-skew metric (~1/C for IID clients, 1.0
    for single-class clients). ``sizes`` masks padded slots of
    variable-size partitions."""
    labels = np.asarray(labels)
    index = np.asarray(index)
    k, n = index.shape
    if sizes is None:
        sizes = np.full((k,), n, np.int64)
    doms = []
    for i in range(k):
        lab = labels[index[i, : sizes[i]]]
        _, counts = np.unique(lab, return_counts=True)
        doms.append(counts.max() / float(sizes[i]))
    return float(np.mean(doms))
