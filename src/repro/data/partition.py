"""Client dataset partitioning (paper Sec 4.1).

Non-IID partitions use the Dirichlet sampling process of Hsu et al. 2019:
for each client, draw a categorical distribution q ~ Dir(alpha * prior) and
sample that client's examples from the class-conditional pools. alpha -> 0
gives single-class clients (the paper's "non-IID", alpha = 0); alpha -> inf
gives IID clients (paper uses alpha = 1000 as "IID").
"""
from __future__ import annotations

import numpy as np


def dirichlet_partition(labels: np.ndarray, num_clients: int,
                        samples_per_client: int, alpha: float,
                        seed: int = 0) -> np.ndarray:
    """Returns index array (num_clients, samples_per_client) into the dataset.

    alpha == 0 is handled as the limit: each client draws all its samples
    from one uniformly-chosen class (paper's fully non-IID setting).
    """
    rng = np.random.RandomState(seed)
    labels = np.asarray(labels)
    classes = np.unique(labels)
    pools = {c: rng.permutation(np.where(labels == c)[0]).tolist() for c in classes}
    out = np.zeros((num_clients, samples_per_client), np.int64)
    for k in range(num_clients):
        if alpha <= 0:
            probs = np.zeros(len(classes))
            probs[rng.randint(len(classes))] = 1.0
        else:
            probs = rng.dirichlet(alpha * np.ones(len(classes)))
        for s in range(samples_per_client):
            # resample class until its pool is non-empty (finite dataset)
            for _ in range(100):
                c = classes[rng.choice(len(classes), p=probs)]
                if pools[c]:
                    break
                nonempty = [i for i, cc in enumerate(classes) if pools[cc]]
                probs = np.zeros(len(classes))
                probs[rng.choice(nonempty)] = 1.0
            out[k, s] = pools[c].pop()
    return out


def iid_partition(num_samples: int, num_clients: int, samples_per_client: int,
                  seed: int = 0) -> np.ndarray:
    rng = np.random.RandomState(seed)
    idx = rng.permutation(num_samples)[: num_clients * samples_per_client]
    return idx.reshape(num_clients, samples_per_client)
