"""Federated data pipeline: owns the client partition and emits per-round
batches in the (K, n, ...) layout expected by repro.core.fed_sim, or the
flat (N, ...) layout expected by the pod-scale fused step.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import augment, partition


class FederatedDataset:
    """Wraps (data, labels) + a client partition.

    data: dict of np arrays with leading N (e.g. {"images": ...} or
    {"tokens": ...}); client_index: (num_clients, samples_per_client) int.
    """

    def __init__(self, data: Dict[str, np.ndarray], labels: np.ndarray,
                 client_index: np.ndarray, vocab: int = 0):
        self.data = data
        self.labels = labels
        self.client_index = client_index
        self.vocab = vocab

    @property
    def num_clients(self) -> int:
        return self.client_index.shape[0]

    @property
    def samples_per_client(self) -> int:
        return self.client_index.shape[1]

    @classmethod
    def build(cls, data, labels, *, num_clients, samples_per_client,
              alpha: float, seed: int = 0, vocab: int = 0):
        if alpha >= 1e6:
            idx = partition.iid_partition(len(labels), num_clients,
                                          samples_per_client, seed)
        else:
            idx = partition.dirichlet_partition(labels, num_clients,
                                                samples_per_client, alpha, seed)
        return cls(data, labels, idx, vocab=vocab)

    # ------------------------------------------------------------- rounds --

    def round_batch(self, key, clients_per_round: int):
        """Sample K clients, gather raw samples, build two augmented views.

        Returns (client_data pytree (K, n, ...), client_sizes (K,)).
        """
        k_sel, k_aug = jax.random.split(key)
        sel = jax.random.choice(k_sel, self.num_clients, (clients_per_round,),
                                replace=False)
        sel = np.asarray(sel)
        idx = self.client_index[sel]                          # (K, n)
        k, n = idx.shape
        out = {}
        if "images" in self.data:
            imgs = jnp.asarray(self.data["images"][idx.reshape(-1)])
            keys = jax.random.split(k_aug, imgs.shape[0])
            v1, v2 = jax.vmap(augment.two_views_image)(keys, imgs)
            out["v1"] = v1.reshape(k, n, *v1.shape[1:])
            out["v2"] = v2.reshape(k, n, *v2.shape[1:])
        if "tokens" in self.data:
            toks = jnp.asarray(self.data["tokens"][idx.reshape(-1)])
            keys = jax.random.split(k_aug, toks.shape[0])
            v1, v2 = jax.vmap(
                lambda kk, tt: augment.two_views_tokens(kk, tt, self.vocab)
            )(keys, toks)
            out["v1"] = v1.reshape(k, n, *v1.shape[1:])
            out["v2"] = v2.reshape(k, n, *v2.shape[1:])
        sizes = jnp.full((k,), n, jnp.int32)
        return out, sizes

    def flat_round_batch(self, key, clients_per_round: int):
        """Same sampling, flattened to (K*n, ...) for the fused pod step."""
        batch, sizes = self.round_batch(key, clients_per_round)
        flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), batch)
        return flat, sizes
