"""Federated data pipeline: owns the client partition and emits per-round
batches in the (K, n, ...) layout expected by repro.core.fed_sim, or the
flat (N, ...) layout expected by the pod-scale fused step.
"""
from __future__ import annotations

import warnings
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import augment
from repro.data import partition as partition_lib


class FederatedDataset:
    """Wraps (data, labels) + a client partition.

    data: dict of np arrays with leading N (e.g. {"images": ...} or
    {"tokens": ...}); client_index: (num_clients, samples_per_client) int;
    client_sizes: (num_clients,) valid-sample counts — rows of
    client_index beyond a client's size are padding (masked out of every
    stats/loss computation downstream), which is how quantity-skewed
    partitions (``PartitionSpec("dirichlet_quantity", ...)``) and the
    paper's variable-size DERM clients are carried.
    """

    def __init__(self, data: Dict[str, np.ndarray], labels: np.ndarray,
                 client_index: np.ndarray, vocab: int = 0,
                 client_sizes: Optional[np.ndarray] = None):
        self.data = data
        self.labels = labels
        self.client_index = client_index
        self.vocab = vocab
        if client_sizes is None:
            client_sizes = np.full((client_index.shape[0],),
                                   client_index.shape[1], np.int64)
        self.client_sizes = np.asarray(client_sizes, np.int64)
        if self.client_sizes.shape != (client_index.shape[0],):
            raise ValueError(
                f"client_sizes shape {self.client_sizes.shape} does not "
                f"match {client_index.shape[0]} clients")
        self._samplers: Dict[int, object] = {}
        self._staged = None      # device-resident (data, index, sizes)

    @property
    def num_clients(self) -> int:
        return self.client_index.shape[0]

    @property
    def samples_per_client(self) -> int:
        return self.client_index.shape[1]

    @classmethod
    def build(cls, data, labels, *, num_clients, samples_per_client,
              partition=None, alpha: float = None,
              seed: int = 0, vocab: int = 0):
        """The one construction path: cut the client partition a
        :class:`repro.data.partition.PartitionSpec` describes.

        ``partition=PartitionSpec(strategy, severity)`` selects any
        registered strategy (iid / uniform / label / dirichlet /
        dirichlet_quantity, see :mod:`repro.data.partition`).

        ``alpha=`` is the deprecated pre-PartitionSpec spelling; it maps
        onto ``PartitionSpec("dirichlet", alpha=alpha)`` (alpha >= 1e6
        still means IID) and produces a bit-identical client assignment
        for existing seeds — tested ``==`` — so old configs, regression
        baselines, and resume streams are unaffected.
        """
        if partition is not None and alpha is not None:
            raise ValueError(
                "pass partition=PartitionSpec(...) or the deprecated "
                "alpha=, not both")
        if partition is None:
            if alpha is None:
                raise TypeError(
                    "FederatedDataset.build needs "
                    "partition=PartitionSpec(...) (or the deprecated "
                    "alpha=)")
            warnings.warn(
                "FederatedDataset.build(alpha=...) is deprecated; use "
                "partition=PartitionSpec('dirichlet', alpha=alpha) or a "
                "severity-mapped PartitionSpec",
                DeprecationWarning, stacklevel=2)
            partition = partition_lib.PartitionSpec(
                "dirichlet", alpha=float(alpha))
        idx, sizes = partition_lib.build_partition(
            partition, labels, num_clients=num_clients,
            samples_per_client=samples_per_client, seed=seed)
        return cls(data, labels, idx, vocab=vocab, client_sizes=sizes)

    # ------------------------------------------------------------- rounds --

    def _two_views_keyed(self, keys, gathered, k: int, n: int):
        """Augment gathered (K*n, ...) raw samples into stacked two-view
        batches (K, n, ...) with explicit per-sample keys (K*n, 2). The
        single source of truth for the view pipeline, shared by the host
        path (round_batch), the in-scan path (make_round_sampler), and the
        chunked path (make_streaming_sampler — which slices the SAME key
        array, so a chunk's views equal the materialized cohort's)."""
        out = {}
        if "images" in gathered:
            v1, v2 = jax.vmap(augment.two_views_image)(keys, gathered["images"])
            out["v1"] = v1.reshape(k, n, *v1.shape[1:])
            out["v2"] = v2.reshape(k, n, *v2.shape[1:])
        if "tokens" in gathered:
            v1, v2 = jax.vmap(
                lambda kk, tt: augment.two_views_tokens(kk, tt, self.vocab)
            )(keys, gathered["tokens"])
            out["v1"] = v1.reshape(k, n, *v1.shape[1:])
            out["v2"] = v2.reshape(k, n, *v2.shape[1:])
        return out

    def _two_views(self, k_aug, gathered, k: int, n: int):
        return self._two_views_keyed(jax.random.split(k_aug, k * n),
                                     gathered, k, n)

    def round_batch(self, key, clients_per_round: int):
        """Sample K clients, gather raw samples, build two augmented views.

        Returns (client_data pytree (K, n, ...), client_sizes (K,)).
        Gathers on the HOST — only the sampled cohort touches the device,
        so this works for corpora larger than device memory. The engine's
        in-scan twin is ``make_round_sampler`` (same math, tested equal)."""
        k_sel, k_aug = jax.random.split(key)
        sel = np.asarray(jax.random.choice(
            k_sel, self.num_clients, (clients_per_round,), replace=False))
        idx = self.client_index[sel]                          # (K, n)
        k, n = idx.shape
        gathered = {kk: jnp.asarray(v[idx.reshape(-1)])
                    for kk, v in self.data.items()}
        return self._two_views(k_aug, gathered, k, n), \
            jnp.asarray(self.client_sizes[sel], jnp.int32)

    def flat_round_batch(self, key, clients_per_round: int):
        """Same sampling, flattened to (K*n, ...) for the fused pod step."""
        batch, sizes = self.round_batch(key, clients_per_round)
        flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), batch)
        return flat, sizes

    # ------------------------------------------------- in-scan sampling --

    def _stage(self):
        """Device-resident (data, client_index, client_sizes), staged once
        per dataset and shared by every in-scan sampler."""
        if self._staged is None:
            self._staged = ({k: jnp.asarray(v) for k, v in self.data.items()},
                            jnp.asarray(self.client_index),
                            jnp.asarray(self.client_sizes, jnp.int32))
        return self._staged

    def make_round_sampler(self, clients_per_round: int):
        """A jax-traceable ``sampler(k_sel, k_aug) -> (batch, sizes)``.

        The whole dataset and client index are staged onto device once per
        dataset (cached, shared by all samplers); the returned closure does
        cohort selection, gather, and the two-view augmentation with pure
        jax ops, so it can run INSIDE a scan body
        (repro.core.round_engine). Assumes the dataset fits in device
        memory — the paper's decentralized corpora are small; for a corpus
        larger than device memory, use the host-gathering ``round_batch``.
        """
        if clients_per_round in self._samplers:
            return self._samplers[clients_per_round]
        data, cindex, csizes = self._stage()
        num_clients, n = self.num_clients, self.samples_per_client
        k_round = clients_per_round

        def sampler(k_sel, k_aug):
            sel = jax.random.choice(k_sel, num_clients, (k_round,),
                                    replace=False)
            idx = cindex[sel].reshape(-1)                    # (K*n,)
            gathered = {kk: v[idx] for kk, v in data.items()}
            out = self._two_views(k_aug, gathered, k_round, n)
            return out, csizes[sel]

        self._samplers[clients_per_round] = sampler
        return sampler

    def make_async_round_sampler(self, clients_per_round: int, latency=None):
        """``make_round_sampler``'s semi-synchronous twin: a jax-traceable
        ``sampler(k_sel, k_aug) -> (batch, sizes, delays)`` for the
        buffered engine (``EngineConfig.async_k``).

        ``delays`` (K,) int32 are per-contribution arrival delays in
        scheduler ticks, drawn from the ``latency`` model
        (:mod:`repro.data.latency`) on the TRUE sampled client ids — a
        heavy-tail model's stragglers therefore persist across rounds.
        The delay key is a ``fold_in`` salt off ``k_sel`` (no extra
        split), so cohort selection and augmentation are bit-identical to
        ``make_round_sampler`` for the same keys: zero-latency async runs
        see exactly the sync engine's batches.
        """
        from repro.data import latency as latency_lib
        model = latency_lib.resolve_latency(latency)
        data, cindex, csizes = self._stage()
        num_clients, n = self.num_clients, self.samples_per_client
        k_round = clients_per_round

        def sampler(k_sel, k_aug):
            sel = jax.random.choice(k_sel, num_clients, (k_round,),
                                    replace=False)
            idx = cindex[sel].reshape(-1)                    # (K*n,)
            gathered = {kk: v[idx] for kk, v in data.items()}
            out = self._two_views(k_aug, gathered, k_round, n)
            sizes = csizes[sel]
            dk = jax.random.fold_in(k_sel, latency_lib._LATENCY_SALT)
            delays = latency_lib.sample_delays(model, dk,
                                               sel.astype(jnp.int32))
            return out, sizes, delays

        sampler.latency = model
        sampler.clients_per_round = k_round
        return sampler

    def make_streaming_sampler(self, clients_per_round: int,
                               cohort_chunk: int):
        """A chunkable sampler for the streaming engine path
        (``EngineConfig.cohort_chunk``): ``prepare(k_sel, k_aug)`` does the
        O(K)-scalar per-round work ONCE (cohort selection indices + the
        K*n per-sample augmentation keys — hoisted out of the chunk scan),
        and ``sample_chunk(state, c)`` gathers and augments ONLY chunk
        ``c``, so a round never materializes more than ``cohort_chunk``
        clients of batch data. Chunks concatenate to exactly the cohort
        ``make_round_sampler`` would emit for the same keys (same
        selection, same per-sample augmentation keys — tested), which is
        what makes streaming-vs-materialized equivalence checkable.
        """
        from repro.hierarchy.streaming import StreamingSampler
        if cohort_chunk < 1 or clients_per_round % cohort_chunk:
            raise ValueError(
                f"clients_per_round={clients_per_round} does not divide "
                f"into chunks of {cohort_chunk}")
        data, cindex, csizes = self._stage()
        num_clients, n = self.num_clients, self.samples_per_client
        k_round, chunk = clients_per_round, cohort_chunk

        def prepare(k_sel, k_aug):
            sel = jax.random.choice(k_sel, num_clients, (k_round,),
                                    replace=False)
            return sel, jax.random.split(k_aug, k_round * n)

        def sample_chunk(state, c):
            sel, aug_keys = state
            sel_c = jax.lax.dynamic_slice(sel, (c * chunk,), (chunk,))
            idx = cindex[sel_c].reshape(-1)                  # (chunk*n,)
            gathered = {kk: v[idx] for kk, v in data.items()}
            keys = jax.lax.dynamic_slice(aug_keys, (c * chunk * n, 0),
                                         (chunk * n, 2))
            batch = self._two_views_keyed(keys, gathered, chunk, n)
            return batch, csizes[sel_c]

        def cohort_sizes(k_sel):
            # recomputes the cohort selection (same key -> same choice as
            # prepare), so variable-size clients report true sizes here too
            sel = jax.random.choice(k_sel, num_clients, (k_round,),
                                    replace=False)
            return csizes[sel]

        return StreamingSampler(k_round, chunk, prepare, sample_chunk,
                                cohort_sizes)
