"""Federated data pipeline: owns the client partition and emits per-round
batches in the (K, n, ...) layout expected by repro.core.fed_sim, or the
flat (N, ...) layout expected by the pod-scale fused step.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import augment, partition


class FederatedDataset:
    """Wraps (data, labels) + a client partition.

    data: dict of np arrays with leading N (e.g. {"images": ...} or
    {"tokens": ...}); client_index: (num_clients, samples_per_client) int.
    """

    def __init__(self, data: Dict[str, np.ndarray], labels: np.ndarray,
                 client_index: np.ndarray, vocab: int = 0):
        self.data = data
        self.labels = labels
        self.client_index = client_index
        self.vocab = vocab
        self._samplers: Dict[int, object] = {}
        self._staged = None      # device-resident (data, client_index)

    @property
    def num_clients(self) -> int:
        return self.client_index.shape[0]

    @property
    def samples_per_client(self) -> int:
        return self.client_index.shape[1]

    @classmethod
    def build(cls, data, labels, *, num_clients, samples_per_client,
              alpha: float, seed: int = 0, vocab: int = 0):
        if alpha >= 1e6:
            idx = partition.iid_partition(len(labels), num_clients,
                                          samples_per_client, seed)
        else:
            idx = partition.dirichlet_partition(labels, num_clients,
                                                samples_per_client, alpha, seed)
        return cls(data, labels, idx, vocab=vocab)

    # ------------------------------------------------------------- rounds --

    def _two_views_keyed(self, keys, gathered, k: int, n: int):
        """Augment gathered (K*n, ...) raw samples into stacked two-view
        batches (K, n, ...) with explicit per-sample keys (K*n, 2). The
        single source of truth for the view pipeline, shared by the host
        path (round_batch), the in-scan path (make_round_sampler), and the
        chunked path (make_streaming_sampler — which slices the SAME key
        array, so a chunk's views equal the materialized cohort's)."""
        out = {}
        if "images" in gathered:
            v1, v2 = jax.vmap(augment.two_views_image)(keys, gathered["images"])
            out["v1"] = v1.reshape(k, n, *v1.shape[1:])
            out["v2"] = v2.reshape(k, n, *v2.shape[1:])
        if "tokens" in gathered:
            v1, v2 = jax.vmap(
                lambda kk, tt: augment.two_views_tokens(kk, tt, self.vocab)
            )(keys, gathered["tokens"])
            out["v1"] = v1.reshape(k, n, *v1.shape[1:])
            out["v2"] = v2.reshape(k, n, *v2.shape[1:])
        return out

    def _two_views(self, k_aug, gathered, k: int, n: int):
        return self._two_views_keyed(jax.random.split(k_aug, k * n),
                                     gathered, k, n)

    def round_batch(self, key, clients_per_round: int):
        """Sample K clients, gather raw samples, build two augmented views.

        Returns (client_data pytree (K, n, ...), client_sizes (K,)).
        Gathers on the HOST — only the sampled cohort touches the device,
        so this works for corpora larger than device memory. The engine's
        in-scan twin is ``make_round_sampler`` (same math, tested equal)."""
        k_sel, k_aug = jax.random.split(key)
        sel = np.asarray(jax.random.choice(
            k_sel, self.num_clients, (clients_per_round,), replace=False))
        idx = self.client_index[sel]                          # (K, n)
        k, n = idx.shape
        gathered = {kk: jnp.asarray(v[idx.reshape(-1)])
                    for kk, v in self.data.items()}
        return self._two_views(k_aug, gathered, k, n), \
            jnp.full((k,), n, jnp.int32)

    def flat_round_batch(self, key, clients_per_round: int):
        """Same sampling, flattened to (K*n, ...) for the fused pod step."""
        batch, sizes = self.round_batch(key, clients_per_round)
        flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), batch)
        return flat, sizes

    # ------------------------------------------------- in-scan sampling --

    def _stage(self):
        """Device-resident (data, client_index), staged once per dataset
        and shared by every in-scan sampler."""
        if self._staged is None:
            self._staged = ({k: jnp.asarray(v) for k, v in self.data.items()},
                            jnp.asarray(self.client_index))
        return self._staged

    def make_round_sampler(self, clients_per_round: int):
        """A jax-traceable ``sampler(k_sel, k_aug) -> (batch, sizes)``.

        The whole dataset and client index are staged onto device once per
        dataset (cached, shared by all samplers); the returned closure does
        cohort selection, gather, and the two-view augmentation with pure
        jax ops, so it can run INSIDE a scan body
        (repro.core.round_engine). Assumes the dataset fits in device
        memory — the paper's decentralized corpora are small; for a corpus
        larger than device memory, use the host-gathering ``round_batch``.
        """
        if clients_per_round in self._samplers:
            return self._samplers[clients_per_round]
        data, cindex = self._stage()
        num_clients, n = self.num_clients, self.samples_per_client
        k_round = clients_per_round

        def sampler(k_sel, k_aug):
            sel = jax.random.choice(k_sel, num_clients, (k_round,),
                                    replace=False)
            idx = cindex[sel].reshape(-1)                    # (K*n,)
            gathered = {kk: v[idx] for kk, v in data.items()}
            out = self._two_views(k_aug, gathered, k_round, n)
            sizes = jnp.full((k_round,), n, jnp.int32)
            return out, sizes

        self._samplers[clients_per_round] = sampler
        return sampler

    def make_async_round_sampler(self, clients_per_round: int, latency=None):
        """``make_round_sampler``'s semi-synchronous twin: a jax-traceable
        ``sampler(k_sel, k_aug) -> (batch, sizes, delays)`` for the
        buffered engine (``EngineConfig.async_k``).

        ``delays`` (K,) int32 are per-contribution arrival delays in
        scheduler ticks, drawn from the ``latency`` model
        (:mod:`repro.data.latency`) on the TRUE sampled client ids — a
        heavy-tail model's stragglers therefore persist across rounds.
        The delay key is a ``fold_in`` salt off ``k_sel`` (no extra
        split), so cohort selection and augmentation are bit-identical to
        ``make_round_sampler`` for the same keys: zero-latency async runs
        see exactly the sync engine's batches.
        """
        from repro.data import latency as latency_lib
        model = latency_lib.resolve_latency(latency)
        data, cindex = self._stage()
        num_clients, n = self.num_clients, self.samples_per_client
        k_round = clients_per_round

        def sampler(k_sel, k_aug):
            sel = jax.random.choice(k_sel, num_clients, (k_round,),
                                    replace=False)
            idx = cindex[sel].reshape(-1)                    # (K*n,)
            gathered = {kk: v[idx] for kk, v in data.items()}
            out = self._two_views(k_aug, gathered, k_round, n)
            sizes = jnp.full((k_round,), n, jnp.int32)
            dk = jax.random.fold_in(k_sel, latency_lib._LATENCY_SALT)
            delays = latency_lib.sample_delays(model, dk,
                                               sel.astype(jnp.int32))
            return out, sizes, delays

        sampler.latency = model
        sampler.clients_per_round = k_round
        return sampler

    def make_streaming_sampler(self, clients_per_round: int,
                               cohort_chunk: int):
        """A chunkable sampler for the streaming engine path
        (``EngineConfig.cohort_chunk``): ``prepare(k_sel, k_aug)`` does the
        O(K)-scalar per-round work ONCE (cohort selection indices + the
        K*n per-sample augmentation keys — hoisted out of the chunk scan),
        and ``sample_chunk(state, c)`` gathers and augments ONLY chunk
        ``c``, so a round never materializes more than ``cohort_chunk``
        clients of batch data. Chunks concatenate to exactly the cohort
        ``make_round_sampler`` would emit for the same keys (same
        selection, same per-sample augmentation keys — tested), which is
        what makes streaming-vs-materialized equivalence checkable.
        """
        from repro.hierarchy.streaming import StreamingSampler
        if cohort_chunk < 1 or clients_per_round % cohort_chunk:
            raise ValueError(
                f"clients_per_round={clients_per_round} does not divide "
                f"into chunks of {cohort_chunk}")
        data, cindex = self._stage()
        num_clients, n = self.num_clients, self.samples_per_client
        k_round, chunk = clients_per_round, cohort_chunk

        def prepare(k_sel, k_aug):
            sel = jax.random.choice(k_sel, num_clients, (k_round,),
                                    replace=False)
            return sel, jax.random.split(k_aug, k_round * n)

        def sample_chunk(state, c):
            sel, aug_keys = state
            sel_c = jax.lax.dynamic_slice(sel, (c * chunk,), (chunk,))
            idx = cindex[sel_c].reshape(-1)                  # (chunk*n,)
            gathered = {kk: v[idx] for kk, v in data.items()}
            keys = jax.lax.dynamic_slice(aug_keys, (c * chunk * n, 0),
                                         (chunk * n, 2))
            batch = self._two_views_keyed(keys, gathered, chunk, n)
            return batch, jnp.full((chunk,), n, jnp.int32)

        def cohort_sizes(k_sel):
            return jnp.full((k_round,), n, jnp.int32)

        return StreamingSampler(k_round, chunk, prepare, sample_chunk,
                                cohort_sizes)
