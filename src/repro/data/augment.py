"""Two-view augmentations (paper App. B: BYOL augmentations minus blur for
images; token analogues for sequence modalities).

All augmentations are stateless jax functions keyed by an explicit PRNGKey —
the paper's footnote 3 attributes its centralized/federated gap to stateful
vs stateless RNG; we are stateless everywhere by construction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ------------------------------------------------------------------ images --

def augment_image(key, img, crop_frac: float = 0.8):
    """Random crop-and-resize (nearest), flip, color jitter. img: (H,W,C)."""
    kc, kf, kb, kcon = jax.random.split(key, 4)
    h, w, c = img.shape
    ch, cw = int(h * crop_frac), int(w * crop_frac)
    top = jax.random.randint(kc, (), 0, h - ch + 1)
    left = jax.random.randint(kc, (), 0, w - cw + 1)
    crop = jax.lax.dynamic_slice(img, (top, left, 0), (ch, cw, c))
    # nearest-neighbour resize back to (h, w)
    ridx = (jnp.arange(h) * ch // h).astype(jnp.int32)
    cidx = (jnp.arange(w) * cw // w).astype(jnp.int32)
    out = crop[ridx][:, cidx]
    out = jnp.where(jax.random.bernoulli(kf), out[:, ::-1], out)
    brightness = 1.0 + 0.4 * (jax.random.uniform(kb) - 0.5)
    contrast = 1.0 + 0.4 * (jax.random.uniform(kcon) - 0.5)
    mean = out.mean()
    return jnp.clip((out - mean) * contrast + mean * brightness, 0.0, 1.0)


def two_views_image(key, img):
    k1, k2 = jax.random.split(key)
    return augment_image(k1, img), augment_image(k2, img)


# ------------------------------------------------------------------ tokens --

def augment_tokens(key, tokens, vocab: int, mask_token: int = 0,
                   mask_prob: float = 0.15, crop_prob: float = 0.5,
                   max_crop_frac: float = 0.25):
    """Span-mask + random-crop-with-roll: the token analogue of crop+jitter."""
    km, kc, ks, kr = jax.random.split(key, 4)
    s = tokens.shape[-1]
    masked = jnp.where(jax.random.bernoulli(km, mask_prob, tokens.shape),
                       jnp.asarray(mask_token, tokens.dtype), tokens)
    # random circular shift (crop analogue; keeps shape static)
    do_crop = jax.random.bernoulli(kc, crop_prob)
    shift = jax.random.randint(ks, (), 0, max(1, int(s * max_crop_frac)))
    rolled = jnp.roll(masked, shift, axis=-1)
    return jnp.where(do_crop, rolled, masked)


def two_views_tokens(key, tokens, vocab: int, **kw):
    k1, k2 = jax.random.split(key)
    return (augment_tokens(k1, tokens, vocab, **kw),
            augment_tokens(k2, tokens, vocab, **kw))
