"""Pluggable client->server communication channels.

DCCO's premise is that clients ship *aggregate encoding statistics* rather
than raw samples (paper Eq. 3, Fig. 2), yet an idealized simulation models
that uplink as a free, lossless sum. A :class:`Channel` makes the wire
explicit: every per-client payload — phase-1 statistics, phase-2 parameter
deltas, FedAvg updates — flows through

    begin_round  ->  encode_decode (per client)  ->  weighted sum
                 ->  post_aggregate (server side)

with bytes-on-the-wire accounting. All channel math is pure traced jax
driven by an explicit PRNG key, so the dispatch is resolved at trace time
and the per-round work compiles INSIDE the engine's ``lax.scan`` — no
per-round Python cost.

Implementations:

  DenseChannel      — identity wire; bit-exact with the un-channeled paths
                      (tested), the baseline every other channel is
                      measured against.
  QuantizedChannel  — int-``bits`` stochastic-rounding encode/decode with
                      per-client per-tensor scales (repro.comm.quantize;
                      optionally the fused Pallas kernel).
  DPGaussianChannel — per-client L2 clipping + calibrated Gaussian noise on
                      the aggregate (uniform client weights — size-weighted
                      aggregation would leak private client sizes), with a
                      zCDP epsilon accountant.
  DropoutChannel    — Bernoulli client dropout with mask-renormalized
                      aggregation, so Eq. 3's normalizer runs over the
                      surviving cohort only; at p=0 it is bit-identical to
                      DenseChannel.

Aggregation semantics: ``aggregate(ctx, tree_k, phase)`` consumes a pytree
of stacked per-client payloads (leading axis K) and returns the weighted
average the protocol expects — for DenseChannel exactly
``cco.weighted_average_stats`` / the delta ``tensordot`` of fed_sim.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.accountant import GaussianAccountant
from repro.comm.quantize import (payload_bytes as quant_payload_bytes,
                                 qmax_for_bits, quant_dequant_payload)

F32 = jnp.float32

# salts folded into the round key so the stats / update / variate phases
# draw independent randomness from one per-round channel key. "variate" is
# the SCAFFOLD control-variate uplink (repro.server.drift): per-client
# variate deltas are payloads like any other, so quantization / DP noise /
# dropout compose with drift correction and the bytes are accounted.
PHASE_SALT = {"stats": 0x57A75, "update": 0x0BDA7E, "variate": 0x5CAF0}


class ChannelContext(NamedTuple):
    """Per-round channel state, computed once by ``begin_round``."""
    key: jnp.ndarray               # per-round payload randomness
    mask: jnp.ndarray              # (K,) f32 — 1 for participating clients
    weights: jnp.ndarray           # (K,) f32 — normalized agg weights
    num_participants: jnp.ndarray  # f32 scalar = sum(mask)


def _leaf_keys(key, phase: str, num_leaves: int):
    return jax.random.split(jax.random.fold_in(key, PHASE_SALT[phase]),
                            max(num_leaves, 1))


class Channel:
    """Base channel: full participation, size-weighted lossless aggregation.

    Subclasses override any of ``begin_round`` (participation + weights),
    ``encode_decode`` (the per-client wire transform), ``post_aggregate``
    (server-side processing of the aggregate), and ``payload_bytes``
    (per-client wire cost of one payload).
    """

    name = "dense"
    # whether the engine may compute phase-1 aggregate stats from the
    # flattened cohort (the cco_stats kernel path) instead of per-client
    # payloads — only lossless, size-weighted, full-participation channels
    # qualify.
    supports_flat_stats = True
    # an *ideal* channel is a lossless identity wire with size-weighted
    # aggregation — exactly the un-channeled math. The hierarchical
    # aggregator (repro.hierarchy) collapses a tree of ideal hops to the
    # flat sum (bit-identical by Eq.-3 linearity). Deliberately False on
    # the base class: a custom subclass that forgets to think about it
    # only loses the fast path, never correctness.
    ideal = False
    # whether begin_round always returns an all-ones participation mask;
    # False lets the hierarchy know it must renormalize weights over the
    # surviving mass when this channel runs a hop.
    full_participation = True

    def begin_round(self, key, client_sizes) -> ChannelContext:
        k = client_sizes.shape[0]
        s = client_sizes.astype(F32)
        return ChannelContext(key, jnp.ones((k,), F32), s / jnp.sum(s),
                              jnp.asarray(float(k), F32))

    def encode_decode(self, ctx: ChannelContext, tree_k, phase: str):
        return tree_k

    def post_aggregate(self, ctx: ChannelContext, tree, phase: str):
        return tree

    def aggregate(self, ctx: ChannelContext, tree_k, phase: str):
        """Weighted average of per-client payloads through the wire."""
        dec = self.encode_decode(ctx, tree_k, phase)
        agg = jax.tree.map(
            lambda v: jnp.tensordot(ctx.weights, v, axes=1), dec)
        return self.post_aggregate(ctx, agg, phase)

    # ------------------------------------------------------ partial folds
    def local_fold(self, ctx_local, dec_tree, phase: str, *,
                   num_shards: int = 1):
        """Fold one shard's already-decoded payloads into its partial
        aggregate (the sharded-cohort path: the psum over shards of these
        partials is the server aggregate). ``ctx_local`` holds the shard's
        slice of the participation mask / weights plus a shard-folded key;
        ``num_shards`` is the static mesh size, which hierarchical
        aggregators use to place their edges on shards. The base fold is
        exactly the weighted sum the un-hooked path computed, so existing
        sharded trajectories are bit-identical."""
        del phase, num_shards
        return jax.tree.map(
            lambda v: jnp.tensordot(ctx_local.weights, v, axes=1), dec_tree)

    def chunk_fold(self, ctx: ChannelContext, tree_chunk, phase: str,
                   chunk_index, chunk_weights):
        """Partial aggregate of one cohort chunk (the streaming engine,
        repro.hierarchy.streaming): encode/decode the chunk's per-client
        payloads with chunk-folded randomness and fold them with the
        chunk's slice of the GLOBAL aggregation weights. Summing the
        partials over all chunks and applying ``post_aggregate`` once
        equals ``aggregate`` on the materialized cohort up to float
        regrouping (exactly, in math, by Eq.-3 linearity)."""
        ctx_c = ctx._replace(key=jax.random.fold_in(ctx.key, chunk_index))
        dec = self.encode_decode(ctx_c, tree_chunk, phase)
        return jax.tree.map(
            lambda v: jnp.tensordot(chunk_weights, v, axes=1), dec)

    # ----------------------------------------------------------- accounting
    def payload_bytes(self, tree) -> float:
        """Static per-client uplink bytes for one payload pytree (shapes of
        one client's slice — equivalently, of the aggregate)."""
        return float(sum(4.0 * int(np.prod(x.shape))
                         for x in jax.tree.leaves(tree)))

    def round_bytes(self, ctx: ChannelContext, payload_template):
        """Traced per-round uplink bytes: participants x payload size."""
        return ctx.num_participants * self.payload_bytes(payload_template)

    def finalize_rounds(self, num_rounds: int) -> None:
        """Host-side hook after a run completes (privacy accounting)."""

    def __repr__(self) -> str:
        return f"{type(self).__name__}()"


class DenseChannel(Channel):
    """Identity wire — f32 payloads, lossless, full participation."""

    ideal = True


class QuantizedChannel(Channel):
    """Stochastic-rounding integer quantization of every payload tensor.

    ``kernel``: "off" (pure jnp), "pallas" (fused Pallas kernel; compiles
    on accelerators), or "interpret" (kernel via the interpreter — exact,
    any backend). All three are bit-identical given the same key.
    """

    name = "quantized"
    supports_flat_stats = False

    def __init__(self, bits: int = 8, kernel: str = "off"):
        qmax_for_bits(bits)                  # validate eagerly
        if kernel not in ("off", "pallas", "interpret"):
            raise ValueError(f"unknown quantization kernel mode {kernel!r}")
        self.bits = bits
        self.kernel = kernel

    def encode_decode(self, ctx, tree_k, phase):
        impl = "jnp" if self.kernel == "off" else self.kernel
        if impl == "pallas" and jax.default_backend() == "cpu":
            # same policy as the engine's stats_kernel="pallas": fall back
            # to the (exact) interpreter so the flag works everywhere
            impl = "interpret"
        # one fused pass over the whole payload tree — same wire semantics
        # (per-client per-tensor scales) as quantizing leaf by leaf, but
        # ONE uniform draw + ONE formula/kernel pass instead of a threefry
        # dispatch per leaf (the int8/int4 wall-clock regression was this
        # per-leaf loop over ~50 parameter leaves every phase)
        key = jax.random.fold_in(ctx.key, PHASE_SALT[phase])
        return quant_dequant_payload(key, tree_k, self.bits, impl)

    def payload_bytes(self, tree) -> float:
        return float(sum(
            quant_payload_bytes(int(np.prod(x.shape)), self.bits)
            for x in jax.tree.leaves(tree)))

    def __repr__(self) -> str:
        return f"QuantizedChannel(bits={self.bits}, kernel={self.kernel!r})"


class DPGaussianChannel(Channel):
    """Differentially-private aggregation: clip each client's payload to
    L2 norm ``clip_norm``, average with uniform weights, add Gaussian noise
    of std ``noise_multiplier * clip_norm / K`` to the mean.

    Noise is applied to the phases in ``noise_phases`` (default: the
    phase-1 statistics, the setting of Ning et al. 2021); clipping bounds
    per-client sensitivity in every phase. The zCDP accountant advances one
    step per noised aggregate via ``finalize_rounds``.
    """

    name = "dp_gaussian"
    supports_flat_stats = False

    def __init__(self, noise_multiplier: float = 1.0, clip_norm: float = 1.0,
                 delta: float = 1e-5,
                 noise_phases: Tuple[str, ...] = ("stats",)):
        unknown = set(noise_phases) - set(PHASE_SALT)
        if unknown:
            raise ValueError(f"unknown noise_phases {sorted(unknown)}; "
                             f"valid: {sorted(PHASE_SALT)}")
        self.noise_multiplier = float(noise_multiplier)
        self.clip_norm = float(clip_norm)
        self.noise_phases = tuple(noise_phases)
        self.accountant = GaussianAccountant(noise_multiplier, delta)

    def begin_round(self, key, client_sizes):
        k = client_sizes.shape[0]
        return ChannelContext(key, jnp.ones((k,), F32),
                              jnp.full((k,), 1.0 / k, F32),
                              jnp.asarray(float(k), F32))

    def encode_decode(self, ctx, tree_k, phase):
        # joint L2 norm over each client's whole payload tree
        sq = sum(jnp.sum(jnp.square(x.astype(F32)).reshape(x.shape[0], -1),
                         axis=1) for x in jax.tree.leaves(tree_k))
        factor = jnp.minimum(1.0, self.clip_norm /
                             jnp.maximum(jnp.sqrt(sq), 1e-12))    # (K,)
        return jax.tree.map(
            lambda x: x.astype(F32) *
            factor.reshape((-1,) + (1,) * (x.ndim - 1)), tree_k)

    def post_aggregate(self, ctx, tree, phase):
        if phase not in self.noise_phases:
            return tree
        std = self.noise_multiplier * self.clip_norm / \
            jnp.maximum(ctx.num_participants, 1.0)
        leaves, treedef = jax.tree.flatten(tree)
        keys = _leaf_keys(ctx.key, phase, len(leaves))
        return jax.tree.unflatten(treedef, [
            x + std * jax.random.normal(k, x.shape, F32)
            for k, x in zip(keys, leaves)])

    def finalize_rounds(self, num_rounds: int) -> None:
        self.accountant.step(num_rounds * len(self.noise_phases))

    def __repr__(self) -> str:
        return (f"DPGaussianChannel(sigma={self.noise_multiplier}, "
                f"clip={self.clip_norm}, phases={self.noise_phases})")


class DropoutChannel(Channel):
    """Bernoulli client dropout: each sampled client independently fails to
    report with probability ``p``. Aggregation weights renormalize over the
    surviving cohort, so Eq. 3's normalizer is the surviving sample count —
    the aggregate stays an unbiased weighted average of what arrived
    instead of shrinking toward zero.
    """

    name = "dropout"
    supports_flat_stats = False
    full_participation = False

    def __init__(self, p: float = 0.1):
        if not 0.0 <= p < 1.0:
            raise ValueError(f"dropout p must be in [0, 1), got {p}")
        self.p = float(p)

    def begin_round(self, key, client_sizes):
        k_mask, k_payload = jax.random.split(key)
        k = client_sizes.shape[0]
        keep = jax.random.bernoulli(
            k_mask, 1.0 - self.p, (k,)).astype(F32)
        s = client_sizes.astype(F32) * keep
        # guard only the all-dropped round (weights 0 -> zero stats/delta);
        # any survivor makes the denominator >= 1 sample, so the guard is
        # bit-invisible otherwise
        w = s / jnp.maximum(jnp.sum(s), 1e-12)
        return ChannelContext(k_payload, keep, w, jnp.sum(keep))

    def __repr__(self) -> str:
        return f"DropoutChannel(p={self.p})"


CHANNELS = ("dense", "int8", "quant", "dp", "dropout")


def get_channel(name: Optional[str], *, quant_bits: int = 8,
                quant_kernel: str = "off", dp_sigma: float = 1.0,
                dp_clip: float = 1.0, dp_delta: float = 1e-5,
                dropout_p: float = 0.1) -> Optional[Channel]:
    """CLI-facing factory. ``None``/"none" -> no channel (legacy paths)."""
    if name is None or name == "none":
        return None
    if name == "dense":
        return DenseChannel()
    if name == "int8":
        return QuantizedChannel(8, kernel=quant_kernel)
    if name == "quant":
        return QuantizedChannel(quant_bits, kernel=quant_kernel)
    if name == "dp":
        return DPGaussianChannel(dp_sigma, dp_clip, dp_delta)
    if name == "dropout":
        return DropoutChannel(dropout_p)
    raise ValueError(f"unknown channel {name!r}; expected one of "
                     f"{('none',) + CHANNELS}")
