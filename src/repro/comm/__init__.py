# Federated communication subsystem: every client->server payload (phase-1
# statistics, phase-2 deltas, FedAvg updates) flows through a Channel —
# dense / quantized / DP-noised / dropout-robust — with wire-cost
# accounting. See docs/architecture.md "Communication layer".
from repro.comm.accountant import (  # noqa: F401
    GaussianAccountant, gaussian_rho_per_step, zcdp_to_epsilon)
from repro.comm.channel import (  # noqa: F401
    CHANNELS, Channel, ChannelContext, DenseChannel, DPGaussianChannel,
    DropoutChannel, QuantizedChannel, get_channel)
from repro.comm.quantize import (  # noqa: F401
    dequantize, quant_dequant, quant_dequant_clients, quant_dequant_payload,
    quantize)
