"""Stochastic-rounding integer quantization for wire payloads.

The encode/decode pair simulates the uplink of a federated client: a
payload tensor is mapped to ``bits``-wide signed integers with one f32
scale per tensor (symmetric, amax-calibrated), shipped, and dequantized
server-side. Stochastic rounding ``floor(v + u), u ~ U[0,1)`` makes the
round-trip unbiased — ``E[decode(encode(x))] = x`` — so quantization noise
averages out across clients and rounds instead of accumulating as bias,
which is what aggregate-statistics protocols like DCCO need.

Everything here is a jit-compatible pure function of an explicit PRNG key.
The batched client path (`quant_dequant_clients`) optionally routes the
fused quantize→dequantize arithmetic through the Pallas kernel in
:mod:`repro.kernels.quantize` (``impl="pallas" | "interpret"``); the jnp
and kernel paths use the identical formula and the same uniforms, so they
are bit-identical (tested).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


def qmax_for_bits(bits: int) -> float:
    """Largest representable magnitude of a signed ``bits``-wide integer."""
    if not 2 <= bits <= 16:
        raise ValueError(f"bits must be in [2, 16], got {bits}")
    return float(2 ** (bits - 1) - 1)


def quant_scale(x, bits: int):
    """Per-tensor symmetric scale: amax / qmax (1/qmax for all-zero x)."""
    amax = jnp.max(jnp.abs(x.astype(F32)))
    return jnp.where(amax > 0, amax, 1.0) / qmax_for_bits(bits)


def quantize(key, x, bits: int = 8):
    """Encode: x -> (q, scale) with stochastic rounding.

    q is int8 for bits <= 8 else int32; the wire cost is ``bits`` per
    element plus one f32 scale per tensor.
    """
    qmax = qmax_for_bits(bits)
    scale = quant_scale(x, bits)
    u = jax.random.uniform(key, x.shape, F32)
    q = jnp.clip(jnp.floor(x.astype(F32) / scale + u), -qmax, qmax)
    return q.astype(jnp.int8 if bits <= 8 else jnp.int32), scale


def dequantize(q, scale):
    """Decode: q * scale, always f32."""
    return q.astype(F32) * scale


def quant_dequant(key, x, bits: int = 8):
    """The full wire round-trip for one tensor. |out - x| <= scale."""
    q, scale = quantize(key, x, bits)
    return dequantize(q, scale)


def _qdq_formula(flat, u, scales, qmax: float):
    """The shared quantize->dequantize arithmetic on (K, n) rows — the
    single source of truth for the jnp path and the Pallas kernel
    (bit-identical by construction). ``scales`` is (K,) for one scale per
    client row, or (K, n) for column-mapped per-leaf scales (the fused
    whole-payload path, where each column carries the scale of the leaf it
    came from)."""
    s = scales[:, None] if scales.ndim == 1 else scales
    q = jnp.clip(jnp.floor(flat / s + u), -qmax, qmax)
    return q * s


def quant_dequant_clients(key, xk, bits: int = 8, impl: str = "jnp"):
    """Wire round-trip for a stacked per-client payload leaf (K, ...).

    Each client row gets its own amax scale (a client only sees its own
    payload). ``impl``: "jnp" (default), "pallas" (compiled kernel on
    accelerators), or "interpret" (kernel via the Pallas interpreter —
    exact, runs anywhere).
    """
    qmax = qmax_for_bits(bits)
    k = xk.shape[0]
    flat = xk.reshape(k, -1).astype(F32)
    amax = jnp.max(jnp.abs(flat), axis=1)
    scales = jnp.where(amax > 0, amax, 1.0) / qmax
    u = jax.random.uniform(key, flat.shape, F32)
    if impl == "jnp":
        out = _qdq_formula(flat, u, scales, qmax)
    elif impl in ("pallas", "interpret"):
        from repro.kernels.quantize import quant_dequant_pallas
        out = quant_dequant_pallas(flat, u, scales, qmax,
                                   interpret=impl == "interpret")
    else:
        raise ValueError(f"unknown quantization impl {impl!r}")
    return out.reshape(xk.shape)


def quant_dequant_payload(key, tree_k, bits: int = 8, impl: str = "jnp"):
    """Wire round-trip for a WHOLE payload pytree of stacked per-client
    leaves (each (K, ...)) in one fused pass.

    Wire semantics are identical to quantizing each leaf separately: every
    client gets one amax scale PER LEAF (a client only sees its own
    payload, and each tensor ships its own f32 scale — see
    ``payload_bytes``). The fusion is purely computational: the per-leaf
    Python loop costs one threefry dispatch + one amax + one formula pass
    per leaf per phase, which for a ~50-leaf parameter tree dominates the
    round's channel time. Here the leaves are concatenated to one
    (K, n_total) matrix, ONE uniform tensor is drawn, per-leaf scales are
    column-mapped across the concatenation, and a single formula/kernel
    pass covers the whole payload.

    The uniform draws differ from the per-leaf path (one stream instead of
    ``_leaf_keys``), so outputs are not bit-identical to leaf-at-a-time
    calls — but the round-trip error bound (<= one scale step) and
    unbiasedness are unchanged, and the jnp / pallas / interpret impls of
    THIS path are bit-identical to each other.
    """
    qmax = qmax_for_bits(bits)
    leaves, treedef = jax.tree.flatten(tree_k)
    if not leaves:
        return tree_k
    k = leaves[0].shape[0]
    flats = [leaf.reshape(k, -1).astype(F32) for leaf in leaves]
    sizes = [f.shape[1] for f in flats]
    flat = flats[0] if len(flats) == 1 else jnp.concatenate(flats, axis=1)
    # per-leaf per-client symmetric scales, column-mapped over the concat
    amax = jnp.stack([jnp.max(jnp.abs(f), axis=1) for f in flats], axis=1)
    scales = jnp.where(amax > 0, amax, 1.0) / qmax          # (K, L)
    col_leaf = np.repeat(np.arange(len(flats)), sizes)      # (n_total,)
    scol = scales[:, col_leaf]                              # (K, n_total)
    u = jax.random.uniform(key, flat.shape, F32)
    if impl == "jnp":
        out = _qdq_formula(flat, u, scol, qmax)
    elif impl in ("pallas", "interpret"):
        from repro.kernels.quantize import quant_dequant_pallas
        out = quant_dequant_pallas(flat, u, scol, qmax,
                                   interpret=impl == "interpret")
    else:
        raise ValueError(f"unknown quantization impl {impl!r}")
    parts = (out,) if len(flats) == 1 else \
        jnp.split(out, np.cumsum(sizes)[:-1], axis=1)
    return jax.tree.unflatten(treedef, [
        p.reshape(leaf.shape) for p, leaf in zip(parts, leaves)])


def payload_bytes(num_elements: int, bits: int) -> float:
    """Wire bytes for one quantized tensor: packed ``bits``-wide codes
    (sub-byte codes pack on the wire) plus the f32 scale."""
    return num_elements * bits / 8.0 + 4.0
