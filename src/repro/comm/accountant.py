"""zCDP privacy accountant for the Gaussian aggregation mechanism.

One noised aggregate with noise multiplier sigma (noise std = sigma * Delta
for L2 sensitivity Delta) is rho = 1/(2 sigma^2) zero-concentrated DP
(Bun & Steinke 2016). zCDP composes additively across rounds, and converts
to (epsilon, delta)-DP via

    epsilon(delta) = rho + 2 * sqrt(rho * ln(1/delta)).

This is the standard tight-enough accountant for repeated Gaussian
releases without subsampling amplification; it is deliberately
conservative for sampled cohorts (amplification by cohort subsampling
would only lower epsilon). Host-side bookkeeping only — nothing here is
traced, so it composes with the scan-compiled engine: the engine advances
the accountant once per completed run (`Channel.finalize_rounds`).
"""
from __future__ import annotations

import math


def gaussian_rho_per_step(noise_multiplier: float) -> float:
    """zCDP cost of one Gaussian release at the given noise multiplier."""
    if noise_multiplier <= 0:
        return math.inf
    return 1.0 / (2.0 * noise_multiplier ** 2)


def zcdp_to_epsilon(rho: float, delta: float) -> float:
    """Convert accumulated zCDP rho to epsilon at the given delta."""
    if rho == 0:
        return 0.0
    if not math.isfinite(rho):
        return math.inf
    return rho + 2.0 * math.sqrt(rho * math.log(1.0 / delta))


class GaussianAccountant:
    """Counts Gaussian-mechanism invocations; reports (epsilon, delta)."""

    def __init__(self, noise_multiplier: float, delta: float = 1e-5):
        self.noise_multiplier = float(noise_multiplier)
        self.delta = float(delta)
        self.steps = 0

    def step(self, n: int = 1) -> None:
        self.steps += int(n)

    @property
    def rho(self) -> float:
        return self.steps * gaussian_rho_per_step(self.noise_multiplier)

    def epsilon(self, delta: float = None) -> float:
        return zcdp_to_epsilon(self.rho,
                               self.delta if delta is None else delta)

    def __repr__(self) -> str:
        return (f"GaussianAccountant(sigma={self.noise_multiplier}, "
                f"steps={self.steps}, eps={self.epsilon():.3f} "
                f"@ delta={self.delta})")
