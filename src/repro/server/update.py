"""ServerUpdate — the pluggable server-side model update of a federated
round.

Every round body used to end with the same three hardcoded lines:

    pseudo_grad = utils.tree_scale(avg_delta, -1.0)
    updates, opt_state = server_opt.update(pseudo_grad, opt_state, params)
    params = opt_lib.apply_updates(params, updates)

:class:`ServerUpdate` gives that step one home and a name, so the server
optimization *strategy* (plain FedAvg delegate, server momentum, the
adaptive FedOpt family) is selected by configuration instead of by editing
round bodies. The ``fedavg_sgd`` strategy wraps whatever
:class:`repro.optim.Optimizer` the caller already built and runs literally
the three lines above — it is bit-identical to the pre-abstraction path
(asserted in tests/test_server_update.py).

Strategy names (``get_server_update``):

  fedavg_sgd  — delegate to the provided base optimizer (or plain
                ``sgd(server_lr)``); the paper's/FedAvg's server step.
  fedavgm     — server heavy-ball momentum (Hsu et al. 2019).
  fedadagrad  — Reddi et al. adaptive server rules with ``tau``
  fedadam       adaptivity; see repro.server.optimizers.
  fedyogi

All strategies are a thin frozen wrapper around an Optimizer, so they jit,
scan, and donate exactly like the raw optimizer state did.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro import utils
from repro.optim import optimizers as opt_lib
from repro.optim.optimizers import Optimizer
from repro.server import optimizers as srv_opt

SERVER_UPDATES = ("fedavg_sgd", "fedavgm", "fedadagrad", "fedadam", "fedyogi")


@dataclasses.dataclass(frozen=True)
class ServerUpdate:
    """A named server optimization strategy over pseudo-gradients."""
    opt: Optimizer
    name: str = "fedavg_sgd"

    def init(self, params) -> Any:
        return self.opt.init(params)

    def step(self, params, opt_state, avg_delta):
        """Apply one server step from the aggregated client delta.

        Returns ``(params, opt_state)``. This is byte-for-byte the update
        every round body performed before the abstraction existed.
        """
        pseudo_grad = utils.tree_scale(avg_delta, -1.0)
        updates, opt_state = self.opt.update(pseudo_grad, opt_state, params)
        return opt_lib.apply_updates(params, updates), opt_state

    def __repr__(self) -> str:
        return f"ServerUpdate({self.name!r})"


def as_server_update(obj) -> ServerUpdate:
    """Coerce: an Optimizer becomes the fedavg_sgd delegate; a ServerUpdate
    passes through. Keeps every existing ``server_opt=`` call site valid."""
    if isinstance(obj, ServerUpdate):
        return obj
    if isinstance(obj, Optimizer):
        return ServerUpdate(obj, "fedavg_sgd")
    raise TypeError(f"expected Optimizer or ServerUpdate, got {type(obj)!r}")


def get_server_update(name: str, *, base_opt: Optional[Optimizer] = None,
                      server_lr=None, momentum: float = 0.9,
                      b1: float = 0.9, b2: float = 0.99,
                      tau: float = 1e-3) -> ServerUpdate:
    """Build a named strategy.

    ``fedavg_sgd`` uses ``base_opt`` when given (the pre-existing
    behavior: any repro.optim optimizer the caller configured), else plain
    SGD at ``server_lr``. The adaptive strategies ignore ``base_opt`` and
    need ``server_lr`` (a float or a schedule).
    """
    if name not in SERVER_UPDATES:
        raise ValueError(f"unknown server update {name!r}; "
                         f"expected one of {SERVER_UPDATES}")
    if name == "fedavg_sgd":
        if base_opt is None:
            if server_lr is None:
                raise ValueError("fedavg_sgd needs base_opt or server_lr")
            base_opt = opt_lib.sgd(server_lr)
        return ServerUpdate(base_opt, name)
    if server_lr is None:
        raise ValueError(f"{name} needs server_lr")
    if name == "fedavgm":
        opt = srv_opt.fedavgm(server_lr, momentum=momentum)
    elif name == "fedadagrad":
        opt = srv_opt.fedadagrad(server_lr, b1=0.0, tau=tau)
    elif name == "fedadam":
        opt = srv_opt.fedadam(server_lr, b1=b1, b2=b2, tau=tau)
    else:  # fedyogi
        opt = srv_opt.fedyogi(server_lr, b1=b1, b2=b2, tau=tau)
    return ServerUpdate(opt, name)
