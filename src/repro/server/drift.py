"""Client-drift correction for local training: FedProx and SCAFFOLD.

On non-IID clients the local loss minimizers disagree, so local steps pull
the cohort's deltas apart ("client drift") and the averaged update both
shrinks and biases — the degradation regime the paper's small single-class
clients sit in. Two standard corrections, both applied inside the round
bodies of :mod:`repro.core.fed_sim`:

**FedProx** (Li et al. 2020) adds a proximal pull toward the broadcast
model to the local objective: ``loss + mu/2 * ||p - p_global||^2``. We
apply its gradient ``mu * (p - p_global)`` analytically in
``fed_sim.client_local_steps`` — no extra autodiff cost. ``mu = 0`` takes
the statically identical code path (bit-identical, tested). With one local
step the first iterate sits at ``p_global`` and the term vanishes — FedProx
only bites at ``local_steps > 1``, exactly where drift appears.

**SCAFFOLD** (Karimireddy et al. 2020) corrects each local gradient with
control variates: client ``k`` steps with ``g - c_k + c`` where ``c_k``
estimates the client's own gradient and ``c`` the population's; after the
local run it refreshes ``c_k`` (option II: from the realized local
progress) and ships ``delta c_k`` up, and the server folds the aggregate
into ``c``.

Slot semantics: the engine's cohorts are *sampled*, and the paper's regime
(millions of tiny, effectively stateless clients) precludes per-client
persistent state — the same argument Reddi et al. make for server-side
adaptivity. We therefore carry one variate per **cohort slot** (K slots,
the scan-carry pytree), not per underlying client: slot ``k``'s variate
tracks an EMA-like estimate of the gradient seen at that cohort position.
With full participation (cohort == client population, as in the DERM-style
small-population configs) this is exact SCAFFOLD; under sampling it is the
stateless-client approximation. The invariant ``sum_k w_k c_k == c`` holds
whenever round weights are constant across rounds (e.g. fixed-size
clients), so the aggregated variates sum to ~0 around the server variate
(tested).

Wire truthfulness: ``delta c_k`` is a per-client uplink the same size as a
model delta, so it is routed through the round's :mod:`repro.comm` Channel
under the ``"variate"`` phase — quantization/DP/dropout compose with
SCAFFOLD and the bytes show up in ``wire_bytes``.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


class ScaffoldState(NamedTuple):
    """SCAFFOLD control variates, carried through the scan.

    ``c``: the server variate, shaped like the (f32) params.
    ``c_slots``: per-cohort-slot client variates, leading axis K.
    """
    c: Any
    c_slots: Any


def scaffold_init(params, num_slots: int) -> ScaffoldState:
    """Zero variates for a cohort of ``num_slots`` clients."""
    c = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
    c_slots = jax.tree.map(
        lambda p: jnp.zeros((num_slots,) + p.shape, F32), params)
    return ScaffoldState(c, c_slots)


def scaffold_corrections(state: ScaffoldState):
    """Per-slot gradient corrections ``c - c_k`` (leading axis K), to be
    *added* to each client's local gradient: the SCAFFOLD local step is
    ``y <- y - lr * (g - c_k + c)``."""
    return jax.tree.map(lambda c, ck: c[None] - ck, state.c, state.c_slots)


def scaffold_new_slot_variates(state: ScaffoldState, deltas,
                               client_lr: float, local_steps: int):
    """Option-II refresh from the realized local progress.

    ``c_k+ = c_k - c + (x - y_k) / (L * lr)``; with ``delta_k = y_k - x``
    (what the round body already computed) that is
    ``c_k - c - delta_k / (L * lr)``. For ``L = 1`` this reduces to the
    client's corrected gradient, i.e. ``c_k+`` is its freshest local
    gradient estimate.
    """
    inv = 1.0 / (float(local_steps) * float(client_lr))
    return jax.tree.map(
        lambda ck, c, d: ck - c[None] - inv * d.astype(F32),
        state.c_slots, state.c, deltas)


def scaffold_apply_round(state: ScaffoldState, c_slots_new, agg_dc,
                         participation_mask=None) -> ScaffoldState:
    """Fold one round's variate refresh into the carried state.

    ``agg_dc`` is the (channel-aggregated) weighted average of the slot
    variate deltas; the server variate absorbs it. Non-participating slots
    (``participation_mask`` 0, e.g. dropped by a DropoutChannel) keep their
    old variate — a client that never reported cannot have refreshed.
    """
    if participation_mask is not None:
        m = participation_mask.astype(F32)
        c_slots_new = jax.tree.map(
            lambda new, old: (m.reshape((-1,) + (1,) * (new.ndim - 1)) * new
                              + (1 - m).reshape((-1,) + (1,) * (new.ndim - 1))
                              * old),
            c_slots_new, state.c_slots)
    c_new = jax.tree.map(lambda c, d: c + d, state.c, agg_dc)
    return ScaffoldState(c_new, c_slots_new)
