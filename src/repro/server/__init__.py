# Server-optimization & client-drift subsystem: pluggable ServerUpdate
# strategies (FedAvg delegate / FedAvgM / FedAdagrad / FedAdam / FedYogi)
# and drift-corrected local training (FedProx, SCAFFOLD control variates).
# See docs/architecture.md "Server optimization & client drift".
from repro.server.drift import (  # noqa: F401
    ScaffoldState, scaffold_apply_round, scaffold_corrections, scaffold_init,
    scaffold_new_slot_variates)
from repro.server.optimizers import (  # noqa: F401
    fedadagrad, fedadam, fedavgm, fedyogi)
from repro.server.update import (  # noqa: F401
    SERVER_UPDATES, ServerUpdate, as_server_update, get_server_update)
