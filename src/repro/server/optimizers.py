"""Adaptive federated *server* optimizers (FedOpt family, Reddi et al. 2021).

In FedOpt the server treats the negated weighted-average client delta as a
pseudo-gradient and feeds it to a first-order optimizer. Plain FedAvg is
SGD(lr=1) on that pseudo-gradient; this module adds the adaptive family —
FedAvgM / FedAdagrad / FedAdam / FedYogi — built on the existing
:class:`repro.optim.Optimizer` contract (``init``/``update`` returning
additive updates applied by ``apply_updates``), so every round body and the
scan engine consume them exactly like the optimizers they already take.

Why they matter here: on small non-IID clients the per-round pseudo-
gradients are noisy and badly scaled across parameters (client drift), and
a fixed server average inherits all of it. The adaptive rules keep
per-parameter second-moment preconditioners ``v`` on the server — where
state is cheap and persistent, unlike the paper's stateless tiny clients —
and damp the update by ``1/(sqrt(v) + tau)``. ``tau`` is Reddi et al.'s
adaptivity knob (their ``τ``), playing the role Adam's ``eps`` plays but
typically orders of magnitude larger (1e-3..1e-1): it bounds how aggressive
the preconditioning may get under federated noise.

Following the reference FedOpt formulation there is **no bias correction**:
``m``/``v`` start at zero and warm up over the first rounds.

All state is f32. Sign convention matches the rest of the repo: these
optimizers consume *pseudo-gradients* ``g = -avg_delta`` and return
additive updates ``-lr * precond(m)``, so the applied step is
``x += lr * precond(avg_delta-momentum)`` — exactly Reddi et al.'s server
update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim import optimizers as opt_lib
from repro.optim.optimizers import Optimizer

F32 = jnp.float32


def fedavgm(lr, momentum: float = 0.9) -> Optimizer:
    """FedAvgM (Hsu et al. 2019): heavy-ball momentum on the server.

    Exactly ``repro.optim.sgd(lr, momentum)`` — re-exported under its
    federated name so ``get_server_update('fedavgm')`` reads like the
    literature.
    """
    return opt_lib.sgd(lr, momentum=momentum)


def _sched(lr):
    return lr if callable(lr) else (lambda step: jnp.asarray(lr, F32))


def _fedopt(lr, b1: float, tau: float, v_update) -> Optimizer:
    """Shared scaffolding of the adaptive family: server momentum ``m``,
    a per-variant second moment ``v`` (``v_update(v, g2) -> v``), and the
    ``m / (sqrt(v) + tau)`` preconditioned step."""
    lr_fn = _sched(lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, F32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params=None):
        g = jax.tree.map(lambda x: x.astype(F32), grads)
        m = jax.tree.map(lambda mi, gi: b1 * mi + (1 - b1) * gi,
                         state["m"], g)
        v = jax.tree.map(lambda vi, gi: v_update(vi, gi * gi), state["v"], g)
        lr_t = lr_fn(state["step"])
        updates = jax.tree.map(
            lambda mi, vi: -lr_t * mi / (jnp.sqrt(vi) + tau), m, v)
        return updates, {"step": state["step"] + 1, "m": m, "v": v}

    return Optimizer(init, update)


def fedadagrad(lr, b1: float = 0.0, tau: float = 1e-3) -> Optimizer:
    """FedAdagrad: ``v += g^2`` (monotone preconditioner)."""
    return _fedopt(lr, b1, tau, lambda v, g2: v + g2)


def fedadam(lr, b1: float = 0.9, b2: float = 0.99, tau: float = 1e-3) -> Optimizer:
    """FedAdam: EMA second moment ``v = b2*v + (1-b2)*g^2``."""
    return _fedopt(lr, b1, tau, lambda v, g2: b2 * v + (1 - b2) * g2)


def fedyogi(lr, b1: float = 0.9, b2: float = 0.99, tau: float = 1e-3) -> Optimizer:
    """FedYogi: additive-only second moment
    ``v = v - (1-b2) * g^2 * sign(v - g^2)`` — moves ``v`` toward ``g^2``
    at a rate independent of its magnitude, which Reddi et al. found more
    stable than FedAdam under heavy-tailed federated pseudo-gradients."""
    return _fedopt(lr, b1, tau,
                   lambda v, g2: v - (1 - b2) * g2 * jnp.sign(v - g2))
