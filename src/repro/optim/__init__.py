from repro.optim.optimizers import (  # noqa: F401
    Optimizer, sgd, adam, lars, get_optimizer)
from repro.optim.schedules import cosine_decay, constant  # noqa: F401
