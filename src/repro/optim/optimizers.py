"""Minimal optimizer library (no optax offline): SGD, Adam, LARS.

Interface mirrors optax: ``opt.init(params) -> state``;
``opt.update(grads, state, params) -> (updates, state)``; apply with
``apply_updates``. All optimizer state is f32; updates are cast back to the
parameter dtype on apply. The *server* optimizer in federated training
consumes pseudo-gradients (negative average client deltas), per FedOpt.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]   # (grads, state, params) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(F32) + u.astype(F32)).astype(p.dtype),
                        params, updates)


def _sched(lr):
    return lr if callable(lr) else (lambda step: jnp.asarray(lr, F32))


def sgd(lr, momentum: float = 0.0, weight_decay: float = 0.0) -> Optimizer:
    lr_fn = _sched(lr)

    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mu"] = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
        return state

    def update(grads, state, params=None):
        step = state["step"]
        g = jax.tree.map(lambda x: x.astype(F32), grads)
        if weight_decay and params is not None:
            g = jax.tree.map(lambda gi, p: gi + weight_decay * p.astype(F32), g, params)
        if momentum:
            mu = jax.tree.map(lambda m, gi: momentum * m + gi, state["mu"], g)
            g = mu
            new_state = {"step": step + 1, "mu": mu}
        else:
            new_state = {"step": step + 1}
        lr_t = lr_fn(step)
        updates = jax.tree.map(lambda gi: -lr_t * gi, g)
        return updates, new_state

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    lr_fn = _sched(lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, F32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        g = jax.tree.map(lambda x: x.astype(F32), grads)
        m = jax.tree.map(lambda mi, gi: b1 * mi + (1 - b1) * gi, state["m"], g)
        v = jax.tree.map(lambda vi, gi: b2 * vi + (1 - b2) * gi * gi, state["v"], g)
        bc1 = 1 - b1 ** step.astype(F32)
        bc2 = 1 - b2 ** step.astype(F32)
        lr_t = lr_fn(state["step"])

        def upd(mi, vi, p):
            u = (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
            if weight_decay and p is not None:
                u = u + weight_decay * p.astype(F32)
            return -lr_t * u

        if params is not None:
            updates = jax.tree.map(upd, m, v, params)
        else:
            updates = jax.tree.map(lambda mi, vi: upd(mi, vi, None), m, v)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def lars(lr, momentum: float = 0.9, weight_decay: float = 0.0,
         trust_coefficient: float = 0.001, eps: float = 1e-8) -> Optimizer:
    """LARS (You et al. 2017) — the paper's server optimizer for DERM and
    its linear-probe optimizer."""
    lr_fn = _sched(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)}

    def update(grads, state, params):
        step = state["step"]
        lr_t = lr_fn(step)

        def upd(g, p, mu):
            g = g.astype(F32)
            pf = p.astype(F32)
            if weight_decay:
                g = g + weight_decay * pf
            p_norm = jnp.linalg.norm(pf)
            g_norm = jnp.linalg.norm(g)
            trust = jnp.where(
                (p_norm > 0) & (g_norm > 0),
                trust_coefficient * p_norm / (g_norm + eps), 1.0)
            mu_new = momentum * mu + trust * g
            return -lr_t * mu_new, mu_new

        flat_g, treedef = jax.tree.flatten(grads)
        flat_p = jax.tree.leaves(params)
        flat_mu = jax.tree.leaves(state["mu"])
        outs = [upd(g, p, mu) for g, p, mu in zip(flat_g, flat_p, flat_mu)]
        updates = jax.tree.unflatten(treedef, [o[0] for o in outs])
        mu = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return updates, {"step": step + 1, "mu": mu}

    return Optimizer(init, update)


def get_optimizer(name: str, lr, **kw) -> Optimizer:
    return {"sgd": sgd, "adam": adam, "lars": lars}[name](lr, **kw)
