"""Learning-rate schedules (paper App. B uses cosine decay everywhere)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_decay(base_lr: float, total_steps: int, warmup_steps: int = 0,
                 final_scale: float = 0.0):
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.where(warmup_steps > 0, step / jnp.maximum(warmup_steps, 1), 1.0)
        warm = jnp.minimum(warm, 1.0)
        t = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return base_lr * warm * (final_scale + (1.0 - final_scale) * cos)
    return schedule


def constant(base_lr: float):
    def schedule(step):
        return jnp.asarray(base_lr, jnp.float32)
    return schedule
