"""DeepSeek-V2-Lite (16B total / 2.4B active). [arXiv:2405.04434]
27L d_model=2048, MLA (kv_lora_rank=512, rope_dim=64), MoE: 2 shared +
64 routed experts (fine-grained, d_ff=1408) top-6, first layer dense.

The pool line says "160 routed" (full V2); the 16B-Lite model card this
entry cites uses 64 routed — we follow the Lite card (noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    source="arXiv:2405.04434 (DeepSeek-V2-Lite)",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    use_mla=True,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    block_pattern=("attn",),
    moe=MoEConfig(num_experts=64, num_shared_experts=2, top_k=6, d_ff=1408,
                  capacity_factor=1.25, balance_weight=0.01,
                  first_k_dense=1, dense_d_ff=10944),
    rope_theta=10_000.0,
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.replace(
    name="deepseek-v2-lite-smoke", num_layers=3, d_model=256, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=512, kv_lora_rank=64,
    qk_rope_head_dim=16, qk_nope_head_dim=32, v_head_dim=32,
    moe=MoEConfig(num_experts=4, num_shared_experts=1, top_k=2, d_ff=128,
                  capacity_factor=1.5, balance_weight=0.01,
                  first_k_dense=1, dense_d_ff=512),
    dtype="float32")
