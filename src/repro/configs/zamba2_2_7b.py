"""Zamba2-2.7B hybrid: Mamba2 backbone + periodic shared attention blocks.
[arXiv:2411.15242] 54L d_model=2560 32H (kv=32) d_ff=10240 ssm_state=64.

Mapped onto the scanned-superblock structure as 9 x (5 Mamba2 + 1 attn+FFN)
= 54 layers; Zamba2's single *weight-shared* attention block is approximated
by per-superblock attention (noted in DESIGN.md — weight sharing is a
memory optimization orthogonal to the paper's technique).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    source="arXiv:2411.15242 (Zamba2)",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    block_pattern=("mamba2", "mamba2", "mamba2", "mamba2", "mamba2", "attn"),
    ssm=SSMConfig(state=64, expand=2, conv_width=4, head_dim=64, chunk=128),
    rope_theta=10_000.0,
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.replace(
    name="zamba2-smoke", num_layers=6, d_model=256, num_heads=4,
    num_kv_heads=4, d_ff=512, vocab_size=512, head_dim=64,
    block_pattern=("mamba2", "mamba2", "attn"),
    ssm=SSMConfig(state=16, expand=2, conv_width=4, head_dim=32, chunk=32),
    dtype="float32")
