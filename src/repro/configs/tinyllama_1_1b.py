"""TinyLlama-1.1B (llama2-architecture small). [arXiv:2401.02385]
22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    source="arXiv:2401.02385 (TinyLlama)",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=5632,
    vocab_size=32000,
    head_dim=64,
    block_pattern=("attn",),
    rope_theta=10_000.0,
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.replace(
    name="tinyllama-smoke", num_layers=2, d_model=256, num_heads=8,
    num_kv_heads=2, d_ff=512, vocab_size=512, head_dim=32, dtype="float32")
