"""Qwen3-1.7B dense decoder with per-head QK-RMSNorm. [hf:Qwen/Qwen3-8B]
28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    source="hf:Qwen/Qwen3-8B (1.7B sibling)",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=6144,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    block_pattern=("attn",),
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.replace(
    name="qwen3-1.7b-smoke", num_layers=2, d_model=256, num_heads=8,
    num_kv_heads=4, d_ff=512, vocab_size=512, head_dim=32, dtype="float32")
