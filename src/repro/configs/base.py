"""Model / training configuration dataclasses and the architecture registry.

Every assigned architecture gets a module ``repro/configs/<id>.py`` exporting
``CONFIG`` (full production config, exact spec from the assignment) and
``SMOKE_CONFIG`` (reduced same-family variant: <=2 superblocks, d_model<=512,
<=4 experts) used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
import importlib
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0            # routed experts
    num_shared_experts: int = 0     # always-on experts (DeepSeek style)
    top_k: int = 0
    d_ff: int = 0                   # per-expert hidden dim
    capacity_factor: float = 1.25
    balance_weight: float = 0.01    # aux load-balance loss weight
    first_k_dense: int = 0          # first K layers use a dense FFN instead
    dense_d_ff: int = 0             # hidden dim of those dense layers


@dataclass(frozen=True)
class SSMConfig:
    state: int = 64                 # N: SSM state size
    expand: int = 2                 # d_inner = expand * d_model
    conv_width: int = 4
    head_dim: int = 64              # Mamba2 head dim (d_inner / heads)
    chunk: int = 128                # chunked-scan block length


@dataclass(frozen=True)
class XLSTMConfig:
    # mLSTM / sLSTM cell sizes; heads come from ModelConfig.num_heads.
    chunk: int = 128                # mLSTM chunkwise-recurrent block length
    proj_factor_mlstm: float = 2.0  # pre-up-projection factor for mLSTM blocks
    proj_factor_slstm: float = 1.333  # post-up-projection (ffn) factor for sLSTM


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"           # dense | moe | hybrid | ssm | vlm | audio
    source: str = ""                # citation for the config values
    # transformer trunk
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: int = 0               # 0 -> d_model // num_heads
    # block pattern, cycled over layers (scan over superblocks).
    # entries: "attn" (attention + FFN/MoE), "mamba2", "mlstm", "slstm"
    block_pattern: Tuple[str, ...] = ("attn",)
    # attention details
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0         # 0 = full attention
    # MLA (DeepSeek-V2 multi-head latent attention)
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128
    # sub-configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # modality ("text" | "vision_text" | "audio_tokens")
    modality: str = "text"
    vis_patches: int = 0            # VLM: number of patch embeddings prepended
    vis_dim: int = 0                # VLM: stub ViT output dim
    # resnet (paper's own encoder; family == "resnet")
    resnet_stages: Tuple[int, ...] = ()
    resnet_channels: Tuple[int, ...] = ()
    resnet_groups: int = 32
    resnet_in_channels: int = 3
    image_size: int = 32
    # norm / numerics
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    tie_embeddings: bool = True
    # attention impl: "blockwise" (flash-style scan, memory-safe) or "naive"
    attn_impl: str = "blockwise"
    attn_block: int = 1024          # kv block for blockwise attention
    # remat policy on the layer scan: "none" | "full"
    remat: str = "none"
    # pin activation batch-dim sharding at block boundaries (FSDP mode needs
    # this so SPMD gathers weights, not activations); None = let XLA infer
    act_shard_axes: Optional[Tuple[str, ...]] = None
    # FSDP: model-axis size for in-scan per-layer weight constraints (keeps
    # the gather inside the loop body — one layer resident, not the stack)
    fsdp_model_size: int = 0
    # KV cache storage dtype: "model" (= cfg.dtype) | "int8" (per-vector
    # max-abs quantization; halves decode cache capacity+bandwidth)
    kv_cache_dtype: str = "model"
    # PaLM-style parallel block: attn and FFN both read norm(x) and their
    # outputs sum into the residual — halves the per-layer TP all-reduces
    # (one joint AR instead of two). A beyond-paper *variant*: numerics
    # differ from the sequential block, so it is opt-in per experiment.
    parallel_block: bool = False
    # scan vs python-unrolled layer loop, and chunked middle ground: the
    # stack splits into `layer_chunks` python-level chunks, each scanned.
    # XLA's loop-invariant code motion hoists FSDP weight all-gathers out of
    # a while loop — chunking bounds the hoisted gather to stack/chunks
    # bytes (measured; see EXPERIMENTS §Perf).
    scan_layers: bool = True
    layer_chunks: int = 1

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def num_prologue(self) -> int:
        return self.moe.first_k_dense if self.moe is not None else 0

    @property
    def num_superblocks(self) -> int:
        scanned = self.num_layers - self.num_prologue
        assert scanned % len(self.block_pattern) == 0, (
            f"{self.name}: scanned layers {scanned} not divisible by "
            f"pattern len {len(self.block_pattern)}")
        return scanned // len(self.block_pattern)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class DualEncoderConfig:
    """Paper Sec. 4.2: 3-layer projection head on top of pooled encodings."""
    proj_dims: Tuple[int, ...] = (1024, 1024, 1024)
    lambda_cco: float = 20.0        # paper's tradeoff parameter
    shared_towers: bool = True      # Fig 1(a) vs 1(b)/(c)
    pool: str = "mean"              # mean-pool token encodings


@dataclass(frozen=True)
class TrainConfig:
    seq_len: int = 128
    global_batch: int = 8
    samples_per_client: int = 1     # clients/round = global_batch // samples_per_client
    local_steps: int = 1            # paper: 1 (the equivalence regime)
    client_lr: float = 1.0          # paper: GD with lr 1.0 on clients
    server_optimizer: str = "adam"  # adam | lars | sgd
    server_lr: float = 5e-3
    total_rounds: int = 100
    warmup_rounds: int = 0
    weight_decay: float = 0.0
    seed: int = 0
    # DCCO path: "fused" (centralized-equivalent, optimized) |
    #            "per_client" (faithful per-client stop-grad combine) |
    #            "shard_map" (protocol-faithful device-level collective)
    dcco_impl: str = "fused"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "internvl2-2b",
    "granite-3-8b",
    "qwen3-8b",
    "qwen3-1.7b",
    "deepseek-v2-lite-16b",
    "zamba2-2.7b",
    "musicgen-large",
    "tinyllama-1.1b",
    "xlstm-350m",
    "deepseek-moe-16b",
    "resnet14-cifar",   # the paper's own encoder config
)


def _module_name(arch_id: str) -> str:
    return "repro.configs." + arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch '{arch_id}'; known: {ARCH_IDS}")
    mod = importlib.import_module(_module_name(arch_id))
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def get_dual_encoder_config(arch_id: str) -> DualEncoderConfig:
    mod = importlib.import_module(_module_name(arch_id))
    return getattr(mod, "DUAL_ENCODER", DualEncoderConfig())
