"""xLSTM-350M: alternating mLSTM / sLSTM blocks. [arXiv:2405.04517]
24L d_model=1024 4H (kv=4) d_ff=0 (gating inside cells) vocab=50304.

Mapped as 12 x (mLSTM, sLSTM) superblocks: mLSTM uses pre-up-projection
(factor 2) and chunkwise-recurrent parallel training; sLSTM uses recurrent
per-head block-diagonal weights + post-up-projection FFN (factor 4/3).
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    source="arXiv:2405.04517 (xLSTM)",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "slstm"),
    xlstm=XLSTMConfig(chunk=128, proj_factor_mlstm=2.0, proj_factor_slstm=1.333),
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.replace(
    name="xlstm-smoke", num_layers=2, d_model=128, num_heads=2,
    num_kv_heads=2, vocab_size=256,
    xlstm=XLSTMConfig(chunk=16, proj_factor_mlstm=2.0, proj_factor_slstm=1.333),
    dtype="float32")
