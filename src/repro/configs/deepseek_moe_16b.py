"""DeepSeekMoE-16B: fine-grained experts + shared-expert isolation.
[arXiv:2401.06066] 28L d_model=2048 16H (kv=16) vocab=102400,
2 shared + 64 routed experts (d_ff=1408) top-6, first layer dense FFN.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    source="arXiv:2401.06066 (DeepSeekMoE-16B)",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    head_dim=128,
    block_pattern=("attn",),
    moe=MoEConfig(num_experts=64, num_shared_experts=2, top_k=6, d_ff=1408,
                  capacity_factor=1.25, balance_weight=0.01,
                  first_k_dense=1, dense_d_ff=10944),
    rope_theta=10_000.0,
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.replace(
    name="deepseek-moe-smoke", num_layers=3, d_model=256, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=512, head_dim=64,
    moe=MoEConfig(num_experts=4, num_shared_experts=1, top_k=2, d_ff=128,
                  capacity_factor=1.5, balance_weight=0.01,
                  first_k_dense=1, dense_d_ff=512),
    dtype="float32")
