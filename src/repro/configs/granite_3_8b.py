"""IBM Granite-3 8B dense decoder. [hf:ibm-granite/granite-3.0-2b-base]
40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    source="hf:ibm-granite/granite-3.0-2b-base (GQA family)",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    head_dim=128,
    block_pattern=("attn",),
    rope_theta=10_000.0,
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.replace(
    name="granite-3-8b-smoke", num_layers=2, d_model=256, num_heads=8,
    num_kv_heads=2, d_ff=512, vocab_size=512, head_dim=32, dtype="float32")
