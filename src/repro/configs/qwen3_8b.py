"""Qwen3-8B dense decoder with per-head QK-RMSNorm. [hf:Qwen/Qwen3-8B]
36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    source="hf:Qwen/Qwen3-8B",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12288,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    block_pattern=("attn",),
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.replace(
    name="qwen3-8b-smoke", num_layers=2, d_model=256, num_heads=8,
    num_kv_heads=2, d_ff=512, vocab_size=512, head_dim=32, dtype="float32")
