"""The paper's own CIFAR-100 encoder: ResNet-14 with weight standardization
+ GroupNorm(32) (paper Sec 4.2), 3-layer [1024,1024,1024] projection head,
lambda = 20 (Sec 4.3)."""
from repro.configs.base import ModelConfig, DualEncoderConfig

CONFIG = ModelConfig(
    name="resnet14-cifar",
    family="resnet",
    source="paper Sec 4.2 (He et al. 2016 ResNet-14, WS+GN variant)",
    num_layers=14,
    d_model=256,                 # final feature width
    vocab_size=0,
    d_ff=0,
    num_heads=1, num_kv_heads=1,
    resnet_stages=(2, 2, 2),
    resnet_channels=(64, 128, 256),
    resnet_groups=32,
    resnet_in_channels=3,
    image_size=32,
    dtype="float32",
)

SMOKE_CONFIG = CONFIG.replace(
    name="resnet14-smoke",
    resnet_stages=(1, 1),
    resnet_channels=(16, 32),
    resnet_groups=8,
    d_model=32,
    image_size=16,
)

DUAL_ENCODER = DualEncoderConfig(proj_dims=(1024, 1024, 1024), lambda_cco=20.0,
                                 shared_towers=True)
