"""InternVL2-2B language backbone (InternLM2-1.8B) + stub InternViT frontend.
[arXiv:2404.16821] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.

The vision encoder (InternViT-300M) is a stub per the assignment carve-out:
input_specs provides precomputed patch embeddings (vis_dim=1024) which the
real MLP projector maps into the LM; the dual-encoder pairing is
cross-modal (paper Fig. 1c): text tower vs vision-patch tower.
"""
from repro.configs.base import ModelConfig, DualEncoderConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    source="arXiv:2404.16821 (InternVL2); InternLM2-1.8B backbone",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    head_dim=128,
    block_pattern=("attn",),
    modality="vision_text",
    vis_patches=256,
    vis_dim=1024,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.replace(
    name="internvl2-2b-smoke", num_layers=2, d_model=256, num_heads=8,
    num_kv_heads=4, d_ff=512, vocab_size=512, head_dim=32,
    vis_patches=16, vis_dim=64, dtype="float32")

DUAL_ENCODER = DualEncoderConfig(proj_dims=(2048, 2048, 2048),
                                 shared_towers=True)
