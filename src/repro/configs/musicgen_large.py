"""MusicGen-Large decoder over EnCodec tokens. [arXiv:2306.05284]
48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048.

The EnCodec conv codec is the stub frontend (assignment carve-out):
input_specs provides the discrete codec token ids directly (vocab 2048,
one codebook stream); the decoder-only transformer is real.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    source="arXiv:2306.05284 (MusicGen)",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    head_dim=64,
    block_pattern=("attn",),
    modality="audio_tokens",
    rope_theta=10_000.0,
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.replace(
    name="musicgen-smoke", num_layers=2, d_model=256, num_heads=4,
    num_kv_heads=4, d_ff=512, vocab_size=256, head_dim=64, dtype="float32")
