from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, XLSTMConfig, DualEncoderConfig,
    TrainConfig, ARCH_IDS, get_config, get_dual_encoder_config)
