"""Fused segment-sum Pallas TPU kernel — the hierarchy's client→edge fold.

Two-level aggregation (repro.hierarchy) folds K per-client stat rows into
E per-edge aggregates: out[e] = sum_{k in edge e} w_k * rows[k]. A naive
implementation gathers/scatter-adds per client; this kernel does the fold
in ONE pass over the rows by turning the segment reduction into an MXU
matmul: each (bk x bd) tile of rows is loaded once, the segment ids of the
tile are expanded on the fly into a one-hot (E x bk) membership matrix
(a broadcasted-iota compare — no materialized one-hot in HBM), and the
per-edge partials accumulate as ``one_hot @ (w * rows)`` with the output
tile resident in VMEM across the whole row axis (revisited-output
pattern, rows innermost in the grid).

This is the same fold the sharded cohort path runs per device when edges
align with the mesh (num_edges % num_shards == 0): each shard folds its
local clients into its local edges, and the cross-shard psum implements
the edge→server hop.

Exactness: the fold is linear in rows, so by paper Eq. 3 any segment
grouping of the statistics is exact in math; numerically the kernel
matches the jnp oracle (``ref.segment_sum_ref``) to float-regrouping
tolerance (interpret-mode tested in tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
import jax.numpy as jnp

F32 = jnp.float32


def _segment_sum_kernel(rows_ref, ids_ref, w_ref, out_ref, *, num_seg_p: int):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    rows = rows_ref[...].astype(F32)                       # (bk, bd)
    w = w_ref[...].astype(F32)                             # (1, bk)
    ids = ids_ref[...]                                     # (1, bk) int32
    # one-hot membership (num_seg_p, bk): row e marks this tile's clients
    # of edge e. Padding rows carry id == num_seg_p, matching no edge.
    seg = jax.lax.broadcasted_iota(jnp.int32, (num_seg_p, rows.shape[0]), 0)
    one_hot = (seg == ids).astype(F32)
    out_ref[...] += jax.lax.dot_general(
        one_hot, rows * w.reshape(-1, 1), (((1,), (0,)), ((), ())),
        preferred_element_type=F32)


@functools.partial(jax.jit, static_argnames=("num_segments", "block_k",
                                             "block_d", "interpret"))
def segment_sum_pallas(rows, seg_ids, num_segments: int, weights=None, *,
                       block_k: int = 512, block_d: int = 256,
                       interpret: bool = False):
    """rows: (K, d), seg_ids: (K,) int32 in [0, num_segments) -> (E, d) f32.

    ``weights`` (K,) optionally scales each row before the fold (the
    hierarchy folds w_k * stats_k). K and d are padded to block multiples
    internally; padding rows get id ``num_segments`` (matches nothing) and
    weight 0, so they contribute exactly nothing. The per-edge output axis
    is padded to the f32 sublane multiple and sliced back.
    """
    k, d = rows.shape
    bk = min(block_k, max(k, 8))
    bd = min(block_d, max(d, 1))
    k_p = -(-k // bk) * bk
    d_p = -(-d // bd) * bd
    e_p = -(-num_segments // 8) * 8          # f32 sublane multiple
    if weights is None:
        weights = jnp.ones((k,), F32)
    if k_p != k or d_p != d:
        rows = jnp.pad(rows, ((0, k_p - k), (0, d_p - d)))
    if k_p != k:
        seg_ids = jnp.pad(seg_ids, (0, k_p - k),
                          constant_values=num_segments)
        weights = jnp.pad(weights, (0, k_p - k))
    out = pl.pallas_call(
        functools.partial(_segment_sum_kernel, num_seg_p=e_p),
        grid=(d_p // bd, k_p // bk),
        in_specs=[
            pl.BlockSpec((bk, bd), lambda di, kb: (kb, di)),   # rows
            pl.BlockSpec((1, bk), lambda di, kb: (0, kb)),     # ids
            pl.BlockSpec((1, bk), lambda di, kb: (0, kb)),     # weights
        ],
        out_specs=pl.BlockSpec((e_p, bd), lambda di, kb: (0, di)),
        out_shape=jax.ShapeDtypeStruct((e_p, d_p), F32),
        interpret=interpret,
    )(rows, seg_ids.astype(jnp.int32).reshape(1, -1),
      weights.astype(F32).reshape(1, -1))
    return out[:num_segments, :d]
