"""Fused quantize->dequantize Pallas TPU kernel for wire payloads.

The communication channel's hot elementwise pass: every per-client payload
row (K clients x n payload elements) goes through
``clip(floor(x / s_k + u), -qmax, qmax) * s_k`` — scale, stochastically
round, clip, and dequantize. Done with separate jnp ops this materializes
three (K, n) intermediates in HBM; the kernel fuses the whole round-trip
into ONE pass so each VMEM tile of the payload (and its uniforms) is read
once and the dequantized result written once.

Grid: (client-row tiles, payload-column tiles). Per-client scales arrive
as a (K, 128) lane-broadcast operand so a (bk, 128) block aligns with the
f32 tile constraint; the kernel reads column 0. Uniforms are an operand
(not in-kernel PRNG) so the kernel is bit-identical to the jnp reference
formula given the same draws — exactness is tested, and interpret mode
works on CPU.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
import jax.numpy as jnp

F32 = jnp.float32


def _qdq_kernel(x_ref, u_ref, scale_ref, out_ref, *, qmax: float):
    x = x_ref[...].astype(F32)                       # (bk, bn)
    s = scale_ref[:, :1]                             # (bk, 1) lane 0
    q = jnp.clip(jnp.floor(x / s + u_ref[...]), -qmax, qmax)
    out_ref[...] = q * s


def _qdq_kernel_2d(x_ref, u_ref, scale_ref, out_ref, *, qmax: float):
    # column-mapped scales: one full (bk, bn) scale block per payload block
    # (the fused whole-payload path, where each column carries its leaf's
    # per-client scale)
    x = x_ref[...].astype(F32)
    s = scale_ref[...]
    q = jnp.clip(jnp.floor(x / s + u_ref[...]), -qmax, qmax)
    out_ref[...] = q * s


@functools.partial(jax.jit,
                   static_argnames=("qmax", "block_k", "block_n", "interpret"))
def quant_dequant_pallas(flat, u, scales, qmax: float, *, block_k: int = 8,
                         block_n: int = 2048, interpret: bool = False):
    """flat, u: (K, n); scales: (K,) or (K, n) -> dequantized (K, n) f32.

    K and n are padded to block multiples (padded scale rows/columns are
    1.0 so the division is benign; padded x/u are 0 -> floor(0+0)=0, sliced
    away). 1-D scales ride a (K, 128) lane-broadcast operand (one VMEM lane
    tile per row block); 2-D scales are blocked exactly like the payload.
    """
    k, n = flat.shape
    bk = min(block_k, -(-k // 8) * 8)
    bn = min(block_n, -(-n // 128) * 128)
    k_p = -(-k // bk) * bk
    n_p = -(-n // bn) * bn
    flat = jnp.pad(flat.astype(F32), ((0, k_p - k), (0, n_p - n)))
    u = jnp.pad(u.astype(F32), ((0, k_p - k), (0, n_p - n)))
    if scales.ndim == 1:
        scales = jnp.pad(scales.astype(F32), (0, k_p - k),
                         constant_values=1.0)
        scales_op = jnp.broadcast_to(scales[:, None], (k_p, 128))
        kernel = _qdq_kernel
        scale_spec = pl.BlockSpec((bk, 128), lambda i, j: (i, 0))
    else:
        scales_op = jnp.pad(scales.astype(F32),
                            ((0, k_p - k), (0, n_p - n)),
                            constant_values=1.0)
        kernel = _qdq_kernel_2d
        scale_spec = pl.BlockSpec((bk, bn), lambda i, j: (i, j))

    out = pl.pallas_call(
        functools.partial(kernel, qmax=qmax),
        grid=(k_p // bk, n_p // bn),
        in_specs=[
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),    # payload rows
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),    # uniforms
            scale_spec,                                     # per-row scales
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k_p, n_p), F32),
        interpret=interpret,
    )(flat, u, scales_op)
    return out[:k, :n]
