"""Fused CCO-statistics Pallas TPU kernel.

The DCCO hot spot: per-cohort encoding statistics
    mean_f, E[f^2], mean_g, E[g^2], E[f g^T]
over a batch of encodings (N, d). A naive implementation reads the
encodings from HBM five times (once per statistic); this kernel computes
all five in ONE pass: each (bn x bd) VMEM tile of zf/zg is loaded once,
the d x d cross-moment tile goes through the MXU, and the four vector
moments ride along on the VPU.

Grid: (d_i tiles, d_j tiles, batch tiles) — batch innermost so output
tiles stay resident in VMEM across the accumulation (revisited-output
pattern). Vector stats are written by the j==0 (resp. i==0) columns only.
Block sizes are multiples of 128 to align with MXU/VREG lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _stats_kernel(zf_ref, zg_ref, inv_n_ref,
                  cross_ref, mean_f_ref, sq_f_ref, mean_g_ref, sq_g_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    kb = pl.program_id(2)
    inv_n = inv_n_ref[0]

    zf = zf_ref[...].astype(F32)          # (bn, bdi)
    zg = zg_ref[...].astype(F32)          # (bn, bdj)

    @pl.when(kb == 0)
    def _init():
        cross_ref[...] = jnp.zeros_like(cross_ref)

    cross_ref[...] += jax.lax.dot_general(
        zf, zg, (((0,), (0,)), ((), ())),
        preferred_element_type=F32) * inv_n

    @pl.when(j == 0)
    def _f_stats():
        @pl.when(kb == 0)
        def _init_f():
            mean_f_ref[...] = jnp.zeros_like(mean_f_ref)
            sq_f_ref[...] = jnp.zeros_like(sq_f_ref)
        mean_f_ref[...] += jnp.sum(zf, axis=0) * inv_n
        sq_f_ref[...] += jnp.sum(zf * zf, axis=0) * inv_n

    @pl.when(i == 0)
    def _g_stats():
        @pl.when(kb == 0)
        def _init_g():
            mean_g_ref[...] = jnp.zeros_like(mean_g_ref)
            sq_g_ref[...] = jnp.zeros_like(sq_g_ref)
        mean_g_ref[...] += jnp.sum(zg, axis=0) * inv_n
        sq_g_ref[...] += jnp.sum(zg * zg, axis=0) * inv_n


@functools.partial(jax.jit, static_argnames=("block_n", "block_d", "interpret"))
def cco_stats_pallas(zf, zg, num_valid=None, *, block_n: int = 512,
                     block_d: int = 256, interpret: bool = False):
    """zf, zg: (N, d) -> dict of the five statistics (all f32).

    N and d are padded to block multiples internally (zero padding is exact
    for sums; the 1/N scale uses the true N). ``num_valid`` (a traced scalar)
    overrides the normalizer — used with pre-masked encodings (rows zeroed
    for padding samples) so variable-size cohorts normalize by the true
    sample count instead of the padded N.
    """
    n, d = zf.shape
    bn = min(block_n, max(n, 8))
    bd = min(block_d, d)
    n_p = -(-n // bn) * bn
    d_p = -(-d // bd) * bd
    if n_p != n or d_p != d:
        zf = jnp.pad(zf, ((0, n_p - n), (0, d_p - d)))
        zg = jnp.pad(zg, ((0, n_p - n), (0, d_p - d)))
    gi, gj, gk = d_p // bd, d_p // bd, n_p // bn
    if num_valid is None:
        inv_n = jnp.full((1,), 1.0 / n, F32)
    else:
        inv_n = (1.0 / jnp.maximum(num_valid, 1.0)).reshape(1).astype(F32)

    out_shapes = (
        jax.ShapeDtypeStruct((d_p, d_p), F32),   # cross
        jax.ShapeDtypeStruct((d_p,), F32),       # mean_f
        jax.ShapeDtypeStruct((d_p,), F32),       # sq_f
        jax.ShapeDtypeStruct((d_p,), F32),       # mean_g
        jax.ShapeDtypeStruct((d_p,), F32),       # sq_g
    )
    grid = (gi, gj, gk)
    cross, mean_f, sq_f, mean_g, sq_g = pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, kb: (kb, i)),   # zf
            pl.BlockSpec((bn, bd), lambda i, j, kb: (kb, j)),   # zg
            pl.BlockSpec((1,), lambda i, j, kb: (0,)),          # inv_n scalar
        ],
        out_specs=(
            pl.BlockSpec((bd, bd), lambda i, j, kb: (i, j)),
            pl.BlockSpec((bd,), lambda i, j, kb: (i,)),
            pl.BlockSpec((bd,), lambda i, j, kb: (i,)),
            pl.BlockSpec((bd,), lambda i, j, kb: (j,)),
            pl.BlockSpec((bd,), lambda i, j, kb: (j,)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(zf, zg, inv_n)
    return {
        "mean_f": mean_f[:d], "sq_f": sq_f[:d],
        "mean_g": mean_g[:d], "sq_g": sq_g[:d],
        "cross": cross[:d, :d],
    }
