"""Fused encoding-statistics Pallas TPU kernel.

The stats-objective hot spot: per-cohort encoding statistics
    mean_f, E[f^2], mean_g, E[g^2], E[f g^T]
over a batch of encodings (N, d). A naive implementation reads the
encodings from HBM once per statistic; this kernel computes all of them
in ONE pass: each (bn x bd) VMEM tile of zf/zg is loaded once, the d x d
moment tiles go through the MXU, and the four vector moments ride along
on the VPU.

``moments`` selects the moment set (the StatsObjective protocol's
``second_moments`` flag): ``"cross"`` emits CCO's five statistics —
byte-for-byte the historical kernel, same pallas_call — while ``"full"``
additionally emits the within-view second moments E[f f^T], E[g g^T]
that the VICReg / W-MSE objectives need, by carrying two extra
j-/i-indexed views of the same inputs so each grid cell can form the
(i, j) tiles of all three d x d moments. The extra MXU work is measured,
not guessed: benchmarks/run.py::stats_kernel_bench times both moment
sets and the one-pass-vs-naive ratio is gated in CI.

Grid: (d_i tiles, d_j tiles, batch tiles) — batch innermost so output
tiles stay resident in VMEM across the accumulation (revisited-output
pattern). Vector stats are written by the j==0 (resp. i==0) columns only.
Block sizes are multiples of 128 to align with MXU/VREG lanes.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
import jax.numpy as jnp

F32 = jnp.float32


def _stats_kernel(zf_ref, zg_ref, inv_n_ref,
                  cross_ref, mean_f_ref, sq_f_ref, mean_g_ref, sq_g_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    kb = pl.program_id(2)
    inv_n = inv_n_ref[0]

    zf = zf_ref[...].astype(F32)          # (bn, bdi)
    zg = zg_ref[...].astype(F32)          # (bn, bdj)

    @pl.when(kb == 0)
    def _init():
        cross_ref[...] = jnp.zeros_like(cross_ref)

    cross_ref[...] += jax.lax.dot_general(
        zf, zg, (((0,), (0,)), ((), ())),
        preferred_element_type=F32) * inv_n

    @pl.when(j == 0)
    def _f_stats():
        @pl.when(kb == 0)
        def _init_f():
            mean_f_ref[...] = jnp.zeros_like(mean_f_ref)
            sq_f_ref[...] = jnp.zeros_like(sq_f_ref)
        mean_f_ref[...] += jnp.sum(zf, axis=0) * inv_n
        sq_f_ref[...] += jnp.sum(zf * zf, axis=0) * inv_n

    @pl.when(i == 0)
    def _g_stats():
        @pl.when(kb == 0)
        def _init_g():
            mean_g_ref[...] = jnp.zeros_like(mean_g_ref)
            sq_g_ref[...] = jnp.zeros_like(sq_g_ref)
        mean_g_ref[...] += jnp.sum(zg, axis=0) * inv_n
        sq_g_ref[...] += jnp.sum(zg * zg, axis=0) * inv_n


def _stats_kernel_full(zf_ref, zg_ref, zfj_ref, zgi_ref, inv_n_ref,
                       cross_ref, mean_f_ref, sq_f_ref, mean_g_ref, sq_g_ref,
                       cov_f_ref, cov_g_ref):
    """The "full" moment set: the five CCO statistics plus the two
    within-view second moments. ``zfj``/``zgi`` are the same inputs under
    the opposite (j-/i-indexed) block maps, so this cell can form the
    (i, j) tiles of cov_f = zf_i^T zf_j and cov_g = zg_i^T zg_j alongside
    cross = zf_i^T zg_j — still a single pass over the batch. The
    within-view moments are symmetric, so their MXU accumulations run only
    on the upper block triangle (j >= i; tile (j, i) is the transpose of
    (i, j)) and the host mirrors the strict-upper blocks down afterwards —
    the strict-lower tiles are initialized to zero and never revisited."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    kb = pl.program_id(2)
    inv_n = inv_n_ref[0]

    zf = zf_ref[...].astype(F32)          # (bn, bdi)
    zg = zg_ref[...].astype(F32)          # (bn, bdj)
    zfj = zfj_ref[...].astype(F32)        # (bn, bdj)
    zgi = zgi_ref[...].astype(F32)        # (bn, bdi)

    @pl.when(kb == 0)
    def _init():
        cross_ref[...] = jnp.zeros_like(cross_ref)
        cov_f_ref[...] = jnp.zeros_like(cov_f_ref)
        cov_g_ref[...] = jnp.zeros_like(cov_g_ref)

    dot = functools.partial(jax.lax.dot_general,
                            dimension_numbers=(((0,), (0,)), ((), ())),
                            preferred_element_type=F32)
    cross_ref[...] += dot(zf, zg) * inv_n

    @pl.when(j >= i)
    def _within_view():
        cov_f_ref[...] += dot(zf, zfj) * inv_n
        cov_g_ref[...] += dot(zgi, zg) * inv_n

    @pl.when(j == 0)
    def _f_stats():
        @pl.when(kb == 0)
        def _init_f():
            mean_f_ref[...] = jnp.zeros_like(mean_f_ref)
            sq_f_ref[...] = jnp.zeros_like(sq_f_ref)
        mean_f_ref[...] += jnp.sum(zf, axis=0) * inv_n
        sq_f_ref[...] += jnp.sum(zf * zf, axis=0) * inv_n

    @pl.when(i == 0)
    def _g_stats():
        @pl.when(kb == 0)
        def _init_g():
            mean_g_ref[...] = jnp.zeros_like(mean_g_ref)
            sq_g_ref[...] = jnp.zeros_like(sq_g_ref)
        mean_g_ref[...] += jnp.sum(zg, axis=0) * inv_n
        sq_g_ref[...] += jnp.sum(zg * zg, axis=0) * inv_n


@functools.partial(jax.jit, static_argnames=("block_n", "block_d",
                                             "interpret", "moments"))
def cco_stats_pallas(zf, zg, num_valid=None, *, block_n: int = 512,
                     block_d: int = 256, interpret: bool = False,
                     moments: str = "cross"):
    """zf, zg: (N, d) -> dict of encoding statistics (all f32).

    ``moments="cross"`` (default) emits the five CCO statistics through
    the historical kernel — bit-identical to the pre-flag behavior.
    ``moments="full"`` additionally emits the within-view second moments
    ``cov_f``/``cov_g`` (the VICReg / W-MSE moment set) in the same
    single pass.

    N and d are padded to block multiples internally (zero padding is exact
    for sums; the 1/N scale uses the true N). ``num_valid`` (a traced scalar)
    overrides the normalizer — used with pre-masked encodings (rows zeroed
    for padding samples) so variable-size cohorts normalize by the true
    sample count instead of the padded N; for a binary mask the pre-masked
    second moments are exact too ((m·f)(m·f) = m·f²).
    """
    if moments not in ("cross", "full"):
        raise ValueError(f"unknown moment set {moments!r}; "
                         f"expected 'cross' or 'full'")
    n, d = zf.shape
    bn = min(block_n, max(n, 8))
    bd = min(block_d, d)
    n_p = -(-n // bn) * bn
    d_p = -(-d // bd) * bd
    if n_p != n or d_p != d:
        zf = jnp.pad(zf, ((0, n_p - n), (0, d_p - d)))
        zg = jnp.pad(zg, ((0, n_p - n), (0, d_p - d)))
    gi, gj, gk = d_p // bd, d_p // bd, n_p // bn
    if num_valid is None:
        inv_n = jnp.full((1,), 1.0 / n, F32)
    else:
        inv_n = (1.0 / jnp.maximum(num_valid, 1.0)).reshape(1).astype(F32)

    out_shapes = (
        jax.ShapeDtypeStruct((d_p, d_p), F32),   # cross
        jax.ShapeDtypeStruct((d_p,), F32),       # mean_f
        jax.ShapeDtypeStruct((d_p,), F32),       # sq_f
        jax.ShapeDtypeStruct((d_p,), F32),       # mean_g
        jax.ShapeDtypeStruct((d_p,), F32),       # sq_g
    )
    grid = (gi, gj, gk)
    in_specs = [
        pl.BlockSpec((bn, bd), lambda i, j, kb: (kb, i)),   # zf
        pl.BlockSpec((bn, bd), lambda i, j, kb: (kb, j)),   # zg
    ]
    out_specs = (
        pl.BlockSpec((bd, bd), lambda i, j, kb: (i, j)),
        pl.BlockSpec((bd,), lambda i, j, kb: (i,)),
        pl.BlockSpec((bd,), lambda i, j, kb: (i,)),
        pl.BlockSpec((bd,), lambda i, j, kb: (j,)),
        pl.BlockSpec((bd,), lambda i, j, kb: (j,)),
    )
    inv_n_spec = pl.BlockSpec((1,), lambda i, j, kb: (0,))
    if moments == "cross":
        cross, mean_f, sq_f, mean_g, sq_g = pl.pallas_call(
            _stats_kernel,
            grid=grid,
            in_specs=in_specs + [inv_n_spec],
            out_specs=out_specs,
            out_shape=out_shapes,
            interpret=interpret,
        )(zf, zg, inv_n)
        cov = ()
    else:
        # the j-/i-indexed views of the SAME arrays; no host copies, just
        # different block maps feeding the within-view moment tiles
        cross, mean_f, sq_f, mean_g, sq_g, cov_f, cov_g = pl.pallas_call(
            _stats_kernel_full,
            grid=grid,
            in_specs=in_specs + [
                pl.BlockSpec((bn, bd), lambda i, j, kb: (kb, j)),   # zf_j
                pl.BlockSpec((bn, bd), lambda i, j, kb: (kb, i)),   # zg_i
                inv_n_spec,
            ],
            out_specs=out_specs + (
                pl.BlockSpec((bd, bd), lambda i, j, kb: (i, j)),
                pl.BlockSpec((bd, bd), lambda i, j, kb: (i, j)),
            ),
            out_shape=out_shapes + (
                jax.ShapeDtypeStruct((d_p, d_p), F32),   # cov_f
                jax.ShapeDtypeStruct((d_p, d_p), F32),   # cov_g
            ),
            interpret=interpret,
        )(zf, zg, zf, zg, inv_n)
        # mirror the strict-upper block triangle into the (zeroed)
        # strict-lower blocks; diagonal blocks were accumulated once
        blk = jnp.arange(d_p) // bd
        strict_upper = blk[:, None] < blk[None, :]
        cov_f = cov_f + jnp.where(strict_upper, cov_f, 0.0).T
        cov_g = cov_g + jnp.where(strict_upper, cov_g, 0.0).T
        cov = (("cov_f", cov_f), ("cov_g", cov_g))
    out = {
        "mean_f": mean_f[:d], "sq_f": sq_f[:d],
        "mean_g": mean_g[:d], "sq_g": sq_g[:d],
        "cross": cross[:d, :d],
    }
    for k, v in cov:
        out[k] = v[:d, :d]
    return out
