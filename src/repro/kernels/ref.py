"""Pure-jnp oracles for the Pallas kernels (ground truth for allclose tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


def cco_stats_ref(zf, zg, second_moments: bool = False):
    """Encoding statistics of the stats-objective family (paper Eq. 2-3).

    zf, zg: (N, d). Returns dict of f32: mean_f/sq_f/mean_g/sq_g (d,),
    cross (d, d); with ``second_moments`` also the within-view moments
    cov_f/cov_g (d, d) — the oracle for ``cco_stats_pallas`` in both
    moment sets."""
    zf = zf.astype(F32)
    zg = zg.astype(F32)
    n = zf.shape[0]
    st = {
        "mean_f": zf.mean(0),
        "sq_f": (zf * zf).mean(0),
        "mean_g": zg.mean(0),
        "sq_g": (zg * zg).mean(0),
        "cross": zf.T @ zg / n,
    }
    if second_moments:
        st["cov_f"] = zf.T @ zf / n
        st["cov_g"] = zg.T @ zg / n
    return st


def segment_sum_ref(rows, seg_ids, num_segments: int, weights=None):
    """Weighted segment sum — the oracle for ``segment_sum_pallas``.

    rows: (K, d) per-client stat rows, seg_ids: (K,) int32 edge ids in
    [0, num_segments) (ids outside the range contribute nothing — padding
    rows use ``num_segments``), weights: optional (K,) f32. Returns
    (num_segments, d) f32: out[e] = sum_{k: seg_ids[k]==e} w_k * rows[k].
    """
    rows = rows.astype(F32)
    if weights is not None:
        rows = rows * weights.astype(F32)[:, None]
    return jax.ops.segment_sum(rows, seg_ids, num_segments=num_segments)


def mips_topk_ref(q, corpus, k: int):
    """Naive maximum-inner-product top-k — the oracle for ``mips_topk``.

    Materializes the full (Q, N) score matrix (one f32 dot per element,
    full depth — the same contraction the kernel computes per tile) and
    ranks it with ``jax.lax.top_k``, whose stable sort breaks ties toward
    the lowest corpus index — the order the kernel's lowest-index-first
    selection reproduces bit-for-bit. Returns ((Q, k) f32, (Q, k) i32).
    """
    s = jax.lax.dot_general(q.astype(F32), corpus.astype(F32),
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=F32)
    vals, idxs = jax.lax.top_k(s, k)
    return vals, idxs.astype(jnp.int32)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float | None = None):
    """q: (B,H,Sq,Dh), k/v: (B,KVH,Skv,Dh) -> (B,H,Sq,Dh).

    Queries are assumed to be the LAST Sq positions of the Skv context
    (self-attention when Sq == Skv)."""
    b, h, sq, dh = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)
    qg = q.reshape(b, kvh, g, sq, dh)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(F32), k.astype(F32)) * scale
    q_pos = jnp.arange(skv - sq, skv)
    kv_pos = jnp.arange(skv)
    m = jnp.ones((sq, skv), bool)
    if causal:
        m &= kv_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= kv_pos[None, :] > (q_pos[:, None] - window)
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(F32))
    return o.reshape(b, h, sq, dh).astype(q.dtype)
