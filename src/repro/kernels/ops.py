"""jit'd public wrappers for the Pallas kernels.

On TPU the Mosaic kernels run natively; on CPU (this container) they run in
interpret mode so the whole stack stays executable. ``use_pallas=False``
falls back to the pure-jnp reference (the path the XLA dry-run lowers).
"""
from __future__ import annotations


import jax

from repro.kernels import ref
from repro.kernels.cco_stats import cco_stats_pallas
from repro.kernels.flash_attention import flash_attention_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def cco_stats(zf, zg, *, use_pallas: bool = True, block_n: int = 512,
              block_d: int = 256):
    """Fused five-statistics op (see kernels/cco_stats.py)."""
    if not use_pallas:
        return ref.cco_stats_ref(zf, zg)
    return cco_stats_pallas(zf, zg, block_n=block_n, block_d=block_d,
                            interpret=not _on_tpu())


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    use_pallas: bool = True, block_q: int = 256,
                    block_kv: int = 512):
    """Blockwise GQA attention op (see kernels/flash_attention.py)."""
    if not use_pallas:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_kv=block_kv,
                                  interpret=not _on_tpu())
