"""Fused maximum-inner-product-search + top-k Pallas TPU kernel.

The retrieval serving hot path (repro.retrieval): score Q normalized query
embeddings against an N-row corpus and keep each query's k best items. The
naive formulation materializes the full (Q, N) score matrix and then sorts
it — O(Q*N) HBM traffic and residency, which is exactly what kills serving
at corpus scale. This kernel fuses the two: (bq, d) query tiles and (bn, d)
corpus tiles are staged in VMEM, scores go through the MXU one (bq, bn)
tile at a time, and a running top-k (values + indices) lives in VMEM
scratch that persists across the innermost (corpus-block) grid dimension —
the same running-state idiom as flash_attention's (m, l) online softmax.
The (Q, N) matrix never exists anywhere in the memory hierarchy.

Selection: TPU Pallas has no in-kernel sort, so each merge runs k rounds of
(max, smallest-index-argmax, mask) over the (bq, k + bn) candidate row —
k is small (<= ~32) and the loop is unrolled at trace time. Ties break
toward the LOWEST corpus index, matching ``jax.lax.top_k``'s stable order,
so the kernel is bit-identical to ``ref.mips_topk_ref`` (scores are
computed by one full-depth f32 dot per element — d is never tiled, so no
re-association).

Three execution paths, one wrapper (``mips_topk``):
  * pallas   — the compiled TPU kernel;
  * interpret— the same kernel under the Pallas interpreter (CPU CI);
  * chunked  — pure-jnp lax.scan over corpus chunks carrying the running
               top-k (``mips_topk_chunked``): the CPU fallback with the
               same O(Q*chunk) peak memory and the same tie order.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import jax.numpy as jnp

F32 = jnp.float32
I32 = jnp.int32
NEG_INF = -1e30         # sentinel score for padded corpus rows / used slots
BIG_IDX = 2 ** 30       # sentinel index (beats any real corpus index in min)


def _select_topk(cand_v, cand_i, k: int):
    """k rounds of (max, lowest-index pick, mask) over candidate rows.

    cand_v, cand_i: (m, c) f32 scores and i32 corpus indices. Returns
    ((m, k) values, (m, k) indices) sorted by descending value, ties by
    ascending index — exactly ``jax.lax.top_k``'s stable order. Sentinel
    (NEG_INF, BIG_IDX) pairs flow through harmlessly: they are only
    emitted when fewer than k real candidates exist, which the wrappers
    exclude (k <= N).
    """
    outs_v, outs_i = [], []
    for _ in range(k):
        m = jnp.max(cand_v, axis=1)
        at_max = cand_v == m[:, None]
        pick = jnp.min(jnp.where(at_max, cand_i, BIG_IDX), axis=1)
        taken = at_max & (cand_i == pick[:, None])
        cand_v = jnp.where(taken, NEG_INF, cand_v)
        outs_v.append(m)
        outs_i.append(pick)
    return jnp.stack(outs_v, axis=1), jnp.stack(outs_i, axis=1)


def _mips_kernel(q_ref, c_ref, v_ref, i_ref, v_scr, i_scr,
                 *, k: int, bq: int, bn: int, n_total: int):
    ik = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ik == 0)
    def _init():
        v_scr[...] = jnp.full_like(v_scr, NEG_INF)
        i_scr[...] = jnp.full_like(i_scr, BIG_IDX)

    q = q_ref[...].astype(F32)                     # (bq, d)
    c = c_ref[...].astype(F32)                     # (bn, d)
    s = jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32)       # (bq, bn)
    n_pos = ik * bn + jax.lax.broadcasted_iota(I32, (bq, bn), 1)
    valid = n_pos < n_total
    s = jnp.where(valid, s, NEG_INF)
    n_idx = jnp.where(valid, n_pos, BIG_IDX)

    cand_v = jnp.concatenate([v_scr[...], s], axis=1)         # (bq, k + bn)
    cand_i = jnp.concatenate([i_scr[...], n_idx], axis=1)
    new_v, new_i = _select_topk(cand_v, cand_i, k)
    v_scr[...] = new_v
    i_scr[...] = new_i

    @pl.when(ik == nk - 1)
    def _finish():
        v_ref[...] = v_scr[...]
        i_ref[...] = i_scr[...]


def _mips_kernel_offset(off_ref, q_ref, c_ref, v_ref, i_ref, v_scr, i_scr,
                        *, k: int, bq: int, bn: int, n_total: int,
                        n_local: int):
    """The shard-local variant: rows are a contiguous slice of a global
    corpus starting at ``off_ref[0, 0]`` (SMEM scalar), ``n_local`` is the
    UNPADDED local row count and ``n_total`` the GLOBAL corpus size. Two
    kinds of rows must mask to (NEG_INF, BIG_IDX): local block-padding
    rows (local position >= n_local — for a non-last shard their global
    position is a valid index belonging to the NEXT shard, so the global
    check alone cannot catch them) and rows past the global end (the
    ragged last shard). Emitted indices are global, so a cross-shard merge
    inherits the lowest-global-index tie order for free."""
    ik = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ik == 0)
    def _init():
        v_scr[...] = jnp.full_like(v_scr, NEG_INF)
        i_scr[...] = jnp.full_like(i_scr, BIG_IDX)

    q = q_ref[...].astype(F32)                     # (bq, d)
    c = c_ref[...].astype(F32)                     # (bn, d)
    s = jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32)       # (bq, bn)
    local_pos = ik * bn + jax.lax.broadcasted_iota(I32, (bq, bn), 1)
    n_pos = off_ref[0, 0] + local_pos
    valid = (local_pos < n_local) & (n_pos < n_total)
    s = jnp.where(valid, s, NEG_INF)
    n_idx = jnp.where(valid, n_pos, BIG_IDX)

    cand_v = jnp.concatenate([v_scr[...], s], axis=1)         # (bq, k + bn)
    cand_i = jnp.concatenate([i_scr[...], n_idx], axis=1)
    new_v, new_i = _select_topk(cand_v, cand_i, k)
    v_scr[...] = new_v
    i_scr[...] = new_i

    @pl.when(ik == nk - 1)
    def _finish():
        v_ref[...] = v_scr[...]
        i_ref[...] = i_scr[...]


@functools.partial(jax.jit, static_argnames=("k", "block_q", "block_n",
                                             "interpret", "n_total"))
def mips_topk_pallas(q, corpus, *, k: int, block_q: int = 128,
                     block_n: int = 512, interpret: bool = False,
                     index_offset=None, n_total: int | None = None):
    """q: (Q, d), corpus: (N, d) -> ((Q, k) f32 scores, (Q, k) i32 indices).

    Scores are plain inner products (callers normalize for cosine). Ragged
    Q/N pad up to block multiples; padded corpus rows are masked to
    (NEG_INF, BIG_IDX) positionally in-kernel, padded query rows are
    sliced off the output.

    ``index_offset`` (traced i32 scalar) switches to the shard-local
    variant: ``corpus`` is rows [offset, offset + N) of a global corpus of
    ``n_total`` rows (static), emitted indices are global, and both local
    block-padding rows and rows past the global end mask to sentinels.
    ``index_offset=None`` (default) compiles the exact pre-offset program.
    """
    qn, d = q.shape
    n, d2 = corpus.shape
    if d != d2:
        raise ValueError(f"query dim {d} != corpus dim {d2}")
    if not 1 <= k <= n:
        raise ValueError(f"k={k} must be in [1, corpus size {n}]")
    bq = min(block_q, qn)
    bn = min(block_n, n)
    q_pad = (-qn) % bq
    n_pad = (-n) % bn
    if q_pad:
        q = jnp.pad(q, ((0, q_pad), (0, 0)))
    if n_pad:
        corpus = jnp.pad(corpus, ((0, n_pad), (0, 0)))
    grid = ((qn + q_pad) // bq, (n + n_pad) // bn)

    nt = n if n_total is None else n_total
    if index_offset is None:
        # offset == 0, so global position == local position: folding the
        # local row count into n_total masks block padding and the global
        # end with the kernel's single check.
        kernel = functools.partial(_mips_kernel, k=k, bq=bq, bn=bn,
                                   n_total=min(n, nt))
        in_specs = [
            pl.BlockSpec((bq, d), lambda iq, ik: (iq, 0)),
            pl.BlockSpec((bn, d), lambda iq, ik: (ik, 0)),
        ]
        operands = (q, corpus)
    else:
        kernel = functools.partial(_mips_kernel_offset, k=k, bq=bq, bn=bn,
                                   n_total=nt, n_local=n)
        off = jnp.asarray(index_offset, I32).reshape(1, 1)   # SMEM scalar
        in_specs = [
            pl.BlockSpec((1, 1), lambda iq, ik: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((bq, d), lambda iq, ik: (iq, 0)),
            pl.BlockSpec((bn, d), lambda iq, ik: (ik, 0)),
        ]
        operands = (off, q, corpus)
    vals, idxs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bq, k), lambda iq, ik: (iq, 0)),
            pl.BlockSpec((bq, k), lambda iq, ik: (iq, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn + q_pad, k), F32),
            jax.ShapeDtypeStruct((qn + q_pad, k), I32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, k), F32),     # running top-k values
            pltpu.VMEM((bq, k), I32),     # running top-k corpus indices
        ],
        interpret=interpret,
    )(*operands)
    return vals[:qn], idxs[:qn]


@functools.partial(jax.jit, static_argnames=("k", "chunk", "n_total"))
def mips_topk_chunked(q, corpus, *, k: int, chunk: int = 512,
                      index_offset=None, n_total: int | None = None):
    """Pure-jnp fallback: lax.scan over corpus chunks carrying the running
    top-k — same O(Q*chunk) peak memory and the same lowest-index tie
    order as the kernel (the running list keeps equal values in ascending
    corpus-index order, new chunks append strictly larger indices, and
    ``lax.top_k`` is stable — so the merge preserves the global order).

    ``index_offset``/``n_total`` mirror ``mips_topk_pallas``'s shard-local
    contract: indices come out global, and chunk-padding rows as well as
    rows past the global end mask to sentinels. ``index_offset`` may be a traced scalar (it is a
    ``lax.axis_index`` product under ``shard_map``); ``index_offset=None``
    (default) traces the exact pre-offset program.
    """
    qn, d = q.shape
    n, d2 = corpus.shape
    if d != d2:
        raise ValueError(f"query dim {d} != corpus dim {d2}")
    if not 1 <= k <= n:
        raise ValueError(f"k={k} must be in [1, corpus size {n}]")
    ch = min(chunk, n)
    n_pad = (-n) % ch
    if n_pad:
        corpus = jnp.pad(corpus, ((0, n_pad), (0, 0)))
    q = q.astype(F32)
    corpus = corpus.astype(F32)
    num_chunks = (n + n_pad) // ch
    nt = n if n_total is None else n_total

    def body(carry, c):
        vals, idxs = carry
        block = jax.lax.dynamic_slice_in_dim(corpus, c * ch, ch)
        s = jax.lax.dot_general(q, block, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32)   # (Q, ch)
        local_pos = c * ch + jnp.arange(ch, dtype=I32)
        pos = local_pos
        if index_offset is not None:
            pos = pos + jnp.asarray(index_offset, I32)
        # mask chunk-padding rows by LOCAL position too: under an offset
        # their global position can be a valid next-shard index, so the
        # global check alone would emit (0.0, wrong-index) candidates
        valid = (local_pos < n) & (pos < nt)
        s = jnp.where(valid[None, :], s, NEG_INF)
        pos = jnp.where(valid, pos, BIG_IDX)
        cand_v = jnp.concatenate([vals, s], axis=1)
        cand_i = jnp.concatenate(
            [idxs, jnp.broadcast_to(pos[None, :], s.shape).astype(I32)],
            axis=1)
        new_v, at = jax.lax.top_k(cand_v, k)
        new_i = jnp.take_along_axis(cand_i, at, axis=1)
        return (new_v, new_i), None

    init = (jnp.full((qn, k), NEG_INF, F32),
            jnp.full((qn, k), BIG_IDX, I32))
    (vals, idxs), _ = jax.lax.scan(body, init,
                                   jnp.arange(num_chunks, dtype=I32))
    return vals, idxs


def mips_topk(q, corpus, k: int, *, backend: str = "auto",
              block_q: int = 128, block_n: int = 512, chunk: int = 512,
              interpret: bool = False, index_offset=None,
              n_total: int | None = None):
    """Top-k maximum-inner-product search, backend-dispatched.

    backend: "auto" (pallas on accelerators, chunked jnp on CPU) |
    "pallas" | "interpret" (pallas under the interpreter) | "chunked".
    Returns ((Q, k) f32 scores, (Q, k) i32 corpus indices), descending
    score, ties by ascending index. Every path keeps peak memory at
    O(Q * block) — the (Q, N) score matrix is never materialized.

    ``index_offset``/``n_total`` select the shard-local variant on every
    backend (see mips_topk_pallas): ``corpus`` is a contiguous slice of a
    global ``n_total``-row corpus starting at ``index_offset``, indices
    come out global — the primitive repro.retrieval.sharded builds its
    bit-exact cross-shard merge on.
    """
    if backend == "auto":
        backend = "chunked" if jax.default_backend() == "cpu" else "pallas"
    if backend in ("pallas", "interpret"):
        return mips_topk_pallas(q, corpus, k=k, block_q=block_q,
                                block_n=block_n,
                                interpret=interpret or backend == "interpret",
                                index_offset=index_offset, n_total=n_total)
    if backend == "chunked":
        return mips_topk_chunked(q, corpus, k=k, chunk=chunk,
                                 index_offset=index_offset, n_total=n_total)
    raise ValueError(f"unknown mips_topk backend {backend!r}; expected "
                     f"auto | pallas | interpret | chunked")
