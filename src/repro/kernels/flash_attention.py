"""Blockwise (flash-style) attention Pallas TPU kernel.

Causal/sliding-window GQA attention with online softmax. The backbone's
attention hot spot re-tiled for the TPU memory hierarchy: (bq x dh) Q tiles
and (bkv x dh) K/V tiles staged in VMEM, scores through the MXU, running
(m, l) statistics in VMEM scratch that persist across the innermost
(kv-block) grid dimension.

Layout: q (B, H, Sq, Dh); k, v (B, KVH, Skv, Dh); GQA is handled in the
index_map (query head h reads kv head h // group).

Queries are the last Sq positions of the Skv-long context (covers both
self-attention Sq == Skv and chunked prefill).
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool, window: int,
                  bq: int, bkv: int, q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(F32)                       # (bq, dh)
    k = k_ref[0, 0].astype(F32)                       # (bkv, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32) * scale  # (bq, bkv)

    q_pos = q_offset + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    kv_pos = ik * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    valid = jnp.ones((bq, bkv), jnp.bool_)
    if causal:
        valid &= kv_pos <= q_pos
    if window > 0:
        valid &= kv_pos > (q_pos - window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    v = v_ref[0, 0].astype(F32)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=F32)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_kv", "interpret", "scale"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           scale: float | None = None, block_q: int = 256,
                           block_kv: int = 512, interpret: bool = False):
    """q: (B,H,Sq,Dh); k, v: (B,KVH,Skv,Dh) -> (B,H,Sq,Dh)."""
    b, h, sq, dh = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    assert sq % bq == 0 and skv % bkv == 0, (
        f"seq dims ({sq},{skv}) must divide blocks ({bq},{bkv})")
    grid = (b, h, sq // bq, skv // bkv)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bkv=bkv, q_offset=skv - sq)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda bb, hh, iq, ik: (bb, hh, iq, 0)),
            pl.BlockSpec((1, 1, bkv, dh), lambda bb, hh, iq, ik: (bb, hh // (h // kvh), ik, 0)),
            pl.BlockSpec((1, 1, bkv, dh), lambda bb, hh, iq, ik: (bb, hh // (h // kvh), ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda bb, hh, iq, ik: (bb, hh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), F32),       # running max
            pltpu.VMEM((bq,), F32),       # running denom
            pltpu.VMEM((bq, dh), F32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
