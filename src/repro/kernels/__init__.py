from repro.kernels.ops import cco_stats, flash_attention  # noqa: F401
