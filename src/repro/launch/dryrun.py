import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the very first two lines — before ANY other import — because jax
# locks the device count on first initialization. Never set this globally
# (smoke tests / benches must see 1 device).
#
# Multi-pod dry-run: AOT lower + compile every (arch x input-shape) on the
# production mesh; records memory/cost/collective analysis for the roofline.
# Run as a script: ``PYTHONPATH=src python -m repro.launch.dryrun --all``.

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Any, Dict  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import ARCH_IDS, get_config, get_dual_encoder_config, \
    TrainConfig  # noqa: E402
from repro.launch import inputs as inp  # noqa: E402
from repro.launch import steps as steps_lib  # noqa: E402
from repro.launch.mesh import make_production_mesh, HardwareSpec  # noqa: E402
from repro.models import dual_encoder, transformer  # noqa: E402
from repro.optim import optimizers as opt_lib  # noqa: E402
from repro.sharding import specs as shard_specs  # noqa: E402

SDS = jax.ShapeDtypeStruct
DRYRUN_ARCHS = tuple(a for a in ARCH_IDS if a != "resnet14-cifar")
RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "dryrun_results.json")

_COLL_RE = re.compile(
    r"=\s*(?P<type>(?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]\S*))\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([\d,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
                "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1}


def _split_computations(hlo_text: str):
    """Split post-optimization HLO text into {name: block_text}."""
    comps = {}
    cur_name, cur_lines = None, []
    for line in hlo_text.splitlines():
        st = line.strip()
        if (st.startswith("%") or st.startswith("ENTRY")) and st.endswith("{") \
                and "(" in st and "->" in st:
            name = st.split()[1] if st.startswith("ENTRY") else st.split()[0]
            cur_name = name.lstrip("%").split(" ")[0]
            cur_lines = []
        elif st == "}" and cur_name is not None:
            comps[cur_name] = "\n".join(cur_lines)
            cur_name = None
        elif cur_name is not None:
            cur_lines.append(line)
    return comps


_WHILE_RE = re.compile(r"while\([^)]*\),\s*condition=%([\w\.\-]+),\s*body=%([\w\.\-]+)")
_TRIP_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _line_bytes(text: str) -> float:
    bytes_ = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        bytes_ += n * _DTYPE_BYTES[dt]
    return bytes_


def collective_stats(hlo_text: str) -> Dict[str, Any]:
    """Per-device collective bytes from the post-SPMD HLO, **scaled by while-
    loop trip counts** (XLA text lists each loop body once; jax scans lower
    to whiles whose bound is an s32 constant in the condition computation).

    Ring-model wire estimate: all-reduce ~ 2x payload; others ~ 1x.
    """
    comps = _split_computations(hlo_text)
    entry = None
    for name in comps:
        if name.startswith("main"):
            entry = name
    if entry is None:  # fallback: treat whole text as one block
        comps = {"main": hlo_text}
        entry = "main"

    def trip_count(cond_name: str) -> int:
        consts = [int(x) for x in _TRIP_RE.findall(comps.get(cond_name, ""))]
        consts = [c for c in consts if c > 1]
        return max(consts) if consts else 1

    per_op: Dict[str, float] = {}
    count: Dict[str, int] = {}
    wire = 0.0
    seen = set()

    def visit(name: str, mult: float):
        nonlocal wire
        if (name, mult) in seen or name not in comps:
            return
        seen.add((name, mult))
        block = comps[name]
        for m in _COLL_RE.finditer(block):
            if "-done(" in m.group(0):
                continue
            op = m.group("op")
            b = _line_bytes(m.group("type")) * mult
            per_op[op] = per_op.get(op, 0.0) + b
            count[op] = count.get(op, 0) + 1
            wire += b * (2.0 if op == "all-reduce" else 1.0)
        for wm in _WHILE_RE.finditer(block):
            cond, body = wm.group(1), wm.group(2)
            visit(body, mult * trip_count(cond))

    visit(entry, 1.0)
    return {"bytes_by_op": per_op, "count_by_op": count, "wire_bytes": wire,
            "total_bytes": sum(per_op.values())}


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _eval_shape_params(cfg, de_cfg, train: bool):
    key = SDS((2,), jnp.uint32)
    if train:
        fn = lambda k: dual_encoder.init_dual_encoder(k, cfg, de_cfg)
    else:
        fn = lambda k: transformer.init_params(cfg, k)
    return jax.eval_shape(fn, key)


def build_case(arch: str, shape_name: str, mesh, *, dcco_impl: str = "fused",
               remat: str = "auto", num_microbatches: int = 16,
               sharding: str = "tp", parallel_block: bool = False,
               kv_int8: bool = False, moe_group: int = 512):
    """Returns (step_fn, in_args_sds, in_shardings, out_shardings).

    Baseline training memory policy (required to fit 16 GiB v5e HBM at
    batch 256 x 4k x two views): remat on the layer scan + exact DCCO
    microbatching (stats pass then grad pass — Appendix A makes this
    lossless; see steps.make_dcco_train_step).
    """
    shape = inp.INPUT_SHAPES[shape_name]
    if remat == "auto":
        remat = "full" if shape.kind == "train" else "none"
    cfg = get_config(arch).replace(dtype="bfloat16", attn_impl="blockwise",
                                   remat=remat, parallel_block=parallel_block,
                                   kv_cache_dtype="int8" if kv_int8 else "model")
    cfg = inp.arch_variant_for_shape(cfg, shape)
    de_cfg = get_dual_encoder_config(arch)

    if shape.kind == "train":
        tcfg = TrainConfig(seq_len=shape.seq_len, global_batch=shape.global_batch,
                           samples_per_client=1, dcco_impl=dcco_impl)
        opt = opt_lib.adam(5e-3)
        all_axes = tuple(mesh.axis_names)
        data_ax = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        if sharding == "fsdp":
            # batch spread over every axis -> per-device activations shrink
            # by the model-axis factor; exact microbatching becomes
            # unnecessary (1 seq/device already fits with remat). Activation
            # shardings are pinned so SPMD gathers weights, not activations.
            num_microbatches = 1
            n_super = cfg.num_superblocks
            chunks = next((c for c in (6, 4, 3, 2) if n_super % c == 0), 1)
            cfg = cfg.replace(act_shard_axes=tuple(mesh.axis_names),
                              layer_chunks=chunks,
                              fsdp_model_size=dict(zip(
                                  mesh.axis_names,
                                  mesh.devices.shape))["model"])
        if dcco_impl == "shard_map":
            # protocol-faithful device-level DCCO: local stats -> explicit
            # psum over the data axes -> stop-grad combine (Fig. 2 on wire).
            # shard_map needs the concrete mesh; microbatching is bypassed.
            num_microbatches = 1
        step = steps_lib.make_dcco_train_step(
            cfg, de_cfg, tcfg, opt, num_microbatches=num_microbatches,
            constrain_sharding=True, data_axes=data_ax,
            mesh=mesh if dcco_impl == "shard_map" else None)
        params_sds = _eval_shape_params(cfg, de_cfg, train=True)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        batch_sds = inp.train_input_specs(cfg, shape)
        pspecs = shard_specs.param_pspecs(params_sds, mesh, mode=sharding)
        ospecs = shard_specs.opt_state_pspecs(
            shard_specs.param_pspecs(opt_sds, mesh, mode=sharding),
            opt_sds, mesh)  # ZeRO-1
        total_dev = int(np.prod(mesh.devices.shape))
        def _bspec(x):
            if sharding == "fsdp" and x.shape[0] % total_dev == 0:
                return P(all_axes, *([None] * (x.ndim - 1)))
            return shard_specs.batch_pspec(mesh, x.ndim, x.shape[0])
        bspecs = jax.tree.map(_bspec, batch_sds)
        mspecs = {"loss": P(), "encoding_std": P()}
        in_sh = (_named(mesh, pspecs), _named(mesh, ospecs), _named(mesh, bspecs))
        out_sh = (_named(mesh, pspecs), _named(mesh, ospecs), _named(mesh, mspecs))
        return step, (params_sds, opt_sds, batch_sds), in_sh, out_sh

    if shape.kind == "prefill":
        if sharding == "fsdp":
            # inference FSDP: weights stay model-sharded as storage and are
            # gathered once per layer; activations pinned batch-over-data.
            n_super = cfg.num_superblocks
            chunks = next((c for c in (6, 4, 3, 2) if n_super % c == 0), 1)
            cfg = cfg.replace(
                act_shard_axes=("pod", "data") if "pod" in mesh.axis_names
                else ("data",),
                layer_chunks=chunks,
                fsdp_model_size=dict(zip(mesh.axis_names,
                                         mesh.devices.shape))["model"])
        step = steps_lib.make_prefill_step(cfg, max_len=shape.seq_len)
        params_sds = _eval_shape_params(cfg, de_cfg, train=False)
        batch_sds = inp.prefill_input_specs(cfg, shape)
        pspecs = shard_specs.param_pspecs(params_sds, mesh, mode=sharding)
        bspecs = jax.tree.map(lambda x: shard_specs.batch_pspec(mesh, x.ndim, x.shape[0]), batch_sds)
        in_sh = (_named(mesh, pspecs), _named(mesh, bspecs))
        return step, (params_sds, batch_sds), in_sh, None

    # decode
    step = steps_lib.make_serve_step(cfg)
    params_sds = _eval_shape_params(cfg, de_cfg, train=False)
    cache_sds = jax.eval_shape(
        lambda: transformer.init_cache(cfg, shape.global_batch, shape.seq_len))
    batch_sds = inp.decode_input_specs(cfg, shape)
    pspecs = shard_specs.param_pspecs(params_sds, mesh)
    seq_shard = shape.global_batch == 1
    cspecs = shard_specs.cache_pspecs(cache_sds, mesh, seq_shard=seq_shard)
    bspecs = jax.tree.map(lambda x: shard_specs.batch_pspec(mesh, x.ndim, x.shape[0]), batch_sds)
    logits_spec = shard_specs.batch_pspec(mesh, 2, shape.global_batch)
    in_sh = (_named(mesh, pspecs), _named(mesh, cspecs), _named(mesh, bspecs))
    out_sh = (_named(mesh, logits_spec), _named(mesh, cspecs))
    return step, (params_sds, cache_sds, batch_sds), in_sh, out_sh


def run_case(arch: str, shape_name: str, multi_pod: bool, **kw) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    step, args, in_sh, out_sh = build_case(arch, shape_name, mesh, **kw)
    # donation: decode donates the cache (in-place update); train donates
    # params+opt state (outputs alias inputs). Halves the respective temps.
    kind = inp.INPUT_SHAPES[shape_name].kind
    donate = (1,) if kind == "decode" else (0, 1) if kind == "train" else ()
    with mesh:
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    t1 = time.time()
    try:
        mem = compiled.memory_analysis()
        mem_rec = {k: int(getattr(mem, k)) for k in
                   ("argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes")
                   if hasattr(mem, k)}
    except Exception as e:  # CPU backend may not implement it fully
        mem_rec = {"error": str(e)}
    try:
        cost = compiled.cost_analysis() or {}
        cost_rec = {k: float(v) for k, v in cost.items()
                    if isinstance(v, (int, float)) and (
                        "flops" in k or "bytes" in k or "utilization" in k.lower())}
        flops = float(cost.get("flops", 0.0))
        bytes_accessed = float(cost.get("bytes accessed", 0.0))
    except Exception as e:
        cost_rec, flops, bytes_accessed = {"error": str(e)}, 0.0, 0.0
    coll = collective_stats(compiled.as_text())
    chips = int(np.prod(mesh.devices.shape))
    hw = HardwareSpec
    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "chips": chips, "compile_s": round(t1 - t0, 2),
        "memory": mem_rec, "cost": cost_rec,
        "flops_per_device": flops, "bytes_per_device": bytes_accessed,
        "collectives": coll,
        "roofline": {
            "compute_s": flops / hw.PEAK_FLOPS_BF16,
            "memory_s": bytes_accessed / hw.HBM_BW,
            "collective_s": coll["wire_bytes"] / hw.ICI_BW,
        },
    }
    terms = rec["roofline"]
    rec["roofline"]["dominant"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    return rec


def load_results(path: str) -> Dict[str, Any]:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--out", default=os.path.normpath(RESULTS_PATH))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--dcco-impl", default="fused")
    ap.add_argument("--remat", default="auto")
    ap.add_argument("--micro", type=int, default=16)
    ap.add_argument("--sharding", choices=["tp", "fsdp"], default="tp")
    ap.add_argument("--parallel-block", action="store_true")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--bf16-comm", action="store_true",
                    help="bf16 matmul partial sums -> bf16 TP all-reduces")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(DRYRUN_ARCHS)
    shapes = [args.shape] if args.shape else list(inp.INPUT_SHAPES)
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]
    results = load_results(args.out)
    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mp in pods:
                key = f"{args.tag}/{arch}/{shape_name}/{'multi' if mp else 'single'}"
                if key in results and not args.force:
                    print(f"[skip cached] {key}")
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                if args.bf16_comm:
                    from repro.models import common as common_mod
                    common_mod.set_matmul_preferred(jnp.bfloat16)
                try:
                    rec = run_case(arch, shape_name, mp,
                                   dcco_impl=args.dcco_impl, remat=args.remat,
                                   num_microbatches=args.micro,
                                   sharding=args.sharding,
                                   parallel_block=args.parallel_block,
                                   kv_int8=args.kv_int8)
                    results[key] = rec
                    r = rec["roofline"]
                    print(f"  ok compile={rec['compile_s']}s "
                          f"compute={r['compute_s']:.4f}s mem={r['memory_s']:.4f}s "
                          f"coll={r['collective_s']:.4f}s dom={r['dominant']}",
                          flush=True)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((key, str(e)))
                    results[key] = {"error": str(e), "arch": arch,
                                    "shape": shape_name, "multi_pod": mp}
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    print(f"done. {len(failures)} failures")
    for k, e in failures:
        print(" FAIL", k, e[:200])
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
