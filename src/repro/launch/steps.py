"""Step builders: DCCO train step (the paper's technique at pod scale),
prefill and serve (decode) steps. Pure functions of (cfg, de_cfg, tcfg) so
the dry-run can lower them AOT against ShapeDtypeStructs.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core import cco, dcco
from repro.models import dual_encoder, transformer
from repro.optim import optimizers as opt_lib

F32 = jnp.float32


def make_dcco_train_step(cfg, de_cfg, tcfg, server_opt, mesh=None,
                         data_axes=("data",), num_microbatches: int = 1,
                         constrain_sharding: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). One federated DCCO round == one step (Appendix-A theorem);
    the client axis is the leading batch dim, sharded over (pod, data).

    num_microbatches > 1 enables EXACT microbatched large-batch CCO: the
    paper's statistics-aggregation trick applied inside the device —
    phase 1 scans microbatches accumulating the five statistics (no
    activations kept), phase 2 scans again taking per-microbatch gradients
    of L_CCO(local + sg(agg - local)); by Appendix A their average IS the
    full-batch gradient. Costs one extra forward (~33% FLOPs) and cuts
    live activation memory by the microbatch factor. (A naive microbatched
    CCO would compute small-batch statistics — exactly the degradation the
    paper exists to avoid.)
    """
    lam = de_cfg.lambda_cco
    clients = 0
    if tcfg.dcco_impl == "per_client":
        clients = tcfg.global_batch // tcfg.samples_per_client

    def add_aux(loss, aux):
        if cfg.moe is not None and cfg.moe.num_experts > 0:
            loss = loss + cfg.moe.balance_weight * aux["balance"] \
                + 1e-4 * aux["router_z"]
        return loss

    def loss_fn(params, batch):
        zf, zg, aux = dual_encoder.encode_pair(cfg, de_cfg, params,
                                               batch["view1"], batch["view2"])
        loss = add_aux(dcco.dcco_loss(zf, zg, lam, impl=tcfg.dcco_impl,
                                      clients=clients, mesh=mesh,
                                      data_axes=data_axes), aux)
        metrics = {"loss": loss,
                   "encoding_std": jnp.sqrt(jnp.var(zf, axis=0) + 1e-8).mean()}
        return loss, metrics

    def single_step(params, opt_state, batch):
        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        updates, opt_state = server_opt.update(grads, opt_state, params)
        params = opt_lib.apply_updates(params, updates)
        return params, opt_state, metrics

    if num_microbatches <= 1:
        return single_step

    def micro_step(params, opt_state, batch):
        nm = num_microbatches
        micro = jax.tree.map(
            lambda x: x.reshape((nm, x.shape[0] // nm) + x.shape[1:]), batch)
        if constrain_sharding:
            # keep the per-microbatch batch dim sharded over (pod, data) —
            # XLA's reshape propagation otherwise replicates it and the
            # remat-saved activations blow up by the data-parallel factor
            from jax.sharding import PartitionSpec as P
            ax = data_axes if len(data_axes) > 1 else data_axes[0]
            micro = jax.tree.map(
                lambda x: jax.lax.with_sharding_constraint(
                    x, P(None, ax, *([None] * (x.ndim - 2)))), micro)

        # phase 1: accumulate global statistics (forward only, no residuals)
        def stats_body(acc, mb):
            zf, zg, _ = dual_encoder.encode_pair(cfg, de_cfg, params,
                                                 mb["view1"], mb["view2"])
            st = cco.encoding_stats(zf, zg)
            return jax.tree.map(lambda a, s: a + s / nm, acc, st), None

        d_out = de_cfg.proj_dims[-1]
        zero_stats = {"mean_f": jnp.zeros((d_out,), F32),
                      "sq_f": jnp.zeros((d_out,), F32),
                      "mean_g": jnp.zeros((d_out,), F32),
                      "sq_g": jnp.zeros((d_out,), F32),
                      "cross": jnp.zeros((d_out, d_out), F32)}
        agg, _ = jax.lax.scan(stats_body, zero_stats, micro)
        agg = jax.lax.stop_gradient(agg)

        # phase 2: per-microbatch gradients with combined statistics.
        # Each view's tower is wrapped in jax.checkpoint: only the pooled
        # encodings are saved across the loss; towers are recomputed one at
        # a time in the backward pass, so a single view's activations are
        # live at any moment (2x less residual memory for +1 forward).
        def mb_loss(p, mb):
            enc_f = jax.checkpoint(
                lambda pp, v: dual_encoder.encode(cfg, de_cfg, pp, v, tower="f"))
            enc_g = jax.checkpoint(
                lambda pp, v: dual_encoder.encode(cfg, de_cfg, pp, v, tower="g"))
            zf, aux1 = enc_f(p, mb["view1"])
            zg, aux2 = enc_g(p, mb["view2"])
            aux = {k: aux1[k] + aux2[k] for k in aux1}
            local = cco.encoding_stats(zf, zg)
            combined = cco.dcco_combine(local, agg)
            loss = add_aux(cco.cco_loss_from_stats(combined, lam), aux)
            std = jnp.sqrt(jnp.var(zf, axis=0) + 1e-8).mean()
            return loss, std

        def grad_body(acc, mb):
            (loss, std), g = jax.value_and_grad(mb_loss, has_aux=True)(params, mb)
            acc = jax.tree.map(lambda a, gi: a + gi.astype(F32) / nm, acc, g)
            return acc, (loss, std)

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
        grads, (losses_m, stds) = jax.lax.scan(grad_body, zero_g, micro)
        updates, opt_state = server_opt.update(grads, opt_state, params)
        params = opt_lib.apply_updates(params, updates)
        metrics = {"loss": losses_m.mean(), "encoding_std": stds.mean()}
        return params, opt_state, metrics

    return micro_step


def make_prefill_step(cfg, max_len: int):
    """prefill_step(tower_params, batch) -> (last_logits, cache)."""

    def prefill_step(params, batch):
        tokens = batch["tokens"]
        b = tokens.shape[0]
        cache = transformer.init_cache(cfg, b, max_len)
        return transformer.prefill(cfg, params, tokens, cache,
                                   patch_embeds=batch.get("patch_embeds"))

    return prefill_step


def make_serve_step(cfg):
    """serve_step(tower_params, cache, batch) -> (logits, cache).

    One new token against a pre-populated KV cache/recurrent state.
    """

    def serve_step(params, cache, batch):
        return transformer.decode_step(cfg, params, cache, batch["tokens"])

    return serve_step


def make_lm_train_step(cfg, server_opt):
    """Plain next-token LM training step (used by examples & finetuning)."""

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        h = transformer.forward(cfg, params, tokens[:, :-1])
        logits = transformer.logits_from_hidden(cfg, params, h)
        logp = jax.nn.log_softmax(logits.astype(F32), axis=-1)
        nll = -jnp.take_along_axis(logp, tokens[:, 1:][..., None], axis=-1)[..., 0]
        return nll.mean()

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        updates, opt_state = server_opt.update(grads, opt_state, params)
        params = opt_lib.apply_updates(params, updates)
        return params, opt_state, {"loss": loss}

    return step
