"""Production mesh construction (TPU v5e, 256 chips/pod).

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (host) devices exist — used by tests."""
    return jax.make_mesh((data, model), ("data", "model"))


class HardwareSpec:
    """TPU v5e constants used by the roofline analysis (benchmarks/roofline)."""
    PEAK_FLOPS_BF16 = 197e12        # per chip
    PEAK_FLOPS_F32 = 98.5e12        # per chip (MXU f32 runs at half rate)
    HBM_BW = 819e9                  # bytes/s per chip
    ICI_BW = 50e9                   # bytes/s per link
    HBM_BYTES = 16 * 2**30          # 16 GiB per chip
    # federated-client uplink, NOT a datacenter link: the paper's setting
    # ships client payloads over consumer connections. 20 Mbit/s is a
    # conservative residential uplink; benchmarks/run.py `comm_round`
    # models wire time as payload_bytes / FED_UPLINK_BW (clients upload
    # in parallel, so the round waits on ONE client's payload).
    FED_UPLINK_BW = 2.5e6           # bytes/s per client
