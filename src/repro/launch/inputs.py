"""Assigned input shapes and ShapeDtypeStruct stand-ins for every model
input (no device allocation — the dry-run lowers/compiles against these).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# long-context policy per attention family (see DESIGN.md §4):
#   gqa  -> sliding-window 8192 variant (ring-buffer cache)
#   mla  -> full latent cache (memory/step-compute are linear already)
#   ssm  -> native O(1) state
LONG_CONTEXT_WINDOW = 8192


def arch_variant_for_shape(cfg, shape: InputShape):
    """Apply the long-context variant where required."""
    if shape.name == "long_500k" and not cfg.use_mla \
            and any(k == "attn" for k in cfg.block_pattern):
        return cfg.replace(sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def _tok(batch, seq):
    return SDS((batch, seq), jnp.int32)


def train_input_specs(cfg, shape: InputShape):
    """Two augmented views for the DCCO dual-encoder train step.

    VLM (Fig. 1c): view1 = text tokens of the full seq_len; view2 = the
    vision tower input (stub patch embeddings + 1 BOS token).
    """
    b, s = shape.global_batch, shape.seq_len
    if cfg.modality == "vision_text":
        return {
            "view1": {"tokens": _tok(b, s)},
            "view2": {"tokens": _tok(b, 1),
                      "patch_embeds": SDS((b, cfg.vis_patches, cfg.vis_dim),
                                          jnp.bfloat16)},
        }
    return {"view1": {"tokens": _tok(b, s)}, "view2": {"tokens": _tok(b, s)}}


def prefill_input_specs(cfg, shape: InputShape):
    b, s = shape.global_batch, shape.seq_len
    if cfg.modality == "vision_text":
        return {"tokens": _tok(b, s - cfg.vis_patches),
                "patch_embeds": SDS((b, cfg.vis_patches, cfg.vis_dim), jnp.bfloat16)}
    return {"tokens": _tok(b, s)}


def decode_input_specs(cfg, shape: InputShape):
    return {"tokens": _tok(shape.global_batch, 1)}


def sds_tree(tree):
    """Concrete pytree -> ShapeDtypeStruct pytree."""
    return jax.tree.map(lambda x: SDS(x.shape, x.dtype), tree)
