"""Serving driver: batched prefill + autoregressive decode for any assigned
architecture, runnable on CPU with smoke configs.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke \\
      --batch 2 --prompt-len 16 --gen 8

(``--arch tinyllama-1.1b`` is the default; any transformer config in
``src/repro/configs`` works, e.g. ``--arch qwen3-1.7b``.) To serve a
pretrained tower, pass ``--ckpt /path/to/<arch>.msgpack`` — a checkpoint
written by ``launch/train.py`` / the round engine's segment checkpointing;
it is restored via ``repro.checkpoint.restore_checkpoint`` before prefill.

``--retrieval`` switches to the dual-encoder serving path instead (paper
Sec. 1's deployed use case): build a ``repro.retrieval.CorpusIndex`` per
``--corpus-sizes`` entry (chunked encode, O(chunk) activations), answer
batched top-k queries through the fused MIPS search behind a
``QueryServer``, and report wall-clock and serial queries/sec plus
p50/p99 latency vs corpus size:

  PYTHONPATH=src python -m repro.launch.serve --retrieval \\
      --corpus-sizes 512,2048 --serve-batches 8

Scaling tiers (PR 9) compose with ``--retrieval``:

  * ``--shards S`` partitions each index over a ``make_corpus_mesh`` S-
    device "corpus" axis (``ShardedCorpusIndex`` — bit-identical results;
    with one device the vmap-simulated shard path runs);
  * ``--ivf C`` serves the approximate ``IVFIndex`` tier with C k-means
    centroids; ``--nprobe`` picks the recall-vs-qps operating point.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import restore_checkpoint
from repro.configs.base import DualEncoderConfig, get_config
from repro.launch import steps as steps_lib
from repro.models import transformer


def run_retrieval(args) -> None:
    """Retrieval serving: index build + QueryServer latency sweep."""
    from repro.data import synthetic
    from repro.models import dual_encoder
    from repro.retrieval import (CorpusIndex, IVFIndex, QueryServer,
                                 ShardedCorpusIndex, l2_normalize)

    if args.shards > 0 and args.ivf > 0:
        raise SystemExit("--shards and --ivf are separate serving tiers; "
                         "pick one per run")
    cfg = get_config(args.arch, smoke=args.smoke)
    de = DualEncoderConfig(proj_dims=(64, 64))
    key = jax.random.PRNGKey(args.seed)
    params = dual_encoder.init_dual_encoder(key, cfg, de)
    if args.ckpt:
        blob, step = restore_checkpoint(args.ckpt, {"params": params})
        params = blob["params"]
        print(f"restored dual encoder from {args.ckpt} @ {step}")

    def embed(p, batch):
        z, _ = dual_encoder.encode(cfg, de, p, batch)
        return z

    sizes = [int(s) for s in args.corpus_sizes.split(",")]
    max_n = max(sizes)
    toks, _ = synthetic.synthetic_labeled_tokens(
        max_n, 4, args.prompt_len, vocab=cfg.vocab_size, seed=args.seed)
    qtoks, _ = synthetic.synthetic_labeled_tokens(
        args.batch * args.serve_batches, 4, args.prompt_len,
        vocab=cfg.vocab_size, seed=args.seed + 1)
    qz = l2_normalize(embed(params, {"tokens": jnp.asarray(qtoks)}))
    print(f"retrieval serving: {args.arch} d={qz.shape[1]} "
          f"k={args.k} batch={args.batch}")
    mesh = None
    if args.shards > 0:
        from repro.sharding import make_corpus_mesh
        if args.shards <= jax.device_count():
            mesh = make_corpus_mesh(args.shards)
            tier = f"sharded x{args.shards} (mesh)"
        else:
            tier = f"sharded x{args.shards} (vmap-simulated)"
        print(f"  tier: {tier}")
    elif args.ivf > 0:
        print(f"  tier: ivf C={args.ivf} nprobe={args.nprobe}")

    for n in sizes:
        t0 = time.time()
        corpus = {"tokens": jnp.asarray(toks[:n])}
        if args.shards > 0:
            idx = ShardedCorpusIndex.build(embed, params, corpus,
                                           num_shards=args.shards,
                                           mesh=mesh, chunk=min(256, n))
            jax.block_until_ready(idx.shards)
        elif args.ivf > 0:
            idx = IVFIndex.build(embed, params, corpus,
                                 num_centroids=min(args.ivf, n),
                                 nprobe=min(args.nprobe, args.ivf),
                                 chunk=min(256, n))
            jax.block_until_ready(idx.lists_emb)
        else:
            idx = CorpusIndex.build(embed, params, corpus,
                                    chunk=min(256, n))
            jax.block_until_ready(idx.embeddings)
        t_build = time.time() - t0
        srv = QueryServer(idx, k=args.k, batch=args.batch).warmup()
        for i in range(args.serve_batches):
            srv.query(qz[i * args.batch:(i + 1) * args.batch])
        s = srv.stats()
        print(f"  corpus {n:6d}: built {t_build:6.2f}s | "
              f"qps={s['qps']:8.0f} (serial {s['qps_serial']:8.0f}) "
              f"p50={s['p50_us']:7.0f}us p99={s['p99_us']:7.0f}us "
              f"({s['batches']} batches)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--retrieval", action="store_true",
                    help="serve dual-encoder retrieval (CorpusIndex + "
                         "fused MIPS QueryServer) instead of generative "
                         "decode; reports qps and p50/p99 latency per "
                         "--corpus-sizes entry")
    ap.add_argument("--corpus-sizes", default="512,2048",
                    help="comma-separated corpus sizes for --retrieval")
    ap.add_argument("--serve-batches", type=int, default=8,
                    help="timed query batches per corpus size "
                         "(--retrieval)")
    ap.add_argument("--k", type=int, default=10,
                    help="retrieved neighbours per query (--retrieval)")
    ap.add_argument("--shards", type=int, default=0,
                    help="partition each index over this many corpus-mesh "
                         "shards (--retrieval; 0 = unsharded; falls back "
                         "to vmap-simulated shards past the device count)")
    ap.add_argument("--ivf", type=int, default=0,
                    help="serve the approximate IVF tier with this many "
                         "k-means centroids (--retrieval; 0 = exact)")
    ap.add_argument("--nprobe", type=int, default=8,
                    help="inverted lists scanned per query (--ivf)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.retrieval:
        if args.batch == ap.get_default("batch"):
            args.batch = 16        # a serving batch, not a decode batch
        return run_retrieval(args)

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(args.seed)
    params = transformer.init_params(cfg, key)
    if args.ckpt:
        blob, step = restore_checkpoint(args.ckpt, {"params": params})
        params = blob["params"]
        print(f"restored tower from {args.ckpt} @ {step}")

    max_len = args.prompt_len + args.gen + 1
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    prefill = jax.jit(steps_lib.make_prefill_step(cfg, max_len))
    serve = jax.jit(steps_lib.make_serve_step(cfg), donate_argnums=1)

    batch = {"tokens": prompt}
    if cfg.modality == "vision_text":
        batch["patch_embeds"] = jax.random.normal(
            key, (args.batch, cfg.vis_patches, cfg.vis_dim), jnp.bfloat16)

    t0 = time.time()
    logits, cache = prefill(params, batch)
    logits = jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill * 1e3:.1f}ms")

    tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
    generated = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = serve(params, cache, {"tokens": tok})
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / args.temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"decode: {args.gen} tokens x {args.batch} in {t_dec * 1e3:.1f}ms "
          f"({t_dec / max(args.gen - 1, 1) * 1e3:.1f} ms/tok)")
    for b in range(args.batch):
        print(f"  seq{b}: prompt={prompt[b, :8].tolist()}... "
              f"-> {out[b].tolist()}")


if __name__ == "__main__":
    main()
