"""Serving driver: batched prefill + autoregressive decode for any assigned
architecture, runnable on CPU with smoke configs.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke \\
      --batch 2 --prompt-len 16 --gen 8

(``--arch tinyllama-1.1b`` is the default; any transformer config in
``src/repro/configs`` works, e.g. ``--arch qwen3-1.7b``.) To serve a
pretrained tower, pass ``--ckpt /path/to/<arch>.msgpack`` — a checkpoint
written by ``launch/train.py`` / the round engine's segment checkpointing;
it is restored via ``repro.checkpoint.restore_checkpoint`` before prefill.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import restore_checkpoint
from repro.configs.base import get_config
from repro.launch import steps as steps_lib
from repro.models import transformer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(args.seed)
    params = transformer.init_params(cfg, key)
    if args.ckpt:
        blob, step = restore_checkpoint(args.ckpt, {"params": params})
        params = blob["params"]
        print(f"restored tower from {args.ckpt} @ {step}")

    max_len = args.prompt_len + args.gen + 1
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    prefill = jax.jit(steps_lib.make_prefill_step(cfg, max_len))
    serve = jax.jit(steps_lib.make_serve_step(cfg), donate_argnums=1)

    batch = {"tokens": prompt}
    if cfg.modality == "vision_text":
        batch["patch_embeds"] = jax.random.normal(
            key, (args.batch, cfg.vis_patches, cfg.vis_dim), jnp.bfloat16)

    t0 = time.time()
    logits, cache = prefill(params, batch)
    logits = jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill * 1e3:.1f}ms")

    tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
    generated = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = serve(params, cache, {"tokens": tok})
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / args.temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"decode: {args.gen} tokens x {args.batch} in {t_dec * 1e3:.1f}ms "
          f"({t_dec / max(args.gen - 1, 1) * 1e3:.1f} ms/tok)")
    for b in range(args.batch):
        print(f"  seq{b}: prompt={prompt[b, :8].tolist()}... "
              f"-> {out[b].tolist()}")


if __name__ == "__main__":
    main()
