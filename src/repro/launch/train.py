"""Production training driver: federated stats-objective pretraining
(``--objective dcco|dvicreg|dwmse``, ``repro.objectives``) of any assigned
architecture (``--arch``), runnable end-to-end on CPU with smoke configs.

Three execution modes:
  * ``--mode engine``    — scan-compiled round engine (default): the whole
                           multi-round loop (sampling included) is ONE jitted
                           lax.scan program per metrics segment, with donated
                           carry and periodic checkpointing.
  * ``--mode fused``     — pod-style fused train step (one jit'd step ==
                           one federated round via the Appendix-A theorem;
                           what the dry-run lowers to the production mesh).
  * ``--mode protocol``  — the client-level federated simulator, one Python
                           dispatch per round (reference semantics; also the
                           baseline the engine is benchmarked against).

Example (CPU, reduced config, a few hundred rounds):
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \\
      --smoke --rounds 200 --clients-per-round 16 --samples-per-client 2
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import comm, hierarchy, objectives as objectives_lib
from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs.base import (DualEncoderConfig, TrainConfig, get_config,
                                get_dual_encoder_config)
from repro.core import buffer as buffer_lib
from repro.core import eval as eval_lib, fed_sim, round_engine
from repro.data import latency as latency_lib
from repro.data import partition as partition_lib
from repro.data import pipeline, synthetic
from repro.launch import steps as steps_lib
from repro.models import dual_encoder
from repro.optim import optimizers as opt_lib, schedules
from repro.server import drift as drift_lib
from repro.server import update as server_update_lib
from repro.sharding import maybe_initialize_distributed


def build_dataset(cfg, args):
    if cfg.family == "resnet":
        imgs, labels = synthetic.synthetic_labeled_images(
            args.dataset_size, args.num_classes, image_size=cfg.image_size,
            noise=0.5, seed=args.seed)
        data = {"images": imgs}
        vocab = 0
    else:
        toks, labels = synthetic.synthetic_labeled_tokens(
            args.dataset_size, args.num_classes, args.seq_len,
            vocab=cfg.vocab_size, seed=args.seed)
        data = {"tokens": toks}
        vocab = cfg.vocab_size
    num_clients = max(args.dataset_size // args.samples_per_client, 4)
    if args.partition is not None:
        spec = partition_lib.PartitionSpec(
            args.partition,
            severity=1.0 if args.severity is None else args.severity)
    elif args.alpha is not None:
        # deprecated spelling; the PartitionSpec alias is bit-identical
        print("--alpha is deprecated; use --partition dirichlet "
              "--severity (see docs/architecture.md §15)", flush=True)
        spec = partition_lib.PartitionSpec("dirichlet", alpha=args.alpha)
    else:
        # legacy default: the paper's fully non-IID partition (alpha=0)
        spec = partition_lib.PartitionSpec("dirichlet", alpha=0.0)
    return pipeline.FederatedDataset.build(
        data, labels, num_clients=num_clients,
        samples_per_client=args.samples_per_client, partition=spec,
        seed=args.seed, vocab=vocab), labels


def _forbid_ignored_flags(ap, args, attrs, why: str) -> None:
    """Exit loudly when a flag was set but the selected mode/channel would
    silently ignore it (e.g. --quant-bits without --channel quant)."""
    flagged = ["--" + a.replace("_", "-") for a in attrs
               if getattr(args, a) != ap.get_default(a)]
    if flagged:
        raise SystemExit(f"{', '.join(flagged)} would be silently ignored: "
                         f"{why}")


def validate_flags(ap, args) -> None:
    if args.partition is not None and args.alpha is not None:
        raise SystemExit(
            "--alpha is the deprecated spelling of --partition dirichlet; "
            "pass one, not both (--alpha X == --partition dirichlet with "
            "the raw concentration X)")
    if args.partition is None:
        _forbid_ignored_flags(
            ap, args, ["severity"],
            "--severity maps onto --partition's strategy parameter; "
            "without --partition the legacy dirichlet(alpha) cut is used")
    elif args.severity is not None and not 0.0 <= args.severity <= 1.0:
        raise SystemExit(f"--severity {args.severity} must be in [0, 1]")
    if args.partition == "dirichlet_quantity" and args.mode == "fused":
        raise SystemExit(
            "--partition dirichlet_quantity yields variable-size clients "
            "(padded rows masked by per-client sizes); the fused pod step "
            "flattens the cohort without a mask — use --mode engine or "
            "protocol")
    if args.clusters:
        if args.mode != "engine":
            raise SystemExit(
                f"--clusters runs the cluster-aware round inside the scan "
                f"engine; --mode {args.mode} has no clustered body — use "
                f"--mode engine")
        if args.async_k:
            raise SystemExit(
                "--clusters with --async-k: the staleness buffer folds "
                "contributions into ONE server aggregate as they arrive; "
                "per-cluster aggregation needs the materialized "
                "synchronous cohort — drop one")
        if args.cohort_chunk:
            raise SystemExit(
                "--clusters with --cohort-chunk: cluster assignment reads "
                "the whole cohort's stats at once; the streamed cohort "
                "never materializes them — drop one")
        if args.scaffold:
            raise SystemExit(
                "--clusters with --scaffold: SCAFFOLD variates assume one "
                "shared broadcast model, the clustered round broadcasts "
                "per-cluster params — drop one")
        if args.stats_kernel != "off":
            raise SystemExit(
                "--clusters needs PER-CLIENT phase-1 stats for the "
                "k-means assignment; --stats-kernel aggregates the "
                "flattened cohort and never materializes them — drop one")
        if args.channel == "dp":
            raise SystemExit(
                "--clusters refuses --channel dp: per-cluster aggregates "
                "change the DP sensitivity, the accountant's epsilon "
                "would not cover the release — run DP on the global path")
        if args.edges and args.edges != args.clusters:
            raise SystemExit(
                f"--clusters {args.clusters} with --edges {args.edges}: "
                f"cluster ids route clients through their own edge, so "
                f"the tree needs exactly one edge per cluster "
                f"(--edges == --clusters)")
        if args.clusters > args.clients_per_round:
            raise SystemExit(
                f"--clusters {args.clusters} exceeds --clients-per-round "
                f"{args.clients_per_round}: every cluster needs a chance "
                f"of cohort members")
    else:
        _forbid_ignored_flags(
            ap, args, ["cluster_iters"],
            "--cluster-iters tunes the in-scan k-means of --clusters")
    if args.objective != "dcco":
        if args.mode == "fused":
            raise SystemExit(
                f"--objective {args.objective} needs the objective-"
                f"parametric round bodies; the fused pod step hardcodes "
                f"the CCO loss — use --mode engine or protocol")
        _forbid_ignored_flags(
            ap, args, ["lam"],
            f"--lam is the CCO off-diagonal weight; --objective "
            f"{args.objective} has its own hyperparameters")
    if args.channel != "quant":
        _forbid_ignored_flags(
            ap, args, ["quant_bits"],
            f"--quant-bits only applies to --channel quant "
            f"(got --channel {args.channel})")
    if args.channel not in ("quant", "int8"):
        _forbid_ignored_flags(
            ap, args, ["quant_kernel"],
            f"--quant-kernel only applies to the quantized channels "
            f"(got --channel {args.channel})")
    if args.channel != "dp":
        _forbid_ignored_flags(
            ap, args, ["dp_sigma", "dp_clip", "dp_delta"],
            f"DP flags only apply to --channel dp (got --channel "
            f"{args.channel})")
    if args.channel != "dropout" and not (args.edges
                                          and args.edge_channel == "dropout"):
        _forbid_ignored_flags(
            ap, args, ["dropout_p"],
            f"--dropout-p only applies to --channel dropout or an "
            f"--edge-channel dropout hop (got --channel {args.channel})")
    if args.mode != "engine":
        _forbid_ignored_flags(
            ap, args, ["stats_kernel", "chunk_rounds", "cohort_chunk"],
            f"--mode {args.mode} does not run the scan engine")
    if args.retrieval_eval:
        if args.mode != "engine":
            raise SystemExit(
                f"--retrieval-eval runs inside the scan engine's round "
                f"loop; --mode {args.mode} has no in-scan eval slot — "
                f"use --mode engine")
        if args.retrieval_every < 1:
            raise SystemExit(f"--retrieval-every {args.retrieval_every} "
                             f"must be >= 1")
        if args.retrieval_corpus < 10:
            raise SystemExit(
                f"--retrieval-corpus {args.retrieval_corpus} is smaller "
                f"than the largest reported cutoff (recall@10)")
        held_out = args.retrieval_corpus + args.retrieval_queries
        if held_out > args.dataset_size:
            raise SystemExit(
                f"--retrieval-corpus {args.retrieval_corpus} + "
                f"--retrieval-queries {args.retrieval_queries} = "
                f"{held_out} exceeds --dataset-size {args.dataset_size}")
    else:
        _forbid_ignored_flags(
            ap, args, ["retrieval_every", "retrieval_corpus",
                       "retrieval_queries", "retrieval_dtype"],
            "retrieval flags configure the --retrieval-eval loop")
    if args.async_k:
        if args.mode != "engine":
            raise SystemExit(
                f"--async-k buffers contributions inside the scan engine; "
                f"--mode {args.mode} runs strictly synchronous rounds "
                f"(the fused pod step and the protocol loop have no "
                f"buffered scheduler) — use --mode engine")
        if args.cohort_chunk:
            raise SystemExit(
                "--async-k with --cohort-chunk: the staleness buffer and "
                "the streamed cohort are two schedulers for the same "
                "round and are not composed — drop one")
        if args.channel == "dp":
            raise SystemExit(
                "--async-k refuses --channel dp: DP noise calibration "
                "across staleness-weighted multi-tick aggregates is "
                "undefined (repro.core.buffer) — run DP on the "
                "synchronous engine")
        if args.stats_kernel != "off":
            raise SystemExit(
                "--async-k scatters per-client contributions by arrival "
                "delay; --stats-kernel aggregates the flattened cohort "
                "and never materializes them — drop one")
        if not 1 <= args.async_k <= args.clients_per_round:
            raise SystemExit(
                f"--async-k {args.async_k} must be in [1, "
                f"--clients-per-round {args.clients_per_round}]")
    else:
        _forbid_ignored_flags(
            ap, args, ["staleness", "latency_tail"],
            "--staleness / --latency-tail shape the buffered "
            "(--async-k) engine's arrival model; the synchronous engine "
            "ignores them")
    if args.edges:
        if args.clients_per_round % args.edges and not args.clusters:
            raise SystemExit(
                f"--edges {args.edges} does not divide --clients-per-round "
                f"{args.clients_per_round}: edges are contiguous "
                f"equal-size client groups (unless --clusters routes "
                f"clients to edges by cluster id)")
        if args.channel == "dp":
            raise SystemExit(
                "--edges refuses a DP client hop: noise calibration and "
                "epsilon accounting across a two-level tree are undefined "
                "(repro.hierarchy) — drop --edges or use a flat --channel dp")
        if args.mode == "fused":
            raise SystemExit(
                "--edges models the client->edge->server wire; the fused "
                "pod step has no per-client wire — use --mode engine or "
                "protocol")
    else:
        _forbid_ignored_flags(
            ap, args, ["edge_channel"],
            "--edge-channel configures the edge->server hop of --edges")
    if args.cohort_chunk:
        if args.clients_per_round % args.cohort_chunk:
            raise SystemExit(
                f"--cohort-chunk {args.cohort_chunk} does not divide "
                f"--clients-per-round {args.clients_per_round}")
        if args.edges and args.cohort_chunk % max(
                args.clients_per_round // args.edges, 1):
            raise SystemExit(
                f"--cohort-chunk {args.cohort_chunk} does not hold whole "
                f"edges of {args.clients_per_round // args.edges} clients "
                f"(--edges {args.edges})")
        _forbid_ignored_flags(
            ap, args, ["scaffold", "stats_kernel"],
            "streaming rounds keep no cohort-resident state: SCAFFOLD "
            "slot variates and the flattened-cohort stats kernel both "
            "need the materialized cohort")
    if args.mode == "fused":
        if args.channel != "none":
            raise SystemExit(
                "--channel models the client uplink; the fused pod step "
                "has no per-client wire — use --mode engine or protocol")
        _forbid_ignored_flags(
            ap, args, ["server_opt", "fedprox_mu", "scaffold", "local_steps"],
            "the fused pod step hardcodes the FedOpt delegate with one "
            "local step — use --mode engine or protocol for server/drift "
            "strategies")
    if args.server_opt != "fedavg_sgd":
        _forbid_ignored_flags(
            ap, args, ["server_optimizer"],
            f"--server-opt {args.server_opt} builds its own server "
            f"optimizer; the base --server-optimizer is unused")
    if args.server_opt in ("fedavg_sgd", "fedavgm"):
        _forbid_ignored_flags(
            ap, args, ["server_tau"],
            "--server-tau only applies to the adaptive --server-opt "
            "strategies (fedadagrad / fedadam / fedyogi)")


def make_apply(cfg, de_cfg):
    def apply(p, batch):
        if isinstance(batch, dict) and "v1" in batch:
            leaf = "images" if batch["v1"].ndim >= 4 else "tokens"
            zf, _ = dual_encoder.encode(cfg, de_cfg, p, {leaf: batch["v1"]})
            zg, _ = dual_encoder.encode(cfg, de_cfg, p, {leaf: batch["v2"]})
            return zf, zg
        raise ValueError("unexpected batch structure")
    return apply


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="Federated dual-encoder pretraining driver "
                    "(flags are grouped; see each group below)")
    ap.add_argument("--arch", default="resnet14-cifar")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--mode", choices=["engine", "fused", "protocol"],
                    default="engine")
    ap.add_argument("--objective", default="dcco",
                    choices=list(objectives_lib.OBJECTIVES),
                    help="stats objective (repro.objectives) trained by "
                         "the two-phase protocol: 'dcco' = the paper's "
                         "cross-correlation loss (5-stat payload, --lam); "
                         "'dvicreg' / 'dwmse' = VICReg / whitening-MSE "
                         "from 7 statistics (engine/protocol modes)")
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--clients-per-round", type=int, default=16)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--eval-every", type=int, default=25)
    ap.add_argument("--resume", default=None)
    ap.add_argument("--seed", type=int, default=0)

    g = ap.add_argument_group(
        "data & partition",
        "synthetic dataset shape + how it is cut into client shards "
        "(repro.data.partition — strategies are registered data; "
        "severity in [0,1] is the one cross-strategy heterogeneity axis)")
    g.add_argument("--partition", default=None,
                   choices=list(partition_lib.PARTITIONS),
                   help="client partition strategy: 'iid' (shuffled "
                        "control), 'uniform' (class-stratified, most "
                        "homogeneous), 'label' (pathological shards: "
                        "round(C - severity*(C-1)) classes/client), "
                        "'dirichlet' (label skew, alpha = "
                        "10**(3-6*severity)), 'dirichlet_quantity' "
                        "(client SIZES ~ Dir(beta), labels IID). "
                        "Default: the legacy fully non-IID dirichlet "
                        "(alpha=0) partition")
    g.add_argument("--severity", type=float, default=None,
                   help="heterogeneity severity in [0,1] for --partition "
                        "(0 = homogeneous, 1 = maximally skewed; default "
                        "1.0). Each strategy maps it onto its own "
                        "parameter — see docs/architecture.md §15")
    g.add_argument("--alpha", type=float, default=None,
                   help="DEPRECATED: raw Dirichlet concentration (old "
                        "spelling; 0=non-IID, >=1e6=IID). Use "
                        "--partition dirichlet --severity instead; "
                        "--alpha keeps existing configs bit-identical")
    g.add_argument("--samples-per-client", type=int, default=2)
    g.add_argument("--seq-len", type=int, default=64)
    g.add_argument("--dataset-size", type=int, default=600)
    g.add_argument("--num-classes", type=int, default=5)

    g = ap.add_argument_group(
        "clustered aggregation",
        "cluster-aware server aggregation for heterogeneous populations "
        "(repro.cluster): cosine k-means on the phase-1 stats assigns "
        "cohort clients to clusters inside the round scan; each cluster "
        "keeps its own correlation target and server-update slot")
    g.add_argument("--clusters", type=int, default=0,
                   help="number of server-side client clusters (engine "
                        "mode; 0/1 = the global single-model path — "
                        "--clusters 1 is bit-identical to 0). With "
                        "--edges, each cluster routes through its own "
                        "edge (requires --edges == --clusters)")
    g.add_argument("--cluster-iters", type=int, default=2,
                   help="Lloyd iterations per round of the in-scan "
                        "k-means (warm-started from the previous round's "
                        "centroids, so a small count suffices)")

    g = ap.add_argument_group(
        "engine", "scan-compiled round engine knobs (--mode engine)")
    g.add_argument("--chunk-rounds", type=int, default=0,
                   help="rounds per scan segment (engine mode; 0=eval-every)")
    g.add_argument("--stats-kernel", choices=["off", "pallas", "interpret"],
                   default="off",
                   help="route phase-1 aggregate stats through the fused "
                        "Pallas kernel (engine mode; 'pallas' falls back "
                        "to the interpreter on CPU)")
    g.add_argument("--compute-dtype", default="float32",
                   choices=sorted(round_engine.COMPUTE_DTYPES),
                   help="encoder forward/backward compute dtype (engine "
                        "mode). 'bfloat16' halves activation traffic and "
                        "doubles MXU throughput; the Eq.-3 statistics "
                        "accumulation, parameters, and server state stay "
                        "float32 regardless (see docs/performance.md)")
    g.add_argument("--cohort-chunk", type=int, default=0,
                   help="stream the cohort through each round in chunks "
                        "of this many clients (engine mode; peak memory "
                        "O(chunk) instead of O(cohort), unlocking "
                        "thousands of clients/round; 0 = materialized)")

    g = ap.add_argument_group(
        "communication", "client->server wire models (repro.comm) and "
        "the two-level aggregation tree (repro.hierarchy)")
    g.add_argument("--channel", default="none",
                   choices=["none", "dense", "int8", "quant", "dp",
                            "dropout"],
                   help="client->server communication channel "
                        "(repro.comm): 'none' = ideal lossless wire; "
                        "'int8' = 8-bit stochastic-rounding quantization; "
                        "'quant' = --quant-bits quantization; 'dp' = "
                        "clipped + Gaussian-noised aggregation; "
                        "'dropout' = Bernoulli client dropout")
    g.add_argument("--quant-bits", type=int, default=8,
                   help="wire width for --channel quant")
    g.add_argument("--quant-kernel", choices=["off", "pallas", "interpret"],
                   default="off",
                   help="route quantize->dequantize through the fused "
                        "Pallas kernel (kernels/quantize.py)")
    g.add_argument("--dp-sigma", type=float, default=1.0,
                   help="DP noise multiplier (--channel dp)")
    g.add_argument("--dp-clip", type=float, default=1.0,
                   help="per-client L2 clip norm (--channel dp)")
    g.add_argument("--dp-delta", type=float, default=1e-5,
                   help="target delta for the epsilon accountant")
    g.add_argument("--dropout-p", type=float, default=0.1,
                   help="per-round client dropout probability "
                        "(--channel dropout)")
    g.add_argument("--edges", type=int, default=0,
                   help="fan the cohort in through this many edge "
                        "aggregators (repro.hierarchy): clients -> edges "
                        "-> server, --channel on the client->edge hop and "
                        "--edge-channel on the edge->server hop, both "
                        "hops' bytes accounted (0 = flat aggregation)")
    g.add_argument("--edge-channel", default="dense",
                   choices=["dense", "int8", "dropout"],
                   help="edge->server hop channel for --edges ('dropout' "
                        "models a regional edge outage taking all its "
                        "clients down at once, p = --dropout-p)")

    g = ap.add_argument_group(
        "asynchrony", "semi-synchronous FedBuff-style scheduling "
        "(repro.core.buffer) and the straggler arrival model")
    g.add_argument("--async-k", type=int, default=0,
                   help="semi-synchronous FedBuff-style engine "
                        "(repro.core.buffer): apply the server update "
                        "once this many client contributions have "
                        "ARRIVED — contributions are staleness-weighted "
                        "and buffered as they land, so throughput is "
                        "bounded by the server fold rate, not the "
                        "slowest client (0 = synchronous rounds)")
    g.add_argument("--staleness", default="unit",
                   choices=list(buffer_lib.STALENESS_FNS),
                   help="staleness down-weight s(tau) of a contribution "
                        "arriving tau ticks after dispatch: 'unit' = no "
                        "down-weighting, 'poly' = (1+tau)^-1/2 (the "
                        "FedBuff choice), 'inv' = 1/(1+tau)")
    g.add_argument("--latency-tail", type=float, default=0.0,
                   help="heavy-tail straggler severity (Pareto exponent "
                        "of the persistent per-client arrival-delay "
                        "distribution, repro.data.latency); 0 = every "
                        "contribution arrives the tick it was dispatched")

    g = ap.add_argument_group(
        "retrieval eval", "periodic in-training retrieval eval "
        "(repro.retrieval, engine mode)")
    g.add_argument("--retrieval-eval", action="store_true",
                   help="periodic in-training retrieval eval "
                        "(repro.retrieval): encode a held-out corpus + "
                        "query split with the current params each "
                        "--retrieval-every rounds (inside the scan, via "
                        "the fused MIPS top-k search) and report "
                        "recall@{1,5,10} / MRR alongside the probe "
                        "(engine mode)")
    g.add_argument("--retrieval-every", type=int, default=5,
                   help="rounds between in-scan retrieval evals "
                        "(--retrieval-eval); skipped rounds emit NaN")
    g.add_argument("--retrieval-corpus", type=int, default=256,
                   help="held-out items indexed as the retrieval corpus")
    g.add_argument("--retrieval-queries", type=int, default=64,
                   help="held-out query items scored against the corpus")
    g.add_argument("--retrieval-dtype", default="float32",
                   choices=["float32", "bfloat16"],
                   help="storage dtype of the in-eval corpus embeddings "
                        "(bfloat16 halves index residency; scores still "
                        "accumulate in f32)")

    g = ap.add_argument_group(
        "server & client optimization",
        "server update strategies (repro.server) and client local "
        "training hyperparameters")
    g.add_argument("--server-optimizer", default="adam",
                   choices=["sgd", "adam", "lars"],
                   help="base repro.optim optimizer consumed by the "
                        "fedavg_sgd server strategy (ignored — and "
                        "rejected if set — for adaptive --server-opt)")
    g.add_argument("--server-opt", default="fedavg_sgd",
                   choices=list(server_update_lib.SERVER_UPDATES),
                   help="server update strategy (repro.server): "
                        "'fedavg_sgd' = the FedOpt delegate to "
                        "--server-optimizer (pre-existing behavior); "
                        "'fedavgm' = server momentum; 'fedadagrad' / "
                        "'fedadam' / 'fedyogi' = Reddi-style adaptive "
                        "server optimizers with --server-tau adaptivity")
    g.add_argument("--server-tau", type=float, default=1e-3,
                   help="adaptivity epsilon tau of the adaptive server "
                        "optimizers")
    g.add_argument("--fedprox-mu", type=float, default=0.0,
                   help="FedProx proximal coefficient mu on the client "
                        "local loss (0 = off; only bites at "
                        "--local-steps > 1)")
    g.add_argument("--scaffold", action="store_true",
                   help="SCAFFOLD control variates (per-cohort-slot) for "
                        "client-drift correction; the variate uplink is "
                        "routed through --channel")
    g.add_argument("--local-steps", type=int, default=1,
                   help="client local GD steps per round")
    g.add_argument("--server-lr", type=float, default=2e-3)
    g.add_argument("--client-lr", type=float, default=1.0)
    g.add_argument("--lam", type=float, default=5.0)
    g.add_argument("--micro", type=int, default=1)
    return ap


def main():
    # no-op unless the REPRO_COORDINATOR / REPRO_NUM_PROCESSES /
    # REPRO_PROCESS_ID launch contract is set (multi-host runs); must
    # happen before any jax device use
    maybe_initialize_distributed()
    ap = build_parser()
    args = ap.parse_args()
    validate_flags(ap, args)

    objective = objectives_lib.get_objective(
        args.objective, **({"lam": args.lam} if args.objective == "dcco"
                           else {}))
    cfg = get_config(args.arch, smoke=args.smoke)
    de_cfg = DualEncoderConfig(
        proj_dims=(64, 64) if args.smoke else
        get_dual_encoder_config(args.arch).proj_dims,
        lambda_cco=args.lam)
    key = jax.random.PRNGKey(args.seed)
    params = dual_encoder.init_dual_encoder(key, cfg, de_cfg)
    sched = schedules.cosine_decay(args.server_lr, args.rounds)
    if args.server_opt == "fedavg_sgd":
        # pre-existing behavior: delegate to the configured base optimizer
        opt = server_update_lib.get_server_update(
            "fedavg_sgd",
            base_opt=opt_lib.get_optimizer(args.server_optimizer, sched))
    else:
        opt = server_update_lib.get_server_update(
            args.server_opt, server_lr=sched, tau=args.server_tau)
    opt_state = opt.init(params)
    start_round = 0
    drift_state = (drift_lib.scaffold_init(params, args.clients_per_round)
                   if args.scaffold else None)
    if args.resume:
        tmpl = {"params": params, "opt": opt_state}
        if args.scaffold:
            tmpl["drift"] = drift_state
        blob, start_round = restore_checkpoint(args.resume, tmpl)
        params, opt_state = blob["params"], blob["opt"]
        if args.scaffold:
            drift_state = blob["drift"]
        print(f"resumed from {args.resume} @ round {start_round}")

    ds, labels = build_dataset(cfg, args)
    apply = make_apply(cfg, de_cfg)

    fused_step = None
    if args.mode == "fused":
        tcfg = TrainConfig(seq_len=args.seq_len,
                           global_batch=args.clients_per_round * args.samples_per_client,
                           samples_per_client=args.samples_per_client,
                           dcco_impl="fused")
        fused_step = jax.jit(steps_lib.make_dcco_train_step(
            cfg, de_cfg, tcfg, opt.opt, num_microbatches=args.micro))

    def evaluate(p):
        if cfg.family != "resnet":
            return float("nan")
        from repro.models import resnet as resnet_mod
        z = resnet_mod.resnet_forward(cfg, p["tower"],
                                      jnp.asarray(ds.data["images"]))
        n = len(labels)
        cut = int(n * 0.7)
        return float(eval_lib.ridge_linear_probe(
            z[:cut], jnp.asarray(labels[:cut]), z[cut:],
            jnp.asarray(labels[cut:]), args.num_classes))

    channel = comm.get_channel(
        args.channel, quant_bits=args.quant_bits,
        quant_kernel=args.quant_kernel, dp_sigma=args.dp_sigma,
        dp_clip=args.dp_clip, dp_delta=args.dp_delta,
        dropout_p=args.dropout_p)
    if args.edges:
        # two-level topology: --channel becomes the client->edge hop
        channel = hierarchy.HierarchicalChannel(
            args.edges, client_channel=channel,
            edge_channel=comm.get_channel(args.edge_channel,
                                          dropout_p=args.dropout_p))
    wire_total = [0.0]

    os.makedirs(args.ckpt_dir, exist_ok=True)
    history = []
    t0 = time.time()

    if args.mode == "engine":
        chunk = args.chunk_rounds or args.eval_every or 25
        latency = None
        if args.async_k and args.latency_tail > 0:
            latency = latency_lib.LatencyModel(
                "heavytail", horizon=8, tail=args.latency_tail,
                seed=args.seed)
        retrieval_eval = None
        if args.retrieval_eval:
            from repro import retrieval as retrieval_lib
            leaf = "images" if "images" in ds.data else "tokens"
            data_arr = jnp.asarray(ds.data[leaf])
            lab_arr = jnp.asarray(labels)
            nc, nq = args.retrieval_corpus, args.retrieval_queries
            # held-out split: the first nc items are indexed as the
            # corpus, the next nq serve as queries (label-match relevance)

            def embed(p, batch):
                z, _ = dual_encoder.encode(cfg, de_cfg, p, batch)
                return z

            retrieval_eval = retrieval_lib.make_retrieval_eval(
                embed, {leaf: data_arr[:nc]}, lab_arr[:nc],
                {leaf: data_arr[nc:nc + nq]}, lab_arr[nc:nc + nq],
                chunk=min(256, nc),
                index_dtype=(jnp.bfloat16 if args.retrieval_dtype
                             == "bfloat16" else jnp.float32))
        ecfg = round_engine.EngineConfig(
            algorithm="dcco", objective=objective, lam=args.lam,
            client_lr=args.client_lr,
            local_steps=args.local_steps, chunk_rounds=chunk,
            cohort_chunk=args.cohort_chunk,
            stats_kernel=args.stats_kernel, channel=channel,
            compute_dtype=args.compute_dtype,
            server_update=opt, prox_mu=args.fedprox_mu,
            scaffold=args.scaffold, async_k=args.async_k,
            staleness_fn=args.staleness, latency=latency,
            retrieval_eval=retrieval_eval,
            retrieval_every=args.retrieval_every,
            num_clusters=args.clusters,
            cluster_iters=args.cluster_iters)
        if args.cohort_chunk:
            sampler = ds.make_streaming_sampler(args.clients_per_round,
                                                args.cohort_chunk)
        elif args.async_k:
            sampler = ds.make_async_round_sampler(args.clients_per_round,
                                                  latency)
        else:
            sampler = ds.make_round_sampler(args.clients_per_round)
        engine = round_engine.RoundEngine(apply, opt, sampler, ecfg)
        buffer_state = None
        if args.resume and engine._async_real:
            # second pass over the blob: the buffer template needs the
            # built engine (stat shapes come from eval_shape on the
            # sampler), which needs the dataset — both exist only now
            try:
                b, _ = restore_checkpoint(
                    args.resume,
                    {"buffer": engine._init_async_state(params)})
                buffer_state = b["buffer"]
            except KeyError:
                print("resume checkpoint holds no buffer state (written "
                      "by the synchronous engine) — starting the buffered "
                      "run with an empty buffer", flush=True)

        def on_segment(round_end, carry, m):
            history.extend(float(x) for x in np.asarray(m.loss))
            wire_total[0] += float(np.sum(np.asarray(m.wire_bytes)))
            acc = evaluate(carry.params)
            dt = time.time() - t0
            extra = ""
            if args.async_k:
                extra = (f" updates={int(np.sum(np.asarray(m.applied)))}"
                         f"/{m.applied.shape[0]}t")
            if args.retrieval_eval:
                # latest evaluated round in this segment (skipped = NaN)
                r1 = np.asarray(m.retrieval["recall_at_1"])
                live = np.flatnonzero(~np.isnan(r1))
                if live.size:
                    i = live[-1]
                    extra += (
                        f" recall@1={r1[i]:.3f}"
                        f" recall@10="
                        f"{np.asarray(m.retrieval['recall_at_10'])[i]:.3f}"
                        f" mrr={np.asarray(m.retrieval['mrr'])[i]:.3f}")
            print(f"round {round_end:5d} loss={history[-1]:9.4f} "
                  f"enc_std={float(m.encoding_std[-1]):.4f} "
                  f"probe_acc={acc:.3f}{extra} "
                  f"({dt / (round_end - start_round):.2f}s/round)", flush=True)

        params, opt_state, _ = engine.run(
            params, opt_state, jax.random.PRNGKey(args.seed),
            args.rounds - start_round, start_round=start_round,
            on_segment=on_segment, ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every, ckpt_name=args.arch,
            drift_state=drift_state, buffer_state=buffer_state)
        _report(args, history, evaluate, params, channel, wire_total[0])
        return

    for r in range(start_round, args.rounds):
        rkey = jax.random.PRNGKey(args.seed * 100003 + r)
        if args.mode == "protocol":
            batch, sizes = ds.round_batch(rkey, args.clients_per_round)
            out = fed_sim.stats_round(
                apply, params, opt_state, opt, batch, sizes,
                objective=objective, client_lr=args.client_lr,
                local_steps=args.local_steps, prox_mu=args.fedprox_mu,
                scaffold_state=drift_state, channel=channel,
                channel_key=jax.random.fold_in(
                    rkey, round_engine._CHANNEL_SALT))
            if args.scaffold:
                params, opt_state, drift_state, m = out
            else:
                params, opt_state, m = out
            if channel is not None:
                channel.finalize_rounds(1)
                wire_total[0] += float(m.wire_bytes)
            loss = float(m.loss)
        else:
            flat, _ = ds.flat_round_batch(rkey, args.clients_per_round)
            leaf = "images" if "images" in ds.data else "tokens"
            batch = {"view1": {leaf: flat["v1"]}, "view2": {leaf: flat["v2"]}}
            params, opt_state, m = fused_step(params, opt_state, batch)
            loss = float(m["loss"])
        history.append(loss)
        if (r + 1) % args.eval_every == 0:
            acc = evaluate(params)
            dt = time.time() - t0
            print(f"round {r + 1:5d} loss={loss:9.4f} probe_acc={acc:.3f} "
                  f"({dt / (r - start_round + 1):.2f}s/round)", flush=True)
        if (r + 1) % args.ckpt_every == 0:
            path = os.path.join(args.ckpt_dir, f"{args.arch}.msgpack")
            blob = {"params": params, "opt": opt_state}
            if args.scaffold:
                blob["drift"] = drift_state
            save_checkpoint(path, blob, r + 1)
    _report(args, history, evaluate, params, channel, wire_total[0])


def _report(args, history, evaluate, params, channel=None, wire_bytes=0.0):
    if history:
        print(f"final loss {history[-1]:.4f}; first {history[0]:.4f}; "
              f"probe {evaluate(params):.3f}")
    else:
        print(f"no rounds to run (resumed at or past --rounds "
              f"{args.rounds}); probe {evaluate(params):.3f}")
        return
    if channel is not None:
        line = f"channel {channel!r}: uplink {wire_bytes / 1e6:.3f} MB total"
        acct = getattr(channel, "accountant", None)
        if acct is not None:
            line += (f"; DP epsilon={acct.epsilon():.2f} "
                     f"@ delta={acct.delta:g}")
        print(line)
    with open(os.path.join(args.ckpt_dir, "history.json"), "w") as f:
        json.dump(history, f)


if __name__ == "__main__":
    main()
