"""Backbone assembly: config-driven block pattern scanned over superblocks.

A *superblock* is one pass through ``cfg.block_pattern`` (e.g. zamba2:
5 x mamba2 + 1 x attn). Parameters of all superblocks are stacked along a
leading axis and the layer stack runs under ``jax.lax.scan`` — this keeps the
HLO small enough that 512-way SPMD partitioning is tractable and matches how
production frameworks (MaxText et al.) structure deep stacks.

Public entry points:
  init_params(cfg, key)                      -> params
  forward(cfg, params, tokens, patch_embeds) -> hidden (B,S,D)
  lm_loss / logits helpers
  init_cache(cfg, batch, max_len)            -> cache pytree
  prefill(cfg, params, tokens, cache)        -> (logits_last, cache)
  decode_step(cfg, params, cache, token)     -> (logits, cache)
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models import common
from repro.models.common import F32, dtype_of, embed, embedding_init, linear, linear_init, \
    mlp, mlp_init, rmsnorm, rmsnorm_init, swiglu, swiglu_init, unembed


# =========================================================================
# per-block init / forward / decode dispatch
# =========================================================================

def _use_moe(cfg, layer_in_pattern_is_attn: bool) -> bool:
    return cfg.moe is not None and cfg.moe.num_experts > 0


def _block_init(key, cfg, kind: str, dtype, moe_layer: bool):
    ks = jax.random.split(key, 4)
    if kind == "attn":
        p = {"ln1": rmsnorm_init(cfg.d_model), "ln2": rmsnorm_init(cfg.d_model)}
        if cfg.use_mla:
            p["attn"] = attn.mla_init(ks[0], cfg, dtype)
        else:
            p["attn"] = attn.gqa_init(ks[0], cfg, dtype)
        if moe_layer:
            p["moe"] = moe_mod.moe_init(ks[1], cfg.d_model, cfg.moe, dtype)
        else:
            d_ff = cfg.d_ff if cfg.d_ff > 0 else 4 * cfg.d_model
            if cfg.moe is not None and cfg.moe.dense_d_ff > 0:
                d_ff = cfg.moe.dense_d_ff
            p["ffn"] = swiglu_init(ks[1], cfg.d_model, d_ff, dtype)
        return p
    if kind == "mamba2":
        return {"ln1": rmsnorm_init(cfg.d_model),
                "mixer": ssm_mod.mamba2_init(ks[0], cfg, dtype)}
    if kind == "mlstm":
        return {"ln1": rmsnorm_init(cfg.d_model),
                "mixer": xlstm_mod.mlstm_init(ks[0], cfg, dtype)}
    if kind == "slstm":
        return {"ln1": rmsnorm_init(cfg.d_model),
                "mixer": xlstm_mod.slstm_init(ks[0], cfg, dtype)}
    raise ValueError(f"unknown block kind {kind}")


def _block_forward(cfg, kind: str, p, x, positions):
    """Full-sequence forward (training). Returns (y, aux)."""
    aux = {}
    if kind == "attn":
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        attn_fn = attn.mla_forward if cfg.use_mla else attn.gqa_forward
        if cfg.parallel_block:
            # PaLM-style: attn + FFN in parallel off one norm; their summed
            # output closes the TP contraction with a single all-reduce
            a = attn_fn(cfg, p["attn"], h, positions)
            if "moe" in p:
                y, aux = moe_mod.moe_forward(p["moe"], h, cfg.moe)
            else:
                y = swiglu(p["ffn"], h)
            return x + a + y, aux
        x = x + attn_fn(cfg, p["attn"], h, positions)
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if "moe" in p:
            y, aux = moe_mod.moe_forward(p["moe"], h, cfg.moe)
            x = x + y
        else:
            x = x + swiglu(p["ffn"], h)
        return x, aux
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind == "mamba2":
        x = x + ssm_mod.mamba2_forward(cfg, p["mixer"], h)
    elif kind == "mlstm":
        y, _ = xlstm_mod.mlstm_forward(cfg, p["mixer"], h)
        x = x + y
    elif kind == "slstm":
        y, _ = xlstm_mod.slstm_forward(cfg, p["mixer"], h)
        x = x + y
    return x, aux


def _block_cache_init(cfg, kind: str, batch: int, max_len: int, dtype):
    if kind == "attn":
        if cfg.use_mla:
            return attn.mla_cache_init(cfg, batch, max_len, dtype)
        return attn.gqa_cache_init(cfg, batch, max_len, dtype)
    if kind == "mamba2":
        return ssm_mod.mamba2_cache_init(cfg, batch, dtype)
    if kind == "mlstm":
        return xlstm_mod.mlstm_state_init(cfg, batch)
    if kind == "slstm":
        return xlstm_mod.slstm_state_init(cfg, batch)
    raise ValueError(kind)


def _block_prefill(cfg, kind: str, p, x, positions, cache):
    if kind == "attn":
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        pre_fn = attn.mla_prefill if cfg.use_mla else attn.gqa_prefill
        if cfg.parallel_block:
            a, cache = pre_fn(cfg, p["attn"], h, positions, cache)
            if "moe" in p:
                y, _ = moe_mod.moe_forward(p["moe"], h, cfg.moe)
            else:
                y = swiglu(p["ffn"], h)
            return x + a + y, cache
        y, cache = pre_fn(cfg, p["attn"], h, positions, cache)
        x = x + y
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if "moe" in p:
            y, _ = moe_mod.moe_forward(p["moe"], h, cfg.moe)
            x = x + y
        else:
            x = x + swiglu(p["ffn"], h)
        return x, cache
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind == "mamba2":
        # run full forward; recompute final state for the cache via decode chunking
        y, cache = _mamba2_prefill(cfg, p["mixer"], h, cache)
        return x + y, cache
    if kind == "mlstm":
        st = (cache["C"], cache["n"], cache["m"])
        q, k, v, ipre, fpre, gate = xlstm_mod._mlstm_cell_io(cfg, p["mixer"], h)
        y, (c, n, m) = xlstm_mod._mlstm_chunk_scan(q, k, v, ipre, fpre, st, cfg.xlstm.chunk)
        bb, s, _ = x.shape
        hcount, d_in, dh = xlstm_mod._heads_dims(cfg)
        y = y.reshape(bb, s, d_in).astype(x.dtype)
        y = rmsnorm(p["mixer"]["norm"], y, cfg.norm_eps) * \
            jax.nn.silu(gate.astype(F32)).astype(x.dtype)
        y = linear(p["mixer"]["down"], y)
        return x + y, {"C": c, "n": n, "m": m}
    if kind == "slstm":
        y, cache = xlstm_mod.slstm_forward(cfg, p["mixer"], h, cache)
        return x + y, cache
    raise ValueError(kind)


def _mamba2_prefill(cfg, p, x, cache):
    """Forward over full sequence, returning the final (conv, ssm) state."""
    y = ssm_mod.mamba2_forward(cfg, p, x)
    # final conv state: last (W-1) xbc inputs; final ssm state: recompute via scan
    proj = common.linear(p["in_proj"], x)
    z, xbc, dt_pre = ssm_mod._split_proj(cfg, proj)
    w = cfg.ssm.conv_width
    conv_state = xbc[:, -(w - 1):, :]
    # ssm final state via chunked scan final carry
    d_inner, heads, _ = ssm_mod._dims(cfg)
    n = cfg.ssm.state
    xbc_c = ssm_mod._causal_conv(p, xbc)
    xi = xbc_c[..., :d_inner].reshape(x.shape[0], x.shape[1], heads, cfg.ssm.head_dim)
    b = xbc_c[..., d_inner:d_inner + n]
    c = xbc_c[..., d_inner + n:]
    dt = jax.nn.softplus(dt_pre.astype(F32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    hfinal = _ssd_final_state(xi, dt, a, b, cfg.ssm.chunk)
    return y, {"conv": conv_state.astype(cache["conv"].dtype), "ssm": hfinal}


def _ssd_final_state(x, dt, a, b, chunk: int):
    bb, s, h, pdim = x.shape
    n = b.shape[-1]
    l = min(chunk, s)
    nc = s // l
    xs = x.reshape(bb, nc, l, h, pdim).transpose(1, 0, 2, 3, 4).astype(F32)
    dts = dt.reshape(bb, nc, l, h).transpose(1, 0, 2, 3)
    bs = b.reshape(bb, nc, l, n).transpose(1, 0, 2, 3).astype(F32)

    def step(hprev, inp):
        x_g, dt_g, b_g = inp
        da = dt_g * a[None, None, :]
        cum = jnp.cumsum(da, axis=1)
        tot = cum[:, -1]
        sdecay = jnp.exp(tot[:, None, :] - cum) * dt_g
        states = jnp.einsum("bsh,bsn,bshp->bhnp", sdecay, b_g, x_g,
                            preferred_element_type=F32)
        return hprev * jnp.exp(tot)[..., None, None] + states, None

    h0 = jnp.zeros((bb, h, n, pdim), F32)
    hfinal, _ = jax.lax.scan(step, h0, (xs, dts, bs))
    return hfinal


def _block_decode(cfg, kind: str, p, x, pos, cache):
    if kind == "attn":
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        dec_fn = attn.mla_decode if cfg.use_mla else attn.gqa_decode
        if cfg.parallel_block:
            a, cache = dec_fn(cfg, p["attn"], h, pos, cache)
            if "moe" in p:
                y, _ = moe_mod.moe_forward(p["moe"], h, cfg.moe,
                                           group_size=x.shape[0])
            else:
                y = swiglu(p["ffn"], h)
            return x + a + y, cache
        y, cache = dec_fn(cfg, p["attn"], h, pos, cache)
        x = x + y
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if "moe" in p:
            y, _ = moe_mod.moe_forward(p["moe"], h, cfg.moe, group_size=x.shape[0])
            x = x + y
        else:
            x = x + swiglu(p["ffn"], h)
        return x, cache
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind == "mamba2":
        y, cache = ssm_mod.mamba2_decode(cfg, p["mixer"], h, cache)
        return x + y, cache
    if kind == "mlstm":
        y, cache = xlstm_mod.mlstm_decode(cfg, p["mixer"], h, cache)
        return x + y, cache
    if kind == "slstm":
        y, cache = xlstm_mod.slstm_decode(cfg, p["mixer"], h, cache)
        return x + y, cache
    raise ValueError(kind)


# =========================================================================
# whole-model init / forward / prefill / decode
# =========================================================================

def _moe_flags(cfg):
    """Which scanned pattern slots use MoE FFN (first_k_dense handled via
    separate prologue layers, so all scanned attn slots are MoE)."""
    return [cfg.moe is not None and cfg.moe.num_experts > 0 and k == "attn"
            for k in cfg.block_pattern]


def init_params(cfg, key):
    dtype = dtype_of(cfg.dtype)
    n_super = cfg.num_superblocks
    k_emb, k_layers, k_pro, k_final, k_vis = jax.random.split(key, 5)
    params: Dict[str, Any] = {
        "embed": embedding_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = linear_init(k_final, cfg.d_model, cfg.vocab_size, dtype)
    if cfg.modality == "vision_text":
        params["vis_proj"] = mlp_init(k_vis, (cfg.vis_dim, cfg.d_model, cfg.d_model),
                                      dtype, bias=True)

    moe_flags = _moe_flags(cfg)
    # prologue: first_k_dense dense-FFN attention layers (unscanned)
    n_pro = cfg.moe.first_k_dense if cfg.moe is not None else 0
    if n_pro:
        pro_keys = jax.random.split(k_pro, n_pro)
        params["prologue"] = [
            _block_init(pk, cfg, "attn", dtype, moe_layer=False) for pk in pro_keys]

    # scanned superblocks: stack params along leading axis
    def one_super(k):
        ks = jax.random.split(k, len(cfg.block_pattern))
        return {f"b{i}": _block_init(ks[i], cfg, kind, dtype, moe_flags[i])
                for i, kind in enumerate(cfg.block_pattern)}

    layer_keys = jax.random.split(k_layers, n_super)
    stacked = jax.vmap(one_super)(layer_keys)
    params["layers"] = stacked
    return params


def _constrain_act(cfg, x):
    if cfg.act_shard_axes is None:
        return x
    from jax.sharding import PartitionSpec as P
    ax = cfg.act_shard_axes if len(cfg.act_shard_axes) > 1 else cfg.act_shard_axes[0]
    return jax.lax.with_sharding_constraint(x, P(ax, *([None] * (x.ndim - 1))))


def _constrain_fsdp_layer_params(cfg, sp):
    """FSDP: re-pin each *sliced* (per-layer) weight to its model-axis shard
    inside the scan body, so SPMD gathers one layer at a time instead of
    hoisting a whole-stack all-gather out of the loop."""
    if not cfg.fsdp_model_size:
        return sp
    from jax.sharding import PartitionSpec as P
    m = cfg.fsdp_model_size

    def rule(leaf):
        shape = leaf.shape
        nd = len(shape)
        if nd < 2:
            return leaf
        cands = [(shape[i], i) for i in range(nd)
                 if shape[i] % m == 0 and shape[i] >= m]
        if not cands:
            return leaf
        _, dim = max(cands)
        parts = [None] * nd
        parts[dim] = "model"
        return jax.lax.with_sharding_constraint(leaf, P(*parts))

    return jax.tree.map(rule, sp)


def _superblock_forward(cfg, sp, x, positions):
    sp = _constrain_fsdp_layer_params(cfg, sp)
    auxes = []
    for i, kind in enumerate(cfg.block_pattern):
        x, aux = _block_forward(cfg, kind, sp[f"b{i}"], x, positions)
        x = _constrain_act(cfg, x)
        auxes.append({k: aux.get(k, jnp.zeros((), F32)) for k in ("balance", "router_z")})
    tot = {k: sum(a[k] for a in auxes) for k in ("balance", "router_z")}
    return x, tot


def forward(cfg, params, tokens, patch_embeds=None, return_aux: bool = False):
    """tokens: (B,S_text) int32. For VLM, patch_embeds (B,P,vis_dim) are
    projected and prepended (total sequence = P + S_text)."""
    x = embed(params["embed"], tokens)
    if cfg.modality == "vision_text" and patch_embeds is not None:
        vis = mlp(params["vis_proj"], patch_embeds.astype(x.dtype))
        x = jnp.concatenate([vis.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    for p in params.get("prologue", []):
        x, _ = _block_forward(cfg, "attn", p, x, positions)

    def body(x, sp):
        x, aux = _superblock_forward(cfg, sp, x, positions)
        return x, aux

    if cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    n_super = cfg.num_superblocks
    if not cfg.scan_layers:
        aux_list = []
        for i in range(n_super):
            sp = jax.tree.map(lambda a: a[i], params["layers"])
            x, aux = body(x, sp)
            aux_list.append(aux)
        auxes = jax.tree.map(lambda *xs: jnp.stack(xs), *aux_list)
    elif cfg.layer_chunks > 1 and n_super % cfg.layer_chunks == 0:
        k = n_super // cfg.layer_chunks
        aux_list = []
        for c in range(cfg.layer_chunks):
            sub = jax.tree.map(lambda a: a[c * k:(c + 1) * k], params["layers"])
            x, aux = jax.lax.scan(body, x, sub)
            aux_list.append(aux)
        auxes = jax.tree.map(lambda *xs: jnp.concatenate(xs), *aux_list)
    else:
        x, auxes = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_aux:
        aux = {k: jnp.sum(v) for k, v in auxes.items()}
        return x, aux
    return x


def logits_from_hidden(cfg, params, hidden):
    if cfg.tie_embeddings:
        return unembed(params["embed"], hidden)
    return linear(params["unembed"], hidden).astype(F32)


# ------------------------------------------------------------------ cache ---

def init_cache(cfg, batch: int, max_len: int):
    dtype = dtype_of(cfg.dtype)
    n_super = cfg.num_superblocks

    def one_super():
        return {f"b{i}": _block_cache_init(cfg, kind, batch, max_len, dtype)
                for i, kind in enumerate(cfg.block_pattern)}

    # stack cache along leading superblock axis
    proto = one_super()
    stacked = jax.tree.map(lambda a: jnp.broadcast_to(a, (n_super,) + a.shape).copy(), proto)
    cache = {"layers": stacked, "pos": jnp.zeros((), jnp.int32)}
    n_pro = cfg.moe.first_k_dense if cfg.moe is not None else 0
    if n_pro:
        cache["prologue"] = [
            _block_cache_init(cfg, "attn", batch, max_len, dtype) for _ in range(n_pro)]
    return cache


def prefill(cfg, params, tokens, cache, patch_embeds=None):
    """Run the full prompt, fill the cache. Returns (last_logits (B,V), cache)."""
    x = embed(params["embed"], tokens)
    if cfg.modality == "vision_text" and patch_embeds is not None:
        vis = mlp(params["vis_proj"], patch_embeds.astype(x.dtype))
        x = jnp.concatenate([vis.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    new_pro = []
    for p, pc in zip(params.get("prologue", []), cache.get("prologue", [])):
        x, pc = _block_prefill(cfg, "attn", p, x, positions, pc)
        new_pro.append(pc)

    # cache stack rides in the scan CARRY and is updated with
    # dynamic-update-slice — XLA performs the update in place (one resident
    # cache buffer + donated input) instead of allocating a second stacked
    # cache as scan-ys output.
    def body(carry, sp_and_idx):
        x, cstack = carry
        sp, idx = sp_and_idx
        c = jax.tree.map(lambda a: jax.lax.dynamic_index_in_dim(
            a, idx, 0, keepdims=False), cstack)
        for i, kind in enumerate(cfg.block_pattern):
            x, c_i = _block_prefill(cfg, kind, sp[f"b{i}"], x, positions, c[f"b{i}"])
            c = {**c, f"b{i}": c_i}
        cstack = jax.tree.map(lambda a, ci: jax.lax.dynamic_update_index_in_dim(
            a, ci.astype(a.dtype), idx, 0), cstack, c)
        return (x, cstack), None

    n_super = cfg.num_superblocks
    (x, new_layer_cache), _ = jax.lax.scan(
        body, (x, cache["layers"]),
        (params["layers"], jnp.arange(n_super, dtype=jnp.int32)))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, x[:, -1])
    new_cache = {"layers": new_layer_cache, "pos": jnp.asarray(s, jnp.int32)}
    if new_pro:
        new_cache["prologue"] = new_pro
    return logits, new_cache


def decode_step(cfg, params, cache, token_ids):
    """token_ids: (B,1) int32; returns (logits (B,V), cache)."""
    x = embed(params["embed"], token_ids)
    pos = cache["pos"]

    new_pro = []
    for p, pc in zip(params.get("prologue", []), cache.get("prologue", [])):
        x, pc = _block_decode(cfg, "attn", p, x, pos, pc)
        new_pro.append(pc)

    # see prefill: cache stack in the carry, in-place dynamic-update-slice
    def body(carry, sp_and_idx):
        x, cstack = carry
        sp, idx = sp_and_idx
        c = jax.tree.map(lambda a: jax.lax.dynamic_index_in_dim(
            a, idx, 0, keepdims=False), cstack)
        for i, kind in enumerate(cfg.block_pattern):
            x, c_i = _block_decode(cfg, kind, sp[f"b{i}"], x, pos, c[f"b{i}"])
            c = {**c, f"b{i}": c_i}
        cstack = jax.tree.map(lambda a, ci: jax.lax.dynamic_update_index_in_dim(
            a, ci.astype(a.dtype), idx, 0), cstack, c)
        return (x, cstack), None

    n_super = cfg.num_superblocks
    (x, new_layer_cache), _ = jax.lax.scan(
        body, (x, cache["layers"]),
        (params["layers"], jnp.arange(n_super, dtype=jnp.int32)))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_from_hidden(cfg, params, x[:, 0])
    new_cache = {"layers": new_layer_cache, "pos": pos + 1}
    if new_pro:
        new_cache["prologue"] = new_pro
    return logits, new_cache
