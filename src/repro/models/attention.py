"""Attention: GQA (+qk_norm, RoPE, sliding window) and MLA (DeepSeek-V2).

Two XLA implementations are provided:
  * ``naive``      — materializes (B,H,Sq,Skv) scores; fine for short seq.
  * ``blockwise``  — flash-style online-softmax over KV blocks, scanned over
                     Q blocks; O(Sq·block) live memory. This is the XLA
                     analogue of the Pallas kernel in repro/kernels/flash.

Decode uses a KV cache; sliding-window decode uses a ring buffer of size W so
``long_500k`` decode state is O(W), not O(S). MLA caches the compressed
latent (kv_lora + rope dims per token) instead of full K/V.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import F32, linear, linear_init, rmsnorm, rmsnorm_init, apply_rope

NEG_INF = -1e30


# =========================================================================
# masking helpers
# =========================================================================

def _mask(q_pos, kv_pos, window: int):
    """(..., Sq, Skv) boolean validity. q_pos: (...,Sq), kv_pos: (...,Skv)."""
    m = kv_pos[..., None, :] <= q_pos[..., :, None]
    if window > 0:
        m &= kv_pos[..., None, :] > (q_pos[..., :, None] - window)
    m &= kv_pos[..., None, :] >= 0          # ring-buffer slots not yet written
    return m


# =========================================================================
# core attention math (shared by GQA and MLA paths)
# =========================================================================

def naive_attention(q, k, v, q_pos, kv_pos, window: int = 0, scale: float | None = None):
    """q: (B,Sq,H,Dh) k: (B,Skv,KVH,Dk) v: (B,Skv,KVH,Dv); H % KVH == 0."""
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)
    qg = q.reshape(b, sq, kvh, g, dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k, preferred_element_type=F32) * scale
    m = _mask(q_pos, kv_pos, window)[:, None, None]          # (B,1,1,Sq,Skv)
    scores = jnp.where(m, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v, preferred_element_type=F32)
    return out.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)


def blockwise_attention(q, k, v, q_pos, kv_pos, window: int = 0,
                        kv_block: int = 1024, scale: float | None = None,
                        unroll: int = 1):
    """Flash-style attention: online softmax scanned over KV blocks.

    Q is processed whole — with batch sharded over (pod,data) and heads over
    `model`, per-device score blocks are (B/dp, Sq, H/mp, kv_block), which
    fits HBM for every assigned shape. Same semantics as naive_attention.
    All reductions in f32.
    """
    b, sq, h, dh = q.shape
    skv, kvh, dv = k.shape[1], k.shape[2], v.shape[-1]
    g = h // kvh
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)

    kv_block = min(kv_block, skv)
    skv_p = -(-skv // kv_block) * kv_block
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, skv_p - skv)), constant_values=-2)
    nkv = skv_p // kv_block
    qg = q.reshape(b, sq, kvh, g, dh)
    ks = k.reshape(b, nkv, kv_block, kvh, dh).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nkv, kv_block, kvh, dv).transpose(1, 0, 2, 3, 4)
    kps = kv_pos.reshape(b, nkv, kv_block).transpose(1, 0, 2)

    def kv_step(carry, kb):
        acc, m_run, l_run = carry
        ki, vi, kpi = kb
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, ki,
                       preferred_element_type=F32) * scale     # (B,KVH,G,Sq,kvb)
        valid = _mask(q_pos, kpi, window)[:, None, None]
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vi.dtype), vi,
                        preferred_element_type=F32)
        acc = acc * alpha[..., None] + pv
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((b, kvh, g, sq, dv), F32)
    m0 = jnp.full((b, kvh, g, sq), NEG_INF, F32)
    l0 = jnp.zeros((b, kvh, g, sq), F32)
    # flash semantics: recompute scores/probabilities in the backward pass
    # instead of saving the (Sq x kv_block) f32 tensors per block
    (acc, m_run, l_run), _ = jax.lax.scan(
        jax.checkpoint(kv_step, prevent_cse=False),
        (acc0, m0, l0), (ks, vs, kps), unroll=unroll)
    out = acc / jnp.maximum(l_run, 1e-30)[..., None]           # (B,KVH,G,Sq,Dv)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv).astype(q.dtype)


def attention_math(cfg, q, k, v, q_pos, kv_pos, scale=None):
    window = cfg.sliding_window
    if cfg.attn_impl == "blockwise" and q.shape[1] > 1:
        return blockwise_attention(q, k, v, q_pos, kv_pos, window,
                                   kv_block=cfg.attn_block, scale=scale)
    return naive_attention(q, k, v, q_pos, kv_pos, window, scale=scale)


# =========================================================================
# GQA block
# =========================================================================

def gqa_init(key, cfg, dtype):
    d, h, kvh, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": linear_init(ks[0], d, h * dh, dtype),
        "wk": linear_init(ks[1], d, kvh * dh, dtype),
        "wv": linear_init(ks[2], d, kvh * dh, dtype),
        "wo": linear_init(ks[3], h * dh, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(dh)
        p["k_norm"] = rmsnorm_init(dh)
    return p


def _gqa_qkv(cfg, p, x, positions):
    b, s, _ = x.shape
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = linear(p["wq"], x).reshape(b, s, h, dh)
    k = linear(p["wk"], x).reshape(b, s, kvh, dh)
    v = linear(p["wv"], x).reshape(b, s, kvh, dh)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_forward(cfg, p, x, positions):
    """Self-attention over a full sequence. x: (B,S,D); positions: (B,S)."""
    b, s, _ = x.shape
    q, k, v = _gqa_qkv(cfg, p, x, positions)
    out = attention_math(cfg, q, k, v, positions, positions)
    return linear(p["wo"], out.reshape(b, s, -1))


def _quantize_kv(x):
    """Per-(position, head) max-abs int8 quantization. x: (B,S,KVH,Dh)."""
    scale = jnp.max(jnp.abs(x.astype(F32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(F32) / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0].astype(F32)


def _dequantize_kv(q, scale, dtype):
    return (q.astype(F32) * scale[..., None]).astype(dtype)


def gqa_cache_init(cfg, batch: int, max_len: int, dtype):
    kvh, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    w = min(max_len, cfg.sliding_window) if cfg.sliding_window > 0 else max_len
    if cfg.kv_cache_dtype == "int8":
        return {
            "k": jnp.zeros((batch, w, kvh, dh), jnp.int8),
            "v": jnp.zeros((batch, w, kvh, dh), jnp.int8),
            "k_scale": jnp.zeros((batch, w, kvh), F32),
            "v_scale": jnp.zeros((batch, w, kvh), F32),
            "kv_pos": jnp.full((batch, w), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, w, kvh, dh), dtype),
        "v": jnp.zeros((batch, w, kvh, dh), dtype),
        "kv_pos": jnp.full((batch, w), -1, jnp.int32),
    }


def _cache_write(cfg, cache, k, v, positions, slot):
    """Write k/v (B,S,KVH,Dh) into the cache at slot (ring index or 0)."""
    upd = {"kv_pos": jax.lax.dynamic_update_slice_in_dim(
        cache["kv_pos"], positions, slot, axis=1)}
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        upd["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, slot, axis=1)
        upd["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, slot, axis=1)
        upd["k_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k_scale"], ks, slot, axis=1)
        upd["v_scale"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v_scale"], vs, slot, axis=1)
    else:
        upd["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
        upd["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    return upd


def _cache_read(cfg, cache, dtype):
    if cfg.kv_cache_dtype == "int8":
        return (_dequantize_kv(cache["k"], cache["k_scale"], dtype),
                _dequantize_kv(cache["v"], cache["v_scale"], dtype))
    return cache["k"], cache["v"]


def gqa_prefill(cfg, p, x, positions, cache):
    """Full-sequence forward that also fills the cache (positions start at 0).

    Attention runs on the full-precision K/V; the cache stores the
    (possibly int8-quantized) copies — standard serving practice."""
    b, s, _ = x.shape
    q, k, v = _gqa_qkv(cfg, p, x, positions)
    out = attention_math(cfg, q, k, v, positions, positions)
    w = cache["k"].shape[1]
    if s >= w:  # keep last w entries (ring consistent: slot = pos % w)
        tail_pos = positions[:, s - w:]
        idx = tail_pos[0] % w
        k_t, v_t = k[:, s - w:], v[:, s - w:]
        if cfg.kv_cache_dtype == "int8":
            kq, ks = _quantize_kv(k_t)
            vq, vs = _quantize_kv(v_t)
            cache = {
                "k": cache["k"].at[:, idx].set(kq),
                "v": cache["v"].at[:, idx].set(vq),
                "k_scale": cache["k_scale"].at[:, idx].set(ks),
                "v_scale": cache["v_scale"].at[:, idx].set(vs),
                "kv_pos": cache["kv_pos"].at[:, idx].set(tail_pos),
            }
        else:
            cache = {
                "k": cache["k"].at[:, idx].set(k_t),
                "v": cache["v"].at[:, idx].set(v_t),
                "kv_pos": cache["kv_pos"].at[:, idx].set(tail_pos),
            }
    else:
        cache = _cache_write(cfg, cache, k, v, positions, 0)
    return linear(p["wo"], out.reshape(b, s, -1)), cache


def gqa_decode(cfg, p, x, pos, cache):
    """One-token decode. x: (B,1,D); pos: () int32 current position."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _gqa_qkv(cfg, p, x, positions)
    w = cache["k"].shape[1]
    slot = pos % w
    cache = dict(cache, **_cache_write(cfg, cache, k, v, positions, slot))
    k_full, v_full = _cache_read(cfg, cache, k.dtype)
    out = naive_attention(q, k_full, v_full, positions, cache["kv_pos"],
                          cfg.sliding_window)
    return linear(p["wo"], out.reshape(b, 1, -1)), cache


# =========================================================================
# MLA (multi-head latent attention, DeepSeek-V2) block
# =========================================================================

def mla_init(key, cfg, dtype):
    d, h = cfg.d_model, cfg.num_heads
    r, dr, dn, dv = cfg.kv_lora_rank, cfg.qk_rope_head_dim, cfg.qk_nope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": linear_init(ks[0], d, h * (dn + dr), dtype),
        "w_dkv": linear_init(ks[1], d, r + dr, dtype),      # latent + shared rope key
        "kv_norm": rmsnorm_init(r),
        "w_uk": linear_init(ks[2], r, h * dn, dtype),
        "w_uv": linear_init(ks[3], r, h * dv, dtype),
        "wo": linear_init(ks[4], h * dv, d, dtype),
    }
    return p


def _mla_latent(cfg, p, x, positions):
    """Returns (latent (B,S,r) normalized, k_rope (B,S,1,dr) rotated)."""
    r, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    ckv = linear(p["w_dkv"], x)
    latent, k_rope = ckv[..., :r], ckv[..., r:]
    latent = rmsnorm(p["kv_norm"], latent, cfg.norm_eps)
    k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)  # (B,S,1,dr)
    return latent, k_rope


def _mla_q(cfg, p, x, positions):
    b, s, _ = x.shape
    h, dn, dr = cfg.num_heads, cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = linear(p["wq"], x).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_expand_kv(cfg, p, latent, k_rope):
    """Materialize per-head K (nope+rope) and V from the latent."""
    b, s, _ = latent.shape
    h, dn, dv = cfg.num_heads, cfg.qk_nope_head_dim, cfg.v_head_dim
    k_nope = linear(p["w_uk"], latent).reshape(b, s, h, dn)
    v = linear(p["w_uv"], latent).reshape(b, s, h, dv)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, k_rope.shape[-1]))], -1)
    return k, v


def mla_forward(cfg, p, x, positions):
    b, s, _ = x.shape
    scale = 1.0 / np.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    latent, k_rope = _mla_latent(cfg, p, x, positions)
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    k, v = _mla_expand_kv(cfg, p, latent, k_rope)
    q = jnp.concatenate([q_nope, q_rope], -1)
    out = attention_math(cfg, q, k, v, positions, positions, scale=scale)
    return linear(p["wo"], out.reshape(b, s, -1))


def mla_cache_init(cfg, batch: int, max_len: int, dtype):
    return {
        "latent": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
        "kv_pos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def mla_prefill(cfg, p, x, positions, cache):
    out = mla_forward(cfg, p, x, positions)
    latent, k_rope = _mla_latent(cfg, p, x, positions)
    cache = {
        "latent": jax.lax.dynamic_update_slice_in_dim(cache["latent"], latent, 0, axis=1),
        "k_rope": jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope[:, :, 0, :], 0, axis=1),
        "kv_pos": jax.lax.dynamic_update_slice_in_dim(cache["kv_pos"], positions, 0, axis=1),
    }
    return out, cache


def mla_decode(cfg, p, x, pos, cache, absorb: bool = True):
    """One-token MLA decode.

    absorb=True uses the weight-absorption trick: attention runs directly in
    the latent space (scores = (q_nope W_uk^T) · latent), so the cached latent
    is never expanded to per-head K/V — per-step HBM traffic drops from
    O(S·h·(dn+dv)) to O(S·(r+dr)). absorb=False is the naive baseline that
    expands the full cache every step; kept for §Perf comparison.
    """
    b = x.shape[0]
    h, dn, dv, r, dr = (cfg.num_heads, cfg.qk_nope_head_dim, cfg.v_head_dim,
                        cfg.kv_lora_rank, cfg.qk_rope_head_dim)
    scale = 1.0 / np.sqrt(dn + dr)
    positions = jnp.full((b, 1), pos, jnp.int32)
    latent, k_rope = _mla_latent(cfg, p, x, positions)
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    cache = {
        "latent": jax.lax.dynamic_update_slice_in_dim(cache["latent"], latent, pos, axis=1),
        "k_rope": jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope[:, :, 0, :], pos, axis=1),
        "kv_pos": jax.lax.dynamic_update_slice_in_dim(cache["kv_pos"], positions, pos, axis=1),
    }
    lat, krope_c, kv_pos = cache["latent"], cache["k_rope"], cache["kv_pos"]
    if absorb:
        wuk = p["w_uk"]["w"].reshape(r, h, dn)
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, wuk, preferred_element_type=F32)
        s_lat = jnp.einsum("bqhr,bsr->bhqs", q_lat.astype(lat.dtype), lat,
                           preferred_element_type=F32)
        s_rope = jnp.einsum("bqhd,bsd->bhqs", q_rope, krope_c, preferred_element_type=F32)
        scores = (s_lat + s_rope) * scale
        m = _mask(positions, kv_pos, 0)[:, None]
        scores = jnp.where(m, scores, NEG_INF)
        pr = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhqs,bsr->bqhr", pr.astype(lat.dtype), lat,
                           preferred_element_type=F32)         # (B,1,h,r)
        wuv = p["w_uv"]["w"].reshape(r, h, dv)
        out = jnp.einsum("bqhr,rhd->bqhd", o_lat.astype(x.dtype), wuv,
                         preferred_element_type=F32).astype(x.dtype)
    else:
        k, v = _mla_expand_kv(cfg, p, lat, krope_c[:, :, None, :])
        q = jnp.concatenate([q_nope, q_rope], -1)
        out = naive_attention(q, k, v, positions, kv_pos, 0, scale=scale)
    return linear(p["wo"], out.reshape(b, 1, -1)), cache
