"""xLSTM blocks: mLSTM (matrix memory, chunkwise-recurrent) and sLSTM
(scalar memory, exponential gating, sequential scan). arXiv:2405.04517.

TPU adaptation: the CUDA kernels of the reference are replaced by
  * mLSTM — chunkwise formulation: intra-chunk is a gated (L x L) matmul
    (MXU), inter-chunk is a short scan over chunk states; numerically
    stabilized with the running max-state m (as in the paper).
  * sLSTM — inherently sequential (recurrent weights); a ``lax.scan`` over
    time with per-head block-diagonal recurrent matrices.

Decode state is O(1): mLSTM carries (C: (B,H,dk,dv), n: (B,H,dk), m: (B,H));
sLSTM carries (c,n,h,m): (B,D) each.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import F32, linear, linear_init, rmsnorm, rmsnorm_init

LOG_EPS = -1e30


def _heads_dims(cfg):
    h = cfg.num_heads
    d_in = int(cfg.d_model * cfg.xlstm.proj_factor_mlstm)
    d_in -= d_in % (h * 2)
    dh = d_in // h
    return h, d_in, dh


# =========================================================================
# mLSTM block (pre-up-projection, as in the paper)
# =========================================================================

def mlstm_init(key, cfg, dtype):
    d = cfg.d_model
    h, d_in, dh = _heads_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "up": linear_init(ks[0], d, 2 * d_in, dtype),         # [cell path | gate path]
        "wq": linear_init(ks[1], d_in, d_in, dtype),
        "wk": linear_init(ks[2], d_in, d_in, dtype),
        "wv": linear_init(ks[3], d_in, d_in, dtype),
        "w_i": linear_init(ks[4], d_in, h, dtype, bias=True),
        "w_f": linear_init(ks[5], d_in, h, dtype, bias=True),
        "norm": rmsnorm_init(d_in),
        "down": linear_init(ks[6], d_in, d, dtype),
    }


def _mlstm_chunk_scan(q, k, v, i_pre, f_pre, state, chunk: int):
    """Stabilized chunkwise mLSTM.

    q,k,v: (B,S,H,dh); i_pre,f_pre: (B,S,H) gate preactivations.
    state: (C (B,H,dk,dv), n (B,H,dk), m (B,H)).
    Returns (y (B,S,H,dh), new_state).
    """
    bb, s, h, dh = q.shape
    l = min(chunk, s)
    assert s % l == 0
    nc = s // l
    logf = jax.nn.log_sigmoid(f_pre.astype(F32))               # (B,S,H)
    mask = jnp.tril(jnp.ones((l, l), bool))

    def r(t):
        return t.reshape(bb, nc, l, *t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1))

    qs, ks_, vs = r(q.astype(F32)), r(k.astype(F32)), r(v.astype(F32))
    is_, fs = r(i_pre.astype(F32)), r(logf)

    def step(carry, inp):
        c_prev, n_prev, m_prev = carry
        q_g, k_g, v_g, i_g, f_g = inp                          # (B,l,H,dh), (B,l,H)
        b_cum = jnp.cumsum(f_g, axis=1)                        # (B,l,H)
        a_run = jax.lax.cummax(i_g - b_cum, axis=1)            # running max of (i_s - b_s)
        m_t = b_cum + jnp.maximum(m_prev[:, None, :], a_run)   # (B,l,H)
        # intra weights W[t,s] = exp(b_t - b_s + i_s - m_t), s<=t
        seg = (b_cum[:, :, None, :] - b_cum[:, None, :, :]
               + i_g[:, None, :, :] - m_t[:, :, None, :])      # (B,t,s,H)
        # mask BEFORE exp (s>t exponents overflow; inf*0 NaNs the backward)
        w_ts = jnp.exp(jnp.where(mask[None, :, :, None], seg, -1e30))
        qk = jnp.einsum("bthd,bshd->btsh", q_g, k_g,
                        preferred_element_type=F32) / np.sqrt(dh)
        num_intra = jnp.einsum("btsh,bshd->bthd", w_ts * qk, v_g,
                               preferred_element_type=F32)
        den_intra = jnp.einsum("btsh->bth", w_ts * qk)
        # inter: scale exp(m_prev + b_t - m_t)
        g_t = jnp.exp(m_prev[:, None, :] + b_cum - m_t)        # (B,l,H)
        # NOTE: c_prev/n_prev already accumulate k/sqrt(dh); q is NOT rescaled
        num_inter = jnp.einsum("bthd,bhde->bthe", q_g, c_prev,
                               preferred_element_type=F32) * g_t[..., None]
        den_inter = jnp.einsum("bthd,bhd->bth", q_g, n_prev) * g_t
        num = num_intra + num_inter
        den = den_intra + den_inter
        m_last = m_t[:, -1]                                    # (B,H)
        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # state update to chunk end
        b_tot = b_cum[:, -1]                                   # (B,H)
        sc = jnp.exp(m_prev + b_tot - m_last)                  # (B,H)
        kv_dec = jnp.exp(b_tot[:, None, :] - b_cum + i_g - m_last[:, None, :])  # (B,l,H)
        c_new = c_prev * sc[..., None, None] + jnp.einsum(
            "bsh,bshd,bshe->bhde", kv_dec, k_g / np.sqrt(dh), v_g,
            preferred_element_type=F32)
        n_new = n_prev * sc[..., None] + jnp.einsum(
            "bsh,bshd->bhd", kv_dec, k_g / np.sqrt(dh))
        return (c_new, n_new, m_last), y

    state_f, ys = jax.lax.scan(step, state, (qs, ks_, vs, is_, fs))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bb, s, h, dh)
    return y, state_f


def mlstm_state_init(cfg, batch: int):
    h, d_in, dh = _heads_dims(cfg)
    return {"C": jnp.zeros((batch, h, dh, dh), F32),
            "n": jnp.zeros((batch, h, dh), F32),
            "m": jnp.full((batch, h), -1e30, F32)}


def _mlstm_cell_io(cfg, p, x):
    bb, s, _ = x.shape
    h, d_in, dh = _heads_dims(cfg)
    up = linear(p["up"], x)
    cell_in, gate = up[..., :d_in], up[..., d_in:]
    q = linear(p["wq"], cell_in).reshape(bb, s, h, dh)
    k = linear(p["wk"], cell_in).reshape(bb, s, h, dh)
    v = linear(p["wv"], cell_in).reshape(bb, s, h, dh)
    i_pre = linear(p["w_i"], cell_in)
    f_pre = linear(p["w_f"], cell_in)
    return q, k, v, i_pre, f_pre, gate


def mlstm_forward(cfg, p, x, state=None):
    bb, s, _ = x.shape
    h, d_in, dh = _heads_dims(cfg)
    q, k, v, i_pre, f_pre, gate = _mlstm_cell_io(cfg, p, x)
    if state is None:
        state = mlstm_state_init(cfg, bb)
        state = (state["C"], state["n"], state["m"])
    y, state_f = _mlstm_chunk_scan(q, k, v, i_pre, f_pre, state, cfg.xlstm.chunk)
    y = y.reshape(bb, s, d_in).astype(x.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps) * jax.nn.silu(gate.astype(F32)).astype(x.dtype)
    return linear(p["down"], y), state_f


def mlstm_decode(cfg, p, x, state):
    """x: (B,1,D); state dict as mlstm_state_init."""
    y, (c, n, m) = _mlstm_chunk_scan_single(cfg, p, x, state)
    return y, {"C": c, "n": n, "m": m}


def _mlstm_chunk_scan_single(cfg, p, x, state):
    bb = x.shape[0]
    h, d_in, dh = _heads_dims(cfg)
    q, k, v, i_pre, f_pre, gate = _mlstm_cell_io(cfg, p, x)
    st = (state["C"], state["n"], state["m"])
    y, state_f = _mlstm_chunk_scan(q, k, v, i_pre, f_pre, st, chunk=1)
    y = y.reshape(bb, 1, d_in).astype(x.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps) * jax.nn.silu(gate.astype(F32)).astype(x.dtype)
    return linear(p["down"], y), state_f


# =========================================================================
# sLSTM block (post-up-projection, per the paper)
# =========================================================================

def slstm_init(key, cfg, dtype):
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    d_ff = int(d * cfg.xlstm.proj_factor_slstm)
    ks = jax.random.split(key, 8)
    # 4 gates (i,f,z,o): input weights (d -> d) and per-head recurrent (h,dh,dh)
    def rec(k):
        return (jax.random.normal(k, (h, dh, dh), F32) / np.sqrt(dh)).astype(dtype)
    return {
        "w_gates": linear_init(ks[0], d, 4 * d, dtype, bias=True),
        "r_i": rec(ks[1]), "r_f": rec(ks[2]), "r_z": rec(ks[3]), "r_o": rec(ks[4]),
        "norm": rmsnorm_init(d),
        "ffn_up": linear_init(ks[5], d, 2 * d_ff, dtype),
        "ffn_down": linear_init(ks[6], d_ff, d, dtype),
    }


def slstm_state_init(cfg, batch: int):
    d = cfg.d_model
    return {"c": jnp.zeros((batch, d), F32), "n": jnp.zeros((batch, d), F32),
            "h": jnp.zeros((batch, d), F32), "m": jnp.full((batch, d), -1e30, F32)}


def _slstm_step(cfg, p, carry, g_x):
    """One timestep. carry: (c,n,h,m) each (B,D); g_x: (B,4D) input gate preacts."""
    c, n, hh, m = carry
    h_heads = cfg.num_heads
    d = cfg.d_model
    dh = d // h_heads
    hr = hh.reshape(-1, h_heads, dh)
    def rmul(r):
        return jnp.einsum("bhd,hde->bhe", hr, r.astype(F32)).reshape(-1, d)
    gi = g_x[..., :d] + rmul(p["r_i"])
    gf = g_x[..., d:2 * d] + rmul(p["r_f"])
    gz = g_x[..., 2 * d:3 * d] + rmul(p["r_z"])
    go = g_x[..., 3 * d:] + rmul(p["r_o"])
    log_f = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(log_f + m, gi)
    c_new = jnp.exp(log_f + m - m_new) * c + jnp.exp(gi - m_new) * jnp.tanh(gz)
    n_new = jnp.exp(log_f + m - m_new) * n + jnp.exp(gi - m_new)
    h_new = jax.nn.sigmoid(go) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new)


def slstm_forward(cfg, p, x, state=None):
    bb, s, d = x.shape
    if state is None:
        st = slstm_state_init(cfg, bb)
    else:
        st = state
    g_all = linear(p["w_gates"], x).astype(F32)               # (B,S,4D)

    def step(carry, g_t):
        new = _slstm_step(cfg, p, carry, g_t)
        return new, new[2]

    carry0 = (st["c"], st["n"], st["h"], st["m"])
    carry_f, hs = jax.lax.scan(step, carry0, g_all.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype)                 # (B,S,D)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    up = linear(p["ffn_up"], y)
    d_ff = up.shape[-1] // 2
    y = linear(p["ffn_down"], jax.nn.gelu(up[..., :d_ff]) * up[..., d_ff:])
    new_state = {"c": carry_f[0], "n": carry_f[1], "h": carry_f[2], "m": carry_f[3]}
    return y, new_state


def slstm_decode(cfg, p, x, state):
    y, st = slstm_forward(cfg, p, x, state)
    return y, st
