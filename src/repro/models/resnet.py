"""Paper-faithful CIFAR encoder: ResNet-14 with weight standardization
(Qiao et al. 2019) + GroupNorm(32) at every layer (paper Sec 4.2) — the
federated-friendly replacement for batch norm (no cross-client batch stats).

Config fields used (set by repro/configs/resnet14_cifar.py):
  resnet_stages:   blocks per stage, e.g. (2, 2, 2)
  resnet_channels: channels per stage, e.g. (64, 128, 256)
  resnet_groups:   GroupNorm group count (32; clipped to channels)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import F32, groupnorm


def _conv_init(key, kh, kw, cin, cout, dtype):
    fan_in = kh * kw * cin
    w = jax.random.truncated_normal(key, -2, 2, (kh, kw, cin, cout), F32) / np.sqrt(fan_in)
    return {"w": w.astype(dtype)}


def _gn_init(c):
    return {"scale": jnp.ones((c,), F32), "bias": jnp.zeros((c,), F32)}


def _std_weight(w):
    """Weight standardization over (kh, kw, cin) per output channel."""
    wf = w.astype(F32)
    mu = wf.mean(axis=(0, 1, 2), keepdims=True)
    var = wf.var(axis=(0, 1, 2), keepdims=True)
    return ((wf - mu) * jax.lax.rsqrt(var + 1e-5)).astype(w.dtype)


def _conv(p, x, stride=1):
    return jax.lax.conv_general_dilated(
        x, _std_weight(p["w"]), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def block_plan(cfg):
    """Static per-block (cin, cout, stride) derived from the config."""
    plan = []
    cin = cfg.resnet_channels[0]
    for si, (n_blocks, c) in enumerate(zip(cfg.resnet_stages, cfg.resnet_channels)):
        for bi in range(n_blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            plan.append((cin, c, stride))
            cin = c
    return plan


def resnet_init(key, cfg, dtype):
    chans = cfg.resnet_channels
    keys = iter(jax.random.split(key, 256))
    p = {"stem": _conv_init(next(keys), 3, 3, cfg.resnet_in_channels, chans[0], dtype),
         "stem_gn": _gn_init(chans[0]), "blocks": []}
    for cin, c, stride in block_plan(cfg):
        blk = {
            "conv1": _conv_init(next(keys), 3, 3, cin, c, dtype), "gn1": _gn_init(c),
            "conv2": _conv_init(next(keys), 3, 3, c, c, dtype), "gn2": _gn_init(c),
        }
        if stride != 1 or cin != c:
            blk["proj"] = _conv_init(next(keys), 1, 1, cin, c, dtype)
        p["blocks"].append(blk)
    return p


def resnet_forward(cfg, p, images):
    """images: (B,H,W,C) -> pooled (B, channels[-1]) f32."""
    g = cfg.resnet_groups
    x = images.astype(p["stem"]["w"].dtype)
    x = _conv(p["stem"], x)
    x = jax.nn.relu(groupnorm(x, min(g, x.shape[-1]), p["stem_gn"]["scale"],
                              p["stem_gn"]["bias"]))
    for blk, (cin, c, stride) in zip(p["blocks"], block_plan(cfg)):
        h = _conv(blk["conv1"], x, stride)
        h = jax.nn.relu(groupnorm(h, min(g, h.shape[-1]), blk["gn1"]["scale"],
                                  blk["gn1"]["bias"]))
        h = _conv(blk["conv2"], h)
        h = groupnorm(h, min(g, h.shape[-1]), blk["gn2"]["scale"], blk["gn2"]["bias"])
        sc = _conv(blk["proj"], x, stride) if "proj" in blk else x
        x = jax.nn.relu(h + sc)
    return x.astype(F32).mean(axis=(1, 2))
