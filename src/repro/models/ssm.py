"""Mamba2 (SSD) block — TPU adaptation.

The GPU reference implements SSD with a fused CUDA scan; on TPU we use the
chunked formulation: the sequence is split into chunks of length L, the
intra-chunk term is a masked (L x L) matmul batch (MXU-friendly), and the
inter-chunk term is a short ``lax.scan`` over chunk states. This keeps all
heavy math in matmuls with hardware-aligned dims instead of a long
elementwise recurrence.

State spec (decode): conv ring (B, W-1, conv_dim) + SSM state (B, H, P, N),
P = head_dim, N = ssm state size. O(1) in sequence length -> long_500k fits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import F32, linear, linear_init, rmsnorm, rmsnorm_init


def _dims(cfg):
    d_inner = cfg.ssm.expand * cfg.d_model
    heads = d_inner // cfg.ssm.head_dim
    conv_dim = d_inner + 2 * cfg.ssm.state
    return d_inner, heads, conv_dim


def mamba2_init(key, cfg, dtype):
    d = cfg.d_model
    d_inner, heads, conv_dim = _dims(cfg)
    n, w = cfg.ssm.state, cfg.ssm.conv_width
    ks = jax.random.split(key, 5)
    return {
        # order: [z (gate, d_inner) | x (d_inner) | B (n) | C (n) | dt (heads)]
        "in_proj": linear_init(ks[0], d, 2 * d_inner + 2 * n + heads, dtype),
        "conv_w": (jax.random.normal(ks[1], (w, conv_dim), F32) / np.sqrt(w)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, heads, dtype=F32)),
        "D": jnp.ones((heads,), F32),
        "dt_bias": jnp.log(jnp.expm1(jnp.linspace(1e-3, 1e-1, heads, dtype=F32))),
        "norm": rmsnorm_init(d_inner),
        "out_proj": linear_init(ks[2], d_inner, d, dtype),
    }


def _split_proj(cfg, proj):
    d_inner, heads, _ = _dims(cfg)
    n = cfg.ssm.state
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:d_inner + d_inner + 2 * n]
    dt = proj[..., -heads:]
    return z, xbc, dt


def _causal_conv(p, xbc):
    """Depthwise causal conv over (B, S, conv_dim)."""
    w = p["conv_w"].shape[0]
    pad = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * p["conv_w"][i].astype(F32)
              for i in range(w))
    return jax.nn.silu(out + p["conv_b"].astype(F32)).astype(xbc.dtype)


def _ssd_chunked(x, dt, a, b, c, chunk: int):
    """Chunked SSD scan.

    x: (B,S,H,P) inputs; dt: (B,S,H) >0; a: (H,) negative decay;
    b,c: (B,S,N) (single group). Returns y: (B,S,H,P).
    h_t = exp(dt_t a) h_{t-1} + dt_t * x_t b_t^T ;  y_t = h_t c_t + D x (D added by caller)
    """
    bb, s, h, pdim = x.shape
    n = b.shape[-1]
    l = min(chunk, s)
    assert s % l == 0, f"seq {s} % chunk {l} != 0"
    nc = s // l

    def r(t, shape):  # reshape seq -> (chunks, ...)
        return t.reshape(shape)

    xs = r(x, (bb, nc, l, h, pdim)).transpose(1, 0, 2, 3, 4).astype(F32)
    dts = r(dt, (bb, nc, l, h)).transpose(1, 0, 2, 3)
    bs = r(b, (bb, nc, l, n)).transpose(1, 0, 2, 3).astype(F32)
    cs = r(c, (bb, nc, l, n)).transpose(1, 0, 2, 3).astype(F32)
    mask = jnp.tril(jnp.ones((l, l), bool))

    def step(hprev, inp):
        x_g, dt_g, b_g, c_g = inp                         # (B,l,H,P) (B,l,H) (B,l,N)
        da = dt_g * a[None, None, :]                      # (B,l,H) log-decay (<0)
        cum = jnp.cumsum(da, axis=1)
        tot = cum[:, -1]                                  # (B,H)
        # intra: y[t] = sum_{s<=t} exp(cum_t - cum_s) dt_s (c_t.b_s) x_s
        # (mask BEFORE exp: the s>t region has positive exponents that
        # overflow, and inf*0 in the backward pass poisons gradients)
        seg = cum[:, :, None, :] - cum[:, None, :, :]     # (B,t,s,H)
        decay = jnp.exp(jnp.where(mask[None, :, :, None], seg, -1e30))
        cb = jnp.einsum("btn,bsn->bts", c_g, b_g, preferred_element_type=F32)
        w_ts = cb[..., None] * decay * dt_g[:, None, :, :]
        y_intra = jnp.einsum("btsh,bshp->bthp", w_ts, x_g, preferred_element_type=F32)
        # inter: y[t] += exp(cum_t) c_t . h_prev
        y_inter = jnp.einsum("bth,btn,bhnp->bthp", jnp.exp(cum), c_g, hprev,
                             preferred_element_type=F32)
        # state update: h_new = exp(tot) h_prev + sum_s exp(tot - cum_s) dt_s b_s x_s^T
        sdecay = jnp.exp(tot[:, None, :] - cum) * dt_g    # (B,l,H)
        states = jnp.einsum("bsh,bsn,bshp->bhnp", sdecay, b_g, x_g,
                            preferred_element_type=F32)
        hnew = hprev * jnp.exp(tot)[..., None, None] + states
        return hnew, y_intra + y_inter

    h0 = jnp.zeros((bb, h, n, pdim), F32)
    _, ys = jax.lax.scan(step, h0, (xs, dts, bs, cs))     # (nc,B,l,H,P)
    return ys.transpose(1, 0, 2, 3, 4).reshape(bb, s, h, pdim)


def mamba2_forward(cfg, p, x):
    """x: (B,S,D) -> (B,S,D). Training / prefill (no cache)."""
    bsz, s, _ = x.shape
    d_inner, heads, _ = _dims(cfg)
    pdim, n = cfg.ssm.head_dim, cfg.ssm.state
    proj = linear(p["in_proj"], x)
    z, xbc, dt_pre = _split_proj(cfg, proj)
    xbc = _causal_conv(p, xbc)
    xi = xbc[..., :d_inner].reshape(bsz, s, heads, pdim)
    b = xbc[..., d_inner:d_inner + n]
    c = xbc[..., d_inner + n:]
    dt = jax.nn.softplus(dt_pre.astype(F32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    y = _ssd_chunked(xi, dt, a, b, c, cfg.ssm.chunk)
    y = y + p["D"][None, None, :, None] * xi.astype(F32)
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z.astype(F32)).astype(x.dtype), cfg.norm_eps)
    return linear(p["out_proj"], y)


# ------------------------------------------------------------------ decode ---

def mamba2_cache_init(cfg, batch: int, dtype):
    d_inner, heads, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm.conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, heads, cfg.ssm.state, cfg.ssm.head_dim), F32),
    }


def mamba2_decode(cfg, p, x, cache):
    """x: (B,1,D) single step."""
    bsz = x.shape[0]
    d_inner, heads, conv_dim = _dims(cfg)
    pdim, n = cfg.ssm.head_dim, cfg.ssm.state
    proj = linear(p["in_proj"], x)
    z, xbc, dt_pre = _split_proj(cfg, proj)
    # conv ring: window = [cache, current]
    win = jnp.concatenate([cache["conv"], xbc], axis=1)    # (B, W, conv_dim)
    conv = jnp.einsum("bwc,wc->bc", win.astype(F32), p["conv_w"].astype(F32))
    xbc1 = jax.nn.silu(conv + p["conv_b"].astype(F32)).astype(x.dtype)[:, None, :]
    new_conv = win[:, 1:, :]
    xi = xbc1[..., :d_inner].reshape(bsz, heads, pdim)
    b = xbc1[:, 0, d_inner:d_inner + n]
    c = xbc1[:, 0, d_inner + n:]
    dt = jax.nn.softplus(dt_pre[:, 0].astype(F32) + p["dt_bias"])   # (B,H)
    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * a)                                 # (B,H)
    h = cache["ssm"] * decay[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, b.astype(F32), xi.astype(F32))
    y = jnp.einsum("bn,bhnp->bhp", c.astype(F32), h) + p["D"][None, :, None] * xi.astype(F32)
    y = y.reshape(bsz, 1, d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z.astype(F32)).astype(x.dtype), cfg.norm_eps)
    return linear(p["out_proj"], y), {"conv": new_conv, "ssm": h}
