"""Mixture-of-Experts FFN: top-k router + capacity-based einsum dispatch.

TPU-native (GShard/MaxText style): tokens are split into groups, each group
dispatches into (experts, capacity) slots via one-hot einsums — all shapes
static, so the expert dim shards cleanly over the ``model`` mesh axis
(expert parallelism) and the data→expert reshard lowers to an all-to-all.

DeepSeek flavour: ``num_shared_experts`` always-on experts (fused into one
wider SwiGLU) + fine-grained routed experts (small d_ff), top-k softmax
gating with weights normalized over the selected experts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import F32, swiglu, swiglu_init


def moe_init(key, d_model: int, moe_cfg, dtype):
    e, dff = moe_cfg.num_experts, moe_cfg.d_ff
    k_router, k_exp, k_shared = jax.random.split(key, 3)
    ks = jax.random.split(k_exp, 3)
    std = 1.0 / np.sqrt(d_model)
    p = {
        "router": {"w": (jax.random.normal(k_router, (d_model, e), F32) * std).astype(F32)},
        # stacked expert weights, leading dim = experts (sharded over `model`)
        "experts": {
            "gate": (jax.random.truncated_normal(ks[0], -2, 2, (e, d_model, dff), F32) * std).astype(dtype),
            "up": (jax.random.truncated_normal(ks[1], -2, 2, (e, d_model, dff), F32) * std).astype(dtype),
            "down": (jax.random.truncated_normal(ks[2], -2, 2, (e, dff, d_model), F32)
                     * (1.0 / np.sqrt(dff))).astype(dtype),
        },
    }
    if moe_cfg.num_shared_experts > 0:
        p["shared"] = swiglu_init(k_shared, d_model,
                                  moe_cfg.num_shared_experts * dff, dtype)
    return p


def _capacity(tokens_per_group: int, moe_cfg) -> int:
    c = int(np.ceil(tokens_per_group * moe_cfg.top_k / moe_cfg.num_experts
                    * moe_cfg.capacity_factor))
    return max(c, 1)


def moe_forward(p, x, moe_cfg, group_size: int = 512):
    """x: (B, S, D) -> (y (B,S,D), aux_losses dict).

    Tokens flattened to T=B*S, grouped into G groups of `group_size`; each
    group routes independently (bounds the dispatch tensor to
    group_size x E x C).
    """
    b, s, d = x.shape
    e, k = moe_cfg.num_experts, moe_cfg.top_k
    t = b * s
    g_sz = min(group_size, t)
    assert t % g_sz == 0, f"tokens {t} not divisible by group {g_sz}"
    g = t // g_sz
    xt = x.reshape(g, g_sz, d)

    logits = jnp.einsum("gsd,de->gse", xt.astype(F32), p["router"]["w"],
                        preferred_element_type=F32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (G,S,E)

    # top-k selection; weights renormalized over the chosen experts
    topk_p, topk_idx = jax.lax.top_k(probs, k)                 # (G,S,K)
    topk_w = topk_p / jnp.maximum(topk_p.sum(-1, keepdims=True), 1e-9)

    cap = _capacity(g_sz, moe_cfg)
    sel = jax.nn.one_hot(topk_idx, e, dtype=F32)               # (G,S,K,E)
    # position of each (token, k) within its expert queue, priority by k then s
    pos_in_e = jnp.cumsum(sel.reshape(g, g_sz * k, e), axis=1).reshape(g, g_sz, k, e) - 1.0
    keep = (pos_in_e < cap) * sel                              # drop overflow
    pos_oh = jax.nn.one_hot(pos_in_e.astype(jnp.int32), cap, dtype=F32) * keep[..., None]
    # combine[g,s,e,c] = routing weight of token s into slot (e,c)
    combine = jnp.einsum("gske,gskec->gsec", topk_w[..., None] * keep, pos_oh,
                         preferred_element_type=F32)
    dispatch = (combine > 0).astype(x.dtype)                   # (G,S,E,C)

    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xt, preferred_element_type=F32).astype(x.dtype)
    we = p["experts"]
    h = jnp.einsum("gecd,edf->gecf", xe, we["gate"], preferred_element_type=F32)
    u = jnp.einsum("gecd,edf->gecf", xe, we["up"], preferred_element_type=F32)
    h = (jax.nn.silu(h) * u).astype(x.dtype)
    ye = jnp.einsum("gecf,efd->gecd", h, we["down"], preferred_element_type=F32)
    y = jnp.einsum("gsec,gecd->gsd", combine, ye.astype(F32),
                   preferred_element_type=F32).astype(x.dtype)
    y = y.reshape(b, s, d)

    if "shared" in p:
        y = y + swiglu(p["shared"], x)

    # aux losses: load balance (Shazeer/GShard) + router z-loss
    me = probs.mean(axis=(0, 1))                               # mean prob per expert
    ce = sel[..., :].sum(2).mean(axis=(0, 1))                  # fraction routed per expert
    balance = e * jnp.sum(me * ce) / k
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {"balance": balance, "router_z": z,
           "dropped_frac": 1.0 - keep.sum() / (sel.sum() + 1e-9)}
    return y, aux
