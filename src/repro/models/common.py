"""Shared neural-net primitives (pure JAX, param pytrees are nested dicts).

Conventions:
  * params are dicts of jnp arrays; every creator takes (key, ...) and returns the dict.
  * compute dtype follows the input; params are stored in cfg.dtype (bf16 by
    default) except norms/scales kept in f32.
  * all matmuls accumulate in f32 via ``preferred_element_type``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


# ----------------------------------------------------------------- linear ---

def linear_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16, bias: bool = False,
                scale: float = 1.0):
    std = scale / np.sqrt(d_in)
    p = {"w": (jax.random.truncated_normal(key, -2, 2, (d_in, d_out), F32) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


# Cross-device matmul reduction dtype. XLA places the tensor-parallel
# all-reduce on the dot output BEFORE the cast back to the activation dtype,
# so with preferred_element_type=f32 the partial sums cross the ICI in f32 —
# 2x the necessary wire bytes. Setting bf16 here halves TP collective
# payloads at a small cross-device accumulation-precision cost (a standard
# production knob; see EXPERIMENTS §Perf). None = f32 (default, exact).
_MATMUL_PREFERRED = {"dtype": None}


def set_matmul_preferred(dtype) -> None:
    _MATMUL_PREFERRED["dtype"] = dtype


def linear(p, x):
    pe = _MATMUL_PREFERRED["dtype"] or F32
    y = jnp.einsum("...i,io->...o", x, p["w"], preferred_element_type=pe)
    if "b" in p:
        y = y + p["b"].astype(pe)
    return y.astype(x.dtype)


# ------------------------------------------------------------------ norms ---

def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), F32)}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


def layernorm_init(d: int):
    return {"scale": jnp.ones((d,), F32), "bias": jnp.zeros((d,), F32)}


def layernorm(p, x, eps: float = 1e-6):
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


def groupnorm(x, num_groups: int, scale, bias, eps: float = 1e-5):
    """GroupNorm over channel-last input (..., C). Paper Sec 4.2 (Wu & He)."""
    *lead, c = x.shape
    assert c % num_groups == 0
    xf = x.astype(F32).reshape(*lead, num_groups, c // num_groups)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf.reshape(*lead, c) * scale + bias).astype(x.dtype)


# ------------------------------------------------------------------- rope ---

def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, Dh) rotated pairwise; positions: (..., S) or (S,)."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(dh, theta))          # (dh/2,)
    angles = positions.astype(F32)[..., None] * freqs          # (..., S, dh/2)
    angles = angles[..., None, :]                              # (..., S, 1, dh/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., : dh // 2], x[..., dh // 2:]
    xf1, xf2 = x1.astype(F32), x2.astype(F32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- embedding ---

def embedding_init(key, vocab: int, d_model: int, dtype=jnp.bfloat16):
    emb = jax.random.normal(key, (vocab, d_model), F32) * 0.02
    return {"table": emb.astype(dtype)}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p, x):
    """Tied unembedding: (..., D) @ (V, D)^T -> logits (..., V)."""
    return jnp.einsum("...d,vd->...v", x, p["table"], preferred_element_type=F32)


# ------------------------------------------------------------------- misc ---

def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": linear_init(k1, d_model, d_ff, dtype),
        "up": linear_init(k2, d_model, d_ff, dtype),
        "down": linear_init(k3, d_ff, d_model, dtype),
    }


def swiglu(p, x):
    return linear(p["down"], jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x))


def mlp_init(key, dims, dtype=jnp.bfloat16, bias=True):
    """Plain MLP for projection heads: dims = (d_in, h1, ..., d_out)."""
    keys = jax.random.split(key, len(dims) - 1)
    return {"layers": [linear_init(k, dims[i], dims[i + 1], dtype, bias=bias)
                       for i, k in enumerate(keys)]}


def mlp(p, x, final_activation=False):
    n = len(p["layers"])
    for i, lp in enumerate(p["layers"]):
        x = linear(lp, x)
        if i < n - 1 or final_activation:
            x = jax.nn.relu(x)
    return x
