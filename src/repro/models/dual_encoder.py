"""Dual encoding model (paper Fig. 1): tower(s) + pooling + projection head.

Supports the three wirings in the paper:
  (a) shared tower, two augmented views of the same input  (self-supervised)
  (b) two different towers over two views
  (c) two modality-specific towers (VLM: vision patches vs text tokens)

The projection network follows Sec 4.2: a 3-layer MLP that *increases*
dimensionality before the CCO loss and is discarded downstream.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import transformer, resnet as resnet_mod
from repro.models.common import F32, dtype_of, mlp, mlp_init


def is_resnet(cfg) -> bool:
    return getattr(cfg, "family", "") == "resnet"


def init_dual_encoder(key, cfg, de_cfg):
    k_tower, k_tower2, k_proj, k_proj2 = jax.random.split(key, 4)
    dtype = dtype_of(cfg.dtype)
    if is_resnet(cfg):
        tower = resnet_mod.resnet_init(k_tower, cfg, dtype)
        d_enc = cfg.resnet_channels[-1]
    else:
        tower = transformer.init_params(cfg, k_tower)
        d_enc = cfg.d_model
    params: Dict[str, Any] = {
        "tower": tower,
        "proj": mlp_init(k_proj, (d_enc,) + tuple(de_cfg.proj_dims), dtype, bias=True),
    }
    if not de_cfg.shared_towers:
        if is_resnet(cfg):
            params["tower_g"] = resnet_mod.resnet_init(k_tower2, cfg, dtype)
        else:
            params["tower_g"] = transformer.init_params(cfg, k_tower2)
        params["proj_g"] = mlp_init(k_proj2, (d_enc,) + tuple(de_cfg.proj_dims),
                                    dtype, bias=True)
    return params


def _pool(hidden, mask=None):
    """Mean-pool token encodings -> (B, D) in f32."""
    h = hidden.astype(F32)
    if mask is not None:
        m = mask.astype(F32)[..., None]
        return (h * m).sum(1) / jnp.maximum(m.sum(1), 1.0)
    return h.mean(axis=1)


def encode(cfg, de_cfg, params, view, tower: str = "f"):
    """Encode one view -> (z (B, d_proj) f32, aux dict).

    view: dict with 'tokens' (B,S) and/or 'patch_embeds' (B,P,vis_dim) and/or
    'images' (B,H,W,C) for the resnet tower; optional 'mask' (B,S).
    """
    tower_p = params["tower"] if (tower == "f" or de_cfg.shared_towers) \
        else params["tower_g"]
    proj_p = params["proj"] if (tower == "f" or de_cfg.shared_towers) \
        else params["proj_g"]
    aux = {"balance": jnp.zeros((), F32), "router_z": jnp.zeros((), F32)}
    if is_resnet(cfg):
        pooled = resnet_mod.resnet_forward(cfg, tower_p, view["images"])
    else:
        hidden, aux = transformer.forward(
            cfg, tower_p, view["tokens"],
            patch_embeds=view.get("patch_embeds"), return_aux=True)
        pooled = _pool(hidden, view.get("mask"))
    z = mlp(proj_p, pooled.astype(dtype_of(cfg.dtype)))
    return z.astype(F32), aux


def encode_pair(cfg, de_cfg, params, view1, view2):
    """Encode both views (F and G). Returns (zf, zg, aux)."""
    zf, aux1 = encode(cfg, de_cfg, params, view1, tower="f")
    zg, aux2 = encode(cfg, de_cfg, params, view2, tower="g")
    aux = {k: aux1[k] + aux2[k] for k in aux1}
    return zf, zg, aux
