from repro.checkpoint.checkpoint import (  # noqa: F401
    restore_checkpoint,
    restore_checkpoint_flat,
    save_checkpoint,
)
