"""Sharding-aware msgpack checkpointing (no orbax offline).

Leaves are gathered to host (fully addressable or replicated), serialized
with msgpack + raw buffers, and restored onto a target sharding tree via
``jax.device_put``. Layout: one file per checkpoint with a JSON-able tree
spec and a flat list of (dtype, shape, bytes).
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    paths, leaves, _ = _flatten_with_paths(tree)
    payload = {
        "step": step,
        "paths": paths,
        "leaves": [
            {"dtype": str(np.asarray(x).dtype), "shape": list(np.asarray(x).shape),
             "data": np.ascontiguousarray(np.asarray(x)).tobytes()}
            for x in leaves
        ],
    }
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def restore_checkpoint_flat(path: str):
    """Templateless restore: ``({path: np.ndarray}, step)`` keyed by the
    '/'-joined tree paths the checkpoint was saved with. For consumers that
    own their layout (e.g. ``repro.retrieval.CorpusIndex``) and can rebuild
    structure from the keys — ``restore_checkpoint`` stays the API when a
    ``like`` template tree exists."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    flat = {
        p: np.frombuffer(rec["data"],
                         dtype=rec["dtype"]).reshape(rec["shape"])
        for p, rec in zip(payload["paths"], payload["leaves"])
    }
    return flat, payload["step"]


def restore_checkpoint(path: str, like: Any, shardings: Optional[Any] = None):
    """Restore into the structure of `like`; optionally device_put onto
    matching shardings (same treedef)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    paths, like_leaves, treedef = _flatten_with_paths(like)
    stored = dict(zip(payload["paths"], payload["leaves"]))
    out = []
    for p, ref in zip(paths, like_leaves):
        rec = stored[p]
        arr = np.frombuffer(rec["data"], dtype=rec["dtype"]).reshape(rec["shape"])
        out.append(jnp.asarray(arr))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, payload["step"]
