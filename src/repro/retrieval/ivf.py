"""IVF pruning tier: coarse-quantized inverted lists over the encoded
corpus — the recall-vs-qps knob of retrieval serving.

The exact tier (``CorpusIndex``/``ShardedCorpusIndex``) pays O(N·d) per
query batch. At corpus scale most of that work scores items nowhere near
the query, so this module adds the classical IVF structure on top of the
SAME embeddings:

  * **coarse quantizer** — ``num_centroids`` spherical k-means centroids
    trained on the encoded corpus (``train_centroids``: Lloyd's under
    ``lax.scan``, inner-product assignment, re-normalized means —
    normalized embeddings make cosine == MIPS, the index's contract);
  * **inverted lists, contiguous + padded** — items are bucketed by
    nearest centroid into one (C, L, d) embedding block and one (C, L)
    i32 global-index block, L = the longest list rounded up to a lane
    multiple; the pad slots carry (0-rows, BIG_IDX) so they mask exactly
    like the MIPS kernel's padded corpus rows. One gather per probe then
    lands a whole list as one contiguous tile — no per-item pointer
    chasing on device;
  * **nprobe search** — per query, score the C centroids (the only full
    sweep left, C ≪ N), take the ``nprobe`` closest lists, and stream
    their tiles through the same running-top-k machinery as the fused
    kernel: a ``lax.scan`` over groups of ``probe_chunk`` probe ranks
    carrying the running (Q, k) state, merged by ``_select_topk`` (value
    desc, lowest GLOBAL index on ties — positional stability is NOT
    enough here because later probes may hold smaller indices). Work per
    query drops from O(N·d) to O(C·d + nprobe·L·d), with candidate
    residency bounded at O(Q·probe_chunk·L·d);
  * **exact-tier fallback** — the flat embeddings stay resident, and
    ``search`` routes to ``mips_topk`` whenever the pruned tier cannot
    honor the request (``nprobe <= 0``, or fewer than k candidate slots
    in the probed lists); ``search_exact`` forces it.

``nprobe == num_centroids`` scans every list exactly once, so it
recovers the exact-tier result (the tier-1 property test); smaller
``nprobe`` trades recall for qps — the ``retrieval_scale`` bench measures
that curve and CI gates recall@10 at the default ``nprobe``.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.mips_topk import BIG_IDX, NEG_INF, _select_topk, mips_topk
from repro.retrieval.index import CorpusIndex, encode_corpus_chunked, \
    l2_normalize

F32 = jnp.float32
I32 = jnp.int32


@functools.partial(jax.jit, static_argnames=("num_centroids", "iters"))
def train_centroids(embeddings, *, num_centroids: int, iters: int = 8,
                    seed: int = 0):
    """Spherical k-means on (N, d) normalized embeddings -> (C, d)
    normalized centroids. Lloyd's iterations under ``lax.scan``:
    inner-product assignment (argmax breaks ties toward the lowest
    centroid), segment-sum means, empty clusters keep their previous
    centroid, means re-normalized onto the sphere."""
    emb = embeddings.astype(F32)
    n, _ = emb.shape
    key = jax.random.PRNGKey(seed)
    cent0 = emb[jax.random.permutation(key, n)[:num_centroids]]

    def step(cent, _):
        assign = jnp.argmax(emb @ cent.T, axis=1)
        sums = jax.ops.segment_sum(emb, assign,
                                   num_segments=num_centroids)
        counts = jax.ops.segment_sum(jnp.ones((n,), F32), assign,
                                     num_segments=num_centroids)
        new = jnp.where(counts[:, None] > 0,
                        sums / jnp.maximum(counts, 1.0)[:, None], cent)
        return l2_normalize(new), None

    cent, _ = jax.lax.scan(step, cent0, None, length=iters)
    return cent


@functools.partial(jax.jit,
                   static_argnames=("k", "nprobe", "n_total", "probe_chunk"))
def _ivf_search(q, centroids, lists_emb, lists_idx, *, k: int, nprobe: int,
                n_total: int, probe_chunk: int):
    """The pruned search program: coarse top-nprobe, then a running-top-k
    scan over GROUPS of ``probe_chunk`` probe ranks. Each group gathers
    its lists as one (Q, probe_chunk·L, d) tile and merges once — peak
    candidate residency is O(Q · probe_chunk · L · d), never the full
    O(Q · nprobe · L · d), and fewer merge rounds beat a per-probe scan
    (the per-step select is the fixed cost). ``probe_chunk == nprobe``
    collapses to a single gather + one merge."""
    q = q.astype(F32)
    qn, d = q.shape
    ll = lists_emb.shape[1]
    _, probes = jax.lax.top_k(q @ centroids.T, nprobe)       # (Q, nprobe)
    pc = max(1, min(probe_chunk, nprobe))
    pad = (-nprobe) % pc
    if pad:
        # repeat the last probe to fill the group; duplicated candidates
        # are harmless — _select_topk takes every position matching the
        # chosen (value, index) pair in one round
        probes = jnp.concatenate(
            [probes, jnp.repeat(probes[:, -1:], pad, axis=1)], axis=1)
    groups = jnp.transpose(probes.reshape(qn, -1, pc), (1, 0, 2))

    def body(carry, g_col):                                  # g_col: (Q, pc)
        vals, idxs = carry
        ce = lists_emb[g_col].astype(F32).reshape(qn, pc * ll, d)
        ci = lists_idx[g_col].reshape(qn, pc * ll)
        s = jax.lax.dot_general(q, ce, (((1,), (2,)), ((0,), (0,))),
                                preferred_element_type=F32)  # (Q, pc·L)
        s = jnp.where(ci < n_total, s, NEG_INF)
        cand_v = jnp.concatenate([vals, s], axis=1)
        cand_i = jnp.concatenate([idxs, ci], axis=1)
        return _select_topk(cand_v, cand_i, k), None

    init = (jnp.full((qn, k), NEG_INF, F32),
            jnp.full((qn, k), BIG_IDX, I32))
    (vals, idxs), _ = jax.lax.scan(body, init, groups)
    return vals, idxs


class IVFIndex:
    """Inverted-file approximate index over an encoded corpus."""

    def __init__(self, embeddings, centroids, *, nprobe: int = 8,
                 list_pad: int = 8, normalized: bool = True):
        if embeddings.ndim != 2:
            raise ValueError(f"embeddings must be (N, d), "
                             f"got {embeddings.shape}")
        self.embeddings = embeddings
        self.centroids = jnp.asarray(centroids, F32)
        self.nprobe = int(nprobe)
        self.normalized = normalized
        n, d = embeddings.shape
        c = self.centroids.shape[0]
        if not 1 <= self.nprobe <= c:
            raise ValueError(f"nprobe={nprobe} must be in [1, "
                             f"num_centroids={c}]")
        # ---- contiguous padded inverted lists (host-side, build time) ----
        assign = np.asarray(
            jnp.argmax(embeddings.astype(F32) @ self.centroids.T, axis=1))
        counts = np.bincount(assign, minlength=c)
        pad_to = max(1, int(list_pad))
        ll = int(-(-max(int(counts.max()), 1) // pad_to) * pad_to)
        lists_idx = np.full((c, ll), BIG_IDX, np.int32)
        emb_np = np.asarray(embeddings)
        lists_emb = np.zeros((c, ll, d), emb_np.dtype)
        for ci in range(c):
            members = np.nonzero(assign == ci)[0]   # ascending global idx
            lists_idx[ci, :len(members)] = members
            lists_emb[ci, :len(members)] = emb_np[members]
        self.list_len = ll
        self.list_counts = counts
        self.lists_idx = jnp.asarray(lists_idx)
        self.lists_emb = jnp.asarray(lists_emb)

    @property
    def num_items(self) -> int:
        return self.embeddings.shape[0]

    @property
    def dim(self) -> int:
        return self.embeddings.shape[1]

    @property
    def num_centroids(self) -> int:
        return self.centroids.shape[0]

    @property
    def fill(self) -> float:
        """Occupied fraction of the padded (C, L) layout — the memory
        overhead of contiguous lists is 1/fill."""
        return self.num_items / float(self.num_centroids * self.list_len)

    # -- build ---------------------------------------------------------------
    @classmethod
    def from_index(cls, index: CorpusIndex, *, num_centroids: int,
                   nprobe: int = 8, iters: int = 8, seed: int = 0,
                   list_pad: int = 8) -> "IVFIndex":
        cent = train_centroids(index.embeddings.astype(F32),
                               num_centroids=num_centroids, iters=iters,
                               seed=seed)
        return cls(index.embeddings, cent, nprobe=nprobe, list_pad=list_pad,
                   normalized=index.normalized)

    @classmethod
    def build(cls, encode_fn: Callable, params, corpus, *,
              num_centroids: int, nprobe: int = 8, iters: int = 8,
              seed: int = 0, chunk: int = 256, normalize: bool = True,
              dtype=jnp.float32) -> "IVFIndex":
        z = encode_corpus_chunked(encode_fn, params, corpus, chunk=chunk,
                                  normalize=normalize, dtype=dtype)
        cent = train_centroids(z.astype(F32), num_centroids=num_centroids,
                               iters=iters, seed=seed)
        return cls(z, cent, nprobe=nprobe, normalized=normalize)

    # -- search --------------------------------------------------------------
    def search_exact(self, queries, k: int, *, backend: str = "auto", **kw):
        """The exact tier: full ``mips_topk`` over the flat embeddings."""
        return mips_topk(queries.astype(F32), self.embeddings, k,
                         backend=backend, **kw)

    def search(self, queries, k: int, *, nprobe: Optional[int] = None,
               probe_chunk: int = 8, backend: str = "auto", **kw):
        """Approximate top-k: queries (Q, d) -> ((Q, k) f32 scores, (Q, k)
        i32 global item indices), (score desc, lowest-index ties) order.

        ``nprobe`` overrides the index default; ``nprobe <= 0`` — or a
        request the pruned tier cannot honor (k exceeding the probed
        lists' candidate slots) — falls back to the exact tier.
        ``probe_chunk`` bounds candidate residency (O(Q·probe_chunk·L·d)
        gathered per merge round). ``backend`` and ``kw`` only shape the
        exact-tier fallback; the pruned program is pure jnp (gathers +
        running top-k)."""
        p = self.nprobe if nprobe is None else int(nprobe)
        p = min(p, self.num_centroids)
        if p <= 0 or p * self.list_len < k:
            return self.search_exact(queries, k, backend=backend, **kw)
        if not 1 <= k <= self.num_items:
            raise ValueError(f"k={k} must be in [1, corpus size "
                             f"{self.num_items}]")
        return _ivf_search(queries, self.centroids, self.lists_emb,
                           self.lists_idx, k=k, nprobe=p,
                           n_total=self.num_items,
                           probe_chunk=int(probe_chunk))
