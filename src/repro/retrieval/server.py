"""Batched retrieval query server: the online half of serving.

Wraps a :class:`repro.retrieval.CorpusIndex` (or its sharded / IVF
drop-ins — anything with ``dim`` and ``search``) behind a fixed-batch
jitted search (one compiled program per (batch, k) shape — ragged request
batches pad up to ``batch`` and slice back, the usual serving shape
discipline) and keeps per-batch latency samples so a run reports the
numbers a serving dashboard needs: queries/sec and p50/p99 latency vs
corpus size. Wall-clock is measured host-side around a
``block_until_ready`` so a latency sample covers the full dispatch +
compute + readback path a caller would see.

Two throughput numbers, deliberately distinct: ``qps`` is wall-clock
(queries / window from first sample start to last sample end — what a
load generator observes, gaps between requests included), ``qps_serial``
is the serve-time-only rate (queries / sum of per-batch latencies — the
server's capacity if requests arrived back-to-back). Back-to-back
benches make them nearly equal; a think-time client makes ``qps`` the
honest dashboard number and ``qps_serial`` the capacity bound.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

F32 = jnp.float32


class QueryServer:
    """Fixed-batch top-k query serving over a CorpusIndex."""

    def __init__(self, index, *, k: int = 10, batch: int = 64,
                 backend: str = "auto", **search_kw):
        self.index = index
        self.k = k
        self.batch = batch
        self._samples: list[tuple[float, float]] = []   # (start_s, end_s)
        self._queries = 0

        def search(q):
            return index.search(q, k, backend=backend, **search_kw)

        self._search = jax.jit(search)

    def warmup(self):
        """Compile the serving program outside the measured path."""
        q = jnp.zeros((self.batch, self.index.dim), F32)
        jax.block_until_ready(self._search(q))
        return self

    def query(self, queries):
        """Serve one request batch: (B, d) with B <= batch -> ((B, k)
        scores, (B, k) indices). Pads B up to the compiled batch, records
        one end-to-end latency sample."""
        b = queries.shape[0]
        if b > self.batch:
            raise ValueError(f"request batch {b} exceeds the compiled "
                             f"serving batch {self.batch}")
        if queries.ndim != 2 or queries.shape[-1] != self.index.dim:
            raise ValueError(
                f"queries must be (B, {self.index.dim}) to match the "
                f"index embedding dim, got {tuple(queries.shape)}")
        if b < self.batch:
            queries = jnp.pad(queries, ((0, self.batch - b), (0, 0)))
        t0 = time.perf_counter()
        vals, idxs = jax.block_until_ready(self._search(queries))
        self._samples.append((t0, time.perf_counter()))
        self._queries += b
        return vals[:b], idxs[:b]

    def stats(self) -> Optional[dict]:
        """Serving stats over every recorded batch: wall-clock ``qps``
        (first sample start to last sample end), serve-time-only
        ``qps_serial`` (sum of per-batch latencies), and p50/p99 per-batch
        latency (us). None before any query."""
        if not self._samples:
            return None
        lat = np.asarray([(t1 - t0) * 1e6 for t0, t1 in self._samples])
        serial_s = float(lat.sum()) / 1e6
        wall_s = self._samples[-1][1] - self._samples[0][0]
        return {
            "batches": len(self._samples),
            "queries": self._queries,
            "qps": self._queries / max(wall_s, 1e-12),
            "qps_serial": self._queries / max(serial_s, 1e-12),
            "p50_us": float(np.percentile(lat, 50)),
            "p99_us": float(np.percentile(lat, 99)),
        }

    def reset_stats(self):
        self._samples.clear()
        self._queries = 0
