"""Retrieval serving subsystem: corpus index + fused MIPS search + eval.

The serving half of the dual-encoder story (paper Sec. 1): encode an item
corpus once (:class:`CorpusIndex`, O(chunk)-memory build, fp32/bf16
normalized storage, msgpack persistence), answer batched top-k queries
through the fused Pallas MIPS kernel (``kernels/mips_topk.py`` — no (Q, N)
score materialization on any backend), measure serving throughput/latency
(:class:`QueryServer`), and score retrieval quality during training
(``make_retrieval_eval`` -> recall@k / MRR via core/eval.py, run
periodically by the RoundEngine alongside the probe).
"""
from repro.retrieval.index import (  # noqa: F401
    CorpusIndex,
    encode_corpus_chunked,
    l2_normalize,
    make_retrieval_eval,
)
from repro.retrieval.server import QueryServer  # noqa: F401
