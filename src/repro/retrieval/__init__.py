"""Retrieval serving subsystem: corpus index + fused MIPS search + eval.

The serving half of the dual-encoder story (paper Sec. 1): encode an item
corpus once (:class:`CorpusIndex`, O(chunk)-memory build, fp32/bf16
normalized storage, msgpack persistence), answer batched top-k queries
through the fused Pallas MIPS kernel (``kernels/mips_topk.py`` — no (Q, N)
score materialization on any backend), measure serving throughput/latency
(:class:`QueryServer`), and score retrieval quality during training
(``make_retrieval_eval`` -> recall@k / MRR via core/eval.py, run
periodically by the RoundEngine alongside the probe).

Scaling tiers on the same index (PR 9):

  * :class:`ShardedCorpusIndex` (``sharded.py``) — the corpus partitioned
    over a mesh "corpus" axis, per-shard fused kernels + an all-gather
    top-k merge, bit-identical to single-device search;
  * :class:`IVFIndex` (``ivf.py``) — inverted-file approximate tier with
    an ``nprobe`` recall-vs-qps knob and an exact fallback;
  * drift-gated streaming refresh (``CorpusIndex.refresh`` /
    ``make_refreshing_retrieval_eval``) — re-encode only items that moved
    past an L2 threshold, so a live index tracks a training checkpoint at
    a fraction of full re-encode cost.
"""
from repro.retrieval.index import (  # noqa: F401
    CorpusIndex,
    encode_corpus_chunked,
    l2_normalize,
    make_refreshing_retrieval_eval,
    make_retrieval_eval,
    refresh_embeddings,
)
from repro.retrieval.ivf import IVFIndex, train_centroids  # noqa: F401
from repro.retrieval.server import QueryServer  # noqa: F401
from repro.retrieval.sharded import (  # noqa: F401
    ShardedCorpusIndex,
    sharded_mips_topk,
)
