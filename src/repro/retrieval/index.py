"""Corpus index: the offline half of dual-encoder retrieval serving.

A deployed dual encoder answers nearest-neighbour queries against a corpus
encoded ONCE (paper Sec. 1's use case). ``CorpusIndex`` owns that encoded
corpus:

  * **chunked build** — the corpus is encoded ``chunk`` items at a time
    under ``lax.map`` (the PR-5 streaming idiom: peak activation memory is
    O(chunk), not O(corpus) — the encoder forward never sees more than one
    chunk);
  * **normalized storage** — embeddings are L2-normalized (cosine == inner
    product, the MIPS kernel's contract) and stored fp32 or bf16
    (``dtype=jnp.bfloat16`` halves index residency; search upcasts tiles
    to f32 on the fly);
  * **msgpack persistence** — ``save``/``load`` via ``repro.checkpoint``,
    so an index snapshot rides the same format as engine checkpoints;
  * **search** — ``mips_topk`` (kernels/mips_topk.py) backend-dispatched:
    fused Pallas kernel on accelerators, running-top-k chunked scan on
    CPU; no path materializes the (Q, N) score matrix.

``make_retrieval_eval`` packages an index-build + search + label-match
metrics (core/eval.py) into one traceable ``params -> metrics`` function —
the periodic in-training eval the RoundEngine runs alongside the probe.

**Streaming refresh** (``refresh_embeddings`` / ``CorpusIndex.refresh`` /
``make_refreshing_retrieval_eval``): as training moves the encoder, a
stale index drifts — but between nearby checkpoints most items barely
move. The drift-gated refresh re-encodes only what moved: a chunked
probe re-encodes a strided sample (``probes_per_block`` items per
``block``-item block, ~probes/block of full encode cost), blocks whose
max probe L2 drift exceeds ``threshold`` get a targeted full re-encode
under ``lax.cond`` (the untaken branch costs nothing at runtime), and
everything else keeps its stored rows. ``make_refreshing_retrieval_eval``
carries the index as engine eval STATE (``eval_fn(params, state) ->
(metrics, state)``, marked ``.stateful``), so the periodic in-training
eval tracks the current checkpoint at a fraction of full re-encode cost.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import restore_checkpoint_flat, save_checkpoint
from repro.core import eval as eval_lib
from repro.kernels.mips_topk import mips_topk

F32 = jnp.float32


def l2_normalize(z, eps: float = 1e-8):
    z = z.astype(F32)
    return z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), eps)


def encode_corpus_chunked(encode_fn: Callable, params, corpus, *,
                          chunk: int = 256, normalize: bool = True,
                          dtype=jnp.float32):
    """Encode a corpus pytree (leading axis = items) in O(chunk) activation
    memory: pad the item axis up to a chunk multiple (repeating item 0 —
    sliced off after), reshape to (num_chunks, chunk, ...), and ``lax.map``
    the encoder over chunks. Returns (N, d) embeddings in ``dtype``."""
    n = jax.tree.leaves(corpus)[0].shape[0]
    ch = min(chunk, n)
    pad = (-n) % ch

    def pad_leaf(x):
        if not pad:
            return jnp.asarray(x)
        x = jnp.asarray(x)
        return jnp.concatenate([x, jnp.repeat(x[:1], pad, axis=0)], axis=0)

    stacked = jax.tree.map(
        lambda x: pad_leaf(x).reshape((-1, ch) + x.shape[1:]), corpus)

    def enc(batch):
        z = encode_fn(params, batch).astype(F32)
        if normalize:
            z = l2_normalize(z)
        return z.astype(dtype)

    z = jax.lax.map(enc, stacked)              # (num_chunks, ch, d)
    return z.reshape((-1,) + z.shape[2:])[:n]


def _block_stack(tree, block: int):
    """Pad a corpus pytree's item axis up to a ``block`` multiple
    (repeating item 0) and reshape to (num_blocks, block, ...). Returns
    (stacked tree, real item count n)."""
    n = jax.tree.leaves(tree)[0].shape[0]
    b = min(block, n)
    pad = (-n) % b

    def pad_leaf(x):
        x = jnp.asarray(x)
        if not pad:
            return x
        return jnp.concatenate([x, jnp.repeat(x[:1], pad, axis=0)], axis=0)

    stacked = jax.tree.map(
        lambda x: pad_leaf(x).reshape((-1, b) + x.shape[1:]), tree)
    return stacked, n


def refresh_embeddings(encode_fn: Callable, params, corpus, embeddings, *,
                       threshold: float, block: int = 64,
                       probes_per_block: int = 4, normalize: bool = True):
    """Drift-gated partial re-encode of an encoded corpus (traceable).

    Two tiers, both bounded:

      1. **probe** — ``probes_per_block`` strided items per ``block``-item
         block are re-encoded in one chunked batch (cost ≈
         probes_per_block/block of a full rebuild) and compared to their
         stored rows; a block's drift is its max probe L2 distance;
      2. **targeted re-encode** — a ``lax.scan`` over blocks re-encodes a
         block under ``lax.cond`` only when its drift exceeds
         ``threshold``; quiescent blocks keep their stored rows and the
         untaken encoder branch costs no FLOPs at runtime (the cond is
         never batched).

    Contiguous blocks mean the scatter-back is a reshape, not a gather —
    the refreshed (N, d) array is assembled in index order. Returns
    ``(new_embeddings, stats)`` with traced scalars in ``stats``:
    ``blocks_refreshed``, ``refresh_fraction`` (of blocks),
    ``items_encoded`` (probes + refreshed blocks — the actual encode
    cost), ``max_drift``, ``mean_drift``.
    """
    if not 0 < probes_per_block:
        raise ValueError(f"probes_per_block must be >= 1, "
                         f"got {probes_per_block}")
    stacked, n = _block_stack(corpus, block)
    nb = jax.tree.leaves(stacked)[0].shape[0]
    b = jax.tree.leaves(stacked)[0].shape[1]
    d = embeddings.shape[1]
    pad = nb * b - n
    emb_pad = embeddings
    if pad:
        emb_pad = jnp.concatenate(
            [emb_pad, jnp.repeat(emb_pad[:1], pad, axis=0)], axis=0)
    emb_blocks = emb_pad.reshape(nb, b, d)

    p = min(probes_per_block, b)
    probe_pos = (jnp.arange(p) * (b // p)).astype(jnp.int32)

    def enc(batch):
        z = encode_fn(params, batch).astype(F32)
        return l2_normalize(z) if normalize else z

    probe_items = jax.tree.map(
        lambda x: x[:, probe_pos].reshape((nb * p,) + x.shape[2:]), stacked)
    z_probe = enc(probe_items).reshape(nb, p, d)
    drift = jnp.linalg.norm(
        z_probe - emb_blocks[:, probe_pos].astype(F32), axis=-1)  # (nb, p)
    # pad slots repeat item 0, whose drift must not refresh the tail block
    probe_global = jnp.arange(nb)[:, None] * b + probe_pos[None, :]
    drift = jnp.where(probe_global < n, drift, 0.0)
    block_drift = drift.max(axis=1)
    do_refresh = block_drift > threshold

    def body(_, xs):
        blk_items, blk_emb, do = xs
        new = jax.lax.cond(
            do,
            lambda: enc(blk_items).astype(blk_emb.dtype),
            lambda: blk_emb)
        return 0, new

    _, new_blocks = jax.lax.scan(body, 0, (stacked, emb_blocks, do_refresh))
    new_emb = new_blocks.reshape(nb * b, d)[:n]
    refreshed = do_refresh.sum().astype(F32)
    stats = {
        "blocks_refreshed": refreshed,
        "refresh_fraction": refreshed / nb,
        "items_encoded": nb * p + refreshed * b,
        "max_drift": block_drift.max(),
        "mean_drift": drift.mean(),
    }
    return new_emb, stats


class CorpusIndex:
    """An encoded corpus: (N, d) normalized embeddings + top-k search."""

    def __init__(self, embeddings, *, normalized: bool = True):
        if embeddings.ndim != 2:
            raise ValueError(f"embeddings must be (N, d), "
                             f"got {embeddings.shape}")
        self.embeddings = embeddings
        self.normalized = normalized

    @property
    def num_items(self) -> int:
        return self.embeddings.shape[0]

    @property
    def dim(self) -> int:
        return self.embeddings.shape[1]

    # -- build ---------------------------------------------------------------
    @classmethod
    def build(cls, encode_fn: Callable, params, corpus, *, chunk: int = 256,
              normalize: bool = True, dtype=jnp.float32) -> "CorpusIndex":
        """Encode ``corpus`` (pytree, leading axis = items) with
        ``encode_fn(params, chunk_batch) -> (chunk, d)`` in O(chunk)
        activation memory; store as ``dtype`` (fp32 or bf16)."""
        z = encode_corpus_chunked(encode_fn, params, corpus, chunk=chunk,
                                  normalize=normalize, dtype=dtype)
        return cls(z, normalized=normalize)

    # -- streaming refresh ---------------------------------------------------
    def refresh(self, encode_fn: Callable, params, corpus, *,
                threshold: float, block: int = 64,
                probes_per_block: int = 4) -> dict:
        """Drift-gated in-place update toward the CURRENT params: probe a
        strided sample per block, fully re-encode only blocks whose max
        probe L2 drift exceeds ``threshold`` (see
        :func:`refresh_embeddings`). A live ``QueryServer`` holding this
        index serves the refreshed embeddings on its next query. Returns
        host-side stats: ``blocks_refreshed``, ``refresh_fraction``,
        ``items_encoded``, ``max_drift``, ``mean_drift``."""
        new_emb, stats = refresh_embeddings(
            encode_fn, params, corpus, self.embeddings,
            threshold=threshold, block=block,
            probes_per_block=probes_per_block, normalize=self.normalized)
        self.embeddings = new_emb
        return {k: float(v) for k, v in stats.items()}

    # -- search --------------------------------------------------------------
    def search(self, queries, k: int, *, backend: str = "auto", **kw):
        """Top-k inner-product search: queries (Q, d) -> ((Q, k) f32
        scores, (Q, k) i32 item indices). bf16-stored embeddings upcast to
        f32 inside the score tiles; pass mips_topk's block/chunk kwargs
        through ``kw``."""
        return mips_topk(queries.astype(F32), self.embeddings, k,
                         backend=backend, **kw)

    # -- persistence (repro.checkpoint msgpack) ------------------------------
    def save(self, path: str) -> None:
        save_checkpoint(path, {
            "embeddings": self.embeddings,
            "normalized": jnp.asarray(int(self.normalized), jnp.int32),
        }, step=self.num_items)

    @classmethod
    def load(cls, path: str) -> "CorpusIndex":
        flat, _ = restore_checkpoint_flat(path)
        return cls(jnp.asarray(flat["embeddings"]),
                   normalized=bool(int(flat["normalized"])))


def make_retrieval_eval(encode_fn: Callable, corpus, corpus_labels, queries,
                        query_labels, *, ks=(1, 5, 10), chunk: int = 256,
                        backend: str = "auto", index_dtype=jnp.float32,
                        **search_kw) -> Callable[[Any], dict]:
    """Build the periodic in-training retrieval eval: a traceable
    ``eval_fn(params) -> {"recall_at_k": ..., "mrr": ...}``.

    Re-encodes the held-out corpus and queries with the CURRENT params
    (chunked, O(chunk) activations), runs ``mips_topk`` at k = max(ks),
    and scores label-match relevance (core/eval.py). Runs under jit inside
    the engine's scan, so everything stays on device."""
    kmax = max(ks)
    corpus_labels = jnp.asarray(corpus_labels)
    query_labels = jnp.asarray(query_labels)

    def eval_fn(params):
        cz = encode_corpus_chunked(encode_fn, params, corpus, chunk=chunk,
                                   normalize=True, dtype=index_dtype)
        qz = l2_normalize(encode_fn(params, queries))
        _, idx = mips_topk(qz, cz, kmax, backend=backend, **search_kw)
        return eval_lib.retrieval_metrics(idx, query_labels, corpus_labels,
                                          ks=ks)

    return eval_fn


def make_refreshing_retrieval_eval(
        encode_fn: Callable, corpus, corpus_labels, queries, query_labels, *,
        threshold: float, block: int = 64, probes_per_block: int = 4,
        ks=(1, 5, 10), chunk: int = 256, backend: str = "auto",
        index_dtype=jnp.float32, **search_kw) -> Callable:
    """Stateful variant of :func:`make_retrieval_eval`: the encoded corpus
    is engine eval STATE refreshed drift-gated instead of rebuilt.

    Returns ``eval_fn(params, state) -> (metrics, new_state)`` with
    ``eval_fn.stateful = True`` and ``eval_fn.init_state(params)`` (the
    one full chunked encode seeding the state). Each periodic eval then
    pays probe cost + only the drifted blocks' re-encode (see
    :func:`refresh_embeddings`) — the RoundEngine threads the state
    through its scan carry. Metrics gain ``refresh_fraction`` and
    ``items_encoded`` alongside the usual recall@k/MRR."""
    kmax = max(ks)
    corpus_labels = jnp.asarray(corpus_labels)
    query_labels = jnp.asarray(query_labels)

    def init_state(params):
        return encode_corpus_chunked(encode_fn, params, corpus, chunk=chunk,
                                     normalize=True, dtype=index_dtype)

    def eval_fn(params, state):
        emb, rstats = refresh_embeddings(
            encode_fn, params, corpus, state, threshold=threshold,
            block=block, probes_per_block=probes_per_block, normalize=True)
        emb = emb.astype(index_dtype)
        qz = l2_normalize(encode_fn(params, queries))
        _, idx = mips_topk(qz, emb, kmax, backend=backend, **search_kw)
        metrics = dict(eval_lib.retrieval_metrics(
            idx, query_labels, corpus_labels, ks=ks))
        metrics["refresh_fraction"] = rstats["refresh_fraction"]
        metrics["items_encoded"] = rstats["items_encoded"]
        return metrics, emb

    eval_fn.stateful = True
    eval_fn.init_state = init_state
    return eval_fn
