"""Corpus index: the offline half of dual-encoder retrieval serving.

A deployed dual encoder answers nearest-neighbour queries against a corpus
encoded ONCE (paper Sec. 1's use case). ``CorpusIndex`` owns that encoded
corpus:

  * **chunked build** — the corpus is encoded ``chunk`` items at a time
    under ``lax.map`` (the PR-5 streaming idiom: peak activation memory is
    O(chunk), not O(corpus) — the encoder forward never sees more than one
    chunk);
  * **normalized storage** — embeddings are L2-normalized (cosine == inner
    product, the MIPS kernel's contract) and stored fp32 or bf16
    (``dtype=jnp.bfloat16`` halves index residency; search upcasts tiles
    to f32 on the fly);
  * **msgpack persistence** — ``save``/``load`` via ``repro.checkpoint``,
    so an index snapshot rides the same format as engine checkpoints;
  * **search** — ``mips_topk`` (kernels/mips_topk.py) backend-dispatched:
    fused Pallas kernel on accelerators, running-top-k chunked scan on
    CPU; no path materializes the (Q, N) score matrix.

``make_retrieval_eval`` packages an index-build + search + label-match
metrics (core/eval.py) into one traceable ``params -> metrics`` function —
the periodic in-training eval the RoundEngine runs alongside the probe.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import restore_checkpoint_flat, save_checkpoint
from repro.core import eval as eval_lib
from repro.kernels.mips_topk import mips_topk

F32 = jnp.float32


def l2_normalize(z, eps: float = 1e-8):
    z = z.astype(F32)
    return z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), eps)


def encode_corpus_chunked(encode_fn: Callable, params, corpus, *,
                          chunk: int = 256, normalize: bool = True,
                          dtype=jnp.float32):
    """Encode a corpus pytree (leading axis = items) in O(chunk) activation
    memory: pad the item axis up to a chunk multiple (repeating item 0 —
    sliced off after), reshape to (num_chunks, chunk, ...), and ``lax.map``
    the encoder over chunks. Returns (N, d) embeddings in ``dtype``."""
    n = jax.tree.leaves(corpus)[0].shape[0]
    ch = min(chunk, n)
    pad = (-n) % ch

    def pad_leaf(x):
        if not pad:
            return jnp.asarray(x)
        x = jnp.asarray(x)
        return jnp.concatenate([x, jnp.repeat(x[:1], pad, axis=0)], axis=0)

    stacked = jax.tree.map(
        lambda x: pad_leaf(x).reshape((-1, ch) + x.shape[1:]), corpus)

    def enc(batch):
        z = encode_fn(params, batch).astype(F32)
        if normalize:
            z = l2_normalize(z)
        return z.astype(dtype)

    z = jax.lax.map(enc, stacked)              # (num_chunks, ch, d)
    return z.reshape((-1,) + z.shape[2:])[:n]


class CorpusIndex:
    """An encoded corpus: (N, d) normalized embeddings + top-k search."""

    def __init__(self, embeddings, *, normalized: bool = True):
        if embeddings.ndim != 2:
            raise ValueError(f"embeddings must be (N, d), "
                             f"got {embeddings.shape}")
        self.embeddings = embeddings
        self.normalized = normalized

    @property
    def num_items(self) -> int:
        return self.embeddings.shape[0]

    @property
    def dim(self) -> int:
        return self.embeddings.shape[1]

    # -- build ---------------------------------------------------------------
    @classmethod
    def build(cls, encode_fn: Callable, params, corpus, *, chunk: int = 256,
              normalize: bool = True, dtype=jnp.float32) -> "CorpusIndex":
        """Encode ``corpus`` (pytree, leading axis = items) with
        ``encode_fn(params, chunk_batch) -> (chunk, d)`` in O(chunk)
        activation memory; store as ``dtype`` (fp32 or bf16)."""
        z = encode_corpus_chunked(encode_fn, params, corpus, chunk=chunk,
                                  normalize=normalize, dtype=dtype)
        return cls(z, normalized=normalize)

    # -- search --------------------------------------------------------------
    def search(self, queries, k: int, *, backend: str = "auto", **kw):
        """Top-k inner-product search: queries (Q, d) -> ((Q, k) f32
        scores, (Q, k) i32 item indices). bf16-stored embeddings upcast to
        f32 inside the score tiles; pass mips_topk's block/chunk kwargs
        through ``kw``."""
        return mips_topk(queries.astype(F32), self.embeddings, k,
                         backend=backend, **kw)

    # -- persistence (repro.checkpoint msgpack) ------------------------------
    def save(self, path: str) -> None:
        save_checkpoint(path, {
            "embeddings": self.embeddings,
            "normalized": jnp.asarray(int(self.normalized), jnp.int32),
        }, step=self.num_items)

    @classmethod
    def load(cls, path: str) -> "CorpusIndex":
        flat, _ = restore_checkpoint_flat(path)
        return cls(jnp.asarray(flat["embeddings"]),
                   normalized=bool(int(flat["normalized"])))


def make_retrieval_eval(encode_fn: Callable, corpus, corpus_labels, queries,
                        query_labels, *, ks=(1, 5, 10), chunk: int = 256,
                        backend: str = "auto", index_dtype=jnp.float32,
                        **search_kw) -> Callable[[Any], dict]:
    """Build the periodic in-training retrieval eval: a traceable
    ``eval_fn(params) -> {"recall_at_k": ..., "mrr": ...}``.

    Re-encodes the held-out corpus and queries with the CURRENT params
    (chunked, O(chunk) activations), runs ``mips_topk`` at k = max(ks),
    and scores label-match relevance (core/eval.py). Runs under jit inside
    the engine's scan, so everything stays on device."""
    kmax = max(ks)
    corpus_labels = jnp.asarray(corpus_labels)
    query_labels = jnp.asarray(query_labels)

    def eval_fn(params):
        cz = encode_corpus_chunked(encode_fn, params, corpus, chunk=chunk,
                                   normalize=True, dtype=index_dtype)
        qz = l2_normalize(encode_fn(params, queries))
        _, idx = mips_topk(qz, cz, kmax, backend=backend, **search_kw)
        return eval_lib.retrieval_metrics(idx, query_labels, corpus_labels,
                                          ks=ks)

    return eval_fn
