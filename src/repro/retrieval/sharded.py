"""Mesh-partitioned corpus search: shard the index, keep the bits.

``CorpusIndex`` holds the whole corpus on one device; this module
partitions the (N, d) embedding matrix over a mesh **"corpus"** axis so S
devices each hold a contiguous ~N/S-row slice and the serving hot path
scales with devices:

  1. every shard runs the SAME fused ``mips_topk`` kernel locally, told
     its place in the world via the kernel's ``index_offset``/``n_total``
     contract — local scores are the identical full-depth f32 dots (d is
     never tiled), emitted indices are GLOBAL, and invalid rows mask to
     (NEG_INF, BIG_IDX) in-kernel: the ragged last shard's rows past the
     global end AND each shard's internal block-padding rows (masked by
     local position — their global positions land in the next shard);
  2. the (Q, k) per-shard candidates are ``all_gather``-ed over the axis
     (k·Q small — the psum-style merge moves S·Q·k entries, never rows);
  3. one final selection over the S·k candidates per query
     (``_select_topk`` — the kernel's own value-desc / lowest-index-asc
     pick) emits the global top-k.

**Exactness argument** (tested bit-for-bit in tests/test_retrieval_scale
and the 2-process harness in tests/test_multihost.py): the global order
is (score desc, index asc) — ``lax.top_k``'s stable order over the full
corpus. Each shard's local top-k is the restriction of that order to its
rows, so every global top-k item survives into the gathered candidate
set; ``_select_topk`` then picks by the same (value, global-index) key,
so ties between duplicated rows in DIFFERENT shards still break toward
the lowest global index. Scores are bit-identical because each score is
one full-depth dot of the same two vectors — sharding re-tiles N, never
d, so no f32 sum is re-associated.

Two execution paths, same math:
  * ``mesh=None`` — a ``vmap`` over the stacked (S, shard_size, d)
    shards: single-device "simulated sharding", used by the tier-1
    exactness tests and the bench's per-shard timing;
  * ``mesh=Mesh(..., ("corpus",))`` — ``shard_map`` over the axis: each
    device keeps only its shard resident (S× index capacity), with
    ``lax.axis_index`` supplying the offset and a real all_gather the
    merge traffic. ``repro.sharding.make_corpus_mesh()`` builds the mesh
    over all devices, across hosts when jax.distributed is initialized.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.kernels.mips_topk import _select_topk, mips_topk
from repro.retrieval.index import CorpusIndex, encode_corpus_chunked, \
    refresh_embeddings

F32 = jnp.float32
I32 = jnp.int32


def stack_shards(embeddings, num_shards: int):
    """Contiguously partition (N, d) into (S, shard_size, d), zero-padding
    the last shard up to shard_size = ceil(N / S). Contiguity matters for
    exactness: shard s owns global rows [s * shard_size, ...), so its
    padding rows sit past the global end and mask in-kernel via
    ``n_total``."""
    n, d = embeddings.shape
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    if num_shards > n:
        raise ValueError(f"num_shards={num_shards} exceeds corpus size {n}")
    shard_size = -(-n // num_shards)
    pad = num_shards * shard_size - n
    if pad:
        embeddings = jnp.concatenate(
            [embeddings, jnp.zeros((pad, d), embeddings.dtype)], axis=0)
    return embeddings.reshape(num_shards, shard_size, d)


def merge_topk(vals, idxs, k: int):
    """Merge (S, Q, k) per-shard candidates into the global (Q, k) top-k.

    ``_select_topk`` picks by (max value, lowest index) directly on the
    candidates' GLOBAL indices, so the result is invariant to shard order
    and bit-identical to single-device ``lax.top_k`` whenever the
    candidate set contains the true top-k (which per-shard top-k
    guarantees). Sentinel (NEG_INF, BIG_IDX) pads from short shards flow
    through harmlessly."""
    s, qn, kk = vals.shape
    cand_v = jnp.transpose(vals, (1, 0, 2)).reshape(qn, s * kk)
    cand_i = jnp.transpose(idxs, (1, 0, 2)).reshape(qn, s * kk)
    return _select_topk(cand_v.astype(F32), cand_i.astype(I32), k)


def sharded_mips_topk(q, shards, k: int, *, n_total: int, mesh=None,
                      axis: str = "corpus", backend: str = "auto", **kw):
    """Top-k MIPS over a stacked (S, shard_size, d) contiguous partition
    of an ``n_total``-row corpus; bit-identical to single-device
    ``mips_topk`` on the concatenated corpus (scores, indices, ties).

    ``mesh=None`` simulates the S shards with ``vmap`` on one device;
    with a mesh carrying ``axis``, the same per-shard program runs under
    ``shard_map`` with a real cross-device all_gather merge (queries
    replicated, shards partitioned, output replicated)."""
    s, shard_size, d = shards.shape
    if not 1 <= k <= min(shard_size, n_total):
        raise ValueError(
            f"k={k} must be in [1, min(shard_size={shard_size}, "
            f"n_total={n_total})] — every shard must be able to emit k "
            f"candidates; use fewer shards for larger k")

    def local(shard, off):
        return mips_topk(q, shard, k, backend=backend, index_offset=off,
                         n_total=n_total, **kw)

    if mesh is None:
        offsets = jnp.arange(s, dtype=I32) * shard_size
        vals, idxs = jax.vmap(local)(shards, offsets)       # (S, Q, k)
        return merge_topk(vals, idxs, k)

    from repro.core.dcco import shard_map_compat

    def shard_body(q_rep, shards_loc):
        off = jax.lax.axis_index(axis).astype(I32) * shard_size
        v, i = mips_topk(q_rep, shards_loc[0], k, backend=backend,
                         index_offset=off, n_total=n_total, **kw)
        v = jax.lax.all_gather(v, axis)                     # (S, Q, k)
        i = jax.lax.all_gather(i, axis)
        return merge_topk(v, i, k)

    fn = shard_map_compat(shard_body, mesh,
                          in_specs=(P(), P(axis)), out_specs=(P(), P()))
    return fn(q, shards)


class ShardedCorpusIndex:
    """A :class:`CorpusIndex` partitioned over a mesh "corpus" axis.

    Drop-in for ``QueryServer``: same ``num_items``/``dim``/``search``
    surface, same results bit-for-bit. With a mesh, each shard is placed
    on its axis device (``NamedSharding(mesh, P("corpus"))``) — across
    processes each host materializes only its addressable shards."""

    def __init__(self, embeddings, num_shards: int, *, mesh=None,
                 axis: str = "corpus", normalized: bool = True):
        if embeddings.ndim != 2:
            raise ValueError(f"embeddings must be (N, d), "
                             f"got {embeddings.shape}")
        self.num_shards = int(num_shards)
        self.mesh = mesh
        self.axis = axis
        self.normalized = normalized
        self._n, self._d = embeddings.shape
        shards = stack_shards(embeddings, self.num_shards)
        if mesh is not None:
            if axis not in mesh.axis_names:
                raise ValueError(f"mesh {mesh.axis_names} has no "
                                 f"{axis!r} axis")
            ax_size = mesh.shape[axis]
            if ax_size != self.num_shards:
                raise ValueError(
                    f"num_shards={self.num_shards} must equal the mesh "
                    f"{axis!r} axis size {ax_size} (one shard per device)")
        self.shards = self._place(shards)

    def _place(self, shards):
        """Lay stacked (S, shard_size, d) shards out on the mesh axis —
        one shard per device; across processes each host contributes its
        addressable slice (jax.devices() enumerates in process order)."""
        if self.mesh is None:
            return shards
        if jax.process_count() > 1:
            from repro.sharding import host_local_to_global
            if self.num_shards % jax.process_count() != 0:
                raise ValueError(
                    f"num_shards={self.num_shards} must divide evenly "
                    f"across {jax.process_count()} processes — a ragged "
                    f"split would silently drop trailing shards from the "
                    f"host-local slice")
            per = self.num_shards // jax.process_count()
            lo = jax.process_index() * per
            return host_local_to_global(self.mesh, P(self.axis),
                                        shards[lo:lo + per])
        return jax.device_put(shards, NamedSharding(self.mesh, P(self.axis)))

    @property
    def num_items(self) -> int:
        return self._n

    @property
    def dim(self) -> int:
        return self._d

    @property
    def shard_size(self) -> int:
        return self.shards.shape[1]

    @classmethod
    def from_index(cls, index: CorpusIndex, num_shards: int, *, mesh=None,
                   axis: str = "corpus") -> "ShardedCorpusIndex":
        return cls(index.embeddings, num_shards, mesh=mesh, axis=axis,
                   normalized=index.normalized)

    @classmethod
    def build(cls, encode_fn: Callable, params, corpus, *, num_shards: int,
              mesh=None, axis: str = "corpus", chunk: int = 256,
              normalize: bool = True, dtype=jnp.float32):
        z = encode_corpus_chunked(encode_fn, params, corpus, chunk=chunk,
                                  normalize=normalize, dtype=dtype)
        return cls(z, num_shards, mesh=mesh, axis=axis, normalized=normalize)

    def refresh(self, encode_fn: Callable, params, corpus, *,
                threshold: float, block: int = 64,
                probes_per_block: int = 4) -> dict:
        """Drift-gated in-place shard update (see
        :func:`repro.retrieval.index.refresh_embeddings`): probe, re-encode
        only drifted blocks, re-stack, and re-place each shard on its mesh
        device. Requires the shards to be host-addressable — single
        process (any mesh) only; multi-process serving rebuilds via
        ``build``."""
        if jax.process_count() > 1:
            raise NotImplementedError(
                "ShardedCorpusIndex.refresh needs host-addressable shards; "
                "rebuild with ShardedCorpusIndex.build under multi-process "
                "serving")
        flat = jnp.asarray(self.shards).reshape(-1, self._d)[:self._n]
        new_emb, stats = refresh_embeddings(
            encode_fn, params, corpus, flat, threshold=threshold,
            block=block, probes_per_block=probes_per_block,
            normalize=self.normalized)
        self.shards = self._place(
            stack_shards(new_emb.astype(self.shards.dtype), self.num_shards))
        return {k: float(v) for k, v in stats.items()}

    def search(self, queries, k: int, *, backend: str = "auto", **kw):
        """Global top-k: queries (Q, d) -> ((Q, k) f32 scores, (Q, k) i32
        global item indices), bit-identical to the unsharded
        ``CorpusIndex.search``."""
        return sharded_mips_topk(queries.astype(F32), self.shards, k,
                                 n_total=self._n, mesh=self.mesh,
                                 axis=self.axis, backend=backend, **kw)
