"""Cluster-aware aggregation: the engine's clustered two-phase round.

Global FedAvg-style aggregation averages every client into ONE correlation
target and ONE server model — exactly what hurts when the population is a
mixture of heterogeneous client distributions (severe label skew). This
module keeps the paper's two-phase protocol intact but makes the
aggregation *cluster-aware*:

  1. phase 1 runs unchanged: every cohort client ships its Eq.-3 stats
     dict, computed under the shared readout params — the wire carries
     nothing a global round would not (privacy-neutral, see
     :mod:`repro.cluster.kmeans`);
  2. the server flattens the per-client stats into the (K, D) row matrix
     and runs cosine k-means INSIDE the round scan (warm-started from the
     carried centroids), assigning each cohort client a cluster id;
  3. per-cluster stats fold in ONE weighted ``kernels/segment_sum.py``
     dispatch (``hierarchy.fold_to_edges`` — the same kernel the
     hierarchical and async paths use), giving each cluster its own
     correlation target for the phase-2 stop-grad combine;
  4. each cluster owns a server-update slot: a params copy + optimizer
     state, stepped (``jax.vmap`` over the cluster axis) by its own
     cluster-folded delta average; clusters that received no cohort
     clients this round are left untouched;
  5. with a :class:`repro.hierarchy.HierarchicalChannel` (``num_edges ==
     num_clusters``) the cluster ids BECOME the edge assignment — clients
     route through their cluster's edge aggregator, so the hierarchy is
     semantic, not just topological: the client hop encodes per-client
     payloads, the fold lands per-cluster partials, and the edge hop
     encodes one payload per cluster.

``num_clusters <= 1`` never builds this body: the engine routes to the
ordinary global round — the structural collapse idiom every prior engine
extension uses (async_collapse, HierarchicalChannel.collapse_ideal) — so
a single cluster is bit-identical (``== 0.0``) to the global path per
registered objective (tested).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.cluster import kmeans
from repro.core import fed_sim
from repro.hierarchy.aggregation import HierarchicalChannel, fold_to_edges
from repro.kernels import ref as kernels_ref
from repro.server import update as server_update_lib

F32 = jnp.float32


class ClusterState(NamedTuple):
    """The clustered engine's scan-carry: per-cluster server-update slots
    + the warm-start centroids."""
    params_c: Any                   # params pytree, leading axis C
    opt_c: Any                      # server-update state, leading axis C
    centroids: jnp.ndarray          # (C, D) unit rows; zeros before the
                                    # first round (seeded from round-0 stats)
    initialized: jnp.ndarray        # () bool — centroids seeded yet?


def init_cluster_state(params, opt_state, num_clusters: int,
                       dim: int) -> ClusterState:
    """Fresh slots: every cluster starts from the same (broadcast) params
    and optimizer state; centroids seed from the first round's stats."""
    stack = lambda t: jax.tree.map(                      # noqa: E731
        lambda x: jnp.repeat(jnp.asarray(x)[None], num_clusters, axis=0), t)
    return ClusterState(stack(params), stack(opt_state),
                        jnp.zeros((num_clusters, dim), F32),
                        jnp.zeros((), bool))


def fold_to_clusters(tree_k, weights, cluster_ids, num_clusters: int,
                     impl: str = "jnp"):
    """Per-cluster weighted average of stacked per-client payloads:
    ``(avg (C, ...) pytree, mass (C,))`` — the sums land in one
    segment-sum dispatch over the whole flattened dict
    (:func:`repro.hierarchy.fold_to_edges`), the per-cluster mass
    normalizes them (empty clusters: mass 0, average 0)."""
    sums = fold_to_edges(tree_k, weights, cluster_ids, num_clusters, impl)
    mass = kernels_ref.segment_sum_ref(
        weights.astype(F32)[:, None], cluster_ids, num_clusters)[:, 0]
    denom = jnp.maximum(mass, 1e-12)
    avg = jax.tree.map(
        lambda v: v / denom.reshape((num_clusters,) + (1,) * (v.ndim - 1)),
        sums)
    return avg, mass


def _take_cluster(tree_c, cid):
    return jax.tree.map(lambda x: x[cid], tree_c)


def make_cluster_round_body(encoder_apply: Callable, server_opt,
                            cfg) -> Callable:
    """Build round_fn(params, opt_state, cstate, batch, sizes, key) ->
    (params, opt_state, cstate, metrics) for ``cfg.num_clusters > 1``.
    ``params`` is the mass-weighted readout model (what probes, retrieval
    evals, and checkpoints see); the real training state is the
    per-cluster slots in ``cstate``."""
    from repro.core import round_engine as engine_lib

    num_clusters = int(cfg.num_clusters)
    if cfg.algorithm != "dcco":
        raise ValueError(
            f"num_clusters clusters the two-phase stats round only "
            f"(algorithm 'dcco'), got {cfg.algorithm!r}")
    if cfg.stats_kernel != "off":
        raise ValueError(
            "stats_kernel aggregates phase-1 stats from the flattened "
            "cohort; clustering assigns PER-CLIENT stats — needs "
            "per-client payloads")
    if cfg.scaffold:
        raise ValueError(
            "SCAFFOLD variates assume one shared broadcast model; the "
            "clustered round broadcasts per-cluster params — disable "
            "scaffold for clustered aggregation")
    encoder_apply = engine_lib.cast_encoder_apply(encoder_apply,
                                                  cfg.compute_dtype)
    objective = fed_sim.resolve_objective(cfg.objective, cfg.lam)
    server_update = server_update_lib.as_server_update(
        cfg.server_update if cfg.server_update is not None else server_opt)
    channel = cfg.channel
    hier = isinstance(channel, HierarchicalChannel) and not channel.collapses
    if channel is not None:
        if getattr(channel, "noise_phases", None) is not None:
            raise ValueError(
                f"{channel!r} with num_clusters: per-cluster aggregates "
                f"change the DP sensitivity — the accountant's epsilon "
                f"would not cover what the round releases; run DP on the "
                f"global path")
        if isinstance(channel, HierarchicalChannel) and \
                channel.num_edges != num_clusters:
            raise ValueError(
                f"cluster ids route clients through their own edge, so "
                f"the tree needs one edge per cluster: num_edges="
                f"{channel.num_edges} != num_clusters={num_clusters}")
    fold_impl = channel.fold_impl if hier else cfg.cluster_fold

    def _cluster_fold(ctx, tree_k, w, ids, phase):
        """Per-cluster (sums, mass): the flat fold, or — through a
        non-collapsing hierarchical channel — client-hop encode, fold BY
        CLUSTER ID, edge-hop encode of one payload per cluster."""
        if ctx is None:
            return fold_to_clusters(tree_k, w, ids, num_clusters, fold_impl)
        dec = channel.encode_decode(ctx, tree_k, phase)
        if hier:
            sums = fold_to_edges(dec, w, ids, num_clusters,
                                 channel.fold_impl)
            enc = channel.edge_channel.encode_decode(ctx.edge_ctx, sums,
                                                     phase)
            emask = ctx.edge_ctx.mask                    # (C,)
            mass = kernels_ref.segment_sum_ref(
                w.astype(F32)[:, None], ids, num_clusters)[:, 0] * emask
            denom = jnp.maximum(mass, 1e-12)
            avg = jax.tree.map(
                lambda v: v * emask.reshape(
                    (num_clusters,) + (1,) * (v.ndim - 1)) / denom.reshape(
                    (num_clusters,) + (1,) * (v.ndim - 1)), enc)
            return avg, mass
        return fold_to_clusters(dec, w, ids, num_clusters, fold_impl)

    def round_fn(params, opt_state, cstate, batch, sizes, key):
        k_cohort = jax.tree.leaves(batch)[0].shape[0]
        if num_clusters > k_cohort:
            raise ValueError(
                f"num_clusters={num_clusters} exceeds the cohort of "
                f"{k_cohort} clients — every cluster needs a chance of "
                f"cohort members")
        n_pad = jax.tree.leaves(batch)[0].shape[1]
        masks = fed_sim._client_masks(sizes, n_pad)
        if channel is None:
            ctx = None
            w = sizes.astype(F32) / jnp.sum(sizes.astype(F32))
        else:
            ctx = channel.begin_round(key, sizes)
            w = ctx.weights
        wire = 0.0

        # ---- phase 1: per-client stats under the shared readout params
        def client_stats(b, m):
            zf, zg = encoder_apply(params, b)
            return objective.stats_masked(zf, zg, m)

        st_k = jax.vmap(client_stats)(batch, masks)

        # ---- in-scan cluster assignment on the flattened stats rows
        rows = kmeans.flatten_stats(st_k)
        cent_prev = jnp.where(cstate.initialized, cstate.centroids,
                              kmeans.seed_centroids(rows, num_clusters))
        ids, cents = kmeans.cosine_kmeans(
            rows, num_clusters, iters=cfg.cluster_iters,
            centroids=cent_prev)
        if hier:
            # semantic hierarchy: this round's edge assignment IS the
            # cluster assignment (effective mask/weights recomputed)
            ctx = channel.with_edge_ids(ctx, ids)
            w = ctx.weights

        # ---- per-cluster correlation targets: one weighted segment-sum
        agg_c, mass_c = _cluster_fold(ctx, st_k, w, ids, "stats")
        if ctx is not None:
            wire = wire + channel.round_bytes(
                ctx, jax.tree.map(lambda v: v[0], agg_c))

        # ---- phase 2: client k trains ITS cluster's slot against ITS
        # cluster's target
        def client_update(b, m, cid):
            p_k = _take_cluster(cstate.params_c, cid)
            agg_k = _take_cluster(agg_c, cid)

            def loss_fn(p):
                zf, zg = encoder_apply(p, b)
                local = objective.stats_masked(zf, zg, m)
                return objective.loss_from_stats(
                    objective.combine(local, agg_k))

            return fed_sim.client_local_steps(
                loss_fn, p_k, cfg.client_lr, cfg.local_steps,
                prox_mu=cfg.prox_mu)

        deltas, losses_k = jax.vmap(client_update)(batch, masks, ids)

        # ---- per-cluster server-update slots (empty clusters frozen)
        dbar_c, _ = _cluster_fold(ctx, deltas, w, ids, "update")
        if ctx is not None:
            wire = wire + channel.round_bytes(
                ctx, jax.tree.map(lambda v: v[0], dbar_c))
        p_new, o_new = jax.vmap(server_update.step)(
            cstate.params_c, cstate.opt_c, dbar_c)
        live = mass_c > 1e-12                            # (C,)

        def keep(new, old):
            return jax.tree.map(
                lambda a, b: jnp.where(
                    live.reshape((num_clusters,) + (1,) * (a.ndim - 1)),
                    a, b), new, old)

        params_c = keep(p_new, cstate.params_c)
        opt_c = keep(o_new, cstate.opt_c)

        # ---- readout model: this round's mass-weighted mean of the slots
        m_norm = mass_c / jnp.maximum(jnp.sum(mass_c), 1e-12)
        params_out = jax.tree.map(
            lambda x: jnp.tensordot(m_norm, x.astype(F32), axes=1).astype(
                x.dtype), params_c)

        agg_g = jax.tree.map(lambda v: jnp.tensordot(w, v, axes=1), st_k)
        metrics = fed_sim.RoundMetrics(
            jnp.sum(w * losses_k), objective.encoding_std(agg_g),
            jnp.asarray(wire, F32))
        new_state = ClusterState(params_c, opt_c, cents,
                                 jnp.ones((), bool))
        return params_out, opt_state, new_state, metrics

    return round_fn
