# Cluster-aware aggregation (heterogeneous populations): cosine k-means
# on the Eq.-3 per-client statistics assigns cohort clients to clusters
# inside the round scan; each cluster keeps its own correlation target
# and server-update slot. See repro.cluster.round for the protocol.
from repro.cluster.kmeans import (  # noqa: F401
    assign_clusters, cosine_kmeans, flatten_stats, seed_centroids,
    stats_dim)
from repro.cluster.round import (  # noqa: F401
    ClusterState, fold_to_clusters, init_cluster_state,
    make_cluster_round_body)
