"""Cosine k-means over per-client encoding statistics — fully traceable,
so cluster assignment runs INSIDE the round scan.

The feature vector for client k is its flattened phase-1 stats dict (the
same leaf-concat row layout as ``repro.hierarchy.fold_to_edges``, so the
(K, D) matrix the assignment reads is literally the matrix the per-cluster
fold dispatches through ``kernels/segment_sum.py``). Those statistics are
*already transmitted* under the paper's Eq.-3 protocol, which is what
makes stats-based clustering privacy-neutral: the server learns nothing a
global round did not already ship.

Everything here is deterministic given the rows: seeding is
farthest-point (row 0, then repeatedly the row least similar to any
chosen seed), assignment is argmax cosine similarity (ties toward the
lowest cluster id, matching ``retrieval/ivf.train_centroids``), and Lloyd
updates renormalize per-cluster means onto the sphere with empty clusters
keeping their previous centroid. Determinism matters: the round scan
carries centroids across rounds (warm start — streaming k-means), and
resume/regression streams must be byte-stable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def stats_dim(spec) -> int:
    """Row width D of a flattened stats dict, from the objective's
    ``stat_spec(d)`` ({key: shape}) — no FLOPs."""
    total = 0
    for shape in spec.values():
        size = 1
        for s in shape:
            size *= int(s)
        total += size
    return total


def flatten_stats(st_k) -> jnp.ndarray:
    """Stacked per-client stats (leaves (K, ...)) -> one (K, D) f32 row
    matrix; leaves concatenate in tree order, the exact layout
    ``hierarchy.fold_to_edges`` folds."""
    leaves = jax.tree.leaves(st_k)
    k = leaves[0].shape[0]
    return jnp.concatenate(
        [leaf.astype(F32).reshape(k, -1) for leaf in leaves], axis=1)


def _unit(x, axis=-1):
    return x / jnp.maximum(
        jnp.linalg.norm(x, axis=axis, keepdims=True), 1e-12)


def assign_clusters(rows, centroids) -> jnp.ndarray:
    """(K, D) rows x (C, D) centroids -> (K,) int32 cosine assignment."""
    sims = _unit(rows.astype(F32)) @ _unit(centroids.astype(F32)).T
    return jnp.argmax(sims, axis=1).astype(jnp.int32)


def seed_centroids(rows, num_clusters: int) -> jnp.ndarray:
    """Deterministic farthest-point seeding on the unit sphere: seed 0 is
    row 0; each next seed is the row whose best similarity to the chosen
    seeds is lowest. (K, D) -> (C, D) unit rows."""
    rows_n = _unit(rows.astype(F32))
    cents = jnp.zeros((num_clusters, rows.shape[1]), F32).at[0].set(rows_n[0])

    def body(j, cents):
        sims = rows_n @ cents.T                          # (K, C)
        picked = jnp.arange(num_clusters) < j            # (C,)
        best = jnp.max(jnp.where(picked[None, :], sims, -jnp.inf), axis=1)
        return cents.at[j].set(rows_n[jnp.argmin(best)])

    return jax.lax.fori_loop(1, num_clusters, body, cents)


def cosine_kmeans(rows, num_clusters: int, *, iters: int = 2,
                  centroids=None):
    """Spherical k-means: returns ``(assignments (K,) int32, centroids
    (C, D) unit f32)``. ``centroids`` warm-starts Lloyd's (the round scan
    passes the previous round's — streaming k-means); ``None`` seeds by
    farthest point. Empty clusters keep their previous centroid."""
    rows_n = _unit(rows.astype(F32))
    if centroids is None:
        centroids = seed_centroids(rows, num_clusters)

    def step(cents, _):
        ids = assign_clusters(rows_n, cents)
        sums = jax.ops.segment_sum(rows_n, ids, num_segments=num_clusters)
        counts = jax.ops.segment_sum(
            jnp.ones((rows_n.shape[0],), F32), ids,
            num_segments=num_clusters)
        new = jnp.where(counts[:, None] > 0, _unit(sums), cents)
        return new, None

    cents, _ = jax.lax.scan(step, centroids.astype(F32), None,
                            length=max(1, iters))
    return assign_clusters(rows_n, cents), cents
