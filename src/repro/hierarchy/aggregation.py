"""Two-level aggregation topology: clients → edge aggregators → server.

Cross-device federated populations (McMahan et al., 2017) do not report to
one server socket: clients upload to regional *edge aggregators*, which
forward partial aggregates upstream. Because every payload the stats
protocol ships is linear in samples (paper Eq. 3), aggregation is exact
under ANY summation tree — the edge hop changes the wire, not the math.

:class:`HierarchicalChannel` makes that tree a drop-in
:class:`repro.comm.Channel`: it composes two hop channels,

    clients --client_channel--> edges --edge_channel--> server

so e.g. the bandwidth-starved client uplink runs int8 quantization while
the edge→server backbone stays dense, an edge-hop ``DropoutChannel``
models a regional outage (every client behind the edge vanishes at once),
and ``wire_bytes`` accounts both hops (K client payloads + E edge
payloads per round).

Exactness contract:

  * **ideal hops collapse** — when both hops are ideal identity wires
    (``Channel.ideal``), the two-level tree equals the flat weighted sum
    *in math*, so the aggregate is computed AS the flat sum: bit-identical
    (``== 0.0``) to the un-channeled / DenseChannel paths for every
    registered objective (tested), which keeps engine regression baselines
    and resume streams byte-stable. ``collapse_ideal=False`` forces the
    real tree (used by tests to show the regrouping is float-level only).
  * **lossy hops run the real tree** — encode/decode is not linear, so the
    fold happens where the protocol says it does: per-client encode on the
    client hop, a one-pass segment-sum fold of w_k·payload_k into per-edge
    partials (``kernels/segment_sum.py`` when ``fold_impl`` selects the
    Pallas kernel), per-edge encode on the edge hop, then the server sum.

DP hops are refused loudly: calibrating per-hop Gaussian noise and keeping
the epsilon accountant honest across a two-level tree is its own design
problem (per-edge sensitivity, noise composition across aggregators), and
a silently mis-calibrated epsilon is worse than no DP — same contract as
``fed_sim.check_variate_noise``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.comm.channel import Channel, ChannelContext, DenseChannel
from repro.kernels import ref as kernels_ref
from repro.kernels.segment_sum import segment_sum_pallas

F32 = jnp.float32

# fold_in salt deriving the edge hop's shard-local randomness from a
# shard-folded client key in the sharded local_fold path
_EDGE_SALT = 0xED6E

FOLD_IMPLS = ("jnp", "pallas", "interpret")


def contiguous_edge_ids(num_clients: int, num_edges: int) -> jnp.ndarray:
    """Edge assignment: client k reports to edge k // (K/E) — contiguous
    equal-size groups, the layout that aligns with cohort chunks and with
    the sharded client axis. Requires K % E == 0 (static)."""
    if num_clients % num_edges:
        raise ValueError(
            f"cohort of {num_clients} clients does not divide into "
            f"{num_edges} equal edges")
    return jnp.arange(num_clients, dtype=jnp.int32) // (
        num_clients // num_edges)


def fold_to_edges(tree_k, weights, seg_ids, num_edges: int,
                  impl: str = "jnp"):
    """Fold stacked per-client payloads (leading axis K) into per-edge
    partial sums (leading axis E): out[e] = sum_{k in e} w_k * leaf[k].

    All leaves are flattened and concatenated into ONE (K, D) row matrix
    so the whole stats dict folds in a single pass — the jnp path through
    ``jax.ops.segment_sum``, the kernel paths through
    ``segment_sum_pallas`` (``"pallas"`` falls back to the interpreter on
    CPU, same policy as the engine's stats_kernel flag)."""
    if impl not in FOLD_IMPLS:
        raise ValueError(f"unknown fold impl {impl!r}; "
                         f"expected one of {FOLD_IMPLS}")
    leaves, treedef = jax.tree.flatten(tree_k)
    k = leaves[0].shape[0]
    shapes = [leaf.shape[1:] for leaf in leaves]
    rows = jnp.concatenate(
        [leaf.astype(F32).reshape(k, -1) for leaf in leaves], axis=1)
    if impl == "jnp":
        folded = kernels_ref.segment_sum_ref(rows, seg_ids, num_edges,
                                             weights)
    else:
        interpret = impl == "interpret" or jax.default_backend() == "cpu"
        folded = segment_sum_pallas(rows, seg_ids, num_edges, weights,
                                    interpret=interpret)
    out, off = [], 0
    for shp in shapes:
        size = 1
        for s in shp:
            size *= s
        out.append(folded[:, off:off + size].reshape((num_edges,) + shp))
        off += size
    return jax.tree.unflatten(treedef, out)


class HierarchicalContext(NamedTuple):
    """Composite per-round context. The first four fields mirror
    :class:`repro.comm.ChannelContext` (mask/weights are the *effective*
    per-client values with the edge hop folded in), so every consumer of a
    plain context — fed_sim's loss weighting, the scaffold tail, the
    sharded extra-arg plumbing — works unchanged."""
    key: jnp.ndarray
    mask: jnp.ndarray                  # (K,) — client mask x edge mask
    weights: jnp.ndarray               # (K,) — edge-masked, renormalized
    num_participants: jnp.ndarray      # f32 — surviving clients
    client_ctx: ChannelContext
    edge_ctx: ChannelContext
    edge_ids: jnp.ndarray              # (K,) int32 — client -> edge


class HierarchicalChannel(Channel):
    """Two-level aggregation tree as a pluggable comm Channel."""

    name = "hierarchical"

    def __init__(self, num_edges: int,
                 client_channel: Optional[Channel] = None,
                 edge_channel: Optional[Channel] = None,
                 fold_impl: str = "jnp", collapse_ideal: bool = True):
        if num_edges < 1:
            raise ValueError(f"num_edges must be >= 1, got {num_edges}")
        if fold_impl not in FOLD_IMPLS:
            raise ValueError(f"unknown fold impl {fold_impl!r}; "
                             f"expected one of {FOLD_IMPLS}")
        self.num_edges = int(num_edges)
        self.client_channel = client_channel or DenseChannel()
        self.edge_channel = edge_channel or DenseChannel()
        self.fold_impl = fold_impl
        for hop_name, hop in (("client", self.client_channel),
                              ("edge", self.edge_channel)):
            if isinstance(hop, HierarchicalChannel):
                raise ValueError(
                    f"nested hierarchical {hop_name} hop: flatten the tree "
                    f"into one client->edge->server topology instead")
            if getattr(hop, "noise_phases", None) is not None:
                raise ValueError(
                    f"{hop!r} as the {hop_name} hop: DP noise calibration "
                    f"and epsilon accounting across a two-level tree are "
                    f"not defined here — run the DP channel flat, or add a "
                    f"hierarchy-aware accountant first")
        # both hops ideal -> the tree is the flat sum in math; compute it
        # as the flat sum so the result is bit-identical to the
        # un-channeled paths (and the flat-cohort stats kernel stays exact)
        self.collapses = bool(collapse_ideal and self.client_channel.ideal
                              and self.edge_channel.ideal)
        self.supports_flat_stats = self.collapses
        self.full_participation = (self.client_channel.full_participation
                                   and self.edge_channel.full_participation)

    # ------------------------------------------------------------ round --
    def begin_round(self, key, client_sizes) -> HierarchicalContext:
        k = client_sizes.shape[0]
        edge_ids = contiguous_edge_ids(k, self.num_edges)
        k_client, k_edge = jax.random.split(key)
        cctx = self.client_channel.begin_round(k_client, client_sizes)
        # per-edge mass of *reporting* clients drives the edge hop's sizes
        edge_mass = kernels_ref.segment_sum_ref(
            (client_sizes.astype(F32) * cctx.mask)[:, None], edge_ids,
            self.num_edges)[:, 0]
        ectx = self.edge_channel.begin_round(k_edge, edge_mass)
        if self.edge_channel.full_participation:
            # all-ones edge mask: the client hop's weights are already the
            # effective weights — reuse them untouched (bitwise, so the
            # ideal-ideal collapse stays == the flat dense path)
            mask, weights = cctx.mask, cctx.weights
            num = cctx.num_participants
        else:
            keep = ectx.mask[edge_ids]                       # (K,)
            mask = cctx.mask * keep
            w_raw = cctx.weights * keep
            weights = w_raw / jnp.maximum(jnp.sum(w_raw), 1e-12)
            num = jnp.sum(mask)
        return HierarchicalContext(key, mask, weights, num, cctx, ectx,
                                   edge_ids)

    def with_edge_ids(self, ctx: HierarchicalContext,
                      edge_ids) -> HierarchicalContext:
        """Re-route the round through a SEMANTIC edge assignment (e.g.
        the clustered engine's in-scan cluster ids, repro.cluster) instead
        of the contiguous topological one: the edge hop re-runs
        ``begin_round`` on the new per-edge mass with the same edge key,
        and the effective mask/weights are recomposed exactly as
        ``begin_round`` composes them. ``edge_ids`` may be traced (it is
        computed inside the scan), so no K % E divisibility is assumed —
        an edge may legitimately be empty this round."""
        _, k_edge = jax.random.split(ctx.key)
        cctx = ctx.client_ctx
        # the client hop's masked weights stand in for sizes (proportional
        # — the edge hop only normalizes its per-edge mass)
        mass = kernels_ref.segment_sum_ref(
            (cctx.weights * cctx.mask)[:, None], edge_ids,
            self.num_edges)[:, 0]
        ectx = self.edge_channel.begin_round(k_edge, mass)
        if self.edge_channel.full_participation:
            mask, weights, num = cctx.mask, cctx.weights, \
                cctx.num_participants
        else:
            keep = ectx.mask[edge_ids]                       # (K,)
            mask = cctx.mask * keep
            w_raw = cctx.weights * keep
            weights = w_raw / jnp.maximum(jnp.sum(w_raw), 1e-12)
            num = jnp.sum(mask)
        return ctx._replace(mask=mask, weights=weights,
                            num_participants=num, edge_ctx=ectx,
                            edge_ids=jnp.asarray(edge_ids, jnp.int32))

    # ------------------------------------------------------------- wire --
    def _client_view(self, ctx) -> ChannelContext:
        """The client hop's view of a context: the composite's sub-context
        when available, the plain context itself otherwise (the sharded
        body reconstructs plain contexts from sliced arrays)."""
        if isinstance(ctx, HierarchicalContext):
            return ctx.client_ctx._replace(mask=ctx.mask,
                                           weights=ctx.weights)
        return ctx

    def encode_decode(self, ctx, tree_k, phase: str):
        return self.client_channel.encode_decode(self._client_view(ctx),
                                                 tree_k, phase)

    def post_aggregate(self, ctx, tree, phase: str):
        if isinstance(ctx, HierarchicalContext):
            return self.edge_channel.post_aggregate(ctx.edge_ctx, tree,
                                                    phase)
        return tree

    def aggregate(self, ctx: HierarchicalContext, tree_k, phase: str):
        if self.collapses:
            return self.client_channel.aggregate(self._client_view(ctx),
                                                 tree_k, phase)
        dec = self.client_channel.encode_decode(ctx.client_ctx, tree_k,
                                                phase)
        partials = fold_to_edges(dec, ctx.weights, ctx.edge_ids,
                                 self.num_edges, self.fold_impl)
        enc = self.edge_channel.encode_decode(ctx.edge_ctx, partials, phase)
        agg = jax.tree.map(
            lambda v: jnp.tensordot(ctx.edge_ctx.mask, v, axes=1), enc)
        return self.edge_channel.post_aggregate(ctx.edge_ctx, agg, phase)

    def local_fold(self, ctx_local, dec_tree, phase: str, *,
                   num_shards: int = 1):
        """Sharded-cohort fold: edges align with the mesh — each shard
        folds its K/num_shards clients into its E/num_shards edges with
        the segment-sum kernel and runs the edge hop locally; the psum
        over shards (done by the caller) is the edge→server sum."""
        if self.collapses:
            return super().local_fold(ctx_local, dec_tree, phase)
        if self.num_edges % num_shards:
            raise ValueError(
                f"{self.num_edges} edges do not align with {num_shards} "
                f"shards: num_edges must be a multiple of the cohort mesh "
                f"axis size")
        e_local = self.num_edges // num_shards
        k_local = jax.tree.leaves(dec_tree)[0].shape[0]
        ids = contiguous_edge_ids(k_local, e_local)
        partials = fold_to_edges(dec_tree, ctx_local.weights, ids, e_local,
                                 self.fold_impl)
        ectx_l = ChannelContext(
            jax.random.fold_in(ctx_local.key, _EDGE_SALT),
            jnp.ones((e_local,), F32), jnp.full((e_local,), 1.0 / e_local,
                                                F32),
            jnp.asarray(float(e_local), F32))
        enc = self.edge_channel.encode_decode(ectx_l, partials, phase)
        return jax.tree.map(lambda v: jnp.sum(v, axis=0), enc)

    def chunk_fold(self, ctx: HierarchicalContext, tree_chunk, phase: str,
                   chunk_index, chunk_weights):
        """Streaming fold: the cohort chunk must hold whole edges (the
        engine validates chunk % (K/E) == 0 at build), so each chunk folds
        its clients into its own edges, runs the edge hop, and hands back
        a partial the streaming scan accumulates."""
        chunk = jax.tree.leaves(tree_chunk)[0].shape[0]
        k = ctx.weights.shape[0]
        edge_size = k // self.num_edges
        if chunk % edge_size:
            raise ValueError(
                f"cohort chunk of {chunk} does not hold whole edges "
                f"(edge size {edge_size}): pick cohort_chunk a multiple "
                f"of clients-per-round / num_edges")
        if self.collapses:
            return super().chunk_fold(ctx, tree_chunk, phase, chunk_index,
                                      chunk_weights)
        e_chunk = chunk // edge_size
        cctx_c = ctx.client_ctx._replace(
            key=jax.random.fold_in(ctx.client_ctx.key, chunk_index))
        dec = self.client_channel.encode_decode(cctx_c, tree_chunk, phase)
        partials = fold_to_edges(dec, chunk_weights,
                                 contiguous_edge_ids(chunk, e_chunk),
                                 e_chunk, self.fold_impl)
        ectx_c = ctx.edge_ctx._replace(
            key=jax.random.fold_in(ctx.edge_ctx.key, chunk_index))
        enc = self.edge_channel.encode_decode(ectx_c, partials, phase)
        emask = jax.lax.dynamic_slice(ctx.edge_ctx.mask,
                                      (chunk_index * e_chunk,), (e_chunk,))
        return jax.tree.map(lambda v: jnp.tensordot(emask, v, axes=1), enc)

    # ------------------------------------------------------- accounting --
    def round_bytes(self, ctx: HierarchicalContext, payload_template):
        per_hop = self.hop_bytes(ctx, payload_template)
        return per_hop["client_edge"] + per_hop["edge_server"]

    def hop_bytes(self, ctx: HierarchicalContext, payload_template):
        """Per-hop uplink bytes this round: surviving clients x the client
        hop's payload width, surviving edges x the edge hop's width."""
        return {
            "client_edge": ctx.num_participants *
            self.client_channel.payload_bytes(payload_template),
            "edge_server": ctx.edge_ctx.num_participants *
            self.edge_channel.payload_bytes(payload_template),
        }

    def payload_bytes(self, tree) -> float:
        # per-client wire width = the client hop's encoding
        return self.client_channel.payload_bytes(tree)

    def finalize_rounds(self, num_rounds: int) -> None:
        self.client_channel.finalize_rounds(num_rounds)
        self.edge_channel.finalize_rounds(num_rounds)

    def __repr__(self) -> str:
        return (f"HierarchicalChannel(edges={self.num_edges}, "
                f"client={self.client_channel!r}, "
                f"edge={self.edge_channel!r})")
