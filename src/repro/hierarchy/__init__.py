# Hierarchical aggregation & streaming mega-cohorts: the Eq.-3 linearity
# of every stats payload makes aggregation exact under any summation tree,
# so the cohort can fan in through edge aggregators (per-hop comm
# channels, per-hop wire bytes) and stream through the round in fixed-size
# chunks with O(chunk) peak memory. See docs/architecture.md "Hierarchy &
# streaming".
from repro.hierarchy.aggregation import (  # noqa: F401
    FOLD_IMPLS, HierarchicalChannel, HierarchicalContext,
    contiguous_edge_ids, fold_to_edges)
from repro.hierarchy.streaming import (  # noqa: F401
    StreamingSampler, streaming_stats_round)
