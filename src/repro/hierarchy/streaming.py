"""Streaming mega-cohort execution — cohort size as a memory-free knob.

The materialized engine paths stack the whole cohort on one axis: a round
of K clients holds (K, n, ...) batches and (K, ...) per-client deltas
live at once, capping clients/round far below the cross-device
populations FedAvg targets (thousands of devices, tiny local datasets).

Because every payload is linear in samples (paper Eq. 3), the round does
not need the cohort in memory: this module runs the two-phase stats
protocol over fixed-size cohort *chunks* with an inner ``lax.scan`` whose
carry holds only the running stat-sums / delta-sums — peak memory is
O(cohort_chunk), independent of K, and the result equals the materialized
round up to float regrouping (tested). The streamed round IS the Fig.-2
protocol read literally: the server only ever touches aggregates.

  phase 1: scan chunks — encode each chunk's clients, fold their stats
           into the carry with the chunk's slice of the global Eq.-3
           weights (``Channel.chunk_fold``, so quantization / dropout /
           hierarchical edge trees compose) — then one ``post_aggregate``;
  phase 2: scan chunks again — each chunk's clients take their local
           steps against the stop-grad combine with the phase-1 aggregate,
           and only the weighted delta partial survives the chunk.

Phase 2 re-gathers and re-augments each chunk (the chunk sampler is
deterministic in (k_sel, k_aug, chunk)), which costs no extra encoder
FLOPs vs the materialized round — phase 1 is forward-only and phase 2
re-encodes under the gradient there too.

Note XLA:CPU serializes scan bodies, so on CPU the inner scan trades the
unrolled cohort's inter-op parallelism for bounded memory — the
``population_scale`` benchmark measures exactly that trade (round time
and compiled peak memory vs chunk size).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import fed_sim
from repro.server import update as server_update_lib

F32 = jnp.float32


class StreamingSampler(NamedTuple):
    """A chunkable cohort sampler for the streaming engine path.

    ``prepare(k_sel, k_aug)`` computes the per-round O(K)-scalar state
    once, OUTSIDE the chunk scans (selection indices, augmentation keys
    — cheap to hold, and hoisting it keeps the scan bodies free of
    repeated cohort-wide work); ``sample_chunk(state, c)`` returns chunk
    ``c`` of the round's cohort — ``(batch (chunk, n, ...), sizes
    (chunk,))`` — and must be deterministic in its arguments (phase 2
    replays it); ``cohort_sizes(k_sel)`` returns the full (K,) client
    sizes (channels need them for participation and Eq.-3 weights — the
    *batches* are what never materialize).
    ``FederatedDataset.make_streaming_sampler`` builds one whose chunks
    concatenate to exactly ``make_round_sampler``'s cohort.
    """
    clients_per_round: int
    cohort_chunk: int
    prepare: Callable
    sample_chunk: Callable
    cohort_sizes: Callable

    @property
    def num_chunks(self) -> int:
        return self.clients_per_round // self.cohort_chunk


def streaming_stats_round(encoder_apply: Callable, params, opt_state,
                          server_opt, sample_chunk: Callable,
                          num_chunks: int, client_sizes, *, objective,
                          client_lr: float = 1.0, local_steps: int = 1,
                          channel=None, channel_key=None,
                          prox_mu: float = 0.0):
    """One two-phase stats round streamed over ``num_chunks`` cohort
    chunks. Semantically ``fed_sim.stats_round`` on the concatenated
    cohort (same objective/channel/drift contracts, minus SCAFFOLD — slot
    variates are cohort-resident state, which is exactly what streaming
    removes); returns (params, opt_state, RoundMetrics).

    ``sample_chunk(c) -> (batch, sizes)`` is the already-keyed chunk
    closure; ``client_sizes`` is the full (K,) cohort sizes array.
    """
    server_update = server_update_lib.as_server_update(server_opt)
    k = client_sizes.shape[0]
    if k % num_chunks:
        raise ValueError(f"cohort of {k} does not divide into "
                         f"{num_chunks} chunks")
    chunk = k // num_chunks
    if channel is not None:
        if channel_key is None:
            raise ValueError("channel requires channel_key")
        ctx = channel.begin_round(channel_key, client_sizes)
        w = ctx.weights
    else:
        ctx = None
        w = client_sizes.astype(F32) / jnp.sum(client_sizes.astype(F32))

    def w_slice(c):
        return jax.lax.dynamic_slice(w, (c * chunk,), (chunk,))

    def chunk_stats(c):
        batch, sizes_c = sample_chunk(c)
        n_pad = jax.tree.leaves(batch)[0].shape[1]
        masks = fed_sim._client_masks(sizes_c, n_pad)

        def client_stats(b, m):
            zf, zg = encoder_apply(params, b)
            return objective.stats_masked(zf, zg, m)

        st_k = jax.vmap(client_stats)(batch, masks)
        if ctx is None:
            return jax.tree.map(
                lambda v: jnp.tensordot(w_slice(c), v, axes=1), st_k)
        return channel.chunk_fold(ctx, st_k, "stats", c, w_slice(c))

    # ---- phase 1: stream the chunks, accumulate the stat partials.
    # Chunk 0 runs outside the scan and seeds the carry — no zero
    # templates to derive, and a 1-chunk cohort never builds a scan.
    acc0 = chunk_stats(0)
    if num_chunks > 1:
        agg_sum, _ = jax.lax.scan(
            lambda acc, c: (jax.tree.map(jnp.add, acc, chunk_stats(c)),
                            None),
            acc0, jnp.arange(1, num_chunks))
    else:
        agg_sum = acc0
    agg = agg_sum if ctx is None else channel.post_aggregate(ctx, agg_sum,
                                                             "stats")

    # ---- phase 2: stream again, clients step against the combine
    def chunk_update(c):
        batch, sizes_c = sample_chunk(c)
        n_pad = jax.tree.leaves(batch)[0].shape[1]
        masks = fed_sim._client_masks(sizes_c, n_pad)

        def client_update(b, m):
            def loss_fn(p):
                zf, zg = encoder_apply(p, b)
                local = objective.stats_masked(zf, zg, m)
                return objective.loss_from_stats(
                    objective.combine(local, agg))

            return fed_sim.client_local_steps(loss_fn, params, client_lr,
                                              local_steps, prox_mu=prox_mu)

        deltas, losses_k = jax.vmap(client_update)(batch, masks)
        wc = w_slice(c)
        if ctx is None:
            part = jax.tree.map(lambda d: jnp.tensordot(wc, d, axes=1),
                                deltas)
        else:
            part = channel.chunk_fold(ctx, deltas, "update", c, wc)
        return part, jnp.sum(wc * losses_k)

    d0, l0 = chunk_update(0)
    if num_chunks > 1:
        def p2_body(carry, c):
            part, lo = chunk_update(c)
            return (jax.tree.map(jnp.add, carry[0], part),
                    carry[1] + lo), None

        (delta_sum, loss), _ = jax.lax.scan(p2_body, (d0, l0),
                                            jnp.arange(1, num_chunks))
    else:
        delta_sum, loss = d0, l0
    avg_delta = delta_sum if ctx is None else channel.post_aggregate(
        ctx, delta_sum, "update")

    params, opt_state = server_update.step(params, opt_state, avg_delta)
    enc_std = objective.encoding_std(agg)
    wire = 0.0
    if ctx is not None:
        wire = channel.round_bytes(ctx, agg) + \
            channel.round_bytes(ctx, avg_delta)
    return params, opt_state, fed_sim.RoundMetrics(loss, enc_std,
                                                   jnp.asarray(wire, F32))
