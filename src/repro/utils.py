"""Small shared utilities: pytree helpers, dtype policy, rng streams."""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = Any  # nested dict of jnp arrays
PyTree = Any


def tree_size(tree: PyTree) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x: PyTree, y: PyTree) -> PyTree:
    """alpha * x + y"""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_dot(a: PyTree, b: PyTree):
    leaves = jax.tree.map(lambda x, y: jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32)), a, b)
    return functools.reduce(jnp.add, jax.tree.leaves(leaves))


def tree_norm(a: PyTree):
    return jnp.sqrt(tree_dot(a, a))


def tree_allclose(a: PyTree, b: PyTree, rtol=1e-5, atol=1e-6) -> bool:
    oks = jax.tree.leaves(
        jax.tree.map(lambda x, y: bool(np.allclose(np.asarray(x, np.float64), np.asarray(y, np.float64),
                                                   rtol=rtol, atol=atol)), a, b))
    return all(oks)


def tree_max_abs_diff(a: PyTree, b: PyTree) -> float:
    diffs = jax.tree.leaves(jax.tree.map(
        lambda x, y: float(np.max(np.abs(np.asarray(x, np.float64) - np.asarray(y, np.float64)))) if x.size else 0.0,
        a, b))
    return max(diffs) if diffs else 0.0


def split_key_tree(key, tree: PyTree) -> PyTree:
    """One rng key per leaf, matching the tree structure."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, list(keys))


def has_nan(tree: PyTree) -> bool:
    return any(bool(jnp.isnan(x).any()) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


def chunked(seq, n):
    for i in range(0, len(seq), n):
        yield seq[i:i + n]
