"""Recurrent blocks: Mamba2 chunked SSD and xLSTM cells — parallel training
form must match step-by-step decode recurrence exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, SSMConfig, XLSTMConfig
from repro.models import ssm, xlstm


def _ssm_cfg(chunk=8):
    return ModelConfig(d_model=32, num_heads=2, num_kv_heads=2,
                       ssm=SSMConfig(state=8, expand=2, conv_width=4,
                                     head_dim=16, chunk=chunk))


class TestMamba2:
    def test_forward_matches_decode(self, rng_key):
        cfg = _ssm_cfg()
        p = ssm.mamba2_init(rng_key, cfg, jnp.float32)
        b, s = 2, 16
        x = jax.random.normal(rng_key, (b, s, cfg.d_model)) * 0.5
        y_full = ssm.mamba2_forward(cfg, p, x)
        cache = ssm.mamba2_cache_init(cfg, b, jnp.float32)
        ys = []
        for t in range(s):
            yt, cache = ssm.mamba2_decode(cfg, p, x[:, t:t + 1], cache)
            ys.append(yt)
        np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                                   np.asarray(y_full), rtol=3e-4, atol=3e-4)

    @pytest.mark.parametrize("chunk", [2, 4, 16])
    def test_chunk_invariance(self, chunk, rng_key):
        """The chunked SSD scan is exact for every chunk size."""
        p = ssm.mamba2_init(rng_key, _ssm_cfg(), jnp.float32)
        x = jax.random.normal(rng_key, (1, 16, 32)) * 0.5
        y_ref = ssm.mamba2_forward(_ssm_cfg(chunk=16), p, x)
        y = ssm.mamba2_forward(_ssm_cfg(chunk=chunk), p, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=3e-4, atol=3e-4)

    def test_ssd_against_naive_recurrence(self, rng_key):
        """_ssd_chunked vs an explicit per-step h update (the SSD oracle)."""
        b, s, h, pdim, n = 1, 12, 2, 4, 3
        ks = jax.random.split(rng_key, 4)
        x = jax.random.normal(ks[0], (b, s, h, pdim))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
        bmat = jax.random.normal(ks[3], (b, s, n))
        cmat = jax.random.normal(jax.random.PRNGKey(9), (b, s, n))
        y_fast = ssm._ssd_chunked(x, dt, a, bmat, cmat, chunk=4)
        hstate = jnp.zeros((b, h, n, pdim))
        outs = []
        for t in range(s):
            decay = jnp.exp(dt[:, t] * a[None])                     # (b,h)
            hstate = hstate * decay[..., None, None] + jnp.einsum(
                "bh,bn,bhp->bhnp", dt[:, t], bmat[:, t], x[:, t])
            outs.append(jnp.einsum("bn,bhnp->bhp", cmat[:, t], hstate))
        y_ref = jnp.stack(outs, 1)
        np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)


def _xlstm_cfg(chunk=4):
    return ModelConfig(d_model=32, num_heads=2, num_kv_heads=2,
                       xlstm=XLSTMConfig(chunk=chunk))


class TestMlstm:
    def test_forward_matches_decode(self, rng_key):
        cfg = _xlstm_cfg()
        p = xlstm.mlstm_init(rng_key, cfg, jnp.float32)
        b, s = 2, 12
        x = jax.random.normal(rng_key, (b, s, cfg.d_model)) * 0.5
        y_full, _ = xlstm.mlstm_forward(cfg, p, x)
        st = xlstm.mlstm_state_init(cfg, b)
        ys = []
        for t in range(s):
            yt, st = xlstm.mlstm_decode(cfg, p, x[:, t:t + 1], st)
            ys.append(yt)
        np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                                   np.asarray(y_full), rtol=3e-4, atol=3e-4)

    @pytest.mark.parametrize("chunk", [1, 2, 6, 12])
    def test_chunk_invariance(self, chunk, rng_key):
        p = xlstm.mlstm_init(rng_key, _xlstm_cfg(), jnp.float32)
        x = jax.random.normal(rng_key, (1, 12, 32)) * 0.5
        y_ref, _ = xlstm.mlstm_forward(_xlstm_cfg(chunk=12), p, x)
        y, _ = xlstm.mlstm_forward(_xlstm_cfg(chunk=chunk), p, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=3e-4, atol=3e-4)

    def test_extreme_gates_stable(self, rng_key):
        """Exponential gating must not overflow (stabilizer m at work)."""
        cfg = _xlstm_cfg()
        p = xlstm.mlstm_init(rng_key, cfg, jnp.float32)
        x = jax.random.normal(rng_key, (1, 16, cfg.d_model)) * 50.0
        y, _ = xlstm.mlstm_forward(cfg, p, x)
        assert bool(jnp.isfinite(y).all())


class TestSlstm:
    def test_forward_matches_decode(self, rng_key):
        cfg = _xlstm_cfg()
        p = xlstm.slstm_init(rng_key, cfg, jnp.float32)
        b, s = 2, 10
        x = jax.random.normal(rng_key, (b, s, cfg.d_model)) * 0.5
        y_full, _ = xlstm.slstm_forward(cfg, p, x)
        st = xlstm.slstm_state_init(cfg, b)
        ys = []
        for t in range(s):
            yt, st = xlstm.slstm_decode(cfg, p, x[:, t:t + 1], st)
            ys.append(yt)
        np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                                   np.asarray(y_full), rtol=1e-5, atol=1e-5)

    def test_recurrence_is_stateful(self, rng_key):
        """h feeds back: permuting the input sequence changes outputs."""
        cfg = _xlstm_cfg()
        p = xlstm.slstm_init(rng_key, cfg, jnp.float32)
        x = jax.random.normal(rng_key, (1, 8, cfg.d_model))
        y1, _ = xlstm.slstm_forward(cfg, p, x)
        y2, _ = xlstm.slstm_forward(cfg, p, x[:, ::-1])
        assert float(jnp.max(jnp.abs(y1[:, -1] - y2[:, -1]))) > 1e-5
