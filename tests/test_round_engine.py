"""Scan-compiled round engine (repro.core.round_engine):

  * scan-of-N-rounds == N Python-driven fed_sim rounds (same fold_in keys);
  * segment chunking is a pure implementation detail (chunk=3 == chunk=7);
  * all five algorithm bodies run and train;
  * phase-1 aggregate stats through the Pallas kernel == the jnp path;
  * in-scan sampler == host-driven FederatedDataset.round_batch;
  * chunked metrics streaming + periodic checkpointing;
  * sharded-cohort DCCO == single-device DCCO on a forced 2-device CPU mesh
    (subprocess, --xla_force_host_platform_device_count).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import utils
from repro.core import fed_sim, round_engine
from repro.data import pipeline, synthetic
from repro.optim import optimizers as opt_lib

LAM = 5.0


@pytest.fixture(scope="module")
def toy():
    key = jax.random.PRNGKey(0)
    params = {"w1": jax.random.normal(key, (10, 16)) * 0.3,
              "w2": jax.random.normal(jax.random.PRNGKey(7), (16, 6)) * 0.3}

    def apply(p, batch):
        def enc(x):
            return jnp.tanh(x @ p["w1"]) @ p["w2"]
        return enc(batch["v1"]), enc(batch["v2"])

    pool = {"v1": jax.random.normal(jax.random.PRNGKey(1), (20, 3, 10)),
            "v2": jax.random.normal(jax.random.PRNGKey(2), (20, 3, 10))}

    def sampler(k_sel, k_aug):
        sel = jax.random.choice(k_sel, 20, (6,), replace=False)
        return (jax.tree.map(lambda x: x[sel], pool),
                jnp.full((6,), 3, jnp.int32))

    return params, apply, sampler


def _run_python_loop(params, apply, sampler, opt, rng, rounds, **round_kw):
    """The reference: one fed_sim round per Python dispatch, keys derived
    exactly like the engine derives them in-scan."""
    p, st = params, opt.init(params)
    losses = []
    for r in range(rounds):
        k_sel, k_aug = jax.random.split(jax.random.fold_in(rng, r))
        batch, sizes = sampler(k_sel, k_aug)
        p, st, m = fed_sim.dcco_round(apply, p, st, opt, batch, sizes,
                                      lam=LAM, **round_kw)
        losses.append(float(m.loss))
    return p, st, np.asarray(losses)


class TestScanEquivalence:
    def test_scan_equals_python_loop(self, toy):
        params, apply, sampler = toy
        opt = opt_lib.adam(1e-2)
        rng = jax.random.PRNGKey(3)
        cfg = round_engine.EngineConfig(algorithm="dcco", lam=LAM,
                                        chunk_rounds=8)
        eng = round_engine.RoundEngine(apply, opt, sampler, cfg)
        pe, se, me = eng.run(params, opt.init(params), rng, 8)
        pl, sl, losses = _run_python_loop(params, apply, sampler, opt, rng, 8)
        assert utils.tree_max_abs_diff(pe, pl) < 1e-6
        np.testing.assert_allclose(np.asarray(me.loss), losses,
                                   rtol=1e-5, atol=1e-6)

    @pytest.mark.slow
    def test_chunking_is_invisible(self, toy):
        params, apply, sampler = toy
        opt = opt_lib.sgd(0.1)
        rng = jax.random.PRNGKey(5)
        outs = []
        for chunk in (3, 7):
            cfg = round_engine.EngineConfig(algorithm="dcco", lam=LAM,
                                            chunk_rounds=chunk)
            eng = round_engine.RoundEngine(apply, opt, sampler, cfg)
            outs.append(eng.run(params, opt.init(params), rng, 7))
        assert utils.tree_max_abs_diff(outs[0][0], outs[1][0]) < 1e-6
        np.testing.assert_allclose(np.asarray(outs[0][2].loss),
                                   np.asarray(outs[1][2].loss),
                                   rtol=1e-6, atol=1e-7)

    def test_start_round_offsets_the_rng_stream(self, toy):
        """Resume semantics: running [0, 4) then [4, 8) == running [0, 8)."""
        params, apply, sampler = toy
        opt = opt_lib.sgd(0.1)
        rng = jax.random.PRNGKey(9)
        cfg = round_engine.EngineConfig(algorithm="dcco", lam=LAM,
                                        chunk_rounds=4)
        eng = round_engine.RoundEngine(apply, opt, sampler, cfg)
        p1, s1, _ = eng.run(params, opt.init(params), rng, 4)
        p1, s1, _ = eng.run(p1, s1, rng, 4, start_round=4)
        p2, s2, _ = eng.run(params, opt.init(params), rng, 8)
        assert utils.tree_max_abs_diff(p1, p2) < 1e-6


class TestAlgorithmBodies:
    @pytest.mark.parametrize("algorithm", round_engine.ALGORITHMS)
    def test_runs_and_trains(self, toy, algorithm):
        params, apply, sampler = toy
        opt = opt_lib.adam(1e-2)
        cfg = round_engine.EngineConfig(algorithm=algorithm, lam=LAM,
                                        chunk_rounds=3)
        eng = round_engine.RoundEngine(apply, opt, sampler, cfg)
        p, s, m = eng.run(params, opt.init(params), jax.random.PRNGKey(3), 6)
        assert m.loss.shape == (6,)
        assert bool(jnp.isfinite(m.loss).all())
        assert utils.tree_max_abs_diff(p, params) > 0.0

    def test_dcco_equals_centralized_body(self, toy):
        """Appendix A inside the engine: the dcco and centralized bodies
        produce the same trajectory at client_lr=1, one local step."""
        params, apply, sampler = toy
        opt = opt_lib.sgd(0.05)
        outs = {}
        for algorithm in ("dcco", "centralized"):
            cfg = round_engine.EngineConfig(algorithm=algorithm, lam=LAM,
                                            client_lr=1.0, chunk_rounds=4)
            eng = round_engine.RoundEngine(apply, opt, sampler, cfg)
            outs[algorithm] = eng.run(params, opt.init(params),
                                      jax.random.PRNGKey(3), 4)
        assert utils.tree_max_abs_diff(outs["dcco"][0],
                                       outs["centralized"][0]) < 1e-5

    def test_unknown_algorithm_rejected(self, toy):
        params, apply, sampler = toy
        with pytest.raises(ValueError):
            round_engine.make_round_body(
                apply, opt_lib.sgd(0.1),
                round_engine.EngineConfig(algorithm="fedprox"))


class TestKernelStatsRouting:
    @pytest.mark.slow
    def test_pallas_agg_stats_matches_jnp(self, toy):
        params, apply, sampler = toy
        opt = opt_lib.adam(1e-2)
        rng = jax.random.PRNGKey(3)
        outs = {}
        for kernel in ("off", "interpret"):
            cfg = round_engine.EngineConfig(algorithm="dcco", lam=LAM,
                                            chunk_rounds=4,
                                            stats_kernel=kernel)
            eng = round_engine.RoundEngine(apply, opt, sampler, cfg)
            outs[kernel] = eng.run(params, opt.init(params), rng, 4)
        assert utils.tree_max_abs_diff(outs["off"][0],
                                       outs["interpret"][0]) < 1e-5
        np.testing.assert_allclose(np.asarray(outs["off"][2].loss),
                                   np.asarray(outs["interpret"][2].loss),
                                   rtol=1e-4)


class TestInScanSampler:
    def test_sampler_matches_round_batch(self):
        imgs, labels = synthetic.synthetic_labeled_images(60, 3, image_size=8,
                                                          noise=0.5, seed=1)
        ds = pipeline.FederatedDataset.build(
            {"images": imgs}, labels, num_clients=20, samples_per_client=2,
            alpha=0.0, seed=0)
        sampler = ds.make_round_sampler(5)
        key = jax.random.PRNGKey(11)
        ref_batch, ref_sizes = ds.round_batch(key, 5)
        batch, sizes = jax.jit(sampler)(*jax.random.split(key))
        assert utils.tree_max_abs_diff(batch, ref_batch) < 1e-6
        np.testing.assert_array_equal(np.asarray(sizes), np.asarray(ref_sizes))


class TestStreamingAndCheckpoint:
    def test_segments_stream_and_checkpoint(self, toy, tmp_path):
        from repro.checkpoint import restore_checkpoint
        params, apply, sampler = toy
        opt = opt_lib.adam(1e-2)
        cfg = round_engine.EngineConfig(algorithm="dcco", lam=LAM,
                                        chunk_rounds=2)
        eng = round_engine.RoundEngine(apply, opt, sampler, cfg)
        seen = []
        p, s, m = eng.run(
            params, opt.init(params), jax.random.PRNGKey(3), 6,
            on_segment=lambda end, carry, seg: seen.append((end, seg.loss.shape)),
            ckpt_dir=str(tmp_path), ckpt_every=2, ckpt_name="eng")
        assert seen == [(2, (2,)), (4, (2,)), (6, (2,))]
        assert m.loss.shape == (6,) and m.encoding_std.shape == (6,)
        blob, step = restore_checkpoint(str(tmp_path / "eng.msgpack"),
                                        {"params": params, "opt": opt.init(params)})
        assert step == 6
        assert utils.tree_max_abs_diff(blob["params"], p) < 1e-7


_SHARDED_SCRIPT = """
import jax, jax.numpy as jnp
assert jax.device_count() == 2, jax.device_count()
from repro import utils
from repro.core import fed_sim, round_engine
from repro.optim import optimizers as opt_lib

key = jax.random.PRNGKey(0)
params = {"w1": jax.random.normal(key, (10, 16)) * 0.3,
          "w2": jax.random.normal(jax.random.PRNGKey(7), (16, 6)) * 0.3}
def apply(p, batch):
    enc = lambda x: jnp.tanh(x @ p["w1"]) @ p["w2"]
    return enc(batch["v1"]), enc(batch["v2"])
k1, k2 = jax.random.split(key)
data = {"v1": jax.random.normal(k1, (8, 3, 10)),
        "v2": jax.random.normal(k2, (8, 3, 10))}
sizes = jnp.array([3, 1, 2, 3, 3, 2, 1, 3], jnp.int32)
mesh = jax.make_mesh((2, 1), ("data", "model"))
opt = opt_lib.adam(1e-2)
p1, s1, m1 = fed_sim.dcco_round(apply, params, opt.init(params), opt,
                                data, sizes, lam=5.0)
p2, s2, m2 = round_engine.dcco_round_sharded(apply, params, opt.init(params),
                                             opt, data, sizes, mesh, lam=5.0)
assert utils.tree_max_abs_diff(p1, p2) < 1e-6
assert abs(float(m1.loss) - float(m2.loss)) < 1e-5
assert abs(float(m1.encoding_std) - float(m2.encoding_std)) < 1e-6

# channels through the sharded psum body (repro.comm): dense == legacy,
# dropout(p=0) == dense bitwise, int8 runs and accounts bytes
from repro import comm
ck = jax.random.PRNGKey(42)
pd, sd, md = round_engine.dcco_round_sharded(
    apply, params, opt.init(params), opt, data, sizes, mesh, lam=5.0,
    channel=comm.DenseChannel(), channel_key=ck)
assert utils.tree_max_abs_diff(p2, pd) < 1e-6
assert float(md.wire_bytes) > 0
p0d, s0d, m0d = round_engine.dcco_round_sharded(
    apply, params, opt.init(params), opt, data, sizes, mesh, lam=5.0,
    channel=comm.DropoutChannel(0.0), channel_key=ck)
assert utils.tree_max_abs_diff(pd, p0d) == 0.0
pq, sq, mq = round_engine.dcco_round_sharded(
    apply, params, opt.init(params), opt, data, sizes, mesh, lam=5.0,
    channel=comm.QuantizedChannel(8), channel_key=ck)
assert float(mq.wire_bytes) < float(md.wire_bytes) / 3
assert abs(float(mq.loss) - float(m1.loss)) < 0.5

# and scan-compiled: the engine with cohort_axis on the 2-device mesh
def sampler(k_sel, k_aug):
    return data, sizes
cfg = round_engine.EngineConfig(algorithm="dcco", lam=5.0, chunk_rounds=3,
                                cohort_axis="data")
eng = round_engine.RoundEngine(apply, opt, sampler, cfg, mesh=mesh)
pe, se, me = eng.run(params, opt.init(params), jax.random.PRNGKey(3), 6)
cfg1 = round_engine.EngineConfig(algorithm="dcco", lam=5.0, chunk_rounds=3)
eng1 = round_engine.RoundEngine(apply, opt, sampler, cfg1)
p1, s1, m1 = eng1.run(params, opt.init(params), jax.random.PRNGKey(3), 6)
assert utils.tree_max_abs_diff(pe, p1) < 1e-5

# sharded engine with a dropout channel: compiles, trains, accounts bytes
cfg2 = round_engine.EngineConfig(algorithm="dcco", lam=5.0, chunk_rounds=3,
                                 cohort_axis="data",
                                 channel=comm.DropoutChannel(0.3))
eng2 = round_engine.RoundEngine(apply, opt, sampler, cfg2, mesh=mesh)
pc, sc, mc = eng2.run(params, opt.init(params), jax.random.PRNGKey(3), 6)
assert mc.wire_bytes.shape == (6,)
assert bool(jnp.isfinite(mc.loss).all())

# SCAFFOLD through the sharded psum body == single-device scaffold round:
# slot variates shard with the client axis, the variate-delta average is
# one more psum, the server variate stays replicated
from repro.server import scaffold_init
d0 = scaffold_init(params, 8)
ps1, ss1, ds1, ms1 = fed_sim.dcco_round(
    apply, params, opt.init(params), opt, data, sizes, lam=5.0,
    client_lr=0.1, local_steps=2, scaffold_state=d0)
ps2, ss2, ds2, ms2 = round_engine.dcco_round_sharded(
    apply, params, opt.init(params), opt, data, sizes, mesh, lam=5.0,
    client_lr=0.1, local_steps=2, scaffold_state=d0)
assert utils.tree_max_abs_diff(ps1, ps2) < 1e-6
# variates scale like grad/(L*lr) (~5x the gradients here), so the psum
# reassociation error is correspondingly larger than on the params
assert utils.tree_max_abs_diff(ds1.c, ds2.c) < 1e-4
assert utils.tree_max_abs_diff(ds1.c_slots, ds2.c_slots) < 1e-3
# variate uplink is accounted when a channel is present
pw, sw, dw, mw = round_engine.dcco_round_sharded(
    apply, params, opt.init(params), opt, data, sizes, mesh, lam=5.0,
    client_lr=0.1, local_steps=2, scaffold_state=d0,
    channel=comm.DenseChannel(), channel_key=ck)
assert float(mw.wire_bytes) > float(md.wire_bytes)
# sharded engine with scaffold in the scan carry; client_lr small enough
# that the variate dynamics are stable on this toy (a divergent trajectory
# would amplify benign psum reassociation noise into spurious mismatches)
cfg3 = round_engine.EngineConfig(algorithm="dcco", lam=5.0, chunk_rounds=3,
                                 cohort_axis="data", client_lr=0.03,
                                 local_steps=2, scaffold=True)
eng3 = round_engine.RoundEngine(apply, opt, sampler, cfg3, mesh=mesh)
pe3, se3, me3 = eng3.run(params, opt.init(params), jax.random.PRNGKey(3), 6)
assert bool(jnp.isfinite(me3.loss).all())
cfg4 = round_engine.EngineConfig(algorithm="dcco", lam=5.0, chunk_rounds=3,
                                 client_lr=0.03, local_steps=2, scaffold=True)
eng4 = round_engine.RoundEngine(apply, opt, sampler, cfg4)
pe4, se4, me4 = eng4.run(params, opt.init(params), jax.random.PRNGKey(3), 6)
assert utils.tree_max_abs_diff(pe3, pe4) < 1e-5

# objective-parametric sharded round: D-VICReg through the 2-device psum
# body == the single-device stats_round (the 7-stat dict psums per key),
# and its channel-routed wire costs more bytes than DCCO's 5-stat dict
# hierarchical aggregation on the mesh (repro.hierarchy): 4 edges over 2
# shards -> each device folds its 4 clients into 2 local edges with the
# segment-sum kernel, the psum is the edge->server hop. Dense-dense
# collapses to the flat dense sharded result bitwise; an int8 client hop
# runs the real tree and accounts both hops' bytes.
from repro import hierarchy
ph0, sh0, mh0 = round_engine.dcco_round_sharded(
    apply, params, opt.init(params), opt, data, sizes, mesh, lam=5.0,
    channel=hierarchy.HierarchicalChannel(4), channel_key=ck)
assert utils.tree_max_abs_diff(pd, ph0) == 0.0
phq, shq, mhq = round_engine.dcco_round_sharded(
    apply, params, opt.init(params), opt, data, sizes, mesh, lam=5.0,
    channel=hierarchy.HierarchicalChannel(
        4, client_channel=comm.QuantizedChannel(8), fold_impl="interpret"),
    channel_key=ck)
assert bool(jnp.isfinite(mhq.loss))
assert float(mhq.wire_bytes) > float(mq.wire_bytes)  # + edge hop payloads
# misaligned edges (1 edge on 2 shards) are refused loudly
try:
    round_engine.dcco_round_sharded(
        apply, params, opt.init(params), opt, data, sizes, mesh, lam=5.0,
        channel=hierarchy.HierarchicalChannel(1, collapse_ideal=False),
        channel_key=ck)
    raise AssertionError("misaligned edges were not refused")
except ValueError as e:
    assert "align" in str(e)

from repro.objectives import get_objective
obj = get_objective("dvicreg")
pv1, sv1, mv1 = fed_sim.stats_round(apply, params, opt.init(params), opt,
                                    data, sizes, objective=obj)
pv2, sv2, mv2 = round_engine.stats_round_sharded(
    apply, params, opt.init(params), opt, data, sizes, mesh, objective=obj)
assert utils.tree_max_abs_diff(pv1, pv2) < 1e-6
assert abs(float(mv1.loss) - float(mv2.loss)) < 1e-5
pv3, sv3, mv3 = round_engine.stats_round_sharded(
    apply, params, opt.init(params), opt, data, sizes, mesh, objective=obj,
    channel=comm.DenseChannel(), channel_key=ck)
assert float(mv3.wire_bytes) > float(md.wire_bytes)
print("SHARDED_OK")
"""


class TestShardedCohort:
    @pytest.mark.slow
    def test_two_device_mesh_matches_single_device(self):
        """Runs in a subprocess: the host-platform device count must be
        forced before jax initializes, which has already happened here."""
        env = dict(os.environ)
        env.update({
            "JAX_PLATFORMS": "cpu",
            "XLA_FLAGS": (env.get("XLA_FLAGS", "") +
                          " --xla_force_host_platform_device_count=2").strip(),
            "PYTHONPATH": os.pathsep.join(
                [os.path.join(os.path.dirname(__file__), "..", "src"),
                 env.get("PYTHONPATH", "")]).rstrip(os.pathsep),
        })
        out = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                             env=env, capture_output=True, text=True,
                             timeout=420)
        assert out.returncode == 0, f"stdout={out.stdout}\nstderr={out.stderr}"
        assert "SHARDED_OK" in out.stdout
