"""Sharded + approximate retrieval (PR 9): kernel offset contract,
mesh-partitioned search exactness, IVF tier properties, drift-gated
refresh, and the engine's stateful refreshing eval.

The sharded contract under test is strict: the per-shard fused kernel +
all-gather merge must match single-device ``mips_topk`` BIT-FOR-BIT —
scores AND indices, including the lowest-global-index tie-break across
duplicated rows placed in DIFFERENT shards, and ragged corpora whose
size is not divisible by the shard count. The IVF property test pins the
complementary guarantee: ``nprobe == num_centroids`` scans every list
once and recovers the exact result (indices bit-for-bit; scores to f32
tolerance — the batched list dot re-associates differently than the 2-D
matmul).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mips_topk import BIG_IDX, mips_topk, mips_topk_chunked
from repro.retrieval import (CorpusIndex, IVFIndex, QueryServer,
                             ShardedCorpusIndex, encode_corpus_chunked,
                             l2_normalize, make_refreshing_retrieval_eval,
                             refresh_embeddings, sharded_mips_topk,
                             train_centroids)
from repro.retrieval.sharded import stack_shards

from test_retrieval import _toy_engine


def _qc(key, qn, n, d, dup_rows=()):
    kq, kc = jax.random.split(key)
    q = jax.random.normal(kq, (qn, d), jnp.float32)
    c = jax.random.normal(kc, (n, d), jnp.float32)
    for a, b in dup_rows:
        c = c.at[a].set(c[b])
    return q, c


def _assert_bitwise(got, want):
    gv, gi = got
    wv, wi = want
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    assert gi.dtype == jnp.int32


class TestKernelOffsetContract:
    """index_offset/n_total: shard-local search emits GLOBAL indices and
    masks rows past the global end in-kernel."""

    @pytest.mark.parametrize("backend", ["chunked", "interpret"])
    def test_offset_slice_matches_global(self, backend):
        q, c = _qc(jax.random.PRNGKey(0), 5, 96, 16)
        k, lo, rows = 4, 32, 48
        # search only rows [lo, lo+rows) with their global offset: must
        # equal the offset-free search on that slice, indices shifted up
        v, i = mips_topk(q, c[lo:lo + rows], k, backend=backend,
                         index_offset=jnp.asarray(lo, jnp.int32),
                         n_total=96)
        ref_v, ref_i = mips_topk(q, c[lo:lo + rows], k, backend="chunked")
        np.testing.assert_array_equal(np.asarray(i),
                                      np.asarray(ref_i) + lo)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(ref_v))

    @pytest.mark.parametrize("backend", ["chunked", "interpret"])
    def test_ragged_tail_masks_past_n_total(self, backend):
        """Padding rows past n_total must never surface, even when their
        scores would win."""
        q, c = _qc(jax.random.PRNGKey(1), 4, 40, 8)
        pad = jnp.concatenate([c[32:], 100.0 * q[:4][:4]], axis=0)
        # shard rows [32, 44) but only 40 global rows exist: the 4 huge
        # appended rows sit past the end and must mask to sentinels
        v, i = mips_topk(q, pad, 3, backend=backend,
                         index_offset=jnp.asarray(32, jnp.int32),
                         n_total=40)
        assert np.asarray(i).max() < 40
        want_v, want_i = mips_topk(q, c[32:40], 3, backend="chunked")
        np.testing.assert_array_equal(np.asarray(v), np.asarray(want_v))
        np.testing.assert_array_equal(np.asarray(i),
                                      np.asarray(want_i) + 32)

    def test_default_path_unchanged(self):
        """index_offset=None compiles the exact pre-change program."""
        q, c = _qc(jax.random.PRNGKey(2), 3, 64, 8)
        _assert_bitwise(mips_topk_chunked(q, c, k=5),
                        mips_topk_chunked(q, c, k=5, index_offset=None,
                                          n_total=None))


class TestShardedExactness:
    """Bit-for-bit equality of sharded and single-device search."""

    @pytest.mark.parametrize("n,shards,k", [
        (101, 3, 7),    # ragged: 101 = 3*34 - 1
        (128, 4, 10),   # even split
        (97, 5, 3),     # ragged prime
        (64, 1, 16),    # degenerate single shard
    ])
    def test_bitwise_vs_single_device(self, n, shards, k):
        q, c = _qc(jax.random.PRNGKey(3), 6, n, 12)
        want = mips_topk(q, c, k, backend="chunked")
        got = sharded_mips_topk(q, stack_shards(c, shards), k, n_total=n,
                                backend="chunked")
        _assert_bitwise(got, want)

    @pytest.mark.parametrize("backend", ["chunked", "interpret"])
    def test_block_padding_rows_never_surface(self, backend):
        """Regression: a NON-last shard's internal zero-pad rows (added to
        round shard_size up to the chunk/block size) sit at valid global
        positions belonging to the NEXT shard, so the global-position mask
        alone cannot catch them — they score 0.0 against any query, which
        WINS whenever the true top-k scores are all negative. shard_size
        650 > default chunk/block 512 with 650 % 512 != 0 exercises
        exactly that layout."""
        kq, kc = jax.random.split(jax.random.PRNGKey(13))
        q = jnp.abs(jax.random.normal(kq, (3, 8), jnp.float32))
        c = -jnp.abs(jax.random.normal(kc, (1300, 8), jnp.float32))
        want = mips_topk(q, c, 5, backend="chunked")
        got = sharded_mips_topk(q, stack_shards(c, 2), 5, n_total=1300,
                                backend=backend)
        _assert_bitwise(got, want)
        # every true score is negative: any surfaced 0.0 is a padding row
        assert float(np.asarray(got[0]).max()) < 0.0

    def test_cross_shard_duplicate_tie_break(self):
        """Duplicated rows in DIFFERENT shards tie exactly; the merge must
        pick the lowest GLOBAL index, like lax.top_k's stable order."""
        # 90 rows over 3 shards of 30: dups straddle shard boundaries
        q, c = _qc(jax.random.PRNGKey(4), 5, 90, 8,
                   dup_rows=[(61, 2), (35, 2), (88, 40)])
        want = mips_topk(q, c, 6, backend="chunked")
        got = sharded_mips_topk(q, stack_shards(c, 3), 6, n_total=90,
                                backend="chunked")
        _assert_bitwise(got, want)
        # the duplicated top row's LOWEST global index must be the pick
        top_idx = np.asarray(got[1])
        assert (top_idx < 90).all()

    def test_sharded_index_drop_in(self):
        """ShardedCorpusIndex.search == CorpusIndex.search bit-for-bit,
        and it serves through QueryServer unchanged."""
        q, c = _qc(jax.random.PRNGKey(5), 8, 70, 16)
        c = l2_normalize(c)
        flat = CorpusIndex(c)
        sharded = ShardedCorpusIndex(c, 4)
        _assert_bitwise(sharded.search(q, 5, backend="chunked"),
                        flat.search(q, 5, backend="chunked"))
        srv = QueryServer(sharded, k=5, batch=8, backend="chunked").warmup()
        v, i = srv.query(l2_normalize(q))
        assert v.shape == (8, 5) and srv.stats()["queries"] == 8

    def test_one_device_mesh_shard_map(self):
        """The shard_map path on a 1-device corpus mesh matches the
        single-device search bitwise (the multi-device version of this
        assertion runs in tests/test_multihost.py)."""
        from repro.sharding import make_corpus_mesh
        q, c = _qc(jax.random.PRNGKey(6), 4, 33, 8)
        mesh = make_corpus_mesh(1)
        want = mips_topk(q, c, 3, backend="chunked")
        got = ShardedCorpusIndex(c, 1, mesh=mesh).search(
            q, 3, backend="chunked")
        _assert_bitwise(got, want)

    def test_validation(self):
        c = jnp.zeros((10, 4))
        with pytest.raises(ValueError, match="exceeds corpus size"):
            stack_shards(c, 11)
        with pytest.raises(ValueError, match="num_shards"):
            ShardedCorpusIndex(c, 0)
        with pytest.raises(ValueError, match="every shard"):
            # k > shard_size: a shard cannot emit k candidates
            sharded_mips_topk(jnp.zeros((2, 4)), stack_shards(c, 5), 3,
                              n_total=10)


class TestIVF:
    def _clustered(self, key, n, d, true_c, qn, qnoise=0.1):
        centers = l2_normalize(jax.random.normal(key, (true_c, d),
                                                 jnp.float32))
        per = -(-n // true_c)
        c = l2_normalize(
            jnp.repeat(centers, per, axis=0)[:n] + 0.2 * jax.random.normal(
                jax.random.fold_in(key, 1), (n, d), jnp.float32))
        qg = jax.random.randint(jax.random.fold_in(key, 2), (qn,), 0,
                                true_c)
        q = l2_normalize(centers[qg] + qnoise * jax.random.normal(
            jax.random.fold_in(key, 3), (qn, d), jnp.float32))
        return q, c

    @pytest.mark.parametrize("n,cc,k", [(257, 7, 10), (512, 16, 5)])
    def test_nprobe_full_recovers_exact(self, n, cc, k):
        """nprobe == num_centroids scans every list once: indices must
        match the exact search bit-for-bit (duplicated rows included —
        _select_topk's global-index tie-break), scores to f32 tolerance."""
        q, c = _qc(jax.random.PRNGKey(7), 9, n, 16,
                   dup_rows=[(5, n - 1), (17, n - 2)])
        c = l2_normalize(c)
        ivf = IVFIndex.from_index(CorpusIndex(c), num_centroids=cc,
                                  nprobe=cc, seed=1)
        ev, ei = mips_topk(q, c, k, backend="chunked")
        av, ai = ivf.search(q, k, nprobe=cc)
        np.testing.assert_array_equal(np.asarray(ai), np.asarray(ei))
        np.testing.assert_allclose(np.asarray(av), np.asarray(ev),
                                   atol=1e-6)

    def test_probe_chunking_invariant(self):
        """probe_chunk only re-tiles the gather; results are identical."""
        q, c = self._clustered(jax.random.PRNGKey(8), 300, 16, 10, 6)
        ivf = IVFIndex.from_index(CorpusIndex(c), num_centroids=10, seed=2)
        want = ivf.search(q, 5, nprobe=6, probe_chunk=6)
        for pc in (1, 2, 4):
            got = ivf.search(q, 5, nprobe=6, probe_chunk=pc)
            _assert_bitwise(got, want)

    def test_pruned_recall_on_clustered_corpus(self):
        q, c = self._clustered(jax.random.PRNGKey(9), 600, 16, 20, 16)
        ivf = IVFIndex.from_index(CorpusIndex(c), num_centroids=40,
                                  nprobe=4, seed=3)
        _, ei = mips_topk(q, c, 10, backend="chunked")
        _, ai = ivf.search(q, 10)
        recall = np.mean([
            len(set(np.asarray(ai)[i]) & set(np.asarray(ei)[i])) / 10
            for i in range(16)])
        assert recall >= 0.9

    def test_exact_fallbacks(self):
        q, c = _qc(jax.random.PRNGKey(10), 4, 100, 8)
        c = l2_normalize(c)
        ivf = IVFIndex.from_index(CorpusIndex(c), num_centroids=8, seed=4)
        want = mips_topk(q, c, 6, backend="chunked")
        # nprobe <= 0 forces the exact tier
        _assert_bitwise(ivf.search(q, 6, nprobe=0, backend="chunked"), want)
        # k exceeding the probed candidate slots falls back too
        k_big = ivf.list_len + 1
        want_big = mips_topk(q, c, k_big, backend="chunked")
        _assert_bitwise(ivf.search(q, k_big, nprobe=1, backend="chunked"),
                        want_big)

    def test_build_and_layout(self):
        q, c = self._clustered(jax.random.PRNGKey(11), 200, 8, 5, 3)
        ivf = IVFIndex.from_index(CorpusIndex(c), num_centroids=5,
                                  nprobe=2, list_pad=8, seed=5)
        assert ivf.lists_emb.shape == (5, ivf.list_len, 8)
        assert ivf.list_len % 8 == 0
        assert int(ivf.list_counts.sum()) == 200
        # padding slots carry the sentinel index
        idx = np.asarray(ivf.lists_idx)
        for ci, cnt in enumerate(ivf.list_counts):
            assert (idx[ci, cnt:] == BIG_IDX).all()
            assert (np.diff(idx[ci, :cnt]) > 0).all()   # ascending global
        with pytest.raises(ValueError, match="nprobe"):
            IVFIndex(c, ivf.centroids, nprobe=6)

    def test_train_centroids_normalized(self):
        _, c = self._clustered(jax.random.PRNGKey(12), 150, 8, 6, 1)
        cent = train_centroids(c, num_centroids=6, iters=4)
        np.testing.assert_allclose(
            np.asarray(jnp.linalg.norm(cent, axis=-1)), 1.0, atol=1e-5)


def _drift_setup(n=130, d_in=12, d=8, seed=0):
    """Two-group linear encoder: perturbing the first feature block's
    weights drifts only the first 64 items."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(d_in, d)), jnp.float32)
    feats = jnp.asarray(rng.normal(size=(n, d_in)), jnp.float32)
    feats = feats.at[:64, d_in // 2:].set(0.0).at[64:, :d_in // 2].set(0.0)
    w2 = w.at[:d_in // 2].add(0.5 * jnp.asarray(
        rng.normal(size=(d_in // 2, d)), jnp.float32))
    enc = lambda p, x: x @ p  # noqa: E731
    return enc, w, w2, feats


class TestRefresh:
    def test_refresh_targets_only_drifted_blocks(self):
        enc, w, w2, feats = _drift_setup()
        idx = CorpusIndex.build(enc, w, feats, chunk=32)
        emb0 = idx.embeddings
        stats = idx.refresh(enc, w2, feats, threshold=1e-3, block=16,
                            probes_per_block=4)
        full = encode_corpus_chunked(enc, w2, feats, chunk=32)
        # drifted half re-encoded, quiescent half bit-untouched
        np.testing.assert_allclose(np.asarray(idx.embeddings[:64]),
                                   np.asarray(full[:64]), atol=1e-6)
        np.testing.assert_array_equal(np.asarray(idx.embeddings[64:]),
                                      np.asarray(emb0[64:]))
        assert stats["blocks_refreshed"] == 4
        assert stats["items_encoded"] < 130  # cheaper than a rebuild

    def test_huge_threshold_is_noop(self):
        enc, w, w2, feats = _drift_setup()
        idx = CorpusIndex.build(enc, w, feats, chunk=32)
        emb0 = idx.embeddings
        stats = idx.refresh(enc, w2, feats, threshold=100.0, block=16)
        assert stats["blocks_refreshed"] == 0
        np.testing.assert_array_equal(np.asarray(idx.embeddings),
                                      np.asarray(emb0))

    def test_pad_items_do_not_refresh_tail(self):
        """The tail block pads with repeats of item 0; item 0 drifting
        must not drag the (quiescent) tail block into a re-encode."""
        enc, w, w2, feats = _drift_setup()   # n=130: tail block is padded
        emb0 = encode_corpus_chunked(enc, w, feats, chunk=32)
        _, stats = refresh_embeddings(enc, w2, feats, emb0, threshold=1e-3,
                                      block=16, probes_per_block=4)
        # 130 items / block 16 -> 9 blocks; only blocks 0-3 (items 0..63)
        # drifted — the padded tail block (items 128-129) stays quiescent
        assert float(stats["blocks_refreshed"]) == 4

    def test_sharded_refresh_in_place(self):
        enc, w, w2, feats = _drift_setup()
        emb0 = encode_corpus_chunked(enc, w, feats, chunk=32)
        sh = ShardedCorpusIndex(emb0, 4)
        sh.refresh(enc, w2, feats, threshold=1e-3, block=16)
        q = l2_normalize(jnp.asarray(
            np.random.default_rng(3).normal(size=(5, emb0.shape[1])),
            jnp.float32))
        full = encode_corpus_chunked(enc, w2, feats, chunk=32)
        _assert_bitwise(sh.search(q, 3, backend="chunked"),
                        CorpusIndex(full).search(q, 3, backend="chunked"))


class TestEngineStatefulEval:
    def _reval(self, enc, threshold=0.05):
        x = jax.random.normal(jax.random.PRNGKey(11), (40, 10), jnp.float32)
        labels = jnp.arange(40) % 4

        def embed(p, batch):
            return enc(p, batch["x"])

        return make_refreshing_retrieval_eval(
            embed, {"x": x[:32]}, labels[:32], {"x": x[32:]}, labels[32:],
            ks=(1, 5), chunk=16, threshold=threshold, block=8,
            probes_per_block=2)

    def test_engine_threads_refresh_state(self):
        eng, params, opt_state, enc = _toy_engine()
        eng, params, opt_state, enc = _toy_engine(
            retrieval_eval=self._reval(enc))
        _, _, m = eng.run(params, opt_state, jax.random.PRNGKey(0), 4)
        assert {"recall_at_1", "recall_at_5", "mrr", "refresh_fraction",
                "items_encoded"} <= set(m.retrieval)
        frac = np.asarray(m.retrieval["refresh_fraction"])
        assert frac.shape == (4,)
        # cadence 2: rounds 0 and 2 evaluated, 1 and 3 NaN-skipped
        assert not np.isnan(frac[[0, 2]]).any()
        assert np.isnan(frac[[1, 3]]).all()

    def test_stateful_eval_does_not_perturb_training(self):
        eng0, params, opt_state, enc = _toy_engine()
        p0, _, _ = eng0.run(params, opt_state, jax.random.PRNGKey(0), 4)
        eng1, params, opt_state, enc = _toy_engine()
        eng1, params, opt_state, enc = _toy_engine(
            retrieval_eval=self._reval(enc))
        p1, _, _ = eng1.run(params, opt_state, jax.random.PRNGKey(0), 4)
        for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_quiescent_params_refresh_nothing(self):
        """With unchanged params the drift probe finds nothing: the second
        eval's refresh_fraction is exactly 0."""
        _, _, _, enc = _toy_engine()
        ev = self._reval(enc)
        w = {"w1": jax.random.normal(jax.random.PRNGKey(0), (10, 16)) * 0.3,
             "w2": jax.random.normal(jax.random.PRNGKey(7), (16, 6)) * 0.3}
        state = ev.init_state(w)
        m, state = jax.jit(ev)(w, state)
        m2, _ = jax.jit(ev)(w, state)
        assert float(m2["refresh_fraction"]) == 0.0

    def test_stateful_validation(self):
        bad = lambda p, s: ({}, s)  # noqa: E731
        bad.stateful = True         # but no init_state
        with pytest.raises(ValueError, match="init_state"):
            _toy_engine(retrieval_eval=bad)


class TestServerSatellites:
    def test_query_dim_mismatch_raises(self):
        idx = CorpusIndex(l2_normalize(jax.random.normal(
            jax.random.PRNGKey(0), (32, 16), jnp.float32)))
        srv = QueryServer(idx, k=3, batch=4, backend="chunked")
        with pytest.raises(ValueError, match="embedding dim"):
            srv.query(jnp.zeros((2, 8)))
        with pytest.raises(ValueError, match="embedding dim"):
            srv.query(jnp.zeros((2, 16, 1)))

    def test_stats_report_wall_clock_and_serial_qps(self):
        idx = CorpusIndex(l2_normalize(jax.random.normal(
            jax.random.PRNGKey(0), (64, 8), jnp.float32)))
        srv = QueryServer(idx, k=2, batch=4, backend="chunked").warmup()
        import time
        for _ in range(3):
            srv.query(jnp.zeros((4, 8)))
            time.sleep(0.01)        # think time: wall-clock qps < serial
        s = srv.stats()
        assert s["qps"] > 0 and s["qps_serial"] > 0
        # serial excludes the sleeps, wall-clock includes two of them
        assert s["qps"] < s["qps_serial"]
        assert s["queries"] == 12 and s["batches"] == 3
