"""Properties of the CCO loss and the five encoding statistics (paper Eq. 1-3)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import cco

SET = settings(max_examples=25, deadline=None)


def _rand(key, n, d):
    return jax.random.normal(key, (n, d), jnp.float32)


class TestStatsLinearity:
    """The paper's central insight: batch statistics are exactly weighted
    averages of per-client statistics (Eq. 3)."""

    @SET
    @given(clients=st.integers(2, 6), n_per=st.integers(1, 5),
           d=st.integers(2, 16), seed=st.integers(0, 2**16))
    def test_aggregate_equals_global(self, clients, n_per, d, seed):
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        zf = _rand(k1, clients * n_per, d)
        zg = _rand(k2, clients * n_per, d)
        st_global = cco.encoding_stats(zf, zg)
        st_k = cco.per_client_stats(zf, zg, clients)
        agg = cco.weighted_average_stats(st_k, jnp.full((clients,), n_per, jnp.float32))
        for k in cco.STAT_KEYS:
            np.testing.assert_allclose(agg[k], st_global[k], rtol=2e-5, atol=2e-6)

    @SET
    @given(seed=st.integers(0, 2**16))
    def test_variable_sizes(self, seed):
        """Weighted averaging with unequal N_k == masked global stats."""
        key = jax.random.PRNGKey(seed)
        k1, k2, k3 = jax.random.split(key, 3)
        clients, n_pad, d = 4, 5, 8
        sizes = jax.random.randint(k3, (clients,), 1, n_pad + 1)
        zf = _rand(k1, clients * n_pad, d)
        zg = _rand(k2, clients * n_pad, d)
        mask = (jnp.arange(n_pad)[None, :] < sizes[:, None]).astype(jnp.float32)
        st_k = jax.vmap(cco.encoding_stats_masked)(
            zf.reshape(clients, n_pad, d), zg.reshape(clients, n_pad, d), mask)
        agg = cco.weighted_average_stats(st_k, sizes.astype(jnp.float32))
        st_global = cco.encoding_stats_masked(zf, zg, mask.reshape(-1))
        for k in cco.STAT_KEYS:
            np.testing.assert_allclose(agg[k], st_global[k], rtol=2e-5, atol=2e-6)


class TestCorrelation:
    def test_bounds(self, rng_key):
        zf = _rand(rng_key, 64, 12)
        zg = _rand(jax.random.PRNGKey(9), 64, 12)
        c = cco.correlation_matrix(cco.encoding_stats(zf, zg))
        assert jnp.all(jnp.abs(c) <= 1.0 + 1e-4)

    def test_perfect_correlation_zero_on_diagonal_loss(self, rng_key):
        z = _rand(rng_key, 256, 8)
        c = cco.correlation_matrix(cco.encoding_stats(z, z))
        np.testing.assert_allclose(np.diag(np.asarray(c)), 1.0, atol=1e-4)

    def test_loss_minimized_by_identity_correlation(self, rng_key):
        """Loss ~0 when F == G and dimensions are decorrelated."""
        n, d = 4096, 4
        z = _rand(rng_key, n, d)
        # decorrelate via PCA whitening
        zc = z - z.mean(0)
        u, s, vt = jnp.linalg.svd(zc, full_matrices=False)
        zw = u * jnp.sqrt(n)
        loss = cco.cco_loss(zw, zw, lam=20.0)
        assert float(loss) < 1e-2

    def test_collapse_has_high_loss(self):
        """A constant encoder (collapse) keeps the on-diagonal term ~d."""
        z = jnp.ones((32, 8)) + 1e-3 * jax.random.normal(jax.random.PRNGKey(0), (32, 8))
        loss = cco.cco_loss(z, z, lam=20.0)
        assert float(loss) > 1.0


class TestDccoCombine:
    def test_value_equals_aggregate(self, rng_key):
        k1, k2 = jax.random.split(rng_key)
        zf, zg = _rand(k1, 12, 6), _rand(k2, 12, 6)
        st_k = cco.per_client_stats(zf, zg, 3)
        agg = cco.weighted_average_stats(st_k, jnp.ones((3,)))
        local0 = jax.tree.map(lambda x: x[0], st_k)
        comb = cco.dcco_combine(local0, agg)
        for k in cco.STAT_KEYS:
            np.testing.assert_allclose(comb[k], agg[k], rtol=1e-5, atol=1e-7)

    def test_gradient_flows_through_local_only(self, rng_key):
        """d combined / d local == I; d combined / d agg == 0 (Eq. 4-5)."""
        local = {"mean_f": jnp.array([1.0, 2.0])}
        agg = {"mean_f": jnp.array([5.0, 5.0])}
        g_local = jax.grad(
            lambda l: cco.dcco_combine(l, agg)["mean_f"].sum())(local)
        np.testing.assert_allclose(g_local["mean_f"], 1.0)
        g_agg = jax.grad(
            lambda a: cco.dcco_combine(local, a)["mean_f"].sum())(agg)
        np.testing.assert_allclose(g_agg["mean_f"], 0.0)

    def test_lambda_normalization(self, rng_key):
        """The 1/(d-1) factor keeps off-diag term scale-free in d (footnote 2)."""
        losses = []
        for d in (4, 16):
            zf = _rand(rng_key, 128, d)
            zg = zf + 0.1 * _rand(jax.random.PRNGKey(d), 128, d)
            st = cco.encoding_stats(zf, zg)
            c = cco.correlation_matrix(st)
            off = (jnp.sum(c * c) - jnp.sum(jnp.diag(c) ** 2)) / (d - 1)
            losses.append(float(off) / d)
        # per-dimension off-diagonal penalty should be same order of magnitude
        assert 0.1 < losses[0] / losses[1] < 10.0
