"""Mixed-precision numerics contract (docs/performance.md).

Two halves, both pinned here:
  * the encoder forward/backward may run in bf16
    (``EngineConfig.compute_dtype`` / ``cast_encoder_apply``), and
  * the Eq.-3 statistics ACCUMULATE in f32 regardless — bf16 encodings
    feed f32 sums (``cco.moment_stats`` casts before reducing), so
    bf16-compute stats differ from f32-compute stats only by bf16
    *rounding of the encodings*, never by accumulation error.
"""
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro import utils
from repro.core import cco, round_engine
from repro.optim import optimizers as opt_lib

SET = settings(max_examples=20, deadline=None)


def _encodings(seed, n, d):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(k1, (n, d)), jax.random.normal(k2, (n, d)))


class TestStatsAccumulationDtype:
    @given(seed=st.integers(0, 2**16), n=st.integers(2, 64),
           second=st.booleans())
    @SET
    def test_bf16_inputs_accumulate_f32_and_track_f32_stats(self, seed, n,
                                                            second):
        """Property: for bf16 encodings, every stat leaf is f32 and within
        bf16-rounding tolerance of the f32-input stats. n spans a range so
        a (hypothetical) low-precision accumulator would drift with n; the
        tolerance does not."""
        zf, zg = _encodings(seed, n, 8)
        st32 = cco.moment_stats(zf, zg, second_moments=second)
        st16 = cco.moment_stats(zf.astype(jnp.bfloat16),
                                zg.astype(jnp.bfloat16),
                                second_moments=second)
        for k, v in st16.items():
            assert v.dtype == jnp.float32, (k, v.dtype)
            # bf16 has an 8-bit mantissa: inputs carry ~2^-8 relative
            # rounding; sums of n of them keep that RELATIVE error (f32
            # accumulator), so a scale-aware bound is tight and n-free
            scale = jnp.max(jnp.abs(st32[k])) + 1.0
            assert float(jnp.max(jnp.abs(v - st32[k]))) < 0.02 * float(scale), k

    def test_f32_inputs_untouched(self):
        zf, zg = _encodings(0, 16, 8)
        st = cco.moment_stats(zf, zg)
        assert all(v.dtype == jnp.float32 for v in st.values())


class TestCastEncoderApply:
    def _apply(self):
        k = jax.random.PRNGKey(0)
        params = {"w1": jax.random.normal(k, (10, 16)) * 0.3,
                  "w2": jax.random.normal(jax.random.PRNGKey(7), (16, 6)) * 0.3}

        def apply(p, batch):
            enc = lambda x: jnp.tanh(x @ p["w1"]) @ p["w2"]  # noqa: E731
            return enc(batch["v1"]), enc(batch["v2"])

        k1, k2 = jax.random.split(k)
        batch = {"v1": jax.random.normal(k1, (4, 10)),
                 "v2": jax.random.normal(k2, (4, 10))}
        return apply, params, batch

    def test_f32_is_identity(self):
        apply, params, batch = self._apply()
        assert round_engine.cast_encoder_apply(apply, "float32") is apply
        assert round_engine.cast_encoder_apply(apply, "f32") is apply

    def test_bf16_outputs_bf16_params_untouched(self):
        apply, params, batch = self._apply()
        wrapped = round_engine.cast_encoder_apply(apply, "bfloat16")
        zf, zg = wrapped(params, batch)
        assert zf.dtype == jnp.bfloat16 and zg.dtype == jnp.bfloat16
        # the wrap casts at the call boundary; the master params it was
        # handed stay f32 (server state is f32 by contract)
        assert all(v.dtype == jnp.float32 for v in params.values())
        zf32, zg32 = apply(params, batch)
        assert float(jnp.max(jnp.abs(zf.astype(jnp.float32) - zf32))) < 0.05

    def test_unknown_dtype_rejected(self):
        with pytest.raises(ValueError, match="compute_dtype"):
            round_engine.resolve_compute_dtype("float16")


class TestEngineBf16:
    def test_engine_bf16_round_trains_finite_with_f32_state(self):
        """End-to-end: the scan engine at compute_dtype='bfloat16' trains,
        metrics stay finite, and params/opt state remain f32 (only the
        encoder call is demoted). Loss tracks the f32 engine loosely —
        same trajectory up to bf16 encoder rounding."""
        apply, params, batch0 = self._setup()

        def sampler(k_sel, k_aug):
            k1, k2 = jax.random.split(k_sel)
            data = {"v1": jax.random.normal(k1, (8, 3, 10)),
                    "v2": jax.random.normal(k2, (8, 3, 10))}
            return data, jnp.full((8,), 3, jnp.int32)

        opt = opt_lib.sgd(0.1)
        runs = {}
        for tag in ("float32", "bfloat16"):
            cfg = round_engine.EngineConfig(algorithm="dcco", lam=5.0,
                                            chunk_rounds=3, compute_dtype=tag)
            eng = round_engine.RoundEngine(apply, opt, sampler, cfg)
            p, s, m = eng.run(params, opt.init(params), jax.random.PRNGKey(3),
                              6)
            assert bool(jnp.isfinite(m.loss).all()), tag
            assert all(v.dtype == jnp.float32
                       for v in jax.tree.leaves(p)), tag
            runs[tag] = (p, m)
        diff = utils.tree_max_abs_diff(runs["float32"][0],
                                       runs["bfloat16"][0])
        assert 0.0 < float(diff) < 0.1  # differs (bf16 bites), but tracks

    def _setup(self):
        k = jax.random.PRNGKey(0)
        params = {"w1": jax.random.normal(k, (10, 16)) * 0.3,
                  "w2": jax.random.normal(jax.random.PRNGKey(7), (16, 6)) * 0.3}

        def apply(p, batch):
            enc = lambda x: jnp.tanh(x @ p["w1"]) @ p["w2"]  # noqa: E731
            return enc(batch["v1"]), enc(batch["v2"])

        return apply, params, None
