"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.cco_stats import cco_stats_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.segment_sum import segment_sum_pallas


class TestCcoStatsKernel:
    @pytest.mark.parametrize("n,d", [(64, 128), (512, 256), (300, 200),
                                     (1000, 384), (128, 512), (9, 64)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, n, d, dtype, rng_key):
        k1, k2 = jax.random.split(rng_key)
        zf = jax.random.normal(k1, (n, d), jnp.float32).astype(dtype)
        zg = jax.random.normal(k2, (n, d), jnp.float32).astype(dtype)
        out = cco_stats_pallas(zf, zg, block_n=128, block_d=128, interpret=True)
        expected = ref.cco_stats_ref(zf, zg)
        tol = 1e-4 if dtype == jnp.float32 else 3e-2
        for k in expected:
            np.testing.assert_allclose(np.asarray(out[k]), np.asarray(expected[k]),
                                       rtol=tol, atol=tol)

    @pytest.mark.parametrize("bn,bd", [(128, 128), (256, 256), (512, 128)])
    def test_block_shape_invariance(self, bn, bd, rng_key):
        zf = jax.random.normal(rng_key, (384, 256), jnp.float32)
        zg = jax.random.normal(jax.random.PRNGKey(3), (384, 256), jnp.float32)
        out = cco_stats_pallas(zf, zg, block_n=bn, block_d=bd, interpret=True)
        expected = ref.cco_stats_ref(zf, zg)
        for k in expected:
            np.testing.assert_allclose(np.asarray(out[k]), np.asarray(expected[k]),
                                       rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("n,d", [(64, 128), (300, 200), (9, 64)])
    def test_full_moment_set_matches_ref(self, n, d, rng_key):
        """moments="full" (the VICReg/W-MSE moment set): the two
        within-view second moments match the oracle and the five shared
        statistics are bit-identical to the default "cross" kernel."""
        k1, k2 = jax.random.split(rng_key)
        zf = jax.random.normal(k1, (n, d), jnp.float32)
        zg = jax.random.normal(k2, (n, d), jnp.float32)
        out5 = cco_stats_pallas(zf, zg, block_n=128, block_d=128,
                                interpret=True)
        out7 = cco_stats_pallas(zf, zg, block_n=128, block_d=128,
                                interpret=True, moments="full")
        expected = ref.cco_stats_ref(zf, zg, second_moments=True)
        assert set(out7) == set(expected)
        for k in out5:
            np.testing.assert_array_equal(np.asarray(out5[k]),
                                          np.asarray(out7[k]))
        for k in expected:
            np.testing.assert_allclose(np.asarray(out7[k]),
                                       np.asarray(expected[k]),
                                       rtol=1e-4, atol=1e-4)

    def test_unknown_moment_set_rejected(self, rng_key):
        z = jax.random.normal(rng_key, (8, 8), jnp.float32)
        with pytest.raises(ValueError):
            cco_stats_pallas(z, z, interpret=True, moments="diag")

    def test_feeds_cco_loss(self, rng_key):
        """End-to-end: kernel statistics -> identical CCO loss value."""
        from repro.core import cco
        zf = jax.random.normal(rng_key, (256, 128), jnp.float32)
        zg = zf + 0.1 * jax.random.normal(jax.random.PRNGKey(5), (256, 128))
        st_kernel = cco_stats_pallas(zf, zg, interpret=True)
        l1 = cco.cco_loss_from_stats(st_kernel, 20.0)
        l2 = cco.cco_loss(zf, zg, 20.0)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)


class TestSegmentSumKernel:
    """The hierarchy's client->edge fold (kernels/segment_sum.py) vs the
    jax.ops.segment_sum oracle — random ids, weighted rows, ragged shapes
    that exercise the internal padding."""

    @pytest.mark.parametrize("k,d,e", [(64, 128, 8), (300, 96, 5),
                                       (37, 13, 3), (8, 1, 8), (512, 256, 2)])
    def test_matches_ref(self, k, d, e, rng_key):
        k1, k2, k3 = jax.random.split(rng_key, 3)
        rows = jax.random.normal(k1, (k, d), jnp.float32)
        ids = jax.random.randint(k2, (k,), 0, e)
        w = jax.random.uniform(k3, (k,), jnp.float32)
        out = segment_sum_pallas(rows, ids, e, w, interpret=True)
        expected = ref.segment_sum_ref(rows, ids, e, w)
        assert out.shape == (e, d)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                                   rtol=1e-5, atol=1e-5)

    def test_unweighted_and_empty_segments(self, rng_key):
        rows = jax.random.normal(rng_key, (40, 24), jnp.float32)
        ids = jnp.minimum(jnp.arange(40, dtype=jnp.int32) // 10, 2)
        out = segment_sum_pallas(rows, ids, 6, interpret=True)
        expected = ref.segment_sum_ref(rows, ids, 6)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                                   rtol=1e-5, atol=1e-5)
        # segments 3..5 receive no rows and must be exactly zero
        assert float(jnp.abs(out[3:]).max()) == 0.0

    @pytest.mark.parametrize("bk,bd", [(16, 8), (128, 128), (512, 64)])
    def test_block_shape_invariance(self, bk, bd, rng_key):
        k1, k2 = jax.random.split(rng_key)
        rows = jax.random.normal(k1, (200, 48), jnp.float32)
        ids = jax.random.randint(k2, (200,), 0, 7)
        out = segment_sum_pallas(rows, ids, 7, block_k=bk, block_d=bd,
                                 interpret=True)
        expected = ref.segment_sum_ref(rows, ids, 7)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                                   rtol=1e-5, atol=1e-5)

    def test_contiguous_fold_equals_reshape_sum(self, rng_key):
        """The hierarchy's layout: contiguous equal edges — the fold is a
        reshape-sum, the kernel must agree."""
        rows = jax.random.normal(rng_key, (64, 32), jnp.float32)
        ids = jnp.arange(64, dtype=jnp.int32) // 16
        out = segment_sum_pallas(rows, ids, 4, interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(rows.reshape(4, 16, 32).sum(1)),
                                   rtol=1e-5, atol=1e-5)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("b,h,kvh,sq,skv,dh", [
        (2, 4, 2, 128, 128, 64),
        (1, 8, 8, 256, 256, 32),
        (2, 4, 1, 64, 256, 64),     # GQA 4:1, chunked-prefill style
        (1, 2, 2, 128, 128, 128),
        (1, 16, 4, 64, 64, 64),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, b, h, kvh, sq, skv, dh, dtype, rng_key):
        ks = jax.random.split(rng_key, 3)
        q = jax.random.normal(ks[0], (b, h, sq, dh), jnp.float32).astype(dtype)
        k = jax.random.normal(ks[1], (b, kvh, skv, dh), jnp.float32).astype(dtype)
        v = jax.random.normal(ks[2], (b, kvh, skv, dh), jnp.float32).astype(dtype)
        o = flash_attention_pallas(q, k, v, causal=True, block_q=64,
                                   block_kv=64, interpret=True)
        expected = ref.flash_attention_ref(q, k, v, causal=True)
        tol = 2e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(expected, np.float32),
                                   rtol=tol, atol=tol)

    @pytest.mark.parametrize("window", [32, 96])
    def test_sliding_window(self, window, rng_key):
        ks = jax.random.split(rng_key, 3)
        q = jax.random.normal(ks[0], (1, 4, 256, 64))
        k = jax.random.normal(ks[1], (1, 4, 256, 64))
        v = jax.random.normal(ks[2], (1, 4, 256, 64))
        o = flash_attention_pallas(q, k, v, causal=True, window=window,
                                   block_q=64, block_kv=64, interpret=True)
        expected = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(o), np.asarray(expected),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("bq,bkv", [(32, 32), (64, 128), (128, 64)])
    def test_block_shape_invariance(self, bq, bkv, rng_key):
        ks = jax.random.split(rng_key, 3)
        q = jax.random.normal(ks[0], (1, 2, 128, 64))
        k = jax.random.normal(ks[1], (1, 2, 128, 64))
        v = jax.random.normal(ks[2], (1, 2, 128, 64))
        o = flash_attention_pallas(q, k, v, causal=True, block_q=bq,
                                   block_kv=bkv, interpret=True)
        expected = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(o), np.asarray(expected),
                                   rtol=2e-5, atol=2e-5)

    def test_non_causal(self, rng_key):
        ks = jax.random.split(rng_key, 3)
        q = jax.random.normal(ks[0], (1, 2, 64, 32))
        k = jax.random.normal(ks[1], (1, 2, 64, 32))
        v = jax.random.normal(ks[2], (1, 2, 64, 32))
        o = flash_attention_pallas(q, k, v, causal=False, block_q=32,
                                   block_kv=32, interpret=True)
        expected = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(o), np.asarray(expected),
                                   rtol=2e-5, atol=2e-5)
