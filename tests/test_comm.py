"""Communication subsystem (repro.comm):

  * quantize->dequantize round-trip error is bounded by one quantization
    step and the encoding is ``bits``-wide (property test);
  * the Pallas quantize-dequantize kernel is bit-identical to the jnp
    formula given the same uniforms;
  * DenseChannel and DropoutChannel(p=0) are bit-identical to the existing
    un-channeled ``dcco_round`` — eagerly and through the scan-compiled
    engine;
  * DropoutChannel renormalizes aggregation weights over survivors only;
  * DPGaussianChannel clips per-client payloads, noises the stats
    aggregate, and its zCDP accountant composes across rounds;
  * wire-bytes accounting matches the static payload sizes;
  * engine guards: channel + flat-stats kernel, channel + centralized.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm, utils
from repro.comm.quantize import qmax_for_bits
from repro.core import cco, fed_sim, round_engine
from repro.optim import optimizers as opt_lib

from tests._hypothesis_compat import given, settings, st

LAM = 5.0
F32 = jnp.float32


@pytest.fixture(scope="module")
def toy():
    key = jax.random.PRNGKey(0)
    params = {"w1": jax.random.normal(key, (10, 16)) * 0.3,
              "w2": jax.random.normal(jax.random.PRNGKey(7), (16, 6)) * 0.3}

    def apply(p, batch):
        def enc(x):
            return jnp.tanh(x @ p["w1"]) @ p["w2"]
        return enc(batch["v1"]), enc(batch["v2"])

    k1, k2 = jax.random.split(key)
    data = {"v1": jax.random.normal(k1, (8, 3, 10)),
            "v2": jax.random.normal(k2, (8, 3, 10))}
    sizes = jnp.array([3, 1, 2, 3, 3, 2, 1, 3], jnp.int32)
    return params, apply, data, sizes


def _sampler_from(data, sizes):
    def sampler(k_sel, k_aug):
        return data, sizes
    return sampler


# ---------------------------------------------------------------------------
# quantization
# ---------------------------------------------------------------------------

class TestQuantize:
    @settings(deadline=None, max_examples=25)
    @given(n=st.integers(1, 48), d=st.integers(1, 24),
           bits=st.sampled_from([8, 4, 6]), seed=st.integers(0, 2 ** 20),
           magnitude=st.floats(min_value=0.01, max_value=100.0))
    def test_roundtrip_error_within_one_step(self, n, d, bits, seed,
                                             magnitude):
        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(key, (n, d)) * magnitude
        q, scale = comm.quantize(jax.random.fold_in(key, 1), x, bits)
        err = jnp.max(jnp.abs(comm.dequantize(q, scale) - x))
        # stochastic rounding moves a value by < 1 code; clipping at the
        # amax-calibrated edges cannot exceed that
        assert float(err) <= float(scale) * (1 + 1e-5)
        qmax = qmax_for_bits(bits)
        assert float(jnp.max(jnp.abs(q.astype(F32)))) <= qmax

    def test_int8_dtype_and_zero_payload(self):
        key = jax.random.PRNGKey(0)
        q, scale = comm.quantize(key, jnp.ones((4, 4)), 8)
        assert q.dtype == jnp.int8
        q0, s0 = comm.quantize(key, jnp.zeros((4, 4)), 8)
        np.testing.assert_array_equal(np.asarray(q0), 0)
        assert np.isfinite(float(s0))

    def test_kernel_matches_jnp_bitwise(self):
        xk = jax.random.normal(jax.random.PRNGKey(3), (5, 3, 7)) * 2.0
        key = jax.random.PRNGKey(4)
        ref = comm.quant_dequant_clients(key, xk, 8, impl="jnp")
        ker = comm.quant_dequant_clients(key, xk, 8, impl="interpret")
        assert utils.tree_max_abs_diff(ref, ker) == 0.0

    def test_stochastic_rounding_is_unbiased(self):
        x = jnp.full((2000,), 0.3)
        outs = jnp.stack([comm.quant_dequant(jax.random.PRNGKey(i), x, 8)
                          for i in range(4)])
        # mean over many draws converges to x (floor(v+u) is unbiased)
        assert float(jnp.abs(outs.mean() - 0.3)) < 2e-3

    def test_bad_bits_rejected(self):
        with pytest.raises(ValueError):
            comm.QuantizedChannel(bits=1)
        with pytest.raises(ValueError):
            comm.QuantizedChannel(bits=8, kernel="nope")


class TestFusedPayload:
    """quant_dequant_payload — the fused whole-payload path behind
    QuantizedChannel (one PRNG draw + one kernel pass over the
    concatenated leaves, replacing the per-leaf loop that made int8
    rounds slower than dense pre-fusion)."""

    def _payload(self, k=5):
        ks = jax.random.split(jax.random.PRNGKey(11), 3)
        # deliberately mixed magnitudes and ranks: per-leaf scales matter
        return {"mean_f": jax.random.normal(ks[0], (k, 6)) * 0.01,
                "cross": jax.random.normal(ks[1], (k, 6, 6)) * 50.0,
                "sq_g": jax.random.normal(ks[2], (k, 6)) ** 2}

    def test_jnp_and_interpret_bit_identical(self):
        tree = self._payload()
        key = jax.random.PRNGKey(4)
        ref = comm.quant_dequant_payload(key, tree, 8, impl="jnp")
        ker = comm.quant_dequant_payload(key, tree, 8, impl="interpret")
        assert utils.tree_max_abs_diff(ref, ker) == 0.0

    def test_per_leaf_per_client_scales_preserved(self):
        """Wire semantics: each (client, tensor) pair gets its own amax
        scale. The 0.01-magnitude leaf must roundtrip with 0.01-magnitude
        error even though it is fused with a 50-magnitude leaf — a shared
        scale would blow its error up by ~5000x."""
        tree = self._payload()
        out = comm.quant_dequant_payload(jax.random.PRNGKey(4), tree, 8)
        qmax = qmax_for_bits(8)
        for name, leaf in tree.items():
            k = leaf.shape[0]
            amax = jnp.max(jnp.abs(leaf.reshape(k, -1)), axis=1)
            step = jnp.where(amax > 0, amax, 1.0) / qmax  # per-client scale
            err = jnp.max(jnp.abs((out[name] - leaf).reshape(k, -1)), axis=1)
            assert bool(jnp.all(err <= step * (1 + 1e-5))), name

    def test_matches_per_leaf_quantization_statistics(self):
        """The fused path's PRNG layout differs from per-leaf
        quant_dequant_clients, so outputs differ draw-by-draw — but both
        are unbiased one-step-error quantizers, so their means agree."""
        k = 4
        x = {"a": jnp.full((k, 2000), 0.3)}
        fused = comm.quant_dequant_payload(jax.random.PRNGKey(0), x, 8)
        assert float(jnp.abs(fused["a"].mean() - 0.3)) < 2e-3

    def test_empty_and_single_leaf(self):
        assert comm.quant_dequant_payload(jax.random.PRNGKey(0), {}, 8) == {}
        one = {"a": jax.random.normal(jax.random.PRNGKey(1), (3, 5))}
        out = comm.quant_dequant_payload(jax.random.PRNGKey(2), one, 8)
        assert out["a"].shape == (3, 5)


class TestCommRoundCostRegression:
    def test_int8_round_never_costs_more_than_dense(self):
        """Pin the PR-8 fix via the simulated cost model (machine-portable,
        unlike wall-clock): on the bench payload shape, quantize compute
        plus the int8 wire must undercut the dense wire. Pre-fix this held
        analytically but NOT in the measured bench (per-leaf threefry
        compile/dispatch swamped the wire saving) — compare.py gates the
        measured ratio; this test gates the model itself."""
        from benchmarks import costmodel
        k, n = 16, 55_296  # clients x payload elems, the comm_round shape
        dense_s = costmodel.comm_round_cost(n, bits=32)["wire_s"]
        for bits in (8, 4):
            q = costmodel.quantize_cost(k, n, bits=bits)
            compute_s = q.roofline()["step_s_lower_bound"]
            wire_s = costmodel.comm_round_cost(n, bits=bits)["wire_s"]
            assert compute_s + wire_s < dense_s, bits
            # and the wire itself shrinks by ~32/bits (header aside)
            assert wire_s < dense_s * (bits / 32) * 1.01, bits


# ---------------------------------------------------------------------------
# channel semantics
# ---------------------------------------------------------------------------

class TestChannelAggregation:
    @pytest.mark.slow
    def test_dense_and_dropout0_bit_identical_to_unchanneled(self, toy):
        params, apply, data, sizes = toy
        opt = opt_lib.adam(1e-2)
        p0, s0, m0 = fed_sim.dcco_round(apply, params, opt.init(params), opt,
                                        data, sizes, lam=LAM)
        for ch in (comm.DenseChannel(), comm.DropoutChannel(0.0)):
            p1, s1, m1 = fed_sim.dcco_round(
                apply, params, opt.init(params), opt, data, sizes, lam=LAM,
                channel=ch, channel_key=jax.random.PRNGKey(42))
            assert utils.tree_max_abs_diff(p0, p1) == 0.0
            assert float(m0.loss) == float(m1.loss)
            assert float(m0.encoding_std) == float(m1.encoding_std)

    def test_dense_aggregate_equals_weighted_average_stats(self, toy):
        _, _, _, sizes = toy
        st_k = {"a": jax.random.normal(jax.random.PRNGKey(0), (8, 5)),
                "b": jax.random.normal(jax.random.PRNGKey(1), (8, 3, 3))}
        ch = comm.DenseChannel()
        ctx = ch.begin_round(jax.random.PRNGKey(2), sizes)
        agg = ch.aggregate(ctx, st_k, "stats")
        ref = cco.weighted_average_stats(st_k, sizes.astype(F32))
        assert utils.tree_max_abs_diff(agg, ref) == 0.0

    def test_dropout_renormalizes_over_survivors(self, toy):
        _, _, _, sizes = toy
        ch = comm.DropoutChannel(0.5)
        ctx = ch.begin_round(jax.random.PRNGKey(5), sizes)
        mask = np.asarray(ctx.mask)
        assert 0 < mask.sum() < len(mask)          # some but not all dropped
        st_k = {"a": jax.random.normal(jax.random.PRNGKey(0), (8, 5))}
        agg = ch.aggregate(ctx, st_k, "stats")
        s = np.asarray(sizes, np.float32) * mask
        ref = (s / s.sum()) @ np.asarray(st_k["a"])
        np.testing.assert_allclose(np.asarray(agg["a"]), ref, rtol=1e-6)
        # weights of dropped clients are exactly zero
        assert np.all(np.asarray(ctx.weights)[mask == 0] == 0.0)

    def test_dp_clips_and_noises_stats_only(self, toy):
        _, _, _, sizes = toy
        # sigma=0: pure clipped uniform mean, deterministic
        ch = comm.DPGaussianChannel(0.0, clip_norm=1e9)
        ctx = ch.begin_round(jax.random.PRNGKey(0), sizes)
        st_k = {"a": jax.random.normal(jax.random.PRNGKey(1), (8, 5))}
        agg = ch.aggregate(ctx, st_k, "stats")
        np.testing.assert_allclose(np.asarray(agg["a"]),
                                   np.asarray(st_k["a"]).mean(0), rtol=1e-5)
        # tight clip bounds every client's joint payload norm
        tight = comm.DPGaussianChannel(0.0, clip_norm=0.1)
        clipped = tight.encode_decode(ctx, st_k, "stats")
        norms = np.linalg.norm(
            np.asarray(clipped["a"]).reshape(8, -1), axis=1)
        assert np.all(norms <= 0.1 * (1 + 1e-5))
        # noise hits the stats phase, not the update phase
        noisy = comm.DPGaussianChannel(1.0, clip_norm=1.0)
        nctx = noisy.begin_round(jax.random.PRNGKey(2), sizes)
        zeros = {"a": jnp.zeros((8, 5))}
        agg_stats = noisy.aggregate(nctx, zeros, "stats")
        agg_upd = noisy.aggregate(nctx, zeros, "update")
        assert float(jnp.max(jnp.abs(agg_stats["a"]))) > 0.0
        assert float(jnp.max(jnp.abs(agg_upd["a"]))) == 0.0

    def test_dp_accountant_composition(self):
        acct = comm.GaussianAccountant(noise_multiplier=1.0, delta=1e-5)
        assert acct.epsilon() == 0.0
        acct.step(100)
        rho = 100 / 2.0
        assert acct.rho == pytest.approx(rho)
        assert acct.epsilon() == pytest.approx(
            rho + 2 * np.sqrt(rho * np.log(1e5)))
        eps_100 = acct.epsilon()
        acct.step(100)
        assert acct.epsilon() > eps_100       # epsilon grows with rounds
        assert comm.GaussianAccountant(0.0).epsilon() == np.inf

    def test_wire_bytes_accounting(self, toy):
        _, _, _, sizes = toy
        tmpl = {"v": jnp.zeros((6,)), "c": jnp.zeros((6, 6))}
        dense = comm.DenseChannel()
        assert dense.payload_bytes(tmpl) == 42 * 4
        q8 = comm.QuantizedChannel(8)
        assert q8.payload_bytes(tmpl) == 42 + 2 * 4
        q4 = comm.QuantizedChannel(4)
        assert q4.payload_bytes(tmpl) == 21 + 2 * 4
        ctx = dense.begin_round(jax.random.PRNGKey(0), sizes)
        assert float(dense.round_bytes(ctx, tmpl)) == 8 * 42 * 4

    def test_get_channel_factory(self):
        assert comm.get_channel("none") is None
        assert isinstance(comm.get_channel("dense"), comm.DenseChannel)
        ch = comm.get_channel("quant", quant_bits=4)
        assert ch.bits == 4
        assert isinstance(comm.get_channel("dp", dp_sigma=0.5),
                          comm.DPGaussianChannel)
        assert comm.get_channel("dropout", dropout_p=0.25).p == 0.25
        with pytest.raises(ValueError):
            comm.get_channel("carrier-pigeon")


# ---------------------------------------------------------------------------
# engine integration: channel dispatch inside the scanned round
# ---------------------------------------------------------------------------

class TestEngineChannel:
    def test_dense_channel_engine_bit_identical(self, toy):
        params, apply, data, sizes = toy
        sampler = _sampler_from(data, sizes)
        opt = opt_lib.adam(1e-2)
        rng = jax.random.PRNGKey(3)
        cfg0 = round_engine.EngineConfig(algorithm="dcco", lam=LAM,
                                         chunk_rounds=3)
        e0 = round_engine.RoundEngine(apply, opt, sampler, cfg0)
        p0, s0, m0 = e0.run(params, opt.init(params), rng, 6)
        e1 = round_engine.RoundEngine(
            apply, opt, sampler, cfg0._replace(channel=comm.DenseChannel()))
        p1, s1, m1 = e1.run(params, opt.init(params), rng, 6)
        assert utils.tree_max_abs_diff(p0, p1) == 0.0
        np.testing.assert_array_equal(np.asarray(m0.loss),
                                      np.asarray(m1.loss))
        # un-channeled metrics report zero wire cost; dense reports K*payload
        np.testing.assert_array_equal(np.asarray(m0.wire_bytes), 0.0)
        assert m1.wire_bytes.shape == (6,)
        assert float(m1.wire_bytes[0]) > 0

    @pytest.mark.parametrize("channel", [
        comm.QuantizedChannel(8), comm.QuantizedChannel(8, kernel="interpret"),
        comm.DPGaussianChannel(0.3, clip_norm=10.0), comm.DropoutChannel(0.4),
    ])
    def test_lossy_channels_train_in_scan(self, toy, channel):
        params, apply, data, sizes = toy
        opt = opt_lib.adam(1e-2)
        cfg = round_engine.EngineConfig(algorithm="dcco", lam=LAM,
                                        chunk_rounds=3, channel=channel)
        eng = round_engine.RoundEngine(apply, opt, _sampler_from(data, sizes),
                                       cfg)
        p, s, m = eng.run(params, opt.init(params), jax.random.PRNGKey(3), 6)
        assert bool(jnp.isfinite(m.loss).all())
        assert m.wire_bytes.shape == (6,)
        assert utils.tree_max_abs_diff(p, params) > 0.0

    def test_dp_accountant_advances_with_engine_rounds(self, toy):
        params, apply, data, sizes = toy
        ch = comm.DPGaussianChannel(1.0, clip_norm=1.0)
        opt = opt_lib.adam(1e-2)
        cfg = round_engine.EngineConfig(algorithm="dcco", lam=LAM,
                                        chunk_rounds=3, channel=ch)
        eng = round_engine.RoundEngine(apply, opt, _sampler_from(data, sizes),
                                       cfg)
        eng.run(params, opt.init(params), jax.random.PRNGKey(3), 6)
        assert ch.accountant.steps == 6
        assert ch.accountant.epsilon() > 0

    def test_fedavg_body_routes_through_channel(self, toy):
        params, apply, data, sizes = toy
        opt = opt_lib.adam(1e-2)
        cfg = round_engine.EngineConfig(algorithm="fedavg_cco", lam=LAM,
                                        chunk_rounds=2,
                                        channel=comm.DropoutChannel(0.3))
        eng = round_engine.RoundEngine(apply, opt, _sampler_from(data, sizes),
                                       cfg)
        p, s, m = eng.run(params, opt.init(params), jax.random.PRNGKey(3), 4)
        assert bool(jnp.isfinite(m.loss).all())
        # dropout rounds ship fewer client updates than the full cohort
        per_client = comm.DenseChannel().payload_bytes(params)
        assert float(jnp.max(m.wire_bytes)) <= 8 * per_client

    def test_channel_guards(self, toy):
        params, apply, data, sizes = toy
        opt = opt_lib.adam(1e-2)
        with pytest.raises(ValueError, match="stats_kernel"):
            round_engine.make_round_body(
                apply, opt, round_engine.EngineConfig(
                    stats_kernel="interpret",
                    channel=comm.QuantizedChannel(8)))
        with pytest.raises(ValueError, match="centralized"):
            round_engine.make_round_body(
                apply, opt, round_engine.EngineConfig(
                    algorithm="centralized", channel=comm.DenseChannel()))
        with pytest.raises(ValueError, match="channel_key"):
            fed_sim.dcco_round(apply, params, opt_lib.sgd(0.1).init(params),
                               opt_lib.sgd(0.1), data, sizes,
                               channel=comm.DenseChannel())
        # a stats-only DP channel on fedavg would add no noise while the
        # accountant still reports epsilon — rejected at build time
        with pytest.raises(ValueError, match="noise_phases"):
            round_engine.make_round_body(
                apply, opt, round_engine.EngineConfig(
                    algorithm="fedavg_cco",
                    channel=comm.DPGaussianChannel(1.0)))
        round_engine.make_round_body(
            apply, opt, round_engine.EngineConfig(
                algorithm="fedavg_cco",
                channel=comm.DPGaussianChannel(
                    1.0, noise_phases=("update",))))
        with pytest.raises(ValueError, match="noise_phases"):
            comm.DPGaussianChannel(1.0, noise_phases=("stats", "weights"))
        # dense + flat kernel stats is allowed (lossless, size-weighted)
        round_engine.make_round_body(
            apply, opt, round_engine.EngineConfig(
                stats_kernel="interpret", channel=comm.DenseChannel()))

    def test_quant_pallas_kernel_falls_back_on_cpu(self, toy):
        """kernel='pallas' must work everywhere, like stats_kernel='pallas':
        on CPU it routes through the interpreter (bit-identical anyway)."""
        assert jax.default_backend() == "cpu"
        xk = jax.random.normal(jax.random.PRNGKey(0), (4, 9))
        ch = comm.QuantizedChannel(8, kernel="pallas")
        ctx = ch.begin_round(jax.random.PRNGKey(1), jnp.full((4,), 2))
        out = ch.encode_decode(ctx, {"a": xk}, "stats")
        ref = comm.QuantizedChannel(8, kernel="interpret").encode_decode(
            ctx, {"a": xk}, "stats")
        assert utils.tree_max_abs_diff(out, ref) == 0.0
