"""2-process jax.distributed smoke of the multi-host cohort mesh.

Each subprocess is one "host" with 2 forced CPU devices
(--xla_force_host_platform_device_count), joined by the REPRO_* env
contract (repro.sharding.maybe_initialize_distributed) into a (2, 2)
("data", "client") global mesh. The sharded DCCO round runs with the
axis TUPLE — the cross-host psum path — and every process checks the
result against its own single-device reference round (exact by Eq.-3
linearity, up to psum reassociation).

The same pattern as TestShardedCohort's subprocess harness
(tests/test_round_engine.py), grown to two processes: the device count
must be forced and gloo selected before jax initializes, which can only
happen in a fresh interpreter.
"""
import os
import socket
import subprocess
import sys

import pytest

_DIST_SCRIPT = """
from repro.sharding import (host_local_to_global, make_multihost_mesh,
                            maybe_initialize_distributed)
assert maybe_initialize_distributed(), "REPRO_* env contract not picked up"

import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
assert jax.process_count() == 2, jax.process_count()
assert jax.device_count() == 4, jax.device_count()

from repro import comm, utils
from repro.core import fed_sim, round_engine
from repro.optim import optimizers as opt_lib

mesh = make_multihost_mesh(("data", "client"))
assert mesh.devices.shape == (2, 2), mesh.devices.shape

key = jax.random.PRNGKey(0)
params = {"w1": jax.random.normal(key, (10, 16)) * 0.3,
          "w2": jax.random.normal(jax.random.PRNGKey(7), (16, 6)) * 0.3}
def apply(p, batch):
    enc = lambda x: jnp.tanh(x @ p["w1"]) @ p["w2"]
    return enc(batch["v1"]), enc(batch["v2"])
k1, k2 = jax.random.split(key)
# full 8-client cohort, identical on every process (same seed)
data = {"v1": jax.random.normal(k1, (8, 3, 10)),
        "v2": jax.random.normal(k2, (8, 3, 10))}
sizes = jnp.array([3, 1, 2, 3, 3, 2, 1, 3], jnp.int32)
opt = opt_lib.adam(1e-2)
opt_state = opt.init(params)

# single-device reference (process-local, no collectives)
p1, s1, m1 = fed_sim.dcco_round(apply, params, opt_state, opt,
                                data, sizes, lam=5.0)

# assemble globals: each process contributes ITS 4 clients of the K axis
rank = jax.process_index()
lo, hi = rank * 4, rank * 4 + 4
shard = P(("data", "client"))
data_g = host_local_to_global(
    mesh, shard, {k: v[lo:hi] for k, v in data.items()})
sizes_g = host_local_to_global(mesh, shard, sizes[lo:hi])
params_g = host_local_to_global(mesh, P(), params)
opt_g = host_local_to_global(mesh, P(), opt_state)

p2, s2, m2 = round_engine.dcco_round_sharded(
    apply, params_g, opt_g, opt, data_g, sizes_g, mesh, lam=5.0,
    axis=("data", "client"))

def local_np(tree):
    # round outputs are replicated -> any addressable shard is the array
    return jax.tree.map(lambda x: np.asarray(x.addressable_data(0)), tree)

diff = utils.tree_max_abs_diff(local_np(p2), jax.device_get(p1))
assert diff < 1e-5, diff
assert abs(float(np.asarray(m2.loss.addressable_data(0)))
           - float(m1.loss)) < 1e-4

# int8 channel over the 2-host wire: runs, accounts bytes, stays finite
pq, sq, mq = round_engine.dcco_round_sharded(
    apply, params_g, opt_g, opt, data_g, sizes_g, mesh, lam=5.0,
    axis=("data", "client"), channel=comm.QuantizedChannel(8),
    channel_key=jax.random.PRNGKey(42))
assert float(np.asarray(mq.wire_bytes.addressable_data(0))) > 0
assert np.isfinite(float(np.asarray(mq.loss.addressable_data(0))))

print("DIST_OK", flush=True)
"""


# 4-shard corpus mesh over 2 processes x 2 devices: each host materializes
# only its 2 addressable shards, the shard_map all_gather merges candidates,
# and the result must match the single-device search BIT-FOR-BIT — ragged
# N=90 (pad rows mask via n_total) and rows duplicated across shard
# boundaries (lowest-global-index tie-break survives the wire).
_SHARDED_RETRIEVAL_SCRIPT = """
from repro.sharding import make_corpus_mesh, maybe_initialize_distributed
assert maybe_initialize_distributed(), "REPRO_* env contract not picked up"

import jax, jax.numpy as jnp
import numpy as np
assert jax.process_count() == 2, jax.process_count()
assert jax.device_count() == 4, jax.device_count()

from repro.kernels.mips_topk import mips_topk
from repro.retrieval import ShardedCorpusIndex, l2_normalize

key = jax.random.PRNGKey(0)
kq, kc = jax.random.split(key)
q = l2_normalize(jax.random.normal(kq, (6, 16), jnp.float32))
c = l2_normalize(jax.random.normal(kc, (90, 16), jnp.float32))
# exact duplicates straddling shard boundaries (shard_size = 23):
c = c.at[61].set(c[2]).at[35].set(c[2]).at[88].set(c[40])
# query 0 IS the duplicated row -> rows {2, 35, 61} tie at the top and
# the merge must break toward the lowest global index
q = q.at[0].set(c[2])

mesh = make_corpus_mesh(4)
assert mesh.shape["corpus"] == 4, mesh.shape
idx = ShardedCorpusIndex(c, 4, mesh=mesh)
v, i = idx.search(q, 5, backend="chunked")
want_v, want_i = mips_topk(q, c, 5, backend="chunked")

got_v = np.asarray(v.addressable_data(0))
got_i = np.asarray(i.addressable_data(0))
np.testing.assert_array_equal(got_v, np.asarray(want_v))
np.testing.assert_array_equal(got_i, np.asarray(want_i))
assert got_i.dtype == np.int32, got_i.dtype
# the duplicated winner resolves to the LOWEST global index (row 2),
# then the copies in shards 1 and 2 follow in ascending order
assert got_i[0, 0] == 2, got_i[0]
assert got_i[0, 1] == 35 and got_i[0, 2] == 61, got_i[0]

print("DIST_OK", flush=True)
"""


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _run_two_process(script: str):
    port = _free_port()
    procs = []
    for rank in range(2):
        env = dict(os.environ)
        env.update({
            "JAX_PLATFORMS": "cpu",
            "XLA_FLAGS": (env.get("XLA_FLAGS", "") +
                          " --xla_force_host_platform_device_count=2"
                          ).strip(),
            "PYTHONPATH": os.pathsep.join(
                [os.path.join(os.path.dirname(__file__), "..", "src"),
                 env.get("PYTHONPATH", "")]).rstrip(os.pathsep),
            "REPRO_COORDINATOR": f"127.0.0.1:{port}",
            "REPRO_NUM_PROCESSES": "2",
            "REPRO_PROCESS_ID": str(rank),
        })
        procs.append(subprocess.Popen(
            [sys.executable, "-c", script], env=env,
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True))
    outs = [p.communicate(timeout=420) for p in procs]
    for rank, (p, (out, err)) in enumerate(zip(procs, outs)):
        assert p.returncode == 0, (
            f"rank {rank}: stdout={out}\nstderr={err}")
        assert "DIST_OK" in out, f"rank {rank}: stdout={out}"


class TestMultiHost:
    @pytest.mark.slow
    def test_two_process_mesh_matches_single_device(self):
        _run_two_process(_DIST_SCRIPT)

    @pytest.mark.slow
    def test_two_process_sharded_retrieval_bitwise(self):
        _run_two_process(_SHARDED_RETRIEVAL_SCRIPT)
