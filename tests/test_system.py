"""End-to-end behaviour: federated DCCO pretraining on small non-IID
clients improves representations (paper's headline claim, miniaturized),
and the pod-scale fused train step is gradient-identical to the
protocol-faithful per-client path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import utils
from repro.configs.base import get_config, DualEncoderConfig, TrainConfig
from repro.core import eval as eval_lib, fed_sim
from repro.data import pipeline, synthetic
from repro.launch import steps as steps_lib
from repro.models import dual_encoder
from repro.optim import optimizers as opt_lib


def _resnet_setup(rng_key):
    cfg = get_config("resnet14-cifar", smoke=True)
    de = DualEncoderConfig(proj_dims=(32, 32), lambda_cco=5.0)
    params = dual_encoder.init_dual_encoder(rng_key, cfg, de)

    def apply(p, batch):
        zf, _ = dual_encoder.encode(cfg, de, p, {"images": batch["v1"]})
        zg, _ = dual_encoder.encode(cfg, de, p, {"images": batch["v2"]})
        return zf, zg

    def embed(p, images):
        from repro.models import resnet as resnet_mod
        return resnet_mod.resnet_forward(cfg, p["tower"], images)

    return cfg, de, params, apply, embed


def test_dcco_pretraining_improves_linear_probe(rng_key):
    """30 rounds of DCCO on non-IID 2-sample clients must beat the
    random-init encoder under the linear evaluation protocol."""
    cfg, de, params, apply, embed = _resnet_setup(rng_key)
    imgs, labels = synthetic.synthetic_labeled_images(
        600, 5, image_size=cfg.image_size, noise=0.5, seed=1)
    ds = pipeline.FederatedDataset.build(
        {"images": imgs}, labels, num_clients=128, samples_per_client=2,
        alpha=0.0, seed=0)
    opt = opt_lib.adam(2e-3)
    state = opt.init(params)
    p = params

    def probe(pp):
        z = embed(pp, jnp.asarray(imgs))
        return float(eval_lib.ridge_linear_probe(
            z[:400], jnp.asarray(labels[:400]), z[400:],
            jnp.asarray(labels[400:]), 5))

    acc0 = probe(params)
    losses = []
    for r in range(30):
        batch, sizes = ds.round_batch(jax.random.PRNGKey(100 + r), 16)
        p, state, m = fed_sim.dcco_round(apply, p, state, opt, batch, sizes,
                                         lam=5.0, client_lr=1.0)
        losses.append(float(m.loss))
    acc1 = probe(p)
    assert losses[-1] < losses[0], f"loss did not decrease: {losses[0]} -> {losses[-1]}"
    assert acc1 > acc0 - 0.02, f"probe degraded: {acc0} -> {acc1}"
    assert np.isfinite(losses).all()


def test_fused_step_matches_per_client_step(rng_key):
    """The optimized pod-scale loss path == the faithful per-client path
    (theorem at the train-step level, with a real transformer tower)."""
    cfg = get_config("tinyllama-1.1b", smoke=True)
    de = DualEncoderConfig(proj_dims=(16, 16), lambda_cco=5.0)
    opt = opt_lib.sgd(0.1)
    params = dual_encoder.init_dual_encoder(rng_key, cfg, de)
    toks = jax.random.randint(rng_key, (4, 16), 0, cfg.vocab_size)
    batch = {"view1": {"tokens": toks}, "view2": {"tokens": jnp.roll(toks, 1, -1)}}

    outs = {}
    for impl in ("fused", "per_client"):
        tcfg = TrainConfig(seq_len=16, global_batch=4, samples_per_client=2,
                           dcco_impl=impl)
        step = steps_lib.make_dcco_train_step(cfg, de, tcfg, opt)
        p2, _, m = step(params, opt.init(params), batch)
        outs[impl] = (p2, float(m["loss"]))
    np.testing.assert_allclose(outs["fused"][1], outs["per_client"][1], rtol=1e-5)
    # relative tolerance: the two paths reorder f32 summations, so absolute
    # diffs measure conditioning, not the theorem (cf. TestResNetEquivalence)
    diff = utils.tree_max_abs_diff(outs["fused"][0], outs["per_client"][0])
    upd = utils.tree_max_abs_diff(outs["fused"][0], params) + 1e-12
    assert diff / upd < 1e-4, f"relative deviation {diff / upd}"


def test_lm_train_step_decreases_loss(rng_key):
    cfg = get_config("tinyllama-1.1b", smoke=True)
    opt = opt_lib.adam(1e-3)
    from repro.models import transformer
    params = transformer.init_params(cfg, rng_key)
    step = jax.jit(steps_lib.make_lm_train_step(cfg, opt))
    state = opt.init(params)
    toks = jax.random.randint(rng_key, (4, 32), 0, 64)  # low-entropy slice
    losses = []
    for _ in range(20):
        params, state, m = step(params, state, {"tokens": toks})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9


def test_checkpoint_resume_federated_training(tmp_path, rng_key):
    """Checkpoint mid-training, restore, continue — identical trajectory."""
    import os
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    cfg, de, params, apply, _ = _resnet_setup(rng_key)
    imgs, labels = synthetic.synthetic_labeled_images(100, 4, image_size=cfg.image_size)
    ds = pipeline.FederatedDataset.build(
        {"images": imgs}, labels, num_clients=20, samples_per_client=2,
        alpha=0.0, seed=0)
    opt = opt_lib.adam(1e-3)
    state = opt.init(params)
    p = params
    for r in range(2):
        batch, sizes = ds.round_batch(jax.random.PRNGKey(r), 4)
        p, state, _ = fed_sim.dcco_round(apply, p, state, opt, batch, sizes)
    path = os.path.join(tmp_path, "fed.msgpack")
    save_checkpoint(path, {"params": p, "opt": state}, step=2)
    restored, step = restore_checkpoint(path, {"params": p, "opt": state})
    batch, sizes = ds.round_batch(jax.random.PRNGKey(99), 4)
    p_a, _, _ = fed_sim.dcco_round(apply, p, state, opt, batch, sizes)
    p_b, _, _ = fed_sim.dcco_round(apply, restored["params"], restored["opt"],
                                   opt, batch, sizes)
    assert utils.tree_max_abs_diff(utils.tree_cast(p_a, jnp.float32),
                                   utils.tree_cast(p_b, jnp.float32)) < 1e-7
