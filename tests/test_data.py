"""Data substrate: Dirichlet non-IID partitioning (Hsu et al. process),
stateless two-view augmentations, federated pipeline layouts."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import augment, partition, pipeline, synthetic


class TestPartition:
    def test_alpha_zero_single_class_clients(self):
        """alpha=0 (paper's non-IID): every client is single-class."""
        _, labels = synthetic.synthetic_labeled_images(2000, 10, image_size=4)
        idx = partition.dirichlet_partition(labels, 50, 8, alpha=0.0, seed=1)
        per_client_classes = [len(np.unique(labels[row])) for row in idx]
        assert np.mean(per_client_classes) < 1.5

    def test_alpha_large_is_iid_like(self):
        _, labels = synthetic.synthetic_labeled_images(4000, 10, image_size=4)
        idx = partition.dirichlet_partition(labels, 40, 16, alpha=1000.0, seed=1)
        per_client_classes = [len(np.unique(labels[row])) for row in idx]
        assert np.mean(per_client_classes) > 5

    def test_no_duplicate_samples(self):
        _, labels = synthetic.synthetic_labeled_images(1000, 5, image_size=4)
        idx = partition.dirichlet_partition(labels, 20, 10, alpha=1.0, seed=0)
        flat = idx.reshape(-1)
        assert len(np.unique(flat)) == len(flat)

    def test_iid_partition_shapes(self):
        idx = partition.iid_partition(500, 25, 4, seed=3)
        assert idx.shape == (25, 4)
        assert len(np.unique(idx.reshape(-1))) == 100


class TestAugment:
    def test_stateless_determinism(self, rng_key):
        img = jax.random.uniform(rng_key, (16, 16, 3))
        a1 = augment.augment_image(jax.random.PRNGKey(5), img)
        a2 = augment.augment_image(jax.random.PRNGKey(5), img)
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))

    def test_two_views_differ(self, rng_key):
        img = jax.random.uniform(rng_key, (16, 16, 3))
        v1, v2 = augment.two_views_image(rng_key, img)
        assert float(jnp.max(jnp.abs(v1 - v2))) > 1e-3
        assert v1.shape == img.shape

    def test_token_augment_preserves_shape_and_vocab(self, rng_key):
        toks = jax.random.randint(rng_key, (32,), 0, 100)
        v1, v2 = augment.two_views_tokens(rng_key, toks, vocab=100)
        assert v1.shape == toks.shape
        assert int(v1.max()) < 100 and int(v1.min()) >= 0
        assert not np.array_equal(np.asarray(v1), np.asarray(v2))


class TestPipeline:
    def _ds(self):
        imgs, labels = synthetic.synthetic_labeled_images(400, 5, image_size=8)
        return pipeline.FederatedDataset.build(
            {"images": imgs}, labels, num_clients=40, samples_per_client=4,
            alpha=0.0, seed=0)

    def test_round_batch_layout(self, rng_key):
        ds = self._ds()
        batch, sizes = ds.round_batch(rng_key, clients_per_round=8)
        assert batch["v1"].shape == (8, 4, 8, 8, 3)
        assert batch["v2"].shape == (8, 4, 8, 8, 3)
        assert sizes.shape == (8,)

    def test_flat_round_batch(self, rng_key):
        ds = self._ds()
        flat, sizes = ds.flat_round_batch(rng_key, clients_per_round=8)
        assert flat["v1"].shape == (32, 8, 8, 3)

    def test_token_dataset(self, rng_key):
        toks, labels = synthetic.synthetic_labeled_tokens(200, 4, 16, vocab=64)
        ds = pipeline.FederatedDataset.build(
            {"tokens": toks}, labels, num_clients=20, samples_per_client=2,
            alpha=0.0, seed=0, vocab=64)
        batch, sizes = ds.round_batch(rng_key, clients_per_round=4)
        assert batch["v1"].shape == (4, 2, 16)
        assert batch["v1"].dtype == jnp.int32


class TestSynthetic:
    def test_labels_linearly_separable_in_pixel_space(self):
        """The synthetic generator must carry class signal (probe sanity)."""
        from repro.core import eval as eval_lib
        imgs, labels = synthetic.synthetic_labeled_images(600, 5, image_size=8,
                                                          noise=0.2)
        z = jnp.asarray(imgs.reshape(600, -1))
        y = jnp.asarray(labels)
        acc = eval_lib.ridge_linear_probe(z[:400], y[:400], z[400:], y[400:], 5)
        assert float(acc) > 0.9
