"""Data substrate: Dirichlet non-IID partitioning (Hsu et al. process),
partition strategies as data (PartitionSpec), stateless two-view
augmentations, federated pipeline layouts."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data import augment, partition, pipeline, synthetic

SET = settings(max_examples=20, deadline=None)


class TestPartition:
    def test_alpha_zero_single_class_clients(self):
        """alpha=0 (paper's non-IID): every client is single-class."""
        _, labels = synthetic.synthetic_labeled_images(2000, 10, image_size=4)
        idx = partition.dirichlet_partition(labels, 50, 8, alpha=0.0, seed=1)
        per_client_classes = [len(np.unique(labels[row])) for row in idx]
        assert np.mean(per_client_classes) < 1.5

    def test_alpha_large_is_iid_like(self):
        _, labels = synthetic.synthetic_labeled_images(4000, 10, image_size=4)
        idx = partition.dirichlet_partition(labels, 40, 16, alpha=1000.0, seed=1)
        per_client_classes = [len(np.unique(labels[row])) for row in idx]
        assert np.mean(per_client_classes) > 5

    def test_no_duplicate_samples(self):
        _, labels = synthetic.synthetic_labeled_images(1000, 5, image_size=4)
        idx = partition.dirichlet_partition(labels, 20, 10, alpha=1.0, seed=0)
        flat = idx.reshape(-1)
        assert len(np.unique(flat)) == len(flat)

    def test_iid_partition_shapes(self):
        idx = partition.iid_partition(500, 25, 4, seed=3)
        assert idx.shape == (25, 4)
        assert len(np.unique(idx.reshape(-1))) == 100


class TestPartitionSpec:
    """Strategies-as-data API: registry, severity axis, conservation."""

    def _labels(self, n=900, c=6):
        _, labels = synthetic.synthetic_labeled_images(n, c, image_size=4)
        return labels

    def test_registry_lists_all_strategies(self):
        assert set(partition.PARTITIONS) >= {
            "iid", "uniform", "label", "dirichlet", "dirichlet_quantity"}
        for name in partition.PARTITIONS:
            assert callable(partition.get_partition(name))
        with pytest.raises(ValueError, match="unknown partition"):
            partition.get_partition("no_such_strategy")

    def test_register_partition_extends_registry(self):
        def halves(labels, num_clients, samples_per_client, severity,
                   seed=0):
            return partition.iid_partition(
                len(np.asarray(labels)), num_clients, samples_per_client,
                seed)
        partition.register_partition("test_halves", halves)
        try:
            assert "test_halves" in partition.PARTITIONS
            idx, sizes = partition.build_partition(
                partition.PartitionSpec("test_halves", 0.5),
                self._labels(), num_clients=10, samples_per_client=3)
            assert idx.shape == (10, 3)
            assert (sizes == 3).all()
        finally:
            partition._REGISTRY.pop("test_halves")
            partition.PARTITIONS = tuple(partition._REGISTRY)

    @SET
    @given(strategy=st.sampled_from(
        ["iid", "uniform", "label", "dirichlet", "dirichlet_quantity"]),
        severity=st.floats(0.0, 1.0), seed=st.integers(0, 2**10))
    def test_sample_conservation(self, strategy, severity, seed):
        """Every strategy: each assigned (non-padding) slot holds a
        distinct dataset index — no sample duplicated or invented."""
        labels = self._labels()
        idx, sizes = partition.build_partition(
            partition.PartitionSpec(strategy, severity), labels,
            num_clients=30, samples_per_client=6, seed=seed)
        assert idx.shape == (30, 6) and sizes.shape == (30,)
        assert (1 <= sizes).all() and (sizes <= 6).all()
        valid = np.concatenate(
            [idx[k, : sizes[k]] for k in range(30)])
        assert len(np.unique(valid)) == len(valid)
        assert valid.min() >= 0 and valid.max() < len(labels)

    @SET
    @given(seed=st.integers(0, 2**10))
    def test_label_dominance_monotone_in_severity(self, seed):
        """The label-skew metric rises with severity for the skewing
        strategies and stays flat for the controls."""
        labels = self._labels()
        for strategy in ("label", "dirichlet"):
            doms = []
            for sev in (0.0, 0.5, 1.0):
                idx, sizes = partition.build_partition(
                    partition.PartitionSpec(strategy, sev), labels,
                    num_clients=30, samples_per_client=6, seed=seed)
                doms.append(partition.label_dominance(labels, idx, sizes))
            assert doms[0] <= doms[1] <= doms[2], (strategy, doms)
            assert doms[2] > doms[0] + 0.3, (strategy, doms)
        # uniform: severity-flat, maximally homogeneous
        u0, _ = partition.build_partition(
            partition.PartitionSpec("uniform", 0.0), labels,
            num_clients=30, samples_per_client=6, seed=seed)
        u1, _ = partition.build_partition(
            partition.PartitionSpec("uniform", 1.0), labels,
            num_clients=30, samples_per_client=6, seed=seed)
        np.testing.assert_array_equal(u0, u1)

    def test_quantity_skew_severity_spreads_sizes(self):
        labels = self._labels()
        _, s0 = partition.build_partition(
            partition.PartitionSpec("dirichlet_quantity", 0.0), labels,
            num_clients=30, samples_per_client=6, seed=0)
        _, s1 = partition.build_partition(
            partition.PartitionSpec("dirichlet_quantity", 1.0), labels,
            num_clients=30, samples_per_client=6, seed=0)
        assert np.std(s1) > np.std(s0)

    def test_infeasible_partition_raises_with_bound(self):
        labels = self._labels(n=100)
        with pytest.raises(ValueError, match="supports at most 16 clients"):
            partition.dirichlet_partition(labels, 20, 6, alpha=1.0)
        with pytest.raises(ValueError, match="infeasible"):
            partition.build_partition(
                partition.PartitionSpec("label", 1.0), labels,
                num_clients=101, samples_per_client=1)

    def test_severity_out_of_range_rejected(self):
        with pytest.raises(ValueError, match="severity"):
            partition.build_partition(
                partition.PartitionSpec("label", 1.5), self._labels(),
                num_clients=4, samples_per_client=2)

    def test_alpha_override_dirichlet_only(self):
        with pytest.raises(ValueError, match="'dirichlet' strategy only"):
            partition.build_partition(
                partition.PartitionSpec("label", alpha=3.0), self._labels(),
                num_clients=4, samples_per_client=2)

    def test_severity_alpha_anchors(self):
        assert partition.severity_to_alpha(0.0) == pytest.approx(1000.0)
        assert partition.severity_to_alpha(1.0) == pytest.approx(1e-3)
        assert partition.severity_to_classes(0.0, 10) == 10
        assert partition.severity_to_classes(1.0, 10) == 1

    def test_deprecated_alpha_alias_bit_identical(self):
        """build(alpha=...) == build(partition=PartitionSpec(...)) — the
        historical client assignment survives the API redesign exactly."""
        imgs, labels = synthetic.synthetic_labeled_images(300, 5,
                                                          image_size=4)
        for alpha in (0.0, 0.5, 1e7):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                old = pipeline.FederatedDataset.build(
                    {"images": imgs}, labels, num_clients=20,
                    samples_per_client=4, alpha=alpha, seed=3)
            new = pipeline.FederatedDataset.build(
                {"images": imgs}, labels, num_clients=20,
                samples_per_client=4,
                partition=partition.PartitionSpec("dirichlet", alpha=alpha),
                seed=3)
            np.testing.assert_array_equal(old.client_index,
                                          new.client_index)
            np.testing.assert_array_equal(old.client_sizes,
                                          new.client_sizes)

    def test_deprecated_alpha_warns_and_both_rejected(self):
        imgs, labels = synthetic.synthetic_labeled_images(100, 4,
                                                          image_size=4)
        with pytest.warns(DeprecationWarning):
            pipeline.FederatedDataset.build(
                {"images": imgs}, labels, num_clients=10,
                samples_per_client=2, alpha=0.0)
        with pytest.raises(ValueError, match="not both"):
            pipeline.FederatedDataset.build(
                {"images": imgs}, labels, num_clients=10,
                samples_per_client=2, alpha=0.0,
                partition=partition.PartitionSpec("iid"))
        with pytest.raises(TypeError):
            pipeline.FederatedDataset.build(
                {"images": imgs}, labels, num_clients=10,
                samples_per_client=2)

    def test_variable_sizes_ride_the_samplers(self, rng_key):
        """dirichlet_quantity sizes flow through round_batch AND the
        in-scan sampler (pad slots masked downstream by sizes)."""
        imgs, labels = synthetic.synthetic_labeled_images(300, 5,
                                                          image_size=4)
        ds = pipeline.FederatedDataset.build(
            {"images": imgs}, labels, num_clients=30, samples_per_client=4,
            partition=partition.PartitionSpec("dirichlet_quantity", 0.9),
            seed=0)
        assert ds.client_sizes.min() >= 1
        assert (ds.client_sizes <= 4).any()
        _, sizes_host = ds.round_batch(rng_key, clients_per_round=8)
        sampler = ds.make_round_sampler(8)
        k_sel, k_aug = jax.random.split(rng_key)
        _, sizes_scan = sampler(k_sel, k_aug)
        assert sizes_host.shape == (8,) and sizes_scan.shape == (8,)
        assert int(jnp.max(sizes_scan)) <= 4


class TestAugment:
    def test_stateless_determinism(self, rng_key):
        img = jax.random.uniform(rng_key, (16, 16, 3))
        a1 = augment.augment_image(jax.random.PRNGKey(5), img)
        a2 = augment.augment_image(jax.random.PRNGKey(5), img)
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))

    def test_two_views_differ(self, rng_key):
        img = jax.random.uniform(rng_key, (16, 16, 3))
        v1, v2 = augment.two_views_image(rng_key, img)
        assert float(jnp.max(jnp.abs(v1 - v2))) > 1e-3
        assert v1.shape == img.shape

    def test_token_augment_preserves_shape_and_vocab(self, rng_key):
        toks = jax.random.randint(rng_key, (32,), 0, 100)
        v1, v2 = augment.two_views_tokens(rng_key, toks, vocab=100)
        assert v1.shape == toks.shape
        assert int(v1.max()) < 100 and int(v1.min()) >= 0
        assert not np.array_equal(np.asarray(v1), np.asarray(v2))


class TestPipeline:
    def _ds(self):
        imgs, labels = synthetic.synthetic_labeled_images(400, 5, image_size=8)
        return pipeline.FederatedDataset.build(
            {"images": imgs}, labels, num_clients=40, samples_per_client=4,
            alpha=0.0, seed=0)

    def test_round_batch_layout(self, rng_key):
        ds = self._ds()
        batch, sizes = ds.round_batch(rng_key, clients_per_round=8)
        assert batch["v1"].shape == (8, 4, 8, 8, 3)
        assert batch["v2"].shape == (8, 4, 8, 8, 3)
        assert sizes.shape == (8,)

    def test_flat_round_batch(self, rng_key):
        ds = self._ds()
        flat, sizes = ds.flat_round_batch(rng_key, clients_per_round=8)
        assert flat["v1"].shape == (32, 8, 8, 3)

    def test_token_dataset(self, rng_key):
        toks, labels = synthetic.synthetic_labeled_tokens(200, 4, 16, vocab=64)
        ds = pipeline.FederatedDataset.build(
            {"tokens": toks}, labels, num_clients=20, samples_per_client=2,
            alpha=0.0, seed=0, vocab=64)
        batch, sizes = ds.round_batch(rng_key, clients_per_round=4)
        assert batch["v1"].shape == (4, 2, 16)
        assert batch["v1"].dtype == jnp.int32


class TestSynthetic:
    def test_labels_linearly_separable_in_pixel_space(self):
        """The synthetic generator must carry class signal (probe sanity)."""
        from repro.core import eval as eval_lib
        imgs, labels = synthetic.synthetic_labeled_images(600, 5, image_size=8,
                                                          noise=0.2)
        z = jnp.asarray(imgs.reshape(600, -1))
        y = jnp.asarray(labels)
        acc = eval_lib.ridge_linear_probe(z[:400], y[:400], z[400:], y[400:], 5)
        assert float(acc) > 0.9
