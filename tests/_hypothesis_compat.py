"""Hypothesis if installed, else minimal stand-ins.

The property-based tests (test_cco, test_vicreg, test_attention) want
``hypothesis``, which the dev extra provides (``pip install -r
requirements-dev.txt``). On a bare install this module substitutes
single-example stand-ins: each ``@given`` property runs ONCE with a fixed,
deterministic representative drawn from each strategy — so the suite still
collects and exercises every property, just without the randomized search.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Fixed:
        """A strategy reduced to one representative example."""

        def __init__(self, value):
            self.value = value

    class st:  # noqa: N801 — mirrors `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value=0, max_value=None):
            if max_value is None:
                return _Fixed(min_value)
            return _Fixed((min_value + max_value) // 2)

        @staticmethod
        def sampled_from(elements):
            return _Fixed(list(elements)[0])

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Fixed((min_value + max_value) / 2.0)

        @staticmethod
        def booleans():
            return _Fixed(False)

    def settings(*_args, **_kw):
        def deco(fn):
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            # no functools.wraps: pytest must see a parameterless signature,
            # not the property's argument list (it would hunt for fixtures)
            def wrapper(*args):
                fixed = {k: s.value for k, s in strategies.items()}
                return fn(*args, **fixed)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
