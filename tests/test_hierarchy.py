"""Hierarchical aggregation + streaming mega-cohorts (repro.hierarchy):

  * two-level dense aggregation is BIT-identical (== 0.0) to flat
    aggregation for every registered objective — the Eq.-3 summation-tree
    exactness, computed-as-collapse so baselines stay byte-stable;
  * the forced real tree (collapse_ideal=False) matches flat to float
    regrouping only — demonstrating the exactness is math, not luck;
  * lossy hops compose: int8 client uplink, edge-outage dropout with
    surviving-mass renormalization, per-hop wire-bytes accounting;
  * the segment-sum fold: kernel (interpret) == jnp oracle inside the
    channel;
  * guards: DP hops, nested trees, non-dividing cohorts refused loudly;
  * streaming rounds (EngineConfig.cohort_chunk): chunked engine ==
    materialized engine on the same key stream, channels/hierarchy
    compose, chunk samplers concatenate to the materialized cohort, and
    the build-time guards fire.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm, hierarchy, utils
from repro.core import fed_sim, round_engine
from repro.data import pipeline, synthetic
from repro.objectives import OBJECTIVES, get_objective
from repro.optim import optimizers as opt_lib

LAM = 5.0


@pytest.fixture(scope="module")
def toy():
    key = jax.random.PRNGKey(0)
    params = {"w1": jax.random.normal(key, (10, 16)) * 0.3,
              "w2": jax.random.normal(jax.random.PRNGKey(7), (16, 6)) * 0.3}

    def apply(p, batch):
        def enc(x):
            return jnp.tanh(x @ p["w1"]) @ p["w2"]
        return enc(batch["v1"]), enc(batch["v2"])

    data = {"v1": jax.random.normal(jax.random.PRNGKey(1), (8, 3, 10)),
            "v2": jax.random.normal(jax.random.PRNGKey(2), (8, 3, 10))}
    sizes = jnp.array([3, 1, 2, 3, 3, 2, 1, 3], jnp.int32)
    return params, apply, data, sizes


@pytest.fixture(scope="module")
def image_ds():
    imgs, labels = synthetic.synthetic_labeled_images(60, 3, image_size=8,
                                                      noise=0.5, seed=1)
    ds = pipeline.FederatedDataset.build(
        {"images": imgs}, labels, num_clients=20, samples_per_client=2,
        alpha=0.0, seed=0)
    params = {"w1": jax.random.normal(jax.random.PRNGKey(0),
                                      (8 * 8 * 3, 32)) * 0.05,
              "w2": jax.random.normal(jax.random.PRNGKey(7), (32, 16)) * 0.1}

    def apply(p, batch):
        def enc(x):
            return jnp.tanh(x.reshape(x.shape[0], -1) @ p["w1"]) @ p["w2"]
        return enc(batch["v1"]), enc(batch["v2"])

    return ds, params, apply


class TestTwoLevelExactness:
    @pytest.mark.parametrize("name", OBJECTIVES)
    def test_dense_tree_bit_identical_to_flat(self, toy, name):
        """The acceptance property: a dense-dense two-level tree == flat
        aggregation, bit for bit, for every registered objective."""
        params, apply, data, sizes = toy
        obj = get_objective(name, **({"lam": LAM} if name == "dcco" else {}))
        opt = opt_lib.adam(1e-2)
        p0, s0, m0 = fed_sim.stats_round(apply, params, opt.init(params),
                                         opt, data, sizes, objective=obj)
        ch = hierarchy.HierarchicalChannel(4)
        p1, s1, m1 = fed_sim.stats_round(apply, params, opt.init(params),
                                         opt, data, sizes, objective=obj,
                                         channel=ch,
                                         channel_key=jax.random.PRNGKey(42))
        assert utils.tree_max_abs_diff(p0, p1) == 0.0
        assert float(m0.loss) == float(m1.loss)
        # both hops are accounted even on the ideal wire: K client + E
        # edge payloads per phase
        assert float(m1.wire_bytes) > 0.0

    def test_real_tree_matches_flat_to_regrouping(self, toy):
        """collapse_ideal=False forces the genuine two-level computation
        (segment fold + edge sum): equal to flat up to float regrouping —
        the Eq.-3 exactness is mathematical, the collapse only preserves
        the bits."""
        params, apply, data, sizes = toy
        obj = get_objective("dcco", lam=LAM)
        opt = opt_lib.adam(1e-2)
        p0, _, m0 = fed_sim.stats_round(apply, params, opt.init(params),
                                        opt, data, sizes, objective=obj)
        ch = hierarchy.HierarchicalChannel(4, collapse_ideal=False)
        assert not ch.collapses
        p1, _, m1 = fed_sim.stats_round(apply, params, opt.init(params),
                                        opt, data, sizes, objective=obj,
                                        channel=ch,
                                        channel_key=jax.random.PRNGKey(42))
        assert utils.tree_max_abs_diff(p0, p1) < 1e-6
        assert abs(float(m0.loss) - float(m1.loss)) < 1e-5

    def test_kernel_fold_matches_jnp_fold(self, toy):
        """The Pallas segment-sum fold (interpret mode) inside the channel
        == the jnp segment_sum fold."""
        params, apply, data, sizes = toy
        obj = get_objective("dcco", lam=LAM)
        opt = opt_lib.adam(1e-2)
        outs = {}
        for impl in ("jnp", "interpret"):
            ch = hierarchy.HierarchicalChannel(
                4, client_channel=comm.QuantizedChannel(8), fold_impl=impl)
            outs[impl] = fed_sim.stats_round(
                apply, params, opt.init(params), opt, data, sizes,
                objective=obj, channel=ch,
                channel_key=jax.random.PRNGKey(42))
        assert utils.tree_max_abs_diff(outs["jnp"][0],
                                       outs["interpret"][0]) < 1e-6

    def test_fold_to_edges_matches_manual(self):
        tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (6, 3)),
                "b": jax.random.normal(jax.random.PRNGKey(1), (6, 2, 2))}
        w = jax.random.uniform(jax.random.PRNGKey(2), (6,))
        ids = hierarchy.contiguous_edge_ids(6, 3)
        np.testing.assert_array_equal(np.asarray(ids), [0, 0, 1, 1, 2, 2])
        out = hierarchy.fold_to_edges(tree, w, ids, 3)
        for k in tree:
            want = jnp.stack([
                jnp.tensordot(w[2 * e:2 * e + 2], tree[k][2 * e:2 * e + 2],
                              axes=1) for e in range(3)])
            np.testing.assert_allclose(np.asarray(out[k]), np.asarray(want),
                                       rtol=1e-6, atol=1e-7)


class TestLossyHops:
    def test_int8_uplink_trains_and_accounts_both_hops(self, toy):
        params, apply, data, sizes = toy
        obj = get_objective("dcco", lam=LAM)
        opt = opt_lib.adam(1e-2)
        ch = hierarchy.HierarchicalChannel(
            4, client_channel=comm.QuantizedChannel(8))
        p, s, m = fed_sim.stats_round(apply, params, opt.init(params), opt,
                                      data, sizes, objective=obj, channel=ch,
                                      channel_key=jax.random.PRNGKey(42))
        assert bool(jnp.isfinite(m.loss))
        ctx = ch.begin_round(jax.random.PRNGKey(0), sizes)
        tmpl = obj.stat_template(6)
        hop = ch.hop_bytes(ctx, tmpl)
        # 8 int8 client payloads + 4 dense edge payloads, and the split
        # sums to the round accounting
        assert float(hop["client_edge"]) == pytest.approx(
            8 * comm.QuantizedChannel(8).payload_bytes(tmpl))
        assert float(hop["edge_server"]) == pytest.approx(
            4 * comm.DenseChannel().payload_bytes(tmpl))
        assert float(ch.round_bytes(ctx, tmpl)) == pytest.approx(
            float(hop["client_edge"] + hop["edge_server"]))

    def test_edge_outage_renormalizes_over_survivors(self, toy):
        """An edge-hop dropout drops whole client groups; the effective
        weights renormalize over the surviving mass and still sum to 1."""
        params, apply, data, sizes = toy
        ch = hierarchy.HierarchicalChannel(
            4, edge_channel=comm.DropoutChannel(0.5))
        assert not ch.full_participation
        # some key where at least one edge survives and one drops
        for seed in range(20):
            ctx = ch.begin_round(jax.random.PRNGKey(seed), sizes)
            keep = np.asarray(ctx.edge_ctx.mask)
            if 0 < keep.sum() < 4:
                break
        else:
            pytest.fail("no key produced a partial outage")
        mask = np.asarray(ctx.mask)
        w = np.asarray(ctx.weights)
        # clients behind a dropped edge vanish together
        np.testing.assert_array_equal(mask, np.repeat(keep, 2))
        assert w[mask == 0].sum() == 0.0
        np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)
        # and the round still trains
        obj = get_objective("dcco", lam=LAM)
        opt = opt_lib.adam(1e-2)
        p, s, m = fed_sim.stats_round(apply, params, opt.init(params), opt,
                                      data, sizes, objective=obj, channel=ch,
                                      channel_key=jax.random.PRNGKey(seed))
        assert bool(jnp.isfinite(m.loss))

    def test_edge_outage_p0_matches_dense_tree(self, toy):
        """p=0 edge dropout == the dense edge hop up to the one extra
        surviving-mass renormalization (a division by fl(sum w) ~= 1.0 —
        ulp-level, and absent entirely when edges actually drop nothing
        numerically relevant)."""
        params, apply, data, sizes = toy
        obj = get_objective("dcco", lam=LAM)
        opt = opt_lib.adam(1e-2)
        outs = []
        for edge_ch in (None, comm.DropoutChannel(0.0)):
            ch = hierarchy.HierarchicalChannel(4, edge_channel=edge_ch,
                                               collapse_ideal=False)
            outs.append(fed_sim.stats_round(
                apply, params, opt.init(params), opt, data, sizes,
                objective=obj, channel=ch,
                channel_key=jax.random.PRNGKey(42)))
        assert utils.tree_max_abs_diff(outs[0][0], outs[1][0]) < 1e-7


class TestGuards:
    def test_dp_hop_refused(self):
        with pytest.raises(ValueError, match="DP noise calibration"):
            hierarchy.HierarchicalChannel(
                2, client_channel=comm.DPGaussianChannel(0.5))
        with pytest.raises(ValueError, match="DP noise calibration"):
            hierarchy.HierarchicalChannel(
                2, edge_channel=comm.DPGaussianChannel(0.5))

    def test_nested_tree_refused(self):
        with pytest.raises(ValueError, match="nested"):
            hierarchy.HierarchicalChannel(
                2, client_channel=hierarchy.HierarchicalChannel(2))

    def test_non_dividing_cohort_refused(self, toy):
        params, apply, data, sizes = toy
        ch = hierarchy.HierarchicalChannel(3)
        with pytest.raises(ValueError, match="does not divide"):
            ch.begin_round(jax.random.PRNGKey(0), sizes)   # 8 % 3 != 0

    def test_bad_fold_impl_refused(self):
        with pytest.raises(ValueError, match="fold impl"):
            hierarchy.HierarchicalChannel(2, fold_impl="magic")


class TestStreaming:
    def test_streaming_engine_matches_materialized(self, image_ds):
        """chunked == materialized on the same (selection, augmentation)
        key stream, up to the float regrouping of the chunked sums."""
        ds, params, apply = image_ds
        opt = opt_lib.adam(1e-2)
        rng = jax.random.PRNGKey(3)
        cfg_m = round_engine.EngineConfig(algorithm="dcco", lam=LAM,
                                          chunk_rounds=4)
        eng_m = round_engine.RoundEngine(apply, opt,
                                         ds.make_round_sampler(8), cfg_m)
        pm, sm, mm = eng_m.run(params, opt.init(params), rng, 4)
        cfg_s = cfg_m._replace(cohort_chunk=2)
        eng_s = round_engine.RoundEngine(
            apply, opt, ds.make_streaming_sampler(8, 2), cfg_s)
        ps, ss, ms = eng_s.run(params, opt.init(params), rng, 4)
        assert utils.tree_max_abs_diff(pm, ps) < 1e-4
        np.testing.assert_allclose(np.asarray(mm.loss), np.asarray(ms.loss),
                                   rtol=1e-3, atol=1e-4)

    def test_chunks_concatenate_to_materialized_cohort(self, image_ds):
        ds, _, _ = image_ds
        key = jax.random.PRNGKey(11)
        k_sel, k_aug = jax.random.split(key)
        full_sampler = ds.make_round_sampler(6)
        batch, sizes = full_sampler(k_sel, k_aug)
        stream = ds.make_streaming_sampler(6, 2)
        assert stream.num_chunks == 3
        state = stream.prepare(k_sel, k_aug)
        chunks = [stream.sample_chunk(state, c) for c in range(3)]
        cat = jax.tree.map(lambda *xs: jnp.concatenate(xs),
                           *[b for b, _ in chunks])
        assert utils.tree_max_abs_diff(batch, cat) == 0.0
        np.testing.assert_array_equal(
            np.asarray(sizes),
            np.concatenate([np.asarray(s) for _, s in chunks]))
        np.testing.assert_array_equal(np.asarray(stream.cohort_sizes(k_sel)),
                                      np.asarray(sizes))

    def test_streaming_with_quantized_channel(self, image_ds):
        ds, params, apply = image_ds
        opt = opt_lib.adam(1e-2)
        cfg = round_engine.EngineConfig(
            algorithm="dcco", lam=LAM, chunk_rounds=2, cohort_chunk=2,
            channel=comm.QuantizedChannel(8))
        eng = round_engine.RoundEngine(
            apply, opt, ds.make_streaming_sampler(8, 2), cfg)
        p, s, m = eng.run(params, opt.init(params), jax.random.PRNGKey(3), 2)
        assert bool(jnp.isfinite(m.loss).all())
        # wire accounting is the same K-client payload math as materialized
        cfg_m = round_engine.EngineConfig(
            algorithm="dcco", lam=LAM, chunk_rounds=2,
            channel=comm.QuantizedChannel(8))
        eng_m = round_engine.RoundEngine(apply, opt,
                                         ds.make_round_sampler(8), cfg_m)
        pm, sm, mm = eng_m.run(params, opt.init(params),
                               jax.random.PRNGKey(3), 2)
        np.testing.assert_allclose(np.asarray(m.wire_bytes),
                                   np.asarray(mm.wire_bytes))

    def test_streaming_with_hierarchy_chunk_holds_whole_edges(self, image_ds):
        ds, params, apply = image_ds
        opt = opt_lib.adam(1e-2)
        ch = hierarchy.HierarchicalChannel(
            4, client_channel=comm.QuantizedChannel(8))
        cfg = round_engine.EngineConfig(algorithm="dcco", lam=LAM,
                                        chunk_rounds=2, cohort_chunk=4,
                                        channel=ch)
        eng = round_engine.RoundEngine(
            apply, opt, ds.make_streaming_sampler(8, 4), cfg)
        p, s, m = eng.run(params, opt.init(params), jax.random.PRNGKey(3), 2)
        assert bool(jnp.isfinite(m.loss).all())
        assert float(m.wire_bytes[0]) > 0

    def test_streaming_dense_hierarchy_matches_flat_streaming(self, image_ds):
        ds, params, apply = image_ds
        opt = opt_lib.adam(1e-2)
        rng = jax.random.PRNGKey(3)
        outs = []
        for ch in (None, hierarchy.HierarchicalChannel(4)):
            cfg = round_engine.EngineConfig(algorithm="dcco", lam=LAM,
                                            chunk_rounds=2, cohort_chunk=4,
                                            channel=ch)
            eng = round_engine.RoundEngine(
                apply, opt, ds.make_streaming_sampler(8, 4), cfg)
            outs.append(eng.run(params, opt.init(params), rng, 2))
        assert utils.tree_max_abs_diff(outs[0][0], outs[1][0]) == 0.0

    def test_streaming_guards(self, image_ds):
        ds, params, apply = image_ds
        opt = opt_lib.adam(1e-2)

        def build(cfg, sampler):
            return round_engine.RoundEngine(apply, opt, sampler, cfg)

        stream = ds.make_streaming_sampler(8, 2)
        base = round_engine.EngineConfig(algorithm="dcco", cohort_chunk=2)
        with pytest.raises(ValueError, match="chunkable sampler"):
            build(base, ds.make_round_sampler(8))
        with pytest.raises(ValueError, match="stats round only"):
            build(base._replace(algorithm="fedavg_cco"), stream)
        with pytest.raises(ValueError, match="SCAFFOLD"):
            build(base._replace(scaffold=True), stream)
        with pytest.raises(ValueError, match="stats_kernel"):
            build(base._replace(stats_kernel="interpret"), stream)
        with pytest.raises(ValueError, match="stream it or shard it"):
            build(base._replace(cohort_axis="data"), stream)
        with pytest.raises(ValueError, match="cohort_chunk=4"):
            build(base._replace(cohort_chunk=4), stream)
        with pytest.raises(ValueError, match="does not divide"):
            ds.make_streaming_sampler(8, 3)
        # hierarchy whose edges don't fit the chunk fails at trace time
        ch = hierarchy.HierarchicalChannel(
            2, client_channel=comm.QuantizedChannel(8))
        eng = build(base._replace(channel=ch), stream)   # 2 < edge size 4
        with pytest.raises(ValueError, match="whole edges"):
            eng.run(params, opt.init(params), jax.random.PRNGKey(0), 1)
