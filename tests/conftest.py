import os

# Tests run on the single real CPU device (the dry-run sets its own flags in
# a separate process). Force deterministic, quiet jax.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ.setdefault("JAX_ENABLE_X64", "0")

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
