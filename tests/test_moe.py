"""MoE routing: capacity enforcement, combine-weight sanity, shared experts,
load-balance aux loss."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models import moe


def _cfg(**kw):
    base = dict(num_experts=4, num_shared_experts=1, top_k=2, d_ff=16,
                capacity_factor=1.5, balance_weight=0.01)
    base.update(kw)
    return MoEConfig(**base)


def _setup(rng_key, cfg, b=2, s=8, d=32):
    p = moe.moe_init(rng_key, d, cfg, jnp.float32)
    x = jax.random.normal(rng_key, (b, s, d))
    return p, x


class TestRouting:
    def test_output_shape_finite(self, rng_key):
        cfg = _cfg()
        p, x = _setup(rng_key, cfg)
        y, aux = moe.moe_forward(p, x, cfg)
        assert y.shape == x.shape
        assert bool(jnp.isfinite(y).all())
        assert float(aux["balance"]) > 0

    def test_shared_expert_always_contributes(self, rng_key):
        """Zeroing all routed experts must leave the shared-expert output."""
        cfg = _cfg()
        p, x = _setup(rng_key, cfg)
        p_zeroed = dict(p, experts=jax.tree.map(jnp.zeros_like, p["experts"]))
        y, _ = moe.moe_forward(p_zeroed, x, cfg)
        from repro.models.common import swiglu
        np.testing.assert_allclose(np.asarray(y), np.asarray(swiglu(p["shared"], x)),
                                   rtol=1e-5, atol=1e-6)

    def test_no_shared_expert(self, rng_key):
        cfg = _cfg(num_shared_experts=0)
        p, x = _setup(rng_key, cfg)
        assert "shared" not in p
        y, _ = moe.moe_forward(p, x, cfg)
        assert bool(jnp.isfinite(y).all())

    def test_capacity_drops_overflow(self, rng_key):
        """With capacity factor ~0 almost every token must be dropped and the
        routed output goes to ~zero (shared experts disabled)."""
        cfg = _cfg(num_shared_experts=0, capacity_factor=1e-6)
        p, x = _setup(rng_key, cfg, b=1, s=64)
        y, aux = moe.moe_forward(p, x, cfg)
        assert float(aux["dropped_frac"]) > 0.5

    def test_single_token_decode_path(self, rng_key):
        cfg = _cfg()
        p, x = _setup(rng_key, cfg, b=2, s=1)
        y, _ = moe.moe_forward(p, x, cfg, group_size=2)
        assert y.shape == x.shape
        assert bool(jnp.isfinite(y).all())

    def test_balance_loss_uniform_router_is_one(self, rng_key):
        """With a zero router (uniform probs) the GShard balance loss == E *
        sum(me*ce)/k == 1 in expectation."""
        cfg = _cfg(num_shared_experts=0)
        p, x = _setup(rng_key, cfg, b=4, s=64)
        p = dict(p, router={"w": jnp.zeros_like(p["router"]["w"])})
        _, aux = moe.moe_forward(p, x, cfg)
        assert 0.8 < float(aux["balance"]) < 1.2

    def test_group_size_invariance_without_drops(self, rng_key):
        cfg = _cfg(capacity_factor=8.0)
        p, x = _setup(rng_key, cfg, b=2, s=16)
        y1, _ = moe.moe_forward(p, x, cfg, group_size=32)
        y2, _ = moe.moe_forward(p, x, cfg, group_size=8)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-5)

    def test_gradients_flow_to_router_and_experts(self, rng_key):
        cfg = _cfg()
        p, x = _setup(rng_key, cfg)

        def loss(p_):
            y, aux = moe.moe_forward(p_, x, cfg)
            return (y ** 2).mean() + 0.01 * aux["balance"]

        g = jax.grad(loss)(p)
        assert float(jnp.abs(g["router"]["w"]).max()) > 0
        assert float(jnp.abs(g["experts"]["gate"]).max()) > 0
