"""Beyond-paper performance features: int8 KV cache, parallel block,
FSDP sharding rules, exact microbatching, analytic cost model validation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import utils
from repro.configs.base import DualEncoderConfig, TrainConfig, get_config
from repro.launch import steps as steps_lib
from repro.models import attention as attn, dual_encoder, transformer
from repro.optim import optimizers as opt_lib


class TestInt8KvCache:
    def test_quantize_roundtrip(self, rng_key):
        x = jax.random.normal(rng_key, (2, 8, 4, 16)) * 3.0
        q, s = attn._quantize_kv(x)
        x2 = attn._dequantize_kv(q, s, jnp.float32)
        err = float(jnp.max(jnp.abs(x - x2)))
        assert err < float(jnp.max(jnp.abs(x))) / 100, f"int8 err {err}"
        assert q.dtype == jnp.int8

    @pytest.mark.parametrize("arch", ["musicgen-large", "tinyllama-1.1b"])
    def test_decode_accuracy(self, arch, rng_key):
        cfg = get_config(arch, smoke=True)
        params = transformer.init_params(cfg, rng_key)
        toks = jax.random.randint(rng_key, (2, 16), 0, cfg.vocab_size)
        h = transformer.forward(cfg, params, toks)
        ref = transformer.logits_from_hidden(cfg, params, h[:, -1])
        c = cfg.replace(kv_cache_dtype="int8")
        cache = transformer.init_cache(c, 2, 20)
        _, cache = transformer.prefill(c, params, toks[:, :15], cache)
        ld, _ = transformer.decode_step(c, params, cache, toks[:, 15:16])
        scale = float(jnp.max(jnp.abs(ref)))
        assert float(jnp.max(jnp.abs(ref - ld))) < 0.05 * max(scale, 1.0)

    def test_cache_is_half_size(self):
        cfg = get_config("tinyllama-1.1b", smoke=True)
        c_full = transformer.init_cache(cfg, 2, 64)
        c_int8 = transformer.init_cache(cfg.replace(kv_cache_dtype="int8"), 2, 64)
        assert utils.tree_bytes(c_int8) < 0.65 * utils.tree_bytes(c_full)

    def test_int8_sliding_window_ring(self, rng_key):
        cfg = get_config("tinyllama-1.1b", smoke=True).replace(
            kv_cache_dtype="int8", sliding_window=8, attn_impl="naive")
        params = transformer.init_params(cfg, rng_key)
        toks = jax.random.randint(rng_key, (1, 20), 0, cfg.vocab_size)
        cache = transformer.init_cache(cfg, 1, max_len=8)
        _, cache = transformer.prefill(cfg, params, toks[:, :12], cache)
        for t in range(12, 20):
            logits, cache = transformer.decode_step(cfg, params, cache,
                                                    toks[:, t:t + 1])
        assert bool(jnp.isfinite(logits).all())


class TestParallelBlock:
    def test_forward_decode_consistency(self, rng_key):
        cfg = get_config("granite-3-8b", smoke=True).replace(parallel_block=True)
        params = transformer.init_params(cfg, rng_key)
        toks = jax.random.randint(rng_key, (2, 16), 0, cfg.vocab_size)
        h = transformer.forward(cfg, params, toks)
        ref = transformer.logits_from_hidden(cfg, params, h[:, -1])
        cache = transformer.init_cache(cfg, 2, 20)
        _, cache = transformer.prefill(cfg, params, toks[:, :15], cache)
        ld, _ = transformer.decode_step(cfg, params, cache, toks[:, 15:16])
        scale = float(jnp.max(jnp.abs(ref)))
        assert float(jnp.max(jnp.abs(ref - ld))) < 2e-2 * max(scale, 1.0)

    def test_differs_from_sequential(self, rng_key):
        cfg = get_config("granite-3-8b", smoke=True)
        params = transformer.init_params(cfg, rng_key)
        toks = jax.random.randint(rng_key, (2, 8), 0, cfg.vocab_size)
        h1 = transformer.forward(cfg, params, toks)
        h2 = transformer.forward(cfg.replace(parallel_block=True), params, toks)
        assert float(jnp.max(jnp.abs(h1 - h2))) > 1e-4


class TestLayerChunking:
    @pytest.mark.parametrize("chunks", [1, 2])
    def test_chunked_scan_identical(self, chunks, rng_key):
        cfg = get_config("tinyllama-1.1b", smoke=True)   # 2 superblocks
        params = transformer.init_params(cfg, rng_key)
        toks = jax.random.randint(rng_key, (2, 8), 0, cfg.vocab_size)
        ref = transformer.forward(cfg, params, toks)
        out = transformer.forward(cfg.replace(layer_chunks=chunks), params, toks)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=1e-5, atol=1e-6)

    def test_unrolled_identical(self, rng_key):
        cfg = get_config("tinyllama-1.1b", smoke=True)
        params = transformer.init_params(cfg, rng_key)
        toks = jax.random.randint(rng_key, (2, 8), 0, cfg.vocab_size)
        ref = transformer.forward(cfg, params, toks)
        out = transformer.forward(cfg.replace(scan_layers=False), params, toks)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=1e-4, atol=1e-5)


class TestMicrobatchedDcco:
    def test_exact_vs_full_batch(self, rng_key):
        """The microbatched two-phase step == the single-batch step
        (Appendix A inside the device)."""
        cfg = get_config("tinyllama-1.1b", smoke=True)
        de = DualEncoderConfig(proj_dims=(16, 16), lambda_cco=5.0)
        opt = opt_lib.sgd(0.1)
        params = dual_encoder.init_dual_encoder(rng_key, cfg, de)
        toks = jax.random.randint(rng_key, (8, 16), 0, cfg.vocab_size)
        batch = {"view1": {"tokens": toks},
                 "view2": {"tokens": jnp.roll(toks, 1, -1)}}
        tcfg = TrainConfig(seq_len=16, global_batch=8)
        outs = {}
        for nm in (1, 4):
            step = steps_lib.make_dcco_train_step(cfg, de, tcfg, opt,
                                                  num_microbatches=nm)
            p2, _, m = step(params, opt.init(params), batch)
            outs[nm] = (p2, float(m["loss"]))
        np.testing.assert_allclose(outs[1][1], outs[4][1], rtol=1e-4)
        diff = utils.tree_max_abs_diff(outs[1][0], outs[4][0])
        upd = utils.tree_max_abs_diff(outs[1][0], params) + 1e-12
        assert diff / upd < 1e-2, f"relative {diff / upd}"


class TestCostModel:
    def test_flops_match_xla_on_scanfree_config(self, rng_key):
        """Validate the analytic per-layer flops against XLA cost analysis on
        a configuration with NO loops (unrolled layers, naive attention)."""
        from benchmarks import costmodel
        cfg = get_config("tinyllama-1.1b", smoke=True).replace(
            scan_layers=False, attn_impl="naive", dtype="float32")
        params = transformer.init_params(cfg, rng_key)
        b, s = 2, 64
        toks = jax.random.randint(rng_key, (b, s), 0, cfg.vocab_size)

        def fwd(p, t):
            return transformer.forward(cfg, p, t).sum()

        compiled = jax.jit(fwd).lower(params, toks).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):   # older jax returns [dict]
            ca = ca[0]
        xla_flops = ca["flops"]
        counts = costmodel.param_counts(cfg)
        analytic = (2.0 * (counts["active"] - counts["embed"]) * b * s
                    + costmodel._attn_layers(cfg)
                    * costmodel._attn_quad_flops(cfg, b, s, s))
        ratio = xla_flops / analytic
        assert 0.7 < ratio < 1.5, f"xla={xla_flops:.3e} analytic={analytic:.3e}"

    def test_roofline_rows_complete(self):
        from benchmarks import roofline
        rows = roofline.build_table()
        assert len(rows) == 40  # 10 archs x 4 shapes
        for r in rows:
            assert r["dominant"] in ("compute", "memory", "collective")
            assert r["step_lower_bound_s"] > 0
