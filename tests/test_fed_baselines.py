"""Federated baselines (paper Sec 2/4): FedAvg+CCO, FedAvg+contrastive,
and the App.-C predictive-loss collapse probe."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cco, fed_sim, losses
from repro.optim import optimizers as opt_lib


def _enc(key, d_in=8, d=4):
    params = {"w": jax.random.normal(key, (d_in, d)) * 0.5}

    def apply(p, batch):
        return batch["v1"] @ p["w"], batch["v2"] @ p["w"]

    return params, apply


def _data(key, clients, n, d_in=8):
    k1, k2 = jax.random.split(key)
    base = jax.random.normal(k1, (clients, n, d_in))
    return {"v1": base, "v2": base + 0.1 * jax.random.normal(k2, (clients, n, d_in))}


class TestFedAvgBaselines:
    @pytest.mark.parametrize("loss_kind", ["cco", "contrastive"])
    def test_round_runs_and_is_finite(self, rng_key, loss_kind):
        params, apply = _enc(rng_key)
        data = _data(rng_key, 4, 4)
        sizes = jnp.full((4,), 4, jnp.int32)
        opt = opt_lib.adam(1e-2)
        p, _, m = fed_sim.fedavg_round(apply, params, opt.init(params), opt,
                                       data, sizes, loss_kind=loss_kind,
                                       client_lr=0.1)
        assert jnp.isfinite(m.loss)

    def test_fedavg_cco_differs_from_dcco(self, rng_key):
        """Without stats aggregation the update is different (Sec 3.3: naive
        FedAvg+CCO is NOT equivalent to centralized training)."""
        params, apply = _enc(rng_key)
        data = _data(rng_key, 4, 4)
        sizes = jnp.full((4,), 4, jnp.int32)
        opt = opt_lib.sgd(0.1)
        p_dcco, _, _ = fed_sim.dcco_round(apply, params, opt.init(params), opt,
                                          data, sizes, client_lr=1.0)
        p_fa, _, _ = fed_sim.fedavg_round(apply, params, opt.init(params), opt,
                                          data, sizes, loss_kind="cco",
                                          client_lr=1.0)
        from repro import utils
        assert utils.tree_max_abs_diff(p_dcco, p_fa) > 1e-6

    def test_dcco_trains_with_single_sample_clients_fedavg_cannot(self, rng_key):
        """Paper Table 1, 1 sample/client: per-client CCO stats are degenerate
        (zero variance -> no learning signal), DCCO's aggregated stats are not."""
        params, apply = _enc(rng_key)
        data = _data(rng_key, clients=16, n=1)
        sizes = jnp.ones((16,), jnp.int32)
        zf, zg = apply(params, jax.tree.map(lambda x: x.reshape(16, -1), data))
        st_one = cco.encoding_stats(zf[:1], zg[:1])
        c_one = cco.correlation_matrix(st_one)
        st_agg = cco.encoding_stats(zf, zg)
        c_agg = cco.correlation_matrix(st_agg)
        # single-sample variance is 0 -> correlations degenerate (~0 with eps)
        assert float(jnp.max(jnp.abs(c_one))) < 0.1
        assert float(jnp.max(jnp.abs(c_agg))) > 0.5


class TestCollapseProbe:
    """App. C, footnote 1: without batch statistics the predictive (BYOL/
    SimSiam) objective admits a degenerate constant-encoder solution — 'the
    loss quickly drops close to its lowest possible value and the model does
    not learn'. The CCO loss does not: collapsed encodings have zero
    variance, so its correlation terms cannot be satisfied. We assert the
    landscape property directly (deterministic, architecture-independent)."""

    def test_constant_encoder_is_byol_minimum_but_not_cco(self, rng_key):
        n, d = 64, 8
        z_const = jnp.ones((n, d)) * 0.7 + 1e-4 * jax.random.normal(rng_key, (n, d))
        # predictive loss at the collapsed point: at (its) global minimum
        byol_at_collapse = float(losses.byol_predictive_loss(z_const, z_const))
        assert byol_at_collapse < 1e-6
        # CCO at the collapsed point: large (>= on-diagonal term ~ d)
        cco_at_collapse = float(cco.cco_loss(z_const, z_const, lam=5.0))
        assert cco_at_collapse > 1.0
        # and a healthy (whitened) encoder has much lower CCO loss
        zf = jax.random.normal(jax.random.PRNGKey(1), (4096, d))
        zc = zf - zf.mean(0)
        u, s, vt = jnp.linalg.svd(zc, full_matrices=False)
        zw = u * jnp.sqrt(4096)
        assert float(cco.cco_loss(zw, zw, lam=5.0)) < 0.1 * cco_at_collapse

    def test_collapse_direction_is_descent_for_byol_not_cco(self, rng_key):
        """Shrinking encodings toward a constant strictly reduces the
        predictive loss to ~0 (collapse is its descent direction) while the
        CCO loss gains nothing along the path (correlations are affine-
        invariant) and explodes at the collapsed endpoint."""
        k1, k2 = jax.random.split(rng_key)
        zf = jax.random.normal(k1, (128, 6))
        zg = zf + 0.3 * jax.random.normal(k2, (128, 6))

        const = jnp.ones((6,)) * 2.0     # the collapse target

        def shrink(z, t):
            return const[None] * t + z * (1 - t)

        ts = (0.0, 0.7, 0.99)
        byol = [float(losses.byol_predictive_loss(shrink(zf, t), shrink(zg, t)))
                for t in ts]
        cco_v = [float(cco.cco_loss(shrink(zf, t), shrink(zg, t), 5.0))
                 for t in ts]
        assert byol[2] < byol[1] < byol[0], f"byol not decreasing: {byol}"
        assert byol[2] < 1e-4
        # CCO gains nothing along the collapse path...
        assert cco_v[2] > 0.9 * cco_v[0], f"cco: {cco_v}"
        # ...and explodes at the collapsed endpoint
        z_end = shrink(zf, 1.0) + 1e-5 * zf
        assert float(cco.cco_loss(z_end, z_end, 5.0)) > 10 * cco_v[0]


class TestClientSampling:
    def test_sample_without_replacement(self, rng_key):
        sel = fed_sim.sample_clients(rng_key, 100, 32)
        assert len(np.unique(np.asarray(sel))) == 32
        assert int(sel.max()) < 100
