"""D-VICReg (paper Sec. 6 future work): the aggregated-statistics strategy
with VICReg's seven statistics — same linearity, same equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro import utils
from repro.core import cco, vicreg
from repro.optim import optimizers as opt_lib

SET = settings(max_examples=20, deadline=None)


class TestVicregStats:
    @SET
    @given(clients=st.integers(2, 5), n_per=st.integers(1, 4),
           d=st.integers(2, 12), seed=st.integers(0, 2**16))
    def test_linearity(self, clients, n_per, d, seed):
        """All seven statistics aggregate exactly (the property that makes
        the paper's strategy transfer to VICReg)."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        zf = jax.random.normal(k1, (clients * n_per, d))
        zg = jax.random.normal(k2, (clients * n_per, d))
        st_global = vicreg.vicreg_stats(zf, zg)
        st_k = jax.vmap(vicreg.vicreg_stats)(
            zf.reshape(clients, n_per, d), zg.reshape(clients, n_per, d))
        agg = cco.weighted_average_stats(st_k, jnp.ones((clients,)) * n_per)
        for k in vicreg.VICREG_STAT_KEYS:
            np.testing.assert_allclose(np.asarray(agg[k]),
                                       np.asarray(st_global[k]),
                                       rtol=2e-5, atol=2e-6)

    def test_loss_matches_direct_formula(self, rng_key):
        """Stats-based VICReg == the direct per-sample formulation."""
        k1, k2 = jax.random.split(rng_key)
        zf = jax.random.normal(k1, (64, 8))
        zg = zf + 0.2 * jax.random.normal(k2, (64, 8))
        via_stats = float(vicreg.vicreg_loss(zf, zg))
        # direct
        inv = float(jnp.mean(jnp.sum((zf - zg) ** 2, -1) / 8))
        def v(z):
            return float(jnp.mean(jax.nn.relu(
                1.0 - jnp.sqrt(jnp.var(z, axis=0) + 1e-4))))
        def c(z):
            zc = z - z.mean(0)
            cov = zc.T @ zc / z.shape[0]
            return float((jnp.sum(cov ** 2) - jnp.sum(jnp.diag(cov) ** 2)) / 8)
        direct = 25 * inv + 25 * (v(zf) + v(zg)) + (c(zf) + c(zg))
        np.testing.assert_allclose(via_stats, direct, rtol=1e-4)

    def test_collapse_penalized(self, rng_key):
        z = jnp.ones((32, 6)) * 0.5
        healthy = jax.random.normal(rng_key, (32, 6))
        assert float(vicreg.vicreg_loss(z, z)) > \
            float(vicreg.vicreg_loss(healthy, healthy))


class TestDVicregEquivalence:
    def test_per_client_equals_fused_gradient(self, rng_key):
        """Appendix-A transfers: per-client stop-grad D-VICReg gradients ==
        centralized VICReg gradients."""
        k1, k2 = jax.random.split(rng_key)
        zf = jax.random.normal(k1, (12, 6))
        zg = jax.random.normal(k2, (12, 6))
        g1 = jax.grad(lambda z: vicreg.vicreg_loss(z, zg))(zf)
        g2 = jax.grad(lambda z: vicreg.dvicreg_loss_per_client(z, zg, 4))(zf)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=1e-6)

    def test_federated_round_equals_centralized(self, rng_key):
        """Full D-VICReg round through the federated simulator == one
        centralized VICReg step (theorem holds for any stats-based loss)."""
        params = {"w": jax.random.normal(rng_key, (10, 6)) * 0.4}

        def apply(p, batch):
            return jnp.tanh(batch["v1"] @ p["w"]), jnp.tanh(batch["v2"] @ p["w"])

        k1, k2 = jax.random.split(rng_key)
        data = {"v1": jax.random.normal(k1, (5, 3, 10)),
                "v2": jax.random.normal(k2, (5, 3, 10))}
        sizes = jnp.full((5,), 3, jnp.int32)
        opt = opt_lib.sgd(0.1)

        # D-VICReg round (reusing fed_sim machinery with vicreg stats/loss)
        masks = (jnp.arange(3)[None] < sizes[:, None]).astype(jnp.float32)
        st_k = jax.vmap(lambda b1, b2, m: vicreg.vicreg_stats_masked(
            jnp.tanh(b1 @ params["w"]), jnp.tanh(b2 @ params["w"]), m))(
            data["v1"], data["v2"], masks)
        agg = cco.weighted_average_stats(st_k, sizes.astype(jnp.float32))

        def client_update(b1, b2, m):
            def loss_fn(p):
                st = vicreg.vicreg_stats_masked(
                    jnp.tanh(b1 @ p["w"]), jnp.tanh(b2 @ p["w"]), m)
                return vicreg.vicreg_loss_from_stats(cco.dcco_combine(st, agg))
            g = jax.grad(loss_fn)(params)
            return jax.tree.map(lambda x: -1.0 * x, g)  # client lr 1.0 delta

        deltas = jax.vmap(client_update)(data["v1"], data["v2"], masks)
        w = sizes.astype(jnp.float32) / sizes.sum()
        avg_delta = jax.tree.map(lambda d_: jnp.tensordot(w, d_, axes=1), deltas)
        upd, _ = opt.update(utils.tree_scale(avg_delta, -1.0), opt.init(params), params)
        p_fed = opt_lib.apply_updates(params, upd)

        # centralized VICReg step
        union1 = data["v1"].reshape(15, 10)
        union2 = data["v2"].reshape(15, 10)

        def central_loss(p):
            return vicreg.vicreg_loss(jnp.tanh(union1 @ p["w"]),
                                      jnp.tanh(union2 @ p["w"]))

        g = jax.grad(central_loss)(params)
        upd, _ = opt.update(g, opt.init(params), params)
        p_cent = opt_lib.apply_updates(params, upd)
        assert utils.tree_max_abs_diff(p_fed, p_cent) < 1e-5
