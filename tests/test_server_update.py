"""Server-optimization & client-drift subsystem (repro.server):

  * ``fedavg_sgd`` ServerUpdate is BIT-IDENTICAL (==) to the pre-existing
    hardcoded server step, for every base optimizer;
  * the adaptive FedOpt rules (fedadagrad / fedadam / fedyogi) match their
    hand-computed Reddi-style update on a toy pseudo-gradient;
  * FedProx with mu=0 is bit-identical to the plain local step; mu>0
    shrinks multi-step client drift and matches the analytic proximal
    gradient on a quadratic;
  * SCAFFOLD: the aggregated slot variates sum to ~0 around the server
    variate (sum_k w_k c_k == c), the corrected training converges to the
    true optimum of a heterogeneous quadratic federation where plain
    FedAvg stalls at a biased fixed point, DenseChannel is bit-identical
    to the channel-less path, and the variate uplink is accounted;
  * the engine carries drift state through the scan: scan-of-N == N
    Python-driven scaffold rounds, and resume via drift_state= continues
    the same trajectory.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm, utils
from repro.core import fed_sim, round_engine
from repro.optim import optimizers as opt_lib
from repro.server import (ScaffoldState, as_server_update,
                          drift as drift_lib, get_server_update,
                          optimizers as srv_opt, scaffold_init)

LAM = 5.0
F32 = jnp.float32


@pytest.fixture(scope="module")
def toy():
    key = jax.random.PRNGKey(0)
    params = {"w1": jax.random.normal(key, (10, 16)) * 0.3,
              "w2": jax.random.normal(jax.random.PRNGKey(7), (16, 6)) * 0.3}

    def apply(p, batch):
        def enc(x):
            return jnp.tanh(x @ p["w1"]) @ p["w2"]
        return enc(batch["v1"]), enc(batch["v2"])

    k1, k2 = jax.random.split(key)
    data = {"v1": jax.random.normal(k1, (8, 3, 10)),
            "v2": jax.random.normal(k2, (8, 3, 10))}
    sizes = jnp.full((8,), 3, jnp.int32)
    return params, apply, data, sizes


class TestServerUpdateExact:
    @pytest.mark.parametrize("make_opt", [
        lambda: opt_lib.sgd(0.1, momentum=0.9),
        lambda: opt_lib.adam(1e-2),
        lambda: opt_lib.lars(0.1),
    ], ids=["sgd", "adam", "lars"])
    def test_fedavg_sgd_bit_identical_to_hardcoded_path(self, toy, make_opt):
        """ServerUpdate('fedavg_sgd').step == the literal three lines every
        round body used to inline (exact equality, not allclose)."""
        params, _, data, _ = toy
        opt = make_opt()
        avg_delta = jax.tree.map(lambda p: 0.01 * jnp.ones_like(p), params)
        state = opt.init(params)
        # the pre-abstraction hardcoded path
        pseudo_grad = utils.tree_scale(avg_delta, -1.0)
        updates, s_ref = opt.update(pseudo_grad, state, params)
        p_ref = opt_lib.apply_updates(params, updates)
        # the abstraction
        p_new, s_new = as_server_update(opt).step(params, opt.init(params),
                                                  avg_delta)
        assert utils.tree_max_abs_diff(p_ref, p_new) == 0.0
        assert utils.tree_max_abs_diff(s_ref, s_new) == 0.0

    def test_round_accepts_optimizer_or_serverupdate_identically(self, toy):
        params, apply, data, sizes = toy
        opt = opt_lib.adam(1e-2)
        p1, s1, m1 = fed_sim.dcco_round(apply, params, opt.init(params), opt,
                                        data, sizes, lam=LAM)
        p2, s2, m2 = fed_sim.dcco_round(apply, params, opt.init(params),
                                        as_server_update(opt), data, sizes,
                                        lam=LAM)
        assert utils.tree_max_abs_diff(p1, p2) == 0.0
        assert float(m1.loss) == float(m2.loss)

    def test_as_server_update_is_idempotent_and_typed(self):
        su = get_server_update("fedavg_sgd", server_lr=0.1)
        assert as_server_update(su) is su
        with pytest.raises(TypeError):
            as_server_update(object())
        with pytest.raises(ValueError):
            get_server_update("fedprox")   # drift correction, not a server opt
        with pytest.raises(ValueError):
            get_server_update("fedadam")   # needs server_lr


class TestAdaptiveServerOptimizers:
    def _pseudo_grad(self, params):
        return jax.tree.map(
            lambda p: 0.1 * jnp.arange(p.size, dtype=F32).reshape(p.shape)
            / p.size - 0.05, params)

    @pytest.mark.parametrize("name", ["fedadagrad", "fedadam", "fedyogi"])
    def test_matches_hand_computed_reddi_update(self, name):
        lr, b1, b2, tau = 0.05, 0.9, 0.99, 1e-3
        params = {"w": jnp.ones((4,))}
        g = {"w": jnp.array([0.2, -0.1, 0.05, 0.0])}
        if name == "fedadagrad":
            opt = srv_opt.fedadagrad(lr, tau=tau)
            b1_eff = 0.0
        else:
            opt = {"fedadam": srv_opt.fedadam,
                   "fedyogi": srv_opt.fedyogi}[name](lr, b1=b1, b2=b2, tau=tau)
            b1_eff = b1
        state = opt.init(params)
        # two steps so the v-recursions differ between the variants
        for _ in range(2):
            updates, state = opt.update(g, state, params)
        gv = np.asarray(g["w"])
        m = np.zeros(4)
        v = np.zeros(4)
        for _ in range(2):
            m = b1_eff * m + (1 - b1_eff) * gv
            g2 = gv * gv
            if name == "fedadagrad":
                v = v + g2
            elif name == "fedadam":
                v = b2 * v + (1 - b2) * g2
            else:
                v = v - (1 - b2) * g2 * np.sign(v - g2)
            ref = -lr * m / (np.sqrt(v) + tau)
        np.testing.assert_allclose(np.asarray(updates["w"]), ref, rtol=1e-6)

    def test_fedavgm_is_server_momentum_sgd(self):
        params = {"w": jnp.ones((3,))}
        g = {"w": jnp.array([1.0, -2.0, 0.5])}
        a, b = srv_opt.fedavgm(0.1, momentum=0.9), opt_lib.sgd(0.1, momentum=0.9)
        ua, _ = a.update(g, a.init(params), params)
        ub, _ = b.update(g, b.init(params), params)
        assert utils.tree_max_abs_diff(ua, ub) == 0.0

    @pytest.mark.parametrize("name", ["fedavgm", "fedadagrad", "fedadam",
                                      "fedyogi"])
    def test_engine_trains_with_strategy(self, toy, name):
        params, apply, data, sizes = toy
        su = get_server_update(name, server_lr=0.05)

        def sampler(k_sel, k_aug):
            return data, sizes

        cfg = round_engine.EngineConfig(algorithm="dcco", lam=LAM,
                                        chunk_rounds=3, server_update=su)
        eng = round_engine.RoundEngine(apply, su, sampler, cfg)
        p, s, m = eng.run(params, su.init(params), jax.random.PRNGKey(3), 3)
        assert bool(jnp.isfinite(m.loss).all())
        assert utils.tree_max_abs_diff(p, params) > 0.0


class TestFedProx:
    def test_mu0_bit_identical_to_plain_local_step(self, toy):
        params, apply, data, sizes = toy

        def loss_fn(p):
            zf, zg = apply(p, jax.tree.map(lambda x: x[0], data))
            return jnp.sum(zf * zg) * 1e-2

        d0, l0 = fed_sim.client_local_steps(loss_fn, params, 0.1, 3)
        d1, l1 = fed_sim.client_local_steps(loss_fn, params, 0.1, 3,
                                            prox_mu=0.0)
        assert utils.tree_max_abs_diff(d0, d1) == 0.0
        assert float(l0) == float(l1)
        opt = opt_lib.adam(1e-2)
        p0, s0, m0 = fed_sim.dcco_round(apply, params, opt.init(params), opt,
                                        data, sizes, lam=LAM, local_steps=2,
                                        client_lr=0.1)
        p1, s1, m1 = fed_sim.dcco_round(apply, params, opt.init(params), opt,
                                        data, sizes, lam=LAM, local_steps=2,
                                        client_lr=0.1, prox_mu=0.0)
        assert utils.tree_max_abs_diff(p0, p1) == 0.0

    def test_matches_analytic_proximal_gradient_on_quadratic(self):
        """f(w) = 0.5||w - t||^2 with proximal pull toward w0 = 0:
        step s: w <- w - lr * ((w - t) + mu * w)."""
        t = jnp.array([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros((3,))}
        lr, mu, L = 0.1, 0.7, 4

        def loss_fn(p):
            return 0.5 * jnp.sum((p["w"] - t) ** 2)

        delta, _ = fed_sim.client_local_steps(loss_fn, params, lr, L,
                                              prox_mu=mu)
        w = np.zeros(3)
        for _ in range(L):
            w = w - lr * ((w - np.asarray(t)) + mu * w)
        np.testing.assert_allclose(np.asarray(delta["w"]), w, rtol=1e-6)

    def test_prox_shrinks_client_drift(self, toy):
        params, apply, data, sizes = toy

        def one_client_delta(mu):
            def loss_fn(p):
                zf, zg = apply(p, jax.tree.map(lambda x: x[0], data))
                st = fed_sim.cco.encoding_stats_masked(
                    zf, zg, jnp.ones(zf.shape[0]))
                return fed_sim.cco.cco_loss_from_stats(st, LAM)
            d, _ = fed_sim.client_local_steps(loss_fn, params, 0.1, 5,
                                              prox_mu=mu)
            return float(utils.tree_norm(d))

        assert one_client_delta(5.0) < one_client_delta(0.0)


class TestScaffold:
    def test_variates_sum_to_zero_after_aggregation(self, toy):
        """Invariant: with constant round weights, sum_k w_k c_k == c, i.e.
        the aggregated (c_k - c) corrections cancel — client variates are a
        zero-mean decomposition of the server variate."""
        params, apply, data, sizes = toy
        opt = opt_lib.adam(1e-2)
        st = opt.init(params)
        p, d = params, scaffold_init(params, 8)
        for _ in range(4):
            p, st, d, m = fed_sim.dcco_round(
                apply, p, st, opt, data, sizes, lam=LAM, client_lr=0.05,
                local_steps=2, scaffold_state=d)
        w = sizes.astype(F32) / jnp.sum(sizes.astype(F32))
        resid = jax.tree.map(
            lambda ck, c: jnp.tensordot(w, ck, axes=1) - c, d.c_slots, d.c)
        assert float(utils.tree_norm(resid)) < 1e-4 * max(
            1.0, float(utils.tree_norm(d.c)))

    def test_scaffold_fixes_fedavg_bias_on_heterogeneous_quadratics(self):
        """The canonical SCAFFOLD result: K clients minimizing
        0.5||A_k w - b_k||^2 with heterogeneous A_k and many local steps.
        FedAvg's fixed point is biased away from the global optimum;
        SCAFFOLD converges to it."""
        K, d = 8, 6
        rng = np.random.RandomState(0)
        A = np.stack([np.diag(rng.uniform(0.2, 3.0, d)) for _ in range(K)])
        b = np.stack([rng.randn(d) for _ in range(K)])
        H = sum(a.T @ a for a in A)
        w_star = np.linalg.solve(H / K, sum(a.T @ bb for a, bb
                                            in zip(A, b)) / K)
        A_s, b_s = jnp.asarray(A), jnp.asarray(b)
        params = {"w": jnp.zeros((d,))}
        opt = opt_lib.sgd(1.0)        # server applies the avg delta directly
        su = as_server_update(opt)
        L, clr = 10, 0.05
        w_agg = jnp.full((K,), 1.0 / K)

        def run(scaffold: bool):
            p, st = params, opt.init(params)
            dstate = scaffold_init(params, K) if scaffold else None
            for _ in range(150):
                def client_update(ak, bk, corr=None):
                    def loss_fn(pp):
                        e = ak @ pp["w"] - bk
                        return 0.5 * jnp.dot(e, e)
                    return fed_sim.client_local_steps(loss_fn, p, clr, L,
                                                      correction=corr)
                if scaffold:
                    corr = drift_lib.scaffold_corrections(dstate)
                    deltas, _ = jax.vmap(client_update)(A_s, b_s, corr)
                else:
                    deltas, _ = jax.vmap(client_update)(A_s, b_s)
                avg = jax.tree.map(lambda x: jnp.tensordot(w_agg, x, axes=1),
                                   deltas)
                p, st = su.step(p, st, avg)
                if scaffold:
                    dstate, _ = fed_sim._scaffold_round_tail(
                        dstate, deltas, clr, L, w_agg, None, None)
            return np.asarray(p["w"])

        err_fedavg = np.linalg.norm(run(False) - w_star)
        err_scaffold = np.linalg.norm(run(True) - w_star)
        assert err_fedavg > 1e-2          # the bias is real
        assert err_scaffold < 1e-5        # and scaffold removes it

    def test_dense_channel_bit_identical_and_variate_bytes_accounted(self, toy):
        params, apply, data, sizes = toy
        opt = opt_lib.adam(1e-2)
        d0 = scaffold_init(params, 8)
        p1, s1, d1, m1 = fed_sim.dcco_round(
            apply, params, opt.init(params), opt, data, sizes, lam=LAM,
            client_lr=0.05, local_steps=2, scaffold_state=d0)
        p2, s2, d2, m2 = fed_sim.dcco_round(
            apply, params, opt.init(params), opt, data, sizes, lam=LAM,
            client_lr=0.05, local_steps=2, scaffold_state=d0,
            channel=comm.DenseChannel(), channel_key=jax.random.PRNGKey(42))
        assert utils.tree_max_abs_diff(p1, p2) == 0.0
        assert utils.tree_max_abs_diff(d1.c, d2.c) == 0.0
        assert utils.tree_max_abs_diff(d1.c_slots, d2.c_slots) == 0.0
        # without scaffold, the same channeled round ships fewer bytes:
        # the "variate" phase adds one params-sized payload per client
        p3, s3, m3 = fed_sim.dcco_round(
            apply, params, opt.init(params), opt, data, sizes, lam=LAM,
            client_lr=0.05, local_steps=2,
            channel=comm.DenseChannel(), channel_key=jax.random.PRNGKey(42))
        assert float(m2.wire_bytes) > float(m3.wire_bytes)

    def test_dropped_slots_keep_their_variates(self, toy):
        """Under client dropout, a slot that did not report keeps its old
        control variate (it cannot have refreshed it)."""
        params, apply, data, sizes = toy
        opt = opt_lib.adam(1e-2)
        d0 = scaffold_init(params, 8)
        # one warm round so variates are non-zero, then a dropout round
        p, st, d1, _ = fed_sim.dcco_round(
            apply, params, opt.init(params), opt, data, sizes, lam=LAM,
            client_lr=0.05, local_steps=2, scaffold_state=d0)
        ch = comm.DropoutChannel(0.5)
        key = jax.random.PRNGKey(123)
        ctx = ch.begin_round(key, sizes)
        mask = np.asarray(ctx.mask)
        assert 0 < mask.sum() < 8, "pick a key that drops some clients"
        p2, st2, d2, _ = fed_sim.dcco_round(
            apply, p, st, opt, data, sizes, lam=LAM, client_lr=0.05,
            local_steps=2, scaffold_state=d1, channel=ch, channel_key=key)
        kept = jax.tree.map(
            lambda new, old: np.asarray(jnp.abs(new - old).reshape(8, -1)
                                        .max(axis=1)), d2.c_slots, d1.c_slots)
        for leaf in jax.tree.leaves(kept):
            assert np.all(leaf[mask == 0.0] == 0.0)
            assert np.all(leaf[mask == 1.0] > 0.0)

    def test_dp_channel_must_noise_variates(self, toy):
        """A DP channel that does not noise the 'variate' phase would
        release the variate aggregate un-noised while reporting a finite
        epsilon — rejected loudly; including 'variate' runs."""
        params, apply, data, sizes = toy
        opt = opt_lib.sgd(0.1)
        with pytest.raises(ValueError, match="variate"):
            fed_sim.dcco_round(
                apply, params, opt.init(params), opt, data, sizes, lam=LAM,
                scaffold_state=scaffold_init(params, 8),
                channel=comm.DPGaussianChannel(0.3, clip_norm=10.0),
                channel_key=jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="variate"):
            round_engine.make_round_body(
                apply, opt, round_engine.EngineConfig(
                    scaffold=True, channel=comm.DPGaussianChannel(0.3)))
        out = fed_sim.dcco_round(
            apply, params, opt.init(params), opt, data, sizes, lam=LAM,
            scaffold_state=scaffold_init(params, 8),
            channel=comm.DPGaussianChannel(
                0.3, clip_norm=10.0,
                noise_phases=("stats", "update", "variate")),
            channel_key=jax.random.PRNGKey(0))
        assert len(out) == 4

    def test_centralized_body_rejects_drift(self, toy):
        params, apply, data, sizes = toy
        with pytest.raises(ValueError):
            round_engine.make_round_body(
                apply, opt_lib.sgd(0.1),
                round_engine.EngineConfig(algorithm="centralized",
                                          scaffold=True))
        with pytest.raises(ValueError):
            round_engine.make_round_body(
                apply, opt_lib.sgd(0.1),
                round_engine.EngineConfig(algorithm="centralized",
                                          prox_mu=0.1))


class TestEngineDrift:
    def test_scan_equals_python_loop_with_scaffold(self, toy):
        params, apply, data, sizes = toy
        opt = opt_lib.adam(1e-2)

        def sampler(k_sel, k_aug):
            return data, sizes

        cfg = round_engine.EngineConfig(algorithm="dcco", lam=LAM,
                                        chunk_rounds=4, client_lr=0.05,
                                        local_steps=2, scaffold=True)
        eng = round_engine.RoundEngine(apply, opt, sampler, cfg)
        rng = jax.random.PRNGKey(3)
        pe, se, me = eng.run(params, opt.init(params), rng, 4)
        assert isinstance(eng.drift_state, ScaffoldState)

        p, st, d = params, opt.init(params), scaffold_init(params, 8)
        losses = []
        for r in range(4):
            k_sel, k_aug = jax.random.split(jax.random.fold_in(rng, r))
            batch, sz = sampler(k_sel, k_aug)
            p, st, d, m = fed_sim.dcco_round(
                apply, p, st, opt, batch, sz, lam=LAM, client_lr=0.05,
                local_steps=2, scaffold_state=d)
            losses.append(float(m.loss))
        assert utils.tree_max_abs_diff(pe, p) < 1e-6
        assert utils.tree_max_abs_diff(eng.drift_state.c, d.c) < 1e-5
        np.testing.assert_allclose(np.asarray(me.loss), losses, rtol=1e-5,
                                   atol=1e-6)

    def test_drift_state_resume_continues_trajectory(self, toy):
        params, apply, data, sizes = toy
        opt = opt_lib.sgd(0.1)

        def sampler(k_sel, k_aug):
            return data, sizes

        cfg = round_engine.EngineConfig(algorithm="dcco", lam=LAM,
                                        chunk_rounds=4, client_lr=0.05,
                                        local_steps=2, scaffold=True)
        eng = round_engine.RoundEngine(apply, opt, sampler, cfg)
        rng = jax.random.PRNGKey(9)
        p1, s1, _ = eng.run(params, opt.init(params), rng, 4)
        d1 = eng.drift_state
        p1, s1, _ = eng.run(p1, s1, rng, 4, start_round=4, drift_state=d1)
        p2, s2, _ = eng.run(params, opt.init(params), rng, 8)
        assert utils.tree_max_abs_diff(p1, p2) < 1e-6
        assert utils.tree_max_abs_diff(eng.drift_state.c, eng.drift_state.c) == 0.0

    def test_checkpoint_resume_with_drift_and_lossy_channel(self, toy,
                                                            tmp_path):
        """The full production resume path in ONE run: SCAFFOLD variates in
        the scan carry AND a non-dense (int8) channel active, checkpointed
        mid-run, restored, and resumed — the resumed trajectory must equal
        the uninterrupted one. Previously drift resume and channel resume
        were only exercised separately; this pins their composition (the
        channel key is a fold_in off the round key, so a resume at round r
        replays the identical quantization randomness)."""
        from repro.checkpoint import restore_checkpoint
        params, apply, data, sizes = toy
        opt = opt_lib.sgd(0.1)

        def sampler(k_sel, k_aug):
            return data, sizes

        def build():
            cfg = round_engine.EngineConfig(
                algorithm="dcco", lam=LAM, chunk_rounds=2, client_lr=0.05,
                local_steps=2, scaffold=True,
                channel=comm.QuantizedChannel(8))
            return round_engine.RoundEngine(apply, opt, sampler, cfg)

        rng = jax.random.PRNGKey(17)
        # uninterrupted reference
        eng_ref = build()
        p_ref, s_ref, m_ref = eng_ref.run(params, opt.init(params), rng, 6)

        # run [0, 4), checkpointing every 2 rounds (params + opt + drift)
        eng_a = build()
        pa, sa, ma = eng_a.run(params, opt.init(params), rng, 4,
                               ckpt_dir=str(tmp_path), ckpt_every=2,
                               ckpt_name="drift_ch")
        tmpl = {"params": params, "opt": opt.init(params),
                "drift": scaffold_init(params, 8)}
        blob, step = restore_checkpoint(str(tmp_path / "drift_ch.msgpack"),
                                        tmpl)
        assert step == 4
        assert utils.tree_max_abs_diff(blob["params"], pa) < 1e-7
        assert utils.tree_max_abs_diff(blob["drift"].c_slots,
                                       eng_a.drift_state.c_slots) < 1e-7

        # resume [4, 6) from the restored blob in a FRESH engine
        eng_b = build()
        pb, sb, mb = eng_b.run(blob["params"], blob["opt"], rng, 2,
                               start_round=step, drift_state=blob["drift"])
        assert utils.tree_max_abs_diff(pb, p_ref) < 1e-6
        assert utils.tree_max_abs_diff(eng_b.drift_state.c,
                                       eng_ref.drift_state.c) < 1e-6
        np.testing.assert_allclose(np.asarray(mb.loss),
                                   np.asarray(m_ref.loss)[4:], rtol=1e-5,
                                   atol=1e-6)
        # the lossy wire was actually on in every leg
        assert float(np.sum(np.asarray(ma.wire_bytes))) > 0
        assert float(np.sum(np.asarray(mb.wire_bytes))) > 0

    def test_async_checkpoint_roundtrips_buffer_and_drift(self, toy,
                                                          tmp_path):
        """The buffered (async_k) engine's resume path: SCAFFOLD variates,
        an int8 channel, heavy-tail stragglers AND a half-full staleness
        buffer all checkpointed mid-run, restored, and resumed — the
        resumed trajectory equals the uninterrupted one, and every buffer
        field (ring partial sums, mass, K-trigger count, staleness
        counters, applied_total) round-trips through the msgpack blob."""
        from repro.checkpoint import restore_checkpoint
        from repro.core import buffer as buffer_lib
        from repro.data import latency as latency_lib
        params, apply, data, sizes = toy
        opt = opt_lib.sgd(0.1)
        lat = latency_lib.LatencyModel("heavytail", horizon=4, tail=0.8)
        sampler = latency_lib.make_async_sampler(
            lambda k1, k2: (data, sizes), lat, 8)

        def build():
            cfg = round_engine.EngineConfig(
                algorithm="dcco", lam=LAM, chunk_rounds=2, client_lr=0.05,
                local_steps=2, scaffold=True, async_k=3,
                staleness_fn="poly", latency=lat,
                channel=comm.QuantizedChannel(8))
            return round_engine.RoundEngine(apply, opt, sampler, cfg)

        rng = jax.random.PRNGKey(17)
        eng_ref = build()
        p_ref, s_ref, m_ref = eng_ref.run(params, opt.init(params), rng, 6)

        eng_a = build()
        pa, sa, ma = eng_a.run(params, opt.init(params), rng, 4,
                               ckpt_dir=str(tmp_path), ckpt_every=2,
                               ckpt_name="async_ch")
        tmpl = {"params": params, "opt": opt.init(params),
                "drift": scaffold_init(params, 8),
                "buffer": jax.tree.map(jnp.zeros_like, eng_a.buffer_state)}
        blob, step = restore_checkpoint(str(tmp_path / "async_ch.msgpack"),
                                        tmpl)
        assert step == 4
        assert isinstance(blob["buffer"], buffer_lib.AsyncState)
        restored, live = blob["buffer"], eng_a.buffer_state
        assert utils.tree_max_abs_diff(restored.buffer._asdict(),
                                       live.buffer._asdict()) < 1e-7
        assert utils.tree_max_abs_diff(restored.pending._asdict(),
                                       live.pending._asdict()) < 1e-7
        assert int(restored.applied_total) == int(live.applied_total)
        # heavy-tail delays leave REAL in-flight mass at the cut — the
        # round-trip above is not vacuously comparing zeros
        assert float(jnp.sum(restored.pending.mass)) > 0.0

        eng_b = build()
        pb, sb, mb = eng_b.run(blob["params"], blob["opt"], rng, 2,
                               start_round=step, drift_state=blob["drift"],
                               buffer_state=blob["buffer"])
        assert utils.tree_max_abs_diff(pb, p_ref) < 1e-6
        assert utils.tree_max_abs_diff(eng_b.drift_state.c,
                                       eng_ref.drift_state.c) < 1e-6
        assert int(eng_b.buffer_state.applied_total) == \
            int(eng_ref.buffer_state.applied_total)
        assert utils.tree_max_abs_diff(
            eng_b.buffer_state.buffer._asdict(),
            eng_ref.buffer_state.buffer._asdict()) < 1e-6
        np.testing.assert_allclose(np.asarray(mb.loss),
                                   np.asarray(m_ref.loss)[4:], rtol=1e-5,
                                   atol=1e-6)
        np.testing.assert_array_equal(np.asarray(mb.applied),
                                      np.asarray(m_ref.applied)[4:])
        assert float(np.sum(np.asarray(ma.wire_bytes))) > 0
        assert float(np.sum(np.asarray(mb.wire_bytes))) > 0

    def test_fedavg_body_supports_scaffold(self, toy):
        params, apply, data, sizes = toy
        su = get_server_update("fedadam", server_lr=0.05)

        def sampler(k_sel, k_aug):
            return data, sizes

        cfg = round_engine.EngineConfig(algorithm="fedavg_cco", lam=LAM,
                                        chunk_rounds=3, client_lr=0.05,
                                        local_steps=2, scaffold=True,
                                        server_update=su)
        eng = round_engine.RoundEngine(apply, su, sampler, cfg)
        p, s, m = eng.run(params, su.init(params), jax.random.PRNGKey(3), 3)
        assert bool(jnp.isfinite(m.loss).all())
        assert isinstance(eng.drift_state, ScaffoldState)
