"""The StatsObjective protocol (repro.objectives): one sufficient-
statistics abstraction behind DCCO, D-VICReg, and D-WMSE.

  * registry + stat specs match what the accumulator actually emits;
  * linearity property per registered objective — weighted average of
    per-client stats == flattened-cohort stats (the invariant paper
    Eq. 3, the fused kernel path, and the shard_map psum path rely on);
  * masked stats are bit-identical to the historical per-loss formulas
    (the copy-paste-drift satellite: one shared accumulator);
  * per-objective gradient equivalence: fused (centralized) ==
    per-client stop-grad == shard_map psum;
  * the refactored round == the pre-protocol DCCO round, exactly;
  * the variance floor: bit-invisible on healthy statistics, bounded on
    degenerate ones, and the local_steps>=2 2-sample-client NaN is gone;
  * every objective trains end-to-end through the scan engine with a
    comm channel, with wire bytes reflecting its payload;
  * validate_flags coverage for --objective.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import comm, objectives as objectives_lib, utils
from repro.core import cco, fed_sim, round_engine, vicreg, wmse
from repro.objectives import get_objective, make_shard_map_loss, per_client_loss
from repro.optim import optimizers as opt_lib

SET = settings(max_examples=15, deadline=None)

ALL_OBJECTIVES = list(objectives_lib.OBJECTIVES)


def _views(key, n, d):
    k1, k2 = jax.random.split(key)
    zf = jax.random.normal(k1, (n, d), jnp.float32)
    return zf, zf * 0.7 + 0.3 * jax.random.normal(k2, (n, d), jnp.float32)


class TestRegistry:
    def test_three_objectives_registered(self):
        assert set(ALL_OBJECTIVES) >= {"dcco", "dvicreg", "dwmse"}

    @pytest.mark.parametrize("name", ALL_OBJECTIVES)
    def test_stat_spec_matches_stats(self, name, rng_key):
        obj = get_objective(name)
        zf, zg = _views(rng_key, 10, 6)
        for stats in (obj.stats(zf, zg),
                      obj.stats_masked(zf, zg, jnp.ones((10,)))):
            assert set(stats) == set(obj.stat_keys)
            for k, shape in obj.stat_spec(6).items():
                assert stats[k].shape == shape, (name, k)

    def test_payload_sizes_differ_by_moment_set(self):
        d = 8
        b5 = comm.DenseChannel().payload_bytes(
            get_objective("dcco").stat_template(d))
        b7 = comm.DenseChannel().payload_bytes(
            get_objective("dvicreg").stat_template(d))
        assert b7 == b5 + 2 * 4 * d * d    # + cov_f, cov_g

    def test_instance_passthrough_and_unknown_rejected(self):
        obj = get_objective("dcco", lam=3.0)
        assert get_objective(obj) is obj
        with pytest.raises(ValueError):
            get_objective(obj, lam=4.0)
        with pytest.raises(ValueError):
            get_objective("barlow")

    def test_register_objective_extends_registry(self):
        class Custom(objectives_lib.CCOObjective):
            name = "custom_cco"
        objectives_lib.register_objective("custom_cco", Custom)
        try:
            assert "custom_cco" in objectives_lib.OBJECTIVES
            assert isinstance(get_objective("custom_cco"), Custom)
        finally:
            objectives_lib._REGISTRY.pop("custom_cco")
            objectives_lib.OBJECTIVES = tuple(objectives_lib._REGISTRY)

    def test_custom_stat_key_gets_correct_spec(self, rng_key):
        """stat_spec is derived from stats() itself, so an objective with
        its own statistic (still linear in samples) specs correctly."""
        class ThirdMoment(objectives_lib.CCOObjective):
            stat_keys = objectives_lib.CCOObjective.stat_keys + ("m3_f",)

            def stats(self, zf, zg):
                st = super().stats(zf, zg)
                st["m3_f"] = (zf.astype(jnp.float32) ** 3).mean(0)
                return st

        obj = ThirdMoment()
        assert obj.stat_spec(6)["m3_f"] == (6,)
        assert obj.stat_template(6)["m3_f"].shape == (6,)

    def test_resolve_objective_honors_lam_for_dcco_name(self):
        """objective="dcco" (the name) must not silently drop lam."""
        assert fed_sim.resolve_objective("dcco", 5.0).lam == 5.0
        assert fed_sim.resolve_objective(None, 5.0).lam == 5.0
        cfg = round_engine.EngineConfig(algorithm="dcco", objective="dcco",
                                        lam=5.0)
        body = round_engine.make_round_body(
            lambda p, b: (b["v1"], b["v2"]), opt_lib.sgd(0.1), cfg)
        assert body is not None    # builds by name; lam resolution above


class TestLinearity:
    """Satellite: the property every registered objective must satisfy for
    Eq. 3 / the kernel path / the psum path to be exact."""

    @SET
    @given(clients=st.integers(2, 5), n_per=st.integers(1, 4),
           d=st.integers(2, 10), seed=st.integers(0, 2**16))
    def test_weighted_client_stats_equal_cohort_stats(self, clients, n_per,
                                                      d, seed):
        zf, zg = _views(jax.random.PRNGKey(seed), clients * n_per, d)
        for name in ALL_OBJECTIVES:
            obj = get_objective(name)
            st_global = obj.stats(zf, zg)
            st_k = jax.vmap(obj.stats)(zf.reshape(clients, n_per, d),
                                       zg.reshape(clients, n_per, d))
            agg = cco.weighted_average_stats(
                st_k, jnp.full((clients,), n_per, jnp.float32))
            for k in obj.stat_keys:
                np.testing.assert_allclose(
                    np.asarray(agg[k]), np.asarray(st_global[k]),
                    rtol=2e-5, atol=2e-6, err_msg=f"{name}/{k}")

    @SET
    @given(seed=st.integers(0, 2**16))
    def test_masked_variable_sizes(self, seed):
        """Same property under padding masks (unequal client sizes)."""
        clients, n_pad, d = 4, 5, 6
        key = jax.random.PRNGKey(seed)
        zf, zg = _views(key, clients * n_pad, d)
        sizes = jax.random.randint(jax.random.fold_in(key, 1),
                                   (clients,), 1, n_pad + 1)
        mask = (jnp.arange(n_pad)[None, :] < sizes[:, None]).astype(jnp.float32)
        for name in ALL_OBJECTIVES:
            obj = get_objective(name)
            st_k = jax.vmap(obj.stats_masked)(
                zf.reshape(clients, n_pad, d), zg.reshape(clients, n_pad, d),
                mask)
            agg = cco.weighted_average_stats(st_k, sizes.astype(jnp.float32))
            st_global = obj.stats_masked(zf, zg, mask.reshape(-1))
            for k in obj.stat_keys:
                np.testing.assert_allclose(
                    np.asarray(agg[k]), np.asarray(st_global[k]),
                    rtol=2e-5, atol=2e-6, err_msg=f"{name}/{k}")


class TestSharedAccumulator:
    """Satellite: cco/vicreg masked stats route through ONE accumulator and
    are bit-identical to the historical per-loss formulas."""

    def _legacy_cco_masked(self, zf, zg, mask):
        zf = zf.astype(jnp.float32)
        zg = zg.astype(jnp.float32)
        w = mask.astype(jnp.float32)
        n = jnp.maximum(w.sum(), 1.0)
        zf_m = zf * w[:, None]
        zg_m = zg * w[:, None]
        return {
            "mean_f": zf_m.sum(0) / n,
            "sq_f": (zf_m * zf).sum(0) / n,
            "mean_g": zg_m.sum(0) / n,
            "sq_g": (zg_m * zg).sum(0) / n,
            "cross": zf_m.T @ zg / n,
        }

    def test_masked_stats_bit_identical_to_legacy(self, rng_key):
        zf, zg = _views(rng_key, 12, 6)
        mask = (jnp.arange(12) < 9).astype(jnp.float32)
        legacy = self._legacy_cco_masked(zf, zg, mask)
        new = cco.encoding_stats_masked(zf, zg, mask)
        vr = vicreg.vicreg_stats_masked(zf, zg, mask)
        wm = wmse.wmse_stats_masked(zf, zg, mask)
        for k in cco.STAT_KEYS:
            assert (new[k] == legacy[k]).all(), k
            assert (vr[k] == legacy[k]).all(), k    # no copy-paste drift
            assert (wm[k] == legacy[k]).all(), k
        # the legacy vicreg cov formula, verbatim
        w = mask.astype(jnp.float32)
        n = jnp.maximum(w.sum(), 1.0)
        assert ((zf * w[:, None]).T @ zf / n == vr["cov_f"]).all()
        assert ((zg * w[:, None]).T @ zg / n == vr["cov_g"]).all()

    def test_unmasked_stats_bit_identical_across_objectives(self, rng_key):
        zf, zg = _views(rng_key, 16, 5)
        five = cco.encoding_stats(zf, zg)
        seven = vicreg.vicreg_stats(zf, zg)
        for k in cco.STAT_KEYS:
            assert (five[k] == seven[k]).all(), k


class TestGradientEquivalence:
    """Acceptance: fused == per-client == shard_map gradients, per
    objective (Appendix-A style, generalized)."""

    @pytest.mark.parametrize("name", ALL_OBJECTIVES)
    def test_fused_vs_per_client_vs_shard_map(self, name, rng_key):
        obj = get_objective(name)
        zf, zg = _views(rng_key, 12, 6)
        g_fused = jax.grad(lambda z: obj.loss(z, zg))(zf)
        g_pc = jax.grad(lambda z: per_client_loss(obj, z, zg, 4))(zf)
        np.testing.assert_allclose(np.asarray(g_fused), np.asarray(g_pc),
                                   rtol=1e-4, atol=1e-6)
        mesh = jax.make_mesh((1,), ("data",))
        loss_fn = make_shard_map_loss(obj, mesh)
        np.testing.assert_allclose(float(loss_fn(zf, zg)),
                                   float(obj.loss(zf, zg)), rtol=1e-5)
        g_sm = jax.grad(lambda z: loss_fn(z, zg))(zf)
        np.testing.assert_allclose(np.asarray(g_fused), np.asarray(g_sm),
                                   rtol=1e-4, atol=1e-6)

    def test_cco_objective_matches_dcco_loss_paths(self, rng_key):
        """The generic per-client loss reproduces core/dcco.py exactly."""
        from repro.core import dcco
        obj = get_objective("dcco", lam=5.0)
        zf, zg = _views(rng_key, 12, 6)
        assert float(per_client_loss(obj, zf, zg, 4)) == pytest.approx(
            float(dcco.dcco_loss_per_client(zf, zg, 5.0, 4)), rel=1e-6)

    @pytest.mark.parametrize("name", ALL_OBJECTIVES)
    def test_federated_round_equals_centralized(self, name, rng_key):
        """One stats_round at client_lr=1, one local step == one
        centralized step — the Appendix-A theorem per objective."""
        obj = get_objective(name)
        params = {"w": jax.random.normal(rng_key, (10, 6)) * 0.4}

        def apply(p, batch):
            return jnp.tanh(batch["v1"] @ p["w"]), jnp.tanh(batch["v2"] @ p["w"])

        k1, k2 = jax.random.split(rng_key)
        data = {"v1": jax.random.normal(k1, (5, 3, 10)),
                "v2": jax.random.normal(k2, (5, 3, 10))}
        sizes = jnp.full((5,), 3, jnp.int32)
        opt = opt_lib.sgd(0.1)
        p_fed, _, _ = fed_sim.stats_round(
            apply, params, opt.init(params), opt, data, sizes, objective=obj)
        union = jax.tree.map(lambda x: x.reshape(15, 10), data)
        p_cent, _, _ = fed_sim.centralized_step(
            apply, params, opt.init(params), opt, union, objective=obj)
        assert utils.tree_max_abs_diff(p_fed, p_cent) < 1e-5


class TestBackCompatBitIdentity:
    """Acceptance: the pre-protocol DCCO path is exactly preserved."""

    def _toy(self, rng_key):
        params = {"w": jax.random.normal(rng_key, (10, 6)) * 0.4}

        def apply(p, batch):
            return jnp.tanh(batch["v1"] @ p["w"]), jnp.tanh(batch["v2"] @ p["w"])

        k1, k2 = jax.random.split(rng_key)
        data = {"v1": jax.random.normal(k1, (6, 3, 10)),
                "v2": jax.random.normal(k2, (6, 3, 10))}
        sizes = jnp.array([3, 1, 2, 3, 2, 3], jnp.int32)
        return params, apply, data, sizes

    def _legacy_dcco_round(self, apply, params, opt, data, sizes, lam):
        """The pre-StatsObjective dcco_round body, written out longhand
        with the pre-floor correlation formula — the == oracle."""
        def legacy_corr(stats, eps=1e-8):
            var_f = stats["sq_f"] - stats["mean_f"] ** 2
            var_g = stats["sq_g"] - stats["mean_g"] ** 2
            cov = stats["cross"] - jnp.outer(stats["mean_f"], stats["mean_g"])
            denom = jnp.sqrt(jnp.maximum(var_f, 0.0) + eps)[:, None] * \
                jnp.sqrt(jnp.maximum(var_g, 0.0) + eps)[None, :]
            return cov / denom

        def legacy_loss(stats, lam):
            c = legacy_corr(stats)
            d = c.shape[0]
            diag = jnp.diagonal(c)
            on = jnp.sum((1.0 - diag) ** 2)
            off = (jnp.sum(c * c) - jnp.sum(diag * diag)) / (d - 1)
            return on + lam * off

        n_pad = data["v1"].shape[1]
        masks = (jnp.arange(n_pad)[None] < sizes[:, None]).astype(jnp.float32)

        def client_stats(batch, mask):
            zf, zg = apply(params, batch)
            return cco.encoding_stats_masked(zf, zg, mask)

        st_k = jax.vmap(client_stats)(data, masks)
        agg = cco.weighted_average_stats(st_k, sizes.astype(jnp.float32))

        def client_update(batch, mask):
            def loss_fn(p):
                zf, zg = apply(p, batch)
                local = cco.encoding_stats_masked(zf, zg, mask)
                return legacy_loss(cco.dcco_combine(local, agg), lam)
            loss, g = jax.value_and_grad(loss_fn)(params)
            return jax.tree.map(lambda x: -1.0 * x, g), loss

        deltas, losses_k = jax.vmap(client_update)(data, masks)
        w = sizes.astype(jnp.float32) / jnp.sum(sizes.astype(jnp.float32))
        avg_delta = jax.tree.map(lambda d: jnp.tensordot(w, d, axes=1), deltas)
        from repro.server import update as server_update_lib
        server_update = server_update_lib.as_server_update(opt)
        p2, _ = server_update.step(params, opt.init(params), avg_delta)
        return p2, jnp.sum(w * losses_k)

    def test_stats_round_equals_legacy_round_exactly(self, rng_key):
        params, apply, data, sizes = self._toy(rng_key)
        opt = opt_lib.adam(1e-2)
        p_new, _, m = fed_sim.dcco_round(apply, params, opt.init(params), opt,
                                         data, sizes, lam=5.0)
        p_old, loss_old = self._legacy_dcco_round(apply, params, opt, data,
                                                  sizes, 5.0)
        assert utils.tree_max_abs_diff(p_new, p_old) == 0.0
        assert float(m.loss) == float(loss_old)

    def test_engine_default_objective_is_explicit_cco(self, rng_key):
        params, apply, data, sizes = self._toy(rng_key)

        def sampler(k_sel, k_aug):
            return data, sizes

        opt = opt_lib.adam(1e-2)
        outs = []
        for objective in (None, get_objective("dcco", lam=5.0)):
            cfg = round_engine.EngineConfig(algorithm="dcco", lam=5.0,
                                            chunk_rounds=3,
                                            objective=objective)
            eng = round_engine.RoundEngine(apply, opt, sampler, cfg)
            outs.append(eng.run(params, opt.init(params),
                                jax.random.PRNGKey(3), 3))
        assert utils.tree_max_abs_diff(outs[0][0], outs[1][0]) == 0.0
        np.testing.assert_array_equal(np.asarray(outs[0][2].loss),
                                      np.asarray(outs[1][2].loss))


class TestVarianceFloor:
    """Satellite: the PR-3 NaN edge — degenerate combined variance on
    2-sample clients with local_steps >= 2."""

    def test_floor_bit_invisible_on_healthy_stats(self, rng_key):
        zf, zg = _views(rng_key, 64, 8)
        stats = cco.encoding_stats(zf, zg)
        c_new = cco.correlation_matrix(stats)
        # pre-floor formula, verbatim
        var_f = stats["sq_f"] - stats["mean_f"] ** 2
        var_g = stats["sq_g"] - stats["mean_g"] ** 2
        cov = stats["cross"] - jnp.outer(stats["mean_f"], stats["mean_g"])
        denom = jnp.sqrt(jnp.maximum(var_f, 0.0) + 1e-8)[:, None] * \
            jnp.sqrt(jnp.maximum(var_g, 0.0) + 1e-8)[None, :]
        assert (c_new == cov / denom).all()

    def test_degenerate_variance_bounded(self):
        """A catastrophically-cancelled stats dict (negative variance,
        non-cancelled covariance) must yield a bounded correlation, not
        the ~1e7 blow-up of the old absolute eps."""
        d = 4
        stats = {"mean_f": jnp.full((d,), 1.0),
                 "sq_f": jnp.full((d,), 0.8),      # var = -0.2 < 0
                 "mean_g": jnp.full((d,), 1.0),
                 "sq_g": jnp.full((d,), 0.8),
                 "cross": jnp.full((d, d), 0.5)}
        c = cco.correlation_matrix(stats)
        assert bool(jnp.isfinite(c).all())
        # floor = 1e-6 * 1.8 -> |C| <= 0.5 / (1e-6 * 1.8) ~ 2.8e5,
        # and far below the old ~0.5 / 1e-8 = 5e7
        assert float(jnp.abs(c).max()) < 1e6
        g = jax.grad(lambda s: cco.cco_loss_from_stats(s, 5.0))(stats)
        assert bool(all(jnp.isfinite(x).all() for x in jax.tree.leaves(g)))

    def test_no_nan_on_two_sample_cohort_multi_local_steps(self):
        """Regression: a 2-sample-client cohort with multiple local GD
        steps at client_lr=1.0 — the documented NaN edge. The unbounded
        (linear) encoder makes the later-step local stats diverge, the
        stop-grad combine cancels catastrophically (negative combined
        variance, non-cancelled covariance), and with the old absolute
        1e-8 eps the amplified gradients overflowed the client params to
        NaN within the round (verified: this exact configuration was
        non-finite pre-floor). With the relative floor the round stays
        finite."""
        key = jax.random.PRNGKey(0)
        params = {"w": jax.random.normal(key, (10, 8)) * 0.5}

        def apply(p, batch):
            return batch["v1"] @ p["w"], batch["v2"] @ p["w"]

        k1, k2 = jax.random.split(key)
        base = jax.random.normal(k1, (8, 2, 10))
        data = {"v1": base,
                "v2": base + 0.05 * jax.random.normal(k2, (8, 2, 10))}
        sizes = jnp.full((8,), 2, jnp.int32)
        opt = opt_lib.sgd(1.0)
        p, _, m = fed_sim.dcco_round(apply, params, opt.init(params), opt,
                                     data, sizes, lam=20.0, client_lr=1.0,
                                     local_steps=4)
        assert bool(jnp.isfinite(m.loss))
        assert bool(all(jnp.isfinite(x).all() for x in jax.tree.leaves(p)))


class TestEngineEndToEnd:
    """Acceptance: every objective trains through the scan engine with a
    comm channel; wire bytes reflect the objective's payload."""

    def _toy(self):
        key = jax.random.PRNGKey(0)
        params = {"w1": jax.random.normal(key, (10, 16)) * 0.3,
                  "w2": jax.random.normal(jax.random.PRNGKey(7), (16, 6)) * 0.3}

        def apply(p, batch):
            def enc(x):
                return jnp.tanh(x @ p["w1"]) @ p["w2"]
            return enc(batch["v1"]), enc(batch["v2"])

        pool = {"v1": jax.random.normal(jax.random.PRNGKey(1), (20, 3, 10)),
                "v2": jax.random.normal(jax.random.PRNGKey(2), (20, 3, 10))}

        def sampler(k_sel, k_aug):
            sel = jax.random.choice(k_sel, 20, (6,), replace=False)
            return (jax.tree.map(lambda x: x[sel], pool),
                    jnp.full((6,), 3, jnp.int32))

        return params, apply, sampler

    @pytest.mark.parametrize("name", ALL_OBJECTIVES)
    def test_trains_with_quant_channel(self, name):
        params, apply, sampler = self._toy()
        obj = get_objective(name)
        opt = opt_lib.adam(1e-2)
        cfg = round_engine.EngineConfig(
            algorithm="dcco", objective=obj, chunk_rounds=3,
            channel=comm.QuantizedChannel(8))
        eng = round_engine.RoundEngine(apply, opt, sampler, cfg)
        p, s, m = eng.run(params, opt.init(params), jax.random.PRNGKey(3), 3)
        assert bool(jnp.isfinite(m.loss).all())
        assert utils.tree_max_abs_diff(p, params) > 0.0
        # per-round uplink: stats payload + delta payload, quantized
        ch = comm.QuantizedChannel(8)
        expect = 6 * (ch.payload_bytes(obj.stat_template(6))
                      + ch.payload_bytes(params))
        np.testing.assert_allclose(np.asarray(m.wire_bytes),
                                   expect, rtol=1e-6)

    def test_seven_stat_payload_costs_more_wire(self):
        params, apply, sampler = self._toy()
        wires = {}
        for name in ("dcco", "dvicreg"):
            opt = opt_lib.adam(1e-2)
            cfg = round_engine.EngineConfig(
                algorithm="dcco", objective=get_objective(name),
                chunk_rounds=2, channel=comm.DenseChannel())
            eng = round_engine.RoundEngine(apply, opt, sampler, cfg)
            _, _, m = eng.run(params, opt.init(params),
                              jax.random.PRNGKey(3), 2)
            wires[name] = float(m.wire_bytes[0])
        # + 2 f32 (d, d) within-view moments x 6 clients
        assert wires["dvicreg"] == wires["dcco"] + 2 * 4 * 6 * 6 * 6

    @pytest.mark.parametrize("name", ["dvicreg", "dwmse"])
    def test_stats_kernel_full_moments_matches_jnp(self, name):
        params, apply, sampler = self._toy()
        obj = get_objective(name)
        outs = {}
        for kernel in ("off", "interpret"):
            opt = opt_lib.adam(1e-2)
            cfg = round_engine.EngineConfig(
                algorithm="dcco", objective=obj, chunk_rounds=3,
                stats_kernel=kernel)
            eng = round_engine.RoundEngine(apply, opt, sampler, cfg)
            outs[kernel] = eng.run(params, opt.init(params),
                                   jax.random.PRNGKey(3), 3)
        assert utils.tree_max_abs_diff(outs["off"][0],
                                       outs["interpret"][0]) < 1e-5


class TestValidateFlags:
    def _args(self, argv):
        from repro.launch import train as train_mod
        ap = train_mod.build_parser()
        return train_mod, ap, ap.parse_args(argv)

    def test_objective_accepted_in_engine_mode(self):
        train_mod, ap, args = self._args(["--objective", "dvicreg"])
        train_mod.validate_flags(ap, args)     # no exit

    def test_objective_rejected_in_fused_mode(self):
        train_mod, ap, args = self._args(
            ["--objective", "dvicreg", "--mode", "fused"])
        with pytest.raises(SystemExit, match="fused"):
            train_mod.validate_flags(ap, args)

    def test_lam_rejected_for_non_cco_objective(self):
        train_mod, ap, args = self._args(
            ["--objective", "dwmse", "--lam", "7.5"])
        with pytest.raises(SystemExit, match="lam"):
            train_mod.validate_flags(ap, args)

    def test_default_objective_keeps_lam(self):
        train_mod, ap, args = self._args(["--lam", "7.5"])
        train_mod.validate_flags(ap, args)     # no exit
