"""Semi-synchronous buffered round engine (EngineConfig.async_k,
repro.core.buffer + repro.data.latency):

  * the provably-synchronous configuration (K = cohort, zero latency, unit
    staleness) is BIT-identical (== 0.0) to the sync engine for every
    registered objective — the collapse idiom of the hierarchy tests;
  * the forced real buffered path (async_collapse=False) matches the sync
    engine to float regrouping only — the Eq.-3 exactness is math, the
    collapse only preserves the bits;
  * the staleness-weighted buffer fold is linear in contributions and
    permutation / partition invariant: any arrival order equals the flat
    Eq.-3 weighted sum (property tests via tests/_hypothesis_compat);
  * fault injection: heavy-tail stragglers + DropoutChannel outages leave
    the buffer renormalization finite (no NaN);
  * build-time guards and validate_flags rejections fire loudly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import comm, hierarchy, utils
from repro.core import buffer as buffer_lib
from repro.core import round_engine
from repro.core.round_engine import EngineConfig, RoundEngine
from repro.data import latency as latency_lib
from repro.launch import train as train_lib
from repro.objectives import OBJECTIVES, get_objective
from repro.optim import optimizers as opt_lib

LAM = 5.0
COHORT = 8


@pytest.fixture(scope="module")
def toy():
    key = jax.random.PRNGKey(0)
    params = {"w1": jax.random.normal(key, (10, 16)) * 0.3,
              "w2": jax.random.normal(jax.random.PRNGKey(7), (16, 6)) * 0.3}

    def apply(p, batch):
        def enc(x):
            return jnp.tanh(x @ p["w1"]) @ p["w2"]
        return enc(batch["v1"]), enc(batch["v2"])

    data = {"v1": jax.random.normal(jax.random.PRNGKey(1), (8, 3, 10)),
            "v2": jax.random.normal(jax.random.PRNGKey(2), (8, 3, 10))}
    sizes = jnp.array([3, 1, 2, 3, 3, 2, 1, 3], jnp.int32)
    return params, apply, data, sizes


def _base_sampler(data, sizes):
    return lambda k_sel, k_aug: (data, sizes)


def _run(apply, params, sampler, rounds=4, **cfg_kw):
    cfg_kw.setdefault("lam", LAM)
    cfg_kw.setdefault("chunk_rounds", 4)
    opt = opt_lib.adam(1e-2)
    eng = RoundEngine(apply, opt, sampler, EngineConfig(**cfg_kw))
    p, o, m = eng.run(params, opt.init(params), jax.random.PRNGKey(3),
                      rounds)
    return eng, p, m


# ---------------------------------------------------------------------------
# equivalence against the sync scan
# ---------------------------------------------------------------------------

class TestSyncEquivalence:
    @pytest.mark.parametrize("name", OBJECTIVES)
    def test_sync_config_bit_identical(self, toy, name):
        """The acceptance property: async_k = cohort size, zero latency,
        unit staleness == the sync RoundEngine, bit for bit, per
        registered objective (the buffered round IS the sync round, so it
        is computed as one — the collapse_ideal idiom)."""
        params, apply, data, sizes = toy
        obj = get_objective(name, **({"lam": LAM} if name == "dcco" else {}))
        base = _base_sampler(data, sizes)
        _, p0, m0 = _run(apply, params, base, objective=obj)
        asamp = latency_lib.make_async_sampler(base, None, COHORT)
        _, p1, m1 = _run(apply, params, asamp, objective=obj,
                         async_k=COHORT)
        assert utils.tree_max_abs_diff(p0, p1) == 0.0
        np.testing.assert_array_equal(np.asarray(m0.loss),
                                      np.asarray(m1.loss))
        # collapsed async rounds apply an update every tick, like sync
        assert np.all(np.asarray(m1.applied) == 1.0)

    def test_forced_real_buffer_matches_sync_to_regrouping(self, toy):
        """async_collapse=False forces the genuine buffered machinery
        (ring scatter, pop, mass-renormalized apply): equal to the sync
        engine up to float regrouping only."""
        params, apply, data, sizes = toy
        base = _base_sampler(data, sizes)
        _, p0, m0 = _run(apply, params, base)
        asamp = latency_lib.make_async_sampler(base, None, COHORT)
        eng, p1, m1 = _run(apply, params, asamp, async_k=COHORT,
                           async_collapse=False)
        assert eng._async_real
        assert utils.tree_max_abs_diff(p0, p1) < 1e-6
        assert utils.tree_max_abs_diff(p0, p1) > 0.0 or True
        np.testing.assert_allclose(np.asarray(m0.loss),
                                   np.asarray(m1.loss), atol=1e-5)
        assert np.all(np.asarray(m1.applied) == 1.0)
        assert int(eng.buffer_state.applied_total) == 4

    def test_buffered_heavytail_trains_and_counts_staleness(self, toy):
        """K < cohort under heavy-tail latency: updates apply on
        K-triggers, the staleness metric reports the applied aggregate's
        mean delay, and training stays finite."""
        params, apply, data, sizes = toy
        lat = latency_lib.LatencyModel("heavytail", horizon=6, tail=0.8)
        asamp = latency_lib.make_async_sampler(
            _base_sampler(data, sizes), lat, COHORT)
        eng, p1, m1 = _run(apply, params, asamp, rounds=12, async_k=4,
                           staleness_fn="poly", latency=lat)
        assert all(np.isfinite(np.asarray(v)).all()
                   for v in jax.tree.leaves(p1))
        applied = np.asarray(m1.applied)
        assert 0 < applied.sum() <= 12
        assert int(eng.buffer_state.applied_total) == int(applied.sum())
        stale = np.asarray(m1.staleness)
        assert np.all(stale >= 0.0) and np.isfinite(stale).all()
        # heavy-tail delays + poly weighting must surface real staleness
        assert stale.max() > 0.0


# ---------------------------------------------------------------------------
# buffer fold properties (Eq.-3 linearity)
# ---------------------------------------------------------------------------

def _random_contributions(rng, k=8):
    st_k = {"a": jnp.asarray(rng.normal(size=(k, 4)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(k, 3, 2)), jnp.float32)}
    deltas = {"w": jnp.asarray(rng.normal(size=(k, 5)), jnp.float32)}
    losses = jnp.asarray(rng.normal(size=(k,)), jnp.float32)
    w_eff = jnp.asarray(rng.uniform(0.05, 1.0, size=(k,)), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, size=(k,)), jnp.float32)
    return st_k, deltas, losses, w_eff, mask


def _zero_pending(horizon, k=8):
    spec = {"a": (4,), "b": (3, 2)}
    params = {"w": jnp.zeros((5,), jnp.float32)}
    return buffer_lib.init_state(spec, params, horizon).pending


def _ring_total(pending):
    """Sum every ring slot — the order-free total of all in-flight mass."""
    return jax.tree.map(lambda x: x.sum(axis=0), pending)


class TestBufferFoldProperties:
    @settings(deadline=None, max_examples=20)
    @given(seed=st.integers(0, 10_000), horizon=st.integers(1, 6))
    def test_scatter_totals_equal_flat_weighted_sum(self, seed, horizon):
        """Scattering a cohort into delay buckets re-associates but never
        changes the flat Eq.-3 weighted sum: summing the ring equals
        tensordot(w_eff, x) leaf-wise."""
        rng = np.random.default_rng(seed)
        st_k, deltas, losses, w_eff, mask = _random_contributions(rng)
        delays = jnp.asarray(rng.integers(0, horizon, size=(8,)), jnp.int32)
        pending = buffer_lib.dispatch_fold(
            _zero_pending(horizon), st_k, deltas, losses, w_eff, mask,
            delays)
        tot = _ring_total(pending)
        for leaf, flat in [
                (tot.stats["a"], jnp.tensordot(w_eff, st_k["a"], 1)),
                (tot.stats["b"], jnp.tensordot(w_eff, st_k["b"], 1)),
                (tot.delta["w"], jnp.tensordot(w_eff, deltas["w"], 1)),
                (tot.loss, jnp.dot(w_eff, losses)),
                (tot.mass, jnp.sum(w_eff)),
                (tot.count, jnp.sum(mask))]:
            np.testing.assert_allclose(np.asarray(leaf), np.asarray(flat),
                                       atol=1e-5)

    @settings(deadline=None, max_examples=20)
    @given(seed=st.integers(0, 10_000), horizon=st.integers(2, 6))
    def test_fold_permutation_invariant(self, seed, horizon):
        """Any arrival order folds to the same buffers: permuting the
        contribution axis leaves the per-slot partial sums unchanged to
        fp tolerance."""
        rng = np.random.default_rng(seed)
        st_k, deltas, losses, w_eff, mask = _random_contributions(rng)
        delays = jnp.asarray(rng.integers(0, horizon, size=(8,)), jnp.int32)
        perm = jnp.asarray(rng.permutation(8))
        p_id = buffer_lib.dispatch_fold(
            _zero_pending(horizon), st_k, deltas, losses, w_eff, mask,
            delays)
        p_perm = buffer_lib.dispatch_fold(
            _zero_pending(horizon),
            jax.tree.map(lambda x: x[perm], st_k),
            jax.tree.map(lambda x: x[perm], deltas),
            losses[perm], w_eff[perm], mask[perm], delays[perm])
        assert utils.tree_max_abs_diff(p_id._asdict(),
                                       p_perm._asdict()) < 1e-5

    @settings(deadline=None, max_examples=20)
    @given(seed=st.integers(0, 10_000), split=st.integers(1, 7))
    def test_fold_linear_in_contribution_groups(self, seed, split):
        """Folding a cohort in two dispatch groups == folding it in one:
        the buffer fold is additive in contributions (linearity), so any
        partition of arrivals yields the same state."""
        horizon = 4
        rng = np.random.default_rng(seed)
        st_k, deltas, losses, w_eff, mask = _random_contributions(rng)
        delays = jnp.asarray(rng.integers(0, horizon, size=(8,)), jnp.int32)
        whole = buffer_lib.dispatch_fold(
            _zero_pending(horizon), st_k, deltas, losses, w_eff, mask,
            delays)
        lo = slice(0, split)
        hi = slice(split, 8)
        parts = _zero_pending(horizon)
        for sl in (lo, hi):
            parts = buffer_lib.dispatch_fold(
                parts, jax.tree.map(lambda x: x[sl], st_k),
                jax.tree.map(lambda x: x[sl], deltas),
                losses[sl], w_eff[sl], mask[sl], delays[sl])
        assert utils.tree_max_abs_diff(whole._asdict(),
                                       parts._asdict()) < 1e-5

    def test_ring_pop_conserves_mass(self):
        """Popping the ring moves slot 0 into the arrived buffer and
        shifts the rest — nothing is created or lost."""
        rng = np.random.default_rng(0)
        st_k, deltas, losses, w_eff, mask = _random_contributions(rng)
        delays = jnp.asarray(rng.integers(0, 4, size=(8,)), jnp.int32)
        pending = buffer_lib.dispatch_fold(
            _zero_pending(4), st_k, deltas, losses, w_eff, mask, delays)
        total_before = _ring_total(pending)
        buf = buffer_lib.init_state(
            {"a": (4,), "b": (3, 2)}, {"w": jnp.zeros((5,))}, 4).buffer
        for _ in range(4):
            arrived, pending = buffer_lib.ring_pop(pending)
            buf = buffer_lib.buffer_add(buf, arrived)
        assert utils.tree_max_abs_diff(buf._asdict(),
                                       total_before._asdict()) < 1e-6
        assert float(jnp.abs(_ring_total(pending).mass)) == 0.0

    def test_empty_buffer_aggregate_is_finite(self):
        """Mass-floored renormalization: an empty (or outage-starved)
        buffer aggregates to zeros, never NaN."""
        state = buffer_lib.init_state({"a": (4,)}, {"w": jnp.zeros((5,))}, 3)
        avg_stats, avg_delta, tau = buffer_lib.buffer_aggregate(state.buffer)
        assert np.isfinite(np.asarray(avg_stats["a"])).all()
        assert np.isfinite(np.asarray(avg_delta["w"])).all()
        assert float(tau) == 0.0


class TestStalenessRegistry:
    def test_registered_weights(self):
        tau = jnp.asarray([0.0, 3.0])
        np.testing.assert_allclose(
            buffer_lib.resolve_staleness("unit")(tau), [1.0, 1.0])
        np.testing.assert_allclose(
            buffer_lib.resolve_staleness("poly")(tau), [1.0, 0.5])
        np.testing.assert_allclose(
            buffer_lib.resolve_staleness("inv")(tau), [1.0, 0.25])
        fn = lambda t: t * 0 + 2.0  # noqa: E731
        assert buffer_lib.resolve_staleness(fn) is fn
        with pytest.raises(ValueError, match="unknown staleness"):
            buffer_lib.resolve_staleness("bogus")


class TestLatencyModel:
    def test_resolve_and_validate(self):
        assert latency_lib.resolve_latency(None).kind == "zero"
        assert latency_lib.resolve_latency("heavytail").horizon == 8
        with pytest.raises(ValueError, match="unknown latency kind"):
            latency_lib.resolve_latency("bogus")
        with pytest.raises(ValueError, match="horizon must be >= 1"):
            latency_lib.resolve_latency(latency_lib.LatencyModel(horizon=0))
        with pytest.raises(ValueError, match="severity must be > 0"):
            latency_lib.resolve_latency(
                latency_lib.LatencyModel("heavytail", 4, tail=0.0))

    def test_heavytail_delays_are_per_client_persistent(self):
        """The same client id draws the same delay in every round — slow
        clients are consistently slow (the straggler regime)."""
        model = latency_lib.LatencyModel("heavytail", horizon=8, tail=0.7)
        ids = jnp.arange(64, dtype=jnp.int32)
        d1 = latency_lib.sample_delays(model, jax.random.PRNGKey(1), ids)
        d2 = latency_lib.sample_delays(model, jax.random.PRNGKey(2), ids)
        np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
        assert int(d1.max()) > 0 and int(d1.min()) == 0
        assert np.all((np.asarray(d1) >= 0) & (np.asarray(d1) < 8))

    def test_zero_latency_sampler_keeps_sync_streams(self, toy):
        """The async sampler's delay key is a fold_in side stream: batch
        and sizes are bit-identical to the base sampler's."""
        _, _, data, sizes = toy
        base = _base_sampler(data, sizes)
        asamp = latency_lib.make_async_sampler(base, None, COHORT)
        k1, k2 = jax.random.PRNGKey(5), jax.random.PRNGKey(6)
        b0, s0 = base(k1, k2)
        b1, s1, delays = asamp(k1, k2)
        assert utils.tree_max_abs_diff(b0, b1) == 0.0
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
        assert np.all(np.asarray(delays) == 0)


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------

class TestFaultInjection:
    def test_dropout_outage_under_stragglers_stays_finite(self, toy):
        """Heavy-tail stragglers + a high-rate DropoutChannel outage: the
        mass-floored buffer renormalization never NaNs, dropped clients
        contribute neither mass nor K-trigger count, and wire bytes stay
        truthful per contribution."""
        params, apply, data, sizes = toy
        lat = latency_lib.LatencyModel("heavytail", horizon=6, tail=0.9)
        asamp = latency_lib.make_async_sampler(
            _base_sampler(data, sizes), lat, COHORT)
        eng, p1, m1 = _run(apply, params, asamp, rounds=10, async_k=3,
                           staleness_fn="inv", latency=lat,
                           channel=comm.DropoutChannel(0.8))
        for leaf in jax.tree.leaves(p1):
            assert np.isfinite(np.asarray(leaf)).all()
        assert np.isfinite(np.asarray(m1.loss)).all()
        assert np.isfinite(np.asarray(m1.staleness)).all()
        assert np.all(np.asarray(m1.wire_bytes) >= 0.0)
        buf = eng.buffer_state.buffer
        for leaf in jax.tree.leaves(buf._asdict()):
            assert np.isfinite(np.asarray(leaf)).all()

    def test_collapsing_hierarchy_composes_bit_identically(self, toy):
        """An ideal (collapsing) two-level tree through the buffered
        engine == the flat channel-less buffered engine — hierarchy
        composes when its hops are exact."""
        params, apply, data, sizes = toy
        base = _base_sampler(data, sizes)
        asamp = latency_lib.make_async_sampler(base, None, COHORT)
        _, p0, _ = _run(apply, params, asamp, async_k=COHORT,
                        async_collapse=False)
        _, p1, _ = _run(apply, params, asamp, async_k=COHORT,
                        async_collapse=False,
                        channel=hierarchy.HierarchicalChannel(4))
        assert utils.tree_max_abs_diff(p0, p1) < 1e-6


# ---------------------------------------------------------------------------
# build-time guards
# ---------------------------------------------------------------------------

class TestGuards:
    def _cfg(self, **kw):
        kw.setdefault("async_k", 4)
        return EngineConfig(lam=LAM, **kw)

    def _build(self, toy, sampler=None, **cfg_kw):
        params, apply, data, sizes = toy
        if sampler is None:
            sampler = latency_lib.make_async_sampler(
                _base_sampler(data, sizes), None, COHORT)
        return RoundEngine(apply, opt_lib.adam(1e-2), sampler,
                           self._cfg(**cfg_kw))

    def test_plain_sampler_refused(self, toy):
        params, apply, data, sizes = toy
        with pytest.raises(ValueError, match="latency-aware sampler"):
            self._build(toy, sampler=_base_sampler(data, sizes))

    def test_latency_mismatch_refused(self, toy):
        lat = latency_lib.LatencyModel("heavytail", horizon=6, tail=0.8)
        with pytest.raises(ValueError, match="must agree"):
            self._build(toy, latency=lat)     # sampler draws zero-latency

    def test_async_k_out_of_range_refused(self, toy):
        with pytest.raises(ValueError, match="async_k=9 must be in"):
            self._build(toy, async_k=9)

    def test_cohort_chunk_refused(self, toy):
        with pytest.raises(ValueError, match="two schedulers"):
            self._build(toy, cohort_chunk=4)

    def test_cohort_axis_refused(self, toy):
        with pytest.raises(ValueError, match="shard the cohort or buffer"):
            self._build(toy, cohort_axis="data")

    def test_stats_kernel_refused(self, toy):
        with pytest.raises(ValueError, match="per-client payloads"):
            self._build(toy, stats_kernel="interpret")

    def test_non_stats_algorithm_refused(self, toy):
        with pytest.raises(ValueError, match="two-phase stats round only"):
            self._build(toy, algorithm="fedavg_cco")

    def test_dp_channel_refused(self, toy):
        with pytest.raises(ValueError, match="noise calibration"):
            self._build(toy, channel=comm.get_channel("dp"))

    def test_lossy_hierarchy_refused(self, toy):
        ch = hierarchy.HierarchicalChannel(
            4, client_channel=comm.QuantizedChannel(8))
        assert not ch.collapses
        with pytest.raises(ValueError, match="per-CLIENT contributions"):
            self._build(toy, channel=ch)

    def test_unknown_staleness_refused(self, toy):
        with pytest.raises(ValueError, match="unknown staleness"):
            self._build(toy, staleness_fn="bogus")


class TestValidateFlags:
    """PR-3 convention: no silently-ignored flags — every async flag
    combination that cannot run is rejected with a tested message."""

    def _validate(self, argv):
        ap = train_lib.build_parser()
        args = ap.parse_args(argv)
        train_lib.validate_flags(ap, args)

    def test_async_with_fused_mode_rejected(self):
        with pytest.raises(SystemExit,
                           match="runs strictly synchronous rounds"):
            self._validate(["--async-k", "4", "--mode", "fused"])

    def test_async_with_protocol_mode_rejected(self):
        with pytest.raises(SystemExit,
                           match="runs strictly synchronous rounds"):
            self._validate(["--async-k", "4", "--mode", "protocol"])

    def test_async_with_cohort_chunk_rejected(self):
        with pytest.raises(SystemExit, match="two schedulers"):
            self._validate(["--async-k", "4", "--cohort-chunk", "4"])

    def test_async_with_dp_channel_rejected(self):
        with pytest.raises(SystemExit, match="refuses --channel dp"):
            self._validate(["--async-k", "4", "--channel", "dp"])

    def test_async_with_stats_kernel_rejected(self):
        with pytest.raises(SystemExit, match="never materializes"):
            self._validate(["--async-k", "4", "--stats-kernel",
                            "interpret"])

    def test_async_k_out_of_range_rejected(self):
        with pytest.raises(SystemExit, match=r"must be in \[1"):
            self._validate(["--async-k", "20",
                            "--clients-per-round", "16"])

    def test_latency_tail_without_async_rejected(self):
        with pytest.raises(SystemExit, match="would be silently ignored"):
            self._validate(["--latency-tail", "0.5"])

    def test_staleness_without_async_rejected(self):
        with pytest.raises(SystemExit, match="would be silently ignored"):
            self._validate(["--staleness", "poly"])

    def test_valid_async_config_passes(self):
        self._validate(["--async-k", "8", "--latency-tail", "0.7",
                        "--staleness", "poly", "--channel", "int8"])
