"""Appendix-A theorem: one DCCO round (one local step, client lr 1.0)
== one centralized large-batch step — exactly, for real encoders."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import utils
from repro.configs.base import get_config, DualEncoderConfig
from repro.core import dcco, fed_sim
from repro.models import dual_encoder
from repro.optim import optimizers as opt_lib

LAM = 5.0


def _mlp_encoder(key, d_in=10, d=6):
    params = {"w1": jax.random.normal(key, (d_in, 16)) * 0.3,
              "w2": jax.random.normal(jax.random.PRNGKey(7), (16, d)) * 0.3}

    def apply(p, batch):
        def enc(x):
            return jnp.tanh(x @ p["w1"]) @ p["w2"]
        return enc(batch["v1"]), enc(batch["v2"])

    return params, apply


def _client_data(key, clients, n, d_in):
    k1, k2 = jax.random.split(key)
    return {"v1": jax.random.normal(k1, (clients, n, d_in)),
            "v2": jax.random.normal(k2, (clients, n, d_in))}


class TestAppendixA:
    @pytest.mark.parametrize("server", ["sgd", "adam", "lars"])
    def test_round_equals_centralized(self, rng_key, server):
        params, apply = _mlp_encoder(rng_key)
        data = _client_data(rng_key, clients=6, n=3, d_in=10)
        sizes = jnp.full((6,), 3, jnp.int32)
        opt = opt_lib.get_optimizer(server, 0.05)
        p1, _, m1 = fed_sim.dcco_round(apply, params, opt.init(params), opt,
                                       data, sizes, lam=LAM, client_lr=1.0)
        union = jax.tree.map(lambda x: x.reshape(18, -1), data)
        p2, _, m2 = fed_sim.centralized_step(apply, params, opt.init(params),
                                             opt, union, lam=LAM)
        assert utils.tree_max_abs_diff(p1, p2) < 1e-5
        np.testing.assert_allclose(float(m1.loss), float(m2.loss), rtol=1e-5)

    def test_variable_client_sizes(self, rng_key):
        params, apply = _mlp_encoder(rng_key)
        data = _client_data(rng_key, clients=5, n=4, d_in=10)
        sizes = jnp.array([1, 4, 2, 3, 1], jnp.int32)
        opt = opt_lib.sgd(0.1)
        p1, _, _ = fed_sim.dcco_round(apply, params, opt.init(params), opt,
                                      data, sizes, lam=LAM, client_lr=1.0)
        union = jax.tree.map(lambda x: x.reshape(20, -1), data)
        mask = (jnp.arange(4)[None] < sizes[:, None]).reshape(-1).astype(jnp.float32)
        p2, _, _ = fed_sim.centralized_step(apply, params, opt.init(params),
                                            opt, union, mask=mask, lam=LAM)
        assert utils.tree_max_abs_diff(p1, p2) < 1e-5

    def test_single_sample_clients(self, rng_key):
        """Paper Table 1, 1-sample clients: the setting where FedAvg CCO is
        impossible but DCCO still works (stats aggregated across clients)."""
        params, apply = _mlp_encoder(rng_key)
        data = _client_data(rng_key, clients=16, n=1, d_in=10)
        sizes = jnp.ones((16,), jnp.int32)
        opt = opt_lib.sgd(0.1)
        p1, _, m1 = fed_sim.dcco_round(apply, params, opt.init(params), opt,
                                       data, sizes, lam=LAM, client_lr=1.0)
        assert jnp.isfinite(m1.loss)
        union = jax.tree.map(lambda x: x.reshape(16, -1), data)
        p2, _, _ = fed_sim.centralized_step(apply, params, opt.init(params),
                                            opt, union, lam=LAM)
        assert utils.tree_max_abs_diff(p1, p2) < 1e-5

    def test_multiple_rounds_track_centralized(self, rng_key):
        params, apply = _mlp_encoder(rng_key)
        opt = opt_lib.adam(1e-2)
        st_f, st_c = opt.init(params), opt.init(params)
        pf = pc = params
        for r in range(3):
            data = _client_data(jax.random.PRNGKey(r), clients=4, n=2, d_in=10)
            sizes = jnp.full((4,), 2, jnp.int32)
            pf, st_f, _ = fed_sim.dcco_round(apply, pf, st_f, opt, data, sizes,
                                             lam=LAM, client_lr=1.0)
            union = jax.tree.map(lambda x: x.reshape(8, -1), data)
            pc, st_c, _ = fed_sim.centralized_step(apply, pc, st_c, opt, union,
                                                   lam=LAM)
        assert utils.tree_max_abs_diff(pf, pc) < 1e-4

    def test_multi_local_steps_breaks_equivalence(self, rng_key):
        """With >1 local steps (stale stats / partial gradients — paper Sec 6)
        the equivalence no longer holds; the round must still be finite."""
        params, apply = _mlp_encoder(rng_key)
        data = _client_data(rng_key, clients=4, n=3, d_in=10)
        sizes = jnp.full((4,), 3, jnp.int32)
        opt = opt_lib.sgd(0.1)
        p1, _, m = fed_sim.dcco_round(apply, params, opt.init(params), opt,
                                      data, sizes, lam=LAM, client_lr=0.5,
                                      local_steps=3)
        assert jnp.isfinite(m.loss)
        union = jax.tree.map(lambda x: x.reshape(12, -1), data)
        p2, _, _ = fed_sim.centralized_step(apply, params, opt.init(params),
                                            opt, union, lam=LAM)
        assert utils.tree_max_abs_diff(p1, p2) > 1e-6


class TestLossPathEquivalence:
    """fused / per_client / shard_map DCCO losses have identical gradients."""

    def test_fused_vs_per_client(self, rng_key):
        k1, k2 = jax.random.split(rng_key)
        zf = jax.random.normal(k1, (12, 6))
        zg = jax.random.normal(k2, (12, 6))
        g1 = jax.grad(lambda z: dcco.dcco_loss_fused(z, zg, LAM))(zf)
        g2 = jax.grad(lambda z: dcco.dcco_loss_per_client(z, zg, LAM, 4))(zf)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=1e-6)

    def test_shard_map_path(self, rng_key):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        loss_fn = dcco.make_shard_map_dcco_loss(mesh, LAM, data_axes=("data",))
        k1, k2 = jax.random.split(rng_key)
        zf = jax.random.normal(k1, (8, 4))
        zg = jax.random.normal(k2, (8, 4))
        l1 = loss_fn(zf, zg)
        l2 = dcco.dcco_loss_fused(zf, zg, LAM)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
        g1 = jax.grad(lambda z: loss_fn(z, zg))(zf)
        g2 = jax.grad(lambda z: dcco.dcco_loss_fused(z, zg, LAM))(zf)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=1e-6)


class TestResNetEquivalence:
    """The theorem with the paper's actual encoder family (WS+GN ResNet)."""

    def test_resnet_round(self, rng_key):
        cfg = get_config("resnet14-cifar", smoke=True)
        de = DualEncoderConfig(proj_dims=(16, 16), lambda_cco=LAM)
        params = dual_encoder.init_dual_encoder(rng_key, cfg, de)

        def apply(p, batch):
            zf, _ = dual_encoder.encode(cfg, de, p, {"images": batch["v1"]})
            zg, _ = dual_encoder.encode(cfg, de, p, {"images": batch["v2"]})
            return zf, zg

        k1, k2 = jax.random.split(rng_key)
        clients, n, hw = 4, 2, cfg.image_size
        data = {"v1": jax.random.uniform(k1, (clients, n, hw, hw, 3)),
                "v2": jax.random.uniform(k2, (clients, n, hw, hw, 3))}
        sizes = jnp.full((clients,), n, jnp.int32)
        opt = opt_lib.sgd(0.05)
        p1, _, m1 = fed_sim.dcco_round(apply, params, opt.init(params), opt,
                                       data, sizes, lam=LAM, client_lr=1.0)
        union = jax.tree.map(lambda x: x.reshape(8, hw, hw, 3), data)
        p2, _, m2 = fed_sim.centralized_step(apply, params, opt.init(params),
                                             opt, union, lam=LAM)
        # relative tolerance: weight standardization amplifies stem gradients
        # ~1e3x, so absolute diffs measure f32 conditioning, not the protocol
        diff = utils.tree_max_abs_diff(p1, p2)
        upd = utils.tree_max_abs_diff(p1, params) + 1e-12
        assert diff / upd < 2e-3, f"relative deviation {diff / upd}"
        np.testing.assert_allclose(float(m1.loss), float(m2.loss), rtol=1e-4)
        assert jnp.isfinite(m1.loss)
