"""Optimizers, schedules, checkpointing, sharding rules, eval probes."""
import os

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
import numpy as np
import pytest

from repro import utils
from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs.base import get_config
from repro.core import eval as eval_lib
from repro.models import transformer
from repro.optim import optimizers as opt_lib, schedules
from repro.sharding import specs as shard_specs


class TestOptimizers:
    @pytest.mark.parametrize("name,lr", [("sgd", 0.1), ("adam", 0.05)])
    def test_minimizes_quadratic(self, name, lr):
        opt = opt_lib.get_optimizer(name, lr)
        params = {"x": jnp.array([3.0, -2.0])}
        state = opt.init(params)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
            upd, state = opt.update(g, state, params)
            params = opt_lib.apply_updates(params, upd)
        assert float(jnp.abs(params["x"]).max()) < 0.05

    def test_lars_trust_ratio_descends(self):
        """LARS steps are |p|-proportional (trust ratio), so assert steady
        geometric descent rather than convergence-to-zero."""
        opt = opt_lib.lars(20.0, momentum=0.9)
        params = {"x": jnp.array([3.0, -2.0])}
        state = opt.init(params)
        n0 = float(jnp.linalg.norm(params["x"]))
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
            upd, state = opt.update(g, state, params)
            params = opt_lib.apply_updates(params, upd)
        n1 = float(jnp.linalg.norm(params["x"]))
        assert n1 < 0.5 * n0, f"|x| {n0} -> {n1}"

    def test_adam_state_is_f32_for_bf16_params(self):
        opt = opt_lib.adam(1e-3)
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        state = opt.init(params)
        assert state["m"]["w"].dtype == jnp.float32
        g = {"w": jnp.ones((4,), jnp.bfloat16)}
        upd, state = opt.update(g, state, params)
        p2 = opt_lib.apply_updates(params, upd)
        assert p2["w"].dtype == jnp.bfloat16

    def test_cosine_schedule(self):
        s = schedules.cosine_decay(1.0, 100, warmup_steps=10)
        assert float(s(0)) == 0.0
        assert abs(float(s(10)) - 1.0) < 1e-6
        assert float(s(100)) < 1e-6
        assert 0.4 < float(s(55)) < 0.6


class TestCheckpoint:
    def test_roundtrip(self, tmp_path, rng_key):
        tree = {"a": jax.random.normal(rng_key, (4, 4)),
                "b": {"c": jnp.arange(5, dtype=jnp.int32),
                      "d": jnp.ones((2,), jnp.bfloat16)}}
        path = os.path.join(tmp_path, "ckpt.msgpack")
        save_checkpoint(path, tree, step=7)
        restored, step = restore_checkpoint(path, tree)
        assert step == 7
        assert utils.tree_allclose(
            jax.tree.map(lambda x: x.astype(jnp.float32), tree),
            jax.tree.map(lambda x: x.astype(jnp.float32), restored))
        assert restored["b"]["d"].dtype == jnp.bfloat16

    def test_model_params_roundtrip(self, tmp_path, rng_key):
        cfg = get_config("tinyllama-1.1b", smoke=True)
        params = transformer.init_params(cfg, rng_key)
        path = os.path.join(tmp_path, "model.msgpack")
        save_checkpoint(path, params, step=100)
        restored, step = restore_checkpoint(path, params)
        assert utils.tree_max_abs_diff(
            utils.tree_cast(params, jnp.float32),
            utils.tree_cast(restored, jnp.float32)) == 0.0


class TestShardingRules:
    def _mesh(self):
        # 1x1 device mesh but with logical axis names; rules only read sizes,
        # so fabricate a fake 16-way mesh via abstract check below instead.
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_rules_on_abstract_16way(self):
        """Validate specs against a virtual 16x16 mesh using eval_shape
        params (no devices needed: we check the returned PartitionSpecs)."""
        cfg = get_config("qwen3-8b").replace(dtype="bfloat16")
        params = jax.eval_shape(
            lambda k: transformer.init_params(cfg, k),
            jax.ShapeDtypeStruct((2,), jnp.uint32))

        class FakeMesh:
            axis_names = ("data", "model")
            devices = np.empty((16, 16), dtype=object)

        specs = shard_specs.param_pspecs(params, FakeMesh())
        flat = jax.tree_util.tree_flatten_with_path(specs)[0]
        d = {"/".join(str(getattr(k, "key", k)) for k in path): s
             for path, s in flat}
        assert d["embed/table"] == P("model", None)          # 151936 % 16 == 0
        assert d["layers/b0/attn/wq/w"] == P(None, None, "model")
        assert d["layers/b0/attn/wo/w"] == P(None, "model", None)
        assert d["layers/b0/ffn/gate/w"] == P(None, None, "model")
        assert d["layers/b0/ffn/down/w"] == P(None, "model", None)
        assert d["layers/b0/ln1/scale"] == P()

    def test_moe_expert_sharding(self):
        cfg = get_config("deepseek-moe-16b").replace(dtype="bfloat16")
        params = jax.eval_shape(
            lambda k: transformer.init_params(cfg, k),
            jax.ShapeDtypeStruct((2,), jnp.uint32))

        class FakeMesh:
            axis_names = ("data", "model")
            devices = np.empty((16, 16), dtype=object)

        specs = shard_specs.param_pspecs(params, FakeMesh())
        flat = jax.tree_util.tree_flatten_with_path(specs)[0]
        d = {"/".join(str(getattr(k, "key", k)) for k in path): s
             for path, s in flat}
        assert d["layers/b0/moe/experts/gate"] == P(None, "model", None, None)
        assert d["layers/b0/moe/router/w"] == P()
        # vocab 102400 divisible by 16
        assert d["embed/table"] == P("model", None)

    def test_indivisible_dims_stay_replicated(self):
        cfg = get_config("granite-3-8b")  # vocab 49155 (odd)
        params = jax.eval_shape(
            lambda k: transformer.init_params(cfg, k),
            jax.ShapeDtypeStruct((2,), jnp.uint32))

        class FakeMesh:
            axis_names = ("data", "model")
            devices = np.empty((16, 16), dtype=object)

        specs = shard_specs.param_pspecs(params, FakeMesh())
        flat = jax.tree_util.tree_flatten_with_path(specs)[0]
        d = {"/".join(str(getattr(k, "key", k)) for k in path): s
             for path, s in flat}
        assert d["embed/table"] == P()

    def test_batch_pspec_divisibility(self):
        class FakeMesh:
            axis_names = ("data", "model")
            devices = np.empty((16, 16), dtype=object)

        assert shard_specs.batch_pspec(FakeMesh(), 2, 256) == P("data", None)
        assert shard_specs.batch_pspec(FakeMesh(), 2, 1) == P(None, None)


class TestEvalProbes:
    def test_ridge_probe_learns(self, rng_key):
        """Gaussian class clusters — exactly separable by a linear probe
        (argmax-of-random-linear labels are NOT ridge-separable in general)."""
        k1, k2, k3 = jax.random.split(rng_key, 3)
        centers = jax.random.normal(k1, (3, 8)) * 4.0
        y = jax.random.randint(k2, (300,), 0, 3)
        z = centers[y] + 0.5 * jax.random.normal(k3, (300, 8))
        acc = eval_lib.ridge_linear_probe(z[:200], y[:200], z[200:], y[200:], 3)
        assert float(acc) > 0.9

    def test_knn_probe(self, rng_key):
        k1, k2 = jax.random.split(rng_key)
        centers = jax.random.normal(k1, (4, 8)) * 3
        y = jax.random.randint(k2, (200,), 0, 4)
        z = centers[y] + 0.3 * jax.random.normal(k2, (200, 8))
        acc = eval_lib.knn_probe(z[:150], y[:150], z[150:], y[150:])
        assert float(acc) > 0.9

    def test_knn_probe_under_jit(self, rng_key):
        """With an explicit num_classes the probe traces (the default path
        derives it from the concrete labels and cannot run on tracers)."""
        import functools
        k1, k2 = jax.random.split(rng_key)
        centers = jax.random.normal(k1, (4, 8)) * 3
        y = jax.random.randint(k2, (200,), 0, 4)
        z = centers[y] + 0.3 * jax.random.normal(k2, (200, 8))
        jitted = jax.jit(functools.partial(eval_lib.knn_probe, k=5,
                                           num_classes=4))
        acc_jit = jitted(z[:150], y[:150], z[150:], y[150:])
        acc_ref = eval_lib.knn_probe(z[:150], y[:150], z[150:], y[150:])
        assert float(acc_jit) == float(acc_ref)
