"""Cluster-aware aggregation (repro.cluster): in-scan cosine k-means on
the phase-1 stats, per-cluster correlation targets + server-update slots,
semantic hierarchy routing — and the collapse law: ``num_clusters=1`` is
bit-identical (``== 0.0``) to the global path for every registered
objective."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import cluster as cluster_lib
from repro.cluster import round as cluster_round
from repro.comm.channel import QuantizedChannel
from repro.core import round_engine
from repro.hierarchy import HierarchicalChannel
from repro.objectives import get_objective
from repro.optim import optimizers as opt_lib

N_CLIENTS, N_PER, DIM_IN, DIM_OUT = 20, 3, 10, 6


def _toy():
    params = {"w1": jax.random.normal(jax.random.PRNGKey(0),
                                      (DIM_IN, 16)) * 0.3,
              "w2": jax.random.normal(jax.random.PRNGKey(7),
                                      (16, DIM_OUT)) * 0.3}

    def apply(p, batch):
        def enc(x):
            return jnp.tanh(x @ p["w1"]) @ p["w2"]
        return enc(batch["v1"]), enc(batch["v2"])

    pool = {"v1": jax.random.normal(jax.random.PRNGKey(1),
                                    (N_CLIENTS, N_PER, DIM_IN)),
            "v2": jax.random.normal(jax.random.PRNGKey(2),
                                    (N_CLIENTS, N_PER, DIM_IN))}

    def sampler(k_sel, k_aug):
        sel = jax.random.choice(k_sel, N_CLIENTS, (6,), replace=False)
        return (jax.tree.map(lambda x: x[sel], pool),
                jnp.full((6,), N_PER, jnp.int32))

    return params, apply, sampler


def _run(params, apply, sampler, cfg, rounds=3, lr=0.1):
    opt = opt_lib.sgd(lr)
    eng = round_engine.RoundEngine(apply, opt, sampler, cfg)
    p, o, m = eng.run(params, opt.init(params), jax.random.PRNGKey(42),
                      rounds)
    return p, m, eng


class TestKMeans:
    def _rows(self):
        # two well-separated direction bundles on the sphere
        k = jax.random.PRNGKey(3)
        a = jnp.array([1.0, 0.0, 0.0, 0.0])
        b = jnp.array([0.0, 0.0, 0.0, 1.0])
        rows = jnp.concatenate([
            a[None] + 0.05 * jax.random.normal(k, (8, 4)),
            b[None] + 0.05 * jax.random.normal(jax.random.PRNGKey(4),
                                               (8, 4))])
        return rows

    def test_two_bundles_separate(self):
        ids, cents = cluster_lib.cosine_kmeans(self._rows(), 2, iters=4)
        ids = np.asarray(ids)
        assert len(np.unique(ids[:8])) == 1
        assert len(np.unique(ids[8:])) == 1
        assert ids[0] != ids[8]
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(cents), axis=1), 1.0, atol=1e-5)

    def test_deterministic(self):
        r = self._rows()
        ids1, c1 = cluster_lib.cosine_kmeans(r, 3, iters=2)
        ids2, c2 = cluster_lib.cosine_kmeans(r, 3, iters=2)
        np.testing.assert_array_equal(np.asarray(ids1), np.asarray(ids2))
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))

    def test_warm_start_respected(self):
        """Centroids already at the bundle directions are a Lloyd fixed
        point: warm-starting from them keeps the assignment."""
        r = self._rows()
        cents = jnp.stack([jnp.array([1.0, 0.0, 0.0, 0.0]),
                           jnp.array([0.0, 0.0, 0.0, 1.0])])
        ids, _ = cluster_lib.cosine_kmeans(r, 2, iters=2, centroids=cents)
        np.testing.assert_array_equal(
            np.asarray(ids), np.asarray(cluster_lib.assign_clusters(r, cents)))

    def test_empty_cluster_keeps_centroid(self):
        rows = jnp.tile(jnp.array([[1.0, 0.0]]), (5, 1))
        cents = jnp.array([[1.0, 0.0], [0.0, 1.0]])
        _, out = cluster_lib.cosine_kmeans(rows, 2, iters=2, centroids=cents)
        np.testing.assert_allclose(np.asarray(out[1]), [0.0, 1.0], atol=1e-6)

    def test_flatten_matches_stat_spec_dim(self):
        obj = get_objective("dcco")
        zf = jax.random.normal(jax.random.PRNGKey(0), (4, 5, DIM_OUT))
        zg = jax.random.normal(jax.random.PRNGKey(1), (4, 5, DIM_OUT))
        m = jnp.ones((4, 5))
        st_k = jax.vmap(obj.stats_masked)(zf, zg, m)
        rows = cluster_lib.flatten_stats(st_k)
        assert rows.shape == (4, cluster_lib.stats_dim(
            obj.stat_spec(DIM_OUT)))
        assert rows.dtype == jnp.float32


class TestFoldToClusters:
    def test_matches_oracle_loop(self):
        k = jax.random.PRNGKey(9)
        tree = {"a": jax.random.normal(k, (7, 3)),
                "b": jax.random.normal(jax.random.PRNGKey(10), (7, 2, 2))}
        w = jnp.abs(jax.random.normal(jax.random.PRNGKey(11), (7,))) + 0.1
        ids = jnp.array([0, 1, 0, 2, 1, 0, 2], jnp.int32)
        avg, mass = cluster_lib.fold_to_clusters(tree, w, ids, 3)
        for c in range(3):
            sel = np.asarray(ids) == c
            wc = np.asarray(w)[sel]
            assert mass[c] == pytest.approx(wc.sum(), rel=1e-5)
            for key in tree:
                want = np.einsum("k,k...->...", wc,
                                 np.asarray(tree[key])[sel]) / wc.sum()
                np.testing.assert_allclose(np.asarray(avg[key][c]), want,
                                           rtol=1e-5)

    def test_empty_cluster_zero_mass(self):
        tree = {"a": jnp.ones((3, 2))}
        w = jnp.ones((3,))
        ids = jnp.zeros((3,), jnp.int32)
        avg, mass = cluster_lib.fold_to_clusters(tree, w, ids, 2)
        assert float(mass[1]) == 0.0
        np.testing.assert_array_equal(np.asarray(avg["a"][1]), 0.0)


class TestClusterCollapse:
    @pytest.mark.parametrize("objective", ["dcco", "dvicreg", "dwmse"])
    def test_single_cluster_bit_identical(self, objective):
        """num_clusters=1 routes to the global round body — the collapse
        must be exact (== 0.0), not approximate."""
        params, apply, sampler = _toy()
        base = round_engine.EngineConfig(objective=objective,
                                         chunk_rounds=3, donate=False,
                                         client_lr=0.2)
        p0, m0, _ = _run(params, apply, sampler, base)
        p1, m1, _ = _run(params, apply, sampler,
                         base._replace(num_clusters=1))
        for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
            assert float(jnp.max(jnp.abs(a - b))) == 0.0
        assert float(jnp.max(jnp.abs(m0.loss - m1.loss))) == 0.0


class TestClusteredEngine:
    def test_clustered_run_finite_and_state_shapes(self):
        params, apply, sampler = _toy()
        cfg = round_engine.EngineConfig(objective="dcco", chunk_rounds=3,
                                        donate=False, client_lr=0.2,
                                        num_clusters=2)
        p, m, eng = _run(params, apply, sampler, cfg)
        assert np.isfinite(np.asarray(m.loss)).all()
        for leaf in jax.tree.leaves(p):
            assert np.isfinite(np.asarray(leaf)).all()
        cs = eng.cluster_state
        dim = cluster_lib.stats_dim(
            get_objective("dcco").stat_spec(DIM_OUT))
        assert cs.centroids.shape == (2, dim)
        assert bool(cs.initialized)
        assert cs.params_c["w1"].shape == (2, DIM_IN, 16)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(cs.centroids), axis=1), 1.0,
            atol=1e-4)

    def test_semantic_hierarchy_routes_by_cluster(self):
        """HierarchicalChannel with num_edges == num_clusters: clients
        route through their cluster's edge; run stays finite and bills
        both hops."""
        params, apply, sampler = _toy()
        ch = HierarchicalChannel(2, client_channel=QuantizedChannel(bits=8))
        cfg = round_engine.EngineConfig(objective="dcco", chunk_rounds=3,
                                        donate=False, client_lr=0.2,
                                        num_clusters=2, channel=ch)
        p, m, _ = _run(params, apply, sampler, cfg)
        assert np.isfinite(np.asarray(m.loss)).all()
        assert float(np.asarray(m.wire_bytes)[-1]) > 0.0

    def test_readout_params_match_global_shape(self):
        params, apply, sampler = _toy()
        cfg = round_engine.EngineConfig(objective="dcco", chunk_rounds=3,
                                        donate=False, client_lr=0.2,
                                        num_clusters=3)
        p, _, _ = _run(params, apply, sampler, cfg)
        assert jax.tree.structure(p) == jax.tree.structure(params)
        for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(params)):
            assert a.shape == b.shape


class TestClusterGuards:
    def _cfg(self, **kw):
        return round_engine.EngineConfig(objective="dcco", donate=False,
                                         num_clusters=2, **kw)

    def _build(self, cfg):
        params, apply, sampler = _toy()
        opt = opt_lib.sgd(0.1)
        return round_engine.RoundEngine(apply, opt, sampler, cfg)

    def test_negative_clusters_rejected(self):
        with pytest.raises(ValueError, match="num_clusters"):
            self._build(round_engine.EngineConfig(num_clusters=-1))

    def test_async_not_composed(self):
        with pytest.raises(ValueError, match="async_k"):
            self._build(self._cfg(async_k=2))

    def test_cohort_chunk_not_composed(self):
        with pytest.raises(ValueError, match="cohort"):
            self._build(self._cfg(cohort_chunk=2))

    def test_stats_kernel_not_composed(self):
        with pytest.raises(ValueError, match="stats_kernel"):
            self._build(self._cfg(stats_kernel="interpret"))

    def test_scaffold_not_composed(self):
        with pytest.raises(ValueError, match="scaffold"):
            self._build(self._cfg(scaffold=True))

    def test_edges_must_equal_clusters(self):
        ch = HierarchicalChannel(3, client_channel=QuantizedChannel(bits=8))
        with pytest.raises(ValueError, match="num_edges"):
            self._build(self._cfg(channel=ch))

    def test_dp_channel_refused(self):
        from repro.comm import get_channel
        with pytest.raises(ValueError, match="epsilon"):
            self._build(self._cfg(channel=get_channel("dp")))

    def test_clusters_exceed_cohort_rejected(self):
        params, apply, _ = _toy()

        def sampler(k_sel, k_aug):
            sel = jax.random.choice(k_sel, N_CLIENTS, (2,), replace=False)
            pool = {"v1": jnp.zeros((N_CLIENTS, N_PER, DIM_IN)),
                    "v2": jnp.zeros((N_CLIENTS, N_PER, DIM_IN))}
            return (jax.tree.map(lambda x: x[sel], pool),
                    jnp.full((2,), N_PER, jnp.int32))

        opt = opt_lib.sgd(0.1)
        cfg = round_engine.EngineConfig(objective="dcco", donate=False,
                                        num_clusters=4, chunk_rounds=2)
        eng = round_engine.RoundEngine(apply, opt, sampler, cfg)
        with pytest.raises(ValueError, match="exceeds the cohort"):
            eng.run(params, opt.init(params), jax.random.PRNGKey(0), 2)

    def test_non_dcco_algorithm_refused(self):
        obj = get_objective("dcco")
        with pytest.raises(ValueError, match="algorithm"):
            cluster_round.make_cluster_round_body(
                lambda p, b: (None, None), None,
                round_engine.EngineConfig(algorithm="fedavg",
                                          num_clusters=2))
        del obj
