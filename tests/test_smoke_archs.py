"""Per-architecture smoke tests (assignment deliverable f): a REDUCED
variant of each family runs one forward + one DCCO train step on CPU with
correct shapes and no NaNs, plus prefill/decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import utils
from repro.configs.base import ARCH_IDS, TrainConfig, DualEncoderConfig, get_config
from repro.launch import steps as steps_lib
from repro.models import dual_encoder, transformer
from repro.optim import optimizers as opt_lib

TRANSFORMER_ARCHS = [a for a in ARCH_IDS if a != "resnet14-cifar"]
B, S = 2, 32


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    v1 = {"tokens": toks}
    if cfg.modality == "vision_text":
        v2 = {"tokens": toks[:, :1],
              "patch_embeds": jax.random.normal(
                  key, (B, cfg.vis_patches, cfg.vis_dim), jnp.float32)}
    else:
        v2 = {"tokens": jnp.roll(toks, 3, axis=-1)}
    return {"view1": v1, "view2": v2}


@pytest.mark.parametrize("arch", TRANSFORMER_ARCHS)
def test_forward_shapes_no_nan(arch, rng_key):
    cfg = get_config(arch, smoke=True)
    params = transformer.init_params(cfg, rng_key)
    toks = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.modality == "vision_text":
        kw["patch_embeds"] = jax.random.normal(rng_key, (B, cfg.vis_patches, cfg.vis_dim))
    h = transformer.forward(cfg, params, toks, **kw)
    exp_s = S + (cfg.vis_patches if cfg.modality == "vision_text" else 0)
    assert h.shape == (B, exp_s, cfg.d_model)
    assert not bool(jnp.isnan(h).any())
    logits = transformer.logits_from_hidden(cfg, params, h[:, -1])
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", TRANSFORMER_ARCHS)
def test_dcco_train_step(arch, rng_key):
    cfg = get_config(arch, smoke=True)
    de = DualEncoderConfig(proj_dims=(32, 32), lambda_cco=5.0)
    tcfg = TrainConfig(seq_len=S, global_batch=B, samples_per_client=1)
    opt = opt_lib.adam(1e-3)
    step = steps_lib.make_dcco_train_step(cfg, de, tcfg, opt)
    params = dual_encoder.init_dual_encoder(rng_key, cfg, de)
    opt_state = opt.init(params)
    batch = _batch(cfg, rng_key)
    p2, opt_state, metrics = step(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert not utils.has_nan(p2)
    assert utils.tree_max_abs_diff(p2, params) > 0.0, "params did not update"


@pytest.mark.parametrize("arch", TRANSFORMER_ARCHS)
def test_prefill_decode_consistency(arch, rng_key):
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:
        # avoid capacity-drop divergence between batched and single-token routing
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = transformer.init_params(cfg, rng_key)
    toks = jax.random.randint(rng_key, (B, 16), 0, cfg.vocab_size)
    h = transformer.forward(cfg, params, toks)
    ref = transformer.logits_from_hidden(cfg, params, h[:, -1])
    cache = transformer.init_cache(cfg, B, max_len=20)
    _, cache = transformer.prefill(cfg, params, toks[:, :15], cache)
    ld, cache = transformer.decode_step(cfg, params, cache, toks[:, 15:16])
    scale = float(jnp.max(jnp.abs(ref))) + 1e-6
    assert float(jnp.max(jnp.abs(ref - ld))) < 2e-2 * max(scale, 1.0)
    assert int(cache["pos"]) == 16


@pytest.mark.parametrize("arch", TRANSFORMER_ARCHS)
def test_serve_step_multiple_tokens(arch, rng_key):
    cfg = get_config(arch, smoke=True)
    params = transformer.init_params(cfg, rng_key)
    serve = steps_lib.make_serve_step(cfg)
    cache = transformer.init_cache(cfg, B, max_len=8)
    tok = jax.random.randint(rng_key, (B, 1), 0, cfg.vocab_size)
    for t in range(4):
        logits, cache = serve(params, cache, {"tokens": tok})
        assert logits.shape == (B, cfg.vocab_size)
        assert not bool(jnp.isnan(logits).any())
        tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
    assert int(cache["pos"]) == 4


def test_resnet_smoke(rng_key):
    cfg = get_config("resnet14-cifar", smoke=True)
    de = DualEncoderConfig(proj_dims=(32, 32), lambda_cco=5.0)
    params = dual_encoder.init_dual_encoder(rng_key, cfg, de)
    imgs = jax.random.uniform(rng_key, (4, cfg.image_size, cfg.image_size, 3))
    z, _ = dual_encoder.encode(cfg, de, params, {"images": imgs})
    assert z.shape == (4, 32)
    assert not bool(jnp.isnan(z).any())
