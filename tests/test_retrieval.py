"""Retrieval subsystem: fused MIPS top-k kernel vs oracle, corpus index
persistence, recall@k / MRR metrics, query server, engine wiring.

The kernel contract under test is strict: in interpret mode the Pallas
kernel and the chunked-scan fallback must match ``ref.mips_topk_ref``
(full-score ``jax.lax.top_k``) BIT-FOR-BIT — values and indices — because
the kernel keeps the full feature depth per dot (no d-axis re-association)
and its running-top-k picks the lowest corpus index on ties, exactly like
lax.top_k's stable sort.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import eval as eval_lib
from repro.core import round_engine
from repro.kernels import ref
from repro.kernels.mips_topk import (mips_topk, mips_topk_chunked,
                                     mips_topk_pallas)
from repro.retrieval import (CorpusIndex, QueryServer, encode_corpus_chunked,
                             l2_normalize, make_retrieval_eval)

from _hypothesis_compat import given, settings, st


def _qc(key, qn, n, d):
    kq, kc = jax.random.split(key)
    q = jax.random.normal(kq, (qn, d), jnp.float32)
    c = jax.random.normal(kc, (n, d), jnp.float32)
    return q, c


def _assert_bitwise(got, want):
    gv, gi = got
    wv, wi = want
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    assert gi.dtype == jnp.int32


class TestMipsTopkKernel:
    @pytest.mark.parametrize("qn,n,d,k", [
        (128, 512, 64, 10),    # exactly one tile each way
        (64, 4096, 32, 8),     # untiled Q, tiled N (the bench shape)
        (256, 1024, 128, 5),   # tiled both ways
        (3, 17, 16, 3),        # ragged everything (padding paths)
        (130, 700, 48, 7),     # ragged on top of multi-tile
        (8, 8, 8, 8),          # k == N
        (1, 33, 24, 1),        # k == 1
    ])
    def test_matches_oracle_bitwise(self, qn, n, d, k, rng_key):
        q, c = _qc(jax.random.fold_in(rng_key, qn * n), qn, n, d)
        want = ref.mips_topk_ref(q, c, k)
        _assert_bitwise(
            mips_topk_pallas(q, c, k=k, block_q=128, block_n=512,
                             interpret=True), want)

    @pytest.mark.parametrize("bq,bn", [(128, 512), (64, 256), (32, 128)])
    def test_block_shape_invariance(self, bq, bn, rng_key):
        q, c = _qc(rng_key, 96, 900, 64)
        want = ref.mips_topk_ref(q, c, 6)
        _assert_bitwise(mips_topk_pallas(q, c, k=6, block_q=bq, block_n=bn,
                                         interpret=True), want)

    @pytest.mark.parametrize("chunk", [512, 100, 17, 10_000])
    def test_chunked_fallback_bitwise(self, chunk, rng_key):
        q, c = _qc(rng_key, 40, 333, 48)
        _assert_bitwise(mips_topk_chunked(q, c, k=9, chunk=chunk),
                        ref.mips_topk_ref(q, c, 9))

    def test_tie_break_lowest_index(self, rng_key):
        # duplicated corpus rows: every retrieved score block of equal
        # values must list indices ascending, matching lax.top_k's stable
        # sort — on both the kernel and the chunked-scan paths
        base = jax.random.normal(rng_key, (50, 32), jnp.float32)
        c = jnp.concatenate([base, base, base])       # each row thrice
        q = base[:8]
        want = ref.mips_topk_ref(q, c, 7)
        _assert_bitwise(mips_topk_pallas(q, c, k=7, interpret=True), want)
        _assert_bitwise(mips_topk_chunked(q, c, k=7, chunk=40), want)
        # self-match: the duplicate with the LOWEST index (the original
        # in block 0) must rank first
        np.testing.assert_array_equal(np.asarray(want[1][:, 0]),
                                      np.arange(8))

    @settings(max_examples=20, deadline=None)
    @given(qn=st.integers(min_value=1, max_value=80),
           n=st.integers(min_value=12, max_value=700),
           d=st.integers(min_value=4, max_value=96),
           k=st.integers(min_value=1, max_value=12),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_property_both_backends_match_oracle(self, qn, n, d, k, seed):
        q, c = _qc(jax.random.PRNGKey(seed), qn, n, d)
        want = ref.mips_topk_ref(q, c, k)
        _assert_bitwise(mips_topk_pallas(q, c, k=k, interpret=True), want)
        _assert_bitwise(mips_topk_chunked(q, c, k=k, chunk=128), want)

    def test_dispatcher(self, rng_key):
        q, c = _qc(rng_key, 16, 200, 32)
        want = ref.mips_topk_ref(q, c, 4)
        # auto on CPU -> chunked scan; interpret -> Pallas interpreter
        _assert_bitwise(mips_topk(q, c, 4, backend="auto"), want)
        _assert_bitwise(mips_topk(q, c, 4, backend="chunked"), want)
        _assert_bitwise(mips_topk(q, c, 4, backend="pallas",
                                  interpret=True), want)
        with pytest.raises(ValueError, match="backend"):
            mips_topk(q, c, 4, backend="faiss")

    def test_shape_validation(self, rng_key):
        q, c = _qc(rng_key, 8, 64, 16)
        with pytest.raises(ValueError):
            mips_topk_chunked(q, jnp.zeros((64, 8)), k=4)
        with pytest.raises(ValueError):
            mips_topk_chunked(q, c, k=0)
        with pytest.raises(ValueError):
            mips_topk_chunked(q, c, k=65)

    def test_never_materializes_score_matrix(self):
        """Acceptance gate: at the bench shape (Q=64, N=4096) the compiled
        fused path's temporaries stay well under the (Q, N) score matrix
        the naive program materializes (naive temp >= Q*N*4 bytes)."""
        qn, n, d, k = 64, 4096, 32, 8
        q = jnp.zeros((qn, d), jnp.float32)
        c = jnp.zeros((n, d), jnp.float32)

        def naive(q, c):
            return jax.lax.top_k(q @ c.T, k)

        def analyze(fn):
            m = jax.jit(fn).lower(q, c).compile().memory_analysis()
            if m is None or not hasattr(m, "temp_size_in_bytes"):
                pytest.skip("compiled memory analysis unavailable")
            return m.temp_size_in_bytes

        qn_bytes = qn * n * 4
        assert analyze(naive) >= qn_bytes
        fused = analyze(lambda q, c: mips_topk_chunked(q, c, k=k, chunk=512))
        assert fused < qn_bytes / 2


class TestRetrievalMetrics:
    def test_recall_hand_computed(self):
        # 3 queries, top-4 relevance flags laid out by hand
        rel = jnp.asarray([[1, 0, 0, 0],     # hit at rank 1
                           [0, 0, 1, 0],     # first hit at rank 3
                           [0, 0, 0, 0]])    # never hits
        r = eval_lib.recall_at_k(rel, ks=(1, 2, 4))
        assert float(r[1]) == pytest.approx(1 / 3)
        assert float(r[2]) == pytest.approx(1 / 3)
        assert float(r[4]) == pytest.approx(2 / 3)
        # MRR = mean(1/1, 1/3, 0)
        assert float(eval_lib.mean_reciprocal_rank(rel)) == pytest.approx(
            (1 + 1 / 3 + 0) / 3)

    def test_recall_rejects_overdeep_cutoff(self):
        with pytest.raises(ValueError):
            eval_lib.recall_at_k(jnp.zeros((2, 5)), ks=(10,))

    def test_retrieval_metrics_label_match(self):
        corpus_labels = jnp.asarray([0, 0, 1, 1, 2])
        query_labels = jnp.asarray([1, 2])
        retrieved = jnp.asarray([[2, 0, 3],   # rel: 1,0,1 -> rr 1
                                 [0, 1, 3]])  # rel: 0,0,0 -> rr 0
        m = eval_lib.retrieval_metrics(retrieved, query_labels,
                                       corpus_labels, ks=(1, 3))
        assert float(m["recall_at_1"]) == pytest.approx(0.5)
        assert float(m["recall_at_3"]) == pytest.approx(0.5)
        assert float(m["mrr"]) == pytest.approx(0.5)


def _toy_encoder(params, batch):
    return batch["x"] @ params["w"]


def _toy_setup(key, n, d_in=12, d_out=16):
    kw, kx = jax.random.split(key)
    params = {"w": jax.random.normal(kw, (d_in, d_out), jnp.float32)}
    corpus = {"x": jax.random.normal(kx, (n, d_in), jnp.float32)}
    return params, corpus


class TestCorpusIndex:
    def test_chunked_encode_matches_direct(self, rng_key):
        params, corpus = _toy_setup(rng_key, 70)
        z = encode_corpus_chunked(_toy_encoder, params, corpus, chunk=16)
        want = l2_normalize(_toy_encoder(params, corpus))
        assert z.shape == (70, 16)
        np.testing.assert_allclose(np.asarray(z), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)

    def test_search_matches_oracle(self, rng_key):
        params, corpus = _toy_setup(rng_key, 96)
        idx = CorpusIndex.build(_toy_encoder, params, corpus, chunk=32)
        q = l2_normalize(jax.random.normal(jax.random.PRNGKey(7), (9, 16)))
        _assert_bitwise(idx.search(q, 5),
                        ref.mips_topk_ref(q, idx.embeddings, 5))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_save_restore_roundtrip(self, dtype, rng_key, tmp_path):
        params, corpus = _toy_setup(rng_key, 48)
        idx = CorpusIndex.build(_toy_encoder, params, corpus, chunk=16,
                                dtype=dtype)
        path = str(tmp_path / "index.msgpack")
        idx.save(path)
        back = CorpusIndex.load(path)
        assert back.embeddings.dtype == dtype
        assert back.normalized == idx.normalized
        assert back.num_items == 48 and back.dim == 16
        np.testing.assert_array_equal(
            np.asarray(back.embeddings.astype(jnp.float32)),
            np.asarray(idx.embeddings.astype(jnp.float32)))
        q = l2_normalize(jax.random.normal(jax.random.PRNGKey(3), (4, 16)))
        _assert_bitwise(back.search(q, 3), idx.search(q, 3))

    def test_make_retrieval_eval_separable_clusters(self, rng_key):
        # two well-separated clusters in input space with an identity-ish
        # encoder: every query's nearest neighbours share its label
        n, d = 40, 12
        centers = jnp.asarray([10.0, -10.0])
        labels = jnp.arange(n) % 2
        kx = jax.random.normal(rng_key, (n, d), jnp.float32)
        x = kx * 0.01 + centers[labels][:, None]
        params = {"w": jnp.eye(d, 16)}
        fn = make_retrieval_eval(_toy_encoder, {"x": x[:32]}, labels[:32],
                                 {"x": x[32:]}, labels[32:],
                                 ks=(1, 5, 10), chunk=8)
        m = jax.jit(fn)(params)
        assert set(m) == {"recall_at_1", "recall_at_5", "recall_at_10",
                          "mrr"}
        for v in m.values():
            assert float(v) == pytest.approx(1.0)


class TestQueryServer:
    def test_serving_and_stats(self, rng_key):
        params, corpus = _toy_setup(rng_key, 64)
        idx = CorpusIndex.build(_toy_encoder, params, corpus, chunk=32)
        srv = QueryServer(idx, k=4, batch=8).warmup()
        assert srv.stats() is None                    # warmup not measured
        q = l2_normalize(jax.random.normal(jax.random.PRNGKey(5), (5, 16)))
        vals, idxs = srv.query(q)                     # ragged batch pads
        assert vals.shape == (5, 4) and idxs.shape == (5, 4)
        _assert_bitwise((vals, idxs), ref.mips_topk_ref(q, idx.embeddings, 4))
        srv.query(l2_normalize(
            jax.random.normal(jax.random.PRNGKey(6), (8, 16))))
        s = srv.stats()
        assert s["batches"] == 2 and s["queries"] == 13
        assert s["qps"] > 0 and s["p99_us"] >= s["p50_us"] > 0
        with pytest.raises(ValueError, match="exceeds"):
            srv.query(jnp.zeros((9, 16)))
        srv.reset_stats()
        assert srv.stats() is None


def _toy_engine(retrieval_eval=None, retrieval_every=2, chunk_rounds=4):
    from repro.optim import optimizers as opt_lib

    params = {"w1": jax.random.normal(jax.random.PRNGKey(0), (10, 16)) * 0.3,
              "w2": jax.random.normal(jax.random.PRNGKey(7), (16, 6)) * 0.3}

    def enc(p, x):
        return jnp.tanh(x @ p["w1"]) @ p["w2"]

    def apply(p, batch):
        return enc(p, batch["v1"]), enc(p, batch["v2"])

    pool = {"v1": jax.random.normal(jax.random.PRNGKey(1), (20, 3, 10)),
            "v2": jax.random.normal(jax.random.PRNGKey(2), (20, 3, 10))}

    def sampler(k_sel, k_aug):
        sel = jax.random.choice(k_sel, 20, (6,), replace=False)
        return (jax.tree.map(lambda x: x[sel], pool),
                jnp.full((6,), 3, jnp.int32))

    opt = opt_lib.sgd(0.1)
    cfg = round_engine.EngineConfig(
        algorithm="dcco", lam=5.0, chunk_rounds=chunk_rounds,
        retrieval_eval=retrieval_eval, retrieval_every=retrieval_every)
    eng = round_engine.RoundEngine(apply, opt, sampler, cfg)
    return eng, params, opt.init(params), enc


class TestEngineRetrievalWiring:
    def _reval(self, enc):
        x = jax.random.normal(jax.random.PRNGKey(11), (40, 10), jnp.float32)
        labels = jnp.arange(40) % 4

        def embed(p, batch):
            return enc(p, batch["x"])

        return make_retrieval_eval(
            embed, {"x": x[:32]}, labels[:32], {"x": x[32:]}, labels[32:],
            ks=(1, 5, 10), chunk=16)

    def test_engine_emits_recall_and_mrr(self):
        eng, params, opt_state, enc = _toy_engine()
        eng.config = eng.config._replace(retrieval_eval=self._reval(enc))
        _, _, m = eng.run(params, opt_state, jax.random.PRNGKey(0), 4)
        assert set(m.retrieval) == {"recall_at_1", "recall_at_5",
                                    "recall_at_10", "mrr"}
        for v in m.retrieval.values():
            arr = np.asarray(v)
            assert arr.shape == (4,)
            # cadence 2: rounds 0 and 2 evaluated, 1 and 3 NaN-skipped
            assert not np.isnan(arr[[0, 2]]).any()
            assert np.isnan(arr[[1, 3]]).all()
            assert (arr[~np.isnan(arr)] >= 0).all()

    def test_retrieval_does_not_perturb_training(self):
        """The in-scan eval is observation only: params and losses must be
        bit-identical with and without it configured."""
        eng0, params, opt_state, enc = _toy_engine()
        p0, _, m0 = eng0.run(params, opt_state, jax.random.PRNGKey(0), 4)
        eng1, params, opt_state, enc = _toy_engine()
        eng1.config = eng1.config._replace(retrieval_eval=self._reval(enc))
        p1, _, m1 = eng1.run(params, opt_state, jax.random.PRNGKey(0), 4)
        assert m0.retrieval == {}
        np.testing.assert_array_equal(np.asarray(m0.loss),
                                      np.asarray(m1.loss))
        for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_config_validation(self):
        with pytest.raises(ValueError):
            _toy_engine(retrieval_eval=lambda p: {}, retrieval_every=0)
        with pytest.raises(ValueError):
            _toy_engine(retrieval_eval=1)
