"""Attention layer unit tests: blockwise==naive, GQA, sliding-window ring
buffer decode, MLA (incl. weight-absorbed decode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import ModelConfig
from repro.models import attention as attn

SET = settings(max_examples=15, deadline=None)


def _qkv(key, b, sq, skv, h, kvh, dh):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, sq, h, dh))
    k = jax.random.normal(ks[1], (b, skv, kvh, dh))
    v = jax.random.normal(ks[2], (b, skv, kvh, dh))
    qp = jnp.broadcast_to(jnp.arange(skv - sq, skv)[None], (b, sq))
    kp = jnp.broadcast_to(jnp.arange(skv)[None], (b, skv))
    return q, k, v, qp, kp


class TestBlockwise:
    @SET
    @given(sq=st.sampled_from([1, 17, 64]), kvh=st.sampled_from([1, 2, 4]),
           window=st.sampled_from([0, 24]), kb=st.sampled_from([16, 48]),
           seed=st.integers(0, 100))
    def test_matches_naive(self, sq, kvh, window, kb, seed):
        q, k, v, qp, kp = _qkv(jax.random.PRNGKey(seed), 2, sq, 64, 4, kvh, 16)
        o1 = attn.naive_attention(q, k, v, qp, kp, window)
        o2 = attn.blockwise_attention(q, k, v, qp, kp, window, kv_block=kb)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=2e-5, atol=2e-5)


class TestGqaDecode:
    def _cfg(self, window=0):
        return ModelConfig(num_heads=4, num_kv_heads=2, d_model=64, head_dim=16,
                           sliding_window=window, attn_impl="naive")

    @pytest.mark.parametrize("window", [0, 8])
    def test_decode_matches_forward(self, window, rng_key):
        cfg = self._cfg(window)
        p = attn.gqa_init(rng_key, cfg, jnp.float32)
        b, s = 2, 12
        x = jax.random.normal(rng_key, (b, s, cfg.d_model)) * 0.5
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        y_ref = attn.gqa_forward(cfg, p, x, positions)
        cache = attn.gqa_cache_init(cfg, b, max_len=16, dtype=jnp.float32)
        ys = []
        for t in range(s):
            yt, cache = attn.gqa_decode(cfg, p, x[:, t:t + 1], jnp.asarray(t), cache)
            ys.append(yt)
        np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                                   np.asarray(y_ref), rtol=2e-4, atol=2e-4)

    def test_ring_buffer_overwrites_old_entries(self, rng_key):
        """Sliding-window decode past the window size stays correct: the
        ring buffer slot reuse must not change results vs a full cache."""
        cfg_w = self._cfg(window=4)
        p = attn.gqa_init(rng_key, cfg_w, jnp.float32)
        b, s = 1, 10
        x = jax.random.normal(rng_key, (b, s, cfg_w.d_model)) * 0.5
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        y_ref = attn.gqa_forward(cfg_w, p, x, positions)
        cache = attn.gqa_cache_init(cfg_w, b, max_len=4, dtype=jnp.float32)
        assert cache["k"].shape[1] == 4  # ring buffer is window-sized
        ys = []
        for t in range(s):
            yt, cache = attn.gqa_decode(cfg_w, p, x[:, t:t + 1], jnp.asarray(t), cache)
            ys.append(yt)
        np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                                   np.asarray(y_ref), rtol=2e-4, atol=2e-4)


class TestMla:
    def _cfg(self):
        return ModelConfig(num_heads=4, d_model=64, use_mla=True,
                           kv_lora_rank=32, qk_rope_head_dim=8,
                           qk_nope_head_dim=16, v_head_dim=16,
                           attn_impl="naive")

    @pytest.mark.parametrize("absorb", [True, False])
    def test_decode_matches_forward(self, absorb, rng_key):
        cfg = self._cfg()
        p = attn.mla_init(rng_key, cfg, jnp.float32)
        b, s = 2, 10
        x = jax.random.normal(rng_key, (b, s, cfg.d_model)) * 0.5
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        y_ref = attn.mla_forward(cfg, p, x, positions)
        cache = attn.mla_cache_init(cfg, b, max_len=12, dtype=jnp.float32)
        ys = []
        for t in range(s):
            yt, cache = attn.mla_decode(cfg, p, x[:, t:t + 1], t, cache,
                                        absorb=absorb)
            ys.append(yt)
        np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                                   np.asarray(y_ref), rtol=2e-4, atol=2e-4)

    def test_absorbed_equals_naive_decode(self, rng_key):
        """The weight-absorption optimization is numerically transparent."""
        cfg = self._cfg()
        p = attn.mla_init(rng_key, cfg, jnp.float32)
        x = jax.random.normal(rng_key, (2, 1, cfg.d_model))
        c1 = attn.mla_cache_init(cfg, 2, 8, jnp.float32)
        c2 = attn.mla_cache_init(cfg, 2, 8, jnp.float32)
        y1, _ = attn.mla_decode(cfg, p, x, 0, c1, absorb=True)
        y2, _ = attn.mla_decode(cfg, p, x, 0, c2, absorb=False)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-5)

    def test_cache_is_compressed(self):
        """MLA latent cache must be ~(r+dr)/(2*h*dh) the size of full KV."""
        cfg = self._cfg()
        c = attn.mla_cache_init(cfg, 1, 100, jnp.float32)
        latent_bytes = c["latent"].size + c["k_rope"].size
        full_kv = 2 * 100 * cfg.num_heads * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
        assert latent_bytes < full_kv / 3


class TestQkNorm:
    def test_qk_norm_changes_output_and_is_finite(self, rng_key):
        base = ModelConfig(num_heads=4, num_kv_heads=2, d_model=64, head_dim=16,
                           attn_impl="naive")
        x = jax.random.normal(rng_key, (2, 8, 64)) * 3.0
        positions = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
        p = attn.gqa_init(rng_key, base.replace(qk_norm=True), jnp.float32)
        y = attn.gqa_forward(base.replace(qk_norm=True), p, x, positions)
        assert not bool(jnp.isnan(y).any())
        y2 = attn.gqa_forward(base, {k: v for k, v in p.items()
                                     if k not in ("q_norm", "k_norm")},
                              x, positions)
        assert float(jnp.max(jnp.abs(y - y2))) > 1e-4
