"""Bench-regression gate: compare a fresh BENCH.json against the checked-in
baseline (benchmarks/baseline.json) and fail on round_engine or
stats-kernel regressions.

Usage:
    python benchmarks/compare.py BENCH.json benchmarks/baseline.json \
        [--max-regress 0.30]

Gate semantics — machine-portable on purpose: CI runners (and laptops)
differ wildly in absolute speed, so gating raw microseconds against a
baseline recorded on a different machine is pure noise. The engine's
headline metric is the *speedup ratio* of the scan-compiled engine over the
Python round loop (``round_engine/python_loop`` us / ``round_engine/
scan_engine`` us): both sides are measured in the same process on the same
machine, so the ratio cancels machine speed and isolates what this repo
controls (dispatch removal, scan compilation, unroll policy). The gate
fails when that ratio drops more than ``--max-regress`` (default 30%)
below the baseline's ratio.

The generalized stats kernel is gated the same way: the ratio of the
naive per-statistic passes (``stats_kernel/naive_passes``: 7 separately
jitted reductions) over the fused one-pass computation
(``stats_kernel/one_pass``: all 7 statistics from one read — what the
Pallas kernel fuses) must not drop more than ``--max-regress`` below the
baseline's ratio, so a change that silently de-fuses the moment
computation fails CI rather than just reading "covered".

Raw per-row timings for every name present in both files are printed as an
informational table (with the new/baseline ratio) so absolute drifts stay
visible in the CI log without flaking the build.
"""
from __future__ import annotations

import argparse
import json
import sys


def _rows_by_name(blob: dict) -> dict:
    return {r["name"]: r for r in blob["rows"]}


def engine_speedup(rows: dict) -> float:
    try:
        loop = float(rows["round_engine/python_loop"]["us_per_call"])
        scan = float(rows["round_engine/scan_engine"]["us_per_call"])
    except KeyError as e:
        raise SystemExit(f"missing round_engine row {e} — run "
                         f"`python benchmarks/run.py round_engine` first")
    if scan <= 0:
        raise SystemExit(f"bad scan_engine timing {scan}")
    return loop / scan


def kernel_one_pass_ratio(rows: dict):
    """None when the stats_kernel rows are absent (partial local runs may
    gate only what they measured; CI always produces them)."""
    try:
        naive = float(rows["stats_kernel/naive_passes"]["us_per_call"])
        one = float(rows["stats_kernel/one_pass"]["us_per_call"])
    except KeyError:
        return None
    if one <= 0:
        raise SystemExit(f"bad one_pass timing {one}")
    return naive / one


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("new", help="fresh BENCH.json")
    ap.add_argument("baseline", help="checked-in baseline JSON")
    ap.add_argument("--max-regress", type=float, default=0.30,
                    help="maximum tolerated fractional drop of the "
                         "round_engine speedup ratio (default 0.30)")
    args = ap.parse_args(argv)

    with open(args.new) as f:
        new = _rows_by_name(json.load(f))
    with open(args.baseline) as f:
        base = _rows_by_name(json.load(f))

    shared = [n for n in new if n in base]
    if shared:
        print(f"{'name':44s} {'base_us':>12s} {'new_us':>12s} {'ratio':>7s}")
        for n in shared:
            b, w = float(base[n]["us_per_call"]), float(new[n]["us_per_call"])
            ratio = f"{w / b:7.2f}" if b > 0 else "      -"
            print(f"{n:44s} {b:12.1f} {w:12.1f} {ratio}")

    failed = False
    sp_new, sp_base = engine_speedup(new), engine_speedup(base)
    floor = sp_base * (1.0 - args.max_regress)
    print(f"\nround_engine speedup: baseline {sp_base:.2f}x, "
          f"new {sp_new:.2f}x, floor {floor:.2f}x "
          f"(max regress {args.max_regress:.0%})")
    if sp_new < floor:
        print("FAIL: scan-engine speedup regressed past the gate")
        failed = True

    kr_new, kr_base = kernel_one_pass_ratio(new), kernel_one_pass_ratio(base)
    if kr_new is None or kr_base is None:
        which = "new BENCH.json" if kr_new is None else "baseline"
        print(f"stats_kernel one-pass-vs-naive: SKIPPED ({which} has no "
              f"stats_kernel rows — run `python benchmarks/run.py "
              f"stats_kernel` to gate the kernel too)")
    else:
        kfloor = kr_base * (1.0 - args.max_regress)
        print(f"stats_kernel one-pass-vs-naive: baseline {kr_base:.2f}x, "
              f"new {kr_new:.2f}x, floor {kfloor:.2f}x")
        if kr_new < kfloor:
            print("FAIL: fused one-pass stats computation regressed past "
                  "the gate")
            failed = True

    if failed:
        print("If this is a runner-environment shift rather than a code "
              "change (the ratios cancel machine speed but not scheduler/"
              "core-count effects on XLA:CPU's scan unrolling and fusion), "
              "refresh the baseline: download the BENCH.json artifact from "
              "a known-good run of this job and check it in as "
              "benchmarks/baseline.json.")
        return 1
    print("OK: within gate")
    return 0


if __name__ == "__main__":
    sys.exit(main())
