"""Bench-regression gate: compare a fresh BENCH.json against the checked-in
baseline (benchmarks/baseline.json) and fail on round_engine, stats-kernel,
or streaming-engine regressions.

Usage:
    python benchmarks/compare.py BENCH.json benchmarks/baseline.json \
        [--max-regress 0.30]

Gate semantics — machine-portable on purpose: CI runners (and laptops)
differ wildly in absolute speed, so gating raw microseconds against a
baseline recorded on a different machine is pure noise. Every gate is a
*ratio of two timings from the same process on the same machine*, which
cancels machine speed and isolates what this repo controls:

  * engine speedup — the scan-compiled engine over the Python round loop
    (``round_engine/python_loop`` us / ``round_engine/scan_engine`` us):
    dispatch removal, scan compilation, unroll policy. Fails when the
    ratio drops more than ``--max-regress`` below the baseline's.
  * stats-kernel fusion — the naive per-statistic passes
    (``stats_kernel/naive_passes``) over the fused one-pass computation
    (``stats_kernel/one_pass``): a change that silently de-fuses the
    moment computation fails CI rather than just reading "covered".
  * async straggler speedup — the simulated ticks-per-update of the sync
    engine over the buffered (FedBuff-style) engine under the same
    heavy-tail latency stream (``async_stragglers/sync_ticks_per_update``
    / ``async_stragglers/buffered_ticks_per_update``). Both numbers are
    deterministic functions of the latency model and seed, so this gate
    has zero machine noise: it fails if the speedup regresses past
    ``--max-regress`` below the baseline's, and fails HARD (regardless of
    the baseline) if the buffered engine ever stops beating the sync scan
    (ratio <= 1.0) — the buffered path's reason to exist.
  * mips fused memory — XLA's compiled temp-allocation bytes for the
    naive materialize-then-top_k program over the fused MIPS scan
    (``retrieval_serving/naive_temp_bytes`` /
    ``retrieval_serving/fused_temp_bytes``), same compiler + process.
    Fails on regression past ``--max-regress``, and fails HARD if the
    fused path's temps ever reach the (Q, N) score-matrix bytes
    (``retrieval_serving/score_matrix_bytes``) — materializing the score
    matrix is the failure mode the kernel exists to avoid.
  * mips roofline fraction — the fused search's achieved fraction of the
    analytic bound (costmodel.mips_cost) evaluated at THIS machine's
    calibrated peaks (``retrieval_serving/roofline_fraction_pct``); both
    the calibration and the measurement come from the same process.
  * streaming overhead — the streamed round (``population_scale/
    streaming_c{N}``) over the materialized round (``population_scale/
    materialized_c{N}``) at the largest cohort N both paths ran: the
    O(chunk)-memory inner scan is allowed its bounded time overhead, but
    a change that makes streaming pathologically slower than the
    materialized path (ratio grows more than ``--max-regress`` over the
    baseline's) fails.
  * mixed-precision speedup + parity — the modeled f32/bf16 step-time
    bound ratio (``mixed_precision/{f32,bf16}_step_model``, both computed
    by the same cost model in the same process at TPU peaks) must stay
    > 1.0 HARD and must not regress; the measured probe accuracies
    (``mixed_precision/probe_{f32,bf16}``, acc x 1000 in the us field)
    must agree within an absolute tolerance — the numerics-contract
    check that bf16 never leaked into the Eq.-3 statistics accumulation.
  * comm round cost — the quantized round's total wall-clock (measured
    encode/decode compute + modeled federated-uplink wire time,
    ``comm_round/{dense,int8}_round_model``) must satisfy int8 <= dense
    HARD (compression must never cost wall-clock — the PR-8 fix for the
    regression the old comm_sweep baseline exposed) and the int8/dense
    ratio must not regress.
  * kernel roofline fractions — calibrated fraction-of-roofline for the
    remaining Pallas-kernel computations (``kernel_roofline/{cco_stats,
    segment_sum,quantize}_fraction_pct``), same same-process calibration
    as the mips gate; each must not regress past ``--max-regress``.
  * heterogeneity clustered-vs-global — every high-severity (>= 0.8)
    clustered/global probe pair the sweep produced
    (``heterogeneity_sweep/probe/<strategy>/sev<s>/{global,clustered}_
    x1000``): the cluster-aware readout must not probe below the global
    single-model aggregate at high severity HARD (both probes are
    deterministic functions of the seeds — zero machine noise), plus a
    no-regress floor on the canonical label @ 0.9 clustered accuracy.
  * retrieval scale — four retrieval_scale contracts, all same-process
    ratios or deterministic counts: the modeled S-device sharded search
    (measured per-shard time + measured merge time) must beat the
    measured single-device exact search HARD (sharding must never slow a
    fixed-size search) and the vmap-sharded result must match
    single-device search bit-for-bit HARD; the IVF tier at its default
    nprobe must hold recall@10 >= 0.95 of exact HARD while beating the
    exact tier's latency HARD (ratio > 1, plus no-regress floors on
    both); the drift-gated refresh must re-encode < 50% of the corpus
    under the bench's drift scenario HARD with post-refresh top-k parity
    against a full rebuild HARD.

A gated ratio whose rows are missing from either file fails with the
missing row NAMED and the command that produces it — never a raw
KeyError traceback. (The stats-kernel gate alone stays optional-by-design
for partial local runs: absent rows skip it with a notice; CI always
produces them.)

Raw per-row timings for every name present in both files are printed as an
informational table (with the new/baseline ratio) so absolute drifts stay
visible in the CI log without flaking the build.
"""
from __future__ import annotations

import argparse
import json
import re
import sys


def _rows_by_name(blob: dict) -> dict:
    return {r["name"]: r for r in blob["rows"]}


def _us(rows: dict, name: str, which: str, bench: str) -> float:
    """A gated row's timing, or a named-key SystemExit — the gate must
    say WHICH row is missing from WHICH file and how to regenerate it."""
    try:
        return float(rows[name]["us_per_call"])
    except KeyError:
        raise SystemExit(
            f"gated benchmark row '{name}' is missing from {which} — "
            f"run `python benchmarks/run.py {bench}` to produce it "
            f"(BENCH_SMOKE=1 for the CI-sized sweep)")


def engine_speedup(rows: dict, which: str) -> float:
    loop = _us(rows, "round_engine/python_loop", which, "round_engine")
    scan = _us(rows, "round_engine/scan_engine", which, "round_engine")
    if scan <= 0:
        raise SystemExit(f"bad scan_engine timing {scan} in {which}")
    return loop / scan


def kernel_one_pass_ratio(rows: dict):
    """None when the stats_kernel rows are absent (partial local runs may
    gate only what they measured; CI always produces them)."""
    try:
        naive = float(rows["stats_kernel/naive_passes"]["us_per_call"])
        one = float(rows["stats_kernel/one_pass"]["us_per_call"])
    except KeyError:
        return None
    if one <= 0:
        raise SystemExit(f"bad one_pass timing {one}")
    return naive / one


def async_speedup(rows: dict, which: str) -> float:
    sync = _us(rows, "async_stragglers/sync_ticks_per_update", which,
               "async_stragglers")
    buf = _us(rows, "async_stragglers/buffered_ticks_per_update", which,
              "async_stragglers")
    if buf <= 0:
        raise SystemExit(f"bad buffered_ticks_per_update value {buf} "
                         f"in {which}")
    return sync / buf


def mips_memory_ratio(rows: dict, which: str):
    """(naive_temp / fused_temp, fused_temp, score_matrix_bytes) from the
    retrieval_serving compiled-memory rows: XLA's own temp-allocation plan
    for the naive materialize-then-top_k program vs the fused MIPS scan,
    same compiler, same process — machine-portable by construction."""
    naive = _us(rows, "retrieval_serving/naive_temp_bytes", which,
                "retrieval_serving")
    fused = _us(rows, "retrieval_serving/fused_temp_bytes", which,
                "retrieval_serving")
    score = _us(rows, "retrieval_serving/score_matrix_bytes", which,
                "retrieval_serving")
    if fused <= 0 or score <= 0:
        raise SystemExit(
            f"bad retrieval_serving memory rows in {which} (fused_temp="
            f"{fused}, score_matrix={score}) — compiled memory analysis "
            f"was unavailable when BENCH.json was produced")
    return naive / fused, fused, score


def mips_roofline_fraction(rows: dict, which: str) -> float:
    return _us(rows, "retrieval_serving/roofline_fraction_pct", which,
               "retrieval_serving")


def streaming_overhead(rows: dict, which: str) -> float:
    """streaming/materialized round-time ratio at the largest cohort both
    paths ran (population_scale emits materialized rows only up to its
    memory cap, so the shared cohort is discovered, not hardcoded)."""
    mat = {int(m.group(1)) for name in rows
           if (m := re.fullmatch(r"population_scale/materialized_c(\d+)",
                                 name))}
    stream = {int(m.group(1)) for name in rows
              if (m := re.fullmatch(r"population_scale/streaming_c(\d+)",
                                    name))}
    shared = mat & stream
    if not shared:
        raise SystemExit(
            f"gated benchmark rows 'population_scale/materialized_c<N>' + "
            f"'population_scale/streaming_c<N>' (same N) are missing from "
            f"{which} — run `python benchmarks/run.py population_scale` "
            f"to produce them (BENCH_SMOKE=1 for the CI-sized sweep)")
    n = max(shared)
    mat_us = _us(rows, f"population_scale/materialized_c{n}", which,
                 "population_scale")
    stream_us = _us(rows, f"population_scale/streaming_c{n}", which,
                    "population_scale")
    if mat_us <= 0:
        raise SystemExit(f"bad materialized_c{n} timing {mat_us} in {which}")
    return stream_us / mat_us


def mixed_precision_terms(rows: dict, which: str):
    """(modeled f32/bf16 bound ratio, probe_f32, probe_bf16) — the modeled
    ratio is two same-process cost-model evaluations; the probes are
    acc x 1000 measured values (see run.py mixed_precision)."""
    f32 = _us(rows, "mixed_precision/f32_step_model", which,
              "mixed_precision")
    bf16 = _us(rows, "mixed_precision/bf16_step_model", which,
               "mixed_precision")
    if bf16 <= 0:
        raise SystemExit(f"bad bf16_step_model value {bf16} in {which}")
    p32 = _us(rows, "mixed_precision/probe_f32", which, "mixed_precision")
    p16 = _us(rows, "mixed_precision/probe_bf16", which, "mixed_precision")
    return f32 / bf16, p32, p16


def comm_round_ratio(rows: dict, which: str) -> float:
    """int8/dense total-round-cost ratio (measured channel compute +
    modeled federated-uplink wire time, both sides from the same process
    and the same wire model)."""
    dense = _us(rows, "comm_round/dense_round_model", which, "comm_round")
    int8 = _us(rows, "comm_round/int8_round_model", which, "comm_round")
    if dense <= 0:
        raise SystemExit(f"bad dense_round_model value {dense} in {which}")
    return int8 / dense


def retrieval_scale_terms(rows: dict, which: str):
    """(sharded modeled speedup, sharded bitwise-match flag, ivf recall@10
    x1000, ivf qps ratio, refresh items-ratio x1000, refresh parity x1000)
    from the retrieval_scale rows — every term a same-process ratio or a
    deterministic count (see run.py retrieval_scale)."""
    return tuple(
        _us(rows, f"retrieval_scale/{row}", which, "retrieval_scale")
        for row in ("sharded_speedup_modeled", "sharded_exact_match",
                    "ivf_recall_at10_x1000", "ivf_qps_ratio",
                    "refresh_items_ratio_x1000",
                    "refresh_recall_parity_x1000"))


KERNEL_FRACTION_ROWS = ("kernel_roofline/cco_stats_fraction_pct",
                        "kernel_roofline/segment_sum_fraction_pct",
                        "kernel_roofline/quantize_fraction_pct")

# the canonical clustered-vs-global cell every heterogeneity_sweep run
# (smoke or full) produces — anchors the no-regress floor
HET_CANONICAL = "heterogeneity_sweep/probe/label/sev0.9"


def heterogeneity_pairs(rows: dict, which: str):
    """Every high-severity (>= 0.8) clustered/global probe pair in
    ``rows`` as (cell, global_acc_x1000, clustered_acc_x1000) — pairs are
    discovered from the clustered rows so a fuller sweep gates every cell
    it ran, and a clustered row whose global counterpart is missing fails
    NAMED (never a KeyError)."""
    pairs = []
    for name in sorted(rows):
        m = re.fullmatch(
            r"(heterogeneity_sweep/probe/[^/]+/sev(\d+\.\d+))/"
            r"clustered_x1000", name)
        if not m or float(m.group(2)) < 0.8:
            continue
        cell = m.group(1)
        pairs.append((cell,
                      _us(rows, f"{cell}/global_x1000", which,
                          "heterogeneity_sweep"),
                      float(rows[name]["us_per_call"])))
    if not pairs:
        raise SystemExit(
            f"gated benchmark rows '{HET_CANONICAL}/{{global,clustered}}"
            f"_x1000' are missing from {which} — run `python "
            f"benchmarks/run.py heterogeneity_sweep` to produce them "
            f"(BENCH_SMOKE=1 for the CI-sized sweep)")
    return pairs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("new", help="fresh BENCH.json")
    ap.add_argument("baseline", help="checked-in baseline JSON")
    ap.add_argument("--max-regress", type=float, default=0.30,
                    help="maximum tolerated fractional regression of each "
                         "gated ratio (default 0.30)")
    args = ap.parse_args(argv)

    with open(args.new) as f:
        new = _rows_by_name(json.load(f))
    with open(args.baseline) as f:
        base = _rows_by_name(json.load(f))

    shared = [n for n in new if n in base]
    if shared:
        print(f"{'name':44s} {'base_us':>12s} {'new_us':>12s} {'ratio':>7s}")
        for n in shared:
            b, w = float(base[n]["us_per_call"]), float(new[n]["us_per_call"])
            ratio = f"{w / b:7.2f}" if b > 0 else "      -"
            print(f"{n:44s} {b:12.1f} {w:12.1f} {ratio}")

    failed = False
    sp_new = engine_speedup(new, "the new BENCH.json")
    sp_base = engine_speedup(base, "the baseline")
    floor = sp_base * (1.0 - args.max_regress)
    print(f"\nround_engine speedup: baseline {sp_base:.2f}x, "
          f"new {sp_new:.2f}x, floor {floor:.2f}x "
          f"(max regress {args.max_regress:.0%})")
    if sp_new < floor:
        print("FAIL: scan-engine speedup regressed past the gate")
        failed = True

    kr_new, kr_base = kernel_one_pass_ratio(new), kernel_one_pass_ratio(base)
    if kr_new is None or kr_base is None:
        which = "new BENCH.json" if kr_new is None else "baseline"
        print(f"stats_kernel one-pass-vs-naive: SKIPPED ({which} has no "
              f"stats_kernel rows — run `python benchmarks/run.py "
              f"stats_kernel` to gate the kernel too)")
    else:
        kfloor = kr_base * (1.0 - args.max_regress)
        print(f"stats_kernel one-pass-vs-naive: baseline {kr_base:.2f}x, "
              f"new {kr_new:.2f}x, floor {kfloor:.2f}x")
        if kr_new < kfloor:
            print("FAIL: fused one-pass stats computation regressed past "
                  "the gate")
            failed = True

    asp_new = async_speedup(new, "the new BENCH.json")
    asp_base = async_speedup(base, "the baseline")
    afloor = max(asp_base * (1.0 - args.max_regress), 1.0)
    print(f"async straggler speedup (sim ticks/update): baseline "
          f"{asp_base:.2f}x, new {asp_new:.2f}x, floor {afloor:.2f}x")
    if asp_new <= 1.0:
        print("FAIL: the buffered engine no longer beats the sync scan "
              "under heavy-tail stragglers (its reason to exist)")
        failed = True
    elif asp_new < afloor:
        print("FAIL: buffered-engine straggler speedup regressed past "
              "the gate")
        failed = True

    mr_new, fused_new, score_new = mips_memory_ratio(new,
                                                     "the new BENCH.json")
    mr_base, _, _ = mips_memory_ratio(base, "the baseline")
    mfloor = mr_base * (1.0 - args.max_regress)
    print(f"mips fused-vs-naive compiled temp memory: baseline "
          f"{mr_base:.2f}x, new {mr_new:.2f}x, floor {mfloor:.2f}x")
    if fused_new >= score_new:
        print(f"FAIL: the fused MIPS search's compiled temp allocation "
              f"({fused_new:.0f} B) reached the (Q, N) score-matrix size "
              f"({score_new:.0f} B) — the kernel materialized the score "
              f"matrix it exists to avoid")
        failed = True
    elif mr_new < mfloor:
        print("FAIL: the fused MIPS search's memory advantage over the "
              "naive program regressed past the gate")
        failed = True

    rf_new = mips_roofline_fraction(new, "the new BENCH.json")
    rf_base = mips_roofline_fraction(base, "the baseline")
    rffloor = rf_base * (1.0 - args.max_regress)
    print(f"mips calibrated fraction-of-roofline: baseline "
          f"{rf_base:.1f}%, new {rf_new:.1f}%, floor {rffloor:.1f}%")
    if rf_new < rffloor:
        print("FAIL: the fused MIPS search fell further below this "
              "machine's calibrated roofline than the gate allows")
        failed = True

    so_new = streaming_overhead(new, "the new BENCH.json")
    so_base = streaming_overhead(base, "the baseline")
    ceil = so_base * (1.0 + args.max_regress)
    print(f"streaming-vs-materialized round time: baseline {so_base:.2f}x, "
          f"new {so_new:.2f}x, ceiling {ceil:.2f}x")
    if so_new > ceil:
        print("FAIL: the streaming engine's time overhead over the "
              "materialized path regressed past the gate")
        failed = True

    mp_new, p32_new, p16_new = mixed_precision_terms(new,
                                                     "the new BENCH.json")
    mp_base, _, _ = mixed_precision_terms(base, "the baseline")
    mp_floor = max(mp_base * (1.0 - args.max_regress), 1.0)
    print(f"mixed-precision modeled step speedup (f32/bf16 bound): baseline "
          f"{mp_base:.2f}x, new {mp_new:.2f}x, floor {mp_floor:.2f}x")
    if mp_new <= 1.0:
        print("FAIL: the modeled bf16 step is no longer faster than f32 — "
              "the mixed-precision path lost its reason to exist")
        failed = True
    elif mp_new < mp_floor:
        print("FAIL: the modeled bf16-vs-f32 speedup regressed past "
              "the gate")
        failed = True
    # parity is an ABSOLUTE tolerance on this run's own two probes (acc x
    # 1000): 60 milli-acc covers the stochastic-training jitter of the
    # tiny bench encoder while still catching a broken accumulation path
    # (bf16 stats collapse parity by hundreds of milli-acc)
    parity_tol = 60.0
    print(f"mixed-precision probe parity: f32 {p32_new / 1000:.3f}, "
          f"bf16 {p16_new / 1000:.3f}, |d| {abs(p16_new - p32_new) / 1000:.3f}"
          f" (tol {parity_tol / 1000:.3f})")
    if abs(p16_new - p32_new) > parity_tol:
        print("FAIL: bf16-compute probe accuracy diverged from f32 past "
              "the parity tolerance — check that the Eq.-3 statistics "
              "accumulation is still f32 (cast_encoder_apply contract)")
        failed = True

    cr_new = comm_round_ratio(new, "the new BENCH.json")
    cr_base = comm_round_ratio(base, "the baseline")
    cr_ceil = min(cr_base * (1.0 + args.max_regress), 1.0)
    print(f"comm round int8/dense total cost: baseline {cr_base:.3f}, "
          f"new {cr_new:.3f}, ceiling {cr_ceil:.3f}")
    if cr_new > 1.0:
        print("FAIL: the int8 comm round costs more wall-clock than dense "
              "— compression must never cost wall-clock")
        failed = True
    elif cr_new > cr_ceil:
        print("FAIL: the int8 comm round's advantage over dense regressed "
              "past the gate")
        failed = True

    # a fraction row divides two same-process timings (calibration /
    # kernel), so it carries roughly double a single timing's scheduler
    # noise even best-of-timed — the gate gets double the allowance. It
    # exists to catch a kernel falling off its roofline (an accidental
    # algorithmic or fusion regression), not a loaded runner.
    frac_regress = min(2.0 * args.max_regress, 0.95)
    for row in KERNEL_FRACTION_ROWS:
        kf_new = _us(new, row, "the new BENCH.json", "kernel_roofline")
        kf_base = _us(base, row, "the baseline", "kernel_roofline")
        kf_floor = kf_base * (1.0 - frac_regress)
        kname = row.split("/")[1].replace("_fraction_pct", "")
        print(f"{kname} calibrated fraction-of-roofline: baseline "
              f"{kf_base:.1f}%, new {kf_new:.1f}%, floor {kf_floor:.1f}%")
        if kf_new < kf_floor:
            print(f"FAIL: the {kname} kernel computation fell further below "
                  f"this machine's calibrated roofline than the gate allows")
            failed = True

    het_pairs = heterogeneity_pairs(new, "the new BENCH.json")
    het_canon_new = _us(new, f"{HET_CANONICAL}/clustered_x1000",
                        "the new BENCH.json", "heterogeneity_sweep")
    het_canon_base = _us(base, f"{HET_CANONICAL}/clustered_x1000",
                         "the baseline", "heterogeneity_sweep")
    het_floor = het_canon_base * (1.0 - args.max_regress)
    for cell, g, c in het_pairs:
        print(f"heterogeneity {cell}: global {g / 1000:.3f}, "
              f"clustered {c / 1000:.3f}")
        if c < g:
            print(f"FAIL: clustered aggregation probes below the global "
                  f"model at high severity ({cell}) — the per-cluster "
                  f"slots lost their reason to exist (both probes are "
                  f"deterministic: this is a code change, not noise)")
            failed = True
    print(f"heterogeneity clustered probe ({HET_CANONICAL}): baseline "
          f"{het_canon_base / 1000:.3f}, new {het_canon_new / 1000:.3f}, "
          f"floor {het_floor / 1000:.3f}")
    if het_canon_new < het_floor:
        print("FAIL: the clustered probe accuracy at the canonical "
              "high-severity cell regressed past the gate")
        failed = True

    (sh_new, bit_new, rec_new, ivf_new,
     frac_new, par_new) = retrieval_scale_terms(new, "the new BENCH.json")
    (sh_base, _, _, ivf_base,
     _, _) = retrieval_scale_terms(base, "the baseline")
    sh_floor = max(sh_base * (1.0 - args.max_regress), 1.0)
    print(f"sharded retrieval modeled speedup (S devices vs 1): baseline "
          f"{sh_base:.2f}x, new {sh_new:.2f}x, floor {sh_floor:.2f}x")
    if sh_new <= 1.0:
        print("FAIL: the modeled sharded search (per-shard + merge) no "
              "longer beats single-device exact search — sharding must "
              "never slow a fixed-size search down")
        failed = True
    elif sh_new < sh_floor:
        print("FAIL: the sharded search's modeled speedup regressed past "
              "the gate")
        failed = True
    if bit_new != 1.0:
        print("FAIL: sharded search is no longer bit-identical to "
              "single-device search (scores+indices incl. tie-breaks) — "
              "the merge's exactness contract is broken")
        failed = True

    recall_floor = 950.0  # 0.95 x exact, deterministic (fixed seeds)
    ivf_floor = max(ivf_base * (1.0 - args.max_regress), 1.0)
    print(f"ivf recall@10 at default nprobe: new {rec_new / 1000:.3f} "
          f"(floor {recall_floor / 1000:.2f}); qps-vs-exact: baseline "
          f"{ivf_base:.2f}x, new {ivf_new:.2f}x, floor {ivf_floor:.2f}x")
    if rec_new < recall_floor:
        print("FAIL: the IVF tier's recall@10 at its default nprobe fell "
              "below 0.95x exact — the pruning traded away too much "
              "recall")
        failed = True
    if ivf_new <= 1.0:
        print("FAIL: the IVF tier no longer beats exact-search latency — "
              "an approximate tier that is also slower has no reason to "
              "exist")
        failed = True
    elif ivf_new < ivf_floor:
        print("FAIL: the IVF tier's latency advantage regressed past "
              "the gate")
        failed = True

    # deterministic counts (fixed seeds + thresholds), gated absolutely
    print(f"refresh re-encode fraction: new {frac_new / 1000:.3f} "
          f"(ceiling 0.500); post-refresh top-k parity: "
          f"{par_new / 1000:.3f} (floor 0.990)")
    if frac_new >= 500.0:
        print("FAIL: the drift-gated refresh re-encoded >= 50% of the "
              "corpus under the bench drift scenario — the targeted "
              "update lost its cost advantage over a full rebuild")
        failed = True
    if par_new < 990.0:
        print("FAIL: the refreshed index's top-k diverged from a full "
              "rebuild's — the drift gate is skipping items that "
              "actually moved")
        failed = True

    if failed:
        print("If this is a runner-environment shift rather than a code "
              "change (the ratios cancel machine speed but not scheduler/"
              "core-count effects on XLA:CPU's scan unrolling and fusion), "
              "refresh the baseline: download the BENCH.json artifact from "
              "a known-good run of this job and check it in as "
              "benchmarks/baseline.json.")
        return 1
    print("OK: within gate")
    return 0


if __name__ == "__main__":
    sys.exit(main())
