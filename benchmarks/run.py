"""Benchmark harness — one function per paper table/figure + system
microbenchmarks. Prints ``name,us_per_call,derived`` CSV rows.

  table1_cifar    — paper Table 1 protocol, miniaturized: decentralized
                    CIFAR-like splits (clients x samples/client, IID vs
                    non-IID) x {DCCO, CCO+FedAvg, contrastive+FedAvg,
                    centralized CCO, supervised}; derived = linear-probe acc.
  table2_derm     — paper Table 2 protocol: variable 1-6 samples/client
                    (DERM-like), sweep clients/round; derived = probe acc.
  figure3_collapse— paper App. C: BYOL-with-GN collapse probe;
                    derived = encoding std (byol vs cco).
  dcco_round      — federated round latency vs clients/round.
  fused_step      — pod-style fused DCCO step latency (1-device).
  stats_kernel    — fused cco_stats kernel (interpret) vs jnp ref.
  comm_sweep      — bytes-on-the-wire vs probe accuracy across the
                    repro.comm channels (dense / int8 / DP / dropout) on
                    the synthetic non-IID benchmark.
  objective_sweep — the StatsObjective protocol per registered objective
                    (dcco / dvicreg / dwmse): stats payload bytes, kernel
                    time for the objective's moment set, probe accuracy.
  population_scale— cohort size as a memory-free knob (repro.hierarchy):
                    round time + compiled peak memory, materialized vs
                    streamed (cohort_chunk), cohort 64 -> 4096 clients.
  server_opt_sweep— non-IID severity (label-sharded vs IID) x server
                    update strategy (fedavg_sgd / fedavgm / fedadam /
                    fedyogi / fedadam+scaffold), probe accuracy per cell
                    (repro.server).
  retrieval_serving— fused MIPS top-k serving vs the naive materialize-
                    then-top_k program: compiled temp memory (gated),
                    calibrated fraction-of-roofline (gated), QueryServer
                    qps/p50/p99 vs corpus size.
  mixed_precision — EngineConfig.compute_dtype: modeled bf16-vs-f32 step
                    speedup at TPU peaks (gated) + measured probe-accuracy
                    parity on the bench encoder (gated) + measured CPU
                    wall-clock (informational).
  heterogeneity_sweep — client-heterogeneity scenario suite: partition
                    strategy x severity -> cut time + label-dominance
                    skew metric, and clustered (EngineConfig.num_clusters,
                    repro.cluster) vs global aggregation -> cluster-matched
                    probe accuracy (gated: clustered >= global at
                    severity >= 0.8).
  comm_round      — one federated comm round's wall-clock, dense vs int8/
                    int4: measured channel compute + modeled federated-
                    uplink wire time; int8 <= dense is gated HARD.
  kernel_roofline — calibrated fraction-of-roofline for the cco_stats /
                    segment_sum / quantize kernels (gated no-regress).
  roofline        — emits the analytic roofline rows (see roofline.py),
                    including the MIPS serving and federated-kernel shapes.

Set ``BENCH_SMOKE=1`` to shrink the timed sweeps to CI-smoke sizes (the
bench-regression gate in CI runs ``round_engine`` + ``comm_sweep`` +
``objective_sweep`` + ``stats_kernel`` + ``population_scale`` +
``retrieval_serving`` this way and compares against
benchmarks/baseline.json via compare.py).

All model-scale numbers are CPU-host timings of reduced configs — relative
comparisons only; absolute TPU numbers come from the §Roofline analysis.

Besides the printed CSV, every run dumps its rows as machine-readable JSON
(default ``BENCH.json`` in the working directory; override with the
``BENCH_JSON`` env var) so the perf trajectory is diffable across PRs.
Pass benchmark names as argv to run a subset: ``python benchmarks/run.py
comm_sweep round_engine``.
"""
from __future__ import annotations

import functools
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import roofline as roofline_mod
from repro import comm
from repro.configs.base import DualEncoderConfig, get_config
from repro.core import cco, eval as eval_lib, fed_sim, losses, round_engine
from repro.data import pipeline, synthetic
from repro.models import dual_encoder, resnet as resnet_mod
from repro.optim import optimizers as opt_lib

ROWS = []


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append({"name": name, "us_per_call": round(us_per_call, 1),
                 "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def _timeit(fn, n=3, best_of=1):
    """Mean us/call over n calls; with best_of > 1, the MINIMUM of best_of
    such batch means. Best-of is the noise-robust choice for the
    calibrated roofline fractions: a scheduler stall inflates a mean
    forever, but the min converges to the machine's actual capability —
    and the fraction divides two timings, so it carries both their
    noise."""
    out = fn()  # warmup/compile
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(best_of):
        t0 = time.perf_counter()
        for _ in range(n):
            out = fn()
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / n)
    return best * 1e6


def _calibrate_peaks(seed=0, mm_dim=1024):
    """Measure THIS machine's achievable peaks in-process: a jitted
    (mm_dim, mm_dim) matmul for flops/s and a 64 MB f32 elementwise copy
    for HBM bytes/s. Every calibrated fraction-of-roofline row
    (`retrieval_serving`, `kernel_roofline`) scores a measured kernel time
    against an analytic bound evaluated at THESE peaks, so the fraction is
    a ratio of two same-process measurements — portable across runner
    generations, unlike absolute us. Best-of-timed (see _timeit) so a
    transiently loaded runner shrinks neither peak. Returns
    (flops_per_s, bytes_per_s).
    """
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (mm_dim, mm_dim), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 1), (mm_dim, mm_dim),
                          jnp.float32)
    matmul = jax.jit(lambda a, b: a @ b)
    flops_s = 2.0 * mm_dim ** 3 / (
        _timeit(lambda: matmul(a, b), n=5, best_of=4) / 1e6)
    big = jnp.zeros((16, 1 << 20), jnp.float32)          # 64 MB
    copy = jax.jit(lambda x: x * 1.0000001)
    bytes_s = 2.0 * big.nbytes / (
        _timeit(lambda: copy(big), n=5, best_of=4) / 1e6)
    return flops_s, bytes_s


# ---------------------------------------------------------------------------
# shared miniature CIFAR-like setup (paper Sec 4.1-4.3, reduced)
# ---------------------------------------------------------------------------

def _setup(seed=0):
    cfg = get_config("resnet14-cifar", smoke=True)
    de = DualEncoderConfig(proj_dims=(64, 64), lambda_cco=5.0)
    key = jax.random.PRNGKey(seed)
    params = dual_encoder.init_dual_encoder(key, cfg, de)

    def apply(p, batch):
        zf, _ = dual_encoder.encode(cfg, de, p, {"images": batch["v1"]})
        zg, _ = dual_encoder.encode(cfg, de, p, {"images": batch["v2"]})
        return zf, zg

    def embed(p, images):
        return resnet_mod.resnet_forward(cfg, p["tower"], images)

    return cfg, de, params, apply, embed


def _probe(embed, params, imgs, labels, n_train=400):
    z = embed(params, jnp.asarray(imgs))
    return float(eval_lib.ridge_linear_probe(
        z[:n_train], jnp.asarray(labels[:n_train]),
        z[n_train:], jnp.asarray(labels[n_train:]), int(labels.max()) + 1))


def _make_round_fn(method, apply, opt):
    """jit once per (method, shapes) — eager vmapped rounds are ~20x slower."""
    if method == "dcco":
        def fn(p, st, batch, sizes):
            return fed_sim.dcco_round(apply, p, st, opt, batch, sizes,
                                      lam=5.0, client_lr=1.0)
    elif method == "cco_fedavg":
        def fn(p, st, batch, sizes):
            return fed_sim.fedavg_round(apply, p, st, opt, batch, sizes,
                                        loss_kind="cco", lam=5.0, client_lr=0.5)
    elif method == "contrastive_fedavg":
        def fn(p, st, batch, sizes):
            return fed_sim.fedavg_round(apply, p, st, opt, batch, sizes,
                                        loss_kind="contrastive", client_lr=0.5)
    elif method == "centralized":
        def fn(p, st, batch, sizes):
            union = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), batch)
            mask = (jnp.arange(batch["v1"].shape[1])[None]
                    < sizes[:, None]).reshape(-1).astype(jnp.float32)
            return fed_sim.centralized_step(apply, p, st, opt, union,
                                            mask=mask, lam=5.0)
    else:
        raise ValueError(method)
    return jax.jit(fn)


def _pretrain(method, params, apply, ds, rounds, clients_per_round, opt_lr=2e-3):
    opt = opt_lib.adam(opt_lr)
    state = opt.init(params)
    p = params
    m = None
    round_fn = _make_round_fn(method, apply, opt)
    t0 = time.perf_counter()
    for r in range(rounds):
        batch, sizes = ds.round_batch(jax.random.PRNGKey(1000 + r),
                                      clients_per_round)
        p, state, m = round_fn(p, state, batch, sizes)
    us = (time.perf_counter() - t0) / rounds * 1e6
    return p, us, float(m.loss)


def table1_cifar(rounds=25):
    """Paper Table 1, miniaturized: split x method -> probe accuracy."""
    imgs, labels = synthetic.synthetic_labeled_images(600, 5, image_size=16,
                                                      noise=0.5, seed=1)
    cfg, de, params0, apply, embed = _setup()
    acc_rand = _probe(embed, params0, imgs, labels)
    emit("table1/random_init_probe", 0.0, f"acc={acc_rand:.3f}")
    splits = [("noniid_s1", 0.0, 1, 32), ("noniid_s4", 0.0, 4, 8),
              ("iid_s4", 1e9, 4, 8)]
    for split_name, alpha, spc, cpr in splits:
        ds = pipeline.FederatedDataset.build(
            {"images": imgs}, labels, num_clients=256 // max(spc, 1),
            samples_per_client=spc, alpha=alpha, seed=0)
        for method in ("dcco", "cco_fedavg", "contrastive_fedavg", "centralized"):
            if method == "cco_fedavg" and spc < 2:
                emit(f"table1/{split_name}/{method}", 0.0,
                     "acc=FAILED(n<2, per paper)")
                continue
            p, us, loss = _pretrain(method, params0, apply, ds, rounds, cpr)
            acc = _probe(embed, p, imgs, labels)
            emit(f"table1/{split_name}/{method}", us,
                 f"acc={acc:.3f};loss={loss:.3f}")
    sup = _supervised_scratch(cfg, imgs, labels)
    emit("table1/supervised_scratch", 0.0, f"acc={sup:.3f}")


def _supervised_scratch(cfg, imgs, labels, steps=60):
    key = jax.random.PRNGKey(3)
    n_cls = int(labels.max()) + 1
    p = {"tower": resnet_mod.resnet_init(key, cfg, jnp.float32),
         "head": {"w": jnp.zeros((cfg.d_model, n_cls)), "b": jnp.zeros((n_cls,))}}
    opt = opt_lib.adam(5e-3)
    st = opt.init(p)
    # limited labeled data (paper: 1-10% of the dataset; we use ~7%)
    x_tr = jnp.asarray(imgs[:40])
    y_tr = jnp.asarray(labels[:40])

    @jax.jit
    def step(p, st):
        def loss_fn(pp):
            z = resnet_mod.resnet_forward(cfg, pp["tower"], x_tr)
            logits = z @ pp["head"]["w"] + pp["head"]["b"]
            return losses.softmax_cross_entropy(logits, y_tr)
        g = jax.grad(loss_fn)(p)
        upd, st2 = opt.update(g, st, p)
        return opt_lib.apply_updates(p, upd), st2

    for _ in range(steps):
        p, st = step(p, st)
    z = resnet_mod.resnet_forward(cfg, p["tower"], jnp.asarray(imgs[400:]))
    logits = z @ p["head"]["w"] + p["head"]["b"]
    return float((jnp.argmax(logits, -1) == jnp.asarray(labels[400:])).mean())


def table2_derm(rounds=25):
    """Paper Table 2 protocol: clients hold 1-6 images; sweep clients/round."""
    imgs, labels = synthetic.synthetic_labeled_images(600, 5, image_size=16,
                                                      noise=0.5, seed=2)
    cfg, de, params0, apply, embed = _setup(seed=1)
    rng = np.random.RandomState(0)
    n_pad = 6
    num_clients = 80
    idx = rng.permutation(600)[: num_clients * n_pad].reshape(num_clients, n_pad)
    ds = pipeline.FederatedDataset({"images": imgs}, labels, idx)

    for cpr in (8, 16, 32):
        for method in ("dcco", "contrastive_fedavg"):
            opt = opt_lib.adam(2e-3)
            state = opt.init(params0)
            p = params0
            srng = np.random.RandomState(7)
            round_fn = _make_round_fn(method, apply, opt)
            t0 = time.perf_counter()
            for r in range(rounds):
                batch, _ = ds.round_batch(jax.random.PRNGKey(r), cpr)
                sizes = jnp.asarray(srng.randint(1, n_pad + 1, cpr), jnp.int32)
                p, state, m = round_fn(p, state, batch, sizes)
            us = (time.perf_counter() - t0) / rounds * 1e6
            acc = _probe(embed, p, imgs, labels)
            emit(f"table2/cpr{cpr}/{method}", us, f"acc={acc:.3f}")
    emit("table2/cco_fedavg", 0.0, "acc=FAILED(unstable n<=6, per paper)")


def figure3_collapse():
    """App. C / footnote 1 as a landscape probe: the constant (collapsed)
    encoder is the predictive loss's global minimum — 'loss drops to its
    lowest possible value' — while the CCO loss there is large and a
    whitened encoder beats it by >10x (collapse is not a CCO solution)."""
    key = jax.random.PRNGKey(0)
    n, d = 64, 8
    z_const = jnp.ones((n, d)) * 0.7 + 1e-4 * jax.random.normal(key, (n, d))
    byol_c = float(losses.byol_predictive_loss(z_const, z_const))
    cco_c = float(cco.cco_loss(z_const, z_const, 5.0))
    zf = jax.random.normal(jax.random.PRNGKey(1), (4096, d))
    zc = zf - zf.mean(0)
    u, s_, vt = jnp.linalg.svd(zc, full_matrices=False)
    zw = u * jnp.sqrt(4096)
    cco_w = float(cco.cco_loss(zw, zw, 5.0))
    emit("figure3/predictive_loss_at_collapse", 0.0,
         f"loss={byol_c:.2e}(global_min)")
    emit("figure3/cco_loss_at_collapse", 0.0, f"loss={cco_c:.3f}")
    emit("figure3/cco_loss_whitened", 0.0,
         f"loss={cco_w:.4f};collapse_penalty={cco_c / max(cco_w, 1e-6):.0f}x")


def dcco_round_bench():
    cfg, de, params, apply, _ = _setup()
    imgs, labels = synthetic.synthetic_labeled_images(400, 5, image_size=16)
    opt = opt_lib.adam(1e-3)
    state = opt.init(params)
    for cpr in (8, 32):
        ds = pipeline.FederatedDataset.build(
            {"images": imgs}, labels, num_clients=100, samples_per_client=2,
            alpha=0.0, seed=0)
        batch, sizes = ds.round_batch(jax.random.PRNGKey(0), cpr)
        rounder = jax.jit(lambda p, s, b, sz: fed_sim.dcco_round(
            apply, p, s, opt, b, sz, lam=5.0))
        us = _timeit(lambda: rounder(params, state, batch, sizes))
        emit(f"dcco_round/clients{cpr}", us, f"samples={cpr * 2}")


def round_engine_bench(rounds=100, cpr=16):
    """Scan-compiled engine vs the Python round loop, equal rounds.

    The loop path is the pre-engine driver: host-side cohort sampling +
    one jitted round per Python dispatch. The engine compiles sampling and
    all rounds into a single lax.scan program with a donated carry. Measured
    in the paper's regime — tiny clients (s=2), small dual encoder — where
    federated training is dispatch/sampling-bound, the regime the engine
    targets. (A compute-bound body like the smoke ResNet hides dispatch
    under ~90ms of conv work per round; see docs/architecture.md.)"""
    from repro.core import fed_sim, round_engine
    imgs, labels = synthetic.synthetic_labeled_images(400, 5, image_size=16)
    ds = pipeline.FederatedDataset.build(
        {"images": imgs}, labels, num_clients=100, samples_per_client=2,
        alpha=0.0, seed=0)
    key = jax.random.PRNGKey(0)
    params = {"w1": jax.random.normal(key, (16 * 16 * 3, 128)) * 0.05,
              "w2": jax.random.normal(jax.random.PRNGKey(7), (128, 64)) * 0.1}

    def apply(p, batch):
        def enc(x):
            return jnp.tanh(x.reshape(x.shape[0], -1) @ p["w1"]) @ p["w2"]
        return enc(batch["v1"]), enc(batch["v2"])

    opt = opt_lib.adam(1e-3)
    round_fn = jax.jit(lambda p, st, b, s: fed_sim.dcco_round(
        apply, p, st, opt, b, s, lam=5.0))
    batch, sizes = ds.round_batch(jax.random.PRNGKey(0), cpr)
    jax.block_until_ready(round_fn(params, opt.init(params), batch, sizes)[2].loss)
    p, st = params, opt.init(params)
    t0 = time.perf_counter()
    for r in range(rounds):
        batch, sizes = ds.round_batch(jax.random.PRNGKey(1000 + r), cpr)
        p, st, m = round_fn(p, st, batch, sizes)
    jax.block_until_ready(m.loss)
    us_loop = (time.perf_counter() - t0) / rounds * 1e6

    ecfg = round_engine.EngineConfig(algorithm="dcco", lam=5.0,
                                     chunk_rounds=rounds)
    eng = round_engine.RoundEngine(apply, opt, ds.make_round_sampler(cpr), ecfg)
    out = eng.run(params, opt.init(params), jax.random.PRNGKey(7), rounds)
    jax.block_until_ready(out[2].loss)                       # warmup/compile
    t0 = time.perf_counter()
    pe, se, me = eng.run(params, opt.init(params), jax.random.PRNGKey(7), rounds)
    jax.block_until_ready(me.loss)
    us_eng = (time.perf_counter() - t0) / rounds * 1e6

    emit("round_engine/python_loop", us_loop, f"rounds={rounds}")
    emit("round_engine/scan_engine", us_eng,
         f"rounds={rounds};speedup={us_loop / us_eng:.2f}x;"
         f"loss={float(me.loss[-1]):.3f}")


def async_stragglers(ticks=60, cpr=16, async_k=4, tail=0.7):
    """Sync vs buffered (FedBuff-style) engine under heavy-tail stragglers.

    Simulated-time model, machine-portable by construction: one scheduler
    tick is the unit of client latency. The SYNC engine waits for its
    slowest sampled client, so a round costs ``1 + max(cohort delays)``
    ticks (delays replayed host-side from the engine's own key stream);
    the BUFFERED engine (EngineConfig.async_k) dispatches a cohort every
    tick and applies an update per K-trigger, so its cost is
    ``ticks / updates_applied`` ticks per update. Both are deterministic
    functions of the latency model and seed — the gated speedup
    (sync/buffered ticks-per-update, benchmarks/compare.py) cancels
    machine speed entirely. Wall-clock us/tick and probe accuracy ride
    along as informational rows.
    """
    from repro.core import round_engine
    from repro.data import latency as latency_lib
    imgs, labels = synthetic.synthetic_labeled_images(600, 5, image_size=16)
    ds = pipeline.FederatedDataset.build(
        {"images": imgs}, labels, num_clients=128, samples_per_client=2,
        alpha=0.0, seed=0)
    key = jax.random.PRNGKey(0)
    params = {"w1": jax.random.normal(key, (16 * 16 * 3, 128)) * 0.05,
              "w2": jax.random.normal(jax.random.PRNGKey(7), (128, 64)) * 0.1}

    def apply(p, batch):
        def enc(x):
            return jnp.tanh(x.reshape(x.shape[0], -1) @ p["w1"]) @ p["w2"]
        return enc(batch["v1"]), enc(batch["v2"])

    def embed(p, images):
        x = images.reshape(images.shape[0], -1)
        return jnp.tanh(x @ p["w1"]) @ p["w2"]

    lat = latency_lib.LatencyModel("heavytail", horizon=8, tail=tail, seed=0)
    rng = jax.random.PRNGKey(7)

    # simulated sync cost: replay the engine's key derivation (round key =
    # fold_in(rng, r); selection key = split()[0]; delay key = the sampler's
    # fold_in salt) and charge each round its slowest sampled client
    sync_ticks = 0
    for r in range(ticks):
        k_sel, _ = jax.random.split(jax.random.fold_in(rng, r))
        sel = jax.random.choice(k_sel, ds.num_clients, (cpr,), replace=False)
        d = latency_lib.sample_delays(
            lat, jax.random.fold_in(k_sel, latency_lib._LATENCY_SALT),
            sel.astype(jnp.int32))
        sync_ticks += 1 + int(d.max())

    opt = opt_lib.adam(1e-3)
    eng = round_engine.RoundEngine(
        apply, opt, ds.make_round_sampler(cpr),
        round_engine.EngineConfig(algorithm="dcco", lam=5.0,
                                  chunk_rounds=ticks))
    out = eng.run(params, opt.init(params), rng, ticks)
    jax.block_until_ready(out[2].loss)                       # warmup/compile
    t0 = time.perf_counter()
    ps, _, ms = eng.run(params, opt.init(params), rng, ticks)
    jax.block_until_ready(ms.loss)
    us_sync = (time.perf_counter() - t0) / ticks * 1e6
    sync_tpu = sync_ticks / ticks
    emit("async_stragglers/sync", us_sync,
         f"ticks={ticks};sim_ticks={sync_ticks};"
         f"probe={_probe(embed, ps, imgs, labels):.3f}")

    eng = round_engine.RoundEngine(
        apply, opt, ds.make_async_round_sampler(cpr, lat),
        round_engine.EngineConfig(algorithm="dcco", lam=5.0,
                                  chunk_rounds=ticks, async_k=async_k,
                                  staleness_fn="poly", latency=lat))
    out = eng.run(params, opt.init(params), rng, ticks)
    jax.block_until_ready(out[2].loss)                       # warmup/compile
    t0 = time.perf_counter()
    pb, _, mb = eng.run(params, opt.init(params), rng, ticks)
    jax.block_until_ready(mb.loss)
    us_buf = (time.perf_counter() - t0) / ticks * 1e6
    updates = int(jnp.sum(mb.applied))
    stale = mb.staleness[mb.applied > 0]
    buf_tpu = ticks / max(updates, 1)
    emit("async_stragglers/buffered", us_buf,
         f"ticks={ticks};K={async_k};updates={updates};"
         f"stale={float(stale.mean()) if updates else 0.0:.2f};"
         f"probe={_probe(embed, pb, imgs, labels):.3f}")

    # the gated pair: simulated ticks per server update, sync vs buffered
    emit("async_stragglers/sync_ticks_per_update", sync_tpu,
         f"tail={tail};horizon=8")
    emit("async_stragglers/buffered_ticks_per_update", buf_tpu,
         f"tail={tail};K={async_k};"
         f"speedup={sync_tpu / buf_tpu:.2f}x")


def comm_sweep(rounds=25, cpr=16):
    """Bytes-on-the-wire vs probe accuracy across communication channels.

    Same synthetic non-IID setup as table1 (2-sample single-class clients),
    trained with the scan-compiled engine; every channel sees the identical
    round/cohort stream (the channel key is a fold_in off the round key, so
    selection/augmentation streams match the dense run). The derived column
    reports per-round phase-1 statistics bytes, total uplink MB, and the
    compression ratio vs dense — int8 stats compress ~3.97x (4 bytes -> 1
    byte + one f32 scale per tensor per client).
    """
    imgs, labels = synthetic.synthetic_labeled_images(600, 5, image_size=16,
                                                      noise=0.5, seed=1)
    cfg, de, params0, apply, embed = _setup()
    ds = pipeline.FederatedDataset.build(
        {"images": imgs}, labels, num_clients=128, samples_per_client=2,
        alpha=0.0, seed=0)
    sampler = ds.make_round_sampler(cpr)
    # per-client phase-1 payload: the CCO objective's stat spec at the
    # bench encoder's projection dim (stays truthful if either changes)
    from repro import objectives as objectives_lib
    stats_tmpl = objectives_lib.get_objective("dcco").stat_template(
        de.proj_dims[-1])
    dense_stats_b = comm.DenseChannel().payload_bytes(stats_tmpl)

    channels = [
        ("dense", comm.DenseChannel()),
        ("int8", comm.QuantizedChannel(8)),
        ("int4", comm.QuantizedChannel(4)),
        ("dp_s0.3", comm.DPGaussianChannel(0.3, clip_norm=10.0)),
        ("dropout_0.3", comm.DropoutChannel(0.3)),
    ]
    acc_dense = None
    for name, ch in channels:
        opt = opt_lib.adam(2e-3)
        ecfg = round_engine.EngineConfig(algorithm="dcco", lam=5.0,
                                         chunk_rounds=rounds, channel=ch)
        eng = round_engine.RoundEngine(apply, opt, sampler, ecfg)
        # warmup run: compiles the scan segment AND produces the trained
        # params for the probe; the timed run below re-runs the identical
        # stream so per-round us is steady-state, not compile-dominated
        # (pre-PR-8 this bench had no warmup, which is why the baseline
        # showed quantized rounds 1.5-1.6x slower than dense — that gap
        # was threefry compile time, not channel compute; the wall-clock
        # comm gate lives in `comm_round`)
        p, _, m = eng.run(params0, opt.init(params0),
                          jax.random.PRNGKey(7), rounds)
        jax.block_until_ready(p)
        t0 = time.perf_counter()
        p2, _, _ = eng.run(params0, opt.init(params0),
                           jax.random.PRNGKey(7), rounds)
        jax.block_until_ready(p2)
        us = (time.perf_counter() - t0) / rounds * 1e6
        acc = _probe(embed, p, imgs, labels)
        if acc_dense is None:
            acc_dense = acc
        stats_b = ch.payload_bytes(stats_tmpl)
        total_mb = float(jnp.sum(m.wire_bytes)) / 1e6
        extras = ""
        acct = getattr(ch, "accountant", None)
        if acct is not None:
            extras = f";eps={acct.epsilon():.1f}"
        emit(f"comm_sweep/{name}", us,
             f"acc={acc:.3f};d_acc={acc - acc_dense:+.3f};"
             f"stats_B={stats_b:.0f};stats_ratio={dense_stats_b / stats_b:.2f}x;"
             f"uplink_MB={total_mb:.2f}{extras}")


def server_opt_sweep(rounds=25, cpr=16):
    """Non-IID severity x server-update strategy -> probe accuracy.

    The paper's degradation axis (Table 1): label-sharded single-class
    2-sample clients (alpha=0, the hard setting) vs IID splits of the same
    data. Each cell trains the same DCCO engine run, differing only in the
    repro.server ServerUpdate strategy (and drift correction for the
    scaffold row) — the sweep that motivates server adaptivity on small
    non-IID cohorts. Rows emit probe accuracy and the per-round latency,
    so BENCH.json records both the quality and the cost trajectory.
    """
    from repro.server import get_server_update
    imgs, labels = synthetic.synthetic_labeled_images(600, 5, image_size=16,
                                                      noise=1.0, seed=1)
    cfg, de, params0, apply, embed = _setup()
    strategies = [
        ("fedavg_sgd", lambda: get_server_update("fedavg_sgd", server_lr=1.0),
         {}),
        ("fedavgm", lambda: get_server_update("fedavgm", server_lr=0.3), {}),
        ("fedadam", lambda: get_server_update("fedadam", server_lr=1e-2), {}),
        ("fedyogi", lambda: get_server_update("fedyogi", server_lr=1e-2), {}),
        # scaffold at one local step: under cohort sampling the slot
        # variates still reshape the update (slot != client), and the
        # 2-sample clients' local stats make multi-step local training
        # diverge regardless of strategy (degenerate within-client
        # variance), so L=1 is the stable comparison point here
        ("fedadam_scaffold",
         lambda: get_server_update("fedadam", server_lr=1e-2),
         {"scaffold": True}),
    ]
    for split_name, alpha in (("noniid", 0.0), ("iid", 1e9)):
        ds = pipeline.FederatedDataset.build(
            {"images": imgs}, labels, num_clients=300, samples_per_client=2,
            alpha=alpha, seed=0)
        sampler = ds.make_round_sampler(cpr)
        acc_base = None
        for name, make_su, extra in strategies:
            su = make_su()
            ecfg = round_engine.EngineConfig(
                algorithm="dcco", lam=5.0, chunk_rounds=rounds,
                server_update=su, **extra)
            eng = round_engine.RoundEngine(apply, su, sampler, ecfg)
            t0 = time.perf_counter()
            p, _, m = eng.run(params0, su.init(params0),
                              jax.random.PRNGKey(7), rounds)
            us = (time.perf_counter() - t0) / rounds * 1e6
            acc = _probe(embed, p, imgs, labels)
            if acc_base is None:
                acc_base = acc
            emit(f"server_opt_sweep/{split_name}/{name}", us,
                 f"acc={acc:.3f};d_acc={acc - acc_base:+.3f};"
                 f"loss={float(m.loss[-1]):.3f}")


def population_scale(rounds=3, cohorts=(64, 256, 1024, 4096), chunk=64,
                     materialize_max=256):
    """Cohort size as a memory-free knob: round time and compiled peak
    memory, materialized vs streamed (EngineConfig.cohort_chunk), as the
    cohort grows 64 -> 4096 clients/round — the cross-device population
    regime (thousands of devices, 2 samples each).

    Same dispatch-bound tiny-encoder setup as ``round_engine_bench``.
    Memory is read from XLA's compiled-program analysis of the engine's
    scan segment (argument + temp bytes — machine-independent, it is the
    compiler's own allocation plan): the materialized path grows O(cohort)
    while the streamed path stays O(chunk). Rows at the largest cohort
    both paths run feed the CI gate in compare.py: the streamed round's
    time overhead over the materialized round (same process, same host,
    so the ratio is machine-portable) must not regress.
    """
    from repro.core import round_engine
    max_cohort = max(cohorts)
    imgs, labels = synthetic.synthetic_labeled_images(
        2 * max_cohort, 5, image_size=16, noise=0.5, seed=0)
    ds = pipeline.FederatedDataset.build(
        {"images": imgs}, labels, num_clients=max_cohort,
        samples_per_client=2, alpha=0.0, seed=0)
    key = jax.random.PRNGKey(0)
    params = {"w1": jax.random.normal(key, (16 * 16 * 3, 128)) * 0.05,
              "w2": jax.random.normal(jax.random.PRNGKey(7), (128, 64)) * 0.1}

    def apply(p, batch):
        def enc(x):
            return jnp.tanh(x.reshape(x.shape[0], -1) @ p["w1"]) @ p["w2"]
        return enc(batch["v1"]), enc(batch["v2"])

    def compiled_bytes(eng, carry):
        """XLA's own allocation plan for one scan segment (bytes). The
        AOT-lowering surface is version-sensitive; a failure degrades the
        row to compiled_MB=0.0 but says so on stderr rather than letting
        the memory evidence vanish silently."""
        try:
            mem = eng._segment_fn(eng.config.chunk_rounds).lower(
                carry, jnp.asarray(0, jnp.int32)).compile().memory_analysis()
            return sum(int(getattr(mem, f, 0) or 0) for f in
                       ("argument_size_in_bytes", "temp_size_in_bytes",
                        "output_size_in_bytes"))
        except Exception as e:  # pragma: no cover - jax-version drift
            print(f"population_scale: compiled memory analysis "
                  f"unavailable ({type(e).__name__}: {e}); emitting "
                  f"compiled_MB=0.0", file=sys.stderr)
            return 0

    def run_engine(cohort, cohort_chunk):
        opt = opt_lib.adam(1e-3)
        if cohort_chunk:
            sampler = ds.make_streaming_sampler(cohort, cohort_chunk)
        else:
            sampler = ds.make_round_sampler(cohort)
        ecfg = round_engine.EngineConfig(algorithm="dcco", lam=5.0,
                                         chunk_rounds=rounds,
                                         cohort_chunk=cohort_chunk)
        eng = round_engine.RoundEngine(apply, opt, sampler, ecfg)
        carry = round_engine.EngineCarry(params, opt.init(params),
                                         jax.random.PRNGKey(7))
        mem = compiled_bytes(eng, carry)
        out = eng.run(params, opt.init(params), jax.random.PRNGKey(7), rounds)
        jax.block_until_ready(out[2].loss)            # warmup/compile
        t0 = time.perf_counter()
        p, s, m = eng.run(params, opt.init(params), jax.random.PRNGKey(7),
                          rounds)
        jax.block_until_ready(m.loss)
        us = (time.perf_counter() - t0) / rounds * 1e6
        return us, mem, float(m.loss[-1])

    last_mat = None
    for cohort in cohorts:
        if cohort <= materialize_max:
            us_m, mem_m, _ = run_engine(cohort, 0)
            emit(f"population_scale/materialized_c{cohort}", us_m,
                 f"cohort={cohort};compiled_MB={mem_m / 1e6:.1f}")
            last_mat = (cohort, us_m)
        us_s, mem_s, loss = run_engine(cohort, min(chunk, cohort))
        extra = ""
        if last_mat is not None and last_mat[0] == cohort:
            extra = f";stream_vs_mat={us_s / last_mat[1]:.2f}x"
        emit(f"population_scale/streaming_c{cohort}", us_s,
             f"cohort={cohort};chunk={min(chunk, cohort)};"
             f"compiled_MB={mem_s / 1e6:.1f};loss={loss:.3f}{extra}")


def fused_step_bench():
    from repro.configs.base import TrainConfig
    from repro.launch import steps as steps_lib
    cfg = get_config("tinyllama-1.1b", smoke=True)
    de = DualEncoderConfig(proj_dims=(64, 64), lambda_cco=5.0)
    opt = opt_lib.adam(1e-3)
    key = jax.random.PRNGKey(0)
    params = dual_encoder.init_dual_encoder(key, cfg, de)
    toks = jax.random.randint(key, (8, 64), 0, cfg.vocab_size)
    batch = {"view1": {"tokens": toks}, "view2": {"tokens": jnp.roll(toks, 1, -1)}}
    for nm in (1, 4):
        tcfg = TrainConfig(seq_len=64, global_batch=8, dcco_impl="fused")
        step = jax.jit(steps_lib.make_dcco_train_step(
            cfg, de, tcfg, opt, num_microbatches=nm))
        st = opt.init(params)
        us = _timeit(lambda: step(params, st, batch))
        emit(f"fused_step/micro{nm}", us,
             "exact_microbatch" if nm > 1 else "plain")


def stats_kernel_bench(sizes=((512, 256), (2048, 512))):
    from repro.kernels import ref
    from repro.kernels.cco_stats import cco_stats_pallas
    key = jax.random.PRNGKey(0)
    for (n, d) in sizes:
        zf = jax.random.normal(key, (n, d))
        zg = jax.random.normal(jax.random.PRNGKey(1), (n, d))
        us_k = _timeit(lambda: cco_stats_pallas(zf, zg, interpret=True), n=1)
        us_f = _timeit(lambda: cco_stats_pallas(zf, zg, interpret=True,
                                                moments="full"), n=1)
        us_r = _timeit(lambda: ref.cco_stats_ref(zf, zg))
        naive = 5 * 2 * n * d * 4            # five separate passes
        fused = 2 * n * d * 4 + d * d * 4    # one pass + output
        emit(f"stats_kernel/{n}x{d}", us_k,
             f"ref_us={us_r:.0f};full_moments_us={us_f:.0f};"
             f"full_vs_cross={us_f / us_k:.2f}x;"
             f"hbm_naive_vs_fused={naive / fused:.2f}x")

    # The CI-gated row pair (benchmarks/compare.py): the generalized
    # one-pass formulation (all 7 statistics from one read of zf/zg, the
    # computation the Pallas kernel fuses) vs the naive per-statistic
    # passes (one jitted reduction each — 7 separate reads). Both sides
    # run on the same machine in the same process, so the ratio cancels
    # machine speed and isolates what this repo controls: that the fused
    # moment computation stays a single-pass win.
    n, d = 4096, 128
    zf = jax.random.normal(key, (n, d))
    zg = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    one_pass = jax.jit(lambda f, g: ref.cco_stats_ref(f, g,
                                                      second_moments=True))
    naive_fns = [jax.jit(f) for f in (
        lambda f, g: f.mean(0),
        lambda f, g: (f * f).mean(0),
        lambda f, g: g.mean(0),
        lambda f, g: (g * g).mean(0),
        lambda f, g: f.T @ g / f.shape[0],
        lambda f, g: f.T @ f / f.shape[0],
        lambda f, g: g.T @ g / g.shape[0],
    )]
    us_one = _timeit(lambda: one_pass(zf, zg), n=10)
    us_naive = _timeit(lambda: [f(zf, zg) for f in naive_fns], n=10)
    emit("stats_kernel/naive_passes", us_naive, f"n={n};d={d};stats=7")
    emit("stats_kernel/one_pass", us_one,
         f"n={n};d={d};one_pass_vs_naive={us_naive / us_one:.2f}x")


def objective_sweep(rounds=25, cpr=16):
    """The StatsObjective protocol, measured per registered objective:
    phase-1 stats payload bytes, fused-kernel time for the objective's
    moment set (interpret mode — relative cross-vs-full comparison), and
    linear-probe accuracy after the same engine-compiled training run.
    Every objective sees the identical cohort/augmentation stream and a
    DenseChannel wire, so bytes and accuracy are directly comparable.
    """
    from repro import objectives as objectives_lib
    from repro.kernels.cco_stats import cco_stats_pallas
    imgs, labels = synthetic.synthetic_labeled_images(600, 5, image_size=16,
                                                      noise=0.5, seed=1)
    cfg, de, params0, apply, embed = _setup()
    ds = pipeline.FederatedDataset.build(
        {"images": imgs}, labels, num_clients=128, samples_per_client=2,
        alpha=0.0, seed=0)
    sampler = ds.make_round_sampler(cpr)
    d_enc = de.proj_dims[-1]
    kn, kd = 256, 64
    kzf = jax.random.normal(jax.random.PRNGKey(2), (kn, kd))
    kzg = jax.random.normal(jax.random.PRNGKey(3), (kn, kd))
    for name in objectives_lib.OBJECTIVES:
        obj = objectives_lib.get_objective(
            name, **({"lam": 5.0} if name == "dcco" else {}))
        ch = comm.DenseChannel()
        opt = opt_lib.adam(2e-3)
        ecfg = round_engine.EngineConfig(algorithm="dcco", objective=obj,
                                         chunk_rounds=rounds, channel=ch)
        eng = round_engine.RoundEngine(apply, opt, sampler, ecfg)
        t0 = time.perf_counter()
        p, _, m = eng.run(params0, opt.init(params0),
                          jax.random.PRNGKey(7), rounds)
        us = (time.perf_counter() - t0) / rounds * 1e6
        acc = _probe(embed, p, imgs, labels)
        stats_b = ch.payload_bytes(obj.stat_template(d_enc))
        moments = "full" if obj.second_moments else "cross"
        us_kernel = _timeit(lambda: cco_stats_pallas(
            kzf, kzg, interpret=True, moments=moments), n=1)
        emit(f"objective_sweep/{name}", us,
             f"acc={acc:.3f};loss={float(m.loss[-1]):.3f};"
             f"stats_B={stats_b:.0f};stats={len(obj.stat_keys)};"
             f"kernel_us={us_kernel:.0f};"
             f"uplink_MB={float(jnp.sum(m.wire_bytes)) / 1e6:.2f}")


def stale_stats_study(rounds=20):
    """Paper Sec. 6 open question: with >1 local steps per round the
    aggregated statistics go stale and gradients are partial. We fix the
    per-round client lr budget C (so first-order effects cancel between
    L steps of lr C/L and 1 step of lr C) and measure the deviation of the
    resulting round update — the pure staleness error. Finding: the
    deviation is O(C) relative (second-order absolute), i.e. multiple local
    steps are safe at small client lrs and increasingly biased at large
    ones; derived column reports dev/|update| per (C, L)."""
    from repro import utils
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (8, 4)) * 0.4}

    def apply(p, b):
        return jnp.tanh(b["v1"] @ p["w"]), jnp.tanh(b["v2"] @ p["w"])

    k1, k2 = jax.random.split(key)
    data = {"v1": jax.random.normal(k1, (8, 2, 8)),
            "v2": jax.random.normal(k2, (8, 2, 8))}
    sizes = jnp.full((8,), 2, jnp.int32)
    opt = opt_lib.sgd(1.0)
    for C in (0.1, 0.01):
        ref, _, _ = fed_sim.dcco_round(apply, params, opt.init(params), opt,
                                       data, sizes, lam=5.0, client_lr=C,
                                       local_steps=1)
        upd = utils.tree_norm(utils.tree_sub(ref, params)) + 1e-12
        for L in (2, 4):
            pl, _, _ = fed_sim.dcco_round(apply, params, opt.init(params),
                                          opt, data, sizes, lam=5.0,
                                          client_lr=C / L, local_steps=L)
            dev = utils.tree_norm(utils.tree_sub(pl, ref))
            emit(f"stale_stats/C{C}/L{L}", 0.0,
                 f"rel_dev={float(dev / upd):.5f}")


def dvicreg_bench(rounds=20):
    """Paper Sec. 6 future work: the statistics strategy with VICReg —
    now one line through the StatsObjective protocol (fed_sim.stats_round
    with the registered dvicreg objective) instead of a hand-rolled round."""
    from repro import objectives as objectives_lib
    cfg, de, params0, apply, embed = _setup(seed=4)
    imgs, labels = synthetic.synthetic_labeled_images(400, 5, image_size=16,
                                                      noise=0.5, seed=4)
    ds = pipeline.FederatedDataset.build(
        {"images": imgs}, labels, num_clients=100, samples_per_client=2,
        alpha=0.0, seed=0)
    opt = opt_lib.adam(2e-3)
    obj = objectives_lib.get_objective("dvicreg")
    round_fn = jax.jit(lambda p, st, b, s: fed_sim.stats_round(
        apply, p, st, opt, b, s, objective=obj))
    state = opt.init(params0)
    p = params0
    t0 = time.perf_counter()
    for r in range(rounds):
        batch, sizes = ds.round_batch(jax.random.PRNGKey(700 + r), 16)
        p, state, m = round_fn(p, state, batch, sizes)
    us = (time.perf_counter() - t0) / rounds * 1e6
    acc0 = _probe(embed, params0, imgs, labels, n_train=300)
    acc = _probe(embed, p, imgs, labels, n_train=300)
    emit("dvicreg/federated", us,
         f"probe={acc:.3f}(init={acc0:.3f});loss={float(m.loss):.2f}")


def retrieval_serving(qn=64, n=4096, d=64, k=10,
                      corpus_sizes=(1024, 4096, 16384), serve_batches=20):
    """Retrieval serving: the fused MIPS top-k path vs the naive
    materialize-then-top_k program, plus QueryServer throughput/latency
    vs corpus size.

    Three row groups, two of them gated (benchmarks/compare.py):

      * compiled memory — XLA's own allocation plan (temp bytes) for both
        programs at the bench shape (Q=64, N=4096). The naive program
        materializes the (Q, N) f32 score matrix (temp >= Q*N*4 bytes);
        the fused path scans the corpus in chunks and keeps only the
        running (Q, k) state. GATED: the naive/fused temp ratio must not
        regress, and the fused temp must stay strictly under the score-
        matrix bytes (the subsystem's reason to exist) — both sides come
        from the same compiler in the same process, so the ratio is
        machine-portable.
      * calibrated fraction-of-roofline — the fused search's measured
        time vs the analytic bound (costmodel.mips_cost) evaluated with
        THIS machine's calibrated peaks (an in-process jitted matmul for
        flops/s, a big-array copy for bytes/s). GATED as a ratio of two
        same-process measurements; the TPU-spec analytic row rides along
        informationally.
      * QueryServer qps/p50/p99 vs corpus size — informational; the
        serving numbers a dashboard would track.
    """
    from benchmarks import costmodel
    from repro.kernels.mips_topk import mips_topk_chunked
    from repro.launch.mesh import HardwareSpec as HW
    from repro.retrieval import CorpusIndex, QueryServer, l2_normalize
    key = jax.random.PRNGKey(0)
    q = l2_normalize(jax.random.normal(key, (qn, d), jnp.float32))
    c = l2_normalize(jax.random.normal(jax.random.PRNGKey(1), (n, d),
                                       jnp.float32))
    naive = jax.jit(lambda q, c: jax.lax.top_k(q @ c.T, k))
    fused = jax.jit(lambda q, c: mips_topk_chunked(q, c, k=k, chunk=512))

    def temp_bytes(fn):
        """XLA's compiled temp allocation; degrades to 0 with a stderr
        notice on jax-version drift (the gate then fails loudly rather
        than the memory evidence vanishing silently)."""
        try:
            mem = fn.lower(q, c).compile().memory_analysis()
            return int(mem.temp_size_in_bytes)
        except Exception as e:  # pragma: no cover - jax-version drift
            print(f"retrieval_serving: compiled memory analysis "
                  f"unavailable ({type(e).__name__}: {e})", file=sys.stderr)
            return 0

    score_b = qn * n * 4
    naive_b, fused_b = temp_bytes(naive), temp_bytes(fused)
    emit("retrieval_serving/score_matrix_bytes", float(score_b),
         f"q{qn}_n{n}_d{d}_k{k}")
    emit("retrieval_serving/naive_temp_bytes", float(naive_b),
         f"materializes_QN={naive_b >= score_b}")
    emit("retrieval_serving/fused_temp_bytes", float(fused_b),
         f"naive_vs_fused={naive_b / max(fused_b, 1):.2f}x;"
         f"of_score_matrix={fused_b / score_b:.3f}")

    us_naive = _timeit(lambda: naive(q, c), n=5, best_of=4)
    us_fused = _timeit(lambda: fused(q, c), n=5, best_of=4)
    emit("retrieval_serving/naive_search", us_naive, f"q{qn}_n{n}_d{d}_k{k}")
    emit("retrieval_serving/fused_search", us_fused,
         f"fused_vs_naive_time={us_fused / us_naive:.2f}x")

    # calibrate this machine's achievable peaks in-process, then score the
    # fused search against the analytic bound at those peaks
    flops_s, bytes_s = _calibrate_peaks()
    cost = costmodel.mips_cost(qn, n, d, k)
    bound_us = max(cost.flops_dev / flops_s,
                   cost.hbm_bytes_dev / bytes_s) * 1e6
    emit("retrieval_serving/roofline_fraction_pct",
         100.0 * bound_us / us_fused,
         f"bound_us={bound_us:.1f};calib_gflops={flops_s / 1e9:.1f};"
         f"calib_GBps={bytes_s / 1e9:.1f}")
    ro = cost.roofline()
    emit("retrieval_serving/analytic_tpu_bound",
         ro["step_s_lower_bound"] * 1e6,
         f"dom={ro['dominant']};intensity={cost.notes['intensity_fused']:.1f};"
         f"spec={HW.PEAK_FLOPS_BF16 / 1e12:.0f}TF")

    qkey = jax.random.PRNGKey(3)
    qpool = l2_normalize(jax.random.normal(qkey, (serve_batches, 64, d),
                                           jnp.float32))
    for nn in corpus_sizes:
        idx = CorpusIndex(l2_normalize(jax.random.normal(
            jax.random.fold_in(qkey, nn), (nn, d), jnp.float32)))
        srv = QueryServer(idx, k=k, batch=64).warmup()
        for i in range(serve_batches):
            srv.query(qpool[i])
        s = srv.stats()
        emit(f"retrieval_serving/qserver_n{nn}", s["p50_us"],
             f"qps={s['qps']:.0f};qps_serial={s['qps_serial']:.0f};"
             f"p99_us={s['p99_us']:.0f};batches={s['batches']}")


def mixed_precision(rounds=10, cpr=16, arch="qwen3-1.7b", shape="train_4k"):
    """Mixed-precision encoders (EngineConfig.compute_dtype="bfloat16"):
    the encoder forward/backward narrows to bf16, every Eq.-3 statistic
    accumulation stays f32 (core/round_engine.cast_encoder_apply).

    Two row groups:

      * modeled step time (GATED) — costmodel.train_cost at the production
        arch/shape with compute_bytes={F32, BF16} and the matching MXU
        peak; the f32/bf16 bound ratio is the gated speedup. Modeled, not
        measured, because the gate must be machine-portable and XLA:CPU
        has no fast bf16 path (measured on this runner bf16 is SLOWER —
        the measured rows below record exactly that, informationally).
      * probe parity (GATED) — the same engine run at f32 vs bf16 compute
        on the bench encoder; the linear-probe accuracies ride in the
        us_per_call field and compare.py asserts |bf16 - f32| stays within
        tolerance. This is the numerics-contract acceptance: if bf16 ever
        leaks into the statistics accumulation, parity is what breaks.
    """
    from benchmarks import costmodel
    from repro.configs.base import get_dual_encoder_config, get_config as _gc
    from repro.launch.inputs import INPUT_SHAPES, arch_variant_for_shape
    from repro.launch.mesh import HardwareSpec as HW

    # --- modeled rows (the gated speedup)
    sh = INPUT_SHAPES[shape]
    mcfg = arch_variant_for_shape(_gc(arch), sh)
    de_proj = tuple(get_dual_encoder_config(arch).proj_dims)
    bounds = {}
    for label, cbytes, peak in (
            ("f32", costmodel.F32, HW.PEAK_FLOPS_F32),
            ("bf16", costmodel.BF16, HW.PEAK_FLOPS_BF16)):
        cost = costmodel.train_cost(mcfg, sh, multi_pod=False,
                                    de_proj=de_proj, compute_bytes=cbytes)
        ro = cost.roofline(peak)
        bounds[label] = ro["step_s_lower_bound"]
        emit(f"mixed_precision/{label}_step_model",
             ro["step_s_lower_bound"] * 1e6,
             f"{arch}/{shape};dom={ro['dominant']}")
    emit("mixed_precision/model_speedup", 0.0,
         f"bf16_vs_f32={bounds['f32'] / bounds['bf16']:.2f}x")

    # --- measured rows (wall-clock informational, probe parity gated)
    imgs, labels = synthetic.synthetic_labeled_images(600, 5, image_size=16,
                                                      noise=0.5, seed=1)
    cfg, de, params0, apply, embed = _setup()
    ds = pipeline.FederatedDataset.build(
        {"images": imgs}, labels, num_clients=128, samples_per_client=2,
        alpha=0.0, seed=0)
    sampler = ds.make_round_sampler(cpr)
    accs = {}
    for dtype in ("float32", "bfloat16"):
        opt = opt_lib.adam(2e-3)
        ecfg = round_engine.EngineConfig(algorithm="dcco", lam=5.0,
                                         chunk_rounds=rounds,
                                         compute_dtype=dtype)
        eng = round_engine.RoundEngine(apply, opt, sampler, ecfg)
        p, _, m = eng.run(params0, opt.init(params0),
                          jax.random.PRNGKey(7), rounds)
        jax.block_until_ready(p)
        t0 = time.perf_counter()
        p2, _, _ = eng.run(params0, opt.init(params0),
                           jax.random.PRNGKey(7), rounds)
        jax.block_until_ready(p2)
        us = (time.perf_counter() - t0) / rounds * 1e6
        tag = "f32" if dtype == "float32" else "bf16"
        accs[tag] = _probe(embed, p, imgs, labels)
        emit(f"mixed_precision/{tag}_round_measured", us,
             f"loss={float(m.loss[-1]):.3f}")
        # emit() keeps one decimal, so ship acc x 1000 (milli-accuracy) to
        # preserve the resolution the parity gate compares at
        emit(f"mixed_precision/probe_{tag}", accs[tag] * 1000.0,
             "acc_x1000" if tag == "f32" else
             f"acc_x1000;d_acc={accs['bf16'] - accs['f32']:+.3f}")


def comm_round(cpr=16, bits_list=(32, 8, 4)):
    """The gated wall-clock cost of one federated comm round, dense vs
    quantized: encode/decode COMPUTE (measured, warmed, jitted) plus WIRE
    time (modeled at HardwareSpec.FED_UPLINK_BW — clients are phones on
    ~20 Mbit/s uplinks, the paper's setting; clients upload in parallel so
    the round waits on one payload).

    The payload is one realistic round's per-client uplink: the CCO stat
    template plus a full parameter-delta tree of the bench encoder,
    stacked K=cpr clients deep — the exact trees QuantizedChannel sees in
    phases 1 and 2. GATED in compare.py: the int8 round total must be <=
    the dense round total (HARD — compression must never cost wall-clock)
    and the int8/dense ratio must not regress. The fused whole-payload
    quantizer (comm.quantize.quant_dequant_payload) is what makes the
    compute side small enough for the wire saving to dominate.
    """
    from benchmarks import costmodel
    from repro import objectives as objectives_lib
    cfg, de, params0, apply, embed = _setup()
    key = jax.random.PRNGKey(0)
    stats_tmpl = objectives_lib.get_objective("dcco").stat_template(
        de.proj_dims[-1])
    stats_k = jax.tree.map(
        lambda s: jax.random.normal(key, (cpr,) + s.shape, jnp.float32),
        stats_tmpl)
    deltas_k = jax.tree.map(
        lambda p: 0.01 * jax.random.normal(key, (cpr,) + p.shape,
                                           jnp.float32), params0)
    n_elems = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(stats_tmpl))
    n_elems += sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params0))
    n_leaves = len(jax.tree.leaves(stats_tmpl)) + len(jax.tree.leaves(params0))
    sizes = jnp.full((cpr,), 2, jnp.int32)

    totals = {}
    for bits in bits_list:
        name = "dense" if bits == 32 else f"int{bits}"
        ch = comm.DenseChannel() if bits == 32 else comm.QuantizedChannel(bits)

        def both_phases(k1, stats_k, deltas_k, ch=ch):
            ctx = ch.begin_round(k1, sizes)
            return (ch.aggregate(ctx, stats_k, "stats"),
                    ch.aggregate(ctx, deltas_k, "update"))

        fn = jax.jit(both_phases)
        us_compute = _timeit(lambda: fn(key, stats_k, deltas_k), n=5)
        wire = costmodel.comm_round_cost(n_elems, bits)
        us_total = us_compute + wire["wire_s"] * 1e6
        totals[name] = us_total
        emit(f"comm_round/{name}_compute", us_compute,
             f"elems={n_elems};leaves={n_leaves};K={cpr}")
        emit(f"comm_round/{name}_round_model", us_total,
             f"wire_KB={wire['wire_bytes'] / 1e3:.0f};"
             f"wire_us={wire['wire_s'] * 1e6:.0f};"
             f"uplink_Mbps={8 * costmodel.HW.FED_UPLINK_BW / 1e6:.0f}")
    for name in totals:
        if name != "dense":
            emit(f"comm_round/{name}_vs_dense", 0.0,
                 f"ratio={totals[name] / totals['dense']:.3f}")


def kernel_roofline():
    """Calibrated fraction-of-roofline for the remaining federated Pallas
    kernels — `cco_stats`, `segment_sum`, `quantize` — extending the PR-7
    mips_topk gate to the whole kernel surface.

    Method (same as `retrieval_serving`): time the jitted REFERENCE
    implementation of each kernel's math (kernels/ref.py and the shared
    qdq formula — on this CPU runner the Pallas kernels run interpreted,
    which times the interpreter, not the algorithm), compute the analytic
    bound (costmodel.{cco_stats,segment_sum,quantize}_cost) at THIS
    machine's calibrated peaks, and emit the achieved fraction in percent.
    GATED in compare.py as a no-regress ratio; the analytic TPU rows live
    in roofline.build_kernel_table (the `roofline` bench).
    """
    from benchmarks import costmodel
    from repro.comm.quantize import _qdq_formula, qmax_for_bits
    from repro.kernels import ref
    flops_s, bytes_s = _calibrate_peaks()

    def fraction(name, fn, args, cost, n=5):
        us = _timeit(lambda: fn(*args), n=n, best_of=4)
        bound_us = max(cost.flops_dev / flops_s,
                       cost.hbm_bytes_dev / bytes_s) * 1e6
        emit(f"kernel_roofline/{name}_fraction_pct", 100.0 * bound_us / us,
             f"measured_us={us:.1f};bound_us={bound_us:.1f};"
             f"calib_gflops={flops_s / 1e9:.1f};"
             f"calib_GBps={bytes_s / 1e9:.1f}")

    key = jax.random.PRNGKey(0)
    n_rows, d = 4096, 512
    zf = jax.random.normal(key, (n_rows, d), jnp.float32)
    zg = jax.random.normal(jax.random.fold_in(key, 1), (n_rows, d),
                           jnp.float32)
    fraction("cco_stats", jax.jit(ref.cco_stats_ref), (zf, zg),
             costmodel.cco_stats_cost(n_rows, d))

    k_cl, d_st, e = 4096, 4352, 64
    rows = jax.random.normal(key, (k_cl, d_st), jnp.float32)
    seg = jax.random.randint(jax.random.fold_in(key, 2), (k_cl,), 0, e)
    w = jax.random.uniform(jax.random.fold_in(key, 3), (k_cl,), jnp.float32)
    fraction(
        "segment_sum",
        jax.jit(lambda r, s, w: ref.segment_sum_ref(r, s, e, weights=w)),
        (rows, seg, w), costmodel.segment_sum_cost(k_cl, d_st, e))

    kq, nq, bits = 256, 55296, 8
    qmax = qmax_for_bits(bits)
    flat = jax.random.normal(key, (kq, nq), jnp.float32)
    u = jax.random.uniform(jax.random.fold_in(key, 4), (kq, nq), jnp.float32)
    scales = jnp.abs(flat).max(axis=1) / qmax
    fraction("quantize",
             jax.jit(lambda f, u, s: _qdq_formula(f, u, s, qmax)),
             (flat, u, scales), costmodel.quantize_cost(kq, nq, bits))


def roofline_bench():
    rows = roofline_mod.build_table()
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        emit(f"roofline/{r['arch']}/{r['shape']}",
             r["step_lower_bound_s"] * 1e6,
             f"dom={r['dominant']};useful={r['useful_ratio']:.2f}")
    emit("roofline/summary", 0.0,
         ";".join(f"{k}={v}" for k, v in sorted(doms.items())))
    for r in roofline_mod.build_mips_table() + roofline_mod.build_kernel_table():
        emit(f"roofline/{r['arch']}/{r['shape']}",
             r["step_lower_bound_s"] * 1e6,
             f"dom={r['dominant']};"
             f"fused_vs_naive_bound={r['fused_vs_naive_bound']:.2f}x;"
             f"intensity={r['intensity_fused']:.1f}")


def retrieval_scale(qn=64, n=8192, d=64, k=10, shards=4,
                    num_centroids=256, nprobe=4,
                    nprobe_curve=(1, 2, 4, 8, 16),
                    refresh_n=4096, refresh_block=64):
    """Retrieval at scale: sharded exact search, the IVF approximate
    tier's recall-vs-qps curve, and drift-gated streaming refresh.

    Gated rows (benchmarks/compare.py; every gate is a same-process ratio
    or a deterministic count — machine-portable):

      * sharded — one shard's local fused search (N/S rows, offset
        contract) + the S·k candidate merge are timed separately; the
        modeled S-device parallel time (shard_us + merge_us — the
        all-gather moves S*Q*k entries, noise at these shapes) must BEAT
        the measured single-device exact search (HARD: sharding must
        never slow a fixed-size search down), and the vmap-sharded result
        must match single-device search bit-for-bit (HARD);
      * ivf — recall@10 vs the exact ground truth at the default nprobe
        (HARD floor: >= 0.95, x1000 row) while the pruned search beats
        the exact tier's latency (HARD ratio > 1). The corpus is
        clustered (items around num_centroids centers — embedding
        corpora cluster; uniform-random would be IVF's pathological
        no-structure case) and the per-nprobe curve rows record the
        recall-vs-qps tradeoff;
      * refresh — a two-group linear-encoder scenario where perturbing
        one weight block drifts exactly a quarter of the corpus: the
        drift-gated refresh must re-encode < 50% of the items a full
        rebuild would (HARD, includes probe overhead) while its
        post-refresh top-k matches the full rebuild's (HARD parity,
        x1000).
    """
    from benchmarks import costmodel
    from repro.kernels.mips_topk import mips_topk
    from repro.retrieval import (CorpusIndex, IVFIndex, l2_normalize,
                                 refresh_embeddings)
    from repro.retrieval.sharded import (merge_topk, sharded_mips_topk,
                                         stack_shards)

    # clustered corpus: items around true_c natural clusters (embedding
    # corpora cluster — k-means then sub-divides each, true_c = C/2),
    # queries near cluster centers with smaller noise than the items
    key = jax.random.PRNGKey(0)
    true_c = max(1, num_centroids // 2)
    centers = l2_normalize(jax.random.normal(key, (true_c, d), jnp.float32))
    per = -(-n // true_c)
    noise = 0.2 * jax.random.normal(jax.random.PRNGKey(1), (n, d),
                                    jnp.float32)
    c = l2_normalize(jnp.repeat(centers, per, axis=0)[:n] + noise[:n])
    qg = jax.random.randint(jax.random.PRNGKey(2), (qn,), 0, true_c)
    q = l2_normalize(centers[qg] + 0.1 * jax.random.normal(
        jax.random.PRNGKey(3), (qn, d), jnp.float32))

    # ---- sharded exact tier ------------------------------------------------
    exact = jax.jit(lambda q, c: mips_topk(q, c, k, backend="chunked"))
    ev, ei = jax.block_until_ready(exact(q, c))
    us_exact = _timeit(lambda: exact(q, c), n=5, best_of=4)
    emit("retrieval_scale/exact_search", us_exact, f"q{qn}_n{n}_d{d}_k{k}")

    shard_stack = stack_shards(c, shards)
    shard_size = shard_stack.shape[1]
    local = jax.jit(lambda q, s: mips_topk(
        q, s, k, backend="chunked", index_offset=jnp.zeros((), jnp.int32),
        n_total=n))
    jax.block_until_ready(local(q, shard_stack[0]))
    us_shard = _timeit(lambda: local(q, shard_stack[0]), n=5, best_of=4)
    cand_v = jnp.tile(ev[None], (shards, 1, 1))
    cand_i = jnp.tile(ei[None], (shards, 1, 1))
    merge = jax.jit(lambda v, i: merge_topk(v, i, k))
    jax.block_until_ready(merge(cand_v, cand_i))
    us_merge = _timeit(lambda: merge(cand_v, cand_i), n=5, best_of=4)
    emit("retrieval_scale/shard_local_search", us_shard,
         f"rows={shard_size};shards={shards}")
    emit("retrieval_scale/shard_merge", us_merge,
         f"candidates={shards * k}_per_query")
    emit("retrieval_scale/sharded_speedup_modeled",
         us_exact / (us_shard + us_merge),
         f"exact_us={us_exact:.0f};shard_us={us_shard:.0f};"
         f"merge_us={us_merge:.0f};allgather_entries={shards * qn * k}")

    sv, si = jax.block_until_ready(
        jax.jit(lambda q, s: sharded_mips_topk(
            q, s, k, n_total=n, backend="chunked"))(q, shard_stack))
    bit = bool(jnp.array_equal(sv, ev)) and bool(jnp.array_equal(si, ei))
    emit("retrieval_scale/sharded_exact_match", float(bit),
         f"bitwise_scores_and_indices;shards={shards}")

    # ---- IVF approximate tier ----------------------------------------------
    ivf = IVFIndex.from_index(CorpusIndex(c), num_centroids=num_centroids,
                              nprobe=nprobe, seed=7)
    truth = set_rows = [set(np.asarray(ei)[i].tolist()) for i in range(qn)]

    def recall_at_k(idx_arr):
        got = np.asarray(idx_arr)
        return float(np.mean([len(set(got[i]) & truth[i]) / k
                              for i in range(qn)]))

    if nprobe > num_centroids:
        raise ValueError(
            f"nprobe={nprobe} exceeds num_centroids={num_centroids}; the "
            f"default-nprobe gate rows would have nothing to measure")
    us_default = rec_default = None
    for p in sorted(set(tuple(nprobe_curve) + (nprobe,))):
        if p > num_centroids:
            continue
        run = jax.jit(functools.partial(ivf.search, k=k, nprobe=p))
        _, pi = jax.block_until_ready(run(q))
        us_p = _timeit(lambda: run(q), n=5, best_of=4)
        rec = recall_at_k(pi)
        emit(f"retrieval_scale/ivf_search_nprobe{p}", us_p,
             f"recall_at{k}={rec:.3f};qps_vs_exact={us_exact / us_p:.2f}x;"
             f"scan_rows={p * ivf.list_len}")
        if p == nprobe:
            us_default, rec_default = us_p, rec
    emit("retrieval_scale/ivf_recall_at10_x1000", 1000.0 * rec_default,
         f"nprobe={nprobe};C={num_centroids};fill={ivf.fill:.2f}")
    emit("retrieval_scale/ivf_qps_ratio", us_exact / us_default,
         f"exact_us={us_exact:.0f};ivf_us={us_default:.0f};nprobe={nprobe}")
    cost = costmodel.ivf_cost(qn, n, d, k, num_centroids=num_centroids,
                              nprobe=nprobe, list_len=ivf.list_len)
    emit("retrieval_scale/ivf_cost_flops_ratio",
         cost.notes["flops_ratio_exact_over_ivf"],
         f"scan_rows={cost.notes['scan_rows']:.0f};"
         f"intensity={cost.notes['intensity']:.1f}")

    # ---- drift-gated streaming refresh -------------------------------------
    # two-group linear encoder: items 0..m-1 read only the first feature
    # block, the rest only the second — perturbing W's first block drifts
    # exactly the first quarter of the corpus
    d_in, m = 32, refresh_n // 4
    w = jax.random.normal(jax.random.PRNGKey(11), (d_in, d),
                          jnp.float32) * 0.3
    feats = jax.random.normal(jax.random.PRNGKey(12), (refresh_n, d_in),
                              jnp.float32)
    feats = feats.at[:m, d_in // 2:].set(0.0).at[m:, :d_in // 2].set(0.0)
    enc = lambda p, x: x @ p  # noqa: E731
    emb0 = jax.block_until_ready(l2_normalize(enc(w, feats)))
    w2 = w.at[:d_in // 2].add(0.15 * jax.random.normal(
        jax.random.PRNGKey(13), (d_in // 2, d), jnp.float32))
    new_emb, rstats = jax.jit(functools.partial(
        refresh_embeddings, enc, threshold=1e-3, block=refresh_block,
        probes_per_block=4))(w2, feats, emb0)
    frac_items = float(rstats["items_encoded"]) / refresh_n
    full = l2_normalize(enc(w2, feats))
    qr = l2_normalize(jax.random.normal(jax.random.PRNGKey(14), (qn, d),
                                        jnp.float32))
    _, ri = mips_topk(qr, new_emb, k, backend="chunked")
    _, fi = mips_topk(qr, full, k, backend="chunked")
    parity = float(np.mean([
        len(set(np.asarray(ri)[i]) & set(np.asarray(fi)[i])) / k
        for i in range(qn)]))
    emit("retrieval_scale/refresh_items_ratio_x1000", 1000.0 * frac_items,
         f"items_encoded={float(rstats['items_encoded']):.0f}_of_{refresh_n};"
         f"blocks={float(rstats['blocks_refreshed']):.0f};"
         f"probe_overhead_included=True")
    emit("retrieval_scale/refresh_recall_parity_x1000", 1000.0 * parity,
         f"top{k}_overlap_vs_full_rebuild;drifted_quarter=True")


def heterogeneity_sweep(rounds=25, cpr=16, clusters=2,
                        train_strategies=("label", "dirichlet"),
                        severities=(0.0, 0.9), train_severities=None):
    """Client-heterogeneity scenario suite (repro.data.partition) x
    cluster-aware aggregation (repro.cluster).

    Partition rows cut each registered strategy at each severity and
    report the cut time plus the label-dominance skew metric — the
    evidence that the normalized severity axis is real (dominance rises
    with severity for the skewing strategies, stays flat for controls).

    Probe rows train the same DCCO engine twice per (strategy, severity)
    cell — global single-model aggregation vs ``EngineConfig.num_clusters``
    cluster-aware slots — then evaluate BOTH on the same cluster-matched
    subsets: every client is assigned to a cluster with the trained
    centroids, each cluster's samples are probed under that cluster's
    params (clustered row) and under the global run's params (global
    row), and the rows report the sample-weighted mean accuracy
    (acc x 1000 in the us field, the mixed_precision convention). The
    compare.py gate holds clustered >= global at severity >= 0.8 — the
    regime where one averaged model straddles a mixture — with a
    no-regress floor on the clustered accuracy. Both probes are
    deterministic given the seeds, so the gate carries no machine noise.
    """
    from repro import cluster as cluster_lib
    from repro import objectives as objectives_lib
    from repro.data import partition as partition_lib

    imgs, labels = synthetic.synthetic_labeled_images(600, 5, image_size=16,
                                                      noise=1.0, seed=1)
    ncls = int(labels.max()) + 1
    cfg, de, params0, apply, embed = _setup()
    obj = objectives_lib.get_objective("dcco")

    for strategy in partition_lib.PARTITIONS:
        for sev in severities:
            spec = partition_lib.PartitionSpec(strategy, sev)
            t0 = time.perf_counter()
            idx, sizes = partition_lib.build_partition(
                spec, labels, num_clients=300, samples_per_client=2, seed=0)
            us = (time.perf_counter() - t0) * 1e6
            dom = partition_lib.label_dominance(labels, idx, sizes)
            emit(f"heterogeneity_sweep/partition/{strategy}/sev{sev:.1f}",
                 us, f"dominance={dom:.3f}")

    def subset_probe(z, sel):
        """Ridge probe on one cluster's samples: even rows train, odd
        rows test (class-interleaved by the partition's construction)."""
        zs, ys = z[sel], jnp.asarray(labels[sel])
        return float(eval_lib.ridge_linear_probe(
            zs[0::2], ys[0::2], zs[1::2], ys[1::2], ncls))

    for strategy in train_strategies:
        for sev in (severities if train_severities is None
                    else train_severities):
            ds = pipeline.FederatedDataset.build(
                {"images": imgs}, labels, num_clients=300,
                samples_per_client=2,
                partition=partition_lib.PartitionSpec(strategy, sev),
                seed=0)
            sampler = ds.make_round_sampler(cpr)
            ecfg = round_engine.EngineConfig(algorithm="dcco", lam=5.0,
                                             chunk_rounds=rounds)
            opt_g = opt_lib.adam(2e-3)
            eng_g = round_engine.RoundEngine(apply, opt_g, sampler, ecfg)
            t0 = time.perf_counter()
            pg, _, _ = eng_g.run(params0, opt_g.init(params0),
                                 jax.random.PRNGKey(7), rounds)
            us_g = (time.perf_counter() - t0) / rounds * 1e6
            opt_c = opt_lib.adam(2e-3)
            eng_c = round_engine.RoundEngine(
                apply, opt_c, sampler, ecfg._replace(num_clusters=clusters))
            t0 = time.perf_counter()
            pc, _, _ = eng_c.run(params0, opt_c.init(params0),
                                 jax.random.PRNGKey(7), rounds)
            us_c = (time.perf_counter() - t0) / rounds * 1e6
            cs = eng_c.cluster_state

            # assign EVERY client with the trained centroids (stats under
            # the clustered readout, identical views — assignment only)
            def client_stats(x):
                zf, zg = apply(pc, {"v1": x, "v2": x})
                return obj.stats_masked(zf, zg, jnp.ones(x.shape[0]))

            st_k = jax.vmap(client_stats)(
                jnp.asarray(imgs[ds.client_index]))
            ids = np.asarray(cluster_lib.assign_clusters(
                cluster_lib.flatten_stats(st_k), cs.centroids))
            z_g = embed(pg, jnp.asarray(imgs))
            acc_g = acc_c = wsum = 0.0
            for c in range(clusters):
                sel = np.unique(ds.client_index[ids == c].reshape(-1))
                if len(sel) < 2 * ncls:
                    continue                     # degenerate-probe cluster
                p_c = jax.tree.map(lambda x: x[c], cs.params_c)
                z_c = embed(p_c, jnp.asarray(imgs[sel]))
                w = float(len(sel))
                acc_c += w * subset_probe(z_c, np.arange(len(sel)))
                acc_g += w * subset_probe(z_g, sel)
                wsum += w
            acc_g, acc_c = acc_g / wsum, acc_c / wsum
            tag = f"heterogeneity_sweep/probe/{strategy}/sev{sev:.1f}"
            emit(f"{tag}/global_x1000", acc_g * 1000.0,
                 f"acc_x1000;round_us={us_g:.0f}")
            emit(f"{tag}/clustered_x1000", acc_c * 1000.0,
                 f"acc_x1000;d_acc={acc_c - acc_g:+.3f};"
                 f"clusters={clusters};round_us={us_c:.0f}")


BENCHES = {
    "table1": table1_cifar,
    "table2": table2_derm,
    "figure3": figure3_collapse,
    "dcco_round": dcco_round_bench,
    "round_engine": round_engine_bench,
    "async_stragglers": async_stragglers,
    "comm_sweep": comm_sweep,
    "server_opt_sweep": server_opt_sweep,
    "fused_step": fused_step_bench,
    "stats_kernel": stats_kernel_bench,
    "stale_stats": stale_stats_study,
    "dvicreg": dvicreg_bench,
    "objective_sweep": objective_sweep,
    "population_scale": population_scale,
    "retrieval_serving": retrieval_serving,
    "retrieval_scale": retrieval_scale,
    "mixed_precision": mixed_precision,
    "heterogeneity_sweep": heterogeneity_sweep,
    "comm_round": comm_round,
    "kernel_roofline": kernel_roofline,
    "roofline": roofline_bench,
}

# reduced sizes for the CI bench-smoke gate (BENCH_SMOKE=1): enough rounds
# for the engine-vs-loop speedup ratio to stabilize, small enough for a
# shared CPU runner
SMOKE_KW = {
    "round_engine": {"rounds": 40},
    # ticks-per-update ratios are exact functions of the latency stream,
    # so the smoke run may shrink wall time without moving the gate
    "async_stragglers": {"ticks": 24},
    "comm_sweep": {"rounds": 8},
    "server_opt_sweep": {"rounds": 8},
    "objective_sweep": {"rounds": 8},
    "stats_kernel": {"sizes": ((512, 256),)},
    "table1": {"rounds": 8},
    "table2": {"rounds": 8},
    # the 4096-client streaming smoke must stay: it is the acceptance
    # check that mega-cohorts actually fit on a shared CPU runner
    "population_scale": {"rounds": 2, "cohorts": (64, 256, 4096),
                         "chunk": 64, "materialize_max": 256},
    # the gated memory + roofline-fraction rows keep the full bench shape
    # (the Q=64 x N=4096 acceptance size); only the latency sweep shrinks
    "retrieval_serving": {"corpus_sizes": (1024, 4096), "serve_batches": 8},
    # the gated ratios (sharded modeled speedup, ivf recall/qps, refresh
    # fraction/parity) hold at the smaller smoke corpus; only wall time
    # shrinks
    "retrieval_scale": {"n": 4096, "num_centroids": 128,
                        "nprobe_curve": (1, 2, 4, 8), "refresh_n": 2048},
    # modeled rows are shape-exact at any round count; only the measured
    # parity runs shrink (parity is a tolerance check, not a ratio)
    "mixed_precision": {"rounds": 6},
    # the gated clustered-vs-global pair (label @ severity 0.9) must stay;
    # dropping the dirichlet and low-severity training cells keeps the
    # deterministic accuracy contract while fitting the CI runner
    "heterogeneity_sweep": {"rounds": 10, "train_strategies": ("label",),
                            "train_severities": (0.9,)},
    # comm_round / kernel_roofline time single jitted calls at the
    # acceptance shapes — already smoke-sized
}


def main(argv=None) -> None:
    names = list(sys.argv[1:] if argv is None else argv) or list(BENCHES)
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        raise SystemExit(f"unknown benchmarks {unknown}; "
                         f"available: {list(BENCHES)}")
    smoke = bool(os.environ.get("BENCH_SMOKE"))
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n](**(SMOKE_KW.get(n, {}) if smoke else {}))
    print(f"# {len(ROWS)} benchmark rows")
    out_path = os.environ.get("BENCH_JSON", "BENCH.json")
    with open(out_path, "w") as f:
        json.dump({"benchmarks": names, "rows": ROWS}, f, indent=1)
    print(f"# wrote {out_path}")


if __name__ == "__main__":
    main()
