"""Analytic per-(arch x shape x mesh) cost model — the roofline's primary
source.

WHY ANALYTIC: XLA's HloCostAnalysis visits each while-loop body ONCE, so any
scanned program (layer scan, microbatch scan, blockwise-attention scan)
under-reports FLOPs/bytes by the trip counts (verified empirically: a
scan(8x matmul) reports 1/8 the unrolled flops). We therefore derive the
roofline terms analytically from the configs — the same napkin math the
perf methodology requires — and use the compiled HLO for what it IS
reliable for: collective placement/shape (per-op, outside loops x trip
multipliers we know statically) and memory_analysis. tests/test_costmodel.py
validates the analytic flops against XLA cost_analysis on scan-free
configurations.

Conventions: "fwd unit" = one forward pass's matmul work = 2 * N_active *
tokens FLOPs (+ attention quadratic term). Baseline training policy
(dry-run): exact-DCCO microbatching (stats fwd + grad fwd) + layer-scan
remat + per-view checkpoint => 6 fwd units per step vs the un-rematted
ideal of 3 — the MODEL_FLOPS/HLO ratio surfaces exactly this.

UNITS — every quantity in this module is per device per step unless a
name says otherwise:

  *_flops         FLOPs (multiply-add counts x2, the 2ND convention)
  *_bytes         bytes moved through HBM (reads + writes)
  coll_bytes_dev  bytes on the slowest wire link (ring model)
  intensity_*     FLOPs / HBM byte (arithmetic intensity)
  roofline() t_*  seconds, = work / HardwareSpec peak (TPU v5e); the
                  returned step_s_lower_bound is the max of the three —
                  an ideal-overlap lower bound, never a prediction

Element sizes are the BF16/F32 constants below (bytes per element).
``train_cost(compute_bytes=...)`` selects the ENCODER compute dtype's
element size; the f32-only terms (optimizer state, gradient
reduce-scatter, Eq.-3 statistics all-reduce) are hardwired to F32 —
that asymmetry IS the mixed-precision numerics contract
(docs/performance.md) expressed in the cost model.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig
from repro.launch.inputs import INPUT_SHAPES, InputShape, arch_variant_for_shape
from repro.launch.mesh import HardwareSpec as HW

BF16 = 2
F32 = 4


# ---------------------------------------------------------------- params ---

def param_counts(cfg: ModelConfig, de_proj=(1024, 1024, 1024)) -> Dict[str, float]:
    """Analytic parameter counts: total, active (MoE top-k), per-block."""
    d = cfg.d_model
    h, kvh, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    per_block: Dict[str, float] = {}

    def attn_params():
        if cfg.use_mla:
            r, dr, dn, dv = (cfg.kv_lora_rank, cfg.qk_rope_head_dim,
                             cfg.qk_nope_head_dim, cfg.v_head_dim)
            return (d * h * (dn + dr) + d * (r + dr) + r * h * dn
                    + r * h * dv + h * dv * d)
        return d * h * dh + 2 * d * kvh * dh + h * dh * d

    def ffn_params(dff):
        return 3 * d * dff

    moe_total = moe_active = 0.0
    if cfg.moe is not None and cfg.moe.num_experts > 0:
        e, k_, dffe, sh = (cfg.moe.num_experts, cfg.moe.top_k, cfg.moe.d_ff,
                           cfg.moe.num_shared_experts)
        moe_total = e * 3 * d * dffe + sh * 3 * d * dffe + d * e
        moe_active = k_ * 3 * d * dffe + sh * 3 * d * dffe + d * e

    def block_params(kind, active=False):
        if kind == "attn":
            if cfg.moe is not None and cfg.moe.num_experts > 0:
                return attn_params() + (moe_active if active else moe_total)
            return attn_params() + ffn_params(cfg.d_ff or 4 * d)
        if kind == "mamba2":
            di = cfg.ssm.expand * d
            heads = di // cfg.ssm.head_dim
            n = cfg.ssm.state
            conv_dim = di + 2 * n
            return (d * (2 * di + 2 * n + heads)
                    + cfg.ssm.conv_width * conv_dim + di * d + di)
        if kind == "mlstm":
            di = int(d * cfg.xlstm.proj_factor_mlstm)
            di -= di % (cfg.num_heads * 2)
            return d * 2 * di + 3 * di * di + 2 * di * h + di * d
        if kind == "slstm":
            dff = int(d * cfg.xlstm.proj_factor_slstm)
            dh_s = d // h
            return 4 * d * d + 4 * h * dh_s * dh_s + d * 2 * dff + dff * d
        raise ValueError(kind)

    n_super = cfg.num_superblocks
    stack_total = n_super * sum(block_params(k) for k in cfg.block_pattern)
    stack_active = n_super * sum(block_params(k, active=True)
                                 for k in cfg.block_pattern)
    prologue = cfg.num_prologue * (attn_params() + ffn_params(
        cfg.moe.dense_d_ff if cfg.moe else cfg.d_ff))
    embed = cfg.vocab_size * d
    vis = (cfg.vis_dim * d + d * d) if cfg.modality == "vision_text" else 0
    proj = 0
    dims = (d,) + tuple(de_proj)
    for i in range(len(dims) - 1):
        proj += dims[i] * dims[i + 1] + dims[i + 1]
    return {
        "total": stack_total + prologue + embed + vis,
        "active": stack_active + prologue + embed + vis,
        "proj_head": proj,
        "embed": embed,
    }


# ----------------------------------------------------------- mixer flops ---

def _attn_quad_flops(cfg, batch, sq, skv):
    """QK^T + PV flops for one layer (full blocks — the blockwise scan does
    not skip fully-masked causal blocks; that's a §Perf item)."""
    h, dh = cfg.num_heads, cfg.resolved_head_dim
    if cfg.use_mla:
        dh = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    window = cfg.sliding_window
    eff_skv = min(skv, window) if window > 0 and sq == 1 else skv
    return 4.0 * batch * h * sq * eff_skv * dh


def _recurrent_extra_flops(cfg, kind, batch, s):
    """Intra-chunk quadratic terms for SSD / mLSTM (per layer)."""
    if kind == "mamba2" and cfg.ssm is None:
        return 0.0
    if kind in ("mlstm", "slstm") and cfg.xlstm is None:
        return 0.0
    if kind == "mamba2":
        di = cfg.ssm.expand * cfg.d_model
        heads = di // cfg.ssm.head_dim
        l = min(cfg.ssm.chunk, s)
        n = cfg.ssm.state
        # cb (l x l x n) + y_intra + state terms, per chunk
        return 2.0 * batch * s * l * (n + heads * cfg.ssm.head_dim) * 2
    if kind == "mlstm":
        di = int(cfg.d_model * cfg.xlstm.proj_factor_mlstm)
        di -= di % (cfg.num_heads * 2)
        dh = di // cfg.num_heads
        l = min(cfg.xlstm.chunk, s)
        return 4.0 * batch * cfg.num_heads * s * l * dh
    return 0.0


def _attn_layers(cfg):
    n_attn = cfg.num_superblocks * sum(1 for k in cfg.block_pattern if k == "attn")
    return n_attn + cfg.num_prologue


def _recurrent_layers(cfg, kind):
    return cfg.num_superblocks * sum(1 for k in cfg.block_pattern if k == kind)


# ------------------------------------------------------------ step costs ---

@dataclasses.dataclass
class Cost:
    """One program's analytic roofline terms (units: see module docstring).

    ``flops_dev``/``hbm_bytes_dev`` are per-device compute and HBM
    traffic; ``coll_bytes_dev`` is the wire bytes crossing the slowest
    link under a ring model; ``notes`` carries named sub-terms (same
    units) for reporting — they never feed the roofline directly.
    """
    flops_dev: float
    hbm_bytes_dev: float
    coll_bytes_dev: float        # ring-model wire bytes on the slowest link
    notes: Dict[str, float]

    def roofline(self, peak_flops: float = None):
        """Ideal-overlap time lower bounds in seconds at TPU v5e peaks.

        ``peak_flops`` selects the compute ceiling — default the bf16 MXU
        peak; pass ``HW.PEAK_FLOPS_F32`` when the modeled program runs its
        matmuls in f32 (the mixed-precision comparison in
        benchmarks/run.py `mixed_precision` does exactly this).
        """
        peak = HW.PEAK_FLOPS_BF16 if peak_flops is None else peak_flops
        t_c = self.flops_dev / peak
        t_m = self.hbm_bytes_dev / HW.HBM_BW
        t_x = self.coll_bytes_dev / HW.ICI_BW
        dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
                  key=lambda kv: kv[1])[0]
        return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
                "dominant": dom,
                "step_s_lower_bound": max(t_c, t_m, t_x)}


def _mesh_sizes(multi_pod: bool):
    return (2 if multi_pod else 1, 16, 16)   # (pod, data, model)


def _params_dev_bytes(cfg, counts, model_par=16, dtype_bytes=BF16):
    """Approx per-device param bytes: sharded fraction / model_par +
    replicated remainder. We treat attention+FFN+experts+embed as sharded
    (divisibility caveats ignored at this granularity), SSM/xLSTM mixers
    replicated per the baseline policy. ``dtype_bytes`` is the compute
    dtype's element size (the master f32 copy lives in opt_traffic)."""
    total = counts["total"] + counts["proj_head"]
    rec = sum(_recurrent_layers(cfg, k) for k in ("mamba2", "mlstm", "slstm"))
    rec_frac = 0.0
    if rec:
        rec_frac = min(0.9, rec / max(cfg.num_layers, 1))
    sharded = (total * (1 - rec_frac)) / model_par
    replicated = total * rec_frac
    return (sharded + replicated) * dtype_bytes


def train_cost(cfg: ModelConfig, shape: InputShape, *, multi_pod: bool,
               de_proj=(1024, 1024, 1024), num_microbatches: int = 16,
               fwd_units: float = 6.0, compute_bytes: int = BF16) -> Cost:
    """Baseline DCCO train step (two views, exact microbatching, remat,
    per-view checkpoint -> fwd_units = 6; see module docstring).

    ``compute_bytes`` is the encoder compute dtype's element size (BF16
    default, F32 for a full-precision encoder): it scales weight/
    activation/TP-collective/MoE-a2a traffic. Optimizer state, the grad
    reduce-scatter, and the Eq.-3 statistics all-reduce stay F32 in BOTH
    settings — the precision-critical accumulation path never narrows.
    """
    pod, dp, mp = _mesh_sizes(multi_pod)
    chips = pod * dp * mp
    counts = param_counts(cfg, de_proj)
    b_local = shape.global_batch / (pod * dp)      # sequences per device
    views = 2
    s = shape.seq_len

    # matmul flops per fwd unit (weights are model-sharded -> per-device
    # matmul flops = 2 * N_active/mp * tokens_local)
    tokens_local = b_local * s * views
    mm = 2.0 * (counts["active"] - counts["embed"]) / mp * tokens_local
    d_out = de_proj[-1]
    proj = 2.0 * counts["proj_head"] * tokens_local / s  # proj on pooled (per seq)
    stats = 2.0 * b_local * views * d_out * d_out  # cross-moment matmul
    attn = _attn_layers(cfg) * _attn_quad_flops(cfg, b_local * views, s, s) / mp
    rec = sum(_recurrent_layers(cfg, k) *
              _recurrent_extra_flops(cfg, k, b_local * views, s)
              for k in ("mamba2", "mlstm", "slstm"))  # replicated mixers
    flops = fwd_units * (mm + attn + rec) + 2 * (proj + stats)

    # HBM: weights re-read every microbatch x pass + activation traffic
    pbytes = _params_dev_bytes(cfg, counts, mp, compute_bytes)
    weight_traffic = fwd_units * num_microbatches * pbytes
    act_traffic = fwd_units * tokens_local * cfg.d_model * cfg.num_layers \
        * 8 * compute_bytes  # ~8 tensor touches per layer
    opt_traffic = 3 * (counts["total"] + counts["proj_head"]) * F32 / (chips / mp)
    hbm = weight_traffic + act_traffic + opt_traffic

    # collectives (wire bytes, ring model):
    n_total = counts["total"] + counts["proj_head"]
    zero_rs = 2.0 * n_total * F32 / chips * 2      # grad reduce-scatter (f32)
    zero_ag = n_total * compute_bytes / chips * 2  # param all-gather
    # per-layer TP all-reduces (attn-out + ffn-out) per pass, ring factor 2
    tp_ar = (2 * cfg.num_layers * fwd_units * b_local * views * s
             * cfg.d_model * compute_bytes) * 2
    stats_ar = 2 * num_microbatches * (d_out * d_out + 4 * d_out) * F32 * 2
    a2a = 0.0
    if cfg.moe is not None and cfg.moe.num_experts > 0:
        a2a = (2 * fwd_units * (cfg.num_layers - cfg.num_prologue)
               * b_local * views * s * cfg.moe.top_k * cfg.d_model
               * compute_bytes / mp)
    coll = zero_rs + zero_ag + tp_ar + stats_ar + a2a
    return Cost(flops, hbm, coll, {
        "mm_flops": fwd_units * mm, "attn_flops": fwd_units * attn,
        "weight_traffic": weight_traffic, "act_traffic": act_traffic,
        "zero_bytes": zero_rs + zero_ag, "tp_ar_bytes": tp_ar,
        "stats_ar_bytes": stats_ar, "a2a_bytes": a2a,
        "model_flops_6nd": 6.0 * counts["active"] * shape.global_batch * s
        * views / chips,
    })


def prefill_cost(cfg: ModelConfig, shape: InputShape, *, multi_pod: bool) -> Cost:
    pod, dp, mp = _mesh_sizes(multi_pod)
    chips = pod * dp * mp
    counts = param_counts(cfg)
    b_local = shape.global_batch / (pod * dp)
    s = shape.seq_len
    mm = 2.0 * (counts["active"] - counts["embed"]) / mp * b_local * s
    lm_head = 2.0 * b_local * cfg.d_model * cfg.vocab_size / mp
    attn = _attn_layers(cfg) * _attn_quad_flops(cfg, b_local, s, s) / mp
    rec = sum(_recurrent_layers(cfg, k) * _recurrent_extra_flops(cfg, k, b_local, s)
              for k in ("mamba2", "mlstm", "slstm"))
    flops = mm + attn + rec + lm_head
    pbytes = _params_dev_bytes(cfg, counts, mp)
    act = b_local * s * cfg.d_model * cfg.num_layers * 8 * BF16
    cache_w = _cache_bytes(cfg, shape, dp * pod, mp)
    hbm = pbytes + act + cache_w
    tp_ar = 2 * cfg.num_layers * b_local * s * cfg.d_model * BF16 * 2
    a2a = 0.0
    if cfg.moe is not None and cfg.moe.num_experts > 0:
        a2a = (2 * (cfg.num_layers - cfg.num_prologue) * b_local * s
               * cfg.moe.top_k * cfg.d_model * BF16 / mp)
    return Cost(flops, hbm, tp_ar + a2a, {
        "cache_write_bytes": cache_w,
        "model_flops_6nd": 2.0 * counts["active"] * shape.global_batch * s / chips})


def _cache_bytes(cfg, shape, dp, mp):
    """Per-device decode-state bytes."""
    s = shape.seq_len
    b = shape.global_batch
    w = min(s, cfg.sliding_window) if cfg.sliding_window > 0 else s
    per_tok = 0.0
    n_attn = _attn_layers(cfg)
    if cfg.use_mla:
        per_tok = n_attn * (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * BF16
    elif n_attn:
        per_tok = n_attn * 2 * cfg.num_kv_heads * cfg.resolved_head_dim * BF16
    kv = b * w * per_tok
    state = 0.0
    if cfg.ssm is not None:
        di = cfg.ssm.expand * cfg.d_model
        heads = di // cfg.ssm.head_dim
        n_m = _recurrent_layers(cfg, "mamba2")
        state += n_m * b * (heads * cfg.ssm.state * cfg.ssm.head_dim * F32
                            + (cfg.ssm.conv_width - 1) * (di + 2 * cfg.ssm.state) * BF16)
    if cfg.xlstm is not None:
        di = int(cfg.d_model * cfg.xlstm.proj_factor_mlstm)
        di -= di % (cfg.num_heads * 2)
        dh = di // cfg.num_heads
        state += _recurrent_layers(cfg, "mlstm") * b * cfg.num_heads \
            * (dh * dh + dh + 1) * F32
        state += _recurrent_layers(cfg, "slstm") * b * 4 * cfg.d_model * F32
    shard = dp * mp if b == 1 or b >= dp else dp  # seq and/or batch sharding
    return (kv + state) / shard


def decode_cost(cfg: ModelConfig, shape: InputShape, *, multi_pod: bool) -> Cost:
    pod, dp, mp = _mesh_sizes(multi_pod)
    chips = pod * dp * mp
    counts = param_counts(cfg)
    b = shape.global_batch
    b_local = max(b / (pod * dp), b / chips if b == 1 else 1)
    if b == 1:
        b_local = 1.0  # replicated single sequence
    mm = 2.0 * (counts["active"] - counts["embed"]) / mp * b_local
    lm_head = 2.0 * b_local * cfg.d_model * cfg.vocab_size / mp
    s_ctx = shape.seq_len
    attn = _attn_layers(cfg) * _attn_quad_flops(cfg, b_local, 1, s_ctx) / \
        (mp if b > 1 else dp * mp)
    flops = mm + attn + lm_head
    pbytes = _params_dev_bytes(cfg, counts, mp)
    cache = _cache_bytes(cfg, shape, dp * pod, mp)
    hbm = pbytes + 2 * cache + b_local * cfg.d_model * cfg.num_layers * 8 * BF16
    tp_ar = 2 * cfg.num_layers * b_local * cfg.d_model * BF16 * 2
    a2a = 0.0
    if cfg.moe is not None and cfg.moe.num_experts > 0:
        a2a = (2 * (cfg.num_layers - cfg.num_prologue) * b_local
               * cfg.moe.top_k * cfg.d_model * BF16 / mp)
    return Cost(flops, hbm, tp_ar + a2a, {
        "cache_bytes": cache, "params_bytes": pbytes,
        "model_flops_6nd": 2.0 * counts["active"] * b / chips})


def mips_cost(qn: int, n: int, d: int, k: int, *,
              store_bytes: int = F32) -> Cost:
    """Analytic cost of fused MIPS top-k serving (kernels/mips_topk.py):
    the (Q, d) x (d, N) score matmul (2*Q*N*d FLOPs) plus the running
    top-k's k select rounds over every score tile (~Q*N*k compare/select
    ops). HBM traffic is the FUSED path's: the corpus is read once
    (``store_bytes`` per element — 2 for a bf16 index), queries once, and
    only the (Q, k) results are written; the naive path's extra
    write+read round-trip of the (Q, N) score matrix is recorded in
    ``notes["naive_hbm_bytes"]``, which is what the fused kernel's
    memory win is measured against."""
    flops = 2.0 * qn * n * d + 1.0 * qn * n * k
    out_bytes = qn * k * (F32 + 4)               # values f32 + indices i32
    fused = n * d * store_bytes + qn * d * F32 + out_bytes
    score = 1.0 * qn * n * F32
    return Cost(flops, fused, 0.0, {
        "naive_hbm_bytes": fused + 2.0 * score,  # write + re-read (Q, N)
        "score_matrix_bytes": score,
        "intensity_fused": flops / fused,
        "intensity_naive": flops / (fused + 2.0 * score),
    })


def ivf_cost(qn: int, n: int, d: int, k: int, *, num_centroids: int,
             nprobe: int, list_len: int, store_bytes: int = F32) -> Cost:
    """Analytic cost of IVF-pruned MIPS serving (retrieval/ivf.py): the
    coarse (Q, d) x (d, C) centroid sweep + its top-nprobe, then ``nprobe``
    probed lists of ``list_len`` padded rows each streamed through the
    running top-k (same per-row work as the fused exact kernel). HBM: the
    centroids and only the probed lists' rows are read — the pruning win
    over exact search is ``notes["exact_flops"] / flops_dev`` ≈
    N / (C + nprobe * L). ``notes["scan_rows"]`` is the padded row count
    actually scored per query; list padding inflates it above the ideal
    nprobe * N / C."""
    scan_rows = 1.0 * nprobe * list_len
    coarse = 2.0 * qn * num_centroids * d + 1.0 * qn * num_centroids * nprobe
    flops = coarse + 2.0 * qn * scan_rows * d + 1.0 * qn * scan_rows * k
    out_bytes = qn * k * (F32 + 4)
    hbm = (num_centroids * d * F32 + qn * scan_rows * (d * store_bytes + 4)
           + qn * d * F32 + out_bytes)
    exact = mips_cost(qn, n, d, k, store_bytes=store_bytes)
    return Cost(flops, hbm, 0.0, {
        "scan_rows": scan_rows,
        "exact_flops": exact.flops_dev,
        "flops_ratio_exact_over_ivf": exact.flops_dev / flops,
        "intensity": flops / hbm,
    })


def cco_stats_cost(n: int, d: int, *, second_moments: bool = False,
                   in_bytes: int = F32) -> Cost:
    """Analytic cost of the one-pass encoding-statistics kernel
    (kernels/cco_stats.py; oracle kernels/ref.cco_stats_ref).

    zf, zg: (N, d). FLOPs: the (d, d) cross moment is a 2*N*d*d matmul
    (x3 with ``second_moments``: cov_f and cov_g too) plus ~6*N*d
    elementwise/reduce work for the means and squares. HBM (fused): both
    inputs read ONCE (``in_bytes`` per element — 2 when the encoder runs
    bf16) and only the O(d^2) statistics written; the naive multi-pass
    path re-reads the inputs once per statistic, recorded in
    ``notes["naive_hbm_bytes"]``.
    """
    n_mats = 3 if second_moments else 1
    flops = n_mats * 2.0 * n * d * d + 6.0 * n * d
    out = (4 * d + n_mats * d * d) * F32
    fused = 2.0 * n * d * in_bytes + out
    passes = 4 + n_mats                          # mean/sq per view + mats
    return Cost(flops, fused, 0.0, {
        "naive_hbm_bytes": passes * n * d * in_bytes + out,
        "intensity_fused": flops / fused,
    })


def segment_sum_cost(k: int, d: int, e: int) -> Cost:
    """Analytic cost of the weighted segment-sum fold
    (kernels/segment_sum.py; oracle kernels/ref.segment_sum_ref).

    rows: (K, d) per-client stat rows scattered into E edge aggregates.
    FLOPs: one weight multiply + one accumulate per element = 2*K*d. HBM:
    rows + f32 weights + i32 segment ids read once, (E, d) aggregates
    written — a pure streaming pass (intensity < 1 FLOP/byte, memory-bound
    by construction at any size).
    """
    flops = 2.0 * k * d
    hbm = k * d * F32 + k * (F32 + 4) + e * d * F32
    return Cost(flops, hbm, 0.0, {"intensity_fused": flops / hbm})


def quantize_cost(k: int, n: int, bits: int = 8) -> Cost:
    """Analytic cost of the fused quantize->dequantize wire pass
    (kernels/quantize.py; formula repro.comm.quantize._qdq_formula).

    flat, u: (K, n) — K clients x n payload elements — plus per-client
    scales. ~6 elementwise ops per element (divide, add-uniform, floor,
    two-sided clip, dequant multiply). HBM (fused): payload + uniforms
    read once, dequantized payload written once = 3 passes; the unfused
    jnp path materializes the scaled/rounded/clipped intermediates, an
    extra round-trip per op recorded in ``notes["naive_hbm_bytes"]``.
    ``bits`` sets the wire size in ``notes["wire_bytes"]`` (what ships,
    packed codes + one f32 scale per client row) — on-chip all arithmetic
    is f32 regardless.
    """
    flops = 6.0 * k * n
    fused = 3.0 * k * n * F32 + 2 * k * F32
    return Cost(flops, fused, 0.0, {
        "naive_hbm_bytes": 9.0 * k * n * F32,    # +3 intermediate trips
        "intensity_fused": flops / fused,
        "wire_bytes": k * (n * bits / 8.0 + 4.0),
    })


def comm_round_cost(payload_elems: int, bits: int = 32,
                    uplink_bw: float = None) -> Dict[str, float]:
    """Federated uplink model for ONE client's round payload.

    ``payload_elems`` f32 elements quantized to ``bits`` (32 = dense) ship
    over a ``uplink_bw``-bytes/s client connection (default
    HardwareSpec.FED_UPLINK_BW, a 20 Mbit/s residential uplink — the
    paper's clients are phones, not datacenter hosts). Clients upload in
    parallel, so the round's wire time is one client's payload time.
    Returns wire_bytes and wire_s. The quantized path also pays the
    encode/decode compute — benchmarks/run.py `comm_round` measures that
    part and adds it to this wire model for the gated total.
    """
    bw = HW.FED_UPLINK_BW if uplink_bw is None else uplink_bw
    wire = payload_elems * bits / 8.0 + (4.0 if bits < 32 else 0.0)
    return {"wire_bytes": wire, "wire_s": wire / bw}


def shape_cost(cfg: ModelConfig, shape_name: str, *, multi_pod: bool,
               de_proj=(1024, 1024, 1024)) -> Cost:
    shape = INPUT_SHAPES[shape_name]
    cfg = arch_variant_for_shape(cfg, shape)
    if shape.kind == "train":
        return train_cost(cfg, shape, multi_pod=multi_pod, de_proj=de_proj)
    if shape.kind == "prefill":
        return prefill_cost(cfg, shape, multi_pod=multi_pod)
    return decode_cost(cfg, shape, multi_pod=multi_pod)
