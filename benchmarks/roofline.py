"""Roofline analysis (deliverable g): combines the analytic cost model with
the compiled dry-run's HLO-derived records.

For each (arch x shape) on the single-pod mesh it reports:
  * the three terms (compute / memory / collective) in seconds, analytic
  * the dominant bottleneck
  * MODEL_FLOPS (6ND train / 2ND inference, active params) and the
    usefulness ratio MODEL_FLOPS / analytic FLOPs (remat+microbatch waste)
  * HLO cross-checks: raw cost_analysis numbers (loop bodies counted once —
    see costmodel.py docstring) and the HLO collective inventory
  * one-line "what moves the dominant term" advice

UNITS: all *_s columns are SECONDS (analytic lower bounds at TPU v5e
peaks, ideal overlap — never wall-clock predictions); *_bytes are HBM
bytes; intensity_* is FLOPs/byte; fused_vs_naive_bound is a unitless
bound-time ratio. The CALIBRATED fraction-of-roofline numbers (percent of
the machine-under-test's measured peaks actually achieved) do not live
here — they are measured in benchmarks/run.py (`retrieval_serving`,
`kernel_roofline`) against matmul/copy-calibrated peaks of the machine
running the bench, and gated in compare.py. This module is the analytic
(TPU-target) half of that story; see docs/performance.md.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--json out.json]
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks import costmodel
from repro.configs.base import ARCH_IDS, get_config, get_dual_encoder_config
from repro.launch.inputs import INPUT_SHAPES

HERE = os.path.dirname(__file__)
DRYRUN_JSON = os.path.join(HERE, "dryrun_results.json")

ADVICE = {
    ("train", "memory"): "cut weight re-reads: fewer microbatches / larger "
                         "per-device batch, or drop the per-view checkpoint",
    ("train", "compute"): "reduce fwd units: selective remat (save attn out), "
                          "skip fully-masked causal blocks in blockwise attn",
    ("train", "collective"): "overlap grad reduce-scatter with bwd; fuse "
                             "per-layer TP all-reduces; shrink stats payload",
    ("prefill", "collective"): "batch TP all-reduces across layers / overlap "
                               "with compute; sequence-parallel norms",
    ("prefill", "memory"): "fuse cache writes with attention epilogue",
    ("prefill", "compute"): "skip fully-masked causal kv blocks (2x)",
    ("decode", "memory"): "cache quantization (int8) or MLA-style latent "
                          "cache; batch more requests per chip",
    ("decode", "collective"): "fuse the 2 per-layer TP all-reduces; "
                              "collective-permute ring for seq-sharded cache",
    ("decode", "compute"): "weight-absorbed MLA / speculative decoding",
}


def build_table(dryrun_path: str = DRYRUN_JSON, tag: str = "baseline",
                multi_pod: bool = False):
    hlo = {}
    if os.path.exists(dryrun_path):
        with open(dryrun_path) as f:
            hlo = json.load(f)
    rows = []
    for arch in ARCH_IDS:
        if arch == "resnet14-cifar":
            continue
        de = get_dual_encoder_config(arch)
        for shape_name, shape in INPUT_SHAPES.items():
            cfg = get_config(arch)
            cost = costmodel.shape_cost(cfg, shape_name, multi_pod=multi_pod,
                                        de_proj=tuple(de.proj_dims))
            ro = cost.roofline()
            model_flops = cost.notes.get("model_flops_6nd", 0.0)
            key = f"{tag}/{arch}/{shape_name}/{'multi' if multi_pod else 'single'}"
            rec = hlo.get(key, {})
            rows.append({
                "arch": arch, "shape": shape_name,
                "compute_s": ro["compute_s"], "memory_s": ro["memory_s"],
                "collective_s": ro["collective_s"], "dominant": ro["dominant"],
                "step_lower_bound_s": ro["step_s_lower_bound"],
                "model_flops_dev": model_flops,
                "useful_ratio": (model_flops / cost.flops_dev
                                 if cost.flops_dev else 0.0),
                "advice": ADVICE.get((shape.kind, ro["dominant"]), ""),
                "hlo_flops_dev_loopbody": rec.get("flops_per_device"),
                "hlo_bytes_dev_loopbody": rec.get("bytes_per_device"),
                "hlo_coll_wire_bytes": rec.get("collectives", {}).get("wire_bytes"),
                "hlo_coll_by_op": rec.get("collectives", {}).get("bytes_by_op"),
                "hlo_mem": rec.get("memory"),
                "compile_s": rec.get("compile_s"),
                "notes": cost.notes,
            })
    return rows


# retrieval-serving shapes: online query batch x corpus size (paper Sec. 1's
# deployed dual encoder answering nearest-neighbour queries)
MIPS_SHAPES = (
    (128, 1_000_000, 768, 10),
    (128, 10_000_000, 768, 10),
    (1024, 1_000_000, 768, 100),
)


def build_mips_table(shapes=MIPS_SHAPES):
    """Analytic roofline rows for the fused MIPS top-k kernel
    (costmodel.mips_cost): fused-path bound, dominant term, and the
    bound-time ratio vs the naive materialize-then-top-k program — the
    kernel's analytic headroom on the production part. At serving corpus
    sizes the naive path's (Q, N) round-trip dominates its HBM traffic,
    so the fused win is pure memory-boundedness relief."""
    rows = []
    for qn, n, d, k in shapes:
        cost = costmodel.mips_cost(qn, n, d, k)
        ro = cost.roofline()
        naive_ro = costmodel.Cost(cost.flops_dev,
                                  cost.notes["naive_hbm_bytes"], 0.0,
                                  {}).roofline()
        rows.append({
            "arch": "mips_topk", "shape": f"q{qn}_n{n}_d{d}_k{k}",
            "compute_s": ro["compute_s"], "memory_s": ro["memory_s"],
            "collective_s": 0.0, "dominant": ro["dominant"],
            "step_lower_bound_s": ro["step_s_lower_bound"],
            "naive_lower_bound_s": naive_ro["step_s_lower_bound"],
            "fused_vs_naive_bound":
                naive_ro["step_s_lower_bound"] / ro["step_s_lower_bound"],
            "intensity_fused": cost.notes["intensity_fused"],
            "notes": cost.notes,
        })
    return rows


# federated-kernel shapes: cohort-scale statistics / folds / wire payloads
# (K clients, d encoding dims, E edges, n payload elements)
KERNEL_SHAPES = {
    "cco_stats": ((4096, 512), (65536, 1024)),           # (N rows, d)
    "segment_sum": ((4096, 4160, 64), (65536, 4160, 256)),  # (K, d, E)
    "quantize": ((256, 55_000, 8), (4096, 55_000, 8)),   # (K, n, bits)
}


def build_kernel_table(shapes=None):
    """Analytic roofline rows for the remaining Pallas kernels —
    `cco_stats`, `segment_sum`, `quantize` (costmodel.*_cost) — the
    analytic companion to the measured fraction-of-roofline rows the
    `kernel_roofline` bench emits. Every one of these kernels is a
    streaming pass (intensity well below the TPU ridge point of ~240
    FLOPs/byte), so 'memory' dominance below is the expected verdict;
    the fused_vs_naive_bound column is the bound-time win of fusing away
    the naive path's intermediate HBM round-trips."""
    shapes = KERNEL_SHAPES if shapes is None else shapes
    rows = []
    for name, shape_list in shapes.items():
        for shape in shape_list:
            if name == "cco_stats":
                n, d = shape
                cost = costmodel.cco_stats_cost(n, d)
                label = f"n{n}_d{d}"
            elif name == "segment_sum":
                k, d, e = shape
                cost = costmodel.segment_sum_cost(k, d, e)
                label = f"k{k}_d{d}_e{e}"
            else:
                k, n, bits = shape
                cost = costmodel.quantize_cost(k, n, bits)
                label = f"k{k}_n{n}_b{bits}"
            ro = cost.roofline()
            naive_ro = costmodel.Cost(cost.flops_dev,
                                      cost.notes["naive_hbm_bytes"], 0.0,
                                      {}).roofline()
            rows.append({
                "arch": name, "shape": label,
                "compute_s": ro["compute_s"], "memory_s": ro["memory_s"],
                "collective_s": 0.0, "dominant": ro["dominant"],
                "step_lower_bound_s": ro["step_s_lower_bound"],
                "naive_lower_bound_s": naive_ro["step_s_lower_bound"],
                "fused_vs_naive_bound":
                    naive_ro["step_s_lower_bound"] / ro["step_s_lower_bound"],
                "intensity_fused": cost.notes["intensity_fused"],
                "notes": cost.notes,
            })
    return rows


def render_markdown(rows):
    """Pipe-table rendering of ``build_table`` rows (seconds / ratios —
    see the module docstring for units)."""
    out = ["| arch | shape | compute_s | memory_s | collective_s | dominant | "
           "6ND/flops | bound step_s |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['step_lower_bound_s']:.4f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=os.path.join(HERE, "roofline_table.json"))
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    rows = build_table(multi_pod=args.multi_pod)
    with open(args.json, "w") as f:
        json.dump(rows, f, indent=1)
    print(render_markdown(rows))
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print("\ndominant-term histogram:", doms)


if __name__ == "__main__":
    main()
