"""Docs-vs-baseline drift lint (CI).

docs/performance.md documents the calibrated fraction-of-roofline rows by
their literal `benchmarks/baseline.json` row names — that table is how a
reader finds which kernels are gated. Names are easy to let rot when a
bench is renamed or a kernel row is added, so CI holds the two sources
to each other:

  * every calibrated fraction row in baseline.json
    (`kernel_roofline/*_fraction_pct` plus the PR-7
    `retrieval_serving/roofline_fraction_pct`) must appear verbatim in
    docs/performance.md;
  * every such row name mentioned in docs/performance.md must exist in
    baseline.json (no stale doc rows).

Usage: python benchmarks/docs_lint.py  (exit 0 clean, 1 on drift)
"""
from __future__ import annotations

import json
import os
import re
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
BASELINE = os.path.join(HERE, "baseline.json")
PERF_DOC = os.path.join(HERE, "..", "docs", "performance.md")

# the calibrated-fraction row families the doc's table must track
FRACTION_ROW = re.compile(
    r"\b(?:kernel_roofline/[a-z0-9_]+|retrieval_serving/"
    r"roofline_fraction_pct)\b")


def fraction_rows_in_baseline(path: str = BASELINE) -> set:
    with open(path) as f:
        rows = json.load(f)["rows"]
    return {r["name"] for r in rows
            if r["name"].startswith("kernel_roofline/")
            or r["name"] == "retrieval_serving/roofline_fraction_pct"}


def fraction_rows_in_doc(path: str = PERF_DOC) -> set:
    with open(path) as f:
        return set(FRACTION_ROW.findall(f.read()))


def main() -> int:
    in_baseline = fraction_rows_in_baseline()
    in_doc = fraction_rows_in_doc()
    missing = sorted(in_baseline - in_doc)
    stale = sorted(in_doc - in_baseline)
    if missing:
        print("docs/performance.md is missing gated roofline rows present "
              "in benchmarks/baseline.json:")
        for name in missing:
            print(f"  {name}")
    if stale:
        print("docs/performance.md mentions roofline rows that do not "
              "exist in benchmarks/baseline.json:")
        for name in stale:
            print(f"  {name}")
    if missing or stale:
        print("fix: update the calibrated-row table in docs/performance.md "
              "(and/or regenerate the baseline — see that page's "
              "'Regenerating the baseline' section)")
        return 1
    print(f"docs_lint: OK — {len(in_baseline)} calibrated roofline rows "
          "in sync")
    return 0


if __name__ == "__main__":
    sys.exit(main())
