"""Paper Table-1 protocol end-to-end (miniaturized CIFAR-100 analogue):
compare DCCO vs FedAvg variants vs centralized CCO vs supervised-from-scratch
across decentralized splits (clients x samples/client, IID vs non-IID).

This is the end-to-end training driver example: a few hundred federated
rounds of a (reduced) ResNet dual encoder per method and split, each driven
by the scan-compiled round engine (repro.core.round_engine) — one XLA
program per experiment instead of one dispatch per round.

Run: PYTHONPATH=src python examples/federated_cifar.py [--rounds 60]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import DualEncoderConfig, get_config
from repro.core import eval as eval_lib, round_engine
from repro.data import pipeline, synthetic
from repro.models import dual_encoder, resnet
from repro.optim import optimizers as opt_lib, schedules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--dataset-size", type=int, default=600)
    ap.add_argument("--classes", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config("resnet14-cifar", smoke=True)
    de = DualEncoderConfig(proj_dims=(64, 64), lambda_cco=5.0)
    key = jax.random.PRNGKey(0)
    params0 = dual_encoder.init_dual_encoder(key, cfg, de)
    imgs, labels = synthetic.synthetic_labeled_images(
        args.dataset_size, args.classes, image_size=cfg.image_size,
        noise=0.5, seed=1)

    def apply(p, batch):
        zf, _ = dual_encoder.encode(cfg, de, p, {"images": batch["v1"]})
        zg, _ = dual_encoder.encode(cfg, de, p, {"images": batch["v2"]})
        return zf, zg

    def probe(p):
        z = resnet.resnet_forward(cfg, p["tower"], jnp.asarray(imgs))
        cut = int(len(labels) * 0.7)
        return float(eval_lib.ridge_linear_probe(
            z[:cut], jnp.asarray(labels[:cut]), z[cut:],
            jnp.asarray(labels[cut:]), args.classes))

    # Table-1 splits: (name, alpha, samples/client, clients/round)
    splits = [("non-IID s=1", 0.0, 1, 32), ("non-IID s=4", 0.0, 4, 8),
              ("IID s=4", 1e9, 4, 8)]
    methods = ("dcco", "cco_fedavg", "contrastive_fedavg", "centralized")
    algo = {"dcco": "dcco", "cco_fedavg": "fedavg_cco",
            "contrastive_fedavg": "fedavg_contrastive",
            "centralized": "centralized"}

    print(f"{'split':14s} " + " ".join(f"{m:>20s}" for m in methods))
    for split_name, alpha, spc, cpr in splits:
        ds = pipeline.FederatedDataset.build(
            {"images": imgs}, labels,
            num_clients=min(256, args.dataset_size // spc),
            samples_per_client=spc, alpha=alpha, seed=0)
        sampler = ds.make_round_sampler(cpr)
        row = []
        for method in methods:
            if method == "cco_fedavg" and spc < 2:
                row.append("FAILED(n<2)")
                continue
            opt = opt_lib.adam(schedules.cosine_decay(2e-3, args.rounds))
            ecfg = round_engine.EngineConfig(
                algorithm=algo[method], lam=5.0,
                client_lr=0.5 if method.endswith("fedavg") else 1.0,
                chunk_rounds=min(args.rounds, 30))
            eng = round_engine.RoundEngine(apply, opt, sampler, ecfg)
            p, _, _ = eng.run(params0, opt.init(params0),
                              jax.random.PRNGKey(1000), args.rounds)
            row.append(f"{probe(p):.3f}")
        print(f"{split_name:14s} " + " ".join(f"{v:>20s}" for v in row))
    print(f"{'supervised':14s} {'(limited labels below)':>20s}")
    print(f"random-init probe: {probe(params0):.3f}")


if __name__ == "__main__":
    main()
