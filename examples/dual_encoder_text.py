"""Federated DCCO pretraining of a *transformer* dual encoder on token
sequences — the same protocol as the paper but with an assigned LLM backbone
(tinyllama family, reduced) and token-level two-view augmentations.

Demonstrates: token augmentations, the fused pod-style train step (one jit'd
step == one federated round), and the exact-microbatching path.

Run: PYTHONPATH=src python examples/dual_encoder_text.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import DualEncoderConfig, TrainConfig, get_config
from repro.core import eval as eval_lib
from repro.data import pipeline, synthetic
from repro.launch import steps as steps_lib
from repro.models import dual_encoder, transformer
from repro.optim import optimizers as opt_lib

ARCH = "tinyllama-1.1b"
SEQ, CPR, SPC = 32, 16, 1   # 16 single-sample clients per round (paper's
                            # hardest setting — impossible for FedAvg+CCO)

cfg = get_config(ARCH, smoke=True)
de = DualEncoderConfig(proj_dims=(64, 64), lambda_cco=5.0)
key = jax.random.PRNGKey(0)
params = dual_encoder.init_dual_encoder(key, cfg, de)

toks, labels = synthetic.synthetic_labeled_tokens(
    400, 4, SEQ, vocab=cfg.vocab_size, seed=0)
ds = pipeline.FederatedDataset.build(
    {"tokens": toks}, labels, num_clients=400, samples_per_client=SPC,
    alpha=0.0, seed=0, vocab=cfg.vocab_size)

tcfg = TrainConfig(seq_len=SEQ, global_batch=CPR * SPC, samples_per_client=SPC,
                   dcco_impl="fused")
opt = opt_lib.adam(2e-3)
# exact DCCO microbatching (stats pass + grad pass) — 2 microbatches
step = jax.jit(steps_lib.make_dcco_train_step(cfg, de, tcfg, opt,
                                              num_microbatches=2))
state = opt.init(params)


def probe(p):
    h = transformer.forward(cfg, p["tower"], jnp.asarray(toks))
    z = h.astype(jnp.float32).mean(axis=1)
    cut = 300
    return float(eval_lib.ridge_linear_probe(
        z[:cut], jnp.asarray(labels[:cut]), z[cut:],
        jnp.asarray(labels[cut:]), 4))


print(f"random-init probe: {probe(params):.3f}")
for r in range(40):
    flat, _ = ds.flat_round_batch(jax.random.PRNGKey(100 + r), CPR)
    batch = {"view1": {"tokens": flat["v1"]}, "view2": {"tokens": flat["v2"]}}
    params, state, m = step(params, state, batch)
    if (r + 1) % 10 == 0:
        print(f"round {r + 1:3d}  loss={float(m['loss']):8.3f}  "
              f"enc_std={float(m['encoding_std']):.3f}")
print(f"post-pretraining probe: {probe(params):.3f}")
