"""The StatsObjective protocol end-to-end: the same two-phase federated
round (paper Fig. 2) training three different statistics-based losses —
D-CCO (the paper), D-VICReg (the Sec.-6 future-work extension), and
D-WMSE (whitening-style decorrelation) — on the same non-IID cohort
stream, through the scan-compiled engine and an int8 quantized uplink.

Because the protocol only moves *statistics*, switching the objective is
one config field: the engine bodies, the comm channel, and the wire-bytes
accounting are all parametric in the objective's stats dict (D-VICReg /
D-WMSE ship 7 statistics per client where D-CCO ships 5 — visible in the
per-round payload column).

Run: PYTHONPATH=src python examples/federated_vicreg.py [--rounds 40]
(CI smoke: --rounds 3 --dataset-size 120)
"""
import argparse

import jax
import jax.numpy as jnp

from repro import comm, objectives as objectives_lib
from repro.configs.base import DualEncoderConfig, get_config
from repro.core import eval as eval_lib, round_engine
from repro.data import pipeline, synthetic
from repro.models import dual_encoder, resnet
from repro.optim import optimizers as opt_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--dataset-size", type=int, default=600)
    ap.add_argument("--classes", type=int, default=5)
    ap.add_argument("--clients-per-round", type=int, default=16)
    ap.add_argument("--channel", default="int8",
                    choices=["none", "dense", "int8"],
                    help="client->server wire for both protocol phases")
    args = ap.parse_args()

    cfg = get_config("resnet14-cifar", smoke=True)
    de = DualEncoderConfig(proj_dims=(64, 64), lambda_cco=5.0)
    key = jax.random.PRNGKey(0)
    params0 = dual_encoder.init_dual_encoder(key, cfg, de)
    imgs, labels = synthetic.synthetic_labeled_images(
        args.dataset_size, args.classes, image_size=cfg.image_size,
        noise=0.5, seed=1)

    def apply(p, batch):
        zf, _ = dual_encoder.encode(cfg, de, p, {"images": batch["v1"]})
        zg, _ = dual_encoder.encode(cfg, de, p, {"images": batch["v2"]})
        return zf, zg

    def probe(p):
        z = resnet.resnet_forward(cfg, p["tower"], jnp.asarray(imgs))
        cut = int(len(labels) * 0.7)
        return float(eval_lib.ridge_linear_probe(
            z[:cut], jnp.asarray(labels[:cut]), z[cut:],
            jnp.asarray(labels[cut:]), args.classes))

    # single-class 2-sample clients: the paper's hard non-IID setting
    ds = pipeline.FederatedDataset.build(
        {"images": imgs}, labels,
        num_clients=max(args.dataset_size // 2, 8), samples_per_client=2,
        alpha=0.0, seed=0)
    sampler = ds.make_round_sampler(args.clients_per_round)

    specs = [("dcco", {"lam": 5.0}), ("dvicreg", {}), ("dwmse", {})]
    print(f"{'objective':>10s} {'stats':>6s} {'payload B':>10s} "
          f"{'loss':>10s} {'probe':>7s} {'uplink MB':>10s}")
    for name, hyper in specs:
        obj = objectives_lib.get_objective(name, **hyper)
        ch = comm.get_channel(args.channel)
        opt = opt_lib.adam(2e-3)
        ecfg = round_engine.EngineConfig(
            algorithm="dcco", objective=obj,
            chunk_rounds=min(args.rounds, 25), channel=ch)
        eng = round_engine.RoundEngine(apply, opt, sampler, ecfg)
        p, _, m = eng.run(params0, opt.init(params0),
                          jax.random.PRNGKey(7), args.rounds)
        tmpl = obj.stat_template(de.proj_dims[-1])
        payload_b = (ch or comm.DenseChannel()).payload_bytes(tmpl)
        print(f"{name:>10s} {len(obj.stat_keys):>6d} {payload_b:>10.0f} "
              f"{float(m.loss[-1]):>10.3f} {probe(p):>7.3f} "
              f"{float(jnp.sum(m.wire_bytes)) / 1e6:>10.2f}", flush=True)
    print(f"{'random':>10s} {'-':>6s} {'-':>10s} {'-':>10s} "
          f"{probe(params0):>7.3f}")


if __name__ == "__main__":
    main()
