"""Serving example: the two inference paths of the framework.

1. Dual-encoder retrieval: encode a corpus with the (pre)trained tower,
   serve batched nearest-neighbour queries (what a deployed dual encoding
   model does — paper Sec 1's use case).
2. Generative decode: batched prefill + autoregressive serve_step with a KV
   cache (the decode shapes of the dry-run, at smoke scale).

Run: PYTHONPATH=src python examples/serve_retrieval.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.base import DualEncoderConfig, get_config
from repro.data import synthetic
from repro.launch import steps as steps_lib
from repro.models import dual_encoder

ARCH = "qwen3-1.7b"
cfg = get_config(ARCH, smoke=True)
de = DualEncoderConfig(proj_dims=(64, 64))
key = jax.random.PRNGKey(0)
params = dual_encoder.init_dual_encoder(key, cfg, de)

# ---------------------------------------------------------------- retrieval
corpus, labels = synthetic.synthetic_labeled_tokens(256, 4, 32,
                                                    vocab=cfg.vocab_size)
queries, qlabels = synthetic.synthetic_labeled_tokens(16, 4, 32,
                                                      vocab=cfg.vocab_size,
                                                      seed=9)


@jax.jit
def encode(p, toks):
    z, _ = dual_encoder.encode(cfg, de, p, {"tokens": toks})
    return z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), 1e-8)


t0 = time.time()
corpus_z = encode(params, jnp.asarray(corpus))
print(f"indexed {len(corpus)} docs in {time.time() - t0:.2f}s")

q_z = encode(params, jnp.asarray(queries))
sim = q_z @ corpus_z.T
top = jnp.argmax(sim, axis=-1)
match = (jnp.asarray(labels)[top] == jnp.asarray(qlabels)).mean()
print(f"batched retrieval: top-1 label match {float(match):.2f} "
      f"(random would be ~0.25; improves with DCCO pretraining)")

# ------------------------------------------------------------------- decode
serve = jax.jit(steps_lib.make_serve_step(cfg), donate_argnums=1)
prefill = jax.jit(steps_lib.make_prefill_step(cfg, max_len=48))
batch = {"tokens": jnp.asarray(queries[:4, :16])}
logits, cache = prefill(params["tower"], batch)
tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
outs = [tok]
t0 = time.time()
for _ in range(7):
    logits, cache = serve(params["tower"], cache, {"tokens": tok})
    tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
    outs.append(tok)
jax.block_until_ready(tok)
gen = jnp.concatenate(outs, axis=1)
print(f"decoded 8 tokens x 4 seqs in {time.time() - t0:.2f}s: "
      f"{gen[0].tolist()}")
