"""Serving example: the two inference paths of the framework.

1. Dual-encoder retrieval through the ``repro.retrieval`` subsystem (paper
   Sec 1's use case): build a ``CorpusIndex`` from the (pre)trained tower
   (chunked encode — O(chunk) activation memory), serve batched top-k
   queries via the fused MIPS search behind a ``QueryServer``, and score
   recall@k / MRR against the corpus labels. Then the PR-9 scaling tiers
   on the same embeddings: a vmap-simulated ``ShardedCorpusIndex`` (must
   match bit-for-bit), an ``IVFIndex`` pruning tier (recall vs the exact
   tier at small nprobe), and a drift-gated ``refresh`` after perturbing
   the tower (re-encodes only drifted blocks).
2. Generative decode: batched prefill + autoregressive serve_step with a KV
   cache (the decode shapes of the dry-run, at smoke scale).

Run: PYTHONPATH=src python examples/serve_retrieval.py [--docs 256]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DualEncoderConfig, get_config
from repro.core import eval as eval_lib
from repro.data import synthetic
from repro.launch import steps as steps_lib
from repro.models import dual_encoder
from repro.retrieval import (CorpusIndex, IVFIndex, QueryServer,
                             ShardedCorpusIndex, l2_normalize)

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-1.7b")
ap.add_argument("--docs", type=int, default=256)
ap.add_argument("--queries", type=int, default=16)
ap.add_argument("--k", type=int, default=10)
args = ap.parse_args()

cfg = get_config(args.arch, smoke=True)
de = DualEncoderConfig(proj_dims=(64, 64))
key = jax.random.PRNGKey(0)
params = dual_encoder.init_dual_encoder(key, cfg, de)

# ---------------------------------------------------------------- retrieval
corpus, labels = synthetic.synthetic_labeled_tokens(args.docs, 4, 32,
                                                    vocab=cfg.vocab_size)
queries, qlabels = synthetic.synthetic_labeled_tokens(args.queries, 4, 32,
                                                      vocab=cfg.vocab_size,
                                                      seed=9)


def embed(p, batch):
    z, _ = dual_encoder.encode(cfg, de, p, batch)
    return z


t0 = time.time()
index = CorpusIndex.build(embed, params, {"tokens": jnp.asarray(corpus)},
                          chunk=64)
jax.block_until_ready(index.embeddings)
print(f"indexed {index.num_items} docs (d={index.dim}) "
      f"in {time.time() - t0:.2f}s")

server = QueryServer(index, k=args.k, batch=args.queries).warmup()
q_z = l2_normalize(embed(params, {"tokens": jnp.asarray(queries)}))
_, top_idx = server.query(q_z)
metrics = eval_lib.retrieval_metrics(top_idx, jnp.asarray(qlabels),
                                     jnp.asarray(labels), ks=(1, 5, 10))
stats = server.stats()
print(f"batched retrieval: recall@1={float(metrics['recall_at_1']):.2f} "
      f"recall@5={float(metrics['recall_at_5']):.2f} "
      f"recall@10={float(metrics['recall_at_10']):.2f} "
      f"mrr={float(metrics['mrr']):.2f} "
      f"(random recall@1 ~0.25; improves with DCCO pretraining)")
print(f"served {stats['queries']} queries at p50={stats['p50_us']:.0f}us "
      f"(qps={stats['qps']:.0f} wall, {stats['qps_serial']:.0f} serial)")

# ------------------------------------------------- scaling tiers (same index)
sharded = ShardedCorpusIndex.from_index(index, num_shards=4)
sv, si = sharded.search(q_z, args.k)
assert np.array_equal(np.asarray(si), np.asarray(top_idx)), \
    "sharded search must match the flat index bit-for-bit"
print(f"sharded tier: 4 shards of {sharded.shard_size} rows, "
      f"top-{args.k} bitwise == flat index")

ivf = IVFIndex.from_index(index, num_centroids=max(8, args.docs // 16),
                          nprobe=4)
_, ai = ivf.search(q_z, args.k)
overlap = np.mean([
    len(set(np.asarray(ai)[i]) & set(np.asarray(top_idx)[i])) / args.k
    for i in range(args.queries)])
print(f"ivf tier: {ivf.num_centroids} lists (fill {ivf.fill:.2f}), "
      f"nprobe=4 scans ~{4 * ivf.list_len}/{index.num_items} rows, "
      f"recall@{args.k} vs exact = {overlap:.2f}")

# drift-gated refresh: perturb the tower (training moved the checkpoint)
# and re-encode only the blocks whose drift probes cross the threshold —
# drift is heterogeneous across the corpus, so a threshold between the
# mean and max block drift refreshes the hot blocks and skips the rest
moved = jax.tree.map(
    lambda x: x + 0.003 * jax.random.normal(jax.random.PRNGKey(3), x.shape,
                                            x.dtype), params)
rstats = index.refresh(embed, moved, {"tokens": jnp.asarray(corpus)},
                       threshold=0.3, block=32)
print(f"refresh: {rstats['blocks_refreshed']:.0f} blocks re-encoded "
      f"({rstats['items_encoded']:.0f} items incl. probes, vs "
      f"{index.num_items} for a full rebuild)")

# ------------------------------------------------------------------- decode
serve = jax.jit(steps_lib.make_serve_step(cfg), donate_argnums=1)
prefill = jax.jit(steps_lib.make_prefill_step(cfg, max_len=48))
batch = {"tokens": jnp.asarray(queries[:4, :16])}
logits, cache = prefill(params["tower"], batch)
tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
outs = [tok]
t0 = time.time()
for _ in range(7):
    logits, cache = serve(params["tower"], cache, {"tokens": tok})
    tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)
    outs.append(tok)
jax.block_until_ready(tok)
gen = jnp.concatenate(outs, axis=1)
print(f"decoded 8 tokens x 4 seqs in {time.time() - t0:.2f}s: "
      f"{gen[0].tolist()}")
